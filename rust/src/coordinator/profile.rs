//! Module/block profiler: measured fwd+bwd wall time (via the PJRT
//! artifacts) joined with the analytic memory model — the machinery
//! behind Tables 1 & 4 and Fig. 8.
//!
//! Equivalent of the paper's `script/profile.py` (§A.3).

use anyhow::{Context, Result};

use crate::config::{presets, Mode};
use crate::memmodel::{block_peak, BlockWorkload, Module};
use crate::metrics::{bench, BenchResult};
use crate::runtime::{Engine, HostTensor};
use crate::util::rng::Rng;

/// One profiled measurement.
#[derive(Debug, Clone)]
pub struct ProfileRow {
    pub artifact: String,
    pub config: String,
    pub mode: String,
    pub variant: String,
    /// Median wall time of one execution (fwd+bwd) on this testbed.
    pub time: BenchResult,
    /// Analytic peak memory at the *paper's* workload (bs 16, seq 512).
    pub model_mem_bytes: u64,
    /// Tokens processed per second at the measured workload.
    pub tokens_per_sec: f64,
}

/// Build random inputs matching an artifact signature.
pub fn random_inputs(engine: &Engine, name: &str, seed: u64) -> Result<Vec<HostTensor>> {
    let spec = engine.spec(name)?.clone();
    let mut rng = Rng::new(seed);
    spec.inputs
        .iter()
        .map(|s| {
            Ok(match s.dtype {
                crate::runtime::DType::F32 => {
                    HostTensor::randn(s.shape.clone(), 0.5, &mut rng)
                }
                _ => HostTensor::zeros(s)?,
            })
        })
        .collect()
}

/// Initialize block params via the block-init artifact, then assemble
/// step inputs (params..., x).
pub fn block_step_inputs(
    engine: &Engine,
    cfg_name: &str,
    mode: Mode,
    seed: u64,
) -> Result<Vec<HostTensor>> {
    let init = format!("block_init_{cfg_name}_{}", mode.as_str());
    let step = format!("block_step_{cfg_name}_{}", mode.as_str());
    let params = engine.run(&init, &[HostTensor::scalar_i32(seed as i32)])?;
    let spec = engine.spec(&step)?;
    let x_spec = spec.inputs.last().context("block step has inputs")?;
    let mut rng = Rng::new(seed);
    let mut inputs = params;
    inputs.push(HostTensor::randn(x_spec.shape.clone(), 1.0, &mut rng));
    Ok(inputs)
}

/// Profile one block-step artifact (Fig. 8 measurement).
pub fn profile_block(
    engine: &Engine,
    cfg_name: &str,
    mode: Mode,
    warmup: usize,
    samples: usize,
) -> Result<ProfileRow> {
    let name = format!("block_step_{cfg_name}_{}", mode.as_str());
    let spec = engine.spec(&name)?.clone();
    let batch = spec.meta_usize("batch").unwrap_or(1);
    let seq = spec.meta_usize("seq").unwrap_or(128);
    let inputs = block_step_inputs(engine, cfg_name, mode, 7)?;
    engine.load(&name)?; // compile outside the timed region
    let time = bench(&name, warmup, samples, || {
        engine.run(&name, &inputs).expect("block step");
    });
    let cfg = presets::block(cfg_name)?;
    let mem = block_peak(&cfg, mode, &BlockWorkload { batch: 16, seq: 512 });
    let tps = (batch * seq) as f64 / time.median();
    Ok(ProfileRow {
        artifact: name,
        config: cfg_name.to_string(),
        mode: mode.as_str().to_string(),
        variant: mode.as_str().to_string(),
        time,
        model_mem_bytes: mem.peak_bytes(),
        tokens_per_sec: tps,
    })
}

/// Profile a module-level artifact (`mha_*` / `ffn_*`, Tables 1/4/5).
/// `variant` is e.g. "full", "lora", "spt_l8", "spt_b12".
pub fn profile_module(
    engine: &Engine,
    kind: &str, // "mha" | "ffn"
    cfg_name: &str,
    variant: &str,
    warmup: usize,
    samples: usize,
) -> Result<ProfileRow> {
    let name = format!("{kind}_{cfg_name}_{variant}");
    let spec = engine.spec(&name)?.clone();
    let batch = spec.meta_usize("batch").unwrap_or(1);
    let seq = spec.meta_usize("seq").unwrap_or(128);
    let inputs = random_inputs(engine, &name, 11)?;
    engine.load(&name)?;
    let time = bench(&name, warmup, samples, || {
        engine.run(&name, &inputs).expect("module step");
    });
    // Memory at paper workload, restricted to the module.
    let mut cfg = presets::block(cfg_name)?;
    let mode = match variant {
        "full" => Mode::Full,
        "lora" => Mode::Lora,
        _ => Mode::Spt,
    };
    // Sparsity variants encode their fraction in the tag.
    match variant {
        "spt_l4" => cfg.sparsity.mha_den = 4,
        "spt_l8" => cfg.sparsity.mha_den = 8,
        "spt_b34" => {
            cfg.sparsity.ffn_num = 3;
            cfg.sparsity.ffn_den = 4;
        }
        "spt_b12" => {
            cfg.sparsity.ffn_num = 1;
            cfg.sparsity.ffn_den = 2;
        }
        _ => {}
    }
    let module = if kind == "mha" { Module::Mha } else { Module::Ffn };
    let mem = block_peak(&cfg, mode, &BlockWorkload { batch: 16, seq: 512 })
        .module_peak(module);
    let tps = (batch * seq) as f64 / time.median();
    Ok(ProfileRow {
        artifact: name,
        config: cfg_name.to_string(),
        mode: mode.as_str().to_string(),
        variant: variant.to_string(),
        time,
        model_mem_bytes: mem,
        tokens_per_sec: tps,
    })
}
