//! L3 runtime: PJRT client wrapper that loads and executes the AOT
//! artifacts produced by `python/compile/aot.py`.
//!
//! * [`manifest`] — parsed `artifacts/manifest.json` (signatures + metadata)
//! * [`tensor`]   — host tensors + literal marshalling
//! * [`engine`]   — compile cache + execution (literal and buffer paths)
//! * [`goldens`]  — numeric round-trip validation against python outputs

pub mod engine;
pub mod goldens;
pub mod manifest;
pub mod tensor;

pub use engine::{DeviceState, Engine, ExecStats};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
pub use tensor::HostTensor;
