//! Paper Fig. 5: CDF of normalized singular values of the FFN inner
//! projection matrix W_I, the input features X, and the projection output
//! H = relu(X W_I) — the motivation for *dynamic* (not static) FFN
//! pruning: W_I is near-full-rank, H is low-rank.

mod common;

use spt::metrics::Table;
use spt::sparse::svd::singular_value_cdf;
use spt::sparse::Matrix;
use spt::util::rng::Rng;

fn main() {
    // Scaled-down FFN (Jacobi SVD at bench scale); shape, not size, is
    // what Fig. 5 shows.  d=128, D=512, n=256 tokens.
    let (n, d, dd) = (256usize, 128usize, 512usize);
    let mut rng = Rng::new(11);
    let w_i = Matrix::randn(d, dd, 1.0 / (d as f32).sqrt(), &mut rng);
    // Token features with low-rank structure (embeddings live near a
    // subspace — this is what trained feature matrices look like).
    let basis = Matrix::randn(24, d, 1.0, &mut rng);
    let coef = Matrix::randn(n, 24, 1.0, &mut rng);
    let x = coef.matmul(&basis);
    let h = x.matmul(&w_i).relu();

    let cdf_w = singular_value_cdf(&w_i, 20);
    let cdf_x = singular_value_cdf(&x, 20);
    let cdf_h = singular_value_cdf(&h, 20);

    let mut table = Table::new(
        "Fig. 5 — CDF of normalized singular values (FFN, scaled shape)",
        &["fraction of singular values", "W_I (weights)", "X (input)", "H = relu(X W_I)"],
    );
    for i in 0..cdf_w.len().min(cdf_x.len()).min(cdf_h.len()) {
        table.row(&[
            format!("{:.2}", cdf_w[i].0),
            format!("{:.3}", cdf_w[i].1),
            format!("{:.3}", cdf_x[i].1),
            format!("{:.3}", cdf_h[i].1),
        ]);
    }
    common::emit("fig5_svd_cdf", &table);

    let at25 = |cdf: &[(f32, f32)]| {
        cdf.iter().find(|(f, _)| *f >= 0.25).map(|(_, m)| *m).unwrap_or(0.0)
    };
    println!(
        "[fig5] energy in top-25% singular values: W_I {:.0}% (near-linear => full rank), H {:.0}% (paper: >50% => low rank)",
        at25(&cdf_w) * 100.0,
        at25(&cdf_h) * 100.0
    );
    assert!(at25(&cdf_h) > at25(&cdf_w), "H must be lower-rank than W_I");
}
