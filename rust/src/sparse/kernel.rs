//! Register-blocked GEMM microkernel and the shared axpy/dot helpers.
//!
//! The kernel multiplies MR rows of A against one packed-B panel at a
//! time, keeping the partial products in fixed-width `[f32; LANES]`
//! accumulator arrays so the compiler can hold them in vector registers
//! and autovectorize the lane loop (the workspace forbids `unsafe`, so
//! there are no intrinsics here — the shape of the code is the whole
//! optimization).
//!
//! Determinism contract: vectorization runs across the *column*
//! dimension only.  Every output element `out[i][j]` is the plain
//! ascending-`k` sum `Σ a[i][k] * b[k][j]`, with the multiply and the
//! add kept as separate statements so LLVM does not contract them into
//! an FMA (Rust never does so by default).  Splitting the columns into
//! lane strips never reorders any single element's addition chain, so
//! the result is bit-identical to the naive triple loop by
//! construction, at any blocking and any thread count.
//!
//! Accumulators are loaded from and stored back to `out` at K-block
//! boundaries; an f32 store/load roundtrip is exact, so carrying the
//! partial sums through `out` between KC blocks preserves the single
//! ascending-`k` chain.

/// Rows of A processed together by the register-blocked kernel.
pub const MR: usize = 4;

/// Width of one accumulator vector.  Eight f32 lanes fill one AVX2
/// register (256 bits) and two NEON registers — a shape current
/// autovectorizers handle reliably.
pub const LANES: usize = 8;

/// One register tile: `R` rows of A against a `V * LANES`-wide column
/// strip of the packed panel, accumulating `kb..kend` in ascending
/// order.  `panel` is the packed B block (row-major `w`-wide rows per
/// packed `k`), `j` the column offset of the strip inside the panel,
/// and `out` the full output matrix (row stride `n`, panel origin
/// column `p0`).
fn tile<const R: usize, const V: usize>(
    arows: &[&[f32]; R],
    panel: &[f32],
    w: usize,
    j: usize,
    kb: usize,
    kend: usize,
    out: &mut [f32],
    n: usize,
    p0: usize,
) {
    let mut acc = [[[0.0f32; LANES]; V]; R];
    for (r, accr) in acc.iter_mut().enumerate() {
        let base = r * n + p0 + j;
        for (v, lane) in accr.iter_mut().enumerate() {
            lane.copy_from_slice(&out[base + v * LANES..base + (v + 1) * LANES]);
        }
    }
    for kk in kb..kend {
        let brow = &panel[kk * w + j..kk * w + j + V * LANES];
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = arows[r][kk];
            for (v, lane) in accr.iter_mut().enumerate() {
                for (o, &bv) in lane.iter_mut().zip(&brow[v * LANES..(v + 1) * LANES]) {
                    let prod = av * bv;
                    *o += prod;
                }
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let base = r * n + p0 + j;
        for (v, lane) in accr.iter().enumerate() {
            out[base + v * LANES..base + (v + 1) * LANES].copy_from_slice(lane);
        }
    }
}

/// Scalar column tail for strips narrower than one vector: each
/// remaining output element accumulates its own ascending-`k` chain.
fn tail_cols<const R: usize>(
    arows: &[&[f32]; R],
    panel: &[f32],
    w: usize,
    j0: usize,
    kb: usize,
    kend: usize,
    out: &mut [f32],
    n: usize,
    p0: usize,
) {
    for (r, arow) in arows.iter().enumerate() {
        for j in j0..w {
            let mut acc = out[r * n + p0 + j];
            for kk in kb..kend {
                let prod = arow[kk] * panel[kk * w + j];
                acc += prod;
            }
            out[r * n + p0 + j] = acc;
        }
    }
}

/// Sweep one group of `R` A-rows across the full panel width: two
/// vectors at a time, then one, then the scalar tail.
fn row_group<const R: usize>(
    arows: &[&[f32]; R],
    panel: &[f32],
    w: usize,
    kb: usize,
    kend: usize,
    out: &mut [f32],
    n: usize,
    p0: usize,
) {
    let mut j = 0;
    while j + 2 * LANES <= w {
        tile::<R, 2>(arows, panel, w, j, kb, kend, out, n, p0);
        j += 2 * LANES;
    }
    if j + LANES <= w {
        tile::<R, 1>(arows, panel, w, j, kb, kend, out, n, p0);
        j += LANES;
    }
    if j < w {
        tail_cols::<R>(arows, panel, w, j, kb, kend, out, n, p0);
    }
}

/// Register-blocked block GEMM: accumulate `a[0..rows] x panel` over
/// `kb..kend` into `out`.  `a` holds exactly `rows` rows of stride `k`;
/// `panel` is one packed B panel of width `w` whose packed rows run
/// over the full `k` range; `out` is the caller's output block with row
/// stride `n` and the panel's columns starting at `p0`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_block(
    rows: usize,
    k: usize,
    kb: usize,
    kend: usize,
    n: usize,
    p0: usize,
    w: usize,
    a: &[f32],
    panel: &[f32],
    out: &mut [f32],
) {
    let mut i = 0;
    while i + MR <= rows {
        let arows: [&[f32]; MR] = [
            &a[i * k..(i + 1) * k],
            &a[(i + 1) * k..(i + 2) * k],
            &a[(i + 2) * k..(i + 3) * k],
            &a[(i + 3) * k..(i + 4) * k],
        ];
        row_group::<MR>(&arows, panel, w, kb, kend, &mut out[i * n..], n, p0);
        i += MR;
    }
    while i < rows {
        let arows: [&[f32]; 1] = [&a[i * k..(i + 1) * k]];
        row_group::<1>(&arows, panel, w, kb, kend, &mut out[i * n..], n, p0);
        i += 1;
    }
}

/// `acc += a * x`, element-wise, in lane strips of [`LANES`].  Each
/// output element sees exactly one multiply and one add, in the same
/// order as the plain zip loop, so this is bit-identical to the scalar
/// version — the strip split only helps the autovectorizer.
pub fn axpy(acc: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (astrip, xstrip) in ac.by_ref().zip(xc.by_ref()) {
        for (o, &xv) in astrip.iter_mut().zip(xstrip) {
            let prod = a * xv;
            *o += prod;
        }
    }
    for (o, &xv) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        let prod = a * xv;
        *o += prod;
    }
}

/// Ascending-order dot product.  Deliberately scalar: splitting a
/// reduction into lanes would change the summation order and break bit
/// identity with the reference `Σ a[i] * b[i]` chain, so the only
/// freedom here is what the compiler can prove without reassociation.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (&av, &bv) in a.iter().zip(b) {
        let prod = av * bv;
        acc += prod;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Naive ascending-k reference for one packed panel.
    fn naive_block(
        rows: usize,
        k: usize,
        kb: usize,
        kend: usize,
        n: usize,
        p0: usize,
        w: usize,
        a: &[f32],
        panel: &[f32],
        out: &mut [f32],
    ) {
        for i in 0..rows {
            for j in 0..w {
                let mut acc = out[i * n + p0 + j];
                for kk in kb..kend {
                    acc += a[i * k + kk] * panel[kk * w + j];
                }
                out[i * n + p0 + j] = acc;
            }
        }
    }

    #[test]
    fn gemm_block_matches_naive_bitwise_over_ragged_shapes() {
        let mut rng = Rng::new(71);
        // Row counts around MR, widths around the 2-vector/1-vector/
        // scalar strip boundaries, and split k ranges.
        for &(rows, k, w) in &[
            (1usize, 5usize, 1usize),
            (3, 9, 7),
            (4, 16, 8),
            (5, 33, 16),
            (6, 40, 17),
            (9, 21, 24),
            (11, 64, 37),
        ] {
            let n = w + 3; // out wider than the panel: p0 offset in play
            let p0 = 2;
            let a = rng.normal_vec(rows * k);
            let panel = rng.normal_vec(k * w);
            let mut got = rng.normal_vec(rows * n);
            let mut want = got.clone();
            // Two K blocks to exercise the load/accumulate/store path.
            let kmid = k / 2;
            gemm_block(rows, k, 0, kmid, n, p0, w, &a, &panel, &mut got);
            gemm_block(rows, k, kmid, k, n, p0, w, &a, &panel, &mut got);
            naive_block(rows, k, 0, kmid, n, p0, w, &a, &panel, &mut want);
            naive_block(rows, k, kmid, k, n, p0, w, &a, &panel, &mut want);
            let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "rows={rows} k={k} w={w}");
        }
    }

    #[test]
    fn axpy_matches_scalar_loop_bitwise() {
        let mut rng = Rng::new(72);
        for &len in &[1usize, 7, 8, 9, 31, 64, 100] {
            let x = rng.normal_vec(len);
            let base = rng.normal_vec(len);
            let a = 0.37f32;
            let mut got = base.clone();
            axpy(&mut got, a, &x);
            let mut want = base;
            for (o, &xv) in want.iter_mut().zip(&x) {
                *o += a * xv;
            }
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "len={len}");
        }
    }

    #[test]
    fn dot_matches_iterator_sum_bitwise() {
        let mut rng = Rng::new(73);
        for &len in &[0usize, 1, 8, 13, 100] {
            let a = rng.normal_vec(len);
            let b = rng.normal_vec(len);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert_eq!(dot(&a, &b).to_bits(), want.to_bits(), "len={len}");
        }
    }
}
