//! Paper Table 4: MHA/FFN running time + peak memory at different
//! sparsity strengths, for OPT-2048 and LLaMA-4096.
//!
//! Paper shape to reproduce: sparse MHA memory drops with stronger
//! sparsity (1/4 -> 1/8) while its time stays ~LoRA-level; routed FFN
//! time drops near-theoretically with beta (3/4 -> ~1.3x, 1/2 -> ~2x)
//! while its memory barely moves.

mod common;

use spt::coordinator::profile::profile_module;
use spt::metrics::Table;
use spt::util::{fmt_bytes, fmt_duration};

fn main() {
    let Some(engine) = common::engine_or_skip("table4") else { return };
    let (w, s) = (common::warmup(), common::samples());
    for cfg in ["opt-2048", "llama-4096"] {
        let mut table = Table::new(
            &format!("Table 4 — module cost vs sparsity ({cfg})"),
            &["Module", "Method", "Peak Mem @bs16,seq512", "Duration", "vs lora"],
        );
        for (kind, variants) in [
            ("mha", ["lora", "spt_l4", "spt_l8"].as_slice()),
            ("ffn", ["lora", "spt_b34", "spt_b12"].as_slice()),
        ] {
            let mut lora_time = None;
            for v in variants {
                let name = format!("{kind}_{cfg}_{v}");
                if engine.manifest().get(&name).is_err() {
                    println!("[table4] missing artifact {name}, skipping row");
                    continue;
                }
                let row = profile_module(&engine, kind, cfg, v, w, s)
                    .expect("module profile");
                if *v == "lora" {
                    lora_time = Some(row.time.median());
                }
                table.row(&[
                    kind.to_uppercase(),
                    format!("SPT ({v})").replace("SPT (lora)", "LoRA"),
                    fmt_bytes(row.model_mem_bytes),
                    fmt_duration(row.time.median()),
                    lora_time
                        .map(|t| format!("{:.2}x", t / row.time.median()))
                        .unwrap_or_default(),
                ]);
            }
        }
        common::emit(&format!("table4_{}", cfg.replace('-', "_")), &table);
    }
}
