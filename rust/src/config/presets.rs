//! Built-in configurations: the paper's Table 2 blocks and the e2e models.
//! Must stay in sync with `python/compile/model.py` (`BLOCK_CONFIGS`,
//! `MODEL_CONFIGS`) — artifact names embed these config names.

use anyhow::{bail, Result};

use super::{Activation, BlockConfig, ModelConfig, Sparsity};

fn mk(
    name: &str,
    d_model: usize,
    d_head: usize,
    d_ffn: usize,
    activation: Activation,
    rotary: bool,
) -> BlockConfig {
    BlockConfig {
        name: name.into(),
        d_model,
        d_head,
        d_ffn,
        activation,
        rotary,
        lora_rank: 16,
        pq_dsub: 8,
        pq_codewords: 16,
        ffn_groups: 8,
        sparsity: Sparsity::default(),
    }
}

/// The paper's five Table 2 blocks + scaled-down shapes.
pub fn blocks() -> Vec<BlockConfig> {
    vec![
        mk("opt-1024", 1024, 64, 4096, Activation::Relu, false),
        mk("opt-2048", 2048, 64, 8192, Activation::Relu, false),
        mk("opt-2560", 2560, 80, 10240, Activation::Relu, false),
        mk("llama-2560", 2560, 128, 6912, Activation::Gelu, true),
        mk("llama-4096", 4096, 128, 11008, Activation::Gelu, true),
        mk("gpt-768", 768, 64, 3072, Activation::Relu, false),
        mk("mini-512", 512, 64, 2048, Activation::Relu, false),
        mk("mini-256", 256, 32, 1024, Activation::Relu, false),
        mk("mini-64", 64, 16, 256, Activation::Relu, false),
    ]
}

pub fn block(name: &str) -> Result<BlockConfig> {
    blocks()
        .into_iter()
        .find(|b| b.name == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown block config '{name}' (have: {})",
                blocks().iter().map(|b| b.name.clone()).collect::<Vec<_>>().join(", ")
            )
        })
}

/// The five paper-scale blocks only (Table 2 order, for Fig. 8 benches).
pub fn paper_blocks() -> Vec<BlockConfig> {
    ["opt-1024", "opt-2048", "opt-2560", "llama-2560", "llama-4096"]
        .iter()
        .map(|n| block(n).unwrap())
        .collect()
}

/// End-to-end model configs (mirror of python MODEL_CONFIGS).
pub fn models() -> Vec<ModelConfig> {
    vec![
        ModelConfig {
            name: "spt-100m".into(),
            block: block("gpt-768").unwrap(),
            n_layers: 12,
            vocab_size: 16384,
            max_seq: 512,
        },
        ModelConfig {
            name: "spt-30m".into(),
            block: block("mini-512").unwrap(),
            n_layers: 8,
            vocab_size: 8192,
            max_seq: 256,
        },
        ModelConfig {
            name: "spt-tiny".into(),
            block: block("mini-256").unwrap(),
            n_layers: 4,
            vocab_size: 4096,
            max_seq: 128,
        },
        // Bench-scale config over the mini-64 block: big enough that a
        // fine-tune step is GEMM-bound (the table3 native-step bench's
        // thread-scaling target), small enough for CI.
        ModelConfig {
            name: "spt-mini-64".into(),
            block: block("mini-64").unwrap(),
            n_layers: 1,
            vocab_size: 2048,
            max_seq: 128,
        },
        // Depth-bearing bench config: the GEMM-bound mini-64 block
        // stacked 4 layers deep, for the native multi-layer train-step
        // benches (`SPT_TABLE3_NATIVE_MODEL=spt-mini-64-l4`).
        ModelConfig {
            name: "spt-mini-64-l4".into(),
            block: block("mini-64").unwrap(),
            n_layers: 4,
            vocab_size: 2048,
            max_seq: 128,
        },
        // Serving bench config: mini-64 stacked 2 deep — deep enough
        // that continuous batching amortizes real per-layer decode work.
        // CI's recorded trajectory point stays on spt-mini-64; run
        // `SPT_DECODE_BENCH_MODEL=spt-mini-64-l2 cargo bench --bench
        // decode_throughput` for the multi-layer serving measurement.
        ModelConfig {
            name: "spt-mini-64-l2".into(),
            block: block("mini-64").unwrap(),
            n_layers: 2,
            vocab_size: 2048,
            max_seq: 128,
        },
        // Test-scale config for the native backend's fast paths (tests,
        // doc examples); small enough that a full fwd+bwd step is
        // milliseconds on one core.
        ModelConfig {
            name: "spt-nano".into(),
            block: block("mini-64").unwrap(),
            n_layers: 1,
            vocab_size: 512,
            max_seq: 64,
        },
        // spt-nano stacked two layers deep: the smallest model that
        // exercises the multi-layer native path (inter-layer gradient
        // flow, per-layer codebooks, depth-aware checkpoints) in tests.
        ModelConfig {
            name: "spt-nano-l2".into(),
            block: block("mini-64").unwrap(),
            n_layers: 2,
            vocab_size: 512,
            max_seq: 64,
        },
    ]
}

pub fn model(name: &str) -> Result<ModelConfig> {
    match models().into_iter().find(|m| m.name == name) {
        Some(m) => Ok(m),
        None => bail!(
            "unknown model config '{name}' (have: {})",
            models().iter().map(|m| m.name.clone()).collect::<Vec<_>>().join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shapes_match_paper() {
        let rows: Vec<(&str, usize, usize, usize)> = vec![
            ("opt-1024", 1024, 64, 4096),
            ("opt-2048", 2048, 64, 8192),
            ("opt-2560", 2560, 80, 10240),
            ("llama-2560", 2560, 128, 6912),
            ("llama-4096", 4096, 128, 11008),
        ];
        for (name, dm, dh, df) in rows {
            let b = block(name).unwrap();
            assert_eq!((b.d_model, b.d_head, b.d_ffn), (dm, dh, df), "{name}");
        }
    }

    #[test]
    fn paper_blocks_ordered() {
        let names: Vec<String> =
            paper_blocks().iter().map(|b| b.name.clone()).collect();
        assert_eq!(
            names,
            ["opt-1024", "opt-2048", "opt-2560", "llama-2560", "llama-4096"]
        );
    }

    #[test]
    fn model_param_counts() {
        // spt-100m should be ~100M parameters.
        let m = model("spt-100m").unwrap();
        let p = m.param_count();
        assert!((90_000_000..130_000_000).contains(&p), "{p}");
        let t = model("spt-tiny").unwrap();
        assert!(t.param_count() < 10_000_000);
    }

    #[test]
    fn heads_divide_evenly() {
        for b in blocks() {
            assert_eq!(b.d_model % b.d_head, 0, "{}", b.name);
            assert_eq!(b.d_head % b.pq_dsub, 0, "{}", b.name);
            assert!(b.n_heads() >= 8 || b.name.starts_with("mini"));
        }
    }

    #[test]
    fn unknown_names_error() {
        assert!(block("opt-9999").is_err());
        assert!(model("nope").is_err());
    }

    #[test]
    fn depth_variants_share_their_base_shape() {
        // The -l2/-l4 presets differ from their base only in depth, so
        // loss curves compare apples to apples across depths.
        let nano = model("spt-nano").unwrap();
        let nano2 = model("spt-nano-l2").unwrap();
        assert_eq!(nano.block, nano2.block);
        assert_eq!(nano.vocab_size, nano2.vocab_size);
        assert_eq!(nano.max_seq, nano2.max_seq);
        assert_eq!(nano2.n_layers, 2);
        let mini = model("spt-mini-64").unwrap();
        let mini4 = model("spt-mini-64-l4").unwrap();
        assert_eq!(mini.block, mini4.block);
        assert_eq!(mini4.n_layers, 4);
        let mini2 = model("spt-mini-64-l2").unwrap();
        assert_eq!(mini.block, mini2.block);
        assert_eq!(mini.max_seq, mini2.max_seq);
        assert_eq!(mini2.n_layers, 2);
    }
}
