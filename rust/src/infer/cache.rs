//! The decode cache: per-layer, per-head K/V matrices plus (spt mode)
//! the PQ codes of every cached key.
//!
//! Keys and values append row by row as decode advances; codes append
//! through [`pq::quantize_append`], so the cached code matrix is always
//! bit-identical to a fresh quantization of the cached keys — which is
//! exactly what the training forward's top-L selection consumes.

use anyhow::{bail, Result};

use crate::sparse::pq::{self, Codebooks};
use crate::sparse::{Codes, Matrix};

/// One layer's cached decode state.
pub struct LayerCache {
    /// Per-head cached keys, `[len, d_head]` each.
    pub k: Vec<Matrix>,
    /// Per-head cached values, `[len, d_head]` each.
    pub v: Vec<Matrix>,
    /// spt only: per-head PQ codes of the cached keys (`[len, M]`).
    pub codes: Option<Vec<Codes>>,
}

/// Per-sequence decode cache: one [`LayerCache`] per transformer layer.
pub struct DecodeCache {
    pub layers: Vec<LayerCache>,
}

impl DecodeCache {
    /// An empty cache for an `n_layers`-deep model.  `pq_m` is `Some`
    /// (the per-head subspace count) in spt mode, `None` otherwise.
    pub fn new(n_layers: usize, heads: usize, d_head: usize, pq_m: Option<usize>) -> Self {
        let layers = (0..n_layers)
            .map(|_| LayerCache {
                k: (0..heads).map(|_| Matrix::zeros(0, d_head)).collect(),
                v: (0..heads).map(|_| Matrix::zeros(0, d_head)).collect(),
                codes: pq_m.map(|m| (0..heads).map(|_| Codes::zeros(0, m)).collect()),
            })
            .collect();
        DecodeCache { layers }
    }

    /// Cached positions (every layer and head stays in lockstep).
    pub fn len(&self) -> usize {
        self.layers
            .first()
            .and_then(|lc| lc.k.first())
            .map(|m| m.rows)
            .unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one position's K/V rows (`[heads * d_head]` concatenated
    /// head-major, the projection row layout) to layer `li`, quantizing
    /// the new key against `cbs` when this cache carries codes.
    pub fn append(
        &mut self,
        li: usize,
        k_row: &[f32],
        v_row: &[f32],
        cbs: Option<&[Codebooks]>,
    ) -> Result<()> {
        let lc = &mut self.layers[li];
        let heads = lc.k.len();
        let dh = lc.k[0].cols;
        if k_row.len() != heads * dh || v_row.len() != heads * dh {
            bail!(
                "append row has {} values, cache wants {} heads x {}",
                k_row.len(),
                heads,
                dh
            );
        }
        if lc.codes.is_some() && cbs.is_none() {
            bail!("cache carries PQ codes but no codebooks were supplied");
        }
        for h in 0..heads {
            let seg = h * dh..(h + 1) * dh;
            lc.k[h].rows += 1;
            lc.k[h].data.extend_from_slice(&k_row[seg.clone()]);
            lc.v[h].rows += 1;
            lc.v[h].data.extend_from_slice(&v_row[seg.clone()]);
            if let (Some(codes), Some(cbs)) = (&mut lc.codes, cbs) {
                pq::quantize_append(&k_row[seg], &cbs[h], &mut codes[h]);
            }
        }
        Ok(())
    }

    /// Measured bytes held by this cache (K/V floats + code bytes) —
    /// the runtime twin of the analytic `memmodel::decode` accounting.
    pub fn bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|lc| {
                let kv: usize = lc.k.iter().chain(&lc.v).map(Matrix::bytes).sum();
                let codes: usize = lc
                    .codes
                    .as_ref()
                    .map(|cs| cs.iter().map(Codes::bytes).sum())
                    .unwrap_or(0);
                kv + codes
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn append_grows_all_heads_in_lockstep() {
        let mut cache = DecodeCache::new(2, 3, 4, Some(2));
        let mut rng = Rng::new(1);
        let cbs: Vec<Codebooks> =
            (0..3).map(|_| Codebooks::random(2, 4, 2, &mut rng)).collect();
        assert!(cache.is_empty());
        for pos in 0..5 {
            for li in 0..2 {
                let k: Vec<f32> = rng.normal_vec(12);
                let v: Vec<f32> = rng.normal_vec(12);
                cache.append(li, &k, &v, Some(&cbs)).unwrap();
            }
            assert_eq!(cache.len(), pos + 1);
        }
        for lc in &cache.layers {
            for h in 0..3 {
                assert_eq!(lc.k[h].rows, 5);
                assert_eq!(lc.v[h].rows, 5);
                assert_eq!(lc.codes.as_ref().unwrap()[h].n, 5);
            }
        }
        // 2 layers x 3 heads x (2 x 5 x 4 floats) + codes 2x3x(5x2 bytes)
        assert_eq!(cache.bytes(), 2 * 3 * 2 * 5 * 4 * 4 + 2 * 3 * 5 * 2);
    }

    #[test]
    fn append_rejects_wrong_row_width_and_missing_codebooks() {
        let mut cache = DecodeCache::new(1, 2, 4, Some(2));
        assert!(cache.append(0, &[0.0; 4], &[0.0; 8], None).is_err());
        assert!(cache.append(0, &[0.0; 8], &[0.0; 8], None).is_err());
        let mut dense = DecodeCache::new(1, 2, 4, None);
        dense.append(0, &[0.0; 8], &[0.0; 8], None).unwrap();
        assert_eq!(dense.len(), 1);
        assert!(dense.layers[0].codes.is_none());
    }
}
