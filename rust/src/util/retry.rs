//! Deterministic capped exponential backoff for transient I/O errors.
//!
//! The delay schedule is a pure function of the attempt index —
//! `min(cap, base << attempt)`, no jitter — because the callers are
//! single-process local I/O (checkpoint writes, a loopback listener
//! accept), not a distributed thundering herd, and this repo's signature
//! property is that nothing observable depends on randomness or wall
//! clocks.  Injected [`crate::util::fault::Crash`] errors are fatal by
//! design: a retry loop that "survives" a crash would mask exactly the
//! failure mode the chaos tests exist to exercise.

use std::time::Duration;

use anyhow::{Context, Result};

use super::fault;

/// Retry policy: `attempts` total tries, sleeping
/// `min(cap, base * 2^i)` after the i-th failure.
#[derive(Debug, Clone)]
pub struct Backoff {
    pub attempts: u32,
    pub base: Duration,
    pub cap: Duration,
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff { attempts: 3, base: Duration::from_millis(5), cap: Duration::from_millis(50) }
    }
}

impl Backoff {
    /// A no-sleep policy for tests (still `attempts` tries).
    pub fn immediate(attempts: u32) -> Self {
        Backoff { attempts, base: Duration::ZERO, cap: Duration::ZERO }
    }

    /// Deterministic delay before retry `attempt` (0-based).
    pub fn delay(&self, attempt: u32) -> Duration {
        let factor = 1u32.checked_shl(attempt).unwrap_or(u32::MAX);
        self.base.saturating_mul(factor).min(self.cap)
    }
}

/// Run `op` under the policy.  `op` receives the 0-based attempt index.
/// Crash-marked errors ([`fault::is_crash`]) abort immediately; other
/// errors are retried until the attempt budget is spent.
pub fn retry<T>(policy: &Backoff, label: &str, mut op: impl FnMut(u32) -> Result<T>) -> Result<T> {
    let attempts = policy.attempts.max(1);
    let mut last_err = None;
    for attempt in 0..attempts {
        match op(attempt) {
            Ok(v) => return Ok(v),
            Err(e) if fault::is_crash(&e) => return Err(e),
            Err(e) => {
                let delay = policy.delay(attempt);
                if attempt + 1 < attempts && !delay.is_zero() {
                    std::thread::sleep(delay);
                }
                last_err = Some(e);
            }
        }
    }
    Err(last_err.unwrap_or_else(|| anyhow::anyhow!("retry with zero attempts")))
        .with_context(|| format!("{label}: failed after {attempts} attempts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn delay_schedule_is_capped_exponential() {
        let b = Backoff {
            attempts: 5,
            base: Duration::from_millis(5),
            cap: Duration::from_millis(32),
        };
        assert_eq!(b.delay(0), Duration::from_millis(5));
        assert_eq!(b.delay(1), Duration::from_millis(10));
        assert_eq!(b.delay(2), Duration::from_millis(20));
        assert_eq!(b.delay(3), Duration::from_millis(32), "capped");
        assert_eq!(b.delay(31), Duration::from_millis(32), "shift saturates");
    }

    #[test]
    fn transient_errors_recover() {
        let mut calls = 0;
        let out = retry(&Backoff::immediate(3), "op", |attempt| {
            calls += 1;
            if attempt < 2 {
                Err(anyhow!("transient"))
            } else {
                Ok(attempt)
            }
        })
        .unwrap();
        assert_eq!(out, 2);
        assert_eq!(calls, 3);
    }

    #[test]
    fn budget_exhaustion_reports_the_label() {
        let err = retry(&Backoff::immediate(2), "writing ckpt", |_| {
            Err::<(), _>(anyhow!("disk full"))
        })
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("writing ckpt"), "{msg}");
        assert!(msg.contains("2 attempts"), "{msg}");
        assert!(msg.contains("disk full"), "{msg}");
    }

    #[test]
    fn crashes_are_never_retried() {
        let mut calls = 0;
        let err = retry(&Backoff::immediate(5), "op", |_| {
            calls += 1;
            Err::<(), _>(anyhow::Error::from(std::io::Error::other(fault::Crash {
                site: "ckpt_crash".into(),
            })))
        })
        .unwrap_err();
        assert_eq!(calls, 1, "crash aborts the loop");
        assert!(fault::is_crash(&err));
    }
}
