//! Bucket-sort top-L selection (paper §5.1, Alg. 3) — the faithful
//! sequential implementation.
//!
//! This is exactly the algorithm the paper runs per GPU thread: M+1 (or
//! M+2 with the causal sentinel) buckets of capacity L, keys inserted in
//! index order, retrieval from the highest bucket down.  The Pallas kernel
//! (`python/compile/kernels/topl.py`) computes the same ranks vectorized;
//! the two are cross-checked in the proptests below and through the
//! goldens round trip.

use super::pq::match_score;

/// Select the top-L keys for one query (paper Alg. 3, single thread).
///
/// `codes_q`: M codeword ids of the query; `codes_k`: per-key codeword ids.
/// Returns exactly `l` key indices ordered by (-score, key index).
pub fn select_one(
    codes_q: &[u8],
    codes_k: &[Vec<u8>],
    l: usize,
    causal_limit: Option<usize>,
) -> Vec<u32> {
    let m = codes_q.len();
    let nk = codes_k.len();
    assert!(l >= 1 && l <= nk);
    // Buckets[s] holds keys with score s; capacity L each (Alg. 3 line 2).
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); m + 2];
    // Assign phase (lines 3-8): keys scanned in ascending index order.
    for (j, ck) in codes_k.iter().enumerate() {
        let s = match causal_limit {
            Some(limit) if j > limit => 0, // sentinel bucket 0 analog
            _ => (match_score(codes_q, ck) + 1) as usize,
        };
        let b = &mut buckets[s];
        if b.len() < l {
            b.push(j as u32);
        }
        // Overflow: drop (paper Alg. 3 line 7 instead overwrites the last
        // slot to bound shared memory; keeping the *first* L of a bucket is
        // the same memory bound but preserves the exact
        // (-score, key-index) ranking, matching the Pallas kernel and the
        // sort reference bit-for-bit — required for cross-validation).
    }
    // Retrieve phase (lines 9-16): drain buckets from high score to low.
    let mut out = Vec::with_capacity(l);
    for b in buckets.iter().rev() {
        for &j in b {
            if out.len() == l {
                return out;
            }
            out.push(j);
        }
    }
    // Under-full rows (causal prefix): pad with unseen smallest indices so
    // the output shape is static, mirroring the kernel's padding slots.
    let mut j = 0u32;
    while out.len() < l {
        if !out.contains(&j) {
            out.push(j);
        }
        j += 1;
    }
    out
}

/// Batched selection for all queries of one head.
pub fn select(
    codes_q: &[Vec<u8>],
    codes_k: &[Vec<u8>],
    l: usize,
    causal: bool,
) -> Vec<Vec<u32>> {
    codes_q
        .iter()
        .enumerate()
        .map(|(i, cq)| {
            select_one(cq, codes_k, l, causal.then_some(i))
        })
        .collect()
}

/// Reference ranking ("sort by (-score, index), take L") used to verify the
/// bucket implementation in tests.
pub fn select_by_sort(
    codes_q: &[u8],
    codes_k: &[Vec<u8>],
    l: usize,
    causal_limit: Option<usize>,
) -> Vec<u32> {
    let mut scored: Vec<(i64, u32)> = codes_k
        .iter()
        .enumerate()
        .map(|(j, ck)| {
            let s = match causal_limit {
                Some(limit) if j > limit => -1,
                _ => match_score(codes_q, ck) as i64,
            };
            (s, j as u32)
        })
        .collect();
    scored.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    scored.into_iter().take(l).map(|(_, j)| j).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    fn random_codes(g: &mut crate::util::proptest::Gen, n: usize, m: usize, e: usize) -> Vec<Vec<u8>> {
        (0..n)
            .map(|_| (0..m).map(|_| g.usize_in(0, e - 1) as u8).collect())
            .collect()
    }

    #[test]
    fn matches_sort_reference_non_causal() {
        check(100, |g| {
            let n = g.usize_in(2, 64);
            let m = g.usize_in(1, 8);
            let e = g.usize_in(2, 8);
            let l = g.usize_in(1, n);
            let cq = random_codes(g, 1, m, e);
            let ck = random_codes(g, n, m, e);
            let got = select_one(&cq[0], &ck, l, None);
            let want = select_by_sort(&cq[0], &ck, l, None);
            prop_assert(got == want, format!("got {got:?} want {want:?}"))
        });
    }

    #[test]
    fn causal_never_selects_future_when_enough_history() {
        check(50, |g| {
            let n = g.usize_in(8, 48);
            let cq = random_codes(g, n, 4, 4);
            let ck = random_codes(g, n, 4, 4);
            let l = g.usize_in(1, 4);
            let sel = select(&cq, &ck, l, true);
            for (i, row) in sel.iter().enumerate() {
                if i + 1 >= l {
                    // enough eligible keys: all selections must be <= i
                    for &j in row {
                        prop_assert(
                            (j as usize) <= i,
                            format!("row {i} selected future key {j}"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn output_is_unique_and_in_range() {
        check(50, |g| {
            let n = g.usize_in(2, 40);
            let l = g.usize_in(1, n);
            let cq = random_codes(g, 1, 6, 3);
            let ck = random_codes(g, n, 6, 3);
            let got = select_one(&cq[0], &ck, l, None);
            prop_assert(got.len() == l, "wrong length")?;
            let mut sorted = got.clone();
            sorted.sort_unstable();
            sorted.dedup();
            prop_assert(sorted.len() == l, "duplicates")?;
            prop_assert(
                got.iter().all(|&j| (j as usize) < n),
                "out of range",
            )
        });
    }

    #[test]
    fn exact_match_ranks_first() {
        let cq = vec![3u8, 1, 4, 1];
        let mut ck = vec![vec![0u8, 0, 0, 0]; 10];
        ck[7] = cq.clone();
        let got = select_one(&cq, &ck, 3, None);
        assert_eq!(got[0], 7);
    }

    #[test]
    fn ties_break_by_index() {
        let cq = vec![0u8; 4];
        let ck = vec![vec![1u8; 4]; 6]; // all score 0
        assert_eq!(select_one(&cq, &ck, 4, None), vec![0, 1, 2, 3]);
    }

    #[test]
    fn causal_prefix_padding_is_well_formed() {
        let cq = vec![vec![0u8; 4]; 4];
        let ck = vec![vec![0u8; 4]; 4];
        let sel = select(&cq, &ck, 3, true);
        // Row 0 has one eligible key; padding must still give 3 unique ids.
        assert_eq!(sel[0].len(), 3);
        let mut s = sel[0].clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 3);
        assert_eq!(sel[0][0], 0); // the eligible key leads
    }
}
