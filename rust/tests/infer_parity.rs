//! The inference subsystem's parity and determinism contracts:
//!
//! * **Prefill/decode parity** — `prefill(prompt)` + N teacher-forced
//!   decode steps produce logits *bit-identical* to one training
//!   forward over the `prompt + N`-token sequence, in every tuning mode
//!   (the proptest randomizes sequence length, prompt split, and seed).
//! * **Pool invariance** — the same holds under dedicated rayon pools
//!   of 1, 2, and 8 threads, and the decoded bits agree across pools.
//! * **Checkpoint round trip** — train → `save_tagged` → load →
//!   generate is deterministic per seed, and identity mismatches fail
//!   with a clear error instead of a shape panic.

use spt::config::{Mode, RunConfig};
use spt::coordinator::checkpoint::{self, CkptMeta};
use spt::coordinator::{Backend, NativeBackend, Trainer, TrainerOptions};
use spt::data::SyntheticCorpus;
use spt::infer::{InferModel, Request, Sampler, ServeConfig, ServeDriver, Session};
use spt::util::proptest::{check, prop_assert};
use spt::util::rng::Rng;

fn rc(model: &str, mode: Mode, seed: u64) -> RunConfig {
    RunConfig {
        model: model.into(),
        mode,
        seed,
        eval_every: 0,
        codebook_refresh_every: 0,
        ..RunConfig::default()
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Decode logits rows `p-1 .. seq-1` via prefill + teacher-forced decode.
fn decode_bits(model: &InferModel, toks: &[i32], p: usize) -> Vec<Vec<u32>> {
    let mut sess = Session::new(model, &toks[..p], toks.len()).expect("prefill");
    let mut rows = vec![bits(sess.logits())];
    for &t in &toks[p..] {
        rows.push(bits(sess.decode(t).expect("decode")));
    }
    rows
}

/// The parity assertion for one (model, mode, seed, seq, prompt) case.
fn assert_parity(
    model_name: &str,
    mode: Mode,
    seed: u64,
    seq: usize,
    p: usize,
) -> Result<(), String> {
    let cfg = rc(model_name, mode, seed);
    let backend = NativeBackend::new();
    let state = backend.init_state(&cfg).map_err(|e| e.to_string())?;
    let model = InferModel::new(&cfg, state.clone()).map_err(|e| e.to_string())?;
    let mut corpus = SyntheticCorpus::new(backend.vocab(&cfg).unwrap(), 4, 0.85, seed ^ 0xC0);
    let toks: Vec<i32> = corpus.sequence(seq).iter().map(|&t| t as i32).collect();
    let full = backend.forward_logits(&cfg, &state, &toks).map_err(|e| e.to_string())?;
    let got = decode_bits(&model, &toks, p);
    for (step, row) in got.iter().enumerate() {
        let want = bits(full.row(p - 1 + step));
        if row != &want {
            return Err(format!(
                "{model_name}/{mode:?} seed {seed} seq {seq} prompt {p}: \
                 logits row {} diverges from the full forward",
                p - 1 + step
            ));
        }
    }
    Ok(())
}

#[test]
fn prefill_decode_parity_proptest_all_modes() {
    // Randomized over sequence length, prompt split, and seed; every
    // mode must reproduce the training forward bit for bit — including
    // prompts shorter than the session L (the bucket-clamp edge) and
    // 1-token prompts.
    check(8, |g| {
        let seq = g.usize_in(4, 32);
        let p = g.usize_in(1, seq - 1);
        let seed = g.rng().next_u64();
        for mode in Mode::ALL {
            assert_parity("spt-nano", mode, seed, seq, p).map_err(|e| e.to_string())?;
        }
        prop_assert(true, "unreachable")
    });
}

#[test]
fn prefill_decode_parity_multi_layer() {
    // The 2-layer stack: inter-layer residuals flow through the decode
    // caches of both layers.
    for mode in Mode::ALL {
        assert_parity("spt-nano-l2", mode, 11, 28, 9).unwrap();
        // Prompt of 1 token: everything after the first position runs
        // through the incremental path.
        assert_parity("spt-nano-l2", mode, 12, 16, 1).unwrap();
    }
}

#[test]
fn parity_holds_at_pools_1_2_8() {
    // Dedicated pools of 1, 2, and 8 threads: the decoded logits must
    // agree with the single-thread reference bit for bit (and with the
    // full forward, which assert_parity already pins per pool).
    for mode in Mode::ALL {
        let cfg = rc("spt-nano", mode, 21);
        let backend = NativeBackend::new();
        let state = backend.init_state(&cfg).unwrap();
        let model = InferModel::new(&cfg, state).unwrap();
        let mut corpus = SyntheticCorpus::new(512, 4, 0.85, 77);
        let toks: Vec<i32> = corpus.sequence(20).iter().map(|&t| t as i32).collect();
        let run_under = |threads: usize| {
            let pool = rayon::ThreadPoolBuilder::new()
                .num_threads(threads)
                .build()
                .expect("pool");
            pool.install(|| decode_bits(&model, &toks, 7))
        };
        let reference = run_under(1);
        for threads in [2usize, 8] {
            assert_eq!(
                reference,
                run_under(threads),
                "{mode:?}: decode bits diverge between pools of 1 and {threads}"
            );
        }
    }
    // And the parity contract itself under an oversubscribed pool.
    let pool = rayon::ThreadPoolBuilder::new().num_threads(8).build().unwrap();
    pool.install(|| {
        for mode in Mode::ALL {
            assert_parity("spt-nano", mode, 31, 24, 6).unwrap();
        }
    });
}

/// The paged-serving parity reference: each request decoded by its own
/// unpaged [`Session`], with the driver's per-request RNG fork.
fn solo_streams(model: &InferModel, reqs: &[Request], sampler: &Sampler, seed: u64) -> Vec<Vec<i32>> {
    reqs.iter()
        .map(|r| {
            let mut sess =
                Session::new(model, &r.prompt, r.prompt.len() + r.max_new_tokens).expect("prefill");
            let mut rng = Rng::new(
                seed.wrapping_add((r.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            );
            sess.generate(sampler, &mut rng, r.max_new_tokens).expect("generate")
        })
        .collect()
}

/// Drive a shared-prefix trace through the paged driver with staged
/// submission (request 0 first, so its prefix pages are registered for
/// reuse before the rest arrive), returning streams indexed by id.
fn paged_streams(model: &InferModel, reqs: &[Request], cfg: ServeConfig) -> Vec<Vec<i32>> {
    let mut driver = ServeDriver::new(model, cfg).expect("driver");
    driver.submit(reqs[0].clone()).expect("submit");
    for _ in 0..3 {
        driver.step().expect("warm step");
    }
    for r in &reqs[1..] {
        driver.submit(r.clone()).expect("submit");
    }
    let report = driver.run_to_completion().expect("serve");
    assert_eq!(report.failed, 0, "no request may degrade");
    let mut streams = vec![Vec::new(); reqs.len()];
    for c in &report.completions {
        assert!(c.error.is_none(), "request {}: {:?}", c.id, c.error);
        streams[c.id] = c.tokens.clone();
    }
    streams
}

#[test]
fn paged_driver_matches_solo_unpaged_sessions() {
    // The tentpole invariant: per-request token streams out of the
    // paged, chunk-prefilled, prefix-shared driver are bit-identical to
    // a solo unpaged Session — at any page size, pool size, max_batch,
    // and with sharing on or off.
    let sampler = Sampler::TopK { k: 16, temperature: 0.8 };
    let seed = 0xD0_5EEDu64;
    for mode in Mode::ALL {
        let cfg = rc("spt-nano", mode, 91);
        let backend = NativeBackend::new();
        let state = backend.init_state(&cfg).unwrap();
        let model = InferModel::new(&cfg, state).unwrap();
        let mut corpus = SyntheticCorpus::new(model.vocab(), 4, 0.85, 0xA11);
        let prefix: Vec<i32> = corpus.sequence(10).iter().map(|&t| t as i32).collect();
        // Three requests share the 10-token prefix with distinct tails;
        // the fourth is unrelated (no reuse possible).
        let mut reqs: Vec<Request> = (0..3)
            .map(|id| {
                let mut prompt = prefix.clone();
                prompt.push(i32::try_from(40 + id).unwrap());
                prompt.push(i32::try_from(7 * (id + 1)).unwrap());
                Request { id, prompt, max_new_tokens: 6 }
            })
            .collect();
        reqs.push(Request {
            id: 3,
            prompt: corpus.sequence(7).iter().map(|&t| t as i32).collect(),
            max_new_tokens: 5,
        });
        let want = solo_streams(&model, &reqs, &sampler, seed);
        // Tight pool: the largest single request's page demand.
        let tight = |pt: usize| {
            reqs.iter()
                .map(|r| (r.prompt.len() + r.max_new_tokens).div_ceil(pt))
                .max()
                .unwrap()
        };
        for page_tokens in [4usize, 16] {
            for sharing in [true, false] {
                for pool_pages in [None, Some(tight(page_tokens))] {
                    for max_batch in [1usize, 3] {
                        let got = paged_streams(
                            &model,
                            &reqs,
                            ServeConfig {
                                max_batch,
                                sampler: sampler.clone(),
                                seed,
                                page_tokens,
                                prefill_chunk: 5,
                                prefix_sharing: sharing,
                                pool_pages,
                                ..ServeConfig::default()
                            },
                        );
                        assert_eq!(
                            got, want,
                            "{mode:?} page_tokens {page_tokens} sharing {sharing} \
                             pool {pool_pages:?} max_batch {max_batch}: \
                             paged streams diverge from solo sessions"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn paged_driver_parity_holds_at_pools_1_2_8() {
    // The same invariant under dedicated rayon pools of 1, 2, and 8
    // threads: paged batched decoding must not let pool size reach the
    // token streams.
    let sampler = Sampler::TopK { k: 16, temperature: 0.8 };
    let seed = 0xBEE5u64;
    let cfg = rc("spt-nano", Mode::Spt, 92);
    let backend = NativeBackend::new();
    let state = backend.init_state(&cfg).unwrap();
    let model = InferModel::new(&cfg, state).unwrap();
    let mut corpus = SyntheticCorpus::new(model.vocab(), 4, 0.85, 0xA12);
    let prefix: Vec<i32> = corpus.sequence(9).iter().map(|&t| t as i32).collect();
    let reqs: Vec<Request> = (0..4)
        .map(|id| {
            let mut prompt = prefix.clone();
            prompt.push(i32::try_from(11 + id).unwrap());
            Request { id, prompt, max_new_tokens: 6 }
        })
        .collect();
    let want = solo_streams(&model, &reqs, &sampler, seed);
    for threads in [1usize, 2, 8] {
        let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
        let got = pool.install(|| {
            paged_streams(
                &model,
                &reqs,
                ServeConfig {
                    max_batch: 3,
                    sampler: sampler.clone(),
                    seed,
                    page_tokens: 4,
                    prefill_chunk: 5,
                    prefix_sharing: true,
                    ..ServeConfig::default()
                },
            )
        });
        assert_eq!(got, want, "pool of {threads}: paged streams diverge from solo sessions");
    }
}

#[test]
fn train_checkpoint_generate_roundtrip() {
    // Short spt fine-tune -> tagged checkpoint -> load -> generate:
    // deterministic per seed, and the checkpoint's embedded identity
    // guards against loading under the wrong preset.
    let cfg = rc("spt-nano", Mode::Spt, 4);
    let backend = NativeBackend::new();
    let mut train_cfg = cfg.clone();
    train_cfg.steps = 3;
    train_cfg.batch = 2;
    train_cfg.seq = 24;
    let mut trainer = Trainer::new(&backend, train_cfg, TrainerOptions::default());
    trainer.train().expect("train");
    let state = trainer.last_state.as_ref().expect("state");
    let dir = std::env::temp_dir().join("spt_infer_roundtrip_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("gen.ckpt");
    checkpoint::save_tagged(
        state,
        &CkptMeta { model: "spt-nano".into(), mode: Mode::Spt, n_layers: 1 },
        &path,
    )
    .expect("save");

    let gen = |seed: u64| {
        let model = InferModel::from_checkpoint(&cfg, &path).expect("load");
        let mut corpus = SyntheticCorpus::new(model.vocab(), 4, 0.85, 1);
        let prompt: Vec<i32> = corpus.sequence(8).iter().map(|&t| t as i32).collect();
        let mut sess = Session::new(&model, &prompt, prompt.len() + 16).expect("prefill");
        let mut rng = Rng::new(seed);
        sess.generate(&Sampler::TopK { k: 32, temperature: 0.9 }, &mut rng, 16)
            .expect("generate")
    };
    let a = gen(5);
    assert_eq!(a, gen(5), "same seed must reproduce the stream");
    assert_eq!(a.len(), 16);
    assert!(a.iter().all(|&t| (t as usize) < 512), "tokens in vocab");

    // Wrong mode and wrong model fail up front with the identity error.
    let wrong = rc("spt-nano", Mode::Lora, 4);
    let err = InferModel::from_checkpoint(&wrong, &path).unwrap_err();
    assert!(err.to_string().contains("mode"), "unexpected error: {err}");
    let wrong_model = rc("spt-nano-l2", Mode::Spt, 4);
    assert!(InferModel::from_checkpoint(&wrong_model, &path).is_err());
}
