//! Benchmark timing: warmup + N samples + robust statistics.
//! Criterion-lite, built for this repo's offline registry.

use std::time::Instant;

/// Statistics over one benchmarked operation.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        match s.len() {
            0 => 0.0,
            n if n % 2 == 1 => s[n / 2],
            n => 0.5 * (s[n / 2 - 1] + s[n / 2]),
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / self.samples.len().max(1) as f64;
        var.sqrt()
    }

    /// "12.3 ms ± 0.4" style summary.
    pub fn summary(&self) -> String {
        format!(
            "{} ± {}",
            crate::util::fmt_duration(self.median()),
            crate::util::fmt_duration(self.stddev())
        )
    }
}

/// Run `f` with `warmup` discarded iterations then `samples` timed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), samples: out }
}

/// Accumulating stopwatch for phase breakdowns (Table 5-style).
#[derive(Debug, Default)]
pub struct Stopwatch {
    phases: std::collections::BTreeMap<String, (u64, f64)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time one closure under a phase label.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        let e = self.phases.entry(phase.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
        out
    }

    /// (phase, calls, total seconds) sorted by total descending.
    pub fn breakdown(&self) -> Vec<(String, u64, f64)> {
        let mut v: Vec<_> = self
            .phases
            .iter()
            .map(|(k, &(c, s))| (k.clone(), c, s))
            .collect();
        v.sort_by(|a, b| b.2.total_cmp(&a.2));
        v
    }

    pub fn total(&self) -> f64 {
        self.phases.values().map(|&(_, s)| s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 10);
        assert!(r.mean() >= 0.0);
        assert!(r.min() <= r.median());
        assert!(r.median() <= r.mean() + r.stddev() + 1e-9);
    }

    #[test]
    fn median_even_odd() {
        let r = BenchResult { name: "x".into(), samples: vec![3.0, 1.0, 2.0] };
        assert_eq!(r.median(), 2.0);
        let r2 = BenchResult { name: "x".into(), samples: vec![4.0, 1.0, 2.0, 3.0] };
        assert_eq!(r2.median(), 2.5);
    }

    #[test]
    fn stopwatch_breakdown_ordering() {
        let mut sw = Stopwatch::new();
        sw.time("fast", || std::thread::sleep(std::time::Duration::from_millis(1)));
        sw.time("slow", || std::thread::sleep(std::time::Duration::from_millis(5)));
        sw.time("slow", || std::thread::sleep(std::time::Duration::from_millis(5)));
        let bd = sw.breakdown();
        assert_eq!(bd[0].0, "slow");
        assert_eq!(bd[0].1, 2);
        assert!(sw.total() > 0.009);
    }
}
