//! The execution seam of the coordinator: a [`Backend`] provides model
//! init, the forward/backward train step, eval, and the SPT codebook
//! refresh; the trainer, trial manager, and checkpoints are generic over
//! it.
//!
//! Two implementations:
//!
//! * [`crate::coordinator::NativeBackend`] — always available; trains a
//!   transformer block end-to-end on the rust sparse substrate (forward
//!   *and* backward, AdamW applied host-side via
//!   [`super::state::adamw_update`]).
//! * [`PjrtBackend`] (`xla` feature) — the original artifact path: every
//!   hook dispatches a pre-lowered HLO executable through the PJRT
//!   engine, with the AdamW math baked into the train-step artifact.

use anyhow::Result;
#[cfg(feature = "xla")]
use anyhow::{bail, Context};

use super::state::TrainState;
use crate::config::{Mode, RunConfig};
#[cfg(feature = "xla")]
use crate::runtime::Engine;
#[cfg(feature = "xla")]
use crate::runtime::HostTensor;

/// A training backend: everything the coordinator needs to fine-tune one
/// model+mode, behind a uniform seam.
///
/// Token buffers are flat row-major `[batch * seq]` i32, matching the
/// artifact calling convention and [`crate::data::Batch`].
pub trait Backend {
    /// Short identifier ("native", "pjrt") for logs and tables.
    fn name(&self) -> &'static str;

    /// Human-readable execution platform.
    fn platform(&self) -> String;

    /// Whether this backend can train `rc.model` in `mode` (the PJRT
    /// backend checks the artifact manifest; native is always able).
    fn has_mode(&self, rc: &RunConfig, mode: Mode) -> bool;

    /// Workload shape `(batch, seq)` of one train step.
    fn workload(&self, rc: &RunConfig) -> Result<(usize, usize)>;

    /// Vocabulary size of the model.
    fn vocab(&self, rc: &RunConfig) -> Result<usize>;

    /// Fresh training state (params + zero AdamW moments, step 0).
    fn init_state(&self, rc: &RunConfig) -> Result<TrainState>;

    /// One optimization step (forward, backward, AdamW); returns the
    /// mini-batch loss.
    fn train_step(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f32>;

    /// [`Backend::train_step`] plus observability: the backend fills
    /// `obs` with phase timings and value telemetry for the step.  The
    /// zero-perturbation contract binds every implementation — the
    /// returned loss and the resulting `state` must be bit-identical to
    /// a plain `train_step` with the same inputs (`tests/obs_parity.rs`
    /// proves it for the native backend).  The default ignores `obs`,
    /// which satisfies the contract trivially.
    fn train_step_obs(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
        targets: &[i32],
        obs: &mut crate::obs::StepObs,
    ) -> Result<f32> {
        let _ = obs;
        self.train_step(rc, state, tokens, targets)
    }

    /// Whether the scan-of-8 chunked dispatch is available.
    fn supports_chunked(&self, _rc: &RunConfig) -> bool {
        false
    }

    /// Eight optimization steps in one dispatch (tokens/targets are
    /// `[8 * batch * seq]`); returns the eight losses.
    fn train_chunk8(
        &self,
        _rc: &RunConfig,
        _state: &mut TrainState,
        _tokens: &[i32],
        _targets: &[i32],
    ) -> Result<Vec<f32>> {
        anyhow::bail!("chunked dispatch is not supported by this backend")
    }

    /// Mean loss of one held-out batch (no state update).
    fn eval_loss(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f32>;

    /// `[batch][4]` logits of the QA choice tokens at each item's answer
    /// slot (the MMLU-surrogate readout).
    fn qa_choice_logits(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        answer_pos: &[usize],
        answer_tokens: &[u32; 4],
    ) -> Result<Vec<Vec<f32>>>;

    /// DKM codebook refresh (paper §5.1), spt mode only.  Returns true
    /// if a refresh actually ran.
    fn refresh_codebooks(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
    ) -> Result<bool>;
}

/// The artifact-driven PJRT backend (the pre-refactor coordinator path).
#[cfg(feature = "xla")]
pub struct PjrtBackend<'e> {
    engine: &'e Engine,
}

#[cfg(feature = "xla")]
impl<'e> PjrtBackend<'e> {
    pub fn new(engine: &'e Engine) -> Self {
        PjrtBackend { engine }
    }

    pub fn engine(&self) -> &Engine {
        self.engine
    }

    fn artifact(rc: &RunConfig, entry: &str) -> String {
        format!("{entry}_{}_{}", rc.model, rc.mode.as_str())
    }

    fn step_spec(&self, rc: &RunConfig) -> Result<&crate::runtime::ArtifactSpec> {
        self.engine.spec(&Self::artifact(rc, "train_step"))
    }

    /// Run the whole-model DKM refresh artifact and patch codebook
    /// leaves; `Ok(false)` when the artifact was not built.
    fn run_refresh(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
    ) -> Result<bool> {
        let name = format!("codebook_refresh_{}", rc.model);
        if self.engine.manifest().get(&name).is_err() {
            return Ok(false); // refresh artifact not built; skip silently
        }
        let (batch, seq) = self.workload(rc)?;
        let mut inputs = state.params.clone();
        inputs.push(HostTensor::i32(vec![batch, seq], tokens.to_vec()));
        let out = self.engine.run(&name, &inputs)?;
        if out.len() != 2 {
            bail!("codebook refresh returned {} outputs", out.len());
        }
        let q_leaves = state.find_leaves("pq_q");
        let k_leaves = state.find_leaves("pq_k");
        if q_leaves.len() != 1 || k_leaves.len() != 1 {
            bail!(
                "expected exactly one stacked pq_q/pq_k leaf, found {}/{}",
                q_leaves.len(),
                k_leaves.len()
            );
        }
        state.set_leaf(q_leaves[0], out[0].clone())?;
        state.set_leaf(k_leaves[0], out[1].clone())?;
        Ok(true)
    }
}

#[cfg(feature = "xla")]
impl Backend for PjrtBackend<'_> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        self.engine.platform()
    }

    fn has_mode(&self, rc: &RunConfig, mode: Mode) -> bool {
        let name = format!("train_step_{}_{}", rc.model, mode.as_str());
        self.engine.manifest().get(&name).is_ok()
    }

    fn workload(&self, rc: &RunConfig) -> Result<(usize, usize)> {
        let spec = self.step_spec(rc)?;
        let batch = spec.meta_usize("batch").context("meta.batch")?;
        let seq = spec.meta_usize("seq").context("meta.seq")?;
        Ok((batch, seq))
    }

    fn vocab(&self, rc: &RunConfig) -> Result<usize> {
        self.step_spec(rc)?.meta_usize("vocab").context("meta.vocab")
    }

    fn init_state(&self, rc: &RunConfig) -> Result<TrainState> {
        let state = TrainState::init(
            self.engine,
            &Self::artifact(rc, "model_init"),
            rc.seed as i32,
        )?;
        state.check_against(self.step_spec(rc)?)?;
        Ok(state)
    }

    fn train_step(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f32> {
        let (batch, seq) = self.workload(rc)?;
        let tk = HostTensor::i32(vec![batch, seq], tokens.to_vec());
        let tg = HostTensor::i32(vec![batch, seq], targets.to_vec());
        let inputs = state.step_inputs(tk, tg);
        let out = self.engine.run(&Self::artifact(rc, "train_step"), &inputs)?;
        state.absorb_step_outputs(out)?.scalar()
    }

    fn supports_chunked(&self, rc: &RunConfig) -> bool {
        let name = format!("train_chunk8_{}_{}", rc.model, rc.mode.as_str());
        self.engine.manifest().get(&name).is_ok()
    }

    fn train_chunk8(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<Vec<f32>> {
        let (batch, seq) = self.workload(rc)?;
        let name = format!("train_chunk8_{}_{}", rc.model, rc.mode.as_str());
        let tk = HostTensor::i32(vec![8, batch, seq], tokens.to_vec());
        let tg = HostTensor::i32(vec![8, batch, seq], targets.to_vec());
        let inputs = state.step_inputs(tk, tg);
        let out = self.engine.run(&name, &inputs)?;
        let losses = state.absorb_step_outputs(out)?;
        Ok(losses.as_f32()?.to_vec())
    }

    fn eval_loss(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f32> {
        let (batch, seq) = self.workload(rc)?;
        let mut inputs = state.params.clone();
        inputs.push(HostTensor::i32(vec![batch, seq], tokens.to_vec()));
        inputs.push(HostTensor::i32(vec![batch, seq], targets.to_vec()));
        let out = self.engine.run(&Self::artifact(rc, "eval_loss"), &inputs)?;
        out[0].scalar()
    }

    fn qa_choice_logits(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        _answer_pos: &[usize],
        _answer_tokens: &[u32; 4],
    ) -> Result<Vec<Vec<f32>>> {
        // The qa_logits artifact reads the answer slot itself and returns
        // the four choice-token logits per item.
        let (batch, seq) = self.workload(rc)?;
        let mut inputs = state.params.clone();
        inputs.push(HostTensor::i32(vec![batch, seq], tokens.to_vec()));
        let out = self.engine.run(&Self::artifact(rc, "qa_logits"), &inputs)?;
        let logits = out[0].as_f32()?;
        Ok((0..batch).map(|i| logits[i * 4..(i + 1) * 4].to_vec()).collect())
    }

    fn refresh_codebooks(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
    ) -> Result<bool> {
        self.run_refresh(rc, state, tokens)
    }
}
