//! Native inference subsystem: cached-decode autoregressive generation
//! on the rust sparse substrate — the serving counterpart of
//! [`crate::coordinator::native`].
//!
//! Three parts, mirroring the paper's two modules at decode time plus a
//! serving layer above them:
//!
//! * [`cache`] — decode-time KV storage in two shapes.  The per-layer
//!   [`DecodeCache`] holds per-head K/V matrices plus (spt mode) the PQ
//!   codes of the cached keys, so each decode step re-quantizes nothing
//!   and selects top-L straight from integer codes.  This is the
//!   paper's Fig. 9 memory argument applied to a KV cache: sparse MHA
//!   bounds per-token attention *state* at O(L) values + indices
//!   instead of O(n) probabilities, and the cache itself is
//!   O(n·d + n·M) per layer.  The serving layer stores the same rows in
//!   a [`PagePool`]: fixed-size refcounted pages indexed by per-request
//!   [`PageTable`]s, with copy-on-write prefix sharing so N requests
//!   with a common prompt prefix store its full pages once.
//! * [`session`] — [`InferModel`] (a loaded checkpoint materialized
//!   through the trainer's own `Weights` path, packed-B panels cached
//!   once for the session) and [`Session`] (prefill + one-token decode).
//!   **Determinism contract:** prefill runs the *training* forward
//!   bit-for-bit, and `prefill(prompt)` + N decode steps produce logits
//!   bit-identical to a single training forward over `prompt + N`
//!   tokens — at any rayon pool size.  The sparse path pins the
//!   session's L to the target sequence length's L, which is what makes
//!   the equivalence exact (see `session` docs).
//! * [`serve`] — the continuous-batching driver: a step-loop scheduler
//!   that admits queued prompts, retires finished sequences, and batches
//!   every in-flight decode token through one GEMM per projection and
//!   one routed-FFN call per layer (the paper's
//!   batch-tokens-by-activated-block kernel is batch-shape agnostic, so
//!   cross-request batching is free).  Per-request token streams are
//!   bit-identical regardless of the batch composition.
//! * [`daemon`] — the operational layer over the driver: an NDJSON
//!   protocol with bounded admission, page-granular memory budgeting
//!   via [`crate::memmodel::decode_page_bytes`] (the pool is sized from
//!   `--mem_budget`, so committed cache bytes provably never exceed
//!   it), decode-step deadlines, and graceful drain (`spt serve`).
//! * [`sampler`] — greedy and temperature/top-k sampling off the
//!   deterministic [`crate::util::rng::Rng`] stream.

pub mod cache;
pub mod daemon;
pub mod sampler;
pub mod serve;
pub mod session;

pub use cache::{DecodeCache, PagePool, PageTable};
pub use daemon::{Daemon, DaemonConfig};
pub use sampler::Sampler;
pub use serve::{Completion, Request, ServeConfig, ServeDriver, ServeReport};
pub use session::{InferModel, Session};
