"""Bucket-sort top-L kernel vs reference + invariants + Naive-PQ recall."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pq, ref, topl

SETTINGS = dict(max_examples=4, deadline=None)


def _codes(seed, b, n, m, e):
    k = jax.random.PRNGKey(seed)
    kq, kk = jax.random.split(k)
    cq = jax.random.randint(kq, (b, n, m), 0, e, dtype=jnp.int32)
    ck = jax.random.randint(kk, (b, n, m), 0, e, dtype=jnp.int32)
    return cq, ck


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 3),
    n=st.sampled_from([8, 16, 33, 64]),
    m=st.sampled_from([1, 4, 8]),
    e=st.sampled_from([2, 4, 16]),
    lfrac=st.sampled_from([2, 4, 8]),
    causal=st.booleans(),
)
def test_matches_ref(seed, b, n, m, e, lfrac, causal):
    cq, ck = _codes(seed, b, n, m, e)
    l = max(1, n // lfrac)
    got = topl.topl_select(cq, ck, l, causal=causal)
    want = jax.vmap(
        lambda a, bb: ref.topl_select(a, bb, l, causal=causal)
    )(cq, ck)
    assert bool(jnp.all(got == want))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_indices_unique_and_in_range(seed):
    cq, ck = _codes(seed, 2, 32, 4, 8)
    l = 8
    idx = np.asarray(topl.topl_select(cq, ck, l))
    assert idx.min() >= 0 and idx.max() < 32
    for bi in range(idx.shape[0]):
        for qi in range(idx.shape[1]):
            assert len(set(idx[bi, qi].tolist())) == l


def test_ranked_by_score_descending():
    """Output order must be non-increasing in PQ score."""
    cq, ck = _codes(11, 1, 24, 8, 4)
    l = 12
    idx = np.asarray(topl.topl_select(cq, ck, l))[0]
    s = np.asarray(ref.pq_scores(cq[0], ck[0]))
    for qi in range(24):
        row = s[qi][idx[qi]]
        assert all(row[i] >= row[i + 1] for i in range(l - 1)), row


def test_selected_dominate_unselected():
    """Every selected key's score >= every unselected key's score."""
    cq, ck = _codes(12, 1, 32, 6, 4)
    l = 8
    idx = np.asarray(topl.topl_select(cq, ck, l))[0]
    s = np.asarray(ref.pq_scores(cq[0], ck[0]))
    for qi in range(32):
        sel = set(idx[qi].tolist())
        smin = min(s[qi][j] for j in sel)
        smax_unsel = max(
            (s[qi][j] for j in range(32) if j not in sel), default=-1
        )
        assert smin >= smax_unsel


def test_causal_prefix_rows():
    """Row i with i+1 < L: all eligible keys (0..i) must be selected."""
    cq, ck = _codes(13, 1, 16, 4, 4)
    l = 8
    idx = np.asarray(topl.topl_select(cq, ck, l, causal=True))[0]
    for qi in range(l - 1):
        sel = set(idx[qi].tolist())
        assert set(range(qi + 1)) <= sel, (qi, sel)


def test_identical_codes_select_self_first():
    """If q's codes equal k_j's codes exactly and uniquely, j ranks first."""
    m, e = 8, 16
    cq = jnp.zeros((1, 1, m), dtype=jnp.int32) + 5
    ck = jnp.ones((1, 16, m), dtype=jnp.int32)
    ck = ck.at[0, 9].set(5)
    idx = topl.topl_select(cq, ck, 4)
    assert int(idx[0, 0, 0]) == 9


def test_tie_break_by_key_index():
    """Equal scores resolve to ascending key index (Alg. 3 insertion order)."""
    cq = jnp.zeros((1, 2, 4), dtype=jnp.int32)
    ck = jnp.ones((1, 8, 4), dtype=jnp.int32)  # all keys score 0
    idx = np.asarray(topl.topl_select(cq, ck, 5))[0]
    for qi in range(2):
        assert idx[qi].tolist() == [0, 1, 2, 3, 4]


def test_l_equals_n_is_identity_permutation_cover():
    cq, ck = _codes(14, 1, 16, 4, 4)
    idx = np.asarray(topl.topl_select(cq, ck, 16))[0]
    for qi in range(16):
        assert sorted(idx[qi].tolist()) == list(range(16))


@pytest.mark.parametrize("causal", [False, True])
def test_naive_pq_same_io_contract(causal):
    cq, ck = _codes(15, 2, 32, 4, 8)
    cb = pq.init_codebooks(jax.random.PRNGKey(0), 4, 8, 8)
    idx = topl.naive_pq_select(cq, ck, cb, 8, causal=causal)
    assert idx.shape == (2, 32, 8)
    assert int(jnp.min(idx)) >= 0 and int(jnp.max(idx)) < 32


def test_recall_against_exact_mips_clustered():
    """Paper §4.1: PQ top-L recall vs exact dot-product top-L ~ 90%.

    The mechanism behind the paper's claim: trained attention queries attend
    to a *cluster* of related keys, and PQ codewords capture cluster
    structure.  With clustered q/k the integer-score selection must recover
    nearly all of the true top-L set.  (On isotropic gaussian data — no
    structure to exploit — match-count ties dominate and recall degrades
    toward the L/n baseline; see test below.)
    """
    ks = jax.random.split(jax.random.PRNGKey(42), 4)
    n, d, m, e, c = 128, 64, 8, 16, 8
    centers = jax.random.normal(ks[0], (c, d)) * 2.0
    assign = jnp.arange(n) % c
    k_vecs = (centers[assign] + 0.3 * jax.random.normal(ks[1], (n, d)))[None]
    q_vecs = (centers[assign] + 0.3 * jax.random.normal(ks[2], (n, d)))[None]
    cb = pq.init_codebooks(ks[3], m, e, d // m)
    for _ in range(10):  # adapt codebooks to the data (DKM)
        cb = pq.pq_codebook_update(k_vecs, cb, lr=1.0)
    l = n // c  # cluster size
    idx = np.asarray(
        topl.topl_select(pq.pq_quantize(q_vecs, cb), pq.pq_quantize(k_vecs, cb), l)
    )[0]
    exact = np.asarray(jax.lax.top_k(q_vecs[0] @ k_vecs[0].T, l)[1])
    recall = np.mean([len(set(idx[i]) & set(exact[i])) / l for i in range(n)])
    assert recall > 0.85, recall


def test_recall_beats_random_on_isotropic_data():
    """Even with no cluster structure, PQ selection beats the L/n baseline."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(42), 3)
    n, d, m, e, l = 128, 64, 8, 16, 32
    k_vecs = jax.random.normal(k1, (1, n, d))
    q_vecs = k_vecs + 0.3 * jax.random.normal(k2, (1, n, d))
    cb = pq.init_codebooks(k3, m, e, d // m)
    for _ in range(5):
        cb = pq.pq_codebook_update(k_vecs, cb, lr=1.0)
    idx = np.asarray(
        topl.topl_select(pq.pq_quantize(q_vecs, cb), pq.pq_quantize(k_vecs, cb), l)
    )[0]
    exact = np.asarray(jax.lax.top_k(q_vecs[0] @ k_vecs[0].T, l)[1])
    recall = np.mean([len(set(idx[i]) & set(exact[i])) / l for i in range(n)])
    assert recall > 1.5 * (l / n), recall  # baseline = L/n = 0.25
