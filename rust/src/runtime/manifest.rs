//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! lowers every entry point to HLO text) and the rust execution engine.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element type of a tensor in an artifact signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
    Bool,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "float32" => DType::F32,
            "int32" => DType::I32,
            "uint32" => DType::U32,
            "bool" => DType::Bool,
            other => bail!("unsupported dtype in manifest: {other}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::Bool => 1,
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .as_arr()
            .context("spec.shape missing")?
            .iter()
            .map(|x| x.as_usize().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(j.get("dtype").as_str().context("spec.dtype")?)?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One lowered entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub input_paths: Vec<String>,
    pub outputs: Vec<TensorSpec>,
    pub output_paths: Vec<String>,
    pub meta: Json,
}

impl ArtifactSpec {
    /// Metadata lookup helpers.
    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).as_str()
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).as_usize()
    }

    /// Total bytes across inputs (used by the memory model for I/O
    /// accounting and by the engine for buffer budgeting).
    pub fn input_bytes(&self) -> usize {
        self.inputs.iter().map(TensorSpec::bytes).sum()
    }

    pub fn output_bytes(&self) -> usize {
        self.outputs.iter().map(TensorSpec::bytes).sum()
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let root = crate::util::json::parse(text)
            .map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut artifacts = BTreeMap::new();
        let obj = root
            .get("artifacts")
            .as_obj()
            .context("manifest.artifacts missing")?;
        for (name, j) in obj {
            let spec = ArtifactSpec {
                name: name.clone(),
                file: dir.join(j.get("file").as_str().context("file")?),
                inputs: j
                    .get("inputs")
                    .as_arr()
                    .context("inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                input_paths: str_list(j.get("input_paths")),
                outputs: j
                    .get("outputs")
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                output_paths: str_list(j.get("output_paths")),
                meta: j.get("meta").clone(),
            };
            artifacts.insert(name.clone(), spec);
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!(
                "artifact '{name}' not in manifest (have: {})",
                self.artifacts.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// All artifacts whose metadata matches every (key, value) pair.
    pub fn find_by_meta(&self, pairs: &[(&str, &str)]) -> Vec<&ArtifactSpec> {
        self.artifacts
            .values()
            .filter(|a| {
                pairs.iter().all(|(k, v)| a.meta_str(k) == Some(*v))
            })
            .collect()
    }
}

fn str_list(j: &Json) -> Vec<String> {
    j.as_arr()
        .map(|v| {
            v.iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect()
        })
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "foo": {
          "file": "foo.hlo.txt",
          "inputs": [{"shape": [2, 3], "dtype": "float32"}],
          "input_paths": ["[0]"],
          "outputs": [{"shape": [], "dtype": "int32"}],
          "output_paths": ["[0]"],
          "meta": {"kind": "kernel", "batch": 4}
        }
      },
      "generated_unix": 0
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let a = m.get("foo").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].dtype, DType::F32);
        assert_eq!(a.inputs[0].bytes(), 24);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(a.meta_usize("batch"), Some(4));
        assert_eq!(a.file, PathBuf::from("/tmp/a/foo.hlo.txt"));
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.get("bar").is_err());
    }

    #[test]
    fn find_by_meta_filters() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.find_by_meta(&[("kind", "kernel")]).len(), 1);
        assert_eq!(m.find_by_meta(&[("kind", "block")]).len(), 0);
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::Bool.size_bytes(), 1);
        assert!(DType::parse("float64").is_err());
    }
}
