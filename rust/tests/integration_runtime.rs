//! Runtime integration: the python -> HLO-text -> PJRT -> rust round trip.
//!
//! Requires `make artifacts` (skips politely otherwise so a fresh clone
//! can still run `cargo test`) and a build with `--features xla`.
#![cfg(feature = "xla")]

use spt::runtime::{goldens, Engine, HostTensor};

fn engine() -> Option<Engine> {
    let dir = std::env::var("SPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {dir} (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(&dir).expect("engine"))
}

#[test]
fn goldens_match_python_outputs() {
    let Some(engine) = engine() else { return };
    let dir = std::env::var("SPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let goldens = goldens::load_goldens(&dir).expect("goldens.json");
    assert!(!goldens.is_empty(), "no goldens recorded");
    for g in &goldens {
        let diff = goldens::check_artifact(&engine, g, 1e-3)
            .unwrap_or_else(|e| panic!("golden {}: {e:#}", g.name));
        // integer kernels must be exact
        if g.name.contains("topl") || g.name.contains("pq_quantize") {
            assert_eq!(diff, 0.0, "{} not exact", g.name);
        }
    }
}

#[test]
fn every_artifact_parses_and_compiles() {
    let Some(engine) = engine() else { return };
    // Compiling everything is expensive; sample one artifact per `kind`.
    let mut by_kind: std::collections::BTreeMap<String, String> = Default::default();
    for (name, spec) in &engine.manifest().artifacts {
        let kind = spec.meta_str("kind").unwrap_or("?").to_string();
        by_kind.entry(kind).or_insert_with(|| name.clone());
    }
    assert!(by_kind.len() >= 4, "expected several artifact kinds: {by_kind:?}");
    for (kind, name) in &by_kind {
        engine
            .load(name)
            .unwrap_or_else(|e| panic!("kind {kind}: artifact {name}: {e:#}"));
    }
}

#[test]
fn init_is_deterministic_per_seed() {
    let Some(engine) = engine() else { return };
    let name = "model_init_spt-tiny_spt";
    if engine.manifest().get(name).is_err() {
        return;
    }
    let a = engine.run(name, &[HostTensor::scalar_i32(7)]).unwrap();
    let b = engine.run(name, &[HostTensor::scalar_i32(7)]).unwrap();
    let c = engine.run(name, &[HostTensor::scalar_i32(8)]).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.max_abs_diff(y).unwrap(), 0.0);
    }
    let any_diff = a
        .iter()
        .zip(&c)
        .any(|(x, y)| x.max_abs_diff(y).map(|d| d > 0.0).unwrap_or(true));
    assert!(any_diff, "different seeds produced identical params");
}

#[test]
fn signature_validation_rejects_bad_inputs() {
    let Some(engine) = engine() else { return };
    let name = "kernel_dense_ffn";
    if engine.manifest().get(name).is_err() {
        return;
    }
    // Wrong arity.
    assert!(engine.run(name, &[]).is_err());
    // Wrong shape.
    let spec = engine.spec(name).unwrap().clone();
    let mut inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| HostTensor::zeros(s).unwrap())
        .collect();
    inputs[0] = HostTensor::f32(vec![1], vec![0.0]);
    assert!(engine.run(name, &inputs).is_err());
}

#[test]
fn block_step_runs_for_all_modes() {
    let Some(engine) = engine() else { return };
    // Use the smallest block present in the manifest.
    for cfg in ["mini-256", "opt-1024"] {
        let mut ran = false;
        for mode in ["full", "lora", "spt"] {
            let name = format!("block_step_{cfg}_{mode}");
            if engine.manifest().get(&name).is_err() {
                continue;
            }
            let inputs =
                spt::coordinator::profile::block_step_inputs(&engine, cfg, spt::config::Mode::parse(mode).unwrap(), 3)
                    .expect("inputs");
            let out = engine.run(&name, &inputs).expect(&name);
            let loss = out[0].scalar().expect("loss scalar");
            assert!(loss.is_finite(), "{name}: loss {loss}");
            ran = true;
        }
        if ran {
            return; // one config is enough for CI cost
        }
    }
}

#[test]
fn sparse_attention_artifact_matches_rust_substrate() {
    // Cross-layer validation: the XLA sparse-attention kernel and the
    // rust-native substrate must agree on the same inputs.
    let Some(engine) = engine() else { return };
    let name = "kernel_sparse_attention";
    if engine.manifest().get(name).is_err() {
        return;
    }
    let spec = engine.spec(name).unwrap().clone();
    let (bh, n, d) = (
        spec.inputs[0].shape[0],
        spec.inputs[0].shape[1],
        spec.inputs[0].shape[2],
    );
    let l = spec.inputs[3].shape[2];
    let mut rng = spt::util::rng::Rng::new(99);
    let q = HostTensor::randn(vec![bh, n, d], 1.0, &mut rng);
    let k = HostTensor::randn(vec![bh, n, d], 1.0, &mut rng);
    let v = HostTensor::randn(vec![bh, n, d], 1.0, &mut rng);
    // causal-valid indices: idx[i][j] <= i (use topl on random codes)
    let mut idx_data = Vec::with_capacity(bh * n * l);
    for _ in 0..bh {
        for i in 0..n {
            for j in 0..l {
                idx_data.push((j.min(i)) as i32);
            }
        }
    }
    let idx = HostTensor::i32(vec![bh, n, l], idx_data.clone());
    let out = engine.run(name, &[q.clone(), k.clone(), v.clone(), idx]).unwrap();
    let y = out[0].as_f32().unwrap();

    // rust substrate, head 0 (artifact is causal=True)
    use spt::sparse::{Csr, Matrix};
    let qm = Matrix::from_vec(n, d, q.as_f32().unwrap()[..n * d].to_vec());
    let km = Matrix::from_vec(n, d, k.as_f32().unwrap()[..n * d].to_vec());
    let vm = Matrix::from_vec(n, d, v.as_f32().unwrap()[..n * d].to_vec());
    let topl_rows: Vec<Vec<u32>> = (0..n)
        .map(|i| {
            // dedup within a row as the kernel's softmax masks duplicates;
            // keep first occurrence only
            let mut seen = std::collections::HashSet::new();
            (0..l)
                .filter_map(|j| {
                    let key = idx_data[i * l + j] as u32;
                    seen.insert(key).then_some(key)
                })
                .collect()
        })
        .collect();
    let mut a = Csr::from_rows(&topl_rows, n);
    let scale = 1.0 / (d as f32).sqrt();
    let qs = qm.map(|x| x * scale);
    a.sddmm(&qs, &km);
    a.softmax_rows();
    let y_rust = a.spmm(&vm);
    let mut max_diff = 0.0f32;
    for i in 0..n {
        for c in 0..d {
            max_diff = max_diff.max((y[i * d + c] - y_rust.at(i, c)).abs());
        }
    }
    assert!(max_diff < 1e-3, "xla vs rust substrate diff {max_diff}");
}
