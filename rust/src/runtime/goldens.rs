//! Goldens: numeric validation of the python -> HLO -> rust round trip.
//!
//! `aot.py --goldens` stores sample inputs/outputs for the kernel-level
//! artifacts; [`check_artifact`] replays the inputs through the PJRT engine
//! and compares against the python-computed outputs.  This is the
//! cross-language equivalent of the paper's Fig. 11 unit tests.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::engine::Engine;
use super::manifest::{DType, TensorSpec};
use super::tensor::HostTensor;
use crate::util::json::{parse, Json};

/// One golden case: concrete inputs and expected outputs.
#[derive(Debug, Clone)]
pub struct Golden {
    pub name: String,
    pub inputs: Vec<HostTensor>,
    pub outputs: Vec<HostTensor>,
}

fn tensor_from_json(spec_j: &Json, data_j: &Json) -> Result<HostTensor> {
    let shape: Vec<usize> = spec_j
        .get("shape")
        .as_arr()
        .context("golden shape")?
        .iter()
        .map(|x| x.as_usize().unwrap_or(0))
        .collect();
    let dtype = DType::parse(spec_j.get("dtype").as_str().context("golden dtype")?)?;
    let spec = TensorSpec { shape: shape.clone(), dtype };
    let flat = data_j.as_arr().context("golden data")?;
    if flat.len() != spec.elements() {
        bail!("golden data len {} != {}", flat.len(), spec.elements());
    }
    Ok(match dtype {
        DType::F32 => HostTensor::f32(
            shape,
            flat.iter().map(|x| x.as_f64().unwrap_or(0.0) as f32).collect(),
        ),
        DType::I32 | DType::U32 | DType::Bool => HostTensor::i32(
            shape,
            flat.iter().map(|x| x.as_i64().unwrap_or(0) as i32).collect(), // det: cast-bounded
        ),
    })
}

/// Load all goldens from `artifacts/goldens.json`.
pub fn load_goldens(dir: impl AsRef<Path>) -> Result<Vec<Golden>> {
    let path = dir.as_ref().join("goldens.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path:?}"))?;
    let root = parse(&text).map_err(|e| anyhow::anyhow!("goldens: {e}"))?;
    let obj = root.as_obj().context("goldens root")?;
    let mut out = Vec::new();
    for (name, j) in obj {
        let ispecs = j.get("input_specs").as_arr().context("input_specs")?;
        let idata = j.get("inputs").as_arr().context("inputs")?;
        let ospecs = j.get("output_specs").as_arr().context("output_specs")?;
        let odata = j.get("outputs").as_arr().context("outputs")?;
        let inputs = ispecs
            .iter()
            .zip(idata)
            .map(|(s, d)| tensor_from_json(s, d))
            .collect::<Result<_>>()?;
        let outputs = ospecs
            .iter()
            .zip(odata)
            .map(|(s, d)| tensor_from_json(s, d))
            .collect::<Result<_>>()?;
        out.push(Golden { name: name.clone(), inputs, outputs });
    }
    Ok(out)
}

/// Replay one golden through the engine; returns max |diff| across outputs.
pub fn check_artifact(engine: &Engine, golden: &Golden, atol: f32) -> Result<f32> {
    let got = engine.run(&golden.name, &golden.inputs)?;
    if got.len() != golden.outputs.len() {
        bail!(
            "golden '{}': expected {} outputs, got {}",
            golden.name,
            golden.outputs.len(),
            got.len()
        );
    }
    let mut max_diff = 0.0f32;
    for (g, want) in got.iter().zip(&golden.outputs) {
        // Mixed tolerance: GEMM reduction order differs across XLA
        // backends; excess = |a-b| - rtol*|want| must stay under atol.
        let d = want.max_tol_excess(g, 1e-4)?;
        max_diff = max_diff.max(d);
    }
    if max_diff > atol {
        bail!("golden '{}': tolerance excess {} > atol {}", golden.name, max_diff, atol);
    }
    Ok(max_diff)
}
