"""L1 Pallas kernel: bucket-sort top-L selection over PQ codes.

Paper mapping (SPT §5.1, Alg. 3): for each query, count matching codewords
against every key (integer score in ``0..=M``), then select the top-L keys
with a *bucket sort* over the M+1 possible scores — no floating point
compare or full sort anywhere.

The CUDA version keeps per-query buckets in shared memory and walks keys
sequentially.  The TPU/Pallas adaptation vectorizes the same math:

* ``hist[s]``      — per-query histogram of scores (the bucket sizes),
* ``higher[j]``    — #keys with a strictly larger score (suffix-sum of hist),
* ``within[j]``    — #earlier keys with an equal score (exclusive running
                     count per score value, a static M+2-pass loop),
* ``rank[j] = higher[j] + within[j]`` — the exact slot Alg. 3's retrieval
  phase would write key j into; keys with ``rank < L`` are scattered into
  the output at position ``rank``.

This is bit-identical to "sort by (-score, key_index), take first L", which
is what Alg. 3 computes (keys are inserted in ascending index order and
buckets are drained from high score to low).

Everything is integer arithmetic, mirroring the paper's claim that avoiding
float score materialization + sorting is the source of the 4.6x win over
Naive-PQ (Table 6).  The rust substrate (`rust/src/sparse/topl.rs`) has a
sequential implementation of the same contract used for cross-validation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _make_topl_kernel(l: int, m: int, causal: bool):
    def kernel(cq_ref, ck_ref, idx_ref):
        """One batch-head instance.

        cq_ref: [1, nq, M] query codes     ck_ref: [1, nk, M] key codes
        idx_ref: [1, nq, L] output top-L key indices (int32)
        """
        cq = cq_ref[0]  # [nq, M]
        ck = ck_ref[0]  # [nk, M]
        nq = cq.shape[0]
        nk = ck.shape[0]
        # Integer similarity (paper Eq. 6): matching-codeword count.
        eq = cq[:, None, :] == ck[None, :, :]  # [nq, nk, M] bool
        s = jnp.sum(eq.astype(jnp.int32), axis=-1)  # [nq, nk], 0..M
        if causal:
            qi = jax.lax.broadcasted_iota(jnp.int32, (nq, nk), 0)
            kj = jax.lax.broadcasted_iota(jnp.int32, (nq, nk), 1)
            s = jnp.where(kj <= qi, s, -1)
        # --- bucket ranks, all-integer ---
        # within[j]: exclusive count of earlier keys with the same score.
        # Static loop over the M+2 possible score values (incl. -1 sentinel).
        within = jnp.zeros_like(s)
        higher = jnp.zeros_like(s)
        for sv in range(-1 if causal else 0, m + 1):
            is_sv = (s == sv).astype(jnp.int32)  # [nq, nk]
            run = jnp.cumsum(is_sv, axis=1) - is_sv  # exclusive prefix
            within = within + is_sv * run
            if sv < m:
                # keys strictly above sv contribute to 'higher' of sv-keys
                cnt_above = jnp.sum(
                    (s > sv).astype(jnp.int32), axis=1, keepdims=True
                )
                higher = higher + is_sv * cnt_above
        rank = higher + within  # [nq, nk]
        # Scatter key index j into slot rank[i, j] when rank < L.
        # (mode="drop": out-of-range ranks — keys outside the top-L — vanish.)
        out = jnp.zeros((nq, l), dtype=jnp.int32)
        rows = jax.lax.broadcasted_iota(jnp.int32, (nq, nk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (nq, nk), 1)
        out = out.at[rows.reshape(-1), rank.reshape(-1)].set(
            cols.reshape(-1), mode="drop"
        )
        idx_ref[0] = out

    return kernel


def topl_select(
    codes_q: jax.Array,
    codes_k: jax.Array,
    l: int,
    causal: bool = False,
) -> jax.Array:
    """Select the top-L keys per query by PQ-code similarity.

    Args:
      codes_q: ``[b, nq, M]`` int32 query codes.
      codes_k: ``[b, nk, M]`` int32 key codes.
      l: number of keys to keep per query.
      causal: restrict key j <= query i (decoder look-ahead mask). Rows with
        fewer than L eligible keys contain padding slots (index 0); the
        sparse-softmax downstream re-masks them.

    Returns:
      ``[b, nq, L]`` int32 indices, ordered by (-score, key index).
    """
    b, nq, m = codes_q.shape
    _, nk, _ = codes_k.shape
    assert 0 < l <= nk, f"L={l} must be in 1..={nk}"
    kernel = _make_topl_kernel(l, m, causal)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, nq, m), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, nk, m), lambda bi: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nq, l), lambda bi: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, l), jnp.int32),
        interpret=INTERPRET,
    )(codes_q, codes_k)


def naive_pq_select(
    codes_q: jax.Array,
    codes_k: jax.Array,
    codebooks: jax.Array,
    l: int,
    causal: bool = False,
) -> jax.Array:
    """Baseline "Naive-PQ" (paper Table 6): float ADC scores + full sort.

    Looks up the standard PQ asymmetric-distance inner-product table
    ``c^m[t_q]^T c^m[t_k]`` per codebook, sums float scores, and runs a full
    top-k over floats.  Same inputs/outputs as :func:`topl_select`; exists to
    regenerate the Table 6 comparison at the kernel level.
    """
    b, nq, m = codes_q.shape
    _, nk, _ = codes_k.shape
    e = codebooks.shape[1]
    # Inner-product lookup tables per codebook: [M, E, E].
    tables = jnp.einsum("mex,mfx->mef", codebooks, codebooks)
    # Gather per-pair scores; this materializes float [b, nq, nk] — the
    # expensive thing the bucket-sort kernel avoids.
    tq = jax.nn.one_hot(codes_q, e, dtype=jnp.float32)  # [b, nq, M, E]
    tk = jax.nn.one_hot(codes_k, e, dtype=jnp.float32)  # [b, nk, M, E]
    qm = jnp.einsum("bqme,mef->bqmf", tq, tables)  # [b, nq, M, E]
    s = jnp.einsum("bqmf,bkmf->bqk", qm, tk)  # float scores
    if causal:
        qi = jnp.arange(nq)[None, :, None]
        kj = jnp.arange(nk)[None, None, :]
        s = jnp.where(kj <= qi, s, -jnp.inf)
    # argsort, not lax.top_k: the latter lowers to a `topk(largest=...)`
    # instruction the 0.5.1 HLO text parser rejects (see routed_ffn.py).
    idx = jnp.argsort(-s, axis=-1, stable=True)[..., :l]
    return idx.astype(jnp.int32)
