//! Paper Table 1: running time + peak memory decomposition of one
//! Transformer block (OPT-2048) into MHA and FFN, for Full / LoRA / SPT.
//!
//! Time = measured fwd+bwd of the module artifacts on this CPU testbed
//! (shape comparison: SPT-FFN ~2x faster than LoRA-FFN; SPT-MHA ~ parity).
//! Memory = analytic model at the paper's workload (bs 16, seq 512);
//! paper values: Full 3.2/1.3 GB, LoRA 2.6/1.1 GB, SPT 0.9/1.1 GB.

mod common;

#[cfg(feature = "xla")]
use spt::coordinator::profile::profile_module;
#[cfg(feature = "xla")]
use spt::metrics::Table;
#[cfg(feature = "xla")]
use spt::util::{fmt_bytes, fmt_duration};

#[cfg(not(feature = "xla"))]
fn main() {
    println!("[table1] skipped: artifact profiling needs `--features xla`");
}

#[cfg(feature = "xla")]
fn main() {
    let Some(engine) = common::engine_or_skip("table1") else { return };
    let cfg = "opt-2048";
    let (w, s) = (common::warmup(), common::samples());
    let mut table = Table::new(
        "Table 1 — time & memory decomposition per Transformer block (OPT-2048)",
        &[
            "System", "MHA time", "FFN time", "Total time",
            "MHA mem @bs16,seq512", "FFN mem", "paper MHA/FFN mem",
        ],
    );
    let variants = [
        ("Full", "full", "full", "3.2 GB / 1.3 GB"),
        ("LoRA", "lora", "lora", "2.6 GB / 1.1 GB"),
        ("SPT", "spt_l8", "spt_b12", "0.9 GB / 1.1 GB"),
    ];
    for (label, mha_v, ffn_v, paper) in variants {
        let mha = profile_module(&engine, "mha", cfg, mha_v, w, s)
            .expect("mha profile");
        let ffn = profile_module(&engine, "ffn", cfg, ffn_v, w, s)
            .expect("ffn profile");
        table.row(&[
            label.to_string(),
            fmt_duration(mha.time.median()),
            fmt_duration(ffn.time.median()),
            fmt_duration(mha.time.median() + ffn.time.median()),
            fmt_bytes(mha.model_mem_bytes),
            fmt_bytes(ffn.model_mem_bytes),
            paper.to_string(),
        ]);
    }
    common::emit("table1_decomposition", &table);
}
