//! Continuous-batching serve driver: a step-loop scheduler over the
//! cached-decode path, with paged KV storage.
//!
//! Each step (1) **admits** queued requests in submission order while a
//! slot is free *and* the page pool can cover the request's whole
//! target length (pages are charged at admission and credited on
//! retirement, so the driver provably never overcommits its pool),
//! (2) runs **one batched step** over every in-flight sequence — a
//! `--prefill_chunk`-token slice of the prompt for sequences still
//! prefilling, the last sampled token for the rest, all through one
//! GEMM per projection and one routed-FFN call per layer — and (3)
//! **retires** finished sequences in ascending slot order, freeing
//! slots and pages for the next admissions.
//!
//! Paged KV: sequences store K/V (and PQ codes) in fixed-size pages of
//! a driver-owned [`PagePool`] instead of per-slot dense matrices, so
//! memory scales with *live tokens*, not slots × max_len.  With
//! `prefix_sharing` on, page-aligned prompt prefixes are shared across
//! requests via a refcounted prefix trie — the same-prompt fan-out
//! stores its common prefix once and skips recomputing it
//! ([`ServeReport::shared_prefill_tokens`] counts the skipped work).
//!
//! Determinism: per-request token streams depend only on the model, the
//! request (prompt, `max_new_tokens`) and the per-request RNG stream
//! (derived from the driver seed and the request id) — every batched op
//! is row-local and bit-identical to a single-sequence decode, so the
//! batch composition, `max_batch`, page size, pool size, prefill
//! chunking, and prefix sharing never change what any request generates
//! (asserted by `serving_is_batch_invariant` below and
//! `tests/infer_parity.rs` against a solo unpaged [`super::Session`]).
//!
//! Degradation contract: a malformed request or slot (impossible page
//! demand, out-of-range token) retires *that request* with
//! [`Completion::error`] set — the driver keeps serving everything
//! else.  [`ServeDriver::cancel`] retires an in-flight request at a
//! step boundary the same way (the daemon's deadline enforcement).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant; // det: wall-clock (latency metrics only)

use anyhow::{bail, Result};

use super::cache::{PagePool, PageTable};
use super::sampler::Sampler;
use super::session::{decode_runs, DecodeState, InferModel, KvCache, StepScratch};
use crate::config::Mode;
use crate::util::fault::{self, FaultPlan};
use crate::util::rng::Rng;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub tokens: Vec<i32>,
    /// Seconds from the driver's first step to retirement (includes
    /// queueing — the client-visible latency under load).
    pub latency_secs: f64,
    /// Seconds spent queued before a slot admitted this request.
    pub queue_wait_secs: f64,
    /// `Some(reason)` when the request was degraded (impossible
    /// demand, malformed slot, cancellation) instead of completing;
    /// `tokens` then holds whatever was generated before the failure.
    pub error: Option<String>,
}

/// Driver knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// In-flight sequence capacity (1 = the one-at-a-time baseline).
    pub max_batch: usize,
    pub sampler: Sampler,
    /// Base seed; request `id` forks a decorrelated per-request stream.
    pub seed: u64,
    /// Tokens per KV page (the pool's allocation granule).
    pub page_tokens: usize,
    /// Max prompt tokens prefilled per step per request — bounds how
    /// long one long prompt can stall the decode batch.
    pub prefill_chunk: usize,
    /// Share page-aligned common prompt prefixes across requests
    /// (refcounted; never changes any stream's bits).
    pub prefix_sharing: bool,
    /// Pool size override; `None` sizes the pool for `max_batch`
    /// full-length sequences (the dense-equivalent capacity).
    pub pool_pages: Option<usize>,
    /// Deterministic chaos hooks (`page_pool_exhausted` site).
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            sampler: Sampler::Greedy,
            seed: 0,
            page_tokens: 16,
            prefill_chunk: 32,
            prefix_sharing: true,
            pool_pages: None,
            fault: None,
        }
    }
}

/// Bookkeeping for one in-flight sequence (parallel to the driver's
/// `states` vector, which `decode_runs` consumes directly).
struct SlotMeta {
    id: usize,
    rng: Rng,
    /// The full prompt (chunked prefill consumes it across steps; the
    /// prefix trie is keyed on it).
    prompt: Vec<i32>,
    out: Vec<i32>,
    max_new: usize,
    logits: Vec<f32>,
    queue_wait_secs: f64,
    /// Pages charged at admission but not yet allocated (credited back
    /// on retirement if the sequence ends early).
    reserved_left: usize,
    /// `decode_steps` at admission — the daemon's deterministic
    /// per-request deadline anchor.
    admitted_step: usize,
}

/// Aggregate results of a drained driver.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Completions sorted by request id (degraded ones included, with
    /// [`Completion::error`] set).
    pub completions: Vec<Completion>,
    pub wall_secs: f64,
    pub decode_steps: usize,
    pub generated_tokens: usize,
    /// Steady-state decode throughput: generated tokens / wall seconds.
    pub tokens_per_sec: f64,
    /// Peak in-flight sequences observed.
    pub peak_in_flight: usize,
    /// Completions that ended with an error (degraded or cancelled).
    pub failed: usize,
    /// Prompt tokens actually prefilled (computed) across all requests.
    pub prefill_tokens: usize,
    /// Prompt tokens skipped via shared prefix pages.
    pub shared_prefill_tokens: usize,
    /// `shared / (shared + computed)` prefill tokens — the prefix-share
    /// hit rate on this trace (0.0 with sharing off or no overlap).
    pub prefix_hit_rate: f64,
    /// The pool's total page count.
    pub pool_pages: usize,
    /// Peak pages simultaneously live (the true memory high-water mark).
    pub peak_pages_in_use: usize,
}

/// Percentile over a sample (p in [0, 100]); 0.0 on an empty sample.
fn percentile(mut values: Vec<f64>, p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.sort_by(f64::total_cmp);
    let ix = ((p / 100.0) * (values.len() - 1) as f64).round() as usize;
    values[ix.min(values.len() - 1)]
}

impl ServeReport {
    /// Machine-readable form — the shared schema of
    /// `bench_out/BENCH_decode_native.json`, used by `spt serve-bench`,
    /// the `decode_throughput` bench, and the daemon's final report so
    /// the producers cannot drift.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("tokens_per_sec".into(), Json::Num(self.tokens_per_sec));
        m.insert("wall_secs".into(), Json::Num(self.wall_secs));
        m.insert("decode_steps".into(), Json::Num(self.decode_steps as f64));
        m.insert(
            "generated_tokens".into(),
            Json::Num(self.generated_tokens as f64),
        );
        m.insert(
            "peak_in_flight".into(),
            Json::Num(self.peak_in_flight as f64),
        );
        m.insert("completed".into(), Json::Num(self.completions.len() as f64));
        m.insert("failed".into(), Json::Num(self.failed as f64));
        m.insert(
            "prefill_tokens".into(),
            Json::Num(self.prefill_tokens as f64),
        );
        m.insert(
            "shared_prefill_tokens".into(),
            Json::Num(self.shared_prefill_tokens as f64),
        );
        m.insert("prefix_hit_rate".into(), Json::Num(self.prefix_hit_rate));
        m.insert("pool_pages".into(), Json::Num(self.pool_pages as f64));
        m.insert(
            "peak_pages_in_use".into(),
            Json::Num(self.peak_pages_in_use as f64),
        );
        m.insert("p50_latency_s".into(), Json::Num(self.latency_percentile(50.0)));
        m.insert("p90_latency_s".into(), Json::Num(self.latency_percentile(90.0)));
        m.insert("p99_latency_s".into(), Json::Num(self.latency_percentile(99.0)));
        m.insert(
            "queue_wait_p50_s".into(),
            Json::Num(self.queue_wait_percentile(50.0)),
        );
        m.insert(
            "queue_wait_p99_s".into(),
            Json::Num(self.queue_wait_percentile(99.0)),
        );
        Json::Obj(m)
    }

    /// Latency percentile over completions (p in [0, 100]).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        percentile(self.completions.iter().map(|c| c.latency_secs).collect(), p)
    }

    /// Queue-wait percentile over completions (p in [0, 100]) — how
    /// long requests sat in the driver queue before admission, the
    /// overload signal `serve-bench` records.
    pub fn queue_wait_percentile(&self, p: f64) -> f64 {
        percentile(self.completions.iter().map(|c| c.queue_wait_secs).collect(), p)
    }
}

/// The continuous-batching driver.
pub struct ServeDriver<'m> {
    model: &'m InferModel,
    cfg: ServeConfig,
    /// Queued requests with their submit offset (seconds from epoch).
    queue: VecDeque<(Request, f64)>,
    states: Vec<DecodeState>,
    meta: Vec<SlotMeta>,
    finished: Vec<Completion>,
    /// Cross-step decode scratch (GEMM workspace + routing buffers),
    /// reused for the driver's whole lifetime.
    scratch: StepScratch,
    /// Every in-flight sequence's KV pages live here.
    pool: PagePool,
    /// Pages charged to admitted sequences but not yet allocated.  The
    /// admission invariant `reserved_pages + charge <= free_pages`
    /// guarantees in-step allocation never fails.
    reserved_pages: usize,
    epoch: Option<Instant>, // det: wall-clock (latency metrics only)
    decode_steps: usize,
    generated_tokens: usize,
    prefill_tokens: usize,
    shared_prefill_tokens: usize,
    peak_in_flight: usize,
    peak_pages_in_use: usize,
}

impl<'m> ServeDriver<'m> {
    pub fn new(model: &'m InferModel, cfg: ServeConfig) -> Result<Self> {
        if cfg.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        if cfg.page_tokens == 0 {
            bail!("page_tokens must be >= 1");
        }
        if cfg.prefill_chunk == 0 {
            bail!("prefill_chunk must be >= 1");
        }
        let layout = &*model.layout;
        let pages = cfg
            .pool_pages
            .unwrap_or(cfg.max_batch * layout.max_seq.div_ceil(cfg.page_tokens));
        let pq = (model.mode() == Mode::Spt).then_some(layout.pq_m);
        let pool = PagePool::new(
            pages,
            cfg.page_tokens,
            layout.layers.len(),
            layout.heads,
            layout.d_head,
            pq,
            cfg.prefix_sharing,
        )?;
        Ok(ServeDriver {
            model,
            cfg,
            queue: VecDeque::new(),
            states: Vec::new(),
            meta: Vec::new(),
            finished: Vec::new(),
            scratch: StepScratch::default(),
            pool,
            reserved_pages: 0,
            epoch: None,
            decode_steps: 0,
            generated_tokens: 0,
            prefill_tokens: 0,
            shared_prefill_tokens: 0,
            peak_in_flight: 0,
            peak_pages_in_use: 0,
        })
    }

    /// Seconds since the driver's epoch (0.0 before the first step —
    /// requests submitted before serving starts wait from the start).
    fn now_secs(&self) -> f64 {
        self.epoch
            .map(|e| e.elapsed().as_secs_f64()) // det: wall-clock (metrics)
            .unwrap_or(0.0)
    }

    /// Enqueue a request (admitted in submission order).
    pub fn submit(&mut self, req: Request) -> Result<()> {
        if req.max_new_tokens == 0 {
            bail!("request {}: max_new_tokens must be >= 1", req.id);
        }
        if req.prompt.is_empty() {
            bail!("request {}: empty prompt", req.id);
        }
        if req.prompt.len() + req.max_new_tokens > self.model.max_seq() {
            bail!(
                "request {}: prompt {} + max_new {} exceeds max_seq {}",
                req.id,
                req.prompt.len(),
                req.max_new_tokens,
                self.model.max_seq()
            );
        }
        let submitted = self.now_secs();
        self.queue.push_back((req, submitted));
        Ok(())
    }

    /// Request ids currently in flight, in admission order.
    pub fn in_flight_ids(&self) -> Vec<usize> {
        self.meta.iter().map(|m| m.id).collect()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> usize {
        self.meta.len()
    }

    /// Batched decode steps executed so far (the daemon's deterministic
    /// deadline clock).
    pub fn decode_steps(&self) -> usize {
        self.decode_steps
    }

    /// Total pages in the pool.
    pub fn pool_pages(&self) -> usize {
        self.pool.pages()
    }

    pub fn pool_free_pages(&self) -> usize {
        self.pool.free_pages()
    }

    /// Pages currently holding live KV data (the committed footprint,
    /// in pages — multiply by [`Self::page_bytes`] for bytes).
    pub fn pool_pages_in_use(&self) -> usize {
        self.pool.pages_in_use()
    }

    /// Bytes per page (the admission-accounting granule).
    pub fn page_bytes(&self) -> usize {
        self.pool.bytes_per_page()
    }

    /// The `decode_steps` value when request `id` was admitted, if it
    /// is in flight — the daemon's per-request deadline anchor.
    pub fn admitted_step(&self, id: usize) -> Option<usize> {
        self.meta.iter().find(|m| m.id == id).map(|m| m.admitted_step)
    }

    /// Retire request `id` at a step boundary with an error completion
    /// carrying whatever it generated so far.  Returns `false` when the
    /// id is not in flight.  This is how the daemon enforces
    /// per-request deadlines without perturbing other streams.
    pub fn cancel(&mut self, id: usize, reason: &str) -> bool {
        let Some(si) = self.meta.iter().position(|m| m.id == id) else {
            return false;
        };
        let now = self.now_secs();
        let m = self.meta.remove(si);
        let st = self.states.remove(si);
        self.release_slot(&m, &st);
        self.finished.push(Completion {
            id: m.id,
            tokens: m.out,
            latency_secs: now,
            queue_wait_secs: m.queue_wait_secs,
            error: Some(reason.to_string()),
        });
        true
    }

    /// Drain completions retired since the last call (the daemon's
    /// streaming seam; [`Self::report`] folds drained completions back
    /// in via its argument).
    pub fn take_finished(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.finished)
    }

    /// Return a retired sequence's pages to the pool and credit any
    /// part of its admission reservation that was never allocated.
    fn release_slot(&mut self, m: &SlotMeta, st: &DecodeState) {
        if let KvCache::Paged(table) = &st.cache {
            for &pg in &table.pages {
                self.pool.release(pg);
            }
        }
        self.reserved_pages = self.reserved_pages.saturating_sub(m.reserved_left);
    }

    /// One scheduler step: admit → batched prefill/decode → sample →
    /// retire.  Returns `false` once the queue and all slots drain.
    pub fn step(&mut self) -> Result<bool> {
        let epoch = *self.epoch.get_or_insert_with(Instant::now); // det: wall-clock (metrics)
        let page_tokens = self.pool.page_tokens();
        // Admit in submission order while a slot is free AND the pool
        // can cover the request's whole target length.  Charging the
        // full page demand here (minus shared prefix pages) is what
        // makes in-step allocation infallible: `reserved_pages` tracks
        // charged-but-unallocated pages, and admission requires
        // `reserved + charge <= free`.
        while self.states.len() < self.cfg.max_batch {
            let Some((req, submitted)) = self.queue.pop_front() else { break };
            let now = epoch.elapsed().as_secs_f64(); // det: wall-clock (metrics)
            let queue_wait = (now - submitted).max(0.0);
            let target = req.prompt.len() + req.max_new_tokens;
            let need_pages = target.div_ceil(page_tokens);
            if need_pages > self.pool.pages() {
                // Can never fit this pool: degrade instead of wedging
                // the queue forever.
                self.finished.push(Completion {
                    id: req.id,
                    tokens: Vec::new(),
                    latency_secs: now,
                    queue_wait_secs: queue_wait,
                    error: Some(format!(
                        "request needs {need_pages} pages but the pool holds {}",
                        self.pool.pages()
                    )),
                });
                continue;
            }
            // Chaos hook: a starved pool at admission.  Transient — the
            // request waits for a later step, nothing degrades.
            if fault::fire(self.cfg.fault.as_deref(), "page_pool_exhausted") {
                self.queue.push_front((req, submitted));
                break;
            }
            let l_sess = {
                let layout = &*self.model.layout;
                layout.sparsity.topl(target).min(target)
            };
            // Reuse page-aligned shared prompt-prefix pages; each hit
            // is `page_tokens` of prefill this request skips.
            let chain = self.pool.acquire_chain(l_sess, &req.prompt);
            let charge = need_pages - chain.len();
            if self.reserved_pages + charge > self.pool.free_pages() {
                // Not enough headroom yet: un-reserve the walked
                // prefix, requeue, and wait for retirements (admission
                // stays in submission order).
                for &pg in chain.iter().rev() {
                    self.pool.release(pg);
                }
                self.queue.push_front((req, submitted));
                break;
            }
            self.reserved_pages += charge;
            let reused_tokens = chain.len() * page_tokens;
            self.shared_prefill_tokens += reused_tokens;
            self.states.push(DecodeState {
                cache: KvCache::Paged(PageTable { pages: chain }),
                pos: reused_tokens,
                l_sess,
                target_len: target,
            });
            self.meta.push(SlotMeta {
                id: req.id,
                rng: Rng::new(
                    self.cfg
                        .seed
                        .wrapping_add((req.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                ),
                prompt: req.prompt,
                out: Vec::with_capacity(req.max_new_tokens),
                max_new: req.max_new_tokens,
                logits: Vec::new(),
                queue_wait_secs: queue_wait,
                reserved_left: charge,
                admitted_step: self.decode_steps,
            });
        }
        self.peak_in_flight = self.peak_in_flight.max(self.states.len());
        if self.states.is_empty() {
            return Ok(!self.queue.is_empty());
        }
        // Build this step's run per slot: the next prefill chunk while
        // the prompt is being consumed, else the last sampled token.
        let mut runs: Vec<Vec<i32>> = Vec::with_capacity(self.meta.len());
        for (m, st) in self.meta.iter().zip(&self.states) {
            if st.pos < m.prompt.len() {
                let end = (st.pos + self.cfg.prefill_chunk).min(m.prompt.len());
                runs.push(m.prompt[st.pos..end].to_vec());
            } else {
                let Some(&last) = m.out.last() else {
                    bail!("request {}: slot decoding with no sampled token", m.id);
                };
                runs.push(vec![last]);
            }
        }
        // Flat row offsets (decode_runs groups rows per slot, in order).
        let mut row_off = Vec::with_capacity(runs.len());
        let mut acc = 0;
        for run in &runs {
            row_off.push(acc);
            acc += run.len();
        }
        // Make every position the runs will write addressable: extend
        // page tables from the admission reservation (so `alloc` cannot
        // fail), and defensively detach any shared page before writing
        // (unreachable with page-aligned prefix reuse, but cheap).
        for (si, run) in runs.iter().enumerate() {
            let st = &mut self.states[si];
            let KvCache::Paged(table) = &mut st.cache else {
                bail!("serve slot without a paged cache");
            };
            for p in st.pos..st.pos + run.len() {
                let ix = p / page_tokens;
                if ix == table.pages.len() {
                    let Some(pg) = self.pool.alloc() else {
                        bail!("page pool overcommitted: admission accounting bug");
                    };
                    table.pages.push(pg);
                    let m = &mut self.meta[si];
                    debug_assert!(m.reserved_left > 0, "alloc past reservation");
                    m.reserved_left = m.reserved_left.saturating_sub(1);
                    self.reserved_pages = self.reserved_pages.saturating_sub(1);
                } else if self.pool.refcount(table.pages[ix]) > 1 {
                    table.pages[ix] = self.pool.cow(table.pages[ix])?;
                }
            }
        }
        self.peak_pages_in_use = self.peak_pages_in_use.max(self.pool.pages_in_use());
        // One batched step over every in-flight sequence.
        let logits = decode_runs(
            self.model,
            &mut self.states,
            &runs,
            &mut self.scratch,
            Some(&mut self.pool),
        )?;
        self.decode_steps += 1;
        // Post-step, ascending slot order: register freshly prefilled
        // prefix pages in the trie, then sample wherever a row produced
        // next-token logits (a finished prefill's last row, or the
        // decode row).  `retire` collects (slot, error) pairs.
        let mut retire: Vec<(usize, Option<String>)> = Vec::new();
        for (si, m) in self.meta.iter_mut().enumerate() {
            let st = &self.states[si];
            let run_len = runs[si].len();
            let pre_pos = st.pos - run_len;
            if pre_pos < m.prompt.len() {
                self.prefill_tokens += run_len;
                if let KvCache::Paged(table) = &st.cache {
                    self.pool.register_chain(st.l_sess, &m.prompt, table, st.pos);
                }
                if st.pos < m.prompt.len() {
                    continue; // still prefilling; no logits consumed yet
                }
            }
            let last_row = row_off[si] + run_len - 1;
            m.logits.clear();
            m.logits.extend_from_slice(logits.row(last_row));
            let t = self.cfg.sampler.sample(&m.logits, &mut m.rng);
            match i32::try_from(t) {
                Ok(tok) => {
                    m.out.push(tok);
                    self.generated_tokens += 1;
                    if m.out.len() >= m.max_new {
                        retire.push((si, None));
                    }
                }
                Err(_) => {
                    retire.push((si, Some(format!("sampled token {t} exceeds i32 range"))));
                }
            }
        }
        // Retire in ascending slot order (completions keep a stable
        // order); remove descending so indices stay valid, releasing
        // each retired sequence's pages back to the pool.
        let now = epoch.elapsed().as_secs_f64(); // det: wall-clock (metrics)
        for (si, error) in &retire {
            let m = &self.meta[*si];
            self.finished.push(Completion {
                id: m.id,
                tokens: m.out.clone(),
                latency_secs: now,
                queue_wait_secs: m.queue_wait_secs,
                error: error.clone(),
            });
        }
        for (si, _) in retire.iter().rev() {
            let m = self.meta.remove(*si);
            let st = self.states.remove(*si);
            self.release_slot(&m, &st);
        }
        Ok(!(self.queue.is_empty() && self.states.is_empty()))
    }

    /// Aggregate report over `drained` (completions previously taken via
    /// [`Self::take_finished`]) plus anything still in `finished`.  All
    /// counters and the wall clock are anchored to the driver's epoch
    /// (its first `step`), so the numbers stay consistent when manual
    /// `step()` calls preceded this.
    pub fn report(&mut self, drained: Vec<Completion>) -> ServeReport {
        let epoch = *self.epoch.get_or_insert_with(Instant::now); // det: wall-clock (metrics)
        let wall = epoch.elapsed().as_secs_f64();
        let mut completions = drained;
        completions.extend(self.finished.iter().cloned());
        completions.sort_by_key(|c| c.id);
        let failed = completions.iter().filter(|c| c.error.is_some()).count();
        let total_prefill = self.prefill_tokens + self.shared_prefill_tokens;
        let prefix_hit_rate = if total_prefill == 0 {
            0.0
        } else {
            self.shared_prefill_tokens as f64 / total_prefill as f64
        };
        ServeReport {
            wall_secs: wall,
            decode_steps: self.decode_steps,
            generated_tokens: self.generated_tokens,
            tokens_per_sec: self.generated_tokens as f64 / wall.max(1e-9),
            peak_in_flight: self.peak_in_flight,
            failed,
            prefill_tokens: self.prefill_tokens,
            shared_prefill_tokens: self.shared_prefill_tokens,
            prefix_hit_rate,
            pool_pages: self.pool.pages(),
            peak_pages_in_use: self.peak_pages_in_use,
            completions,
        }
    }

    /// Drain queue and slots; returns the aggregate report.
    pub fn run_to_completion(&mut self) -> Result<ServeReport> {
        while self.step()? {}
        Ok(self.report(Vec::new()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode, RunConfig};
    use crate::coordinator::{Backend, NativeBackend};

    fn model(mode: Mode) -> InferModel {
        let rc = RunConfig {
            model: "spt-nano".into(),
            mode,
            seed: 9,
            ..RunConfig::default()
        };
        let backend = NativeBackend::new();
        let state = backend.init_state(&rc).unwrap();
        InferModel::new(&rc, state).unwrap()
    }

    fn requests(n: usize, max_new: usize) -> Vec<Request> {
        (0..n)
            .map(|id| Request {
                id,
                prompt: vec![1 + id as i32, 2, 3, 4 + id as i32],
                max_new_tokens: max_new,
            })
            .collect()
    }

    fn run(model: &InferModel, reqs: &[Request], max_batch: usize) -> ServeReport {
        let cfg = ServeConfig {
            max_batch,
            sampler: Sampler::TopK { k: 8, temperature: 0.9 },
            seed: 77,
            ..Default::default()
        };
        let mut driver = ServeDriver::new(model, cfg).unwrap();
        for r in reqs {
            driver.submit(r.clone()).unwrap();
        }
        driver.run_to_completion().unwrap()
    }

    #[test]
    fn serving_is_batch_invariant() {
        // The continuous-batching contract: every request generates the
        // same tokens whether it shares a batch or runs alone.
        for mode in Mode::ALL {
            let m = model(mode);
            let reqs = requests(5, 7);
            let batched = run(&m, &reqs, 4);
            let serial = run(&m, &reqs, 1);
            assert_eq!(batched.completions.len(), 5, "{mode:?}");
            assert_eq!(serial.completions.len(), 5, "{mode:?}");
            for (b, s) in batched.completions.iter().zip(&serial.completions) {
                assert_eq!(b.id, s.id, "{mode:?}");
                assert_eq!(b.tokens, s.tokens, "{mode:?} request {}", b.id);
                assert_eq!(b.tokens.len(), 7, "{mode:?}");
                assert!(b.error.is_none() && s.error.is_none(), "{mode:?}");
            }
            assert!(batched.peak_in_flight > 1, "{mode:?}: never batched");
            assert_eq!(serial.peak_in_flight, 1, "{mode:?}");
            assert_eq!(batched.failed, 0, "{mode:?}");
            // Queued requests wait longer when slots are scarcer.
            assert!(
                serial.queue_wait_percentile(99.0) >= batched.queue_wait_percentile(50.0),
                "{mode:?}"
            );
        }
    }

    #[test]
    fn admit_and_retire_follow_submission_order() {
        let m = model(Mode::Spt);
        // Request 0 is long, 1 and 2 shorter: with capacity 2, request 2
        // must wait for a retirement, then take the freed slot.
        let reqs = vec![
            Request { id: 0, prompt: vec![1, 2, 3], max_new_tokens: 10 },
            Request { id: 1, prompt: vec![4, 5, 6], max_new_tokens: 3 },
            Request { id: 2, prompt: vec![7, 8, 9], max_new_tokens: 3 },
        ];
        let mut driver =
            ServeDriver::new(&m, ServeConfig { max_batch: 2, ..Default::default() }).unwrap();
        for r in &reqs {
            driver.submit(r.clone()).unwrap();
        }
        // Step 1: 0 and 1 admitted (submission order) and prefilled —
        // each samples its first token from the prefill logits.
        assert!(driver.step().unwrap());
        assert_eq!(driver.in_flight_ids(), vec![0, 1], "admission order");
        assert_eq!(driver.queued(), 1);
        // Steps 2–3: request 1 reaches 3 tokens (1 at prefill + 2
        // decode steps) and retires.
        assert!(driver.step().unwrap());
        assert_eq!(driver.in_flight_ids(), vec![0, 1]);
        assert!(driver.step().unwrap());
        assert_eq!(driver.in_flight_ids(), vec![0], "short request retired");
        assert_eq!(driver.queued(), 1);
        // Step 4: the freed slot goes to request 2.
        assert!(driver.step().unwrap());
        assert_eq!(driver.in_flight_ids(), vec![0, 2], "freed slot refilled");
        let report = driver.run_to_completion().unwrap();
        let ids: Vec<usize> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        let lens: Vec<usize> =
            report.completions.iter().map(|c| c.tokens.len()).collect();
        assert_eq!(lens, vec![10, 3, 3]);
        assert_eq!(report.generated_tokens, 16);
        assert!(report.latency_percentile(50.0) <= report.latency_percentile(99.0));
        assert!(report.queue_wait_percentile(50.0) <= report.queue_wait_percentile(99.0));
    }

    #[test]
    fn submit_validates_requests() {
        let m = model(Mode::Spt);
        let mut driver = ServeDriver::new(&m, ServeConfig::default()).unwrap();
        assert!(driver
            .submit(Request { id: 0, prompt: vec![], max_new_tokens: 1 })
            .is_err());
        assert!(driver
            .submit(Request { id: 1, prompt: vec![1], max_new_tokens: 0 })
            .is_err());
        let too_long = m.max_seq();
        assert!(driver
            .submit(Request { id: 2, prompt: vec![1, 2], max_new_tokens: too_long })
            .is_err());
        assert!(ServeDriver::new(&m, ServeConfig { max_batch: 0, ..Default::default() })
            .is_err());
        assert!(ServeDriver::new(&m, ServeConfig { page_tokens: 0, ..Default::default() })
            .is_err());
        assert!(
            ServeDriver::new(&m, ServeConfig { prefill_chunk: 0, ..Default::default() })
                .is_err()
        );
    }

    #[test]
    fn max_new_one_completes_after_its_prefill_step() {
        let m = model(Mode::Lora);
        let mut driver = ServeDriver::new(&m, ServeConfig::default()).unwrap();
        driver
            .submit(Request { id: 0, prompt: vec![1, 2], max_new_tokens: 1 })
            .unwrap();
        let report = driver.run_to_completion().unwrap();
        assert_eq!(report.completions.len(), 1);
        assert_eq!(report.completions[0].tokens.len(), 1);
        // The prefill chunk is one batched step; the first token comes
        // from its logits, so max_new = 1 needs no decode-only step.
        assert_eq!(report.decode_steps, 1);
        assert_eq!(report.prefill_tokens, 2);
    }

    #[test]
    fn cancel_retires_one_request_without_perturbing_others() {
        let m = model(Mode::Spt);
        let reqs = requests(3, 8);
        let mut driver =
            ServeDriver::new(&m, ServeConfig { max_batch: 4, ..Default::default() }).unwrap();
        for r in &reqs {
            driver.submit(r.clone()).unwrap();
        }
        // Two steps in, cancel request 1 at the boundary.
        driver.step().unwrap();
        driver.step().unwrap();
        assert!(driver.cancel(1, "deadline exceeded"));
        assert!(!driver.cancel(1, "again"), "already retired");
        assert!(!driver.cancel(99, "never existed"));
        let report = driver.run_to_completion().unwrap();
        assert_eq!(report.completions.len(), 3);
        assert_eq!(report.failed, 1);
        let cancelled = &report.completions[1];
        assert_eq!(cancelled.id, 1);
        assert_eq!(cancelled.error.as_deref(), Some("deadline exceeded"));
        assert_eq!(cancelled.tokens.len(), 2, "prefill + 1 decode token");
        // Survivors are bit-identical to an undisturbed run with the
        // same config (per-request RNG streams are independent), and
        // the cancelled request's pages went back to the pool.
        assert_eq!(driver.pool.pages_in_use(), 0);
        assert_eq!(driver.reserved_pages, 0);
        let mut driver2 =
            ServeDriver::new(&m, ServeConfig { max_batch: 4, ..Default::default() }).unwrap();
        for r in &reqs {
            driver2.submit(r.clone()).unwrap();
        }
        let undisturbed = driver2.run_to_completion().unwrap();
        for (got, want) in report
            .completions
            .iter()
            .zip(&undisturbed.completions)
            .filter(|(g, _)| g.error.is_none())
        {
            assert_eq!(got.tokens, want.tokens, "request {}", got.id);
        }
    }

    #[test]
    fn take_finished_streams_and_report_folds_back() {
        let m = model(Mode::Lora);
        let mut driver = ServeDriver::new(&m, ServeConfig::default()).unwrap();
        for r in requests(3, 2) {
            driver.submit(r).unwrap();
        }
        let mut drained: Vec<Completion> = Vec::new();
        while driver.step().unwrap() {
            drained.extend(driver.take_finished());
        }
        drained.extend(driver.take_finished());
        assert_eq!(drained.len(), 3);
        let report = driver.report(drained);
        assert_eq!(report.completions.len(), 3);
        let ids: Vec<usize> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(report.failed, 0);
    }

    #[test]
    fn report_percentiles_on_empty_and_single_sample_and_json_roundtrip() {
        let m = model(Mode::Lora);
        // Empty report: every percentile is 0.0, not a panic.
        let mut driver = ServeDriver::new(&m, ServeConfig::default()).unwrap();
        let empty = driver.report(Vec::new());
        assert!(empty.completions.is_empty());
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(empty.latency_percentile(p), 0.0, "p{p}");
            assert_eq!(empty.queue_wait_percentile(p), 0.0, "p{p}");
        }
        // Single sample: every percentile is that sample.
        let mut driver = ServeDriver::new(&m, ServeConfig::default()).unwrap();
        driver
            .submit(Request { id: 3, prompt: vec![1, 2], max_new_tokens: 2 })
            .unwrap();
        let report = driver.run_to_completion().unwrap();
        assert_eq!(report.completions.len(), 1);
        let lat = report.completions[0].latency_secs;
        let wait = report.completions[0].queue_wait_secs;
        for p in [0.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(report.latency_percentile(p), lat, "p{p}");
            assert_eq!(report.queue_wait_percentile(p), wait, "p{p}");
        }
        // to_json carries the same numbers through the parser.
        let parsed = crate::util::json::parse(&report.to_json().to_string()).unwrap();
        let get = |k: &str| parsed.get(k).as_f64().unwrap_or_else(|| panic!("{k}"));
        assert_eq!(get("completed"), 1.0);
        assert_eq!(get("failed"), 0.0);
        assert_eq!(get("generated_tokens"), report.generated_tokens as f64);
        assert_eq!(get("decode_steps"), report.decode_steps as f64);
        assert_eq!(get("p50_latency_s"), report.latency_percentile(50.0));
        assert_eq!(get("queue_wait_p99_s"), report.queue_wait_percentile(99.0));
        assert_eq!(get("prefix_hit_rate"), report.prefix_hit_rate);
        assert_eq!(get("pool_pages"), report.pool_pages as f64);
        assert_eq!(get("peak_pages_in_use"), report.peak_pages_in_use as f64);
    }

    #[test]
    fn prefix_sharing_reuses_pages_and_never_changes_streams() {
        let m = model(Mode::Spt);
        let mk_cfg = |sharing: bool| ServeConfig {
            max_batch: 4,
            sampler: Sampler::TopK { k: 8, temperature: 0.9 },
            seed: 77,
            page_tokens: 4,
            prefill_chunk: 4,
            prefix_sharing: sharing,
            ..Default::default()
        };
        // Two full pages of prompt; one page (positions 0..4) is
        // reusable — the page holding the last prompt position is
        // always computed fresh.
        let prompt: Vec<i32> = vec![5, 6, 7, 8, 9, 10, 11, 12];
        let run = |sharing: bool| {
            let mut driver = ServeDriver::new(&m, mk_cfg(sharing)).unwrap();
            driver
                .submit(Request { id: 0, prompt: prompt.clone(), max_new_tokens: 6 })
                .unwrap();
            // Let request 0 finish prefilling (registering its prefix
            // pages in the trie) before the same-prompt fan-out
            // arrives — the warm-cache traffic shape.
            driver.step().unwrap();
            driver.step().unwrap();
            for id in 1..4 {
                driver
                    .submit(Request { id, prompt: prompt.clone(), max_new_tokens: 6 })
                    .unwrap();
            }
            let hits_before = driver.pool.shared_page_hits();
            let report = driver.run_to_completion().unwrap();
            let hits = driver.pool.shared_page_hits() - hits_before;
            assert_eq!(driver.pool.pages_in_use(), 0, "pages leaked");
            assert_eq!(driver.reserved_pages, 0, "reservation leaked");
            (report, hits)
        };
        let (shared, hits) = run(true);
        let (dense, no_hits) = run(false);
        assert_eq!(hits, 3, "3 followers x 1 reusable prefix page");
        assert_eq!(no_hits, 0);
        assert_eq!(shared.completions.len(), 4);
        assert_eq!(shared.failed, 0);
        assert_eq!(shared.shared_prefill_tokens, 12, "3 followers x 4 tokens");
        assert!(shared.prefix_hit_rate > 0.0);
        assert_eq!(dense.prefix_hit_rate, 0.0);
        assert_eq!(dense.shared_prefill_tokens, 0);
        // Sharing changes where bytes live, never what streams say.
        for (a, b) in shared.completions.iter().zip(&dense.completions) {
            assert_eq!(a.tokens, b.tokens, "request {}", a.id);
            assert!(a.error.is_none());
        }
    }
}
