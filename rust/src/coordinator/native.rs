//! The native training backend: end-to-end fine-tuning on the rust
//! sparse substrate, no PJRT toolchain or AOT artifacts required.
//!
//! The model is one transformer block with tied machinery to the paper's
//! three tuning modes:
//!
//! * **full** — embeddings + dense causal MHA + dense ReLU FFN + LM
//!   head, everything trained;
//! * **lora** — the backbone frozen, rank-r adapters on the six
//!   projections (q/k/v/o and both FFN matrices) plus the LM head
//!   trained;
//! * **spt**  — LoRA's trainable set, with the *execution* swapped for
//!   the sparse substrate: PQ + bucket-sort top-L sparse attention
//!   ([`MultiHeadSparseAttention`]) and the routed FFN over BSpMV
//!   ([`mha::routed_ffn_par`]).  Gradients flow only through kept
//!   attention entries and activated FFN blocks
//!   ([`crate::sparse::grad`]); PQ codebooks are maintained by the DKM
//!   k-means refresh, and the router/top-G' selection is treated as
//!   non-differentiable, as in the paper's kernels.
//!
//! Deliberate simplifications (tracked in ROADMAP.md): a single block
//! regardless of the preset's `n_layers` (batched multi-layer training
//! is backlog), no layer norm, and an untied LM head that stays
//! trainable in every mode (the task head).
//!
//! ## Parallelism and determinism
//!
//! `train_step` / `eval_loss` fan out over the microbatch items: each
//! item runs its forward + backward into a private [`GradAcc`] (with a
//! per-worker GEMM [`Workspace`] reused across the item's ops), and the
//! per-item gradients and losses are then reduced in ascending item
//! order.  Together with the substrate's own guarantees (every parallel
//! GEMM/head/block path reduces in a fixed order) this keeps the whole
//! step deterministic at any rayon pool size — losses, parameters, and
//! AdamW moments are bit-identical whether the pool has 1 or 64 threads,
//! which the checkpoint-resume and thread-determinism tests rely on.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};
use rayon::prelude::*;

use super::backend::Backend;
use super::state::{adamw_update, AdamW, TrainState};
use crate::config::{presets, Mode, ModelConfig, RunConfig, Sparsity};
use crate::runtime::HostTensor;
use crate::sparse::attention;
use crate::sparse::bspmv::{self, Routing};
use crate::sparse::grad;
use crate::sparse::mha::{self, MultiHeadSparseAttention};
use crate::sparse::pq::{self, Codebooks};
use crate::sparse::{Csr, Matrix, Workspace};
use crate::util::rng::Rng;

/// The always-available backend (see module docs).
#[derive(Debug, Default)]
pub struct NativeBackend {
    /// Memoized preset + leaf layout for the last `(model, mode)` seen,
    /// so repeated steps with an unchanged [`RunConfig`] don't
    /// re-deserialize the preset table and rebuild the layout per call.
    cache: Mutex<Option<LayoutCache>>,
}

#[derive(Debug)]
struct LayoutCache {
    model: String,
    mode: Mode,
    cfg: Arc<ModelConfig>,
    layout: Arc<Layout>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend::default()
    }

    /// The cached `(preset, layout)` pair for `rc`, rebuilding on a
    /// model/mode change.
    fn cached(&self, rc: &RunConfig) -> Result<(Arc<ModelConfig>, Arc<Layout>)> {
        let mut guard = self.cache.lock().expect("layout cache poisoned");
        if let Some(c) = guard.as_ref() {
            if c.model == rc.model && c.mode == rc.mode {
                return Ok((c.cfg.clone(), c.layout.clone()));
            }
        }
        let cfg = Arc::new(presets::model(&rc.model)?);
        let layout = Arc::new(Layout::new(&cfg, rc.mode)?);
        *guard = Some(LayoutCache {
            model: rc.model.clone(),
            mode: rc.mode,
            cfg: cfg.clone(),
            layout: layout.clone(),
        });
        Ok((cfg, layout))
    }
}

/// Leaf indices of one LoRA adapter pair.
#[derive(Debug, Clone, Copy)]
struct LoraIx {
    a: usize,
    b: usize,
}

/// Slots of the six adapted projections, indexing `Layout::lora` /
/// `Weights::lora`.
const SLOT_Q: usize = 0;
const SLOT_K: usize = 1;
const SLOT_V: usize = 2;
const SLOT_O: usize = 3;
const SLOT_WI: usize = 4;
const SLOT_WO2: usize = 5;

/// Static description of the native model: dimensions plus the index of
/// every leaf in the [`TrainState`] vectors.
#[derive(Debug, Clone)]
struct Layout {
    mode: Mode,
    vocab: usize,
    d: usize,
    dff: usize,
    max_seq: usize,
    heads: usize,
    d_head: usize,
    pq_m: usize,
    pq_e: usize,
    pq_dsub: usize,
    groups: usize,
    sparsity: Sparsity,
    tok: usize,
    pos: usize,
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    wi: usize,
    wo2: usize,
    wout: usize,
    lora: Option<[LoraIx; 6]>,
    router: Option<usize>,
    pq_cb: Option<usize>,
    shapes: Vec<(usize, usize)>,
    paths: Vec<String>,
}

/// Leaf registrar backing [`Layout::new`].
#[derive(Default)]
struct LeafBuilder {
    shapes: Vec<(usize, usize)>,
    paths: Vec<String>,
}

impl LeafBuilder {
    fn add(&mut self, path: impl Into<String>, rows: usize, cols: usize) -> usize {
        let ix = self.paths.len();
        self.paths.push(path.into());
        self.shapes.push((rows, cols));
        ix
    }
}

impl Layout {
    fn new(cfg: &ModelConfig, mode: Mode) -> Result<Self> {
        let b = &cfg.block;
        let (d, dff) = (b.d_model, b.d_ffn);
        let (heads, d_head) = (b.n_heads(), b.d_head);
        let (pq_m, pq_e, pq_dsub) = (b.pq_m(), b.pq_codewords, b.pq_dsub);
        if pq_m * pq_dsub != d_head {
            bail!("PQ subspaces ({pq_m} x {pq_dsub}) do not tile d_head {d_head}");
        }
        let r = b.lora_rank;
        let mut lb = LeafBuilder::default();
        let tok = lb.add("['embed']['tok']", cfg.vocab_size, d);
        let pos = lb.add("['embed']['pos']", cfg.max_seq, d);
        let wq = lb.add("['attn']['wq']", d, d);
        let wk = lb.add("['attn']['wk']", d, d);
        let wv = lb.add("['attn']['wv']", d, d);
        let wo = lb.add("['attn']['wo']", d, d);
        let wi = lb.add("['ffn']['wi']", d, dff);
        let wo2 = lb.add("['ffn']['wo']", dff, d);
        let wout = lb.add("['head']['wout']", d, cfg.vocab_size);
        let lora = if mode == Mode::Lora || mode == Mode::Spt {
            let mut pair = |name: &str, rows: usize, cols: usize| LoraIx {
                a: lb.add(format!("['lora']['{name}']['a']"), rows, r),
                b: lb.add(format!("['lora']['{name}']['b']"), r, cols),
            };
            Some([
                pair("q", d, d),
                pair("k", d, d),
                pair("v", d, d),
                pair("o", d, d),
                pair("wi", d, dff),
                pair("wo", dff, d),
            ])
        } else {
            None
        };
        let (router, pq_cb) = if mode == Mode::Spt {
            (
                Some(lb.add("['router']", d, b.ffn_groups)),
                Some(lb.add("['pq']['codebooks']", heads, pq_m * pq_e * pq_dsub)),
            )
        } else {
            (None, None)
        };
        Ok(Layout {
            mode,
            vocab: cfg.vocab_size,
            d,
            dff,
            max_seq: cfg.max_seq,
            heads,
            d_head,
            pq_m,
            pq_e,
            pq_dsub,
            groups: b.ffn_groups,
            sparsity: b.sparsity,
            tok,
            pos,
            wq,
            wk,
            wv,
            wo,
            wi,
            wo2,
            wout,
            lora,
            router,
            pq_cb,
            shapes: lb.shapes,
            paths: lb.paths,
        })
    }

    fn n_leaves(&self) -> usize {
        self.paths.len()
    }

    /// Init scale per leaf: 0.02 for embeddings, fan-in scaled for
    /// weights, small for PQ codebooks, and exactly 0 for LoRA `b`
    /// factors (the standard adapter-delta-starts-at-zero init).
    fn init_scale(&self, ix: usize) -> f32 {
        if ix == self.tok || ix == self.pos {
            return 0.02;
        }
        if let Some(pairs) = &self.lora {
            for p in pairs {
                if ix == p.b {
                    return 0.0;
                }
                if ix == p.a {
                    return 1.0 / (self.shapes[ix].0 as f32).sqrt();
                }
            }
        }
        if Some(ix) == self.pq_cb {
            return 0.05;
        }
        // Dense weights (wq..wout, router): fan-in scaling.
        1.0 / (self.shapes[ix].0 as f32).sqrt()
    }

    /// Which leaves receive AdamW updates in this mode.
    fn trainable(&self) -> Vec<bool> {
        let mut t = vec![false; self.n_leaves()];
        t[self.wout] = true; // the task head trains in every mode
        match self.mode {
            Mode::Full => {
                for ix in [
                    self.tok, self.pos, self.wq, self.wk, self.wv, self.wo, self.wi,
                    self.wo2,
                ] {
                    t[ix] = true;
                }
            }
            Mode::Lora | Mode::Spt => {
                if let Some(pairs) = &self.lora {
                    for p in pairs {
                        t[p.a] = true;
                        t[p.b] = true;
                    }
                }
                // The router and PQ codebooks are not SGD-trained: the
                // top-G' / top-L selections are non-differentiable and
                // codebooks refresh via DKM k-means.
            }
        }
        t
    }
}

/// Materialized effective weights for one step (base + LoRA deltas).
struct Weights {
    wq: Matrix,
    wk: Matrix,
    wv: Matrix,
    wo: Matrix,
    wi: Matrix,
    wo2: Matrix,
    wout: Matrix,
    /// Adapter factors (a, b) per slot, aligned with `Layout::lora`.
    lora: Option<Vec<(Matrix, Matrix)>>,
    router: Option<Matrix>,
    codebooks: Option<Vec<Codebooks>>,
}

fn leaf_matrix(layout: &Layout, state: &TrainState, ix: usize) -> Result<Matrix> {
    let (rows, cols) = layout.shapes[ix];
    let data = state
        .params
        .get(ix)
        .with_context(|| format!("missing leaf {ix}"))?
        .as_f32()?;
    if data.len() != rows * cols {
        bail!(
            "leaf {} ('{}') has {} elements, layout wants {}x{}",
            ix,
            layout.paths[ix],
            data.len(),
            rows,
            cols
        );
    }
    Ok(Matrix::from_vec(rows, cols, data.to_vec()))
}

impl Weights {
    fn materialize(layout: &Layout, state: &TrainState) -> Result<Self> {
        if state.params.len() != layout.n_leaves() {
            bail!(
                "state has {} leaves, layout wants {} (model/mode mismatch?)",
                state.params.len(),
                layout.n_leaves()
            );
        }
        let lora = match &layout.lora {
            Some(pairs) => Some(
                pairs
                    .iter()
                    .map(|p| {
                        Ok((
                            leaf_matrix(layout, state, p.a)?,
                            leaf_matrix(layout, state, p.b)?,
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?,
            ),
            None => None,
        };
        let eff = |base_ix: usize, slot: usize| -> Result<Matrix> {
            let mut w = leaf_matrix(layout, state, base_ix)?;
            if let Some(mats) = &lora {
                let (a, b) = &mats[slot];
                w.add_assign(&a.matmul(b));
            }
            Ok(w)
        };
        let wq = eff(layout.wq, SLOT_Q)?;
        let wk = eff(layout.wk, SLOT_K)?;
        let wv = eff(layout.wv, SLOT_V)?;
        let wo = eff(layout.wo, SLOT_O)?;
        let wi = eff(layout.wi, SLOT_WI)?;
        let wo2 = eff(layout.wo2, SLOT_WO2)?;
        let wout = leaf_matrix(layout, state, layout.wout)?;
        let router = match layout.router {
            Some(ix) => Some(leaf_matrix(layout, state, ix)?),
            None => None,
        };
        let codebooks = match layout.pq_cb {
            Some(ix) => {
                let flat = state.params[ix].as_f32()?;
                let stride = layout.pq_m * layout.pq_e * layout.pq_dsub;
                Some(
                    (0..layout.heads)
                        .map(|h| Codebooks {
                            m: layout.pq_m,
                            e: layout.pq_e,
                            dsub: layout.pq_dsub,
                            data: flat[h * stride..(h + 1) * stride].to_vec(),
                        })
                        .collect(),
                )
            }
            None => None,
        };
        Ok(Weights { wq, wk, wv, wo, wi, wo2, wout, lora, router, codebooks })
    }
}

/// Per-item forward caches consumed by the backward pass.
struct ItemTrace {
    x: Matrix,
    q: Vec<Matrix>,
    k: Vec<Matrix>,
    v: Vec<Matrix>,
    /// spt: per-head post-softmax attention CSRs.
    attn: Option<Vec<Csr>>,
    attn_out: Matrix,
    x1: Matrix,
    /// full/lora: dense FFN hidden activations (post-ReLU).
    h1: Option<Matrix>,
    /// spt: the routing the FFN forward used (backward follows it).
    routing: Option<Routing>,
    x2: Matrix,
}

/// Gradient accumulator: one flat buffer per *trainable* leaf.
struct GradAcc {
    g: Vec<Option<Vec<f32>>>,
}

impl GradAcc {
    fn new(layout: &Layout) -> Self {
        let g = layout
            .trainable()
            .iter()
            .enumerate()
            .map(|(ix, &on)| {
                let (r, c) = layout.shapes[ix];
                on.then(|| vec![0.0f32; r * c])
            })
            .collect();
        GradAcc { g }
    }

    /// Accumulate into leaf `ix` (no-op when the leaf is frozen).
    fn add(&mut self, ix: usize, dm: &Matrix) {
        if let Some(buf) = &mut self.g[ix] {
            debug_assert_eq!(buf.len(), dm.data.len());
            for (o, &x) in buf.iter_mut().zip(&dm.data) {
                *o += x;
            }
        }
    }

    /// Route an effective-weight gradient to the base leaf (full mode)
    /// or decompose onto the LoRA factors (`W_eff = W + a b` gives
    /// `da = dW b^T`, `db = a^T dW`; the frozen base absorbs nothing).
    fn add_weight(
        &mut self,
        layout: &Layout,
        w: &Weights,
        slot: usize,
        base_ix: usize,
        dw: &Matrix,
        ws: &mut Workspace,
    ) {
        match (&layout.lora, &w.lora) {
            (Some(ixs), Some(mats)) => {
                let (a, b) = &mats[slot];
                self.add(ixs[slot].a, &grad::matmul_dx(dw, b));
                self.add(ixs[slot].b, &grad::matmul_dw_ws(a, dw, ws));
            }
            _ => self.add(base_ix, dw),
        }
    }

    /// Accumulate another item's gradients leaf by leaf.  Calling this
    /// in ascending item order reproduces one fixed reduction order, so
    /// the merged gradients are identical at any pool size.
    fn merge(&mut self, other: &GradAcc) {
        for (mine, theirs) in self.g.iter_mut().zip(&other.g) {
            if let (Some(a), Some(b)) = (mine.as_mut(), theirs.as_ref()) {
                debug_assert_eq!(a.len(), b.len());
                for (o, &x) in a.iter_mut().zip(b) {
                    *o += x;
                }
            }
        }
    }

    /// Scatter token/position embedding gradients (full mode only — the
    /// embedding leaves are frozen otherwise and `add` no-ops).
    fn scatter_embed(&mut self, layout: &Layout, tok: &[i32], dx: &Matrix) {
        let d = layout.d;
        if let Some(buf) = &mut self.g[layout.tok] {
            for (s, &t) in tok.iter().enumerate() {
                let off = t as usize * d;
                for (o, &g) in buf[off..off + d].iter_mut().zip(dx.row(s)) {
                    *o += g;
                }
            }
        }
        if let Some(buf) = &mut self.g[layout.pos] {
            for s in 0..dx.rows {
                let off = s * d;
                for (o, &g) in buf[off..off + d].iter_mut().zip(dx.row(s)) {
                    *o += g;
                }
            }
        }
    }
}

/// Column-slice the H heads out of a `[n, H*dh]` matrix.
fn split_heads(x: &Matrix, heads: usize, dh: usize) -> Vec<Matrix> {
    assert_eq!(x.cols, heads * dh, "head split shape mismatch");
    (0..heads)
        .map(|h| {
            let mut m = Matrix::zeros(x.rows, dh);
            for r in 0..x.rows {
                m.row_mut(r).copy_from_slice(&x.row(r)[h * dh..(h + 1) * dh]);
            }
            m
        })
        .collect()
}

/// Inverse of [`split_heads`].
fn concat_heads(parts: &[Matrix]) -> Matrix {
    let rows = parts[0].rows;
    let dh = parts[0].cols;
    let mut out = Matrix::zeros(rows, parts.len() * dh);
    for (h, p) in parts.iter().enumerate() {
        assert_eq!(p.rows, rows, "head {h} row mismatch");
        for r in 0..rows {
            out.row_mut(r)[h * dh..(h + 1) * dh].copy_from_slice(p.row(r));
        }
    }
    out
}

fn unzip3(v: Vec<(Matrix, Matrix, Matrix)>) -> (Vec<Matrix>, Vec<Matrix>, Vec<Matrix>) {
    let mut a = Vec::with_capacity(v.len());
    let mut b = Vec::with_capacity(v.len());
    let mut c = Vec::with_capacity(v.len());
    for (x, y, z) in v {
        a.push(x);
        b.push(y);
        c.push(z);
    }
    (a, b, c)
}

/// Summed cross-entropy over the rows plus `(softmax - onehot) *
/// inv_count` logit gradients (`inv_count` = 1 / total positions in the
/// mini-batch, so accumulating per-item gradients yields the mean-loss
/// gradient).
fn ce_loss_and_grad(
    logits: &Matrix,
    targets: &[i32],
    inv_count: f32,
    vocab: usize,
) -> Result<(f32, Matrix)> {
    assert_eq!(logits.rows, targets.len(), "logits/targets row mismatch");
    let mut dl = Matrix::zeros(logits.rows, logits.cols);
    let mut loss = 0.0f64;
    for r in 0..logits.rows {
        let t = targets[r] as usize;
        if t >= vocab {
            bail!("target token {t} out of vocabulary {vocab}");
        }
        let row = logits.row(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let drow = dl.row_mut(r);
        let mut sum = 0.0f32;
        for (o, &x) in drow.iter_mut().zip(row) {
            *o = (x - mx).exp();
            sum += *o;
        }
        let inv = 1.0 / sum.max(1e-30);
        let p_t = (drow[t] * inv).max(1e-30);
        loss -= (p_t as f64).ln();
        for o in drow.iter_mut() {
            *o *= inv * inv_count;
        }
        drow[t] -= inv_count;
    }
    Ok((loss as f32, dl))
}

/// Summed cross-entropy only (eval paths — no gradient allocation).
fn ce_loss(logits: &Matrix, targets: &[i32], vocab: usize) -> Result<f32> {
    assert_eq!(logits.rows, targets.len(), "logits/targets row mismatch");
    let mut loss = 0.0f64;
    for r in 0..logits.rows {
        let t = targets[r] as usize;
        if t >= vocab {
            bail!("target token {t} out of vocabulary {vocab}");
        }
        let row = logits.row(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &x in row {
            sum += (x - mx).exp();
        }
        let p_t = ((logits.at(r, t) - mx).exp() / sum.max(1e-30)).max(1e-30);
        loss -= (p_t as f64).ln();
    }
    Ok(loss as f32)
}

impl NativeBackend {
    fn model_config(&self, rc: &RunConfig) -> Result<Arc<ModelConfig>> {
        Ok(self.cached(rc)?.0)
    }

    fn layout(&self, rc: &RunConfig) -> Result<Arc<Layout>> {
        Ok(self.cached(rc)?.1)
    }

    /// Token + learned positional embedding for one sequence.
    fn embed(&self, layout: &Layout, state: &TrainState, tok: &[i32]) -> Result<Matrix> {
        let te = state.params[layout.tok].as_f32()?;
        let pe = state.params[layout.pos].as_f32()?;
        let d = layout.d;
        if tok.len() > layout.max_seq {
            bail!("sequence {} exceeds max_seq {}", tok.len(), layout.max_seq);
        }
        let mut x = Matrix::zeros(tok.len(), d);
        for (s, &t) in tok.iter().enumerate() {
            let t = t as usize;
            if t >= layout.vocab {
                bail!("token {t} out of vocabulary {}", layout.vocab);
            }
            let trow = &te[t * d..(t + 1) * d];
            let prow = &pe[s * d..(s + 1) * d];
            for ((o, &a), &b) in x.row_mut(s).iter_mut().zip(trow).zip(prow) {
                *o = a + b;
            }
        }
        Ok(x)
    }

    /// Build the sparse multi-head layer once per call (spt mode only):
    /// the codebooks are constant within a step and `L` depends only on
    /// the sequence length, so per-item construction would just clone
    /// codebooks `batch` times.
    fn sparse_layer(
        &self,
        layout: &Layout,
        w: &Weights,
        seq: usize,
    ) -> Result<Option<MultiHeadSparseAttention>> {
        if layout.mode != Mode::Spt {
            return Ok(None);
        }
        let l = layout.sparsity.topl(seq).min(seq);
        let cbs = w.codebooks.clone().context("spt mode without codebooks")?;
        Ok(Some(MultiHeadSparseAttention::new(cbs, l, true)))
    }

    /// One sequence forward up to the block output `x2` (no LM head).
    /// `ws` is the item's reusable GEMM workspace.
    fn forward_block(
        &self,
        layout: &Layout,
        w: &Weights,
        state: &TrainState,
        tok: &[i32],
        sparse: Option<&MultiHeadSparseAttention>,
        ws: &mut Workspace,
    ) -> Result<ItemTrace> {
        let x = self.embed(layout, state, tok)?;
        let q = split_heads(&x.matmul_ws(&w.wq, ws), layout.heads, layout.d_head);
        let k = split_heads(&x.matmul_ws(&w.wk, ws), layout.heads, layout.d_head);
        let v = split_heads(&x.matmul_ws(&w.wv, ws), layout.heads, layout.d_head);
        let (ys, attn) = if layout.mode == Mode::Spt {
            let layer = sparse.context("spt mode without a sparse layer")?;
            let (ys, csrs) = layer.forward_cached(&q, &k, &v);
            (ys, Some(csrs))
        } else {
            let ys: Vec<Matrix> = (0..layout.heads)
                .into_par_iter()
                .map_init(Workspace::default, |hws, h| {
                    attention::dense_attention_ws(&q[h], &k[h], &v[h], true, hws)
                })
                .collect();
            (ys, None)
        };
        let attn_out = concat_heads(&ys);
        let x1 = x.add(&attn_out.matmul_ws(&w.wo, ws));
        let (f, h1, routing) = if layout.mode == Mode::Spt {
            let router = w.router.as_ref().context("spt mode without router")?;
            let scores = x1.matmul_ws(router, ws);
            let g_active = layout.sparsity.active_groups(layout.groups).min(layout.groups);
            let routing = bspmv::route(&scores, g_active);
            let f = mha::routed_ffn_par(&x1, &w.wi, &w.wo2, &routing);
            (f, None, Some(routing))
        } else {
            let h1 = x1.matmul_ws(&w.wi, ws).relu();
            let f = h1.matmul_ws(&w.wo2, ws);
            (f, Some(h1), None)
        };
        let x2 = x1.add(&f);
        Ok(ItemTrace { x, q, k, v, attn, attn_out, x1, h1, routing, x2 })
    }

    /// One sequence forward; returns the backward caches and the logits.
    fn forward_item(
        &self,
        layout: &Layout,
        w: &Weights,
        state: &TrainState,
        tok: &[i32],
        sparse: Option<&MultiHeadSparseAttention>,
        ws: &mut Workspace,
    ) -> Result<(ItemTrace, Matrix)> {
        let trace = self.forward_block(layout, w, state, tok, sparse, ws)?;
        let logits = trace.x2.matmul_ws(&w.wout, ws);
        Ok((trace, logits))
    }

    /// One sequence backward; accumulates leaf gradients into `acc`.
    /// `ws` is the item's reusable GEMM workspace.
    #[allow(clippy::too_many_arguments)]
    fn backward_item(
        &self,
        layout: &Layout,
        w: &Weights,
        trace: &ItemTrace,
        tok: &[i32],
        dlogits: &Matrix,
        sparse: Option<&MultiHeadSparseAttention>,
        acc: &mut GradAcc,
        ws: &mut Workspace,
    ) -> Result<()> {
        // LM head.
        acc.add(layout.wout, &grad::matmul_dw_ws(&trace.x2, dlogits, ws));
        let dx2 = grad::matmul_dx(dlogits, &w.wout);
        // FFN (dX2 flows through both the residual and the FFN branch).
        let (dx1_ffn, dwi_eff, dwo2_eff) = if layout.mode == Mode::Spt {
            let routing = trace.routing.as_ref().context("missing routing trace")?;
            mha::routed_ffn_backward_par(&trace.x1, &w.wi, &w.wo2, routing, &dx2)
        } else {
            let h1 = trace.h1.as_ref().context("missing ffn trace")?;
            let dwo2 = grad::matmul_dw_ws(h1, &dx2, ws);
            let dpre = grad::relu_backward(h1, &grad::matmul_dx(&dx2, &w.wo2));
            let dwi = grad::matmul_dw_ws(&trace.x1, &dpre, ws);
            let dx = grad::matmul_dx(&dpre, &w.wi);
            (dx, dwi, dwo2)
        };
        acc.add_weight(layout, w, SLOT_WI, layout.wi, &dwi_eff, ws);
        acc.add_weight(layout, w, SLOT_WO2, layout.wo2, &dwo2_eff, ws);
        let dx1 = dx2.add(&dx1_ffn);
        // Attention output projection.
        let dwo_eff = grad::matmul_dw_ws(&trace.attn_out, &dx1, ws);
        acc.add_weight(layout, w, SLOT_O, layout.wo, &dwo_eff, ws);
        let dy_heads = split_heads(&grad::matmul_dx(&dx1, &w.wo), layout.heads, layout.d_head);
        // Attention core.
        let (dq_h, dk_h, dv_h) = if layout.mode == Mode::Spt {
            let layer = sparse.context("spt mode without a sparse layer")?;
            let attn = trace.attn.as_ref().context("missing attn trace")?;
            layer.backward(&trace.q, &trace.k, &trace.v, attn, &dy_heads)
        } else {
            let per: Vec<(Matrix, Matrix, Matrix)> = (0..layout.heads)
                .into_par_iter()
                .map_init(Workspace::default, |hws, h| {
                    grad::dense_attention_backward_ws(
                        &trace.q[h], &trace.k[h], &trace.v[h], true, &dy_heads[h], hws,
                    )
                })
                .collect();
            unzip3(per)
        };
        let dq = concat_heads(&dq_h);
        let dk = concat_heads(&dk_h);
        let dv = concat_heads(&dv_h);
        let dwq_eff = grad::matmul_dw_ws(&trace.x, &dq, ws);
        acc.add_weight(layout, w, SLOT_Q, layout.wq, &dwq_eff, ws);
        let dwk_eff = grad::matmul_dw_ws(&trace.x, &dk, ws);
        acc.add_weight(layout, w, SLOT_K, layout.wk, &dwk_eff, ws);
        let dwv_eff = grad::matmul_dw_ws(&trace.x, &dv, ws);
        acc.add_weight(layout, w, SLOT_V, layout.wv, &dwv_eff, ws);
        // Embedding gradients only exist in full mode (frozen otherwise).
        if layout.mode == Mode::Full {
            let mut dx = dx1.clone();
            dx.add_assign(&grad::matmul_dx(&dq, &w.wq));
            dx.add_assign(&grad::matmul_dx(&dk, &w.wk));
            dx.add_assign(&grad::matmul_dx(&dv, &w.wv));
            acc.scatter_embed(layout, tok, &dx);
        }
        Ok(())
    }

    fn check_batch(
        &self,
        rc: &RunConfig,
        tokens: &[i32],
        targets: Option<&[i32]>,
    ) -> Result<(usize, usize)> {
        let (batch, seq) = self.workload(rc)?;
        if tokens.len() != batch * seq {
            bail!(
                "token buffer has {} entries, workload wants {}x{}",
                tokens.len(),
                batch,
                seq
            );
        }
        if let Some(t) = targets {
            if t.len() != tokens.len() {
                bail!("targets/tokens length mismatch");
            }
        }
        Ok((batch, seq))
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        format!("native-cpu x{}", rayon::current_num_threads())
    }

    fn has_mode(&self, rc: &RunConfig, _mode: Mode) -> bool {
        presets::model(&rc.model).is_ok()
    }

    fn workload(&self, rc: &RunConfig) -> Result<(usize, usize)> {
        let cfg = self.model_config(rc)?;
        let batch = rc.batch.max(1);
        let seq = rc.seq.clamp(1, cfg.max_seq);
        Ok((batch, seq))
    }

    fn vocab(&self, rc: &RunConfig) -> Result<usize> {
        Ok(self.model_config(rc)?.vocab_size)
    }

    fn init_state(&self, rc: &RunConfig) -> Result<TrainState> {
        let layout = self.layout(rc)?;
        let mut rng = Rng::new(rc.seed ^ 0x517A_11CE);
        let mut params = Vec::with_capacity(layout.n_leaves());
        for ix in 0..layout.n_leaves() {
            let (rows, cols) = layout.shapes[ix];
            let scale = layout.init_scale(ix);
            let data = if scale == 0.0 {
                vec![0.0f32; rows * cols]
            } else {
                rng.normal_vec(rows * cols)
                    .into_iter()
                    .map(|x| x * scale)
                    .collect()
            };
            params.push(HostTensor::f32(vec![rows, cols], data));
        }
        TrainState::from_params(params, layout.paths.clone())
    }

    fn train_step(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f32> {
        let (batch, seq) = self.check_batch(rc, tokens, Some(targets))?;
        let layout = self.layout(rc)?;
        let w = Weights::materialize(&layout, state)?;
        let sparse = self.sparse_layer(&layout, &w, seq)?;
        let inv_count = 1.0 / (batch * seq) as f32;
        // Fan out over the microbatch: each item computes its forward +
        // backward into a private GradAcc with a per-worker workspace.
        let layout_ref: &Layout = &layout;
        let state_ref: &TrainState = state;
        let w_ref = &w;
        let sparse_ref = sparse.as_ref();
        let per_item: Result<Vec<(f64, GradAcc)>> = (0..batch)
            .into_par_iter()
            .map_init(Workspace::default, |ws, bi| {
                let tok = &tokens[bi * seq..(bi + 1) * seq];
                let tgt = &targets[bi * seq..(bi + 1) * seq];
                let (trace, logits) =
                    self.forward_item(layout_ref, w_ref, state_ref, tok, sparse_ref, ws)?;
                let (lsum, dlogits) =
                    ce_loss_and_grad(&logits, tgt, inv_count, layout_ref.vocab)?;
                let mut acc = GradAcc::new(layout_ref);
                self.backward_item(
                    layout_ref, w_ref, &trace, tok, &dlogits, sparse_ref, &mut acc, ws,
                )?;
                Ok((lsum as f64, acc))
            })
            .collect();
        // Reduce in ascending item order: the loss sum and every leaf
        // gradient see one fixed operation order at any pool size.
        let mut acc = GradAcc::new(&layout);
        let mut loss_sum = 0.0f64;
        for (lsum, item_acc) in per_item? {
            loss_sum += lsum;
            acc.merge(&item_acc);
        }
        let loss = loss_sum as f32 * inv_count;
        // AdamW update, host side.
        let t = state.step.scalar()? as i32 + 1;
        state.step = HostTensor::scalar_i32(t);
        let hyper = AdamW { lr: rc.lr as f32, ..AdamW::default() };
        let TrainState { params, m, v, .. } = state;
        for (ix, g) in acc.g.iter().enumerate() {
            if let Some(g) = g {
                adamw_update(
                    params[ix].as_f32_mut()?,
                    g,
                    m[ix].as_f32_mut()?,
                    v[ix].as_f32_mut()?,
                    t,
                    &hyper,
                );
            }
        }
        Ok(loss)
    }

    fn eval_loss(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f32> {
        let (batch, seq) = self.check_batch(rc, tokens, Some(targets))?;
        let layout = self.layout(rc)?;
        let w = Weights::materialize(&layout, state)?;
        let sparse = self.sparse_layer(&layout, &w, seq)?;
        let inv_count = 1.0 / (batch * seq) as f32;
        // Item-parallel like train_step; the f64 per-item losses are
        // summed in ascending item order after the join.
        let layout_ref: &Layout = &layout;
        let w_ref = &w;
        let sparse_ref = sparse.as_ref();
        let per_item: Result<Vec<f64>> = (0..batch)
            .into_par_iter()
            .map_init(Workspace::default, |ws, bi| {
                let tok = &tokens[bi * seq..(bi + 1) * seq];
                let tgt = &targets[bi * seq..(bi + 1) * seq];
                let (_, logits) =
                    self.forward_item(layout_ref, w_ref, state, tok, sparse_ref, ws)?;
                Ok(ce_loss(&logits, tgt, layout_ref.vocab)? as f64)
            })
            .collect();
        let mut loss_sum = 0.0f64;
        for l in per_item? {
            loss_sum += l;
        }
        Ok(loss_sum as f32 * inv_count)
    }

    fn qa_choice_logits(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        answer_pos: &[usize],
        answer_tokens: &[u32; 4],
    ) -> Result<Vec<Vec<f32>>> {
        let (batch, seq) = self.check_batch(rc, tokens, None)?;
        if answer_pos.len() != batch {
            bail!("answer_pos has {} entries, batch is {batch}", answer_pos.len());
        }
        let layout = self.layout(rc)?;
        let w = Weights::materialize(&layout, state)?;
        let sparse = self.sparse_layer(&layout, &w, seq)?;
        let mut ws = Workspace::default();
        let mut out = Vec::with_capacity(batch);
        for (bi, &pos) in answer_pos.iter().enumerate() {
            if pos >= seq {
                bail!("answer slot {pos} outside sequence {seq}");
            }
            let tok = &tokens[bi * seq..(bi + 1) * seq];
            let trace =
                self.forward_block(&layout, &w, state, tok, sparse.as_ref(), &mut ws)?;
            // Only the answer slot's choice-token logits are read, so
            // skip the full (seq x vocab) LM-head GEMM: four d-length
            // dot products against the relevant wout columns suffice.
            let h = trace.x2.row(pos);
            out.push(
                answer_tokens
                    .iter()
                    .map(|&t| {
                        let col = t as usize;
                        h.iter()
                            .enumerate()
                            .map(|(i, &a)| a * w.wout.at(i, col))
                            .sum::<f32>()
                    })
                    .collect::<Vec<f32>>(),
            );
        }
        Ok(out)
    }

    fn refresh_codebooks(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
    ) -> Result<bool> {
        if rc.mode != Mode::Spt {
            return Ok(false);
        }
        let (batch, seq) = self.check_batch(rc, tokens, None)?;
        let layout = self.layout(rc)?;
        let Some(cb_ix) = layout.pq_cb else {
            return Ok(false);
        };
        let w = Weights::materialize(&layout, state)?;
        let mut cbs = w.codebooks.clone().context("spt mode without codebooks")?;
        // Collect the current K and Q projections per head (queries and
        // keys share the codebook space — match counts compare their
        // codes directly).
        let dh = layout.d_head;
        let mut head_data: Vec<Vec<f32>> =
            vec![Vec::with_capacity(2 * batch * seq * dh); layout.heads];
        let mut ws = Workspace::default();
        for bi in 0..batch {
            let tok = &tokens[bi * seq..(bi + 1) * seq];
            let x = self.embed(&layout, state, tok)?;
            let kf = x.matmul_ws(&w.wk, &mut ws);
            let qf = x.matmul_ws(&w.wq, &mut ws);
            for proj in [&kf, &qf] {
                for r in 0..proj.rows {
                    let row = proj.row(r);
                    for (h, data) in head_data.iter_mut().enumerate() {
                        data.extend_from_slice(&row[h * dh..(h + 1) * dh]);
                    }
                }
            }
        }
        for (cb, data) in cbs.iter_mut().zip(&head_data) {
            pq::codebook_update(data, cb, 1.0);
        }
        let stride = layout.pq_m * layout.pq_e * layout.pq_dsub;
        let buf = state.params[cb_ix].as_f32_mut()?;
        for (h, cb) in cbs.iter().enumerate() {
            buf[h * stride..(h + 1) * stride].copy_from_slice(&cb.data);
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rc(mode: Mode) -> RunConfig {
        RunConfig {
            model: "spt-nano".into(),
            mode,
            batch: 2,
            seq: 24,
            seed: 7,
            ..RunConfig::default()
        }
    }

    fn lm_batch(rc: &RunConfig, backend: &NativeBackend) -> (Vec<i32>, Vec<i32>) {
        let (batch, seq) = backend.workload(rc).unwrap();
        let vocab = backend.vocab(rc).unwrap();
        let mut corpus =
            crate::data::SyntheticCorpus::new(vocab, 4, 0.85, rc.seed);
        let mut tokens = Vec::new();
        let mut targets = Vec::new();
        for _ in 0..batch {
            let (x, y) = corpus.lm_pair(seq);
            tokens.extend(x.iter().map(|&t| t as i32));
            targets.extend(y.iter().map(|&t| t as i32));
        }
        (tokens, targets)
    }

    #[test]
    fn layouts_have_expected_leaf_counts() {
        let cfg = presets::model("spt-nano").unwrap();
        let full = Layout::new(&cfg, Mode::Full).unwrap();
        assert_eq!(full.n_leaves(), 9);
        let lora = Layout::new(&cfg, Mode::Lora).unwrap();
        assert_eq!(lora.n_leaves(), 9 + 12);
        let spt = Layout::new(&cfg, Mode::Spt).unwrap();
        assert_eq!(spt.n_leaves(), 9 + 12 + 2);
        assert_eq!(spt.paths.len(), spt.shapes.len());
        // Trainable sets: full trains the backbone, lora/spt do not.
        assert!(full.trainable()[full.wq]);
        assert!(!spt.trainable()[spt.wq]);
        assert!(spt.trainable()[spt.lora.unwrap()[SLOT_Q].a]);
        assert!(!spt.trainable()[spt.router.unwrap()]);
    }

    #[test]
    fn train_step_runs_and_is_deterministic_per_seed() {
        for mode in Mode::ALL {
            let rc = rc(mode);
            let backend = NativeBackend::new();
            let (tokens, targets) = lm_batch(&rc, &backend);
            let run = || {
                let mut state = backend.init_state(&rc).unwrap();
                let mut out = Vec::new();
                for _ in 0..3 {
                    out.push(
                        backend
                            .train_step(&rc, &mut state, &tokens, &targets)
                            .unwrap(),
                    );
                }
                out
            };
            let a = run();
            let b = run();
            for (x, y) in a.iter().zip(&b) {
                assert!(x.is_finite(), "{mode:?} loss not finite");
                assert_eq!(x.to_bits(), y.to_bits(), "{mode:?} nondeterministic");
            }
        }
    }

    #[test]
    fn layout_cache_reuses_allocation_until_config_changes() {
        let backend = NativeBackend::new();
        let rc_spt = rc(Mode::Spt);
        let l1 = backend.layout(&rc_spt).unwrap();
        let l2 = backend.layout(&rc_spt).unwrap();
        assert!(Arc::ptr_eq(&l1, &l2), "unchanged config must hit the cache");
        let rc_full = rc(Mode::Full);
        let l3 = backend.layout(&rc_full).unwrap();
        assert!(!Arc::ptr_eq(&l1, &l3), "mode change must rebuild");
        assert_eq!(l3.mode, Mode::Full);
        // Switching back rebuilds (single-entry cache) and stays correct.
        let l4 = backend.layout(&rc_spt).unwrap();
        assert_eq!(l4.mode, Mode::Spt);
        assert_eq!(l4.n_leaves(), l1.n_leaves());
    }

    #[test]
    fn eval_loss_matches_magnitude_and_ignores_state() {
        let rc = rc(Mode::Spt);
        let backend = NativeBackend::new();
        let (tokens, targets) = lm_batch(&rc, &backend);
        let state = backend.init_state(&rc).unwrap();
        let e1 = backend.eval_loss(&rc, &state, &tokens, &targets).unwrap();
        let e2 = backend.eval_loss(&rc, &state, &tokens, &targets).unwrap();
        assert_eq!(e1.to_bits(), e2.to_bits());
        // Untrained loss should sit near ln(vocab).
        let lnv = (backend.vocab(&rc).unwrap() as f32).ln();
        assert!((e1 - lnv).abs() < 1.0, "eval {e1} vs ln(V) {lnv}");
    }

    #[test]
    fn codebook_refresh_updates_codebook_leaf_only_in_spt() {
        let rc = rc(Mode::Spt);
        let backend = NativeBackend::new();
        let (tokens, _) = lm_batch(&rc, &backend);
        let mut state = backend.init_state(&rc).unwrap();
        let layout = backend.layout(&rc).unwrap();
        let cb_ix = layout.pq_cb.unwrap();
        let before = state.params[cb_ix].clone();
        let refreshed = backend.refresh_codebooks(&rc, &mut state, &tokens).unwrap();
        assert!(refreshed);
        let after = &state.params[cb_ix];
        assert!(before.max_abs_diff(after).unwrap() > 0.0, "codebooks unchanged");
        // Full mode: refresh is a no-op.
        let rc_full = rc_full_helper();
        let mut s2 = backend.init_state(&rc_full).unwrap();
        let (t2, _) = lm_batch(&rc_full, &backend);
        assert!(!backend.refresh_codebooks(&rc_full, &mut s2, &t2).unwrap());
    }

    fn rc_full_helper() -> RunConfig {
        rc(Mode::Full)
    }
}
