//! Analytic GPU-memory model for Transformer fine-tuning.
//!
//! The paper measures peak GPU memory on RTX 3090s; this testbed is
//! CPU-PJRT, so peak *device* memory is reproduced analytically: every
//! tensor a training step materializes is accounted by name and phase,
//! using the same structural facts the paper's numbers come from —
//!
//! * dense MHA stores the `[B, H, n, n]` attention matrix (and its
//!   gradient) — quadratic in sequence length (paper Fig. 9);
//! * sparse MHA stores `[B, H, n, L]` values + int32 indices instead
//!   (paper §4.1: O(nL) vs O(n^2));
//! * FFN activations are `[B, n, D]`; the routed FFN saves only the
//!   activated fraction beta (paper §4.2);
//! * Full tuning keeps gradients + AdamW moments for every base weight;
//!   LoRA/SPT only for adapters (paper §2.2) — but *activations* dominate
//!   at realistic batch sizes (paper §6.2 Discussions).
//!
//! The model is validated in-tree: monotonicity properties, the paper's
//! qualitative orderings, and ratio checks against Table 1/Table 4/Fig. 8b
//! live in `rust/tests/` and the bench harness prints model outputs next
//! to the paper's columns.
//!
//! The native training path now *realizes* the `n_layers`-deep
//! activation picture [`model_peak`] prices: `coordinator/native.rs`
//! stacks the preset's full depth and its backward holds every layer's
//! saved activations live (per-layer attention CSRs, routed-FFN
//! routings, layer-norm inputs) exactly as the
//! no-activation-checkpointing branch below assumes, while gradient
//! memory is bounded by the fixed-size chunked accumulator fan-out
//! rather than O(batch).

pub mod block;
pub mod report;

pub use block::{block_peak, module_peak, BlockWorkload, MemBreakdown, Module, Phase, TensorAcct};

use crate::config::{BlockConfig, Mode};

/// Peak memory for an `n_layers`-deep model: with activation
/// checkpointing off (paper's setting), backward keeps every layer's saved
/// activations live, while weights/grads/opt scale with depth — the same
/// structure the native backend's stacked train step materializes.
pub fn model_peak(
    cfg: &BlockConfig,
    mode: Mode,
    batch: usize,
    seq: usize,
    n_layers: usize,
    vocab: usize,
) -> u64 {
    let per_block = block_peak(cfg, mode, &BlockWorkload { batch, seq });
    // Per-layer persistent (weights+grad+opt) and saved activations stack;
    // the transient workspace is needed once (layers execute serially).
    let persistent: u64 = per_block.persistent_bytes();
    let saved: u64 = per_block.saved_activation_bytes();
    let transient: u64 = per_block.transient_bytes();
    let embed = (2 * vocab + seq) as u64 * cfg.d_model as u64 * 4;
    let logits = (batch * seq * vocab) as u64 * 4;
    // logits + grad of logits live at the loss boundary.
    n_layers as u64 * (persistent + saved) + transient + embed * multiplier(mode) + 2 * logits
}

fn multiplier(mode: Mode) -> u64 {
    // Full tuning trains the embedding/head too: grad + 2 opt moments.
    match mode {
        Mode::Full => 4,
        Mode::Lora | Mode::Spt => 1,
    }
}

/// Peak *GPU* memory with DeepSpeed-style parameter/optimizer offloading
/// (the paper's Table 3 setting): persistent state lives in host memory
/// and streams through a 2-block working set; activations (and the loss
/// boundary) stay on the GPU.
pub fn model_peak_offloaded(
    cfg: &BlockConfig,
    mode: Mode,
    batch: usize,
    seq: usize,
    n_layers: usize,
    vocab: usize,
) -> u64 {
    let per_block = block_peak(cfg, mode, &BlockWorkload { batch, seq });
    let working_set = 2 * per_block.persistent_bytes(); // current + prefetch
    // Activation offloading streams saved activations to host, but a
    // pipeline window of blocks stays resident (DeepSpeed keeps several
    // in flight to overlap transfers).
    const ACT_WINDOW: u64 = 4;
    let saved = ACT_WINDOW.min(n_layers as u64) * per_block.saved_activation_bytes();
    let transient = per_block.transient_bytes();
    let embed_act = (batch * seq * cfg.d_model) as u64 * 4;
    let logits = (batch * seq * vocab) as u64 * 4;
    saved + working_set + transient + embed_act + 2 * logits
}

/// Max sequence length under a byte budget, probing in `step` increments —
/// the paper's Table 3 "Max Length" protocol (increments of 128 until OOM,
/// with DeepSpeed offloading enabled).
pub fn max_seq_under_budget(
    cfg: &BlockConfig,
    mode: Mode,
    batch: usize,
    n_layers: usize,
    vocab: usize,
    budget: u64,
    step: usize,
) -> usize {
    let mut best = 0;
    let mut seq = step;
    while seq <= 65536 {
        let peak = model_peak_offloaded(cfg, mode, batch, seq, n_layers, vocab);
        if peak > budget {
            break;
        }
        best = seq;
        seq += step;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn wl() -> BlockWorkload {
        BlockWorkload { batch: 16, seq: 512 }
    }

    #[test]
    fn ordering_matches_paper_block_level() {
        // Fig. 8b: peak(SPT) < peak(LoRA) < peak(Full) for every config.
        for cfg in presets::paper_blocks() {
            let full = block_peak(&cfg, Mode::Full, &wl()).peak_bytes();
            let lora = block_peak(&cfg, Mode::Lora, &wl()).peak_bytes();
            let spt = block_peak(&cfg, Mode::Spt, &wl()).peak_bytes();
            assert!(spt < lora, "{}: spt {} !< lora {}", cfg.name, spt, lora);
            assert!(lora < full, "{}: lora {} !< full {}", cfg.name, lora, full);
        }
    }

    #[test]
    fn quadratic_growth_for_dense_linear_for_sparse() {
        // Fig. 9: dense MHA memory grows ~quadratically in n, sparse ~linearly
        // (L = n/8 keeps nL quadratic too but 8x smaller; the paper's picture
        // is the gap widening with n — assert that).
        let cfg = presets::block("opt-2048").unwrap();
        let gap = |seq: usize| {
            let w = BlockWorkload { batch: 16, seq };
            block_peak(&cfg, Mode::Lora, &w).peak_bytes() as i64
                - block_peak(&cfg, Mode::Spt, &w).peak_bytes() as i64
        };
        assert!(gap(1024) > 2 * gap(512), "{} vs {}", gap(1024), gap(512));
    }

    #[test]
    fn spt_max_length_exceeds_baselines() {
        // Table 3: SPT supports ~2x Full's max length, >1.5x LoRA's.
        let cfg = presets::block("opt-2560").unwrap();
        let budget = 24u64 << 30;
        let full = max_seq_under_budget(&cfg, Mode::Full, 16, 32, 50272, budget, 128);
        let lora = max_seq_under_budget(&cfg, Mode::Lora, 16, 32, 50272, budget, 128);
        let spt = max_seq_under_budget(&cfg, Mode::Spt, 16, 32, 50272, budget, 128);
        assert!(full > 0 && lora >= full && spt > lora, "{full} {lora} {spt}");
    }

    #[test]
    fn batch_scaling_is_linear_in_activations() {
        let cfg = presets::block("opt-1024").unwrap();
        let p1 = block_peak(&cfg, Mode::Spt, &BlockWorkload { batch: 1, seq: 512 });
        let p4 = block_peak(&cfg, Mode::Spt, &BlockWorkload { batch: 4, seq: 512 });
        assert_eq!(p1.persistent_bytes(), p4.persistent_bytes());
        assert!(p4.saved_activation_bytes() >= 4 * p1.saved_activation_bytes() - 64);
    }
}
