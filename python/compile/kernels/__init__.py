"""SPT L1 kernels: Pallas implementations + pure-jnp reference oracles.

Modules:
  pq          — fused cdist+argmin product quantization (paper Alg. 2)
  topl        — integer bucket-sort top-L selection (paper Alg. 3)
  sparse_attn — SDDMM / sparse softmax / SpMM with custom VJP (paper §5.1)
  routed_ffn  — router + blocked sparse matrix-vector multiply (paper Alg. 4)
  ref         — dense jnp oracles for all of the above
"""

from . import pq, ref, routed_ffn, sparse_attn, topl

__all__ = ["pq", "ref", "routed_ffn", "sparse_attn", "topl"]
