"""Sparse attention kernels vs reference: fwd, bwd, and approximation."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pq, ref, sparse_attn, topl

SETTINGS = dict(max_examples=3, deadline=None)


def _setup(seed, b, n, d, l):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, n, d), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (b, n, d), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (b, n, d), dtype=jnp.float32)
    idx = jax.random.randint(ks[3], (b, n, l), 0, n, dtype=jnp.int32)
    return q, k, v, idx


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 3),
    n=st.sampled_from([8, 32, 65]),
    d=st.sampled_from([8, 32, 64]),
    l=st.sampled_from([1, 4, 8]),
)
def test_sddmm_matches_ref(seed, b, n, d, l):
    q, k, v, idx = _setup(seed, b, n, d, l)
    got = sparse_attn.sddmm(q, k, idx)
    want = jax.vmap(ref.sddmm)(q, k, idx)
    assert jnp.allclose(got, want, atol=1e-4)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    causal=st.booleans(),
)
def test_softmax_matches_ref(seed, causal):
    q, k, v, idx = _setup(seed, 2, 32, 16, 8)
    vals = sparse_attn.sddmm(q, k, idx)
    valid = sparse_attn.make_valid_mask(idx, causal)
    got = sparse_attn.sparse_softmax_fwd(vals, valid)
    want = jax.vmap(
        lambda vv, ii: ref.sparse_softmax(vv, ii, causal=causal)
    )(vals, idx)
    assert jnp.allclose(got, want, atol=1e-5)


def test_softmax_rows_sum_to_one():
    q, k, v, idx = _setup(0, 2, 32, 16, 8)
    vals = sparse_attn.sddmm(q, k, idx)
    valid = sparse_attn.make_valid_mask(idx, False)
    w = sparse_attn.sparse_softmax_fwd(vals, valid)
    assert jnp.allclose(jnp.sum(w, axis=-1), 1.0, atol=1e-5)


def test_softmax_masks_duplicates():
    """Duplicate key ids in a row must carry zero weight past the first."""
    idx = jnp.array([[[3, 3, 5, 3]]], dtype=jnp.int32)
    vals = jnp.ones((1, 1, 4), dtype=jnp.float32)
    valid = sparse_attn.make_valid_mask(idx, False)
    assert valid.tolist() == [[[1, 0, 1, 0]]]
    w = sparse_attn.sparse_softmax_fwd(vals, valid)
    assert jnp.allclose(w[0, 0], jnp.array([0.5, 0.0, 0.5, 0.0]), atol=1e-6)


def test_softmax_causal_masks_future():
    idx = jnp.array([[[0, 1, 2, 3], [0, 1, 2, 3]]], dtype=jnp.int32)
    valid = sparse_attn.make_valid_mask(idx, True)
    assert valid[0, 0].tolist() == [1, 0, 0, 0]
    assert valid[0, 1].tolist() == [1, 1, 0, 0]


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), l=st.sampled_from([1, 4, 16]))
def test_spmm_matches_ref(seed, l):
    q, k, v, idx = _setup(seed, 2, 32, 16, l)
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 1), idx.shape))
    got = sparse_attn.spmm(w, idx, v)
    want = jax.vmap(ref.spmm)(w, idx, v)
    assert jnp.allclose(got, want, atol=1e-4)


@pytest.mark.parametrize("causal", [False, True])
def test_attention_matches_ref(causal):
    q, k, v, idx = _setup(7, 2, 64, 32, 8)
    got = sparse_attn.sparse_attention(q, k, v, idx, causal, None)
    want = jax.vmap(
        lambda a, b, c, i: ref.sparse_attention(a, b, c, i, causal=causal)
    )(q, k, v, idx)
    assert jnp.allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("causal", [False, True])
def test_attention_grads_match_ref(causal):
    """Hand-written backward kernels vs autodiff of the dense reference
    (paper Fig. 11: both passes verified)."""
    q, k, v, idx = _setup(8, 2, 32, 16, 8)
    tgt = jax.random.normal(jax.random.PRNGKey(99), q.shape)

    def loss_kernel(q, k, v):
        y = sparse_attn.sparse_attention(q, k, v, idx, causal, None)
        return jnp.sum((y - tgt) ** 2)

    def loss_ref(q, k, v):
        y = jax.vmap(
            lambda a, b, c, i: ref.sparse_attention(a, b, c, i, causal=causal)
        )(q, k, v, idx)
        return jnp.sum((y - tgt) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert jnp.allclose(a, b, atol=1e-3), float(jnp.max(jnp.abs(a - b)))


def test_l_equals_n_recovers_dense_attention():
    """With all keys selected, sparse attention == vanilla attention."""
    b, n, d = 1, 32, 16
    q, k, v, _ = _setup(9, b, n, d, 1)
    idx = jnp.tile(jnp.arange(n, dtype=jnp.int32)[None, None], (b, n, 1))
    for causal in (False, True):
        got = sparse_attn.sparse_attention(q, k, v, idx, causal, None)
        want = jax.vmap(
            lambda a, b2, c: ref.dense_attention(a, b2, c, causal=causal)
        )(q, k, v)
        assert jnp.allclose(got, want, atol=1e-5), causal


def test_topl_attention_approximates_dense():
    """Paper Fig. 3: top-L softmax keeps most of the mass -> small error.

    Uses real PQ + bucket-sort selection end to end (Alg. 1).
    """
    b, n, d, m, e = 1, 128, 64, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(10), 4)
    k_ = jax.random.normal(ks[0], (b, n, d))
    # Correlated queries so attention is skewed (as in trained models).
    q_ = 2.0 * k_ + 0.5 * jax.random.normal(ks[1], (b, n, d))
    v_ = jax.random.normal(ks[2], (b, n, d))
    cb = pq.init_codebooks(ks[3], m, e, d // m)
    for _ in range(5):
        cb = pq.pq_codebook_update(k_, cb, lr=1.0)
    idx = topl.topl_select(pq.pq_quantize(q_, cb), pq.pq_quantize(k_, cb), n // 4)
    y_sparse = sparse_attn.sparse_attention(q_, k_, v_, idx, False, None)
    y_dense = jax.vmap(ref.dense_attention)(q_, k_, v_)
    rel = float(
        jnp.linalg.norm(y_sparse - y_dense) / jnp.linalg.norm(y_dense)
    )
    assert rel < 0.35, rel


def test_attention_weight_cdf_skew():
    """Regenerates the Fig. 3 observation: top-15% of weights >= 50% of mass
    for correlated (trained-like) q/k."""
    n, d = 256, 64
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    k_ = jax.random.normal(ks[0], (n, d))
    q_ = 2.0 * k_ + 0.5 * jax.random.normal(ks[1], (n, d))
    w = jax.nn.softmax((q_ @ k_.T) / jnp.sqrt(d), axis=-1)
    w_sorted = jnp.sort(w, axis=-1)[:, ::-1]
    top15 = int(0.15 * n)
    mass = float(jnp.mean(jnp.sum(w_sorted[:, :top15], axis=-1)))
    assert mass > 0.5, mass
