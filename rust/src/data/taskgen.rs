//! MMLU-surrogate task generator: 4-choice QA over synthetic "knowledge".
//!
//! Each task family has a hidden rule mapping a question pattern to the
//! correct choice; sequences are rendered as
//! `[Q-tokens ... | choice tokens | answer-slot]` so a model fine-tuned
//! with next-token loss learns to emit the right answer token.  Accuracy
//! over held-out questions is the MMLU-score surrogate in Table 3.

use crate::util::rng::Rng;

/// Reserved special tokens at the top of the vocabulary.
const SPECIALS: u32 = 8;
const TOK_SEP: u32 = 1;
const TOK_ANS: u32 = 2;

/// A rendered QA batch.
#[derive(Debug, Clone)]
pub struct QaBatch {
    /// `[batch][seq]` input tokens.
    pub tokens: Vec<Vec<u32>>,
    /// `[batch][seq]` next-token targets (answer token at the slot).
    pub targets: Vec<Vec<u32>>,
    /// `[batch]` position of the answer slot (where accuracy is read).
    pub answer_pos: Vec<usize>,
    /// `[batch]` the correct answer token.
    pub answer_tok: Vec<u32>,
}

/// Generator of synthetic 4-choice QA items.
pub struct QaTaskGen {
    vocab: usize,
    /// Hidden rule: subject token -> correct choice index (0..4).
    rule: Vec<u8>,
    /// Answer tokens for choices A-D.
    answer_tokens: [u32; 4],
    rng: Rng,
}

impl QaTaskGen {
    pub fn new(vocab: usize, n_subjects: usize, seed: u64) -> Self {
        assert!(vocab as u32 > SPECIALS + 4 + n_subjects as u32);
        let mut rng = Rng::new(seed);
        let rule = (0..n_subjects).map(|_| rng.below(4) as u8).collect(); // det: cast-bounded
        let answer_tokens = [3, 4, 5, 6]; // choice tokens A..D
        QaTaskGen { vocab, rule, answer_tokens, rng }
    }

    pub fn n_subjects(&self) -> usize {
        self.rule.len()
    }

    fn subject_token(&self, s: usize) -> u32 {
        SPECIALS + 4 + s as u32
    }

    /// Render one QA item into a fixed-length sequence.
    ///
    /// Layout: [subject, filler..., SEP, A, B, C, D, ANS, answer, pad...]
    fn render(&mut self, seq_len: usize, subject: usize) -> (Vec<u32>, usize, u32) {
        let correct = self.rule[subject] as usize;
        let ans_tok = self.answer_tokens[correct];
        let mut toks = Vec::with_capacity(seq_len);
        toks.push(self.subject_token(subject));
        // Filler "question text": random content tokens (model must learn
        // to key on the subject token).
        let filler = seq_len.saturating_sub(8).min(seq_len - 8);
        for _ in 0..filler {
            // det: cast-bounded (below() result < vocab)
            let t = SPECIALS + 4 + self.rng.below(self.vocab - (SPECIALS + 4) as usize) as u32;
            toks.push(t);
        }
        toks.push(TOK_SEP);
        for &a in &self.answer_tokens {
            toks.push(a);
        }
        toks.push(TOK_ANS);
        let answer_pos = toks.len() - 1; // target at this position = answer
        toks.push(ans_tok);
        while toks.len() < seq_len + 1 {
            toks.push(0); // pad
        }
        toks.truncate(seq_len + 1);
        (toks, answer_pos, ans_tok)
    }

    /// Generate a batch of rendered items (LM-style inputs/targets).
    pub fn batch(&mut self, batch: usize, seq_len: usize) -> QaBatch {
        assert!(seq_len >= 12, "seq too short for QA layout");
        let mut tokens = Vec::with_capacity(batch);
        let mut targets = Vec::with_capacity(batch);
        let mut answer_pos = Vec::with_capacity(batch);
        let mut answer_tok = Vec::with_capacity(batch);
        for _ in 0..batch {
            let subject = self.rng.below(self.rule.len());
            let (seq, pos, ans) = self.render(seq_len, subject);
            tokens.push(seq[..seq_len].to_vec());
            targets.push(seq[1..seq_len + 1].to_vec());
            answer_pos.push(pos);
            answer_tok.push(ans);
        }
        QaBatch { tokens, targets, answer_pos, answer_tok }
    }

    /// Score model predictions: fraction of items whose argmax logit at
    /// the answer slot (over the 4 choice tokens) is correct.
    pub fn accuracy(
        &self,
        batch: &QaBatch,
        logits_at_slots: &[Vec<f32>], // [batch][4] choice-token logits
    ) -> f32 {
        let mut correct = 0usize;
        for (i, row) in logits_at_slots.iter().enumerate() {
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap();
            if self.answer_tokens[pred] == batch.answer_tok[i] {
                correct += 1;
            }
        }
        correct as f32 / logits_at_slots.len().max(1) as f32
    }

    pub fn answer_tokens(&self) -> [u32; 4] {
        self.answer_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_alignment() {
        let mut g = QaTaskGen::new(4096, 64, 1);
        let b = g.batch(8, 64);
        assert_eq!(b.tokens.len(), 8);
        for i in 0..8 {
            assert_eq!(b.tokens[i].len(), 64);
            assert_eq!(b.targets[i].len(), 64);
            // LM alignment: target at answer_pos equals answer token.
            assert_eq!(b.targets[i][b.answer_pos[i]], b.answer_tok[i]);
            // And the input at answer_pos is the ANS marker.
            assert_eq!(b.tokens[i][b.answer_pos[i]], TOK_ANS);
        }
    }

    #[test]
    fn rule_is_consistent_per_subject() {
        let mut g = QaTaskGen::new(4096, 4, 2);
        let b1 = g.batch(64, 32);
        // group answers by subject token (first token)
        let mut seen = std::collections::BTreeMap::new();
        for i in 0..64 {
            let subj = b1.tokens[i][0];
            let e = seen.entry(subj).or_insert(b1.answer_tok[i]);
            assert_eq!(*e, b1.answer_tok[i], "subject {subj} inconsistent");
        }
    }

    #[test]
    fn accuracy_scoring() {
        let mut g = QaTaskGen::new(4096, 8, 3);
        let b = g.batch(4, 32);
        // Perfect logits: one-hot at the right choice.
        let perfect: Vec<Vec<f32>> = (0..4)
            .map(|i| {
                let mut row = vec![0.0f32; 4];
                let idx = g
                    .answer_tokens()
                    .iter()
                    .position(|&t| t == b.answer_tok[i])
                    .unwrap();
                row[idx] = 1.0;
                row
            })
            .collect();
        assert_eq!(g.accuracy(&b, &perfect), 1.0);
        // Constant logits: picks choice 0 always -> accuracy = frequency of A.
        let constant = vec![vec![1.0, 0.0, 0.0, 0.0]; 4];
        let acc = g.accuracy(&b, &constant);
        assert!(acc <= 1.0);
    }

    #[test]
    fn answers_use_choice_tokens_only() {
        let mut g = QaTaskGen::new(4096, 16, 4);
        let b = g.batch(32, 24);
        for &a in &b.answer_tok {
            assert!(g.answer_tokens().contains(&a));
        }
    }
}
