//! Training state: parameter + optimizer leaves, plus the AdamW update
//! the native backend applies host-side.
//!
//! The leaf order contract comes from the AOT artifacts (`aot.py` / jax
//! pytree flattening of `(params, opt, tokens, targets)` with
//! `opt = {"m", "step", "v"}`):
//!
//! ```text
//! inputs  = [params x P, m x P, step, v x P, tokens, targets]
//! outputs = [loss, params x P, m x P, step, v x P]
//! ```
//!
//! The PJRT path bakes the AdamW math into the train-step executable;
//! the native path keeps the same state layout but applies
//! [`adamw_update`] leaf by leaf, so checkpoints are interchangeable
//! bookkeeping-wise and the trainer stays backend-agnostic.
//!
//! Everything here is *leaf-generic*: the native backend's `n_layers`-
//! deep layouts simply register one leaf group per layer
//! (`['blocks'][i][...]` paths — weights, layer norms, adapters, and
//! per-layer PQ codebooks), and the moment vectors, the AdamW sweep, and
//! the artifact I/O contracts thread through unchanged.

use anyhow::{bail, Context, Result};

#[cfg(feature = "xla")]
use crate::runtime::Engine;
use crate::runtime::{ArtifactSpec, HostTensor};

/// AdamW hyperparameters (the native backend's optimizer; the PJRT
/// artifacts bake their own copy of the same defaults).
#[derive(Debug, Clone, Copy)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamW {
    fn default() -> Self {
        AdamW { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

/// One AdamW step on a single leaf, with bias correction at step `t`
/// (1-based).  Deterministic elementwise math — the checkpoint
/// round-trip test relies on resumed updates being bit-identical.
pub fn adamw_update(
    param: &mut [f32],
    grad: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    t: i32,
    h: &AdamW,
) {
    debug_assert_eq!(param.len(), grad.len());
    debug_assert_eq!(param.len(), m.len());
    debug_assert_eq!(param.len(), v.len());
    let bc1 = 1.0 - h.beta1.powi(t);
    let bc2 = 1.0 - h.beta2.powi(t);
    for i in 0..param.len() {
        let g = grad[i];
        m[i] = h.beta1 * m[i] + (1.0 - h.beta1) * g;
        v[i] = h.beta2 * v[i] + (1.0 - h.beta2) * g * g;
        let m_hat = m[i] / bc1;
        let v_hat = v[i] / bc2;
        param[i] -= h.lr * (m_hat / (v_hat.sqrt() + h.eps) + h.weight_decay * param[i]);
    }
}

/// Host-side training state for one model+mode.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: HostTensor,
    /// Leaf paths of `params` (from the backend init), for named lookup.
    pub param_paths: Vec<String>,
}

impl TrainState {
    /// Build a fresh state (zero moments, step 0) from parameter leaves.
    pub fn from_params(params: Vec<HostTensor>, param_paths: Vec<String>) -> Result<Self> {
        if params.len() != param_paths.len() {
            bail!(
                "{} parameter leaves but {} paths",
                params.len(),
                param_paths.len()
            );
        }
        let m = params
            .iter()
            .map(|p| {
                HostTensor::zeros(&crate::runtime::TensorSpec {
                    shape: p.shape().to_vec(),
                    dtype: p.dtype(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let v = m.clone();
        Ok(TrainState {
            params,
            m,
            v,
            step: HostTensor::scalar_i32(0),
            param_paths,
        })
    }

    /// Initialize by executing the `model_init_*` artifact (PJRT path).
    #[cfg(feature = "xla")]
    pub fn init(engine: &Engine, init_artifact: &str, seed: i32) -> Result<Self> {
        let spec = engine.spec(init_artifact)?.clone();
        let params = engine.run(init_artifact, &[HostTensor::scalar_i32(seed)])?;
        Self::from_params(params, spec.output_paths.clone())
    }

    pub fn n_leaves(&self) -> usize {
        self.params.len()
    }

    /// Assemble the input vector for a train-step artifact.
    pub fn step_inputs(&self, tokens: HostTensor, targets: HostTensor) -> Vec<HostTensor> {
        let mut v = Vec::with_capacity(3 * self.params.len() + 3);
        v.extend(self.params.iter().cloned());
        v.extend(self.m.iter().cloned());
        v.push(self.step.clone());
        v.extend(self.v.iter().cloned());
        v.push(tokens);
        v.push(targets);
        v
    }

    /// Consume a train-step artifact's outputs; returns the loss tensor.
    pub fn absorb_step_outputs(&mut self, mut out: Vec<HostTensor>) -> Result<HostTensor> {
        let p = self.params.len();
        let expect = 1 + 3 * p + 1;
        if out.len() != expect {
            bail!("train step returned {} outputs, expected {expect}", out.len());
        }
        let loss = out.remove(0);
        self.params = out.drain(..p).collect();
        self.m = out.drain(..p).collect();
        self.step = out.remove(0);
        self.v = out.drain(..p).collect();
        debug_assert!(out.is_empty());
        Ok(loss)
    }

    /// Validate this state against a train-step artifact signature.
    pub fn check_against(&self, spec: &ArtifactSpec) -> Result<()> {
        let p = self.params.len();
        let want = 3 * p + 3;
        if spec.inputs.len() != want {
            bail!(
                "artifact '{}' has {} inputs; state implies {want}",
                spec.name,
                spec.inputs.len()
            );
        }
        for (i, t) in self.params.iter().enumerate() {
            if !t.matches(&spec.inputs[i]) {
                bail!("param leaf {i} mismatch vs '{}'", spec.name);
            }
        }
        Ok(())
    }

    /// Indices of parameter leaves whose path contains `needle`
    /// (e.g. "pq_q" for codebook patching).
    pub fn find_leaves(&self, needle: &str) -> Vec<usize> {
        self.param_paths
            .iter()
            .enumerate()
            .filter(|(_, p)| p.contains(needle))
            .map(|(i, _)| i)
            .collect()
    }

    /// Replace one parameter leaf (shape-checked).
    pub fn set_leaf(&mut self, idx: usize, t: HostTensor) -> Result<()> {
        let old = self
            .params
            .get(idx)
            .context("leaf index out of range")?;
        if old.shape() != t.shape() || old.dtype() != t.dtype() {
            bail!(
                "leaf {idx} shape/dtype mismatch: {:?} vs {:?}",
                old.shape(),
                t.shape()
            );
        }
        self.params[idx] = t;
        Ok(())
    }

    /// Total bytes held by this state (params + moments).
    pub fn bytes(&self) -> usize {
        self.params.iter().map(HostTensor::bytes).sum::<usize>()
            + self.m.iter().map(HostTensor::bytes).sum::<usize>()
            + self.v.iter().map(HostTensor::bytes).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::DType;

    fn dummy_state(p: usize) -> TrainState {
        let t = |i: usize| HostTensor::f32(vec![2, 2], vec![i as f32; 4]);
        TrainState {
            params: (0..p).map(t).collect(),
            m: (0..p).map(|_| HostTensor::f32(vec![2, 2], vec![0.0; 4])).collect(),
            v: (0..p).map(|_| HostTensor::f32(vec![2, 2], vec![0.0; 4])).collect(),
            step: HostTensor::scalar_i32(0),
            param_paths: (0..p).map(|i| format!("['blocks']['leaf{i}']")).collect(),
        }
    }

    #[test]
    fn step_io_roundtrip() {
        let mut s = dummy_state(3);
        let tokens = HostTensor::i32(vec![1, 4], vec![1, 2, 3, 4]);
        let inputs = s.step_inputs(tokens.clone(), tokens.clone());
        assert_eq!(inputs.len(), 3 * 3 + 3);
        // Fake outputs: loss + bumped state.
        let mut out = vec![HostTensor::scalar_f32(1.5)];
        out.extend((0..3).map(|_| HostTensor::f32(vec![2, 2], vec![9.0; 4]))); // params
        out.extend((0..3).map(|_| HostTensor::f32(vec![2, 2], vec![0.1; 4]))); // m
        out.push(HostTensor::scalar_i32(1));
        out.extend((0..3).map(|_| HostTensor::f32(vec![2, 2], vec![0.2; 4]))); // v
        let loss = s.absorb_step_outputs(out).unwrap();
        assert_eq!(loss.scalar().unwrap(), 1.5);
        assert_eq!(s.params[0].as_f32().unwrap()[0], 9.0);
        assert_eq!(s.step.scalar().unwrap(), 1.0);
        assert_eq!(s.v[2].as_f32().unwrap()[0], 0.2);
    }

    #[test]
    fn absorb_rejects_wrong_arity() {
        let mut s = dummy_state(2);
        assert!(s.absorb_step_outputs(vec![HostTensor::scalar_f32(0.0)]).is_err());
    }

    #[test]
    fn leaf_lookup_and_patch() {
        let mut s = dummy_state(4);
        s.param_paths[2] = "['blocks']['pq_q']".into();
        let found = s.find_leaves("pq_q");
        assert_eq!(found, vec![2]);
        s.set_leaf(2, HostTensor::f32(vec![2, 2], vec![7.0; 4])).unwrap();
        assert_eq!(s.params[2].as_f32().unwrap()[0], 7.0);
        // shape mismatch rejected
        assert!(s.set_leaf(2, HostTensor::f32(vec![4], vec![0.0; 4])).is_err());
        assert!(s
            .set_leaf(9, HostTensor::f32(vec![2, 2], vec![0.0; 4]))
            .is_err());
    }

    #[test]
    fn bytes_accounting() {
        let s = dummy_state(2);
        assert_eq!(s.bytes(), 3 * 2 * 16);
    }

    #[test]
    fn from_params_builds_zero_moments() {
        let params = vec![HostTensor::f32(vec![2], vec![1.0, 2.0])];
        let s = TrainState::from_params(params, vec!["['w']".into()]).unwrap();
        assert_eq!(s.m[0].as_f32().unwrap(), &[0.0, 0.0]);
        assert_eq!(s.v[0].as_f32().unwrap(), &[0.0, 0.0]);
        assert_eq!(s.step.scalar().unwrap(), 0.0);
        // Arity mismatch between leaves and paths is rejected.
        let params = vec![HostTensor::f32(vec![1], vec![0.0])];
        assert!(TrainState::from_params(params, vec![]).is_err());
    }

    #[test]
    fn adamw_first_step_moves_against_gradient() {
        let h = AdamW::default();
        let mut p = vec![1.0f32, -1.0];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        let g = vec![2.0f32, -3.0];
        adamw_update(&mut p, &g, &mut m, &mut v, 1, &h);
        // With zero moments the first update is ~ -lr * sign(g).
        assert!((p[0] - (1.0 - h.lr)).abs() < 1e-4, "{}", p[0]);
        assert!((p[1] - (-1.0 + h.lr)).abs() < 1e-4, "{}", p[1]);
        assert!(m[0] > 0.0 && v[0] > 0.0);
    }

    #[test]
    fn adamw_is_deterministic() {
        let h = AdamW::default();
        let run = || {
            let mut p = vec![0.5f32, 0.25, -0.75];
            let mut m = vec![0.0f32; 3];
            let mut v = vec![0.0f32; 3];
            for t in 1..=10 {
                let g: Vec<f32> = p.iter().map(|x| x * 0.3 + 0.1).collect();
                adamw_update(&mut p, &g, &mut m, &mut v, t, &h);
            }
            p
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
