//! Synthetic data pipeline — the paper's dataset substitutes.
//!
//! The paper fine-tunes on MMLU (4-way QA) and Wikitext-103 (next-word
//! prediction) plus a "Random" generator for micro experiments.  Neither
//! corpus ships with this reproduction, so we build structured synthetic
//! equivalents that exercise identical code paths (see DESIGN.md
//! §Substitutions):
//!
//! * [`corpus`]  — a Zipf-unigram + Markov-bigram language over the model
//!   vocabulary: learnable structure so fine-tuning measurably reduces
//!   loss/PPL (the Fig. 10 axis), unlike i.i.d. uniform tokens.
//! * [`taskgen`] — an MMLU-like 4-choice QA task rendered into token
//!   sequences with an answer slot; accuracy is the MMLU-score surrogate.
//! * [`batcher`] — deterministic shuffled mini-batching with epoch
//!   boundaries (every token scheduled exactly once per epoch).

pub mod batcher;
pub mod corpus;
pub mod taskgen;

pub use batcher::{Batch, Batcher};
pub use corpus::SyntheticCorpus;
pub use taskgen::{QaBatch, QaTaskGen};
