//! The fine-tuning trainer: the L3 hot loop, generic over the execution
//! [`Backend`].
//!
//! The trainer owns everything backend-independent — mini-batching over
//! the synthetic corpus, the DKM codebook-refresh schedule (paper §5.1:
//! every ~20 mini-batches, spt mode only), held-out eval (PPL), QA
//! accuracy (the MMLU surrogate), step timing, loss curves, and
//! checkpoint/resume bookkeeping — and delegates the actual train step
//! to the backend: the native substrate by default, or the AOT/PJRT
//! engine (`--features xla`).
//!
//! Two dispatch paths:
//! * per-step: one `Backend::train_step` per mini-batch;
//! * chunked: `Backend::train_chunk8` scans 8 microbatches inside one
//!   dispatch where the backend supports it (the PJRT scan-of-8
//!   executable, which amortizes host<->device marshalling).
//!
//! Resume contract: a run restored from a checkpoint replays the exact
//! batch schedule of an uninterrupted run (the batcher is deterministic
//! per seed and the trainer fast-forwards every RNG-consuming stream by
//! the restored step count), so the resumed loss curve is bit-identical
//! — `tests/integration_native_train.rs` asserts this.

use std::sync::Arc;
use std::time::Instant; // det: wall-clock (throughput metrics only)

use anyhow::{bail, Context, Result};

use super::backend::Backend;
use super::checkpoint::{self, CkptMeta};
use super::state::TrainState;
use crate::config::{presets, Mode, RunConfig};
use crate::data::{Batcher, QaTaskGen, SyntheticCorpus};
use crate::memmodel;
use crate::metrics::Counters;
use crate::obs::{ObsLog, StepObs};
use crate::runtime::HostTensor;
use crate::util::fault::{self, FaultPlan};
use crate::util::json::Json;

/// Trainer options beyond the run config.
#[derive(Debug, Clone)]
pub struct TrainerOptions {
    /// Use the chunked (scan-of-8) dispatch path when available.
    pub chunked: bool,
    /// Held-out eval batches per eval point.
    pub eval_batches: usize,
    /// Bigram structure of the synthetic corpus.
    pub corpus_branch: usize,
    pub corpus_bigram_p: f64,
    /// Halt after this many optimizer steps *this run* (checkpoint /
    /// resume workflows; `None` runs to `rc.steps`).
    pub stop_after: Option<usize>,
    /// Periodic crash-safe checkpointing: every `ckpt_every` optimizer
    /// steps, write `step-{step:08}.ckpt` into `ckpt_dir` atomically
    /// (v3, per-tensor CRC).  `--auto-resume` scans the same directory.
    pub ckpt_dir: Option<std::path::PathBuf>,
    pub ckpt_every: usize,
    /// Fault plan threaded through checkpoint I/O (chaos tests / the
    /// `SPT_FAULT_PLAN` env var).  Recoverable faults never change what
    /// the trainer computes — only crash faults abort the run.
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            chunked: false,
            eval_batches: 4,
            corpus_branch: 4,
            corpus_bigram_p: 0.85,
            stop_after: None,
            ckpt_dir: None,
            ckpt_every: 0,
            fault: None,
        }
    }
}

/// One eval point on the loss curve.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    pub train_loss: f32,
    pub eval_loss: f32,
    pub ppl: f32,
    pub elapsed_secs: f64,
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub model: String,
    pub mode: Mode,
    pub steps: usize,
    pub losses: Vec<f32>,
    pub evals: Vec<EvalPoint>,
    pub total_secs: f64,
    pub tokens_per_sec: f64,
    pub qa_accuracy: Option<f32>,
    pub refreshes: usize,
}

impl TrainReport {
    /// Final perplexity (paper's Wikitext metric).
    pub fn final_ppl(&self) -> f32 {
        self.evals.last().map(|e| e.ppl).unwrap_or(f32::NAN)
    }

    /// Loss curve as CSV for EXPERIMENTS.md.
    pub fn loss_csv(&self) -> String {
        let mut s = String::from("step,train_loss\n");
        for (i, l) in self.losses.iter().enumerate() {
            s.push_str(&format!("{},{}\n", i + 1, l));
        }
        s
    }
}

/// The trainer itself.
pub struct Trainer<'b, B: Backend> {
    backend: &'b B,
    rc: RunConfig,
    opts: TrainerOptions,
    pub counters: Counters,
    /// Final state of the last `train`/`train_qa` call (checkpointing).
    pub last_state: Option<TrainState>,
    /// Structured obs JSONL sink (`--obs-log`); disabled by default, and
    /// write-only either way — nothing the trainer computes reads it.
    pub obs: ObsLog,
}

impl<'b, B: Backend> Trainer<'b, B> {
    pub fn new(backend: &'b B, rc: RunConfig, opts: TrainerOptions) -> Self {
        Trainer {
            backend,
            rc,
            opts,
            counters: Counters::new(),
            last_state: None,
            obs: ObsLog::disabled(),
        }
    }

    pub fn run_config(&self) -> &RunConfig {
        &self.rc
    }

    /// Build the LM batcher over a synthetic corpus pool.
    fn make_batcher(&self, batch: usize, seq: usize, pool: usize) -> Result<Batcher> {
        let vocab = self.backend.vocab(&self.rc)?;
        let mut corpus = SyntheticCorpus::new(
            vocab,
            self.opts.corpus_branch,
            self.opts.corpus_bigram_p,
            self.rc.seed,
        );
        let mut toks = Vec::with_capacity(pool);
        let mut tgts = Vec::with_capacity(pool);
        for _ in 0..pool {
            let (x, y) = corpus.lm_pair(seq);
            toks.push(x);
            tgts.push(y);
        }
        Ok(Batcher::new(toks, tgts, batch, self.rc.seed ^ 0xBA7C4))
    }

    /// Run LM fine-tuning from a fresh state.
    pub fn train(&mut self) -> Result<TrainReport> {
        let state = self.backend.init_state(&self.rc)?;
        self.train_from(state)
    }

    /// Run LM fine-tuning from an existing (e.g. checkpointed) state:
    /// steps `state.step + 1 ..= rc.steps`, replaying the batch schedule
    /// an uninterrupted run would have used.
    pub fn train_from(&mut self, mut state: TrainState) -> Result<TrainReport> {
        let (batch, seq) = self.backend.workload(&self.rc)?;
        if self.rc.steps == 0 {
            bail!("nothing to train: --steps is 0 (set --steps >= 1)");
        }
        if batch == 0 || seq == 0 {
            bail!("empty workload: batch {batch} x seq {seq} (both must be >= 1)");
        }
        let use_chunk =
            self.opts.chunked && self.backend.supports_chunked(&self.rc);
        let start = state.step.scalar()? as usize;
        if start > self.rc.steps {
            bail!("state is at step {start}, past rc.steps {}", self.rc.steps);
        }
        let pool = (self.rc.steps * batch).clamp(batch * 4, 4096);
        let mut batcher = self.make_batcher(batch, seq, pool)?;
        let mut eval_batcher = self.make_batcher(batch, seq, batch * 8)?;
        self.fast_forward(&mut batcher, &mut eval_batcher, start, use_chunk)?;

        let stop_at = match self.opts.stop_after {
            Some(n) => self.rc.steps.min(start + n),
            None => self.rc.steps,
        };
        let mut losses = Vec::with_capacity(stop_at.saturating_sub(start));
        let mut evals = Vec::new();
        let mut refreshes = 0usize;
        let mut ws_peak = 0u64;
        let t0 = Instant::now(); // det: wall-clock (metrics)
        let mut step_i = start;
        while step_i < stop_at {
            if use_chunk && step_i + 8 <= stop_at {
                // ---- chunked dispatch: 8 microbatches, one execution ----
                let mut toks = Vec::with_capacity(8 * batch * seq);
                let mut tgts = Vec::with_capacity(8 * batch * seq);
                for _ in 0..8 {
                    let b = batcher.next();
                    toks.extend_from_slice(&b.tokens);
                    tgts.extend_from_slice(&b.targets);
                }
                let chunk_losses =
                    self.backend.train_chunk8(&self.rc, &mut state, &toks, &tgts)?;
                losses.extend_from_slice(&chunk_losses);
                step_i += 8;
            } else {
                // ---- per-step dispatch ----
                let b = batcher.next();
                let loss = if self.obs.enabled() {
                    let ts = Instant::now(); // det: wall-clock (obs step timing)
                    let mut sobs = StepObs::default();
                    let loss = self.backend.train_step_obs(
                        &self.rc, &mut state, &b.tokens, &b.targets, &mut sobs,
                    )?;
                    ws_peak = ws_peak.max(sobs.ws_bytes);
                    self.log_step(step_i + 1, loss, ts.elapsed().as_secs_f64(), &sobs)?;
                    loss
                } else {
                    self.backend
                        .train_step(&self.rc, &mut state, &b.tokens, &b.targets)?
                };
                losses.push(loss);
                step_i += 1;
            }
            self.counters.add("steps", 1);
            self.counters.add("tokens", (batch * seq) as u64);

            // DKM codebook refresh (paper §5.1), spt only.
            if self.refresh_due(step_i) {
                let b = batcher.next();
                // Pre-refresh params are cloned for the drift metric
                // only when obs is on — a pure read either way.
                let before = self.obs.enabled().then(|| state.params.clone());
                if self
                    .backend
                    .refresh_codebooks(&self.rc, &mut state, &b.tokens)?
                {
                    refreshes += 1;
                    if let Some(before) = &before {
                        let drift = param_drift(before, &state.params)?;
                        self.obs.event(
                            "refresh",
                            vec![
                                ("step", Json::Num(step_i as f64)),
                                ("codebook_drift", Json::Num(drift)),
                            ],
                        )?;
                    }
                }
            }

            if self.rc.eval_every > 0 && step_i % self.rc.eval_every == 0 {
                let Some(&train_loss) = losses.last() else {
                    bail!(
                        "eval fired at step {step_i} with no training loss recorded \
                         (resumed at {start}, stop_after {:?})",
                        self.opts.stop_after
                    );
                };
                let eval_loss = self.eval_loss(&state, &mut eval_batcher)?;
                evals.push(EvalPoint {
                    step: step_i,
                    train_loss,
                    eval_loss,
                    ppl: eval_loss.exp(),
                    elapsed_secs: t0.elapsed().as_secs_f64(),
                });
                self.obs.event(
                    "eval",
                    vec![
                        ("step", Json::Num(step_i as f64)),
                        ("loss", Json::Num(eval_loss as f64)),
                        ("ppl", Json::Num(eval_loss.exp() as f64)),
                    ],
                )?;
            }

            // Periodic crash-safe checkpoint (after refresh/eval, so a
            // resumed run replays the identical schedule from here).
            if self.opts.ckpt_every > 0 && step_i % self.opts.ckpt_every == 0 {
                if let Some(dir) = self.opts.ckpt_dir.clone() {
                    self.save_periodic(&dir, step_i, &state)?;
                }
            }
        }
        let total = t0.elapsed().as_secs_f64();
        // Memory-truth join: the observed GEMM-workspace high-water
        // against memmodel's analytic per-item transient prediction —
        // the analytic model validated against a live run.
        if self.obs.enabled() && ws_peak > 0 {
            let cfg = presets::model(&self.rc.model)?;
            let wl = memmodel::BlockWorkload { batch: 1, seq };
            let predicted =
                memmodel::block_peak(&cfg.block, self.rc.mode, &wl).transient_bytes();
            self.obs.event(
                "memory",
                vec![
                    ("channel", Json::Str("train_workspace".to_string())),
                    ("observed_bytes", Json::Num(ws_peak as f64)),
                    ("predicted_bytes", Json::Num(predicted as f64)),
                    ("model_err", Json::Num(crate::obs::model_err(ws_peak, predicted))),
                ],
            )?;
        }
        self.obs.flush()?;
        let report = TrainReport {
            model: self.rc.model.clone(),
            mode: self.rc.mode,
            steps: losses.len(),
            tokens_per_sec: (losses.len() * batch * seq) as f64 / total.max(1e-9),
            losses,
            evals,
            total_secs: total,
            qa_accuracy: None,
            refreshes,
        };
        self.last_state = Some(state);
        Ok(report)
    }

    /// Emit one obs `step` event (no-op when the sink is disabled).
    fn log_step(&mut self, step: usize, loss: f32, step_s: f64, sobs: &StepObs) -> Result<()> {
        self.obs.event(
            "step",
            vec![
                ("step", Json::Num(step as f64)),
                ("loss", Json::Num(loss as f64)),
                ("step_s", Json::Num(step_s)),
                ("phases", sobs.phases.to_json()),
                (
                    "attn_density",
                    Json::Arr(sobs.attn_density.iter().map(|&d| Json::Num(d)).collect()),
                ),
                (
                    "expert_load",
                    Json::Arr(
                        sobs.expert_load
                            .iter()
                            .map(|loads| {
                                Json::Arr(
                                    loads.iter().map(|&n| Json::Num(n as f64)).collect(),
                                )
                            })
                            .collect(),
                    ),
                ),
                ("ws_bytes", Json::Num(sobs.ws_bytes as f64)),
                ("trace_bytes", Json::Num(sobs.trace_bytes as f64)),
            ],
        )
    }

    /// Identity stamped into checkpoints this trainer writes.
    pub fn ckpt_meta(&self) -> Result<CkptMeta> {
        Ok(CkptMeta {
            model: self.rc.model.clone(),
            mode: self.rc.mode,
            n_layers: presets::model(&self.rc.model)?.n_layers.max(1),
        })
    }

    /// One periodic crash-safe checkpoint.  A recoverable save failure
    /// (post-retry) is warned and skipped — losing one checkpoint must
    /// not kill a training run; an injected crash fault aborts exactly
    /// like the process dying mid-write.
    fn save_periodic(
        &self,
        dir: &std::path::Path,
        step_i: usize,
        state: &TrainState,
    ) -> Result<()> {
        let path = dir.join(format!("step-{step_i:08}.ckpt"));
        let meta = self.ckpt_meta()?;
        let result = std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {dir:?}"))
            .and_then(|()| {
                checkpoint::save_tagged_with(state, &meta, &path, self.opts.fault.as_deref())
            });
        match result {
            Ok(()) => Ok(()),
            Err(e) if fault::is_crash(&e) => Err(e),
            Err(e) => {
                crate::log_warn!("periodic checkpoint failed, continuing err={e:#}");
                Ok(())
            }
        }
    }

    /// Whether the codebook refresh fires after step `step_i`.
    fn refresh_due(&self, step_i: usize) -> bool {
        self.rc.mode == Mode::Spt
            && self.rc.codebook_refresh_every > 0
            && step_i % self.rc.codebook_refresh_every == 0
    }

    /// Replay the RNG-consuming draws steps `1..=start` would have made,
    /// so a resumed run sees the same batch stream as an uninterrupted
    /// one (the bit-identical-resume contract).  Simulates the exact
    /// dispatch loop — including the chunked path's coarser
    /// refresh/eval cadence — rather than assuming one check per step.
    fn fast_forward(
        &self,
        batcher: &mut Batcher,
        eval_batcher: &mut Batcher,
        start: usize,
        use_chunk: bool,
    ) -> Result<()> {
        let mut step_i = 0usize;
        while step_i < start {
            if use_chunk && step_i + 8 <= self.rc.steps {
                for _ in 0..8 {
                    batcher.next();
                }
                step_i += 8;
            } else {
                batcher.next();
                step_i += 1;
            }
            if step_i > start {
                // The uninterrupted run could only have stopped on this
                // lattice; a mid-chunk checkpoint cannot be replayed.
                bail!(
                    "cannot resume at step {start}: chunked dispatch \
                     advances in blocks of 8 (nearest boundary {step_i})"
                );
            }
            if self.refresh_due(step_i) {
                batcher.next();
            }
            if self.rc.eval_every > 0 && step_i % self.rc.eval_every == 0 {
                for _ in 0..self.opts.eval_batches {
                    eval_batcher.next();
                }
            }
        }
        Ok(())
    }

    /// Mean eval loss over held-out batches.
    pub fn eval_loss(&self, state: &TrainState, batcher: &mut Batcher) -> Result<f32> {
        let mut total = 0.0f32;
        for _ in 0..self.opts.eval_batches {
            let b = batcher.next();
            total += self
                .backend
                .eval_loss(&self.rc, state, &b.tokens, &b.targets)?;
        }
        Ok(total / self.opts.eval_batches.max(1) as f32)
    }

    /// QA fine-tune + accuracy eval (Table 3's MMLU surrogate).
    pub fn train_qa(&mut self) -> Result<TrainReport> {
        let (batch, seq) = self.backend.workload(&self.rc)?;
        if self.rc.steps == 0 {
            bail!("nothing to train: --steps is 0 (set --steps >= 1)");
        }
        if batch == 0 || seq == 0 {
            bail!("empty workload: batch {batch} x seq {seq} (both must be >= 1)");
        }
        let vocab = self.backend.vocab(&self.rc)?;
        let mut state = self.backend.init_state(&self.rc)?;
        let mut gen = QaTaskGen::new(vocab, 64, self.rc.seed);
        let mut losses = Vec::with_capacity(self.rc.steps);
        let mut refreshes = 0usize;
        let t0 = Instant::now(); // det: wall-clock (metrics)
        for step_i in 1..=self.rc.steps {
            let qb = gen.batch(batch, seq);
            let toks: Vec<i32> =
                qb.tokens.iter().flatten().map(|&t| t as i32).collect();
            let tgts: Vec<i32> =
                qb.targets.iter().flatten().map(|&t| t as i32).collect();
            losses.push(
                self.backend
                    .train_step(&self.rc, &mut state, &toks, &tgts)?,
            );
            if self.refresh_due(step_i) {
                // Reuse the refresh machinery with QA tokens.
                let qb2 = gen.batch(batch, seq);
                let toks2: Vec<i32> =
                    qb2.tokens.iter().flatten().map(|&t| t as i32).collect();
                if self
                    .backend
                    .refresh_codebooks(&self.rc, &mut state, &toks2)?
                {
                    refreshes += 1;
                }
            }
        }
        // Held-out accuracy.
        let mut correct_weighted = 0.0f32;
        let eval_rounds = 8;
        for _ in 0..eval_rounds {
            let qb = gen.batch(batch, seq);
            let toks: Vec<i32> =
                qb.tokens.iter().flatten().map(|&t| t as i32).collect();
            let rows = self.backend.qa_choice_logits(
                &self.rc,
                &state,
                &toks,
                &qb.answer_pos,
                &gen.answer_tokens(),
            )?;
            correct_weighted += gen.accuracy(&qb, &rows);
        }
        let total = t0.elapsed().as_secs_f64();
        let report = TrainReport {
            model: self.rc.model.clone(),
            mode: self.rc.mode,
            steps: losses.len(),
            tokens_per_sec: (losses.len() * batch * seq) as f64 / total.max(1e-9),
            losses,
            evals: Vec::new(),
            total_secs: total,
            qa_accuracy: Some(correct_weighted / eval_rounds as f32),
            refreshes,
        };
        self.last_state = Some(state);
        Ok(report)
    }
}

/// Mean absolute per-element movement across the leaves a refresh
/// changed (the PQ codebook drift metric): total |after - before| over
/// the number of changed elements, 0.0 when nothing moved.
fn param_drift(before: &[HostTensor], after: &[HostTensor]) -> Result<f64> {
    let mut total = 0.0f64;
    let mut changed = 0u64;
    for (b, a) in before.iter().zip(after) {
        let (b, a) = (b.as_f32()?, a.as_f32()?);
        for (&x, &y) in b.iter().zip(a) {
            if x.to_bits() != y.to_bits() {
                total += (y as f64 - x as f64).abs();
                changed += 1;
            }
        }
    }
    Ok(if changed == 0 { 0.0 } else { total / changed as f64 })
}
