//! Per-block tensor accounting: every tensor a fwd+bwd training step of
//! one Transformer block materializes, by module / phase / mode.
//!
//! Assumptions (standard eager-framework accounting, documented per line):
//! * f32 everywhere (paper §6.1: single precision);
//! * backward needs the forward's saved activation set plus, transiently,
//!   the gradient of the largest activation (double-buffered);
//! * AdamW holds two moments per *trainable* parameter;
//! * attention softmax output is saved for backward (PyTorch semantics);
//! * sparse attention stores values (f32) + indices (i32) for nL entries
//!   plus per-head PQ codes (int32 [n, M]);
//! * routed FFN saves the activated fraction beta of the hidden
//!   activation plus router scores / assignment indices.

use crate::config::{BlockConfig, Mode};

/// Workload shape for one block step.
#[derive(Debug, Clone, Copy)]
pub struct BlockWorkload {
    pub batch: usize,
    pub seq: usize,
}

/// Which module a tensor belongs to (Table 1 / Table 4 split).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Module {
    Mha,
    Ffn,
    Shared,
}

/// Memory phase of a tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Base + adapter weights (live whole step).
    Weights,
    /// Gradients of trainable weights (live bwd..update).
    Gradients,
    /// AdamW moments (live whole run).
    Optimizer,
    /// Saved-for-backward activations (live fwd..bwd).
    SavedActivation,
    /// Transient workspace (peak contribution = max over ops).
    Transient,
}

/// One accounted tensor.
#[derive(Debug, Clone)]
pub struct TensorAcct {
    pub name: &'static str,
    pub module: Module,
    pub phase: Phase,
    pub bytes: u64,
}

/// Full accounting for one block step.
#[derive(Debug, Clone)]
pub struct MemBreakdown {
    pub tensors: Vec<TensorAcct>,
}

impl MemBreakdown {
    pub fn persistent_bytes(&self) -> u64 {
        self.sum(|t| {
            matches!(t.phase, Phase::Weights | Phase::Gradients | Phase::Optimizer)
        })
    }

    pub fn saved_activation_bytes(&self) -> u64 {
        self.sum(|t| t.phase == Phase::SavedActivation)
    }

    /// Transient peak = the largest single workspace tensor (ops execute
    /// serially; XLA reuses buffers between them).
    pub fn transient_bytes(&self) -> u64 {
        self.tensors
            .iter()
            .filter(|t| t.phase == Phase::Transient)
            .map(|t| t.bytes)
            .max()
            .unwrap_or(0)
    }

    /// Peak memory of the block step.
    pub fn peak_bytes(&self) -> u64 {
        self.persistent_bytes() + self.saved_activation_bytes() + self.transient_bytes()
    }

    /// Peak restricted to one module (+ shared weights excluded) — the
    /// Table 1 / Table 4 per-module columns.
    pub fn module_peak(&self, module: Module) -> u64 {
        let persist = self.sum(|t| {
            t.module == module
                && matches!(
                    t.phase,
                    Phase::Weights | Phase::Gradients | Phase::Optimizer
                )
        });
        let saved = self.sum(|t| {
            t.module == module && t.phase == Phase::SavedActivation
        });
        let transient = self
            .tensors
            .iter()
            .filter(|t| t.module == module && t.phase == Phase::Transient)
            .map(|t| t.bytes)
            .max()
            .unwrap_or(0);
        persist + saved + transient
    }

    fn sum(&self, f: impl Fn(&TensorAcct) -> bool) -> u64 {
        self.tensors.iter().filter(|t| f(t)).map(|t| t.bytes).sum()
    }
}

const F32: u64 = 4;
const I32: u64 = 4;

/// Account one Transformer block training step (fwd+bwd+update).
pub fn block_peak(cfg: &BlockConfig, mode: Mode, wl: &BlockWorkload) -> MemBreakdown {
    let mut t: Vec<TensorAcct> = Vec::new();
    let b = wl.batch as u64;
    let n = wl.seq as u64;
    let d = cfg.d_model as u64;
    let h = cfg.n_heads() as u64;
    let _dh = cfg.d_head as u64;
    let f = cfg.d_ffn as u64;
    let r = cfg.lora_rank as u64;
    let tok = b * n;

    let push = |t: &mut Vec<TensorAcct>, name, module, phase, bytes| {
        t.push(TensorAcct { name, module, phase, bytes });
    };

    // ---------------- weights / grads / optimizer ----------------
    let w_mha = 4 * d * d * F32;
    let w_ffn = (2 * d * f + f + d) * F32;
    push(&mut t, "w_mha(qkvo)", Module::Mha, Phase::Weights, w_mha);
    push(&mut t, "w_ffn(in,out)", Module::Ffn, Phase::Weights, w_ffn);
    push(&mut t, "ln_params", Module::Shared, Phase::Weights, 4 * d * F32);
    match mode {
        Mode::Full => {
            push(&mut t, "grad_mha", Module::Mha, Phase::Gradients, w_mha);
            push(&mut t, "grad_ffn", Module::Ffn, Phase::Gradients, w_ffn);
            push(&mut t, "adamw_mha", Module::Mha, Phase::Optimizer, 2 * w_mha);
            push(&mut t, "adamw_ffn", Module::Ffn, Phase::Optimizer, 2 * w_ffn);
        }
        Mode::Lora | Mode::Spt => {
            let lora_mha = 4 * (d * r + r * d) * F32;
            let lora_ffn = (d * r + r * f + f * r + r * d) * F32;
            push(&mut t, "w_lora_mha", Module::Mha, Phase::Weights, lora_mha);
            push(&mut t, "w_lora_ffn", Module::Ffn, Phase::Weights, lora_ffn);
            push(&mut t, "grad_lora_mha", Module::Mha, Phase::Gradients, lora_mha);
            push(&mut t, "grad_lora_ffn", Module::Ffn, Phase::Gradients, lora_ffn);
            push(&mut t, "adamw_lora_mha", Module::Mha, Phase::Optimizer, 2 * lora_mha);
            push(&mut t, "adamw_lora_ffn", Module::Ffn, Phase::Optimizer, 2 * lora_ffn);
            if mode == Mode::Spt {
                let router = d * cfg.ffn_groups as u64 * F32;
                let cb = 2 * (cfg.pq_m() * cfg.pq_codewords * cfg.pq_dsub) as u64 * F32;
                push(&mut t, "w_router", Module::Ffn, Phase::Weights, router);
                push(&mut t, "grad_router", Module::Ffn, Phase::Gradients, router);
                push(&mut t, "adamw_router", Module::Ffn, Phase::Optimizer, 2 * router);
                push(&mut t, "pq_codebooks", Module::Mha, Phase::Weights, cb);
            }
        }
    }

    // ---------------- MHA activations ----------------
    // input + q,k,v + attention output + o-proj output, saved for bwd.
    push(&mut t, "mha_x", Module::Mha, Phase::SavedActivation, tok * d * F32);
    push(&mut t, "mha_qkv", Module::Mha, Phase::SavedActivation, 3 * tok * d * F32);
    push(&mut t, "mha_attn_out", Module::Mha, Phase::SavedActivation, tok * d * F32);
    if mode != Mode::Full {
        // LoRA intermediates x@B ([tok, r] per projection q,k,v,o).
        push(&mut t, "mha_lora_mid", Module::Mha, Phase::SavedActivation, 4 * tok * r * F32);
    }
    match mode {
        Mode::Full | Mode::Lora => {
            // Dense attention: softmax output saved [B, H, n, n]; its
            // gradient is the transient peak in backward (paper Table 1:
            // MHA dominates peak memory).
            let attn = b * h * n * n * F32;
            push(&mut t, "attn_weights(nxn)", Module::Mha, Phase::SavedActivation, attn);
            push(&mut t, "d_attn_weights", Module::Mha, Phase::Transient, 2 * attn);
        }
        Mode::Spt => {
            // Sparse attention (paper §4.1): values+indices for nL entries
            // per head + PQ codes; gradient transient is O(nL) too.
            let l = cfg.sparsity.topl(wl.seq) as u64;
            let m = cfg.pq_m() as u64;
            let vals = b * h * n * l * F32;
            let idx = b * h * n * l * I32;
            let codes = 2 * b * h * n * m * I32;
            push(&mut t, "attn_vals(nxL)", Module::Mha, Phase::SavedActivation, vals);
            push(&mut t, "attn_idx(nxL)", Module::Mha, Phase::SavedActivation, idx);
            push(&mut t, "pq_codes", Module::Mha, Phase::SavedActivation, codes);
            push(&mut t, "d_attn_vals", Module::Mha, Phase::Transient, 2 * vals);
            // bucket scratch lives in on-chip memory (shared mem / VMEM);
            // it never reaches HBM accounting (paper §5.1).
        }
    }

    // ---------------- FFN activations ----------------
    push(&mut t, "ffn_x", Module::Ffn, Phase::SavedActivation, tok * d * F32);
    match mode {
        Mode::Full | Mode::Lora => {
            let hid = tok * f * F32;
            push(&mut t, "ffn_hidden", Module::Ffn, Phase::SavedActivation, hid);
            push(&mut t, "d_ffn_hidden", Module::Ffn, Phase::Transient, 2 * hid);
            if mode == Mode::Lora {
                push(&mut t, "ffn_lora_mid", Module::Ffn, Phase::SavedActivation, 2 * tok * r * F32);
            }
        }
        Mode::Spt => {
            // Routed FFN: only the activated beta fraction of the hidden
            // activation is materialized (capacity slots), plus routing
            // metadata.  Paper Table 4: FFN memory drops less than MHA
            // ("the sizes of the input, output, and weight tensors remain
            // unchanged").
            let g = cfg.ffn_groups as u64;
            let ga = cfg.sparsity.active_groups(cfg.ffn_groups) as u64;
            let hid_active = tok * f * ga * F32 / g;
            push(&mut t, "ffn_hidden_routed", Module::Ffn, Phase::SavedActivation, hid_active);
            push(&mut t, "d_ffn_hidden_routed", Module::Ffn, Phase::Transient, 2 * hid_active);
            push(&mut t, "router_scores", Module::Ffn, Phase::SavedActivation, tok * g * F32);
            push(&mut t, "block_assignment", Module::Ffn, Phase::SavedActivation, tok * ga * I32);
            push(&mut t, "ffn_lora_mid", Module::Ffn, Phase::SavedActivation, 2 * tok * r * F32);
        }
    }
    // Residual stream + LN activations (shared).
    push(&mut t, "residual+ln", Module::Shared, Phase::SavedActivation, 3 * tok * d * F32);

    MemBreakdown { tensors: t }
}

/// Convenience: peak bytes for one module only (Table 1/4 columns).
pub fn module_peak(cfg: &BlockConfig, mode: Mode, wl: &BlockWorkload, module: Module) -> u64 {
    block_peak(cfg, mode, wl).module_peak(module)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn wl() -> BlockWorkload {
        BlockWorkload { batch: 16, seq: 512 }
    }

    #[test]
    fn table1_shape_mha_dominates_dense_ffn_dominates_nothing() {
        // Paper Table 1 (OPT-2048, bs16, seq512): MHA >> FFN in peak memory
        // for Full/LoRA; SPT shrinks MHA by >2x.
        let cfg = presets::block("opt-2048").unwrap();
        let full = block_peak(&cfg, Mode::Full, &wl());
        let lora = block_peak(&cfg, Mode::Lora, &wl());
        let spt = block_peak(&cfg, Mode::Spt, &wl());
        assert!(full.module_peak(Module::Mha) > full.module_peak(Module::Ffn));
        assert!(lora.module_peak(Module::Mha) > lora.module_peak(Module::Ffn));
        let ratio = lora.module_peak(Module::Mha) as f64
            / spt.module_peak(Module::Mha) as f64;
        assert!(ratio > 2.0, "MHA LoRA/SPT ratio {ratio}");
    }

    #[test]
    fn table4_sparse_mha_memory_shrinks_with_l() {
        let cfg = presets::block("opt-2048").unwrap();
        let mut c14 = cfg.clone();
        c14.sparsity.mha_num = 1;
        c14.sparsity.mha_den = 4;
        let mut c18 = cfg.clone();
        c18.sparsity.mha_den = 8;
        let m14 = module_peak(&c14, Mode::Spt, &wl(), Module::Mha);
        let m18 = module_peak(&c18, Mode::Spt, &wl(), Module::Mha);
        assert!(m18 < m14);
    }

    #[test]
    fn ffn_memory_reduction_is_modest() {
        // Paper: "peak memory reduction brought by routed FFN is less
        // significant" — FFN SPT/LoRA stays within [0.5, 1.0].
        let cfg = presets::block("opt-2048").unwrap();
        let lora = module_peak(&cfg, Mode::Lora, &wl(), Module::Ffn);
        let spt = module_peak(&cfg, Mode::Spt, &wl(), Module::Ffn);
        let ratio = spt as f64 / lora as f64;
        assert!(ratio > 0.4 && ratio < 1.0, "{ratio}");
    }

    #[test]
    fn activations_dominate_params_at_batch16() {
        // Paper §6.2 Discussions: at bs 16 x seq 512, activations (not
        // parameters) dominate, which is why LoRA's memory win is limited.
        let cfg = presets::block("opt-2048").unwrap();
        let lora = block_peak(&cfg, Mode::Lora, &wl());
        assert!(lora.saved_activation_bytes() > lora.persistent_bytes());
    }

    #[test]
    fn breakdown_sums_are_consistent() {
        let cfg = presets::block("opt-1024").unwrap();
        for mode in Mode::ALL {
            let bd = block_peak(&cfg, mode, &wl());
            let by_module: u64 = [Module::Mha, Module::Ffn, Module::Shared]
                .into_iter()
                .map(|m| bd.module_peak(m))
                .sum();
            // module peaks overlap on transient maxima; total peak must be
            // <= the sum but >= each individual module.
            assert!(bd.peak_bytes() <= by_module + bd.transient_bytes() * 2);
            assert!(bd.peak_bytes() >= bd.module_peak(Module::Mha));
        }
    }
}
