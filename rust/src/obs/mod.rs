//! Zero-perturbation observability: structured tracing, phase timing,
//! deterministic value telemetry, and metric rendering.
//!
//! ## The invariant
//!
//! A run with observability fully enabled produces **bit-identical**
//! losses, parameters, optimizer moments, and served token streams to
//! the same run with it disabled.  The design holds that structurally:
//!
//! * **Clocks only at sequential boundaries.**  Every wall-clock read
//!   lives in this module ([`PhaseTimes`]), which kernel code drives
//!   through closures at sequential control-path boundaries (the probe
//!   forward, the grad-step join, the optimizer loop).  `obs` is not a
//!   detlint kernel dir, so the clock ban on `sparse/`, `infer/`, and
//!   `coordinator/` still holds lexically at every call site — and a
//!   dedicated detlint rule additionally bans obs timing symbols from
//!   `sparse/` kernel code outright.
//! * **Value telemetry reads data already in hand.**  Attention density
//!   comes from the CSRs a probe forward materialized anyway, expert
//!   loads from its routing masks, memory high-water from workspace
//!   capacities — pure reads, no RNG draws, no mutation of anything the
//!   computation consumes.
//! * **The sink is write-only.**  [`ObsLog`] appends JSONL events; no
//!   code path reads them back during a run.
//!
//! The invariant is proven end to end by `tests/obs_parity.rs` (train
//! at rayon pools 1/2/8 in every mode, and served streams, obs-on vs
//! obs-off) and at the CLI level by CI's chaos job, which `cmp`s
//! checkpoints from an obs-logged run against a clean run's.
//!
//! ## Event schema (JSONL, one object per line)
//!
//! * `{"event":"header","schema":1,"cmd":…,"provenance":{…}}` — first
//!   line of every log; provenance is [`crate::util::provenance`]'s
//!   git SHA + rayon threads + CPU model stamp.
//! * `{"event":"step","step":N,"loss":…,"phases":{"mha":{"calls":C,
//!   "secs":S},…},"attn_density":[…],"expert_load":[[…]],
//!   "ws_bytes":…,"trace_bytes":…}` — one per train step.
//! * `{"event":"eval","step":N,"loss":…}` — held-out eval points.
//! * `{"event":"refresh","step":N,"codebook_drift":…}` — PQ codebook
//!   refresh, with the mean absolute parameter movement it caused.
//! * `{"event":"memory","observed_bytes":…,"predicted_bytes":…,
//!   "model_err":…}` — the memory-truth channel: observed allocation
//!   high-water joined against `memmodel`'s analytic prediction.
//! * `{"event":"serve_report",…}` / `{"event":"gen",…}` — the serve
//!   daemon's final report and `spt generate`'s span.
//!
//! `spt obs-report <run.jsonl>` ([`report`]) aggregates a log into the
//! paper's Fig. 2-style phase breakdown plus sparsity/memory tables and
//! emits `bench_out/BENCH_obs_native.json` for the benchdiff gate.

pub mod report;

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::metrics::{Counters, Gauge, Histogram};
use crate::util::json::Json;

/// Obs JSONL schema version, stamped into every header event.
pub const SCHEMA_VERSION: u64 = 1;

/// Wall-time accumulator keyed by phase name (`"mha"`, `"ffn"`, `"ln"`,
/// `"optimizer"`, …).  All clock reads happen inside this struct — in a
/// non-kernel module — so instrumented kernel call sites carry no clock
/// tokens and stay on sequential control paths by construction.
#[derive(Debug, Default)]
pub struct PhaseTimes {
    /// phase -> (calls, accumulated seconds), deterministic key order.
    acc: BTreeMap<&'static str, (u64, f64)>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f`, charging its wall time to `phase`.  The closure's value
    /// passes through untouched — timing can reorder or change nothing.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed().as_secs_f64());
        out
    }

    /// Charge `secs` to `phase` without running anything.
    pub fn add(&mut self, phase: &'static str, secs: f64) {
        let e = self.acc.entry(phase).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += secs;
    }

    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }

    /// Total seconds across all phases.
    pub fn total_secs(&self) -> f64 {
        self.acc.values().map(|&(_, s)| s).sum()
    }

    /// `(phase, calls, secs)` in deterministic (alphabetical) order.
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u64, f64)> + '_ {
        self.acc.iter().map(|(&k, &(c, s))| (k, c, s))
    }

    /// `{"mha":{"calls":C,"secs":S},…}` for the step event.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        for (phase, calls, secs) in self.iter() {
            let mut p = BTreeMap::new();
            p.insert("calls".to_string(), Json::Num(calls as f64));
            p.insert("secs".to_string(), Json::Num(secs));
            m.insert(phase.to_string(), Json::Obj(p));
        }
        Json::Obj(m)
    }
}

/// Time `f` under `phase` when a sink is present, or run it untimed.
/// The seam instrumented kernels use: with `None` (obs off, and every
/// pre-existing caller) the closure runs directly and no clock exists
/// anywhere on the path.
pub fn time_opt<T>(
    pt: &mut Option<&mut PhaseTimes>,
    phase: &'static str,
    f: impl FnOnce() -> T,
) -> T {
    match pt {
        Some(p) => p.time(phase, f),
        None => f(),
    }
}

/// Per-step observation bundle a backend fills during
/// [`crate::coordinator::Backend::train_step_obs`].  Everything here is
/// *output only*: the training computation never reads it.
#[derive(Debug, Default)]
pub struct StepObs {
    /// Phase wall times (probe forward: mha/ffn/ln/embed; step: fwd_bwd
    /// and optimizer at their sequential boundaries).
    pub phases: PhaseTimes,
    /// Mean top-L nnz ratio per layer (mean over heads) from the probe
    /// forward's attention CSRs.  Empty outside spt mode.
    pub attn_density: Vec<f64>,
    /// Routed-FFN expert load per layer: tokens routed to each of the G
    /// groups.  Empty outside spt mode.
    pub expert_load: Vec<Vec<u64>>,
    /// Observed per-worker GEMM-workspace high-water (bytes), maxed
    /// across the step's gradient chunks.  Telemetry only: `Vec` growth
    /// amortization makes the exact value scheduling-dependent, which
    /// is one more reason it feeds the obs log and never any
    /// computation.
    pub ws_bytes: u64,
    /// Observed bytes of one item's saved activations (the probe trace).
    pub trace_bytes: u64,
}

/// Structured JSONL event sink.  Disabled, every call is a no-op with
/// zero allocation — the hot path pays one branch.
#[derive(Debug, Default)]
pub struct ObsLog {
    inner: Option<std::io::BufWriter<std::fs::File>>,
}

impl ObsLog {
    /// The no-op sink (obs off).
    pub fn disabled() -> Self {
        Self::default()
    }

    /// Create `path` and write the header event (schema version, the
    /// command being traced, and the build/run provenance stamp).
    pub fn create(path: impl AsRef<Path>, cmd: &str) -> Result<Self> {
        let path = path.as_ref();
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating obs log dir {dir:?}"))?;
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating obs log {path:?}"))?;
        let mut log = ObsLog { inner: Some(std::io::BufWriter::new(file)) };
        log.event(
            "header",
            vec![
                ("schema", Json::Num(SCHEMA_VERSION as f64)),
                ("cmd", Json::Str(cmd.to_string())),
                ("provenance", crate::util::provenance::provenance()),
            ],
        )?;
        Ok(log)
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Append one event line (`{"event":kind, …fields}`), keys in
    /// deterministic order.  No-op when disabled.
    pub fn event(&mut self, kind: &str, fields: Vec<(&str, Json)>) -> Result<()> {
        let Some(w) = &mut self.inner else {
            return Ok(());
        };
        let mut m = BTreeMap::new();
        m.insert("event".to_string(), Json::Str(kind.to_string()));
        for (k, v) in fields {
            m.insert(k.to_string(), v);
        }
        writeln!(w, "{}", Json::Obj(m)).context("writing obs event")?;
        Ok(())
    }

    /// Flush buffered events to disk (end of a command, drain, …).
    pub fn flush(&mut self) -> Result<()> {
        if let Some(w) = &mut self.inner {
            w.flush().context("flushing obs log")?;
        }
        Ok(())
    }
}

/// `|observed - predicted| / predicted` — the memmodel validation
/// metric (0 = the analytic model matched the observed allocation).
pub fn model_err(observed: u64, predicted: u64) -> f64 {
    let p = predicted.max(1) as f64;
    (observed as f64 - p).abs() / p
}

/// Render counters, gauges, and histograms in the Prometheus text
/// exposition format (one snapshot, `# TYPE`-annotated, cumulative
/// `le` buckets).  Purely formatting of already-computed values.
pub fn prometheus_text(
    counters: &Counters,
    gauges: &[Gauge],
    histograms: &[Histogram],
) -> String {
    let mut out = String::new();
    for (name, v) in counters.iter() {
        out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    }
    for g in gauges {
        out.push_str(&format!("# TYPE {} gauge\n{} {}\n", g.name, g.name, g.value));
    }
    for h in histograms {
        out.push_str(&format!("# TYPE {} histogram\n", h.name));
        let cum = h.cumulative();
        for (i, bound) in h.bounds().iter().enumerate() {
            out.push_str(&format!("{}_bucket{{le=\"{}\"}} {}\n", h.name, bound, cum[i]));
        }
        out.push_str(&format!(
            "{}_bucket{{le=\"+Inf\"}} {}\n{}_sum {}\n{}_count {}\n",
            h.name,
            h.count(),
            h.name,
            h.sum(),
            h.name,
            h.count()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_times_accumulate_in_deterministic_order() {
        let mut pt = PhaseTimes::new();
        pt.add("mha", 0.25);
        pt.add("ffn", 0.5);
        pt.add("mha", 0.25);
        let got: Vec<_> = pt.iter().collect();
        assert_eq!(got, vec![("ffn", 1, 0.5), ("mha", 2, 0.5)]);
        assert!((pt.total_secs() - 1.0).abs() < 1e-12);
        let j = pt.to_json();
        assert_eq!(j.get("mha").get("calls").as_usize(), Some(2));
        assert_eq!(j.get("ffn").get("secs"), &Json::Num(0.5));
    }

    #[test]
    fn time_and_time_opt_pass_values_through() {
        let mut pt = PhaseTimes::new();
        assert_eq!(pt.time("x", || 41 + 1), 42);
        let mut none: Option<&mut PhaseTimes> = None;
        assert_eq!(time_opt(&mut none, "x", || 7), 7);
        let mut some = Some(&mut pt);
        assert_eq!(time_opt(&mut some, "x", || 8), 8);
        let (_, calls, _) = pt.iter().next().unwrap();
        assert_eq!(calls, 2, "only the sinks that exist record calls");
    }

    #[test]
    fn disabled_log_is_a_no_op() {
        let mut log = ObsLog::disabled();
        assert!(!log.enabled());
        log.event("step", vec![("step", Json::Num(1.0))]).unwrap();
        log.flush().unwrap();
    }

    #[test]
    fn log_writes_header_then_events() {
        let dir = std::env::temp_dir().join("spt_obs_mod_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.jsonl");
        let mut log = ObsLog::create(&path, "train").unwrap();
        assert!(log.enabled());
        log.event("step", vec![("step", Json::Num(0.0)), ("loss", Json::Num(2.5))]).unwrap();
        log.flush().unwrap();
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<Json> =
            text.lines().map(|l| crate::util::json::parse(l).unwrap()).collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].get("event").as_str(), Some("header"));
        assert_eq!(lines[0].get("schema").as_usize(), Some(1));
        assert_eq!(lines[0].get("cmd").as_str(), Some("train"));
        assert!(!lines[0]
            .get("provenance")
            .get("git_sha")
            .as_str()
            .unwrap_or("")
            .is_empty());
        assert_eq!(lines[1].get("event").as_str(), Some("step"));
        assert_eq!(lines[1].get("loss"), &Json::Num(2.5));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn model_err_is_relative_and_zero_on_match() {
        assert_eq!(model_err(100, 100), 0.0);
        assert!((model_err(150, 100) - 0.5).abs() < 1e-12);
        assert!((model_err(50, 100) - 0.5).abs() < 1e-12);
        // Degenerate prediction never divides by zero.
        assert!(model_err(5, 0).is_finite());
    }

    #[test]
    fn prometheus_rendering_shape() {
        let mut c = Counters::new();
        c.add("spt_decode_steps_total", 12);
        let g = [Gauge::new("spt_pool_occupancy", 0.5)];
        let mut h = Histogram::new("spt_latency_seconds", &[0.1, 1.0]);
        h.observe(0.05);
        h.observe(0.5);
        h.observe(5.0);
        let text = prometheus_text(&c, &g, &[h]);
        assert!(text.contains("# TYPE spt_decode_steps_total counter\n"));
        assert!(text.contains("spt_decode_steps_total 12\n"));
        assert!(text.contains("# TYPE spt_pool_occupancy gauge\n"));
        assert!(text.contains("spt_pool_occupancy 0.5\n"));
        assert!(text.contains("# TYPE spt_latency_seconds histogram\n"));
        assert!(text.contains("spt_latency_seconds_bucket{le=\"0.1\"} 1\n"));
        assert!(text.contains("spt_latency_seconds_bucket{le=\"1\"} 2\n"));
        assert!(text.contains("spt_latency_seconds_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("spt_latency_seconds_sum 5.55\n"));
        assert!(text.contains("spt_latency_seconds_count 3\n"));
        // Every line is either a comment or `name{labels} value`.
        for line in text.lines() {
            assert!(line.starts_with('#') || line.split(' ').count() == 2, "{line}");
        }
    }
}
