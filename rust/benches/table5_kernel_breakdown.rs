//! Paper Table 5: per-kernel time breakdown of the sparse MHA and routed
//! FFN vs their dense counterparts (forward pass).
//!
//! The paper breaks CUDA kernels (sgemm / cusparse::sddmm / csrmm /
//! pq_lookup / index ops).  Here each *artifact* is one fused XLA
//! executable per kernel stage (pq_quantize, topl_select, sparse
//! attention pipeline, routed/dense FFN), timed through the engine; the
//! shape to reproduce is the *ratio* structure: selection overhead small,
//! routed FFN ~= beta x dense FFN, sparse attention ~ dense at these
//! sizes (paper: sparse ops trade FLOPs for irregular access).

mod common;

use spt::coordinator::profile::random_inputs;
use spt::metrics::{bench, Table};
use spt::util::fmt_duration;

fn main() {
    let Some(engine) = common::engine_or_skip("table5") else { return };
    let (w, s) = (common::warmup(), common::samples());
    let kernels = [
        ("pq_lookup (quantize)", "kernel_pq_quantize"),
        ("bucket-sort top-L", "kernel_topl_select"),
        ("naive-PQ select", "kernel_naive_pq_select"),
        ("sparse attn (sddmm+softmax+spmm)", "kernel_sparse_attention"),
        ("dense attention", "kernel_dense_attention"),
        ("routed FFN (BSpMV)", "kernel_routed_ffn"),
        ("dense FFN", "kernel_dense_ffn"),
    ];
    let mut table = Table::new(
        "Table 5 — kernel-level forward-time breakdown (this testbed)",
        &["Kernel", "Median", "Calls/s", "Notes"],
    );
    let mut results = Vec::new();
    for (label, name) in kernels {
        if engine.manifest().get(name).is_err() {
            println!("[table5] missing {name}");
            continue;
        }
        let inputs = random_inputs(&engine, name, 5).expect("inputs");
        engine.load(name).expect("compile");
        let r = bench(name, w, s, || {
            engine.run(name, &inputs).expect("run");
        });
        results.push((label, r));
    }
    // Notes: ratios that correspond to the paper's observations.
    let get = |nm: &str| {
        results
            .iter()
            .find(|(l, _)| *l == nm)
            .map(|(_, r)| r.median())
    };
    for (label, r) in &results {
        let note = match *label {
            "routed FFN (BSpMV)" => get("dense FFN")
                .map(|d| format!("{:.2}x vs dense (beta=1/2 => ~2x ideal)", d / r.median()))
                .unwrap_or_default(),
            "bucket-sort top-L" => get("naive-PQ select")
                .map(|n| format!("{:.2}x vs naive-PQ", n / r.median()))
                .unwrap_or_default(),
            "sparse attn (sddmm+softmax+spmm)" => get("dense attention")
                .map(|d| format!("{:.2}x vs dense (memory, not speed, is the goal)", d / r.median()))
                .unwrap_or_default(),
            _ => String::new(),
        };
        table.row(&[
            label.to_string(),
            fmt_duration(r.median()),
            format!("{:.1}", 1.0 / r.median()),
            note,
        ]);
    }
    common::emit("table5_kernel_breakdown", &table);

    // Engine-level cumulative stats (the "profiler output" analog).
    let mut stats = Table::new(
        "Engine execution stats",
        &["Artifact", "Calls", "Total", "Compile"],
    );
    for (name, st) in engine.stats() {
        stats.row(&[
            name,
            st.calls.to_string(),
            fmt_duration(st.total_secs),
            fmt_duration(st.compile_secs),
        ]);
    }
    common::emit("table5_engine_stats", &stats);
}
