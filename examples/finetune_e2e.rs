//! End-to-end validation driver (EXPERIMENTS.md §E2E): fine-tune a real
//! multi-layer Transformer with all three systems — Full, LoRA, SPT — on
//! the synthetic corpus, logging loss curves, PPL, throughput, and the
//! QA (MMLU-surrogate) accuracy.  All layers compose here: Pallas kernels
//! inside the XLA executables, the JAX model, and the rust coordinator.
//!
//!     cargo run --release --example finetune_e2e -- \
//!         [--model spt-30m] [--steps 120] [--modes full,lora,spt] [--qa-steps 80]
//!
//! Defaults target the ~34M-parameter `spt-30m` model (~100M-class run:
//! `--model spt-100m`, needs `make artifacts` with spt-100m enabled and
//! a few hours of CPU budget).

use anyhow::Result;
use spt::config::{Mode, RunConfig};
use spt::coordinator::{Trainer, TrainerOptions};
use spt::metrics::Table;
use spt::runtime::Engine;
use spt::util::fmt_duration;

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> Result<()> {
    let dir = std::env::var("SPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let model = arg("--model", "spt-30m");
    let steps: usize = arg("--steps", "120").parse()?;
    let qa_steps: usize = arg("--qa-steps", "80").parse()?;
    let modes: Vec<Mode> = arg("--modes", "full,lora,spt")
        .split(',')
        .map(Mode::parse)
        .collect::<Result<_>>()?;

    let engine = Engine::new(&dir)?;
    println!("[e2e] model={model} steps={steps} platform={}", engine.platform());
    std::fs::create_dir_all("runs").ok();

    let mut summary = Table::new(
        &format!("End-to-end fine-tuning — {model} ({steps} LM steps + {qa_steps} QA steps)"),
        &["System", "first loss", "final loss", "final PPL", "LM time", "tokens/s", "speedup vs full", "QA acc"],
    );
    let mut full_time: Option<f64> = None;
    for mode in modes {
        let name = format!("train_step_{model}_{}", mode.as_str());
        if engine.manifest().get(&name).is_err() {
            println!("[e2e] {name} missing; skipping (rebuild artifacts with this model)");
            continue;
        }
        let mut rc = RunConfig::default();
        rc.model = model.clone();
        rc.mode = mode;
        rc.steps = steps;
        rc.eval_every = (steps / 4).max(1);
        rc.codebook_refresh_every = 20; // paper §5.1
        rc.artifacts_dir = dir.clone();
        println!("[e2e] === {} ===", mode.as_str());
        let mut trainer = Trainer::new(&engine, rc.clone(), TrainerOptions::default());
        let report = trainer.train()?;
        for e in &report.evals {
            println!(
                "  step {:>4}: train {:.3} eval {:.3} ppl {:.1} [{}]",
                e.step, e.train_loss, e.eval_loss, e.ppl, fmt_duration(e.elapsed_secs)
            );
        }
        let csv = format!("runs/e2e_loss_{model}_{}.csv", mode.as_str());
        std::fs::write(&csv, report.loss_csv())?;
        println!("  loss curve -> {csv}");

        // QA phase (fresh params; Table 3 protocol).
        let mut rc_qa = rc.clone();
        rc_qa.steps = qa_steps;
        let mut qa_trainer = Trainer::new(&engine, rc_qa, TrainerOptions::default());
        let qa = qa_trainer.train_qa()?;

        if mode == Mode::Full {
            full_time = Some(report.total_secs);
        }
        summary.row(&[
            mode.as_str().to_string(),
            format!("{:.3}", report.losses.first().unwrap()),
            format!("{:.3}", report.losses.last().unwrap()),
            format!("{:.1}", report.final_ppl()),
            fmt_duration(report.total_secs),
            format!("{:.0}", report.tokens_per_sec),
            full_time
                .map(|f| format!("{:.2}x", f / report.total_secs))
                .unwrap_or_default(),
            format!("{:.1}%", qa.qa_accuracy.unwrap_or(f32::NAN) * 100.0),
        ]);
    }
    println!("\n{}", summary.render());
    std::fs::write("runs/e2e_summary.md", summary.render())?;
    println!("[e2e] summary -> runs/e2e_summary.md");
    Ok(())
}
