//! End-to-end attention pipelines over the substrate (dense baseline and
//! sparse MHA), plus the attention-weight CDF measurement behind Fig. 3.
//!
//! The sparse pipeline is split at its differentiability boundary:
//! [`sparse_attention`] = structure selection (PQ quantize + bucket-sort
//! top-L, non-differentiable) followed by [`sparse_attention_masked`]
//! (SDDMM → softmax → SpMM over a *fixed* selection — the part the
//! native training path differentiates via
//! [`super::grad::sparse_attention_backward`]).

use super::codes::TopL;
use super::csr::Csr;
use super::kernel;
use super::matrix::{self, Matrix, Workspace};
use super::pq::{self, Codebooks};
use super::topl;

/// Vanilla dense attention for one head: `softmax(Q K^T / sqrt(d)) V`.
pub fn dense_attention(q: &Matrix, k: &Matrix, v: &Matrix, causal: bool) -> Matrix {
    dense_attention_ws(q, k, v, causal, &mut Workspace::default())
}

/// [`dense_attention`] reusing a caller-owned GEMM workspace: the
/// O(n²) logits/probability matrix lives in the workspace, the logits
/// run on the NT microkernel (no transposed K materialized), and the
/// final product reuses the pack buffer.  Bit-identical to
/// [`dense_attention`].
pub fn dense_attention_ws(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    causal: bool,
    ws: &mut Workspace,
) -> Matrix {
    assert_eq!(q.cols, k.cols, "Q/K dim mismatch");
    assert_eq!(k.rows, v.rows, "K/V row mismatch");
    let scale = 1.0 / (q.cols as f32).sqrt();
    // Field-split borrows: the logits live in ws.attn while the pack
    // buffer packs K (and later V).
    let Workspace { packb, attn, .. } = ws;
    attn.reset_any(q.rows, k.rows);
    matrix::gemm_nt_into(
        q.rows, q.cols, k.rows, &q.data, &k.data, k.cols, 0, &mut attn.data, packb,
    );
    for x in attn.data.iter_mut() {
        *x *= scale;
    }
    if causal {
        for i in 0..attn.rows {
            for j in (i + 1)..attn.cols {
                *attn.at_mut(i, j) = -1e30;
            }
        }
    }
    attn.softmax_rows_inplace();
    let mut out = Matrix::zeros(q.rows, v.cols);
    matrix::gemm_into(
        q.rows,
        k.rows,
        v.cols,
        &attn.data,
        &v.data,
        v.cols,
        0,
        &mut out.data,
        packb,
    );
    out
}

/// Full sparse MHA for one head (paper Alg. 1): PQ quantize -> bucket-sort
/// top-L -> SDDMM -> softmax -> SpMM.  Returns (output, attention CSR).
pub fn sparse_attention(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    cb: &Codebooks,
    l: usize,
    causal: bool,
) -> (Matrix, Csr) {
    let cq = pq::quantize(&q.data, cb);
    let ck = pq::quantize(&k.data, cb);
    let idx = topl::select(&cq, &ck, l, causal);
    sparse_attention_masked(q, k, v, &idx, causal)
}

/// The differentiable tail of the sparse pipeline: SDDMM -> causal
/// re-mask -> softmax -> SpMM over a *fixed* top-L selection.
///
/// Splitting here lets the native backward ([`super::grad`]) and the
/// finite-difference gradient tests treat the selection as a constant
/// mask — gradients w.r.t. Q/K/V flow only through the kept entries,
/// while the selection itself (PQ + bucket sort) stays
/// non-differentiable, as in the paper.  Returns (output, post-softmax
/// attention CSR — the cache the backward pass consumes).
pub fn sparse_attention_masked(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    idx: &TopL,
    causal: bool,
) -> (Matrix, Csr) {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut a = Csr::from_topl(idx, k.rows);
    let q_scaled = q.map(|x| x * scale);
    a.sddmm(&q_scaled, k);
    // Causal re-mask: padding slots may reference future keys.
    if causal {
        for r in 0..a.rows {
            for p in a.row_range(r) {
                if a.indices[p] as usize > r {
                    a.values[p] = -1e30;
                }
            }
        }
    }
    a.softmax_rows();
    let y = a.spmm(v);
    (y, a)
}

/// One query row of the sparse pipeline against a cached key/value set —
/// the cached-decode hot path: SDDMM → causal re-mask → softmax → SpMM
/// over a *fixed* selection `sel`, in exactly the per-row operation
/// order of [`sparse_attention_masked`] (scale the query first, then
/// ascending-dimension dots; softmax as in `Csr::softmax_rows`; SpMM
/// skipping exact-zero weights as in `Csr::spmm`).  A decode step built
/// on this kernel is therefore bit-identical to the corresponding row of
/// a full-sequence forward (see `infer::session` for the selection-side
/// argument).
///
/// `qs` (length `q.len()`) and `vals` (length `sel.len()`) are caller
/// scratch; `out` (length `v.cols`) is fully overwritten.
pub fn sparse_attend_row(
    q: &[f32],
    k: &Matrix,
    v: &Matrix,
    sel: &[u32],
    causal_limit: Option<usize>,
    qs: &mut [f32],
    vals: &mut [f32],
    out: &mut [f32],
) {
    assert_eq!(q.len(), k.cols, "q/K dim mismatch");
    assert_eq!(k.rows, v.rows, "K/V row mismatch");
    assert_eq!(qs.len(), q.len(), "qs scratch length");
    assert_eq!(vals.len(), sel.len(), "vals scratch length");
    assert_eq!(out.len(), v.cols, "out length");
    let scale = 1.0 / (q.len() as f32).sqrt();
    // SDDMM on the scaled query (the sparse pipeline scales Q up front).
    for (s, &x) in qs.iter_mut().zip(q) {
        *s = x * scale;
    }
    for (val, &j) in vals.iter_mut().zip(sel) {
        let krow = k.row(j as usize);
        *val = kernel::dot(qs, krow);
    }
    // Causal re-mask: padding slots may reference future keys.
    if let Some(limit) = causal_limit {
        for (val, &j) in vals.iter_mut().zip(sel) {
            if j as usize > limit {
                *val = -1e30;
            }
        }
    }
    // Row softmax, same order as `Csr::softmax_rows`.
    let mx = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in vals.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    for x in vals.iter_mut() {
        *x /= sum.max(1e-30);
    }
    // SpMM row, same order as `Csr::spmm` (zero-weight skip kept: the
    // sparse operand skips whole V rows).
    out.fill(0.0);
    for (&w, &j) in vals.iter().zip(sel) {
        if w == 0.0 {
            continue;
        }
        kernel::axpy(out, w, v.row(j as usize));
    }
}

/// One query row of the *dense* pipeline against cached K/V (the
/// full/LoRA decode path): logits `(q · k_j) * scale` for every cached
/// key, row softmax, probability-weighted V sum — in exactly the
/// operation order [`dense_attention_ws`] uses for one row (the NT
/// kernel's ascending dot product, then the scalar scale multiply, then
/// `softmax_rows_inplace`, then the register-blocked GEMM's
/// ascending-`j` accumulation, no zero skip — matching the dense GEMM,
/// which dropped its `a == 0.0` branch).  Causally-masked future
/// columns of a full-sequence forward carry probability exactly 0 and
/// sit past the cached prefix; adding `±0.0 * v` terms is bitwise inert
/// (see the [`super::matrix`] module docs), so restricting to the cache
/// preserves every bit.
///
/// `logits` is reusable caller scratch (resized to `k.rows`); `out`
/// (length `v.cols`) is fully overwritten.
pub fn dense_attend_row(
    q: &[f32],
    k: &Matrix,
    v: &Matrix,
    logits: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert_eq!(q.len(), k.cols, "q/K dim mismatch");
    assert_eq!(k.rows, v.rows, "K/V row mismatch");
    assert_eq!(out.len(), v.cols, "out length");
    let scale = 1.0 / (q.len() as f32).sqrt();
    logits.clear();
    logits.resize(k.rows, 0.0);
    // Logits: plain ascending dot (gemm_nt), then the scale multiply.
    for (x, j) in logits.iter_mut().zip(0..k.rows) {
        *x = kernel::dot(q, k.row(j));
    }
    for x in logits.iter_mut() {
        *x *= scale;
    }
    // Row softmax, same order as `Matrix::softmax_rows_inplace`.
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in logits.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    for x in logits.iter_mut() {
        *x /= sum.max(1e-30);
    }
    // P @ V row: ascending j, no zero skip — op-for-op the dense GEMM's
    // row accumulation.
    out.fill(0.0);
    for (j, &w) in logits.iter().enumerate() {
        kernel::axpy(out, w, v.row(j));
    }
}

/// CDF of sorted softmax attention weights, averaged over queries
/// (regenerates paper Fig. 3).  Returns exactly `points` entries of
/// (fraction-kept, mass); the last is (1.0, total mass).
pub fn attention_weight_cdf(
    q: &Matrix,
    k: &Matrix,
    points: usize,
    causal: bool,
) -> Vec<(f32, f32)> {
    assert!(points >= 1, "need at least one CDF point");
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut logits = q.matmul(&k.transpose()).map(|x| x * scale);
    if causal {
        for i in 0..logits.rows {
            for j in (i + 1)..logits.cols {
                *logits.at_mut(i, j) = -1e30;
            }
        }
    }
    let w = logits.softmax_rows();
    let n = w.cols;
    // Average sorted-descending weight profile across rows.
    let mut profile = vec![0.0f64; n];
    for r in 0..w.rows {
        let mut row: Vec<f32> = w.row(r).to_vec();
        row.sort_by(|a, b| b.total_cmp(a));
        for (p, x) in profile.iter_mut().zip(&row) {
            *p += *x as f64;
        }
    }
    for p in profile.iter_mut() {
        *p /= w.rows as f64;
    }
    // Cumulative mass at `points` evenly spaced kept-fractions.  One
    // column can cross several thresholds (always when `points > n`), so
    // emit with a while-loop rather than once per column; the final entry
    // is pinned to exactly (1.0, total mass).
    let mut cdf = Vec::with_capacity(points);
    let mut acc = 0.0f64;
    let mut next_point = 1usize;
    for (i, p) in profile.iter().enumerate() {
        acc += p;
        let frac = (i + 1) as f32 / n as f32;
        while next_point <= points && frac >= next_point as f32 / points as f32 {
            cdf.push((frac, acc as f32));
            next_point += 1;
        }
    }
    // Float rounding can leave trailing thresholds unemitted; they all sit
    // at the full kept-fraction.
    while cdf.len() < points {
        cdf.push((1.0, acc as f32));
    }
    if let Some(last) = cdf.last_mut() {
        *last = (1.0, acc as f32);
    }
    cdf
}

/// Relative approximation error of sparse vs dense attention output
/// (the quality knob behind Fig. 10's MHA axis).
pub fn sparse_vs_dense_error(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    cb: &Codebooks,
    l: usize,
) -> f32 {
    let (ys, _) = sparse_attention(q, k, v, cb, l, false);
    let yd = dense_attention(q, k, v, false);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in ys.data.iter().zip(&yd.data) {
        num += ((a - b) * (a - b)) as f64;
        den += (b * b) as f64;
    }
    (num.sqrt() / den.sqrt().max(1e-30)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn correlated_qkv(n: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let noise = Matrix::randn(n, d, 0.4, &mut rng);
        let q = Matrix::from_vec(
            n,
            d,
            k.data.iter().zip(&noise.data).map(|(a, b)| 2.0 * a + b).collect(),
        );
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        (q, k, v)
    }

    #[test]
    fn l_equals_n_matches_dense() {
        let (q, k, v) = correlated_qkv(24, 16, 0);
        let mut rng = Rng::new(9);
        let cb = Codebooks::random(2, 4, 8, &mut rng);
        let (ys, _) = sparse_attention(&q, &k, &v, &cb, 24, false);
        let yd = dense_attention(&q, &k, &v, false);
        assert!(ys.max_abs_diff(&yd) < 1e-4, "{}", ys.max_abs_diff(&yd));
    }

    #[test]
    fn sparse_error_decreases_with_l() {
        let (q, k, v) = correlated_qkv(64, 32, 1);
        let mut rng = Rng::new(10);
        let mut cb = Codebooks::random(4, 8, 8, &mut rng);
        for _ in 0..5 {
            pq::codebook_update(&k.data, &mut cb, 1.0);
        }
        let e8 = sparse_vs_dense_error(&q, &k, &v, &cb, 8);
        let e32 = sparse_vs_dense_error(&q, &k, &v, &cb, 32);
        let e64 = sparse_vs_dense_error(&q, &k, &v, &cb, 64);
        assert!(e64 < 1e-4, "L=n must be exact, got {e64}");
        assert!(e32 <= e8 + 1e-5, "{e32} > {e8}");
    }

    #[test]
    fn cdf_is_monotone_and_skewed_for_correlated_data() {
        let (q, k, _) = correlated_qkv(128, 64, 2);
        let cdf = attention_weight_cdf(&q, &k, 20, false);
        for w in cdf.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-6);
        }
        // Fig. 3 shape: top 15% of weights carry most of the mass.
        let at15 = cdf
            .iter()
            .find(|(f, _)| *f >= 0.15)
            .map(|(_, m)| *m)
            .unwrap();
        assert!(at15 > 0.5, "mass at 15% = {at15}");
        let last = cdf.last().unwrap().1;
        assert!((last - 1.0).abs() < 1e-3);
    }

    #[test]
    fn cdf_has_exactly_points_entries_even_when_points_exceed_n() {
        // Regression: the old emit loop advanced at most one threshold per
        // column, so points > n (or multi-threshold crossings) returned
        // fewer than `points` entries.
        let (q, k, _) = correlated_qkv(4, 8, 5);
        for points in [1usize, 2, 3, 4, 5, 7, 10, 33] {
            let cdf = attention_weight_cdf(&q, &k, points, false);
            assert_eq!(cdf.len(), points, "points={points}");
            let (f, mass) = *cdf.last().unwrap();
            assert_eq!(f, 1.0, "points={points}: last fraction {f}");
            assert!((mass - 1.0).abs() < 1e-3, "points={points}: mass {mass}");
            for w in cdf.windows(2) {
                assert!(w[1].0 >= w[0].0 && w[1].1 >= w[0].1 - 1e-6);
            }
        }
        // Larger n, causal, points >> n.
        let (q, k, _) = correlated_qkv(16, 8, 6);
        let cdf = attention_weight_cdf(&q, &k, 50, true);
        assert_eq!(cdf.len(), 50);
        assert_eq!(cdf.last().unwrap().0, 1.0);
    }

    #[test]
    fn sparse_attend_row_matches_full_forward_rows_bitwise() {
        // Decode emulation: row i sees only keys 0..=i (the cache), with
        // l_eff = min(L, i+1).  Every row must equal the full-sequence
        // causal forward bit for bit.
        let (q, k, v) = correlated_qkv(20, 16, 7);
        let mut rng = Rng::new(17);
        let mut cb = Codebooks::random(2, 4, 8, &mut rng);
        pq::codebook_update(&k.data, &mut cb, 1.0);
        let l = 6;
        let (want, _) = sparse_attention(&q, &k, &v, &cb, l, true);
        let mut qs = vec![0.0f32; 16];
        let mut out = vec![0.0f32; 16];
        let mut scratch = crate::sparse::topl::BucketScratch::default();
        for i in 0..20 {
            let kc = Matrix::from_vec(i + 1, 16, k.data[..(i + 1) * 16].to_vec());
            let vc = Matrix::from_vec(i + 1, 16, v.data[..(i + 1) * 16].to_vec());
            let ck = pq::quantize(&kc.data, &cb);
            let l_eff = l.min(i + 1);
            let mut qcodes = vec![0u8; cb.m];
            pq::quantize_row(q.row(i), &cb, &mut qcodes);
            let mut sel = vec![0u32; l_eff];
            crate::sparse::topl::select_into(
                &qcodes, &ck, l_eff, Some(i), &mut sel, &mut scratch,
            );
            let mut vals = vec![0.0f32; l_eff];
            sparse_attend_row(
                q.row(i), &kc, &vc, &sel, Some(i), &mut qs, &mut vals, &mut out,
            );
            assert_eq!(out.as_slice(), want.row(i), "row {i}");
        }
    }

    #[test]
    fn dense_attend_row_matches_full_forward_rows_bitwise() {
        let (q, k, v) = correlated_qkv(18, 8, 8);
        let want = dense_attention(&q, &k, &v, true);
        let mut logits = Vec::new();
        let mut out = vec![0.0f32; 8];
        for i in 0..18 {
            let kc = Matrix::from_vec(i + 1, 8, k.data[..(i + 1) * 8].to_vec());
            let vc = Matrix::from_vec(i + 1, 8, v.data[..(i + 1) * 8].to_vec());
            dense_attend_row(q.row(i), &kc, &vc, &mut logits, &mut out);
            assert_eq!(out.as_slice(), want.row(i), "row {i}");
        }
    }

    #[test]
    fn causal_attention_ignores_future() {
        let (q, k, v) = correlated_qkv(16, 8, 3);
        let y = dense_attention(&q, &k, &v, true);
        // Row 0 attends only to key 0 -> output equals v[0].
        for c in 0..8 {
            assert!((y.at(0, c) - v.at(0, c)).abs() < 1e-5);
        }
    }
}
