//! Decode-throughput bench: continuous batching vs one-sequence-at-a-time
//! on the native cached-decode path, plus a paged-KV capacity probe.
//!
//! Runs a synthetic request trace through [`spt::infer::ServeDriver`]
//! twice — once with the in-flight capacity at `SPT_DECODE_MAX_BATCH`
//! (default 8) and once at 1 — cross-checks that every request generated
//! identical tokens (the batching-invariance contract), and emits
//! machine-readable `bench_out/BENCH_decode_native.json` so the serving
//! perf trajectory is tracked across PRs alongside the table3 train-step
//! record.  Model via `SPT_DECODE_BENCH_MODEL` (default `spt-mini-64`,
//! the GEMM-bound bench block); mode via `SPT_DECODE_BENCH_MODE`.
//!
//! The capacity probe replays a shared-prefix trace (every request
//! carries the same prompt) against a fixed page pool twice — prefix
//! sharing on vs off — and records how many concurrent streams the same
//! memory sustains each way.  Sharing stores the common prompt's full
//! pages once, so the shared run must sustain >= 2x the dense-slot
//! stream count at identical per-request token output.

mod common;

use std::collections::BTreeMap;

use spt::config::{Mode, RunConfig};
use spt::coordinator::{Backend, NativeBackend};
use spt::data::SyntheticCorpus;
use spt::infer::serve::ServeReport;
use spt::infer::{InferModel, Request, Sampler, ServeConfig, ServeDriver};
use spt::metrics::Table;
use spt::util::fmt_duration;
use spt::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
        .max(1)
}

fn main() {
    let model_name =
        std::env::var("SPT_DECODE_BENCH_MODEL").unwrap_or_else(|_| "spt-mini-64".into());
    let mode = std::env::var("SPT_DECODE_BENCH_MODE")
        .ok()
        .and_then(|s| Mode::parse(&s).ok())
        .unwrap_or(Mode::Spt);
    let n_requests = env_usize("SPT_DECODE_REQUESTS", 16);
    let prompt_len = env_usize("SPT_DECODE_PROMPT_LEN", 16);
    let tokens = env_usize("SPT_DECODE_TOKENS", 32);
    let max_batch = env_usize("SPT_DECODE_MAX_BATCH", 8);

    let rc = RunConfig {
        model: model_name.clone(),
        mode,
        seed: 0x5E17E,
        ..RunConfig::default()
    };
    let backend = NativeBackend::new();
    let state = backend.init_state(&rc).expect("init state");
    let model = InferModel::new(&rc, state).expect("materialize");
    assert!(
        prompt_len + tokens <= model.max_seq(),
        "workload exceeds max_seq {}",
        model.max_seq()
    );
    let mut corpus = SyntheticCorpus::new(model.vocab(), 4, 0.85, rc.seed);
    let reqs: Vec<Request> = (0..n_requests)
        .map(|id| Request {
            id,
            prompt: corpus.sequence(prompt_len).iter().map(|&t| t as i32).collect(),
            max_new_tokens: tokens,
        })
        .collect();
    let run = |mb: usize| -> ServeReport {
        let cfg = ServeConfig {
            max_batch: mb,
            sampler: Sampler::Greedy,
            seed: rc.seed,
            ..ServeConfig::default()
        };
        let mut driver = ServeDriver::new(&model, cfg).expect("driver");
        for r in &reqs {
            driver.submit(r.clone()).expect("submit");
        }
        driver.run_to_completion().expect("serve")
    };
    // Warmup pass (page in weights/pack panels), then the measured runs.
    let _ = run(max_batch);
    let batched = run(max_batch);
    let baseline = run(1);
    // Overload probe: the whole trace contends for 2 slots, so the
    // queue-wait percentiles measure time waiting for admission.
    let overload = run(2.min(max_batch));
    for (b, s) in batched.completions.iter().zip(&baseline.completions) {
        assert_eq!(b.tokens, s.tokens, "request {}: batching changed the tokens", b.id);
    }
    for (o, s) in overload.completions.iter().zip(&baseline.completions) {
        assert_eq!(o.tokens, s.tokens, "request {}: overload changed the tokens", o.id);
    }
    let speedup = batched.tokens_per_sec / baseline.tokens_per_sec.max(1e-9);

    let mut table = Table::new(
        &format!(
            "Decode throughput — {model_name}/{} ({n_requests} reqs, prompt {prompt_len}, \
             {tokens} new tokens, max_batch {max_batch})",
            mode.as_str()
        ),
        &["Config", "tok/s", "steps", "p50 lat", "p99 lat", "queue p50", "queue p99", "speedup"],
    );
    for (name, r, s) in [
        ("continuous batching", &batched, format!("{speedup:.2}x")),
        ("overload (batch=2)", &overload, String::new()),
        ("one-at-a-time", &baseline, "1.00x".to_string()),
    ] {
        table.row(&[
            name.to_string(),
            format!("{:.0}", r.tokens_per_sec),
            r.decode_steps.to_string(),
            fmt_duration(r.latency_percentile(50.0)),
            fmt_duration(r.latency_percentile(99.0)),
            fmt_duration(r.queue_wait_percentile(50.0)),
            fmt_duration(r.queue_wait_percentile(99.0)),
            s,
        ]);
    }
    common::emit("decode_throughput", &table);

    // ---- Paged-KV capacity probe: shared-prefix trace, fixed pool ----
    //
    // Geometry chosen so a full-length request needs 7 pages of which 5
    // hold reusable full prompt pages, and the pool holds exactly two
    // dense requests' worth of pages.  Dense slots then sustain 2
    // concurrent streams; prefix sharing sustains 4 on the same pool.
    let (page_tokens, cap_prompt_len, cap_new) =
        if model.max_seq() >= 112 { (16usize, 96usize, 16usize) } else { (8, 48, 8) };
    assert!(cap_prompt_len + cap_new <= model.max_seq());
    let need_pages = (cap_prompt_len + cap_new).div_ceil(page_tokens);
    let pool_pages = 2 * need_pages;
    let prefill_chunk = 2 * page_tokens;
    let shared_prompt: Vec<i32> =
        corpus.sequence(cap_prompt_len).iter().map(|&t| t as i32).collect();
    let cap_reqs: Vec<Request> = (0..8)
        .map(|id| Request { id, prompt: shared_prompt.clone(), max_new_tokens: cap_new })
        .collect();
    // Steps for request 0's prefill to finish (registering its prefix
    // pages in the share trie) plus one decode step.
    let warm_steps = cap_prompt_len.div_ceil(prefill_chunk) + 1;
    let capacity_run = |sharing: bool| -> ServeReport {
        let cfg = ServeConfig {
            max_batch: 8,
            sampler: Sampler::Greedy,
            seed: rc.seed,
            page_tokens,
            prefill_chunk,
            prefix_sharing: sharing,
            pool_pages: Some(pool_pages),
            ..ServeConfig::default()
        };
        let mut driver = ServeDriver::new(&model, cfg).expect("capacity driver");
        driver.submit(cap_reqs[0].clone()).expect("submit");
        for _ in 0..warm_steps {
            driver.step().expect("warm step");
        }
        for r in &cap_reqs[1..] {
            driver.submit(r.clone()).expect("submit");
        }
        driver.run_to_completion().expect("capacity serve")
    };
    let shared = capacity_run(true);
    let dense = capacity_run(false);
    for (a, b) in shared.completions.iter().zip(&dense.completions) {
        assert_eq!(a.tokens, b.tokens, "request {}: prefix sharing changed the tokens", a.id);
    }
    assert!(shared.prefix_hit_rate > 0.0, "shared run must hit the prefix trie");
    assert_eq!(dense.prefix_hit_rate, 0.0, "dense run must not share");
    let streams_ratio = shared.peak_in_flight as f64 / dense.peak_in_flight.max(1) as f64;
    assert!(
        streams_ratio >= 2.0,
        "prefix sharing sustained {}x streams (shared {} vs dense {}), want >= 2x",
        streams_ratio,
        shared.peak_in_flight,
        dense.peak_in_flight
    );
    println!(
        "[decode_throughput] capacity: {} pages sustain {} shared-prefix streams vs {} \
         dense ({}x), prefix hit rate {:.2}",
        pool_pages, shared.peak_in_flight, dense.peak_in_flight, streams_ratio,
        shared.prefix_hit_rate
    );

    let mut cap = BTreeMap::new();
    cap.insert("page_tokens".into(), Json::Num(page_tokens as f64));
    cap.insert("pool_pages".into(), Json::Num(pool_pages as f64));
    cap.insert("prompt_len".into(), Json::Num(cap_prompt_len as f64));
    cap.insert("max_new_tokens".into(), Json::Num(cap_new as f64));
    cap.insert("shared".into(), shared.to_json());
    cap.insert("dense".into(), dense.to_json());
    cap.insert("streams_ratio".into(), Json::Num(streams_ratio));

    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("decode_native".into()));
    top.insert("model".into(), Json::Str(model_name));
    top.insert("mode".into(), Json::Str(mode.as_str().into()));
    top.insert("requests".into(), Json::Num(n_requests as f64));
    top.insert("prompt_len".into(), Json::Num(prompt_len as f64));
    top.insert("max_new_tokens".into(), Json::Num(tokens as f64));
    top.insert("max_batch".into(), Json::Num(max_batch as f64));
    top.insert("batched".into(), batched.to_json());
    top.insert("overload".into(), overload.to_json());
    top.insert("baseline".into(), baseline.to_json());
    top.insert("speedup".into(), Json::Num(speedup));
    top.insert("capacity".into(), Json::Obj(cap));
    common::emit_json("BENCH_decode_native", &Json::Obj(top));
    println!("[decode_throughput] continuous batching speedup: {speedup:.2}x");
}
