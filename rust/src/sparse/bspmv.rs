//! BSpMV — blocked sparse matrix-vector multiply (paper §5.2, Alg. 4).
//!
//! The routed FFN's execution strategy: iterate over weight blocks, gather
//! the tokens that activated each block, run dense GEMMs, scatter results
//! back.  This is the rust-native twin of
//! `python/compile/kernels/routed_ffn.py` (which uses the static-capacity
//! TPU formulation); here shapes are dynamic, as in the paper's CUDA code.
//!
//! The per-block GEMMs run on the blocked microkernel in
//! [`super::matrix`] and multiply the `W_I[g]` column block / `W_O[g]`
//! row block *in place* — the kernel's strided-B addressing covers the
//! slices, so no per-block weight copy is materialized.  Each call
//! threads a [`BlockScratch`] through the block kernels to reuse the
//! gather/hidden buffers; scratch contents never affect results.

use super::grad;
use super::matrix::{self, Matrix, Workspace};

/// Router output for a token batch.
#[derive(Debug, Clone)]
pub struct Routing {
    /// `[nt][G]` activation mask.
    pub mask: Vec<Vec<bool>>,
    /// `[nt][G]` gate value (softmax over selected scores * G').
    pub gate: Vec<Vec<f32>>,
    pub g: usize,
    pub g_active: usize,
}

impl Routing {
    /// Debug-build contract check: mask/gate rows are `G` wide, every
    /// token selects exactly `G'` blocks, gates are non-negative, live
    /// only on selected blocks, and sum to `G'` (softmax × G').  Called
    /// after routing and at FFN kernel entry; compiles to nothing in
    /// release builds.
    #[inline]
    pub fn debug_validate(&self) {
        if cfg!(debug_assertions) {
            debug_assert_eq!(self.mask.len(), self.gate.len(), "mask/gate row count");
            debug_assert!(self.g_active >= 1 && self.g_active <= self.g, "G' in 1..=G");
            for (t, (mrow, grow)) in self.mask.iter().zip(&self.gate).enumerate() {
                debug_assert_eq!(mrow.len(), self.g, "token {t}: mask width");
                debug_assert_eq!(grow.len(), self.g, "token {t}: gate width");
                let active = mrow.iter().filter(|&&b| b).count();
                debug_assert_eq!(active, self.g_active, "token {t}: selection count");
                let mut sum = 0.0f32;
                for (j, (&m, &gv)) in mrow.iter().zip(grow).enumerate() {
                    debug_assert!(m || gv == 0.0, "token {t}: gate {j} outside mask");
                    debug_assert!(gv >= 0.0, "token {t}: negative gate {j}");
                    sum += gv;
                }
                debug_assert!(
                    (sum - self.g_active as f32).abs() < 1e-3 * self.g_active as f32,
                    "token {t}: gate sum {sum}"
                );
            }
        }
    }
}

/// Reusable per-task buffers for [`block_partial`] / [`block_backward`]:
/// the token gathers, the hidden activations, and the GEMM workspace.
/// Contents are meaningless between calls — a fresh and a reused scratch
/// produce identical bits.
#[derive(Debug, Default)]
pub struct BlockScratch {
    ws: Workspace,
    xg: Matrix,
    dyg: Matrix,
    h: Matrix,
    hg: Matrix,
    dh: Matrix,
}

/// Compute routing from router scores (top-G' by |score|, gated by a
/// softmax over the selected scores — matches the L1 kernel semantics).
///
/// Selection is `select_nth_unstable`-based — O(G) per token instead of
/// a full O(G log G) sort — followed by a sort of just the G' winners,
/// which restores the |score|-desc-then-index order the full-sort
/// implementation produced, so gate values are bit-identical to it.
pub fn route(scores: &Matrix, g_active: usize) -> Routing {
    let mut out = Routing {
        mask: Vec::new(),
        gate: Vec::new(),
        g: scores.cols,
        g_active,
    };
    route_into(scores, g_active, &mut out);
    out
}

/// [`route`] into a reusable [`Routing`] — the cached-decode hot loop
/// calls the router once per layer per step, so reusing the mask/gate
/// buffers keeps steady-state serving allocation-free.  Bit-identical to
/// a freshly-allocated [`route`]: every row is reset to the no-selection
/// state before the winners are written.
pub fn route_into(scores: &Matrix, g_active: usize, out: &mut Routing) {
    let nt = scores.rows;
    let g = scores.cols;
    assert!(g_active >= 1 && g_active <= g);
    out.g = g;
    out.g_active = g_active;
    out.mask.resize_with(nt, || vec![false; g]);
    out.gate.resize_with(nt, || vec![0.0f32; g]);
    let mut order: Vec<usize> = Vec::with_capacity(g);
    for t in 0..nt {
        let mrow = &mut out.mask[t];
        if mrow.len() == g {
            mrow.fill(false);
        } else {
            mrow.clear();
            mrow.resize(g, false);
        }
        let grow = &mut out.gate[t];
        if grow.len() == g {
            grow.fill(0.0);
        } else {
            grow.clear();
            grow.resize(g, 0.0);
        }
        let row = scores.row(t);
        // top-G' by |score|, ties by lower index — a strict total order,
        // so the winner *set* of select_nth equals the full sort's.
        let cmp = |a: &usize, b: &usize| {
            row[*b].abs().total_cmp(&row[*a].abs()).then(a.cmp(b))
        };
        order.clear();
        order.extend(0..g);
        if g_active < g {
            order.select_nth_unstable_by(g_active - 1, cmp);
        }
        let sel = &mut order[..g_active];
        sel.sort_unstable_by(cmp);
        let mx = sel.iter().map(|&j| row[j]).fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for &j in sel.iter() {
            denom += (row[j] - mx).exp();
        }
        for &j in sel.iter() {
            out.mask[t][j] = true;
            out.gate[t][j] = (row[j] - mx).exp() / denom.max(1e-30) * g_active as f32;
        }
    }
    out.debug_validate();
}

/// One block's contribution (paper Alg. 4 lines 2-5): the activated
/// token list and their output rows `relu(X_g W_I[g]) * gate @ W_O[g]`,
/// or `None` when no token activated the block.  Shared by the
/// sequential [`routed_ffn`] and the parallel
/// [`crate::sparse::mha::routed_ffn_par`], so the two execution paths
/// stay bit-identical by construction.
pub fn block_partial(
    gi: usize,
    x: &Matrix,
    w_i: &Matrix,
    w_o: &Matrix,
    routing: &Routing,
    scratch: &mut BlockScratch,
) -> Option<(Vec<usize>, Matrix)> {
    let nt = x.rows;
    let d = x.cols;
    let dg = w_i.cols / routing.g;
    // Select tokens (Alg. 4 lines 2-3) — the paper's index_get.
    let tokens: Vec<usize> = (0..nt).filter(|&t| routing.mask[t][gi]).collect();
    if tokens.is_empty() {
        return None;
    }
    // Gather X_g.
    scratch.xg.reset_any(tokens.len(), d);
    for (r, &t) in tokens.iter().enumerate() {
        scratch.xg.row_mut(r).copy_from_slice(x.row(t));
    }
    // Inner projection against the W_I column block [gi*dg, (gi+1)*dg),
    // packed straight out of w_i (no wi_g copy), then ReLU + gate.
    scratch.h.reset_any(tokens.len(), dg);
    matrix::gemm_into(
        tokens.len(),
        d,
        dg,
        &scratch.xg.data,
        &w_i.data,
        w_i.cols,
        gi * dg,
        &mut scratch.h.data,
        &mut scratch.ws.packb,
    );
    for v in scratch.h.data.iter_mut() {
        *v = v.max(0.0);
    }
    for (r, &t) in tokens.iter().enumerate() {
        let gate = routing.gate[t][gi];
        for v in scratch.h.row_mut(r) {
            *v *= gate;
        }
    }
    // Outer projection (line 5) against the contiguous W_O row block;
    // the caller scatters — paper's index_put.
    let mut yg = Matrix::zeros(tokens.len(), d);
    matrix::gemm_into(
        tokens.len(),
        dg,
        d,
        &scratch.h.data,
        &w_o.data[gi * dg * d..(gi + 1) * dg * d],
        d,
        0,
        &mut yg.data,
        &mut scratch.ws.packb,
    );
    Some((tokens, yg))
}

/// One block's backward, the unit both [`routed_ffn_backward`] and the
/// parallel [`crate::sparse::mha::routed_ffn_backward_par`] dispatch:
/// recompute the block forward (gather + inner GEMM + ReLU), then push
/// `dY` back through it.  The routing (mask and gate values) is treated
/// as a constant, matching the forward's non-differentiable top-G'
/// selection.  Returns `(tokens, dX_g, dW_I[g], dW_O[g])`, or `None`
/// when no token activated the block.
pub fn block_backward(
    gi: usize,
    x: &Matrix,
    w_i: &Matrix,
    w_o: &Matrix,
    routing: &Routing,
    dy: &Matrix,
    scratch: &mut BlockScratch,
) -> Option<(Vec<usize>, Matrix, Matrix, Matrix)> {
    let nt = x.rows;
    let d = x.cols;
    let dg = w_i.cols / routing.g;
    let tokens: Vec<usize> = (0..nt).filter(|&t| routing.mask[t][gi]).collect();
    if tokens.is_empty() {
        return None;
    }
    let ng = tokens.len();
    // Gather X_g and dY_g.
    scratch.xg.reset_any(ng, d);
    scratch.dyg.reset_any(ng, d);
    for (r, &t) in tokens.iter().enumerate() {
        scratch.xg.row_mut(r).copy_from_slice(x.row(t));
        scratch.dyg.row_mut(r).copy_from_slice(dy.row(t));
    }
    // Recompute the hidden activations (recompute-based backward: the
    // forward keeps no per-block caches).  W_I's column block is packed
    // in place, as in the forward.
    scratch.h.reset_any(ng, dg);
    matrix::gemm_into(
        ng,
        d,
        dg,
        &scratch.xg.data,
        &w_i.data,
        w_i.cols,
        gi * dg,
        &mut scratch.h.data,
        &mut scratch.ws.packb,
    );
    for v in scratch.h.data.iter_mut() {
        *v = v.max(0.0);
    }
    scratch.hg.reset_any(ng, dg);
    scratch.hg.data.copy_from_slice(&scratch.h.data);
    for (r, &t) in tokens.iter().enumerate() {
        let gate = routing.gate[t][gi];
        for v in scratch.hg.row_mut(r) {
            *v *= gate;
        }
    }
    // dW_O[g] = (h * gate)^T dY_g ;  d(h*gate) = dY_g W_O[g]^T (the
    // contiguous W_O row block, multiplied without a transpose copy).
    let dwo_g = grad::matmul_dw_ws(&scratch.hg, &scratch.dyg, &mut scratch.ws);
    scratch.dh.reset_any(ng, dg);
    matrix::gemm_nt_into(
        ng,
        d,
        dg,
        &scratch.dyg.data,
        &w_o.data[gi * dg * d..(gi + 1) * dg * d],
        d,
        0,
        &mut scratch.dh.data,
        &mut scratch.ws.packb,
    );
    for (r, &t) in tokens.iter().enumerate() {
        let gate = routing.gate[t][gi];
        for v in scratch.dh.row_mut(r) {
            *v *= gate;
        }
    }
    // dpre = dh ⊙ [h > 0], in place (the ReLU backward; h = max(pre, 0)
    // is never NaN, so the <= test is the exact complement).
    for (o, &hv) in scratch.dh.data.iter_mut().zip(&scratch.h.data) {
        if hv <= 0.0 {
            *o = 0.0;
        }
    }
    // dW_I[g] = X_g^T dpre ;  dX_g = dpre W_I[g]^T (the W_I column block
    // addressed row-strided, again with no copy).
    let dwi_g = grad::matmul_dw_ws(&scratch.xg, &scratch.dh, &mut scratch.ws);
    let mut dxg = Matrix::zeros(ng, d);
    matrix::gemm_nt_into(
        ng,
        dg,
        d,
        &scratch.dh.data,
        &w_i.data,
        w_i.cols,
        gi * dg,
        &mut dxg.data,
        &mut scratch.ws.packb,
    );
    Some((tokens, dxg, dwi_g, dwo_g))
}

/// Backward of [`routed_ffn`]: per-block weight gradients accumulated
/// along the same [`Routing`] the forward used, plus the scattered input
/// gradient.  Returns `(dx, dw_i, dw_o)`.
pub fn routed_ffn_backward(
    x: &Matrix,
    w_i: &Matrix,
    w_o: &Matrix,
    routing: &Routing,
    dy: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    routing.debug_validate();
    let nt = x.rows;
    let d = x.cols;
    assert_eq!(w_i.cols % routing.g, 0);
    assert_eq!(dy.rows, nt, "dY/X row mismatch");
    assert_eq!(dy.cols, d, "dY/X col mismatch");
    let dg = w_i.cols / routing.g;
    let mut dx = Matrix::zeros(nt, d);
    let mut dwi = Matrix::zeros(w_i.rows, w_i.cols);
    let mut dwo = Matrix::zeros(w_o.rows, w_o.cols);
    let mut scratch = BlockScratch::default();
    for gi in 0..routing.g {
        if let Some((tokens, dxg, dwi_g, dwo_g)) =
            block_backward(gi, x, w_i, w_o, routing, dy, &mut scratch)
        {
            scatter_block_grads(
                &mut dx, &mut dwi, &mut dwo, gi, dg, &tokens, &dxg, &dwi_g, &dwo_g,
            );
        }
    }
    (dx, dwi, dwo)
}

/// Merge one block's backward outputs into the full-size gradient
/// buffers (ascending-block call order keeps the token scatter-add
/// deterministic; the W_I/W_O slices are disjoint per block).  Shared
/// with the parallel reduce in `sparse::mha`.
pub(crate) fn scatter_block_grads(
    dx: &mut Matrix,
    dwi: &mut Matrix,
    dwo: &mut Matrix,
    gi: usize,
    dg: usize,
    tokens: &[usize],
    dxg: &Matrix,
    dwi_g: &Matrix,
    dwo_g: &Matrix,
) {
    for (r, &t) in tokens.iter().enumerate() {
        for (o, &g) in dx.row_mut(t).iter_mut().zip(dxg.row(r)) {
            *o += g;
        }
    }
    let d = dwi.rows;
    for r in 0..d {
        dwi.row_mut(r)[gi * dg..(gi + 1) * dg].copy_from_slice(dwi_g.row(r));
    }
    for r in 0..dg {
        dwo.row_mut(gi * dg + r).copy_from_slice(dwo_g.row(r));
    }
}

/// Routed FFN via BSpMV (paper Alg. 4).
///
/// `w_i`: `[d, D]` split into G column blocks; `w_o`: `[D, d]` split into G
/// row blocks.  For each block g: gather tokens with `mask[t][g]`, compute
/// `relu(X_g W_I[g]) * gate` then `@ W_O[g]`, scatter-add into Y.
pub fn routed_ffn(x: &Matrix, w_i: &Matrix, w_o: &Matrix, routing: &Routing) -> Matrix {
    routing.debug_validate();
    let nt = x.rows;
    let d = x.cols;
    assert_eq!(w_i.cols % routing.g, 0);
    let mut y = Matrix::zeros(nt, d);
    let mut scratch = BlockScratch::default();
    for gi in 0..routing.g {
        if let Some((tokens, yg)) = block_partial(gi, x, w_i, w_o, routing, &mut scratch) {
            for (r, &t) in tokens.iter().enumerate() {
                for (o, &v) in y.row_mut(t).iter_mut().zip(yg.row(r)) {
                    *o += v;
                }
            }
        }
    }
    y
}

/// Dense FFN baseline with the same gating (what BSpMV must equal).
pub fn dense_gated_ffn(
    x: &Matrix,
    w_i: &Matrix,
    w_o: &Matrix,
    routing: &Routing,
) -> Matrix {
    let dd = w_i.cols;
    let g = routing.g;
    let dg = dd / g;
    let h = x.matmul(w_i).relu();
    let mut hg = h;
    for t in 0..x.rows {
        for gi in 0..g {
            let gate = routing.gate[t][gi];
            for c in gi * dg..(gi + 1) * dg {
                *hg.at_mut(t, c) *= gate;
            }
        }
    }
    hg.matmul(w_o)
}

/// FLOPs of the routed FFN (forward) — `beta` of the dense cost.
pub fn routed_flops(nt: usize, d: usize, dd: usize, g: usize, g_active: usize) -> u64 {
    // per active (token, block): 2*d*dg + 2*dg*d
    let dg = (dd / g) as u64;
    (nt as u64) * (g_active as u64) * 4 * (d as u64) * dg
}

/// FLOPs of the dense FFN (forward).
pub fn dense_flops(nt: usize, d: usize, dd: usize) -> u64 {
    4 * (nt as u64) * (d as u64) * (dd as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::rng::Rng;

    #[test]
    fn bspmv_equals_dense_gated_ffn() {
        check(25, |g| {
            let nt = g.usize_in(1, 32);
            let d = g.usize_in(1, 12);
            let gg = *g.pick(&[2usize, 4, 8]);
            let dg = g.usize_in(1, 6);
            let dd = gg * dg;
            let ga = g.usize_in(1, gg);
            let mut rng = g.rng().fork();
            let x = Matrix::randn(nt, d, 1.0, &mut rng);
            let wi = Matrix::randn(d, dd, 0.3, &mut rng);
            let wo = Matrix::randn(dd, d, 0.3, &mut rng);
            let scores = Matrix::randn(nt, gg, 1.0, &mut rng);
            let routing = route(&scores, ga);
            let y1 = routed_ffn(&x, &wi, &wo, &routing);
            let y2 = dense_gated_ffn(&x, &wi, &wo, &routing);
            prop_assert(
                y1.max_abs_diff(&y2) < 1e-4,
                format!("diff {}", y1.max_abs_diff(&y2)),
            )
        });
    }

    #[test]
    fn routing_selects_exactly_g_active() {
        check(25, |g| {
            let nt = g.usize_in(1, 64);
            let gg = *g.pick(&[4usize, 8]);
            let ga = g.usize_in(1, gg);
            let mut rng = g.rng().fork();
            let scores = Matrix::randn(nt, gg, 1.0, &mut rng);
            let r = route(&scores, ga);
            for t in 0..nt {
                let cnt = r.mask[t].iter().filter(|&&b| b).count();
                prop_assert(cnt == ga, format!("token {t}: {cnt} != {ga}"))?;
                let gate_sum: f32 = r.gate[t].iter().sum();
                prop_assert(
                    (gate_sum - ga as f32).abs() < 1e-4,
                    format!("gate sum {gate_sum}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn route_selection_matches_full_sort_reference() {
        // The select_nth-based routing must pick the same winner set and
        // produce the same gate bits as the original full-sort version.
        check(25, |g| {
            let nt = g.usize_in(1, 24);
            let gg = *g.pick(&[2usize, 4, 8, 16]);
            let ga = g.usize_in(1, gg);
            let mut rng = g.rng().fork();
            // Duplicate |score| values to exercise the index tie-break.
            let mut scores = Matrix::randn(nt, gg, 1.0, &mut rng);
            for v in scores.data.iter_mut() {
                *v = (*v * 4.0).round() / 4.0;
            }
            let fast = route(&scores, ga);
            for t in 0..nt {
                let row = scores.row(t);
                let mut order: Vec<usize> = (0..gg).collect();
                order.sort_by(|&a, &b| {
                    row[b].abs().total_cmp(&row[a].abs()).then(a.cmp(&b))
                });
                let sel = &order[..ga];
                let mx =
                    sel.iter().map(|&j| row[j]).fold(f32::NEG_INFINITY, f32::max);
                let mut denom = 0.0f32;
                for &j in sel {
                    denom += (row[j] - mx).exp();
                }
                for j in 0..gg {
                    let want_mask = sel.contains(&j);
                    prop_assert(
                        fast.mask[t][j] == want_mask,
                        format!("token {t} block {j}: mask mismatch"),
                    )?;
                    let want_gate = if want_mask {
                        (row[j] - mx).exp() / denom.max(1e-30) * ga as f32
                    } else {
                        0.0
                    };
                    prop_assert(
                        fast.gate[t][j].to_bits() == want_gate.to_bits(),
                        format!(
                            "token {t} block {j}: gate {} vs {}",
                            fast.gate[t][j], want_gate
                        ),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn route_into_reuse_matches_fresh_route() {
        // Reusing one Routing across differently-shaped calls must give
        // the same bits as a fresh allocation every time.
        let mut rng = Rng::new(31);
        let mut r = Routing { mask: Vec::new(), gate: Vec::new(), g: 1, g_active: 1 };
        for (nt, gg, ga) in [(5usize, 8usize, 3usize), (9, 4, 2), (3, 8, 8), (1, 4, 1)] {
            let scores = Matrix::randn(nt, gg, 1.0, &mut rng);
            route_into(&scores, ga, &mut r);
            let fresh = route(&scores, ga);
            assert_eq!(r.mask, fresh.mask, "{nt}x{gg} mask");
            for t in 0..nt {
                for j in 0..gg {
                    assert_eq!(
                        r.gate[t][j].to_bits(),
                        fresh.gate[t][j].to_bits(),
                        "{nt}x{gg} gate ({t},{j})"
                    );
                }
            }
            assert_eq!((r.g, r.g_active), (gg, ga));
        }
    }

    #[test]
    fn all_blocks_active_with_zero_router_is_plain_ffn() {
        let mut rng = Rng::new(3);
        let (nt, d, dd, g) = (8, 4, 16, 4);
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, dd, 0.3, &mut rng);
        let wo = Matrix::randn(dd, d, 0.3, &mut rng);
        let scores = Matrix::zeros(nt, g);
        let routing = route(&scores, g);
        let y = routed_ffn(&x, &wi, &wo, &routing);
        let want = x.matmul(&wi).relu().matmul(&wo);
        assert!(y.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn backward_with_all_blocks_active_matches_plain_ffn_backward() {
        // Zero router scores + G' = G makes every gate 1.0, so the routed
        // backward must agree with the dense relu-FFN backward assembled
        // from the grad primitives.
        let mut rng = Rng::new(17);
        let (nt, d, dd, g) = (9, 5, 12, 4);
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, dd, 0.4, &mut rng);
        let wo = Matrix::randn(dd, d, 0.4, &mut rng);
        let dy = Matrix::randn(nt, d, 1.0, &mut rng);
        let routing = route(&Matrix::zeros(nt, g), g);
        let (dx, dwi, dwo) = routed_ffn_backward(&x, &wi, &wo, &routing, &dy);
        // Dense reference.
        let h = x.matmul(&wi).relu();
        let dwo_ref = grad::matmul_dw(&h, &dy);
        let dh = grad::matmul_dx(&dy, &wo);
        let dpre = grad::relu_backward(&h, &dh);
        let dwi_ref = grad::matmul_dw(&x, &dpre);
        let dx_ref = grad::matmul_dx(&dpre, &wi);
        assert!(dx.max_abs_diff(&dx_ref) < 1e-4, "{}", dx.max_abs_diff(&dx_ref));
        assert!(dwi.max_abs_diff(&dwi_ref) < 1e-4, "{}", dwi.max_abs_diff(&dwi_ref));
        assert!(dwo.max_abs_diff(&dwo_ref) < 1e-4, "{}", dwo.max_abs_diff(&dwo_ref));
    }

    #[test]
    fn inactive_blocks_get_zero_weight_gradient() {
        let mut rng = Rng::new(18);
        let (nt, d, dd, g, ga) = (6, 4, 8, 4, 1);
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, dd, 0.4, &mut rng);
        let wo = Matrix::randn(dd, d, 0.4, &mut rng);
        let dy = Matrix::randn(nt, d, 1.0, &mut rng);
        let routing = route(&Matrix::randn(nt, g, 1.0, &mut rng), ga);
        let (_, dwi, dwo) = routed_ffn_backward(&x, &wi, &wo, &routing, &dy);
        let dg = dd / g;
        for gi in 0..g {
            let active = (0..nt).any(|t| routing.mask[t][gi]);
            if active {
                continue;
            }
            for r in 0..d {
                assert!(dwi.row(r)[gi * dg..(gi + 1) * dg]
                    .iter()
                    .all(|&v| v == 0.0));
            }
            for r in gi * dg..(gi + 1) * dg {
                assert!(dwo.row(r).iter().all(|&v| v == 0.0));
            }
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "gate 3 outside mask")]
    fn debug_validate_catches_gate_outside_mask() {
        let mut r = route(&Matrix::zeros(2, 4), 2);
        r.gate[0][3] = 0.5;
        r.debug_validate();
    }

    #[test]
    fn flops_ratio_is_beta() {
        let r = routed_flops(512, 2048, 8192, 8, 4) as f64
            / dense_flops(512, 2048, 8192) as f64;
        assert!((r - 0.5).abs() < 1e-9);
    }
}
