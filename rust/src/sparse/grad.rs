//! Backward passes for the sparse substrate (the native training path).
//!
//! The forward pipelines in [`super::attention`] / [`super::bspmv`] treat
//! the *structure* decisions — PQ quantization, bucket-sort top-L
//! selection, router top-G' selection — as non-differentiable, exactly as
//! the paper's CUDA kernels do: gradients flow only through the kept
//! attention entries and the activated FFN blocks, while codebooks are
//! maintained by the DKM-style k-means refresh instead of SGD.
//!
//! The projection backwards run on the blocked microkernel in
//! [`super::matrix`]: `dX = dY @ W^T` maps onto [`matrix::gemm_nt_into`]
//! (no transpose materialized), and `dW = X^T @ dY` onto a blocked
//! transpose plus [`matrix::gemm_into`].  Per-output-element
//! accumulation order is unchanged from the naive loops, so results are
//! bit-identical to the sequential reference at any thread count.  The
//! `*_ws` variants reuse a caller-owned [`Workspace`] so the training
//! hot path stops allocating scratch per op.

use super::csr::Csr;
use super::matrix::{self, Matrix, Workspace};

/// `dX` for `Y = X @ W` given `dY`: `dX = dY @ W^T`.
///
/// `dy` is `[n, p]`, `w` is the forward weight `[m, p]`, result is
/// `[n, m]`.  Runs on the NT microkernel — each output element is one
/// ascending-order dot product.  Allocates a transient workspace for
/// the transpose-pack pass; hot paths should prefer
/// [`matmul_dx_ws`] / [`matmul_dx_into`] with a reused [`Workspace`]
/// (bit-identical either way).
pub fn matmul_dx(dy: &Matrix, w: &Matrix) -> Matrix {
    matmul_dx_ws(dy, w, &mut Workspace::default())
}

/// [`matmul_dx`] reusing `ws` for the NT transpose-pack scratch.
pub fn matmul_dx_ws(dy: &Matrix, w: &Matrix, ws: &mut Workspace) -> Matrix {
    let mut out = Matrix::default();
    matmul_dx_into(dy, w, &mut out, ws);
    out
}

/// [`matmul_dx`] into a reusable output matrix.
pub fn matmul_dx_into(dy: &Matrix, w: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
    assert_eq!(dy.cols, w.cols, "matmul_dx: dY/W inner dim mismatch");
    out.reset_any(dy.rows, w.rows);
    matrix::gemm_nt_into(
        dy.rows,
        dy.cols,
        w.rows,
        &dy.data,
        &w.data,
        w.cols,
        0,
        &mut out.data,
        &mut ws.packb,
    );
}

/// `dW` for `Y = X @ W` given `dY`: `dW = X^T @ dY`.
///
/// `x` is `[n, m]`, `dy` is `[n, p]`, result is `[m, p]`.  Accumulation
/// over the `n` rows happens in ascending row order for every output
/// element, so the result is deterministic at any thread count.
pub fn matmul_dw(x: &Matrix, dy: &Matrix) -> Matrix {
    matmul_dw_ws(x, dy, &mut Workspace::default())
}

/// [`matmul_dw`] reusing `ws` for the transpose + pack scratch.
pub fn matmul_dw_ws(x: &Matrix, dy: &Matrix, ws: &mut Workspace) -> Matrix {
    let mut out = Matrix::default();
    matmul_dw_into(x, dy, &mut out, ws);
    out
}

/// [`matmul_dw`] into a reusable output matrix.
pub fn matmul_dw_into(x: &Matrix, dy: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
    assert_eq!(x.rows, dy.rows, "matmul_dw: X/dY row mismatch");
    out.reset_any(x.cols, dy.cols);
    let Workspace { packb, tmp, .. } = ws;
    matrix::transpose_slice(x.rows, x.cols, &x.data, tmp);
    matrix::gemm_into(
        x.cols, x.rows, dy.cols, tmp, &dy.data, dy.cols, 0, &mut out.data, packb,
    );
}

/// Backward of both directions of `Y = X @ W` at once.
pub fn linear_backward(x: &Matrix, w: &Matrix, dy: &Matrix) -> (Matrix, Matrix) {
    (matmul_dx(dy, w), matmul_dw(x, dy))
}

/// ReLU backward given the forward *output* `h = relu(pre)`:
/// `dpre = dy ⊙ [h > 0]` (the subgradient at the kink is 0, matching
/// `relu`'s `max(0, ·)`).
pub fn relu_backward(h: &Matrix, dy: &Matrix) -> Matrix {
    assert_eq!(h.rows, dy.rows, "relu_backward shape mismatch");
    assert_eq!(h.cols, dy.cols, "relu_backward shape mismatch");
    let data = h
        .data
        .iter()
        .zip(&dy.data)
        .map(|(&hv, &g)| if hv > 0.0 { g } else { 0.0 })
        .collect();
    Matrix { rows: h.rows, cols: h.cols, data }
}

/// Epsilon inside layer norm's variance square root — matches the L2
/// JAX `layer_norm` definition (`python/compile/model.py`).
pub const LN_EPS: f32 = 1e-5;

/// Row-wise layer norm `y = (x - mu) / sqrt(var + LN_EPS) * scale + bias`
/// (biased variance over the feature dimension, as in the L2 model).
///
/// `scale` and `bias` are `[1, d]`.  Per-row reductions run in ascending
/// column order, so results are deterministic and independent of any
/// outer parallelism.
pub fn layer_norm(x: &Matrix, scale: &Matrix, bias: &Matrix) -> Matrix {
    assert_eq!(scale.cols, x.cols, "layer_norm: scale dim mismatch");
    assert_eq!(bias.cols, x.cols, "layer_norm: bias dim mismatch");
    let d = x.cols;
    let mut out = Matrix::zeros(x.rows, d);
    for r in 0..x.rows {
        let row = x.row(r);
        let (mean, inv) = row_mean_inv_std(row);
        for (i, (o, &v)) in out.row_mut(r).iter_mut().zip(row).enumerate() {
            *o = (v - mean) * inv * scale.data[i] + bias.data[i];
        }
    }
    out
}

/// Per-row mean and `1 / sqrt(var + LN_EPS)`, in the exact operation
/// order both the forward and the backward recomputation use.
fn row_mean_inv_std(row: &[f32]) -> (f32, f32) {
    let d = row.len() as f32;
    let mut mean = 0.0f32;
    for &v in row {
        mean += v;
    }
    mean /= d;
    let mut var = 0.0f32;
    for &v in row {
        let c = v - mean;
        var += c * c;
    }
    var /= d;
    (mean, 1.0 / (var + LN_EPS).sqrt())
}

/// Backward of [`layer_norm`] given the forward *input* `x` (mean and
/// variance are recomputed per row in the forward's operation order).
///
/// With `xhat = (x - mu) * inv_std` and `dxhat = dy ⊙ scale`:
/// `dx = inv_std * (dxhat - mean(dxhat) - xhat * mean(dxhat ⊙ xhat))`.
/// Returns `(dx, dscale, dbias)`; `dscale = Σ_rows dy ⊙ xhat` and
/// `dbias = Σ_rows dy` are `[1, d]`, accumulated in ascending row order.
pub fn layer_norm_backward(
    x: &Matrix,
    scale: &Matrix,
    dy: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    assert_eq!(scale.cols, x.cols, "layer_norm_backward: scale dim mismatch");
    assert_eq!(dy.rows, x.rows, "layer_norm_backward: dY row mismatch");
    assert_eq!(dy.cols, x.cols, "layer_norm_backward: dY col mismatch");
    let d = x.cols;
    let inv_d = 1.0 / d as f32;
    let mut dx = Matrix::zeros(x.rows, d);
    let mut dscale = Matrix::zeros(1, d);
    let mut dbias = Matrix::zeros(1, d);
    for r in 0..x.rows {
        let row = x.row(r);
        let dy_row = dy.row(r);
        let (mean, inv) = row_mean_inv_std(row);
        let mut sum_dxhat = 0.0f32;
        let mut sum_dxhat_xhat = 0.0f32;
        for i in 0..d {
            let xhat = (row[i] - mean) * inv;
            let dxh = dy_row[i] * scale.data[i];
            sum_dxhat += dxh;
            sum_dxhat_xhat += dxh * xhat;
            dscale.data[i] += dy_row[i] * xhat;
            dbias.data[i] += dy_row[i];
        }
        let m1 = sum_dxhat * inv_d;
        let m2 = sum_dxhat_xhat * inv_d;
        for (i, o) in dx.row_mut(r).iter_mut().enumerate() {
            let xhat = (row[i] - mean) * inv;
            let dxh = dy_row[i] * scale.data[i];
            *o = inv * (dxh - m1 - xhat * m2);
        }
    }
    (dx, dscale, dbias)
}

/// Backward of [`super::attention::sparse_attention_masked`] through the
/// kept entries only.
///
/// `attn` is the post-softmax CSR the forward returned (probabilities in
/// `values`, the flat top-L structure in `indices`).  Gradients w.r.t.
/// Q/K/V flow exclusively through the kept `(query, key)` pairs; causal
/// padding slots carry probability 0 after the forward re-mask and so
/// contribute nothing here.  Returns `(dq, dk, dv)`.
pub fn sparse_attention_backward(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    attn: &Csr,
    dy: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    assert_eq!(attn.rows, q.rows, "attn/Q row mismatch");
    assert_eq!(attn.cols, k.rows, "attn/K col mismatch");
    assert_eq!(dy.rows, q.rows, "dY/Q row mismatch");
    assert_eq!(dy.cols, v.cols, "dY/V col mismatch");
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut dq = Matrix::zeros(q.rows, q.cols);
    let mut dk = Matrix::zeros(k.rows, k.cols);
    let mut dv = Matrix::zeros(v.rows, v.cols);
    let mut dp = Vec::new();
    for r in 0..attn.rows {
        let range = attn.row_range(r);
        if range.is_empty() {
            continue;
        }
        let dy_row = dy.row(r);
        // dP_rj = dy_r . v_j, plus the softmax-backward row reduction
        // dot = sum_j P_rj dP_rj.
        dp.clear();
        let mut dot = 0.0f32;
        for p in range.clone() {
            let j = attn.indices[p] as usize;
            let g: f32 = dy_row.iter().zip(v.row(j)).map(|(a, b)| a * b).sum();
            dot += attn.values[p] * g;
            dp.push(g);
        }
        for (slot, p) in range.enumerate() {
            let j = attn.indices[p] as usize;
            let prob = attn.values[p];
            if prob != 0.0 {
                // dV_j += P_rj dy_r
                for (o, &g) in dv.row_mut(j).iter_mut().zip(dy_row) {
                    *o += prob * g;
                }
            }
            // Softmax backward: dS_rj = P_rj (dP_rj - dot); the logits
            // were S = scale * q_r . k_j.
            let ds = prob * (dp[slot] - dot);
            if ds == 0.0 {
                continue;
            }
            let c = scale * ds;
            for (o, &x) in dq.row_mut(r).iter_mut().zip(k.row(j)) {
                *o += c * x;
            }
            for (o, &x) in dk.row_mut(j).iter_mut().zip(q.row(r)) {
                *o += c * x;
            }
        }
    }
    (dq, dk, dv)
}

/// Backward of [`super::attention::dense_attention`] (the full/LoRA
/// attention path of the native model).  Recomputes the probability
/// matrix in the forward operation order, then applies the standard
/// softmax-attention gradients.  Returns `(dq, dk, dv)`.
pub fn dense_attention_backward(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    causal: bool,
    dy: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    dense_attention_backward_ws(q, k, v, causal, dy, &mut Workspace::default())
}

/// [`dense_attention_backward`] reusing a caller-owned workspace: the
/// O(n²) probability matrix and its gradient live in the workspace's
/// matrix slots (dS overwrites dP in place), so the backward allocates
/// only its returned gradients.  Bit-identical to the allocating path.
pub fn dense_attention_backward_ws(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    causal: bool,
    dy: &Matrix,
    ws: &mut Workspace,
) -> (Matrix, Matrix, Matrix) {
    assert_eq!(q.cols, k.cols, "Q/K dim mismatch");
    assert_eq!(k.rows, v.rows, "K/V row mismatch");
    assert_eq!(dy.rows, q.rows, "dY/Q row mismatch");
    assert_eq!(dy.cols, v.cols, "dY/V col mismatch");
    let scale = 1.0 / (q.cols as f32).sqrt();
    let (n, nk) = (q.rows, k.rows);
    // Field-split borrows: attn/attn2 hold the O(n²) transients while
    // packb/tmp serve the pack and transpose passes.
    let Workspace { packb, tmp, attn, attn2 } = ws;
    // P = softmax(scale * Q K^T) in ws.attn — NT kernel, no transposed
    // K materialized.
    attn.reset_any(n, nk);
    matrix::gemm_nt_into(n, q.cols, nk, &q.data, &k.data, k.cols, 0, &mut attn.data, packb);
    for x in attn.data.iter_mut() {
        *x *= scale;
    }
    if causal {
        for i in 0..n {
            for j in (i + 1)..nk {
                *attn.at_mut(i, j) = -1e30;
            }
        }
    }
    attn.softmax_rows_inplace();
    // dV = P^T dY: transpose P into ws.tmp, then the packed kernel.
    let mut dv = Matrix::zeros(nk, dy.cols);
    matrix::transpose_slice(n, nk, &attn.data, tmp);
    matrix::gemm_into(nk, n, dy.cols, tmp, &dy.data, dy.cols, 0, &mut dv.data, packb);
    // dP = dY V^T into ws.attn2, then softmax backward overwrites it in
    // place with dS = P ⊙ (dP - sum_j P dP).
    attn2.reset_any(n, nk);
    matrix::gemm_nt_into(n, dy.cols, nk, &dy.data, &v.data, v.cols, 0, &mut attn2.data, packb);
    for r in 0..n {
        let p_row = attn.row(r);
        let dp_row = attn2.row_mut(r);
        let dot: f32 = p_row.iter().zip(dp_row.iter()).map(|(a, b)| a * b).sum();
        for (o, &pv) in dp_row.iter_mut().zip(p_row) {
            *o = pv * (*o - dot);
        }
    }
    // dQ = scale * dS K;  dK = scale * dS^T Q.
    let mut dq = Matrix::zeros(n, k.cols);
    matrix::gemm_into(n, nk, k.cols, &attn2.data, &k.data, k.cols, 0, &mut dq.data, packb);
    for x in dq.data.iter_mut() {
        *x *= scale;
    }
    let mut dk = Matrix::zeros(nk, q.cols);
    matrix::transpose_slice(n, nk, &attn2.data, tmp);
    matrix::gemm_into(nk, n, q.cols, tmp, &q.data, q.cols, 0, &mut dk.data, packb);
    for x in dk.data.iter_mut() {
        *x *= scale;
    }
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::attention;
    use crate::sparse::codes::TopL;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_backward_shapes_and_values() {
        // y = x @ w with scalar-friendly sizes; check against hand math.
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = Matrix::from_vec(2, 1, vec![5.0, 6.0]);
        let dy = Matrix::from_vec(2, 1, vec![1.0, 1.0]);
        let (dx, dw) = linear_backward(&x, &w, &dy);
        // dx = dy w^T = [[5,6],[5,6]]
        assert_eq!(dx.data, vec![5.0, 6.0, 5.0, 6.0]);
        // dw = x^T dy = [[4],[6]]
        assert_eq!(dw.data, vec![4.0, 6.0]);
    }

    #[test]
    fn matmul_dw_matches_naive_rank1_accumulation_bits() {
        // The transpose + blocked-GEMM path must reproduce the naive
        // ascending-row rank-1 accumulation exactly.
        fn naive_dw(x: &Matrix, dy: &Matrix) -> Matrix {
            let mut out = Matrix::zeros(x.cols, dy.cols);
            for i in 0..x.rows {
                let x_row = x.row(i);
                let dy_row = dy.row(i);
                for (k, &a) in x_row.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let out_row = out.row_mut(k);
                    for (o, &b) in out_row.iter_mut().zip(dy_row) {
                        *o += a * b;
                    }
                }
            }
            out
        }
        let mut rng = Rng::new(42);
        for (n, m, p) in [(5, 4, 3), (150, 70, 40), (33, 129, 65)] {
            let x = Matrix::randn(n, m, 1.0, &mut rng);
            let dy = Matrix::randn(n, p, 1.0, &mut rng);
            assert_eq!(matmul_dw(&x, &dy), naive_dw(&x, &dy), "{n}x{m}x{p}");
        }
    }

    #[test]
    fn matmul_dx_ws_and_into_match_allocating_path() {
        let mut rng = Rng::new(43);
        let dy = Matrix::randn(21, 33, 1.0, &mut rng);
        let w = Matrix::randn(17, 33, 1.0, &mut rng);
        let want = matmul_dx(&dy, &w);
        let mut ws = Workspace::default();
        let mut out = Matrix::default();
        matmul_dx_into(&dy, &w, &mut out, &mut ws);
        assert_eq!(out, want);
        assert_eq!(matmul_dx_ws(&dy, &w, &mut ws), want);
        let x = Matrix::randn(21, 17, 1.0, &mut rng);
        let want_dw = matmul_dw(&x, &dy);
        assert_eq!(matmul_dw_ws(&x, &dy, &mut ws), want_dw);
        // Reuse the same workspace for a second, differently-shaped op.
        assert_eq!(matmul_dw_ws(&dy, &x, &mut ws), matmul_dw(&dy, &x));
    }

    #[test]
    fn layer_norm_normalizes_rows() {
        let mut rng = Rng::new(21);
        let x = Matrix::randn(6, 32, 3.0, &mut rng);
        let ones = Matrix::from_vec(1, 32, vec![1.0; 32]);
        let zeros = Matrix::zeros(1, 32);
        let y = layer_norm(&x, &ones, &zeros);
        for r in 0..y.rows {
            let row = y.row(r);
            let mean: f32 = row.iter().sum::<f32>() / 32.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 32.0;
            assert!(mean.abs() < 1e-4, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "row {r} var {var}");
        }
        // Scale and bias are applied per column after normalization.
        let mut scale = Matrix::zeros(1, 32);
        let mut bias = Matrix::zeros(1, 32);
        for i in 0..32 {
            scale.data[i] = 2.0;
            bias.data[i] = -1.0;
        }
        let y2 = layer_norm(&x, &scale, &bias);
        for (a, b) in y2.data.iter().zip(&y.data) {
            assert!((a - (2.0 * b - 1.0)).abs() < 1e-5);
        }
    }

    #[test]
    fn layer_norm_backward_bias_and_scale_reductions() {
        let mut rng = Rng::new(22);
        let x = Matrix::randn(5, 8, 1.0, &mut rng);
        let scale = Matrix::randn(1, 8, 1.0, &mut rng);
        let dy = Matrix::randn(5, 8, 1.0, &mut rng);
        let (_, dscale, dbias) = layer_norm_backward(&x, &scale, &dy);
        // dbias is the plain column sum of dy.
        for c in 0..8 {
            let want: f32 = (0..5).map(|r| dy.at(r, c)).sum();
            assert!((dbias.at(0, c) - want).abs() < 1e-5);
        }
        // dscale matches sum_rows dy * xhat computed independently.
        let ones = Matrix::from_vec(1, 8, vec![1.0; 8]);
        let zeros = Matrix::zeros(1, 8);
        let xhat = layer_norm(&x, &ones, &zeros);
        for c in 0..8 {
            let want: f32 = (0..5).map(|r| dy.at(r, c) * xhat.at(r, c)).sum();
            assert!((dscale.at(0, c) - want).abs() < 1e-4);
        }
    }

    #[test]
    fn relu_backward_masks_inactive() {
        let h = Matrix::from_vec(1, 4, vec![0.0, 1.5, 0.0, 2.0]);
        let dy = Matrix::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(relu_backward(&h, &dy).data, vec![0.0, 2.0, 0.0, 4.0]);
    }

    #[test]
    fn sparse_backward_with_full_mask_matches_dense_backward() {
        // When every key is kept the sparse backward must agree with the
        // dense-attention backward (same function, different bookkeeping).
        let mut rng = Rng::new(11);
        let (n, d) = (10, 6);
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let dy = Matrix::randn(n, d, 1.0, &mut rng);
        let full: Vec<Vec<u32>> = (0..n).map(|_| (0..n as u32).collect()).collect();
        let idx = TopL::from_rows(&full);
        let (_, attn) = attention::sparse_attention_masked(&q, &k, &v, &idx, false);
        let (dq_s, dk_s, dv_s) = sparse_attention_backward(&q, &k, &v, &attn, &dy);
        let (dq_d, dk_d, dv_d) = dense_attention_backward(&q, &k, &v, false, &dy);
        assert!(dq_s.max_abs_diff(&dq_d) < 1e-4, "{}", dq_s.max_abs_diff(&dq_d));
        assert!(dk_s.max_abs_diff(&dk_d) < 1e-4, "{}", dk_s.max_abs_diff(&dk_d));
        assert!(dv_s.max_abs_diff(&dv_d) < 1e-4, "{}", dv_s.max_abs_diff(&dv_d));
    }

    #[test]
    fn dense_backward_ws_matches_allocating_path_bits() {
        let mut rng = Rng::new(44);
        let (n, d) = (12, 8);
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let dy = Matrix::randn(n, d, 1.0, &mut rng);
        let mut ws = Workspace::default();
        for causal in [false, true] {
            let (dq, dk, dv) = dense_attention_backward(&q, &k, &v, causal, &dy);
            let (dq2, dk2, dv2) =
                dense_attention_backward_ws(&q, &k, &v, causal, &dy, &mut ws);
            assert_eq!(dq, dq2, "causal={causal}");
            assert_eq!(dk, dk2, "causal={causal}");
            assert_eq!(dv, dv2, "causal={causal}");
        }
    }

    #[test]
    fn causal_padding_slots_get_no_gradient() {
        // Row 0 of a causal mask keeps only key 0; the padding slots point
        // at future keys whose probability is 0 after the re-mask, so dK
        // and dV rows for those keys must stay 0 (from row 0's view).
        let mut rng = Rng::new(12);
        let (n, d) = (5, 4);
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        // Only query row 0 receives upstream gradient.
        let mut dy = Matrix::zeros(n, d);
        for c in 0..d {
            *dy.at_mut(0, c) = 1.0;
        }
        let idx = TopL::from_rows(&(0..n).map(|_| vec![0u32, 1, 2]).collect::<Vec<_>>());
        let (_, attn) = attention::sparse_attention_masked(&q, &k, &v, &idx, true);
        let (dq, dk, dv) = sparse_attention_backward(&q, &k, &v, &attn, &dy);
        // Future keys 1 and 2 are masked for query 0: no gradient.
        for j in 1..3 {
            assert!(dk.row(j).iter().all(|&x| x == 0.0), "dk row {j}");
            assert!(dv.row(j).iter().all(|&x| x == 0.0), "dv row {j}");
        }
        // Query 0 attends only to key 0 with probability 1: softmax
        // backward collapses to 0 for dq.
        assert!(dq.row(0).iter().all(|&x| x.abs() < 1e-6));
        assert!(dv.row(0).iter().zip(v.row(0)).all(|(&g, _)| g == 1.0));
    }
}
