//! `spt` — the SPT fine-tuning coordinator CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   train       LM fine-tuning run (loss curve, PPL) — paper Fig. 10 axis
//!   train-qa    QA fine-tuning + accuracy (Table 3 MMLU surrogate)
//!   trial       short sparsity trials across modes (paper §3)
//!   generate    cached-decode generation from a checkpoint (native infer)
//!   serve-bench continuous-batching throughput/latency vs one-at-a-time
//!   profile     module-level time+memory (Tables 1/4)
//!   blocks      per-block throughput/memory across configs (Fig. 8)
//!   memplan     memory model: max-length search + seq sweeps (Table 3/Fig. 9)
//!               (--decode adds the KV/code-cache serving tables)
//!   obs-report  render an `--obs-log` JSONL into phase/sparsity/memory tables
//!   version     print build/host provenance (git sha, threads, CPU)
//!   goldens     numeric round-trip validation vs python outputs
//!   artifacts   list the AOT manifest
//!
//! `train`, `train-qa`, `trial`, `generate`, and `serve-bench` run on
//! the native backend by default (no artifacts or PJRT toolchain
//! needed); `--backend pjrt` selects the AOT path in a `--features xla`
//! build.  Run `spt help` for flags.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use spt::config::{presets, Mode, RunConfig};
use spt::coordinator::checkpoint::CkptMeta;
use spt::coordinator::{checkpoint, trial, Backend, NativeBackend, Trainer, TrainerOptions};
use spt::coordinator::trial::TrialManager;
use spt::data::SyntheticCorpus;
use spt::infer::{
    Daemon, DaemonConfig, InferModel, Request, Sampler, ServeConfig, ServeDriver, Session,
};
use spt::infer::serve::ServeReport;
use spt::obs::ObsLog;
use spt::util::fault::FaultPlan;
use spt::util::json::Json;
use spt::util::lock::PidLock;
use spt::util::rng::Rng;
#[cfg(feature = "xla")]
use spt::coordinator::profile as prof;
#[cfg(feature = "xla")]
use spt::coordinator::PjrtBackend;
use spt::memmodel;
use spt::metrics::Table;
#[cfg(feature = "xla")]
use spt::runtime::Engine;
use spt::util::fmt_bytes;
use spt::util::fmt_duration;

/// Minimal `--key value` / `--flag` argument parser.  Positionals are
/// collected for the commands that take one (`obs-report <run.jsonl>`);
/// every other command rejects them in [`run`].
struct Args {
    cmd: String,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut kv = BTreeMap::new();
        let mut flags = Vec::new();
        let mut pos = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                pos.push(a.clone());
                i += 1;
                continue;
            };
            if let Some((k, v)) = key.split_once('=') {
                kv.insert(k.to_string(), v.to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                kv.insert(key.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                flags.push(key.to_string());
            }
            i += 1;
        }
        Ok(Args { cmd, kv, flags, pos })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key}")),
            None => Ok(default),
        }
    }

    fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    fn run_config(&self) -> Result<RunConfig> {
        let mut rc = match self.get("config") {
            Some(path) => RunConfig::from_file(path)?,
            None => RunConfig::default(),
        };
        for key in ["model", "mode", "batch", "seq", "steps", "eval_every",
                    "codebook_refresh_every", "lr", "seed", "artifacts_dir",
                    "out_dir", "memory_budget_gb"] {
            if let Some(v) = self.get(key) {
                rc.set(key, v)?;
            }
        }
        Ok(rc)
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Which training backend a command should use.
enum BackendChoice {
    Native,
    #[cfg(feature = "xla")]
    Pjrt,
}

fn backend_choice(args: &Args) -> Result<BackendChoice> {
    match args.get("backend").unwrap_or("native") {
        "native" => Ok(BackendChoice::Native),
        "pjrt" => {
            #[cfg(feature = "xla")]
            return Ok(BackendChoice::Pjrt);
            #[cfg(not(feature = "xla"))]
            bail!(
                "--backend pjrt executes AOT artifacts through PJRT; \
                 rebuild with `--features xla` (see README)"
            )
        }
        other => bail!("unknown backend '{other}' (native|pjrt)"),
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    if args.cmd != "obs-report" {
        if let Some(p) = args.pos.first() {
            bail!("unexpected positional argument '{p}'");
        }
    }
    match args.cmd.as_str() {
        "train" => dispatch_train(&args, false),
        "train-qa" => dispatch_train(&args, true),
        "trial" => dispatch_trial(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "obs-report" => cmd_obs_report(&args),
        "version" | "--version" | "-V" => cmd_version(),
        #[cfg(feature = "xla")]
        "profile" => cmd_profile(&args),
        #[cfg(feature = "xla")]
        "blocks" => cmd_blocks(&args),
        "memplan" => cmd_memplan(&args),
        #[cfg(feature = "xla")]
        "goldens" => cmd_goldens(&args),
        #[cfg(feature = "xla")]
        "artifacts" => cmd_artifacts(&args),
        #[cfg(not(feature = "xla"))]
        "profile" | "blocks" | "goldens" | "artifacts" => bail!(
            "'{}' executes AOT artifacts through PJRT; rebuild with \
             `--features xla` (see README)",
            args.cmd
        ),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command '{other}'; see `spt help`"),
    }
}

const HELP: &str = "\
spt — SPT sparse fine-tuning coordinator

USAGE: spt <command> [--key value ...]

COMMANDS
  train       fine-tune on the synthetic LM corpus; prints loss curve + PPL
  train-qa    fine-tune + score the 4-choice QA task (MMLU surrogate)
  trial       short trials across full/lora/spt; recommends a mode
  generate    cached-decode generation from a checkpoint (deterministic)
  serve       long-running NDJSON serving daemon over TCP (or --stdio):
              bounded queue, memory-budget admission, graceful drain
  serve-bench continuous-batching decode throughput + latency percentiles
              vs the one-sequence-at-a-time baseline (JSON artifact)
  profile     time+memory for mha/ffn module artifacts (Tables 1/4)
  blocks      throughput + peak memory per Table-2 block (Fig. 8)
  memplan     analytic memory: max-seq search (Table 3), seq sweep (Fig. 9);
              --decode adds KV/code-cache + per-step serving tables
  obs-report  render an --obs-log JSONL into phase-breakdown, sparsity,
              and memory-truth tables; writes BENCH_obs_native.json
  version     print build/host provenance (git sha, rayon threads, CPU)
  goldens     validate artifacts against python-computed goldens
  artifacts   list the AOT manifest

COMMON FLAGS
  --backend B           native (default, no artifacts needed) | pjrt
  --model NAME          spt-tiny | spt-30m | spt-100m | spt-nano[-l2] | spt-mini-64[-l2|-l4]
  --mode MODE           full | lora | spt
  --batch N  --seq N    workload shape (native backend)
  --steps N  --seed N   --eval_every N  --codebook_refresh_every N
  --lr X                AdamW learning rate (native backend)
  --config FILE         TOML run config (keys as above)
  --chunked             scan-of-8 fast dispatch (pjrt backend train)
  --resume FILE         checkpoint to continue training from (train) or to
                        generate/serve from (generate, serve-bench); v2
                        checkpoints verify their model/mode identity
  --save_ckpt FILE      write the final training state (train)
  --ckpt_dir DIR        periodic-checkpoint directory (train; atomic v3
                        writes with per-tensor CRCs)
  --ckpt_every N        checkpoint every N steps into --ckpt_dir (train)
  --auto_resume         resume from the newest valid checkpoint in
                        --ckpt_dir, skipping corrupt files (train; place
                        boolean flags last or use --flag=)
  --obs-log PATH        write a structured observability JSONL (train,
                        generate, serve): per-step phase timings,
                        attention density, expert load, memory truth.
                        Telemetry only reads values already computed, so
                        results are bit-identical with it on or off
  --artifacts_dir DIR   (pjrt backend; default: artifacts)
  SPT_LOG               env: stderr log level (error|warn|info|debug;
                        default info)

GENERATE / SERVE-BENCH FLAGS
  --tokens N            new tokens per sequence (default 32)
  --prompt_len N        synthetic-corpus prompt length (default 8 / 16)
  --temperature X       sampling temperature (omit for greedy)
  --top_k K             restrict sampling to the K best logits
  --requests N          serve-bench: trace size (default 16)
  --max_batch B         serve-bench: in-flight capacity (default 8)
  --page_tokens N       serve-bench: KV pool page size in tokens (default 16)
  --prefill_chunk N     serve-bench: prompt tokens prefilled per step (default 32)

SERVE FLAGS
  --addr HOST:PORT      TCP listen address (default 127.0.0.1:7199)
  --stdio               serve one NDJSON stream on stdin/stdout instead
                        (stdout stays pure protocol; logs go to stderr)
  --max_batch B         in-flight decode capacity (default 8)
  --queue_cap N         admission-queue bound; overflow is rejected with
                        a structured queue_full error (default 64)
  --mem_budget_mb M     size the paged KV pool to fit this budget: pages
                        are charged at admission and credited at retire,
                        so committed cache bytes never exceed it
                        (default: max_batch full-length sequences)
  --page_tokens N       tokens per KV pool page (default 16)
  --prefill_chunk N     prompt tokens prefilled per driver step, so long
                        prompts never stall in-flight decodes (default 32)
  --no_prefix_sharing   disable copy-on-write prompt-prefix page sharing
                        (shared full prompt pages are stored once)
  --deadline_steps N    cancel a request after N decode steps in the
                        driver (deterministic deadline; default off)
  --pid_file PATH       pid/lock file (default <out_dir>/spt-serve.pid);
                        a live holder blocks double-start
  SPT_FAULT_PLAN        env: seeded fault plan, e.g. 'ckpt_write_err:1',
                        'queue_full:2,accept_err:1', or
                        'page_pool_exhausted:1' (see README)

NOTE  the native backend trains the chosen preset's full n_layers-deep
      pre-norm stack end-to-end on the rust sparse substrate, and
      `generate`/`serve-bench` decode on the same substrate with
      per-layer KV + PQ-code caches (same seed -> same tokens at any
      RAYON_NUM_THREADS).  `profile`, `blocks`, `goldens`, and
      `artifacts` always need `--features xla` plus AOT artifacts;
      `memplan` and `help` need nothing.
";

fn dispatch_train(args: &Args, qa: bool) -> Result<()> {
    match backend_choice(args)? {
        BackendChoice::Native => cmd_train(&NativeBackend::new(), args, qa),
        #[cfg(feature = "xla")]
        BackendChoice::Pjrt => {
            let engine = engine_from(args)?;
            cmd_train(&PjrtBackend::new(&engine), args, qa)
        }
    }
}

fn dispatch_trial(args: &Args) -> Result<()> {
    match backend_choice(args)? {
        BackendChoice::Native => cmd_trial(&NativeBackend::new(), args),
        #[cfg(feature = "xla")]
        BackendChoice::Pjrt => {
            let engine = engine_from(args)?;
            cmd_trial(&PjrtBackend::new(&engine), args)
        }
    }
}

#[cfg(feature = "xla")]
fn engine_from(args: &Args) -> Result<Engine> {
    let dir = args.get_or("artifacts_dir", "artifacts");
    Engine::new(&dir)
}

fn cmd_train<B: Backend>(backend: &B, args: &Args, qa: bool) -> Result<()> {
    let rc = args.run_config()?;
    let ckpt_dir = args.get("ckpt_dir").map(std::path::PathBuf::from);
    let ckpt_every = args.usize_or("ckpt_every", 0)?;
    let fault = FaultPlan::from_env()?.map(std::sync::Arc::new);
    if fault.is_some() {
        spt::log_info!("fault plan active (SPT_FAULT_PLAN)");
    }
    let opts = TrainerOptions {
        chunked: args.has("chunked"),
        ckpt_dir: ckpt_dir.clone(),
        ckpt_every,
        fault,
        ..Default::default()
    };
    println!(
        "[spt] {} fine-tuning: model={} mode={} steps={} (backend {}, {})",
        if qa { "QA" } else { "LM" },
        rc.model,
        rc.mode.as_str(),
        rc.steps,
        backend.name(),
        backend.platform()
    );
    let out_dir = rc.out_dir.clone();
    let resume = args.get("resume").map(str::to_string);
    let auto_resume = args.has("auto_resume");
    if qa && (resume.is_some() || auto_resume) {
        bail!("--resume is only supported for `train` (LM); `train-qa` always starts fresh");
    }
    if auto_resume && resume.is_some() {
        bail!("--resume FILE and --auto_resume are mutually exclusive");
    }
    if auto_resume && ckpt_dir.is_none() {
        bail!("--auto_resume needs --ckpt_dir DIR to scan");
    }
    let save_ckpt = args.get("save_ckpt").map(str::to_string);
    let mut trainer = Trainer::new(backend, rc, opts);
    if let Some(path) = args.get("obs-log") {
        trainer.obs = ObsLog::create(path, if qa { "train-qa" } else { "train" })?;
        spt::log_info!("obs log path={path}");
    }
    let report = if qa {
        trainer.train_qa()?
    } else if let Some(path) = resume {
        let (state, meta) = checkpoint::load_tagged(&path)?;
        if let Some(meta) = &meta {
            let rc = trainer.run_config();
            meta.verify(&rc.model, rc.mode)?;
        }
        spt::log_info!("resumed path={path} step={}", state.step.scalar()? as usize);
        trainer.train_from(state)?
    } else if auto_resume {
        let dir = ckpt_dir.clone().unwrap_or_default();
        let latest = if dir.is_dir() { checkpoint::find_latest_valid(&dir)? } else { None };
        match latest {
            Some(latest) => {
                if let Some(meta) = &latest.meta {
                    let rc = trainer.run_config();
                    meta.verify(&rc.model, rc.mode)?;
                }
                spt::log_info!(
                    "auto-resume path={} step={}",
                    latest.path.display(),
                    latest.step
                );
                trainer.train_from(latest.state)?
            }
            None => {
                spt::log_info!(
                    "auto-resume: no valid checkpoint under {}, starting fresh",
                    dir.display()
                );
                trainer.train()?
            }
        }
    } else {
        trainer.train()?
    };
    println!(
        "[spt] {} steps in {} ({:.0} tokens/s), final loss {:.4}",
        report.steps,
        fmt_duration(report.total_secs),
        report.tokens_per_sec,
        report.losses.last().unwrap_or(&f32::NAN)
    );
    for e in &report.evals {
        println!(
            "  step {:>5}: train {:.4}  eval {:.4}  ppl {:.2}  [{}]",
            e.step,
            e.train_loss,
            e.eval_loss,
            e.ppl,
            fmt_duration(e.elapsed_secs)
        );
    }
    if let Some(acc) = report.qa_accuracy {
        println!("[spt] QA accuracy (MMLU surrogate): {:.1}%", acc * 100.0);
    }
    if report.refreshes > 0 {
        println!("[spt] DKM codebook refreshes: {}", report.refreshes);
    }
    if let Some(path) = save_ckpt {
        match &trainer.last_state {
            Some(state) => {
                let rc = trainer.run_config();
                let meta = CkptMeta {
                    model: rc.model.clone(),
                    mode: rc.mode,
                    n_layers: presets::model(&rc.model)?.n_layers.max(1),
                };
                checkpoint::save_tagged(state, &meta, &path)?;
                println!("[spt] checkpoint -> {path} ({}/{})", meta.model, meta.mode.as_str());
            }
            None => println!("[spt] no final state to checkpoint"),
        }
    }
    std::fs::create_dir_all(&out_dir).ok();
    let csv = format!(
        "{out_dir}/loss_{}_{}.csv",
        report.model,
        report.mode.as_str()
    );
    std::fs::write(&csv, report.loss_csv())?;
    println!("[spt] loss curve -> {csv}");
    Ok(())
}

fn cmd_trial<B: Backend>(backend: &B, args: &Args) -> Result<()> {
    let rc = args.run_config()?;
    let steps = args.usize_or("trial_steps", 16)?;
    let tm = TrialManager::new(backend, rc, steps);
    let (results, table) = tm.compare_modes()?;
    println!("{}", table.render());
    if let Some(best) = trial::recommend(&results, 0.10) {
        println!(
            "[spt] recommended: {} ({:.3} s/step at ppl {:.2}, within 10% of best)",
            best.label, best.secs_per_step, best.ppl
        );
    }
    Ok(())
}

/// Load an [`InferModel`] from `--resume`, or fall back to a fresh
/// (untrained) init so the command still demonstrates the decode path.
fn infer_model(args: &Args, rc: &RunConfig) -> Result<InferModel> {
    match args.get("resume") {
        Some(path) => {
            let m = InferModel::from_checkpoint(rc, path)?;
            spt::log_info!(
                "loaded checkpoint path={path} model={} mode={} layers={}",
                rc.model,
                rc.mode.as_str(),
                m.n_layers()
            );
            Ok(m)
        }
        None => {
            spt::log_info!("no --resume: decoding from a fresh (untrained) init");
            let backend = NativeBackend::new();
            let state = backend.init_state(rc)?;
            InferModel::new(rc, state)
        }
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let rc = args.run_config()?;
    let tokens = args.usize_or("tokens", 32)?;
    if tokens == 0 {
        bail!("--tokens must be >= 1");
    }
    let prompt_len = args.usize_or("prompt_len", 8)?.max(1);
    let temperature = match args.get("temperature") {
        Some(v) => Some(v.parse::<f32>().context("--temperature")?),
        None => None,
    };
    let top_k = match args.get("top_k") {
        Some(v) => Some(v.parse::<usize>().context("--top_k")?),
        None => None,
    };
    let sampler = Sampler::from_flags(temperature, top_k)?;
    let model = infer_model(args, &rc)?;
    if prompt_len >= model.max_seq() {
        bail!("--prompt_len {prompt_len} leaves no room under max_seq {}", model.max_seq());
    }
    // Deterministic prompt from the synthetic corpus (this reproduction
    // has no tokenizer): the same --seed gives the same prompt.
    let mut corpus = SyntheticCorpus::new(model.vocab(), 4, 0.85, rc.seed);
    let prompt: Vec<i32> = corpus
        .sequence(prompt_len)
        .iter()
        .map(|&t| t as i32)
        .collect();
    let budget = model.max_seq() - prompt.len();
    let n = tokens.min(budget);
    if n < tokens {
        spt::log_warn!("clamping --tokens {tokens} -> {n} (max_seq {})", model.max_seq());
    }
    let target = prompt.len() + n;
    let mut sess = Session::new(&model, &prompt, target)?;
    let mut rng = Rng::new(rc.seed ^ 0x5A3D_0DE5);
    let t0 = Instant::now();
    let out = sess.generate(&sampler, &mut rng, n)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "[spt] generated {} tokens in {} ({:.1} tok/s, decode cache {})",
        out.len(),
        spt::util::fmt_duration(secs),
        out.len() as f64 / secs.max(1e-9),
        fmt_bytes(sess.cache_bytes() as u64),
    );
    println!("[spt] prompt:  {prompt:?}");
    println!("[spt] output:  {out:?}");
    if let Some(path) = args.get("obs-log") {
        let mut olog = ObsLog::create(path, "generate")?;
        olog.event(
            "gen",
            vec![
                ("prompt_len", Json::Num(prompt.len() as f64)),
                ("new_tokens", Json::Num(out.len() as f64)),
                ("secs", Json::Num(secs)),
                ("tok_s", Json::Num(out.len() as f64 / secs.max(1e-9))),
            ],
        )?;
        // Memory truth: the session's live KV/code cache vs memmodel's
        // analytic prediction at the final sequence length.
        let mc = presets::model(&rc.model)?;
        let observed = sess.cache_bytes() as u64;
        let predicted =
            memmodel::decode_cache_bytes(&mc.block, rc.mode, target, model.n_layers().max(1));
        olog.event(
            "memory",
            vec![
                ("channel", Json::Str("decode_cache".into())),
                ("observed_bytes", Json::Num(observed as f64)),
                ("predicted_bytes", Json::Num(predicted as f64)),
                ("model_err", Json::Num(spt::obs::model_err(observed, predicted))),
            ],
        )?;
        olog.flush()?;
        spt::log_info!("obs log path={path}");
    }
    Ok(())
}

/// `spt serve` — the long-running daemon.  All human-facing logs go to
/// stderr: in `--stdio` mode stdout carries only protocol NDJSON.
fn cmd_serve(args: &Args) -> Result<()> {
    let rc = args.run_config()?;
    let max_batch = args.usize_or("max_batch", 8)?.max(1);
    let queue_cap = args.usize_or("queue_cap", 64)?.max(1);
    let page_tokens = args.usize_or("page_tokens", 16)?.max(1);
    let prefill_chunk = args.usize_or("prefill_chunk", 32)?.max(1);
    let prefix_sharing = !args.has("no_prefix_sharing");
    let mem_budget = match args.get("mem_budget_mb") {
        Some(v) => Some(v.parse::<u64>().context("--mem_budget_mb")? * (1 << 20)),
        None => None,
    };
    let deadline_steps = match args.get("deadline_steps") {
        Some(v) => Some(v.parse::<usize>().context("--deadline_steps")?),
        None => None,
    };
    let temperature = match args.get("temperature") {
        Some(v) => Some(v.parse::<f32>().context("--temperature")?),
        None => None,
    };
    let top_k = match args.get("top_k") {
        Some(v) => Some(v.parse::<usize>().context("--top_k")?),
        None => None,
    };
    let sampler = Sampler::from_flags(temperature, top_k)?;
    let fault = FaultPlan::from_env()?.map(std::sync::Arc::new);
    if fault.is_some() {
        spt::log_info!("fault plan active (SPT_FAULT_PLAN)");
    }
    let model = match args.get("resume") {
        Some(path) => {
            let m = InferModel::from_checkpoint(&rc, path)?;
            spt::log_info!(
                "loaded checkpoint path={path} model={} mode={} layers={}",
                rc.model,
                rc.mode.as_str(),
                m.n_layers()
            );
            m
        }
        None => {
            spt::log_info!("no --resume: serving from a fresh (untrained) init");
            let backend = NativeBackend::new();
            let state = backend.init_state(&rc)?;
            InferModel::new(&rc, state)?
        }
    };
    let pid_path = match args.get("pid_file") {
        Some(p) => std::path::PathBuf::from(p),
        None => std::path::Path::new(&rc.out_dir).join("spt-serve.pid"),
    };
    let lock = PidLock::acquire(&pid_path)?;
    spt::log_info!("pid file path={:?}", lock.path());
    let cfg = DaemonConfig {
        serve: ServeConfig {
            max_batch,
            sampler,
            seed: rc.seed,
            page_tokens,
            prefill_chunk,
            prefix_sharing,
            ..ServeConfig::default()
        },
        queue_cap,
        mem_budget,
        deadline_steps,
        fault,
    };
    let mut daemon = Daemon::new(&model, cfg)?;
    let report = if args.has("stdio") {
        daemon
            .serve_stream(std::io::stdin(), std::io::stdout().lock(), true)?
            .context("stdio stream ended without producing a report")?
    } else {
        let addr = args.get_or("addr", "127.0.0.1:7199");
        daemon.serve_tcp(&addr)?
    };
    spt::log_info!(
        "drained completions={} failed={} decode_steps={} peak_in_flight={}",
        report.completions.len(),
        report.failed,
        report.decode_steps,
        report.peak_in_flight
    );
    if let Some(path) = args.get("obs-log") {
        let mut olog = ObsLog::create(path, "serve")?;
        if let Json::Obj(m) = report.to_json() {
            let fields: Vec<(&str, Json)> =
                m.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
            olog.event("serve_report", fields)?;
        }
        // Memory truth: peak pool pages at the pool's actual per-page
        // allocation vs the analytic page size the budget was planned
        // with ([`memmodel::decode_page_bytes`]).
        let observed = report.peak_pages_in_use as u64 * daemon.observed_page_bytes();
        let predicted = report.peak_pages_in_use as u64 * daemon.planned_page_bytes();
        olog.event(
            "memory",
            vec![
                ("channel", Json::Str("serve_kv_pool".into())),
                ("observed_bytes", Json::Num(observed as f64)),
                ("predicted_bytes", Json::Num(predicted as f64)),
                ("model_err", Json::Num(spt::obs::model_err(observed, predicted))),
            ],
        )?;
        olog.flush()?;
        spt::log_info!("obs log path={path}");
    }
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    let rc = args.run_config()?;
    let n_requests = args.usize_or("requests", 16)?.max(1);
    let prompt_len = args.usize_or("prompt_len", 16)?.max(1);
    let tokens = args.usize_or("tokens", 32)?.max(1);
    let max_batch = args.usize_or("max_batch", 8)?.max(1);
    let page_tokens = args.usize_or("page_tokens", 16)?.max(1);
    let prefill_chunk = args.usize_or("prefill_chunk", 32)?.max(1);
    let model = infer_model(args, &rc)?;
    if prompt_len + tokens > model.max_seq() {
        bail!(
            "--prompt_len {prompt_len} + --tokens {tokens} exceeds max_seq {}",
            model.max_seq()
        );
    }
    // Synthetic request trace, deterministic per seed.
    let mut corpus = SyntheticCorpus::new(model.vocab(), 4, 0.85, rc.seed);
    let reqs: Vec<Request> = (0..n_requests)
        .map(|id| Request {
            id,
            prompt: corpus.sequence(prompt_len).iter().map(|&t| t as i32).collect(),
            max_new_tokens: tokens,
        })
        .collect();
    let run = |mb: usize| -> Result<ServeReport> {
        let cfg = ServeConfig {
            max_batch: mb,
            sampler: Sampler::Greedy,
            seed: rc.seed,
            page_tokens,
            prefill_chunk,
            ..ServeConfig::default()
        };
        let mut driver = ServeDriver::new(&model, cfg)?;
        for r in &reqs {
            driver.submit(r.clone())?;
        }
        driver.run_to_completion()
    };
    println!(
        "[spt] serve-bench: model={} mode={} requests={} prompt={} tokens={} max_batch={}",
        rc.model,
        rc.mode.as_str(),
        n_requests,
        prompt_len,
        tokens,
        max_batch
    );
    let batched = run(max_batch)?;
    let baseline = run(1)?;
    // Overload probe: capacity 2 with the whole trace queued up front —
    // the queue-wait percentiles quantify time spent waiting for a slot.
    let overload = run(2.min(max_batch))?;
    // Continuous batching must not change what any request generates.
    for (b, s) in batched.completions.iter().zip(&baseline.completions) {
        if b.tokens != s.tokens {
            bail!("request {}: batched and serial decode disagree", b.id);
        }
    }
    let speedup = batched.tokens_per_sec / baseline.tokens_per_sec.max(1e-9);
    let mut table = spt::metrics::Table::new(
        "Continuous batching vs one-sequence-at-a-time (native decode)",
        &["Config", "tok/s", "steps", "p50 lat", "p99 lat", "queue p50", "queue p99", "speedup"],
    );
    for (name, r, s) in [
        ("batched", &batched, format!("{speedup:.2}x")),
        ("overload (batch=2)", &overload, String::new()),
        ("baseline (batch=1)", &baseline, "1.00x".into()),
    ] {
        table.row(&[
            name.to_string(),
            format!("{:.0}", r.tokens_per_sec),
            r.decode_steps.to_string(),
            spt::util::fmt_duration(r.latency_percentile(50.0)),
            spt::util::fmt_duration(r.latency_percentile(99.0)),
            spt::util::fmt_duration(r.queue_wait_percentile(50.0)),
            spt::util::fmt_duration(r.queue_wait_percentile(99.0)),
            s,
        ]);
    }
    println!("{}", table.render());

    // Shared-prefix capacity probe: every request carries the same
    // prompt, the pool is fixed (from --mem_budget_mb when given, else
    // two dense requests' worth of pages), and the trace runs twice —
    // prefix sharing on vs off.  Sharing stores the common prompt's
    // full pages once, so the same memory sustains more concurrent
    // streams at bit-identical output.
    let (cap_pt, cap_prompt, cap_new) =
        if model.max_seq() >= 112 { (16usize, 96usize, 16usize) } else { (8, 48, 8) };
    let need_pages = (cap_prompt + cap_new).div_ceil(cap_pt);
    let pool_pages = match args.get("mem_budget_mb") {
        Some(v) => {
            let budget = v.parse::<u64>().context("--mem_budget_mb")? * (1 << 20);
            let mc = spt::config::presets::model(&rc.model)?;
            let pb = spt::memmodel::decode_page_bytes(
                &mc.block,
                rc.mode,
                cap_pt,
                mc.n_layers.max(1),
            );
            let pages = spt::memmodel::pool_pages_for_budget(budget, pb);
            if pages < need_pages {
                bail!(
                    "--mem_budget_mb {v} holds {pages} pages; the capacity probe \
                     needs at least {need_pages}"
                );
            }
            pages
        }
        None => 2 * need_pages,
    };
    let shared_prompt: Vec<i32> =
        corpus.sequence(cap_prompt).iter().map(|&t| t as i32).collect();
    let cap_reqs: Vec<Request> = (0..8)
        .map(|id| Request { id, prompt: shared_prompt.clone(), max_new_tokens: cap_new })
        .collect();
    let warm_steps = cap_prompt.div_ceil(2 * cap_pt) + 1;
    let capacity_run = |sharing: bool| -> Result<ServeReport> {
        let cfg = ServeConfig {
            max_batch: 8,
            sampler: Sampler::Greedy,
            seed: rc.seed,
            page_tokens: cap_pt,
            prefill_chunk: 2 * cap_pt,
            prefix_sharing: sharing,
            pool_pages: Some(pool_pages),
            ..ServeConfig::default()
        };
        let mut driver = ServeDriver::new(&model, cfg)?;
        driver.submit(cap_reqs[0].clone())?;
        for _ in 0..warm_steps {
            driver.step()?;
        }
        for r in &cap_reqs[1..] {
            driver.submit(r.clone())?;
        }
        driver.run_to_completion()
    };
    let shared = capacity_run(true)?;
    let dense = capacity_run(false)?;
    for (a, b) in shared.completions.iter().zip(&dense.completions) {
        if a.tokens != b.tokens {
            bail!("request {}: prefix sharing changed the tokens", a.id);
        }
    }
    let streams_ratio = shared.peak_in_flight as f64 / dense.peak_in_flight.max(1) as f64;
    println!(
        "[spt] capacity: {pool_pages} pages sustain {} shared-prefix streams vs {} dense \
         ({streams_ratio:.2}x), prefix hit rate {:.2}, queue-wait p50/p99 {}/{}",
        shared.peak_in_flight,
        dense.peak_in_flight,
        shared.prefix_hit_rate,
        spt::util::fmt_duration(shared.queue_wait_percentile(50.0)),
        spt::util::fmt_duration(shared.queue_wait_percentile(99.0)),
    );
    let mut cap = BTreeMap::new();
    cap.insert("page_tokens".into(), Json::Num(cap_pt as f64));
    cap.insert("pool_pages".into(), Json::Num(pool_pages as f64));
    cap.insert("prompt_len".into(), Json::Num(cap_prompt as f64));
    cap.insert("max_new_tokens".into(), Json::Num(cap_new as f64));
    cap.insert("shared".into(), shared.to_json());
    cap.insert("dense".into(), dense.to_json());
    cap.insert("streams_ratio".into(), Json::Num(streams_ratio));

    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("decode_native".into()));
    top.insert("model".into(), Json::Str(rc.model.clone()));
    top.insert("mode".into(), Json::Str(rc.mode.as_str().into()));
    top.insert("requests".into(), Json::Num(n_requests as f64));
    top.insert("prompt_len".into(), Json::Num(prompt_len as f64));
    top.insert("max_new_tokens".into(), Json::Num(tokens as f64));
    top.insert("max_batch".into(), Json::Num(max_batch as f64));
    top.insert("batched".into(), batched.to_json());
    top.insert("overload".into(), overload.to_json());
    top.insert("baseline".into(), baseline.to_json());
    top.insert("speedup".into(), Json::Num(speedup));
    top.insert("capacity".into(), Json::Obj(cap));
    top.insert("provenance".into(), spt::util::provenance::provenance());
    let dir = std::path::Path::new("bench_out");
    std::fs::create_dir_all(dir).ok();
    let path = dir.join("BENCH_decode_native.json");
    std::fs::write(&path, format!("{}\n", Json::Obj(top)))?;
    println!("[spt] continuous batching speedup: {speedup:.2}x -> {}", path.display());
    Ok(())
}

/// `spt obs-report <run.jsonl>` — render an `--obs-log` capture as the
/// phase/sparsity/memory tables and emit the benchdiff artifact.
fn cmd_obs_report(args: &Args) -> Result<()> {
    let path = args
        .pos
        .first()
        .map(String::as_str)
        .or_else(|| args.get("log"))
        .context("usage: spt obs-report <run.jsonl>")?;
    if let Some(extra) = args.pos.get(1) {
        bail!("unexpected extra argument '{extra}' (one log per report)");
    }
    let summary = spt::obs::report::summarize(path)?;
    print!("{}", spt::obs::report::render(&summary));
    let dir = std::path::Path::new("bench_out");
    std::fs::create_dir_all(dir).ok();
    let out = dir.join("BENCH_obs_native.json");
    std::fs::write(&out, format!("{}\n", spt::obs::report::bench_json(&summary)))?;
    println!("[spt] obs bench -> {}", out.display());
    Ok(())
}

/// `spt version` — the provenance stamp as one line (what `status` and
/// BENCH artifacts carry).
fn cmd_version() -> Result<()> {
    let p = spt::util::provenance::provenance();
    println!(
        "spt {} git_sha={} rayon_threads={} cpu={}",
        env!("CARGO_PKG_VERSION"),
        p.get("git_sha").as_str().unwrap_or("unknown"),
        p.get("rayon_threads").as_usize().unwrap_or(0),
        p.get("cpu_model").as_str().unwrap_or("unknown"),
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_profile(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let cfg = args.get_or("block", "opt-2048");
    let warmup = args.usize_or("warmup", 1)?;
    let samples = args.usize_or("samples", 5)?;
    let mut table = Table::new(
        &format!("Module profile — {cfg} (paper Tables 1/4 shape)"),
        &["Module", "Method", "Peak Mem (model @bs16,seq512)", "Duration (this testbed)"],
    );
    for (kind, variants) in [
        ("mha", vec!["full", "lora", "spt_l4", "spt_l8"]),
        ("ffn", vec!["full", "lora", "spt_b34", "spt_b12"]),
    ] {
        for v in variants {
            let name = format!("{kind}_{cfg}_{v}");
            if engine.manifest().get(&name).is_err() {
                continue;
            }
            let row = prof::profile_module(&engine, kind, &cfg, v, warmup, samples)?;
            table.row(&[
                kind.to_uppercase(),
                v.to_string(),
                fmt_bytes(row.model_mem_bytes),
                row.time.summary(),
            ]);
        }
    }
    println!("{}", table.render());
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_blocks(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let warmup = args.usize_or("warmup", 1)?;
    let samples = args.usize_or("samples", 3)?;
    let blocks = args.get_or(
        "blocks",
        "opt-1024,opt-2048,opt-2560,llama-2560,llama-4096",
    );
    let mut table = Table::new(
        "Per-block fine-tuning throughput & peak memory (Fig. 8 shape)",
        &["Block", "Mode", "tokens/s", "vs full", "Peak Mem @bs16,seq512", "vs full"],
    );
    for cfg_name in blocks.split(',').filter(|s| !s.is_empty()) {
        let mut base_tps = None;
        let mut base_mem = None;
        for mode in Mode::ALL {
            let name = format!("block_step_{cfg_name}_{}", mode.as_str());
            if engine.manifest().get(&name).is_err() {
                continue;
            }
            let row = prof::profile_block(&engine, cfg_name, mode, warmup, samples)?;
            if mode == Mode::Full {
                base_tps = Some(row.tokens_per_sec);
                base_mem = Some(row.model_mem_bytes);
            }
            table.row(&[
                cfg_name.to_string(),
                mode.as_str().to_string(),
                format!("{:.0}", row.tokens_per_sec),
                base_tps
                    .map(|b| format!("{:.2}x", row.tokens_per_sec / b))
                    .unwrap_or_default(),
                fmt_bytes(row.model_mem_bytes),
                base_mem
                    .map(|b| format!("{:.0}%", 100.0 * row.model_mem_bytes as f64 / b as f64))
                    .unwrap_or_default(),
            ]);
        }
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_memplan(args: &Args) -> Result<()> {
    let cfg_name = args.get_or("block", "opt-2560");
    let cfg = presets::block(&cfg_name)?;
    let batch = args.usize_or("batch", 16)?;
    let layers = args.usize_or("layers", 32)?;
    let vocab = args.usize_or("vocab", 50272)?;
    let budget_gb: f64 = args.get_or("budget-gb", "24").parse()?;
    let budget = (budget_gb * (1u64 << 30) as f64) as u64;

    let mut t1 = Table::new(
        &format!(
            "Max sequence length before OOM — {cfg_name}, {layers} layers, {budget_gb} GB (Table 3 protocol)"
        ),
        &["System", "Max Length"],
    );
    for mode in Mode::ALL {
        let len = memmodel::max_seq_under_budget(&cfg, mode, batch, layers, vocab, budget, 128);
        t1.row(&[mode.as_str().to_string(), len.to_string()]);
    }
    println!("{}", t1.render());

    let mut t2 = Table::new(
        &format!(
            "Peak block memory vs sequence length — {cfg_name}, batch {batch} (Fig. 9 series)"
        ),
        &["Seq", "Full", "LoRA", "SPT"],
    );
    for seq in [128usize, 256, 512, 1024, 2048] {
        let wl = memmodel::BlockWorkload { batch, seq };
        let cells: Vec<String> = Mode::ALL
            .iter()
            .map(|&m| fmt_bytes(memmodel::block_peak(&cfg, m, &wl).peak_bytes()))
            .collect();
        t2.row(&[seq.to_string(), cells[0].clone(), cells[1].clone(), cells[2].clone()]);
    }
    println!("{}", t2.render());

    if args.has("breakdown") {
        for mode in Mode::ALL {
            let wl = memmodel::BlockWorkload { batch, seq: 512 };
            println!("--- {} breakdown (bs {batch}, seq 512) ---", mode.as_str());
            println!("{}", memmodel::block_peak(&cfg, mode, &wl).render());
        }
    }

    if args.has("decode") {
        // Decode-time serving model: per-sequence KV/code caches, the
        // per-step attention state (dense O(n) vs sparse O(L) — the
        // Fig. 9 argument applied to the decode hot loop), and the peak
        // with `batch` sequences in flight.
        let mut t3 = Table::new(
            &format!(
                "Decode-time memory — {cfg_name}, {layers} layers, {batch} sequences in flight"
            ),
            &[
                "Seq",
                "KV cache/seq (dense)",
                "KV+codes/seq (spt)",
                "Step state (dense)",
                "Step state (spt)",
                "Peak @batch (spt)",
            ],
        );
        for seq in [128usize, 256, 512, 1024, 2048] {
            t3.row(&[
                seq.to_string(),
                fmt_bytes(memmodel::decode_cache_bytes(&cfg, Mode::Lora, seq, layers)),
                fmt_bytes(memmodel::decode_cache_bytes(&cfg, Mode::Spt, seq, layers)),
                fmt_bytes(memmodel::decode_step_state_bytes(&cfg, Mode::Lora, seq)),
                fmt_bytes(memmodel::decode_step_state_bytes(&cfg, Mode::Spt, seq)),
                fmt_bytes(memmodel::decode_peak(&cfg, Mode::Spt, batch, seq, layers, vocab)),
            ]);
        }
        println!("{}", t3.render());

        // Paged-pool capacity planning: the serving pool's page granule
        // (16-token pages), pages per request at target length, and how
        // many full-length streams a given --mem_budget_mb sustains —
        // the arithmetic `spt serve` runs at startup to size its pool.
        let page_tokens = 16usize;
        let page = memmodel::decode_page_bytes(&cfg, Mode::Spt, page_tokens, layers);
        let mut t4 = Table::new(
            &format!(
                "Paged KV pool — {cfg_name}, {layers} layers, {page_tokens}-token pages \
                 ({}/page, spt mode)",
                fmt_bytes(page)
            ),
            &["Target len", "Pages/request", "Bytes/request", "Streams @ 256 MB", "Streams @ 1 GB"],
        );
        for target in [128usize, 256, 512, 1024, 2048] {
            let pages = memmodel::decode_request_pages(target, page_tokens);
            let per_req = pages as u64 * page;
            let streams = |budget: u64| {
                (memmodel::pool_pages_for_budget(budget, page) / pages.max(1)).to_string()
            };
            t4.row(&[
                target.to_string(),
                pages.to_string(),
                fmt_bytes(per_req),
                streams(256 << 20),
                streams(1 << 30),
            ]);
        }
        println!("{}", t4.render());
        println!(
            "[spt] serve sizes its pool as --mem_budget_mb / page bytes; prefix sharing \
             stores common full prompt pages once, so shared-prompt streams cost only \
             their unshared tail pages (see ServeReport's prefix_hit_rate)."
        );
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_goldens(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts_dir", "artifacts");
    let engine = engine_from(args)?;
    let goldens = spt::runtime::goldens::load_goldens(&dir)?;
    let mut worst = 0.0f32;
    for g in &goldens {
        let diff = spt::runtime::goldens::check_artifact(&engine, g, 1e-3)?;
        println!("  {:<28} max|diff| = {diff:.2e}", g.name);
        worst = worst.max(diff);
    }
    println!("[spt] {} goldens OK (worst {worst:.2e})", goldens.len());
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_artifacts(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let mut table = Table::new("AOT artifacts", &["Name", "Inputs", "Outputs", "In bytes", "Kind"]);
    for (name, spec) in &engine.manifest().artifacts {
        table.row(&[
            name.clone(),
            spec.inputs.len().to_string(),
            spec.outputs.len().to_string(),
            fmt_bytes(spec.input_bytes() as u64),
            spec.meta_str("kind").unwrap_or("?").to_string(),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
