//! The fine-tuning trainer: the L3 hot loop.
//!
//! Drives the AOT train-step executable over synthetic mini-batches,
//! schedules the DKM codebook refresh (paper §5.1: every ~20 mini-batches,
//! spt mode only), evaluates held-out loss (PPL) and QA accuracy (the
//! MMLU surrogate), and records step timing + loss curves.
//!
//! Two dispatch paths (see EXPERIMENTS.md §Perf):
//! * per-step: one `train_step` execution per mini-batch;
//! * chunked: `train_chunk8` scans 8 microbatches inside one executable,
//!   amortizing host<->device marshalling of the state.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::state::TrainState;
use crate::config::{Mode, RunConfig};
use crate::data::{Batcher, QaTaskGen, SyntheticCorpus};
use crate::metrics::Counters;
use crate::runtime::{Engine, HostTensor};

/// Trainer options beyond the run config.
#[derive(Debug, Clone)]
pub struct TrainerOptions {
    /// Use the chunked (scan-of-8) dispatch path when available.
    pub chunked: bool,
    /// Held-out eval batches per eval point.
    pub eval_batches: usize,
    /// Bigram structure of the synthetic corpus.
    pub corpus_branch: usize,
    pub corpus_bigram_p: f64,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            chunked: false,
            eval_batches: 4,
            corpus_branch: 4,
            corpus_bigram_p: 0.85,
        }
    }
}

/// One eval point on the loss curve.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    pub train_loss: f32,
    pub eval_loss: f32,
    pub ppl: f32,
    pub elapsed_secs: f64,
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub model: String,
    pub mode: Mode,
    pub steps: usize,
    pub losses: Vec<f32>,
    pub evals: Vec<EvalPoint>,
    pub total_secs: f64,
    pub tokens_per_sec: f64,
    pub qa_accuracy: Option<f32>,
    pub refreshes: usize,
}

impl TrainReport {
    /// Final perplexity (paper's Wikitext metric).
    pub fn final_ppl(&self) -> f32 {
        self.evals.last().map(|e| e.ppl).unwrap_or(f32::NAN)
    }

    /// Loss curve as CSV for EXPERIMENTS.md.
    pub fn loss_csv(&self) -> String {
        let mut s = String::from("step,train_loss\n");
        for (i, l) in self.losses.iter().enumerate() {
            s.push_str(&format!("{},{}\n", i + 1, l));
        }
        s
    }
}

/// The trainer itself.
pub struct Trainer<'e> {
    engine: &'e Engine,
    rc: RunConfig,
    opts: TrainerOptions,
    pub counters: Counters,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, rc: RunConfig, opts: TrainerOptions) -> Self {
        Trainer { engine, rc, opts, counters: Counters::new() }
    }

    fn artifact(&self, entry: &str) -> String {
        format!("{entry}_{}_{}", self.rc.model, self.rc.mode.as_str())
    }

    /// Workload shape (batch, seq) baked into the train-step artifact.
    fn workload(&self) -> Result<(usize, usize)> {
        let spec = self.engine.spec(&self.artifact("train_step"))?;
        let batch = spec.meta_usize("batch").context("meta.batch")?;
        let seq = spec.meta_usize("seq").context("meta.seq")?;
        Ok((batch, seq))
    }

    fn vocab(&self) -> Result<usize> {
        let spec = self.engine.spec(&self.artifact("train_step"))?;
        spec.meta_usize("vocab").context("meta.vocab")
    }

    /// Build the LM batcher over a synthetic corpus pool.
    fn make_batcher(&self, batch: usize, seq: usize, pool: usize) -> Result<Batcher> {
        let vocab = self.vocab()?;
        let mut corpus = SyntheticCorpus::new(
            vocab,
            self.opts.corpus_branch,
            self.opts.corpus_bigram_p,
            self.rc.seed,
        );
        let mut toks = Vec::with_capacity(pool);
        let mut tgts = Vec::with_capacity(pool);
        for _ in 0..pool {
            let (x, y) = corpus.lm_pair(seq);
            toks.push(x);
            tgts.push(y);
        }
        Ok(Batcher::new(toks, tgts, batch, self.rc.seed ^ 0xBA7C4))
    }

    /// Run LM fine-tuning for `rc.steps` mini-batches.
    pub fn train(&mut self) -> Result<TrainReport> {
        let (batch, seq) = self.workload()?;
        let step_name = self.artifact("train_step");
        let chunk_name = format!(
            "train_chunk8_{}_{}", self.rc.model, self.rc.mode.as_str()
        );
        let use_chunk = self.opts.chunked
            && self.engine.manifest().get(&chunk_name).is_ok();
        let mut state = TrainState::init(
            self.engine,
            &self.artifact("model_init"),
            self.rc.seed as i32,
        )?;
        state.check_against(self.engine.spec(&step_name)?)?;
        let pool = (self.rc.steps * batch).clamp(batch * 4, 4096);
        let mut batcher = self.make_batcher(batch, seq, pool)?;
        let mut eval_batcher = self.make_batcher(batch, seq, batch * 8)?;

        let mut losses = Vec::with_capacity(self.rc.steps);
        let mut evals = Vec::new();
        let mut refreshes = 0usize;
        let t0 = Instant::now();
        let mut step_i = 0usize;
        while step_i < self.rc.steps {
            if use_chunk && step_i + 8 <= self.rc.steps {
                // ---- chunked dispatch: 8 microbatches, one execution ----
                let mut toks = Vec::with_capacity(8 * batch * seq);
                let mut tgts = Vec::with_capacity(8 * batch * seq);
                for _ in 0..8 {
                    let b = batcher.next();
                    toks.extend_from_slice(&b.tokens);
                    tgts.extend_from_slice(&b.targets);
                }
                let tk = HostTensor::i32(vec![8, batch, seq], toks);
                let tg = HostTensor::i32(vec![8, batch, seq], tgts);
                let inputs = state.step_inputs(tk, tg);
                let out = self.engine.run(&chunk_name, &inputs)?;
                let loss_vec = state.absorb_step_outputs(out)?;
                losses.extend(loss_vec.as_f32()?.iter().copied());
                step_i += 8;
            } else {
                // ---- per-step dispatch ----
                let b = batcher.next();
                let tk = HostTensor::i32(vec![batch, seq], b.tokens);
                let tg = HostTensor::i32(vec![batch, seq], b.targets);
                let inputs = state.step_inputs(tk, tg);
                let out = self.engine.run(&step_name, &inputs)?;
                let loss = state.absorb_step_outputs(out)?.scalar()?;
                losses.push(loss);
                step_i += 1;
            }
            self.counters.add("steps", 1);
            self.counters.add("tokens", (batch * seq) as u64);

            // DKM codebook refresh (paper §5.1), spt only.
            if self.rc.mode == Mode::Spt
                && self.rc.codebook_refresh_every > 0
                && step_i % self.rc.codebook_refresh_every == 0
            {
                self.refresh_codebooks(&mut state, &mut batcher)?;
                refreshes += 1;
            }

            if self.rc.eval_every > 0 && step_i % self.rc.eval_every == 0 {
                let eval_loss = self.eval_loss(&state, &mut eval_batcher)?;
                evals.push(EvalPoint {
                    step: step_i,
                    train_loss: *losses.last().unwrap(),
                    eval_loss,
                    ppl: eval_loss.exp(),
                    elapsed_secs: t0.elapsed().as_secs_f64(),
                });
            }
        }
        let total = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            model: self.rc.model.clone(),
            mode: self.rc.mode,
            steps: losses.len(),
            tokens_per_sec: (losses.len() * batch * seq) as f64 / total,
            losses,
            evals,
            total_secs: total,
            qa_accuracy: None,
            refreshes,
        })
    }

    /// Mean eval loss over held-out batches.
    pub fn eval_loss(&self, state: &TrainState, batcher: &mut Batcher) -> Result<f32> {
        let name = self.artifact("eval_loss");
        let (batch, seq) = self.workload()?;
        let mut total = 0.0f32;
        for _ in 0..self.opts.eval_batches {
            let b = batcher.next();
            let mut inputs = state.params.clone();
            inputs.push(HostTensor::i32(vec![batch, seq], b.tokens));
            inputs.push(HostTensor::i32(vec![batch, seq], b.targets));
            let out = self.engine.run(&name, &inputs)?;
            total += out[0].scalar()?;
        }
        Ok(total / self.opts.eval_batches as f32)
    }

    /// Run the whole-model DKM refresh and patch codebook leaves.
    fn refresh_codebooks(&self, state: &mut TrainState, batcher: &mut Batcher) -> Result<()> {
        let name = format!("codebook_refresh_{}", self.rc.model);
        if self.engine.manifest().get(&name).is_err() {
            return Ok(()); // refresh artifact not built; skip silently
        }
        let (batch, seq) = self.workload()?;
        let b = batcher.next();
        let mut inputs = state.params.clone();
        inputs.push(HostTensor::i32(vec![batch, seq], b.tokens));
        let out = self.engine.run(&name, &inputs)?;
        if out.len() != 2 {
            bail!("codebook refresh returned {} outputs", out.len());
        }
        let q_leaves = state.find_leaves("pq_q");
        let k_leaves = state.find_leaves("pq_k");
        if q_leaves.len() != 1 || k_leaves.len() != 1 {
            bail!(
                "expected exactly one stacked pq_q/pq_k leaf, found {}/{}",
                q_leaves.len(),
                k_leaves.len()
            );
        }
        state.set_leaf(q_leaves[0], out[0].clone())?;
        state.set_leaf(k_leaves[0], out[1].clone())?;
        Ok(())
    }

    /// QA fine-tune + accuracy eval (Table 3's MMLU surrogate).
    pub fn train_qa(&mut self) -> Result<TrainReport> {
        let (batch, seq) = self.workload()?;
        let vocab = self.vocab()?;
        let step_name = self.artifact("train_step");
        let qa_name = self.artifact("qa_logits");
        let mut state = TrainState::init(
            self.engine,
            &self.artifact("model_init"),
            self.rc.seed as i32,
        )?;
        let mut gen = QaTaskGen::new(vocab, 64, self.rc.seed);
        let mut losses = Vec::with_capacity(self.rc.steps);
        let t0 = Instant::now();
        for step_i in 1..=self.rc.steps {
            let qb = gen.batch(batch, seq);
            let toks: Vec<i32> =
                qb.tokens.iter().flatten().map(|&t| t as i32).collect();
            let tgts: Vec<i32> =
                qb.targets.iter().flatten().map(|&t| t as i32).collect();
            let inputs = state.step_inputs(
                HostTensor::i32(vec![batch, seq], toks),
                HostTensor::i32(vec![batch, seq], tgts),
            );
            let out = self.engine.run(&step_name, &inputs)?;
            losses.push(state.absorb_step_outputs(out)?.scalar()?);
            if self.rc.mode == Mode::Spt
                && self.rc.codebook_refresh_every > 0
                && step_i % self.rc.codebook_refresh_every == 0
            {
                // reuse LM refresh machinery with QA tokens
                let name = format!("codebook_refresh_{}", self.rc.model);
                if self.engine.manifest().get(&name).is_ok() {
                    let qb2 = gen.batch(batch, seq);
                    let toks2: Vec<i32> =
                        qb2.tokens.iter().flatten().map(|&t| t as i32).collect();
                    let mut inputs = state.params.clone();
                    inputs.push(HostTensor::i32(vec![batch, seq], toks2));
                    let out = self.engine.run(&name, &inputs)?;
                    if out.len() == 2 {
                        let q = state.find_leaves("pq_q");
                        let k = state.find_leaves("pq_k");
                        state.set_leaf(q[0], out[0].clone())?;
                        state.set_leaf(k[0], out[1].clone())?;
                    }
                }
            }
        }
        // Held-out accuracy.
        let mut correct_weighted = 0.0f32;
        let eval_rounds = 8;
        for _ in 0..eval_rounds {
            let qb = gen.batch(batch, seq);
            let toks: Vec<i32> =
                qb.tokens.iter().flatten().map(|&t| t as i32).collect();
            let mut inputs = state.params.clone();
            inputs.push(HostTensor::i32(vec![batch, seq], toks));
            let out = self.engine.run(&qa_name, &inputs)?;
            let logits = out[0].as_f32()?;
            let rows: Vec<Vec<f32>> = (0..batch)
                .map(|i| logits[i * 4..(i + 1) * 4].to_vec())
                .collect();
            correct_weighted += gen.accuracy(&qb, &rows);
        }
        let total = t0.elapsed().as_secs_f64();
        Ok(TrainReport {
            model: self.rc.model.clone(),
            mode: self.rc.mode,
            steps: losses.len(),
            tokens_per_sec: (losses.len() * batch * seq) as f64 / total,
            losses,
            evals: Vec::new(),
            total_secs: total,
            qa_accuracy: Some(correct_weighted / eval_rounds as f32),
            refreshes: 0,
        })
    }
}
