//! Memory-model validation against the paper's published numbers:
//! the *shape* (orderings, ratios, crossovers) of Tables 1, 3, 4 and
//! Figs. 8b, 9 must hold.

use spt::config::{presets, Mode};
use spt::memmodel::{block_peak, max_seq_under_budget, module_peak, BlockWorkload, Module};

fn wl() -> BlockWorkload {
    BlockWorkload { batch: 16, seq: 512 }
}

#[test]
fn table1_ratios() {
    // Paper Table 1 (OPT-2048, bs16 seq512):
    //   Full: MHA 3.2 GB, FFN 1.3 GB  -> MHA/FFN ~ 2.5
    //   SPT:  MHA 0.9 GB (3.6x less than Full's MHA)
    let cfg = presets::block("opt-2048").unwrap();
    let full_mha = module_peak(&cfg, Mode::Full, &wl(), Module::Mha) as f64;
    let full_ffn = module_peak(&cfg, Mode::Full, &wl(), Module::Ffn) as f64;
    let spt_mha = module_peak(&cfg, Mode::Spt, &wl(), Module::Mha) as f64;
    assert!(full_mha / full_ffn > 1.5, "MHA/FFN = {}", full_mha / full_ffn);
    let reduction = full_mha / spt_mha;
    assert!(
        (2.0..8.0).contains(&reduction),
        "Full-MHA / SPT-MHA = {reduction} (paper ~3.6x)"
    );
}

#[test]
fn table4_sparsity_ladder() {
    // Paper Table 4 (OPT-2048): LoRA 2626 MB > SPT(1/4) 1784 > SPT(1/8) 1123.
    let base = presets::block("opt-2048").unwrap();
    let lora = module_peak(&base, Mode::Lora, &wl(), Module::Mha);
    let mut c4 = base.clone();
    c4.sparsity.mha_den = 4;
    let mut c8 = base.clone();
    c8.sparsity.mha_den = 8;
    let m4 = module_peak(&c4, Mode::Spt, &wl(), Module::Mha);
    let m8 = module_peak(&c8, Mode::Spt, &wl(), Module::Mha);
    assert!(lora > m4 && m4 > m8, "{lora} > {m4} > {m8} violated");
    // paper reductions: 1/4 -> 32%, 1/8 -> 57% vs LoRA.
    let red8 = 1.0 - m8 as f64 / lora as f64;
    assert!(red8 > 0.35, "1/8 reduction {red8} (paper 0.57)");
}

#[test]
fn fig8b_memory_percentages() {
    // Paper: SPT uses 50-73% of Full's peak across the 5 blocks, and the
    // largest reduction is on opt-1024 (MHA-dominated).
    let mut ratios = Vec::new();
    for cfg in presets::paper_blocks() {
        let full = block_peak(&cfg, Mode::Full, &wl()).peak_bytes() as f64;
        let spt = block_peak(&cfg, Mode::Spt, &wl()).peak_bytes() as f64;
        ratios.push((cfg.name.clone(), spt / full));
    }
    for (name, r) in &ratios {
        assert!((0.2..0.95).contains(r), "{name}: SPT/Full = {r}");
    }
    let opt1024 = ratios.iter().find(|(n, _)| n == "opt-1024").unwrap().1;
    let llama4096 = ratios.iter().find(|(n, _)| n == "llama-4096").unwrap().1;
    assert!(
        opt1024 < llama4096,
        "opt-1024 ({opt1024}) should see the largest relative saving vs llama-4096 ({llama4096})"
    );
}

#[test]
fn fig9_quadratic_vs_linear_growth() {
    let cfg = presets::block("opt-2048").unwrap();
    // Dense (LoRA) attention memory grows ~4x when seq doubles at large n;
    // SPT grows much slower per the nL (L = n/8) + linear activations mix.
    let peak = |mode, seq| {
        block_peak(&cfg, mode, &BlockWorkload { batch: 16, seq }).peak_bytes() as f64
    };
    let lora_growth = peak(Mode::Lora, 2048) / peak(Mode::Lora, 1024);
    let spt_growth = peak(Mode::Spt, 2048) / peak(Mode::Spt, 1024);
    assert!(lora_growth > 2.5, "dense growth {lora_growth}");
    assert!(spt_growth < lora_growth, "{spt_growth} !< {lora_growth}");
    // And the SPT/LoRA ratio improves with n (paper: "more substantial
    // memory savings for longer sequences").
    let ratio_512 = peak(Mode::Spt, 512) / peak(Mode::Lora, 512);
    let ratio_2048 = peak(Mode::Spt, 2048) / peak(Mode::Lora, 2048);
    assert!(ratio_2048 < ratio_512, "{ratio_2048} !< {ratio_512}");
}

#[test]
fn table3_max_length_ladder() {
    // Paper Table 3 @ OPT-2.7B (opt-2560 blocks, 32 layers, 24 GB):
    // Full 256 < LoRA 512 < SPT 768.  Exact values depend on DeepSpeed
    // internals; the ladder and rough factors must hold.
    let cfg = presets::block("opt-2560").unwrap();
    let budget = 24u64 << 30;
    let f = max_seq_under_budget(&cfg, Mode::Full, 16, 32, 50272, budget, 128);
    let l = max_seq_under_budget(&cfg, Mode::Lora, 16, 32, 50272, budget, 128);
    let s = max_seq_under_budget(&cfg, Mode::Spt, 16, 32, 50272, budget, 128);
    assert!(f >= 128, "full = {f}");
    assert!(l >= f, "lora {l} < full {f}");
    assert!(s as f64 >= 1.4 * l as f64, "spt {s} not >= 1.4x lora {l}");
    assert!(s as f64 >= 1.7 * f as f64, "spt {s} not ~2x full {f}"); // paper: 3.0x (OPT) / 2.5x (LLaMA); model: ~1.8x — ladder + factor >1.7 preserved
}

#[test]
fn batch_size_invariance_of_relative_saving() {
    // Paper §6.2: "varying the batch size did not impact the speedup" and
    // memory savings are per-sequence.  The SPT/LoRA ratio at seq 512 must
    // be stable across batch sizes (within a few points).
    let cfg = presets::block("opt-2048").unwrap();
    let ratio = |batch| {
        let wlb = BlockWorkload { batch, seq: 512 };
        block_peak(&cfg, Mode::Spt, &wlb).peak_bytes() as f64
            / block_peak(&cfg, Mode::Lora, &wlb).peak_bytes() as f64
    };
    let r4 = ratio(4);
    let r64 = ratio(64);
    assert!((r4 - r64).abs() < 0.15, "ratio drift: {r4} vs {r64}"); // batch-independent persistent bytes shift the ratio slightly at tiny batch
}
