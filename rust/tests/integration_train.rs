//! Coordinator integration: full fine-tuning loops over the AOT
//! artifacts (spt-tiny), checkpoints, trials.  Needs `--features xla`.
#![cfg(feature = "xla")]

use spt::config::{Mode, RunConfig};
use spt::coordinator::{checkpoint, PjrtBackend, TrainState, Trainer, TrainerOptions};
use spt::runtime::{Engine, HostTensor};

fn engine() -> Option<Engine> {
    let dir = std::env::var("SPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(&dir).expect("engine"))
}

fn rc(mode: Mode, steps: usize) -> RunConfig {
    let mut rc = RunConfig::default();
    rc.model = "spt-tiny".into();
    rc.mode = mode;
    rc.steps = steps;
    rc.eval_every = steps;
    rc.codebook_refresh_every = 6;
    rc.artifacts_dir =
        std::env::var("SPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    rc
}

#[test]
fn spt_training_reduces_loss() {
    let Some(engine) = engine() else { return };
    let backend = PjrtBackend::new(&engine);
    let mut trainer = Trainer::new(&backend, rc(Mode::Spt, 14), TrainerOptions::default());
    let report = trainer.train().expect("train");
    assert_eq!(report.steps, 14);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let first = report.losses[0];
    let last = *report.losses.last().unwrap();
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(report.refreshes >= 2, "codebook refresh did not run");
    let e = report.evals.last().expect("eval point");
    assert!(e.ppl.is_finite() && e.ppl > 1.0);
}

#[test]
fn all_modes_train_and_chunked_path_agrees() {
    let Some(engine) = engine() else { return };
    let backend = PjrtBackend::new(&engine);
    for mode in Mode::ALL {
        let name = format!("train_step_spt-tiny_{}", mode.as_str());
        if engine.manifest().get(&name).is_err() {
            continue;
        }
        let mut t = Trainer::new(&backend, rc(mode, 4), TrainerOptions::default());
        let r = t.train().expect("train");
        assert!(r.losses.iter().all(|l| l.is_finite()), "{mode:?}");
    }
    // Chunked dispatch must produce the same loss sequence as per-step
    // (identical math, different batching of dispatches).
    if engine.manifest().get("train_chunk8_spt-tiny_lora").is_ok() {
        let mut cfg = rc(Mode::Lora, 8);
        cfg.eval_every = 0;
        cfg.codebook_refresh_every = 0;
        let mut a = Trainer::new(&backend, cfg.clone(), TrainerOptions::default());
        let ra = a.train().expect("per-step");
        let mut b = Trainer::new(
            &backend,
            cfg,
            TrainerOptions { chunked: true, ..Default::default() },
        );
        let rb = b.train().expect("chunked");
        assert_eq!(ra.losses.len(), rb.losses.len());
        for (x, y) in ra.losses.iter().zip(&rb.losses) {
            assert!((x - y).abs() < 1e-4, "divergence: {x} vs {y}");
        }
    }
}

#[test]
fn qa_training_beats_chance() {
    let Some(engine) = engine() else { return };
    let backend = PjrtBackend::new(&engine);
    let mut cfg = rc(Mode::Lora, 40);
    cfg.eval_every = 0;
    let mut trainer = Trainer::new(&backend, cfg, TrainerOptions::default());
    let report = trainer.train_qa().expect("train-qa");
    let acc = report.qa_accuracy.expect("accuracy");
    // 4 choices -> chance 25%; after 60 steps on the rule-based task the
    // model should be visibly above chance.
    assert!(acc > 0.28, "QA accuracy {acc} not above chance");
}

#[test]
fn checkpoint_roundtrip_preserves_training() {
    let Some(engine) = engine() else { return };
    let state = TrainState::init(&engine, "model_init_spt-tiny_spt", 3).expect("init");
    let dir = std::env::temp_dir().join("spt_int_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ckpt");
    checkpoint::save(&state, &path).expect("save");
    let restored = checkpoint::load(&path).expect("load");
    assert_eq!(state.params.len(), restored.params.len());
    for (a, b) in state.params.iter().zip(&restored.params) {
        assert_eq!(a.max_abs_diff(b).unwrap(), 0.0);
    }
    // The restored state must drive the train step identically.
    let spec = engine.spec("train_step_spt-tiny_spt").unwrap().clone();
    let tok_spec = &spec.inputs[spec.inputs.len() - 2];
    let tokens = HostTensor::zeros(tok_spec).unwrap();
    let mut s1 = state.clone();
    let mut s2 = restored.clone();
    let o1 = engine
        .run("train_step_spt-tiny_spt", &s1.step_inputs(tokens.clone(), tokens.clone()))
        .unwrap();
    let o2 = engine
        .run("train_step_spt-tiny_spt", &s2.step_inputs(tokens.clone(), tokens))
        .unwrap();
    let l1 = s1.absorb_step_outputs(o1).unwrap().scalar().unwrap();
    let l2 = s2.absorb_step_outputs(o2).unwrap().scalar().unwrap();
    assert_eq!(l1, l2);
}

#[test]
fn codebook_refresh_changes_only_codebook_leaves() {
    let Some(engine) = engine() else { return };
    let name = "codebook_refresh_spt-tiny";
    if engine.manifest().get(name).is_err() {
        return;
    }
    let state = TrainState::init(&engine, "model_init_spt-tiny_spt", 1).expect("init");
    let q_idx = state.find_leaves("pq_q");
    let k_idx = state.find_leaves("pq_k");
    assert_eq!(q_idx.len(), 1);
    assert_eq!(k_idx.len(), 1);
    let spec = engine.spec(name).unwrap().clone();
    let tok_spec = spec.inputs.last().unwrap();
    let mut rng = spt::util::rng::Rng::new(4);
    let vocab = 4096;
    let tokens = HostTensor::i32(
        tok_spec.shape.clone(),
        (0..tok_spec.elements())
            .map(|_| rng.below(vocab) as i32)
            .collect(),
    );
    let mut inputs = state.params.clone();
    inputs.push(tokens);
    let out = engine.run(name, &inputs).expect("refresh");
    assert_eq!(out.len(), 2);
    // Refreshed codebooks have the same shape and differ from the old.
    assert_eq!(out[0].shape(), state.params[q_idx[0]].shape());
    assert!(out[0].max_abs_diff(&state.params[q_idx[0]]).unwrap() > 0.0);
}
