//! Token sampling off the deterministic [`Rng`] stream: greedy argmax
//! and temperature/top-k.  Sampling is sequential per sequence and
//! consumes only the per-request RNG, so generated streams are
//! reproducible per seed and independent of thread count or batch
//! composition.

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Sampling policy for one generation stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampler {
    /// Argmax, ties broken toward the lower token id.  Consumes no RNG.
    Greedy,
    /// Softmax over `logits / temperature` restricted to the `k` largest
    /// logits (ties toward the lower token id), sampled with one `f64`
    /// draw.  `k >= vocab` is plain temperature sampling.
    TopK { k: usize, temperature: f32 },
}

impl Sampler {
    /// Build from CLI-style knobs: no temperature → greedy; a
    /// temperature with no `top_k` → full-vocabulary temperature
    /// sampling.
    pub fn from_flags(temperature: Option<f32>, top_k: Option<usize>) -> Result<Self> {
        match (temperature, top_k) {
            (None, None) => Ok(Sampler::Greedy),
            (t, k) => {
                let temperature = t.unwrap_or(1.0);
                if temperature <= 0.0 || !temperature.is_finite() {
                    bail!("--temperature must be a positive finite number");
                }
                let k = k.unwrap_or(usize::MAX);
                if k == 0 {
                    bail!("--top_k must be >= 1");
                }
                Ok(Sampler::TopK { k, temperature })
            }
        }
    }

    /// Draw one token id from a logits row.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> usize {
        assert!(!logits.is_empty(), "empty logits row");
        match *self {
            Sampler::Greedy => {
                let mut best = 0usize;
                for (i, &x) in logits.iter().enumerate().skip(1) {
                    if x > logits[best] {
                        best = i;
                    }
                }
                best
            }
            Sampler::TopK { k, temperature } => {
                let inv_t = 1.0 / temperature as f64;
                if k >= logits.len() {
                    // Temperature-only: softmax over the whole row in
                    // natural index order — no selection, no sort.
                    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let weights: Vec<f64> = logits
                        .iter()
                        .map(|&x| (((x - mx) as f64) * inv_t).exp())
                        .collect();
                    let total: f64 = weights.iter().sum();
                    let mut x = rng.f64() * total;
                    for (i, w) in weights.iter().enumerate() {
                        x -= w;
                        if x <= 0.0 {
                            return i;
                        }
                    }
                    return logits.len() - 1;
                }
                let k = k.max(1);
                // Partition out the k winners in O(V), then sort only
                // them (the same select-then-sort-the-winners shape as
                // `bspmv::route`); (logit desc, index asc) is a strict
                // total order, so the winner set and order match a full
                // sort exactly.
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                let cmp = |a: &usize, b: &usize| {
                    logits[*b].total_cmp(&logits[*a]).then(a.cmp(b))
                };
                idx.select_nth_unstable_by(k - 1, cmp);
                idx.truncate(k);
                idx.sort_unstable_by(cmp);
                // Softmax over the kept logits at temperature T; the max
                // is idx[0] by the sort order.
                let mx = logits[idx[0]];
                let weights: Vec<f64> = idx
                    .iter()
                    .map(|&i| (((logits[i] - mx) as f64) * inv_t).exp())
                    .collect();
                let total: f64 = weights.iter().sum();
                let mut x = rng.f64() * total;
                for (slot, w) in weights.iter().enumerate() {
                    x -= w;
                    if x <= 0.0 {
                        return idx[slot];
                    }
                }
                idx[k - 1]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax_with_low_index_ties() {
        let mut rng = Rng::new(0);
        let s = Sampler::Greedy;
        assert_eq!(s.sample(&[0.1, 3.0, -1.0], &mut rng), 1);
        assert_eq!(s.sample(&[2.0, 2.0, 2.0], &mut rng), 0);
        // Greedy consumed no RNG: the stream is untouched.
        let mut fresh = Rng::new(0);
        assert_eq!(rng.next_u64(), fresh.next_u64());
    }

    #[test]
    fn topk_restricts_support_and_is_seed_deterministic() {
        let logits = vec![5.0f32, 4.0, -10.0, 3.0, -20.0];
        let s = Sampler::TopK { k: 3, temperature: 1.0 };
        let mut rng = Rng::new(7);
        let mut seen = [0usize; 5];
        for _ in 0..200 {
            seen[s.sample(&logits, &mut rng)] += 1;
        }
        assert_eq!(seen[2], 0, "outside top-3");
        assert_eq!(seen[4], 0, "outside top-3");
        assert!(seen[0] > seen[3], "higher logit should dominate");
        // Same seed, same stream.
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits, &mut a), s.sample(&logits, &mut b));
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let logits = vec![1.0f32, 1.2, 0.8];
        let s = Sampler::TopK { k: 3, temperature: 1e-3 };
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn from_flags_validates() {
        assert_eq!(Sampler::from_flags(None, None).unwrap(), Sampler::Greedy);
        assert_eq!(
            Sampler::from_flags(Some(0.7), Some(40)).unwrap(),
            Sampler::TopK { k: 40, temperature: 0.7 }
        );
        assert!(matches!(
            Sampler::from_flags(None, Some(8)).unwrap(),
            Sampler::TopK { k: 8, .. }
        ));
        assert!(Sampler::from_flags(Some(0.0), None).is_err());
        assert!(Sampler::from_flags(Some(f32::NAN), None).is_err());
        assert!(Sampler::from_flags(Some(1.0), Some(0)).is_err());
    }
}
