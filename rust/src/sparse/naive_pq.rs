//! "Naive-PQ" baseline (paper Table 6): the standard PQ practice —
//! asymmetric-distance float score tables + full float sort for top-L.
//!
//! The paper shows the bucket-sort implementation is ~4.6x faster because
//! it never materializes or sorts floating-point scores.  This module
//! exists so `benches/table6_alternatives.rs` can regenerate that
//! comparison at native speed.

use super::codes::{Codes, TopL};
use super::pq::Codebooks;

/// Precomputed inner-product lookup tables: `tables[m][e1][e2] =
/// c^m[e1] . c^m[e2]` — the "inner product table for each codebook".
pub struct ScoreTables {
    pub m: usize,
    pub e: usize,
    data: Vec<f32>, // [m * e * e]
}

impl ScoreTables {
    pub fn build(cb: &Codebooks) -> Self {
        let mut data = vec![0.0f32; cb.m * cb.e * cb.e];
        for mi in 0..cb.m {
            for e1 in 0..cb.e {
                let c1 = cb.codeword(mi, e1);
                for e2 in 0..cb.e {
                    let c2 = cb.codeword(mi, e2);
                    let dot: f32 = c1.iter().zip(c2).map(|(a, b)| a * b).sum();
                    data[(mi * cb.e + e1) * cb.e + e2] = dot;
                }
            }
        }
        ScoreTables { m: cb.m, e: cb.e, data }
    }

    #[inline]
    pub fn score(&self, codes_q: &[u8], codes_k: &[u8]) -> f32 {
        let mut s = 0.0;
        for mi in 0..self.m {
            s += self.data
                [(mi * self.e + codes_q[mi] as usize) * self.e + codes_k[mi] as usize];
        }
        s
    }
}

/// Top-L by float ADC score + full sort (the expensive baseline).
pub fn select(
    codes_q: &Codes,
    codes_k: &Codes,
    tables: &ScoreTables,
    l: usize,
    causal: bool,
) -> TopL {
    let nk = codes_k.n;
    assert!(l >= 1 && l <= nk);
    let mut out = TopL::zeros(codes_q.n, l);
    for (i, row) in out.data.chunks_exact_mut(l).enumerate() {
        let cq = codes_q.row(i);
        // Materialize all float scores (the memory cost Table 6 shows).
        let mut scored: Vec<(f32, u32)> = (0..nk)
            .map(|j| {
                let s = if causal && j > i {
                    f32::NEG_INFINITY
                } else {
                    tables.score(cq, codes_k.row(j))
                };
                (s, j as u32)
            })
            .collect();
        // Full float sort (the time cost Table 6 shows).
        scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        for (slot, (_, j)) in row.iter_mut().zip(scored.into_iter().take(l)) {
            *slot = j;
        }
    }
    out
}

/// Bytes transiently needed per query row (scores + indices) — reported in
/// the Table 6 bench as the memory overhead vs bucket sort.
pub fn scratch_bytes_per_query(nk: usize) -> usize {
    nk * (4 + 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pq;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::rng::Rng;

    #[test]
    fn tables_match_direct_dot() {
        let mut rng = Rng::new(1);
        let cb = Codebooks::random(3, 4, 8, &mut rng);
        let t = ScoreTables::build(&cb);
        let cq = vec![1u8, 3, 0];
        let ck = vec![2u8, 3, 1];
        let mut want = 0.0f32;
        for mi in 0..3 {
            let a = cb.codeword(mi, cq[mi] as usize);
            let b = cb.codeword(mi, ck[mi] as usize);
            want += a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
        }
        assert!((t.score(&cq, &ck) - want).abs() < 1e-5);
    }

    #[test]
    fn identical_codes_score_highest_for_adapted_codebooks() {
        // After codebook adaptation on well-separated clusters, a key with
        // the same codes as the query should land in the top-L.
        let mut rng = Rng::new(2);
        let mut cb = Codebooks::random(2, 4, 4, &mut rng);
        let x = rng.normal_vec(128 * cb.d());
        pq::codebook_update(&x, &mut cb, 1.0);
        let codes = pq::quantize(&x[..16 * cb.d()], &cb);
        let t = ScoreTables::build(&cb);
        let sel = select(&codes, &codes, &t, 4, false);
        // Each query's own row shares all codes -> must be selected unless
        // 4+ other keys tie-beat it; allow majority.
        let hits = sel
            .rows()
            .enumerate()
            .filter(|(i, row)| row.contains(&(*i as u32)))
            .count();
        assert!(hits >= 10, "self-hits {hits}/16");
    }

    #[test]
    fn prop_output_contract_matches_bucket_sort_shape() {
        check(30, |g| {
            let n = g.usize_in(2, 32);
            let l = g.usize_in(1, n);
            let m = g.usize_in(1, 6);
            let e = g.usize_in(2, 8);
            let mut rng = g.rng().fork();
            let cb = Codebooks::random(m, e, 2, &mut rng);
            let x = rng.normal_vec(n * cb.d());
            let codes = pq::quantize(&x, &cb);
            let t = ScoreTables::build(&cb);
            let sel = select(&codes, &codes, &t, l, g.bool());
            prop_assert(sel.n == n, "rows")?;
            prop_assert(sel.l == l && sel.data.len() == n * l, "row length")?;
            prop_assert(
                sel.data.iter().all(|&j| (j as usize) < n),
                "range",
            )
        });
    }
}
