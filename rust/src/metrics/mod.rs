//! Metrics: wall-clock timers with robust statistics, counters, and the
//! table renderer used by every bench target (no `criterion` offline —
//! this module is the measurement harness).

pub mod table;
pub mod timer;

pub use table::Table;
pub use timer::{bench, BenchResult, Stopwatch};

/// Simple monotonically increasing counters keyed by name.
#[derive(Debug, Default)]
pub struct Counters {
    map: std::collections::BTreeMap<String, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, key: &str, v: u64) {
        *self.map.entry(key.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, key: &str) -> u64 {
        self.map.get(key).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.map.iter().map(|(k, &v)| (k.as_str(), v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut c = Counters::new();
        c.add("steps", 1);
        c.add("steps", 2);
        c.add("tokens", 512);
        assert_eq!(c.get("steps"), 3);
        assert_eq!(c.get("tokens"), 512);
        assert_eq!(c.get("missing"), 0);
        assert_eq!(c.iter().count(), 2);
    }
}
