"""L1 Pallas kernel: fused product-quantization (cdist + argmin).

Paper mapping (SPT §5.1, Alg. 2): the CUDA implementation fuses the ``cdist``
and ``argmin`` operators into one kernel so the ``[seq, num_codewords]``
distance matrix never hits global memory.  Here the same fusion happens per
grid step: each (batch, subspace) instance keeps its distance tile entirely
in VMEM scratch and writes only the ``[n]`` codeword ids back to HBM.

Hardware adaptation (CUDA -> Pallas/TPU): one threadblock per (sequence,
codebook) becomes one grid step per (batch-head, codebook); warp reductions
become lane-vectorized ``jnp`` reductions over the E axis (E <= 32, so the
tile is tiny and lives comfortably in VMEM: n*E*4 bytes ~ 32 KiB at n=512).

All kernels are ``interpret=True``: on this CPU-PJRT image real Mosaic
lowering cannot execute; interpret mode lowers to plain HLO and runs
everywhere (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _quantize_kernel(x_ref, cb_ref, codes_ref):
    """One (batch, subspace) instance: nearest codeword for every vector.

    x_ref:     [1, n, 1, d']  slice of the input for this (b, m)
    cb_ref:    [1, E, d']     codebook m
    codes_ref: [1, n, 1]      output codeword ids (int32)
    """
    x = x_ref[0, :, 0, :]  # [n, d']
    cb = cb_ref[0]  # [E, d']
    # Fused cdist+argmin: distances stay in registers/VMEM.
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2 ; ||x||^2 constant per row, skip.
    dots = x @ cb.T  # [n, E]
    c2 = jnp.sum(cb * cb, axis=-1)  # [E]
    dist = c2[None, :] - 2.0 * dots  # [n, E]
    codes_ref[0, :, 0] = jnp.argmin(dist, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def pq_quantize(x: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Quantize batched vectors with PQ.

    Args:
      x: ``[b, n, d]`` vectors (b = batch * heads).
      codebooks: ``[M, E, d']`` with ``d = M * d'``.

    Returns:
      ``[b, n, M]`` int32 codeword ids.
    """
    b, n, d = x.shape
    m, e, dsub = codebooks.shape
    assert d == m * dsub, f"d={d} != M*d'={m}*{dsub}"
    xs = x.reshape(b, n, m, dsub)
    grid = (b, m)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n, 1, dsub), lambda bi, mi: (bi, 0, mi, 0)),
            pl.BlockSpec((1, e, dsub), lambda bi, mi: (mi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, 1), lambda bi, mi: (bi, 0, mi)),
        out_shape=jax.ShapeDtypeStruct((b, n, m), jnp.int32),
        interpret=INTERPRET,
    )(xs, codebooks)


def _quantize_error_kernel(x_ref, cb_ref, err_ref):
    """Like _quantize_kernel but emits the min squared distance (DKM error)."""
    x = x_ref[0, :, 0, :]
    cb = cb_ref[0]
    x2 = jnp.sum(x * x, axis=-1)  # [n]
    dots = x @ cb.T
    c2 = jnp.sum(cb * cb, axis=-1)
    dist = x2[:, None] - 2.0 * dots + c2[None, :]
    err_ref[0, :, 0] = jnp.min(dist, axis=-1)


def pq_quantize_error(x: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Mean squared quantization error over all vectors/subspaces (scalar)."""
    b, n, d = x.shape
    m, e, dsub = codebooks.shape
    xs = x.reshape(b, n, m, dsub)
    per = pl.pallas_call(
        _quantize_error_kernel,
        grid=(b, m),
        in_specs=[
            pl.BlockSpec((1, n, 1, dsub), lambda bi, mi: (bi, 0, mi, 0)),
            pl.BlockSpec((1, e, dsub), lambda bi, mi: (mi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, 1), lambda bi, mi: (bi, 0, mi)),
        out_shape=jax.ShapeDtypeStruct((b, n, m), jnp.float32),
        interpret=INTERPRET,
    )(xs, codebooks)
    # err is ||x||^2-2x.c+||c||^2 >= 0 mathematically; clamp fp noise.
    return jnp.mean(jnp.maximum(per, 0.0)) / dsub


def pq_codebook_update(
    x: jax.Array, codebooks: jax.Array, lr: float = 0.5
) -> jax.Array:
    """DKM-style codebook refresh (paper §5.1: run every ~20 mini-batches).

    Plain-jnp segment means — this runs on the *build/trial* path only, the
    paper likewise amortizes it across mini-batches, so it is not a Pallas
    hot kernel.
    """
    b, n, d = x.shape
    m, e, dsub = codebooks.shape
    codes = pq_quantize(x, codebooks).reshape(b * n, m)
    xs = x.reshape(b * n, m, dsub)
    onehot = jax.nn.one_hot(codes, e, dtype=x.dtype)  # [bn, M, E]
    counts = jnp.sum(onehot, axis=0)  # [M, E]
    sums = jnp.einsum("nme,nmd->med", onehot, xs)
    means = sums / jnp.maximum(counts, 1.0)[:, :, None]
    occupied = (counts > 0)[:, :, None]
    target = jnp.where(occupied, means, codebooks)
    return codebooks + lr * (target - codebooks)


def init_codebooks(
    key: jax.Array, m: int, e: int, dsub: int, scale: float = 1.0
) -> jax.Array:
    """Random-normal codebook init, matched to unit-variance activations."""
    return jax.random.normal(key, (m, e, dsub), dtype=jnp.float32) * scale
