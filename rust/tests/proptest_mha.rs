//! Property tests for the parallel multi-head layer (`sparse::mha`):
//! the rayon path must reproduce the sequential single-head reference
//! for random (H, n, d, L, causal) configurations, at any chunking.

use spt::sparse::mha::{routed_ffn_par, MultiHeadSparseAttention};
use spt::sparse::pq::{self, Codebooks};
use spt::sparse::{attention, bspmv, Matrix};
use spt::util::proptest::{check, prop_assert};

#[test]
fn parallel_mha_matches_sequential_for_random_configs() {
    check(20, |g| {
        let hh = g.usize_in(1, 4);
        let m = g.usize_in(1, 4);
        let dsub = g.usize_in(1, 4);
        let d = m * dsub;
        let n = g.usize_in(2, 40);
        let l = g.usize_in(1, n);
        let causal = g.bool();
        let chunk = g.usize_in(1, 12);
        let mut rng = g.rng().fork();

        let mut cbs = Vec::new();
        let (mut q, mut k, mut v) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..hh {
            let mut cb = Codebooks::random(m, g.usize_in(2, 8), dsub, &mut rng);
            let kh = Matrix::randn(n, d, 1.0, &mut rng);
            let noise = Matrix::randn(n, d, 0.5, &mut rng);
            let qh = Matrix::from_vec(
                n,
                d,
                kh.data
                    .iter()
                    .zip(&noise.data)
                    .map(|(a, b)| 2.0 * a + b)
                    .collect(),
            );
            pq::codebook_update(&kh.data, &mut cb, 1.0);
            cbs.push(cb);
            q.push(qh);
            k.push(kh);
            v.push(Matrix::randn(n, d, 1.0, &mut rng));
        }
        let mut mha = MultiHeadSparseAttention::new(cbs, l, causal);
        mha.query_chunk = chunk;
        let par = mha.forward(&q, &k, &v);
        let seq = mha.forward_seq(&q, &k, &v);
        prop_assert(par.len() == hh && seq.len() == hh, "head count")?;
        for h in 0..hh {
            let diff = par[h].max_abs_diff(&seq[h]);
            prop_assert(
                diff < 1e-5,
                format!(
                    "H={hh} n={n} d={d} L={l} causal={causal} chunk={chunk} \
                     head {h}: diff {diff}"
                ),
            )?;
        }
        // The sequential reference itself must match the single-head
        // attention module (guards against reference drift).
        let (want, _) =
            attention::sparse_attention(&q[0], &k[0], &v[0], &mha.codebooks[0], l, causal);
        prop_assert(
            seq[0].max_abs_diff(&want) < 1e-7,
            "forward_seq drifted from sparse_attention",
        )
    });
}

#[test]
fn parallel_routed_ffn_matches_sequential_for_random_configs() {
    check(25, |g| {
        let nt = g.usize_in(1, 48);
        let d = g.usize_in(1, 10);
        let gg = *g.pick(&[2usize, 4, 8]);
        let dg = g.usize_in(1, 6);
        let ga = g.usize_in(1, gg);
        let mut rng = g.rng().fork();
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, gg * dg, 0.3, &mut rng);
        let wo = Matrix::randn(gg * dg, d, 0.3, &mut rng);
        let scores = Matrix::randn(nt, gg, 1.0, &mut rng);
        let routing = bspmv::route(&scores, ga);
        let par = routed_ffn_par(&x, &wi, &wo, &routing);
        let seq = bspmv::routed_ffn(&x, &wi, &wo, &routing);
        let diff = par.max_abs_diff(&seq);
        prop_assert(
            diff < 1e-5,
            format!("nt={nt} d={d} G={gg} G'={ga}: diff {diff}"),
        )
    });
}

#[test]
fn parallel_path_is_deterministic_across_pool_sizes() {
    check(6, |g| {
        let mut rng = g.rng().fork();
        let n = g.usize_in(8, 24);
        let mut cb = Codebooks::random(2, 4, 4, &mut rng);
        let k = Matrix::randn(n, 8, 1.0, &mut rng);
        let q = Matrix::randn(n, 8, 1.0, &mut rng);
        let v = Matrix::randn(n, 8, 1.0, &mut rng);
        pq::codebook_update(&k.data, &mut cb, 1.0);
        let mha = MultiHeadSparseAttention::new(vec![cb; 2], n / 2, true);
        let qs = vec![q.clone(), q];
        let ks = vec![k.clone(), k];
        let vs = vec![v.clone(), v];
        let base = mha.forward(&qs, &ks, &vs);
        for t in [1usize, 3] {
            let pool = rayon::ThreadPoolBuilder::new()
                .num_threads(t)
                .build()
                .map_err(|e| e.to_string())?;
            let got = pool.install(|| mha.forward(&qs, &ks, &vs));
            for h in 0..base.len() {
                prop_assert(
                    got[h] == base[h],
                    format!("{t}-thread pool changed head {h}"),
                )?;
            }
        }
        Ok(())
    });
}
