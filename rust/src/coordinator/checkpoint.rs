//! Binary checkpointing of training state (no external format crates:
//! a simple length-prefixed container with a magic header and version).
//!
//! Layout (little-endian):
//! ```text
//! magic "SPTCKPT2" | u32 model_len | model bytes | u8 mode | u32 n_layers
//!                  | u32 n_leaves
//! per leaf: u8 dtype | u32 ndim | u64 dims... | u64 byte_len | payload
//! repeated for: params, m, v, then step (i32)
//! ```
//!
//! v2 embeds the model identity ([`CkptMeta`]: model name, tuning mode,
//! layer count) so `--resume` and `spt generate` can fail fast with a
//! clear error instead of a leaf-shape mismatch deep in materialization.
//! Legacy v1 files ("SPTCKPT1", no identity block) still load — they
//! just carry no metadata to verify against.
//!
//! The format is leaf-count generic, so the native backend's multi-layer
//! states (one leaf group per transformer layer) round-trip without any
//! format changes — `tests/integration_native_train.rs` asserts a
//! mid-run resume on an `n_layers = 2` preset is bit-identical to an
//! uninterrupted run.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::state::TrainState;
use crate::config::Mode;
use crate::runtime::HostTensor;

const MAGIC_V1: &[u8; 8] = b"SPTCKPT1";
const MAGIC_V2: &[u8; 8] = b"SPTCKPT2";

/// Model identity embedded in v2 checkpoint headers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CkptMeta {
    pub model: String,
    pub mode: Mode,
    pub n_layers: usize,
}

impl CkptMeta {
    /// Fail with a clear error when this checkpoint does not belong to
    /// the `(model, mode)` the caller is about to run.
    pub fn verify(&self, model: &str, mode: Mode) -> Result<()> {
        if self.model != model || self.mode != mode {
            bail!(
                "checkpoint was trained as model '{}' mode '{}' ({} layers); \
                 requested model '{}' mode '{}' — pass the matching --model/--mode",
                self.model,
                self.mode.as_str(),
                self.n_layers,
                model,
                mode.as_str()
            );
        }
        Ok(())
    }

    /// [`Self::verify`] plus the layer count — for callers about to
    /// materialize a model with a known depth (resume, `spt generate`,
    /// serving), so a depth drift fails here with a clear message
    /// instead of as a leaf-shape mismatch deep in materialization.
    pub fn verify_layers(&self, model: &str, mode: Mode, n_layers: usize) -> Result<()> {
        self.verify(model, mode)?;
        if self.n_layers != n_layers {
            bail!(
                "checkpoint was trained with {} layers; model '{model}' ({}) builds {n_layers} \
                 — pass the preset this checkpoint was trained on",
                self.n_layers,
                mode.as_str()
            );
        }
        Ok(())
    }
}

fn mode_code(mode: Mode) -> u8 {
    match mode {
        Mode::Full => 0,
        Mode::Lora => 1,
        Mode::Spt => 2,
    }
}

fn mode_from_code(code: u8) -> Result<Mode> {
    Ok(match code {
        0 => Mode::Full,
        1 => Mode::Lora,
        2 => Mode::Spt,
        other => bail!("corrupt checkpoint: mode code {other}"),
    })
}

fn write_tensor(w: &mut impl Write, t: &HostTensor) -> Result<()> {
    let (code, bytes): (u8, Vec<u8>) = match t {
        HostTensor::F32 { data, .. } => {
            (0, data.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        HostTensor::I32 { data, .. } => {
            (1, data.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
    };
    w.write_all(&[code])?;
    let shape = t.shape();
    w.write_all(&(shape.len() as u32).to_le_bytes())?; // det: cast-bounded (ndim <= 16)
    for &d in shape {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    w.write_all(&(bytes.len() as u64).to_le_bytes())?;
    w.write_all(&bytes)?;
    Ok(())
}

fn read_tensor(r: &mut impl Read) -> Result<HostTensor> {
    let mut code = [0u8; 1];
    r.read_exact(&mut code)?;
    let mut ndim = [0u8; 4];
    r.read_exact(&mut ndim)?;
    let ndim = u32::from_le_bytes(ndim) as usize;
    if ndim > 16 {
        bail!("corrupt checkpoint: ndim {ndim}");
    }
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        let mut d = [0u8; 8];
        r.read_exact(&mut d)?;
        shape.push(u64::from_le_bytes(d) as usize);
    }
    let mut len = [0u8; 8];
    r.read_exact(&mut len)?;
    let len = u64::from_le_bytes(len) as usize;
    let expect: usize = shape.iter().product::<usize>() * 4;
    if len != expect {
        bail!("corrupt checkpoint: payload {len} != {expect}");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(match code[0] {
        0 => HostTensor::f32(
            shape,
            payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        1 => HostTensor::i32(
            shape,
            payload
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        other => bail!("corrupt checkpoint: dtype code {other}"),
    })
}

/// Save a training state (params + optimizer) to disk in the legacy v1
/// format (no model identity).  Prefer [`save_tagged`], which stamps the
/// checkpoint with its [`CkptMeta`] so later loads can verify it.
pub fn save(state: &TrainState, path: impl AsRef<Path>) -> Result<()> {
    save_inner(state, None, path.as_ref())
}

/// Save a training state stamped with its model identity (v2 header).
pub fn save_tagged(state: &TrainState, meta: &CkptMeta, path: impl AsRef<Path>) -> Result<()> {
    save_inner(state, Some(meta), path.as_ref())
}

fn save_inner(state: &TrainState, meta: Option<&CkptMeta>, path: &Path) -> Result<()> {
    let mut w = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
    );
    match meta {
        None => w.write_all(MAGIC_V1)?,
        Some(m) => {
            w.write_all(MAGIC_V2)?;
            // det: cast-bounded (model name <= 4096 bytes, checked on load)
            w.write_all(&(m.model.len() as u32).to_le_bytes())?;
            w.write_all(m.model.as_bytes())?;
            w.write_all(&[mode_code(m.mode)])?;
            w.write_all(&(m.n_layers as u32).to_le_bytes())?;
        }
    }
    w.write_all(&(state.params.len() as u32).to_le_bytes())?; // det: cast-bounded (leaves)
    for group in [&state.params, &state.m, &state.v] {
        for t in group {
            write_tensor(&mut w, t)?;
        }
    }
    write_tensor(&mut w, &state.step)?;
    // Paths footer for leaf lookup after restore.
    let paths = state.param_paths.join("\n");
    w.write_all(&(paths.len() as u64).to_le_bytes())?;
    w.write_all(paths.as_bytes())?;
    Ok(())
}

/// Restore a training state from disk (either header version),
/// discarding any identity metadata.  Use [`load_tagged`] when the
/// caller wants to verify the checkpoint against a run configuration.
pub fn load(path: impl AsRef<Path>) -> Result<TrainState> {
    Ok(load_tagged(path)?.0)
}

/// Restore a training state plus its identity metadata (`None` for
/// legacy v1 checkpoints, which carry none).
pub fn load_tagged(path: impl AsRef<Path>) -> Result<(TrainState, Option<CkptMeta>)> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let meta = if &magic == MAGIC_V1 {
        None
    } else if &magic == MAGIC_V2 {
        let mut mlen = [0u8; 4];
        r.read_exact(&mut mlen)?;
        let mlen = u32::from_le_bytes(mlen) as usize;
        if mlen > 4096 {
            bail!("corrupt checkpoint: model name length {mlen}");
        }
        let mut mbuf = vec![0u8; mlen];
        r.read_exact(&mut mbuf)?;
        let model = String::from_utf8(mbuf).context("checkpoint model name")?;
        let mut code = [0u8; 1];
        r.read_exact(&mut code)?;
        let mode = mode_from_code(code[0])?;
        let mut nl = [0u8; 4];
        r.read_exact(&mut nl)?;
        Some(CkptMeta { model, mode, n_layers: u32::from_le_bytes(nl) as usize })
    } else {
        bail!("not an SPT checkpoint (bad magic)");
    };
    let mut n = [0u8; 4];
    r.read_exact(&mut n)?;
    let n = u32::from_le_bytes(n) as usize;
    if n > 1_000_000 {
        bail!("corrupt checkpoint: {n} leaves");
    }
    fn read_group(r: &mut impl Read, n: usize) -> Result<Vec<HostTensor>> {
        (0..n).map(|_| read_tensor(r)).collect()
    }
    let params = read_group(&mut r, n)?;
    let m = read_group(&mut r, n)?;
    let v = read_group(&mut r, n)?;
    let step = read_tensor(&mut r)?;
    let mut plen = [0u8; 8];
    r.read_exact(&mut plen)?;
    let plen = u64::from_le_bytes(plen) as usize;
    let mut pbuf = vec![0u8; plen];
    r.read_exact(&mut pbuf)?;
    let param_paths = String::from_utf8(pbuf)?
        .split('\n')
        .map(str::to_string)
        .collect();
    Ok((TrainState { params, m, v, step, param_paths }, meta))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> TrainState {
        TrainState {
            params: vec![
                HostTensor::f32(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 9.0, -7.25]),
                HostTensor::i32(vec![2], vec![4, -5]),
            ],
            m: vec![
                HostTensor::f32(vec![2, 3], vec![0.1; 6]),
                HostTensor::i32(vec![2], vec![0, 0]),
            ],
            v: vec![
                HostTensor::f32(vec![2, 3], vec![0.2; 6]),
                HostTensor::i32(vec![2], vec![0, 0]),
            ],
            step: HostTensor::scalar_i32(42),
            param_paths: vec!["['a']".into(), "['b']".into()],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("spt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.ckpt");
        let s = state();
        save(&s, &path).unwrap();
        let s2 = load(&path).unwrap();
        assert_eq!(s.params, s2.params);
        assert_eq!(s.m, s2.m);
        assert_eq!(s.v, s2.v);
        assert_eq!(s.step, s2.step);
        assert_eq!(s.param_paths, s2.param_paths);
    }

    #[test]
    fn tagged_roundtrip_preserves_meta_and_state() {
        let dir = std::env::temp_dir().join("spt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tagged.ckpt");
        let s = state();
        let meta = CkptMeta {
            model: "spt-nano-l2".into(),
            mode: Mode::Spt,
            n_layers: 2,
        };
        save_tagged(&s, &meta, &path).unwrap();
        let (s2, m2) = load_tagged(&path).unwrap();
        assert_eq!(s.params, s2.params);
        assert_eq!(s.step, s2.step);
        assert_eq!(m2.as_ref(), Some(&meta));
        // The untagged loader still reads it.
        let s3 = load(&path).unwrap();
        assert_eq!(s.params, s3.params);
        // verify(): exact match passes, any identity drift fails clearly.
        meta.verify("spt-nano-l2", Mode::Spt).unwrap();
        let err = meta.verify("spt-nano", Mode::Spt).unwrap_err();
        assert!(err.to_string().contains("spt-nano-l2"), "{err}");
        assert!(meta.verify("spt-nano-l2", Mode::Full).is_err());
    }

    #[test]
    fn legacy_v1_loads_with_no_meta() {
        let dir = std::env::temp_dir().join("spt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy.ckpt");
        let s = state();
        save(&s, &path).unwrap();
        let (s2, meta) = load_tagged(&path).unwrap();
        assert_eq!(s.params, s2.params);
        assert!(meta.is_none());
    }

    #[test]
    fn verify_layers_catches_depth_mismatch() {
        let meta = CkptMeta { model: "spt-nano".into(), mode: Mode::Spt, n_layers: 2 };
        meta.verify_layers("spt-nano", Mode::Spt, 2).unwrap();
        let err = meta.verify_layers("spt-nano", Mode::Spt, 1).unwrap_err();
        assert!(err.to_string().contains("2 layers"), "{err}");
        assert!(err.to_string().contains("builds 1"), "{err}");
        // Model/mode drift still fails through verify()'s message.
        assert!(meta.verify_layers("spt-mini", Mode::Spt, 2).is_err());
    }

    #[test]
    fn detects_truncation_inside_v2_header() {
        let dir = std::env::temp_dir().join("spt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc_header.ckpt");
        let meta = CkptMeta { model: "spt-nano-l2".into(), mode: Mode::Spt, n_layers: 2 };
        save_tagged(&state(), &meta, &path).unwrap();
        // Cut mid-way through the model name: magic (8) + name len (4) + 3.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..15]).unwrap();
        assert!(load_tagged(&path).is_err());
    }

    #[test]
    fn rejects_corrupt_mode_code() {
        let dir = std::env::temp_dir().join("spt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("badmode.ckpt");
        let meta = CkptMeta { model: "m".into(), mode: Mode::Lora, n_layers: 1 };
        save_tagged(&state(), &meta, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // The mode code sits at magic (8) + name len (4) + name (1).
        bytes[13] = 9;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_tagged(&path).unwrap_err();
        assert!(err.to_string().contains("mode code 9"), "{err}");
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("spt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn detects_truncation() {
        let dir = std::env::temp_dir().join("spt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.ckpt");
        save(&state(), &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
    }
}
