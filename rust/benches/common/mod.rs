#![allow(dead_code)] // shared across bench binaries; each uses a subset
//! Shared helpers for the bench binaries (one per paper table/figure).

use std::path::Path;

use spt::metrics::Table;
#[cfg(feature = "xla")]
use spt::runtime::Engine;
use spt::sparse::bspmv::{self, Routing};
use spt::sparse::mha::MultiHeadSparseAttention;
use spt::sparse::pq::{self, Codebooks};
use spt::sparse::Matrix;
use spt::util::rng::Rng;

/// Artifacts directory: SPT_ARTIFACTS env or ./artifacts.
pub fn artifacts_dir() -> String {
    std::env::var("SPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Open the engine, or explain how to build artifacts and exit 0 (so
/// `cargo bench` degrades gracefully on a fresh checkout).
#[cfg(feature = "xla")]
pub fn engine_or_skip(bench: &str) -> Option<Engine> {
    let dir = artifacts_dir();
    if !Path::new(&dir).join("manifest.json").exists() {
        println!("[{bench}] skipped: no artifacts at '{dir}' (run `make artifacts`)");
        return None;
    }
    match Engine::new(&dir) {
        Ok(e) => Some(e),
        Err(err) => {
            println!("[{bench}] skipped: {err:#}");
            None
        }
    }
}

/// Deterministic H-head sparse-MHA + routed-FFN workload for the
/// engine-free thread-scaling sections of the table benches.
pub struct NativeWorkload {
    pub mha: MultiHeadSparseAttention,
    pub q: Vec<Matrix>,
    pub k: Vec<Matrix>,
    pub v: Vec<Matrix>,
    pub x: Matrix,
    pub wi: Matrix,
    pub wo: Matrix,
    pub routing: Routing,
}

#[allow(clippy::too_many_arguments)]
pub fn native_workload(
    heads: usize,
    n: usize,
    d: usize,
    l: usize,
    nt: usize,
    dff: usize,
    g: usize,
    ga: usize,
) -> NativeWorkload {
    let (m, e) = (8usize.min(d), 16usize);
    assert_eq!(d % m, 0, "d must split into {m} subspaces");
    let mut rng = Rng::new(0x5127);
    let mut codebooks = Vec::new();
    let (mut q, mut k, mut v) = (Vec::new(), Vec::new(), Vec::new());
    for _ in 0..heads {
        let mut cb = Codebooks::random(m, e, d / m, &mut rng);
        let kh = Matrix::randn(n, d, 1.0, &mut rng);
        let noise = Matrix::randn(n, d, 0.5, &mut rng);
        // Correlated Q/K so top-L selection is realistic (trained-like).
        let qh = Matrix::from_vec(
            n,
            d,
            kh.data
                .iter()
                .zip(&noise.data)
                .map(|(a, b)| 2.0 * a + b)
                .collect(),
        );
        for _ in 0..2 {
            pq::codebook_update(&kh.data, &mut cb, 1.0);
        }
        codebooks.push(cb);
        q.push(qh);
        k.push(kh);
        v.push(Matrix::randn(n, d, 1.0, &mut rng));
    }
    let x = Matrix::randn(nt, d, 1.0, &mut rng);
    let wi = Matrix::randn(d, dff, 0.2, &mut rng);
    let wo = Matrix::randn(dff, d, 0.2, &mut rng);
    let routing = bspmv::route(&Matrix::randn(nt, g, 1.0, &mut rng), ga);
    NativeWorkload {
        mha: MultiHeadSparseAttention::new(codebooks, l, true),
        q,
        k,
        v,
        x,
        wi,
        wo,
        routing,
    }
}

/// Thread counts for the scaling column: 1, 2, 4, 8 capped at the
/// machine's rayon default, which is always included.
pub fn thread_counts() -> Vec<usize> {
    let max = rayon_default_threads();
    let mut ts: Vec<usize> = [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&t| t <= max)
        .collect();
    if !ts.contains(&max) {
        ts.push(max);
    }
    ts
}

fn rayon_default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Build a dedicated rayon pool of `t` threads.
pub fn pool(t: usize) -> rayon::ThreadPool {
    rayon::ThreadPoolBuilder::new()
        .num_threads(t)
        .build()
        .expect("thread pool")
}

/// The shared thread-scaling measurement: the sequential reference
/// (per-head pipeline + sequential routed FFN) as the 1.00x row, then
/// the rayon paths on dedicated pools per [`thread_counts`] entry.
/// Emits a [Threads | MHA+FFN median | Speedup vs sequential] table.
pub fn emit_thread_scaling(wl: &NativeWorkload, title: &str, emit_name: &str) {
    let (w, s) = (warmup().max(1), samples().max(3));
    let seq = spt::metrics::bench("seq", w, s, || {
        std::hint::black_box(wl.mha.forward_seq(&wl.q, &wl.k, &wl.v));
        std::hint::black_box(bspmv::routed_ffn(&wl.x, &wl.wi, &wl.wo, &wl.routing));
    });
    let mut table = Table::new(
        title,
        &["Threads", "MHA+FFN median", "Speedup vs sequential"],
    );
    table.row(&[
        "seq (reference)".into(),
        spt::util::fmt_duration(seq.median()),
        "1.00x".into(),
    ]);
    for t in thread_counts() {
        let p = pool(t);
        let r = spt::metrics::bench(&format!("par_t{t}"), w, s, || {
            p.install(|| {
                std::hint::black_box(wl.mha.forward(&wl.q, &wl.k, &wl.v));
                std::hint::black_box(spt::sparse::mha::routed_ffn_par(
                    &wl.x, &wl.wi, &wl.wo, &wl.routing,
                ));
            });
        });
        table.row(&[
            t.to_string(),
            spt::util::fmt_duration(r.median()),
            format!("{:.2}x", seq.median() / r.median()),
        ]);
    }
    emit(emit_name, &table);
}

/// Write the rendered table to stdout and bench_out/<name>.{md,csv}.
pub fn emit(name: &str, table: &Table) {
    println!("{}", table.render());
    let dir = Path::new("bench_out");
    std::fs::create_dir_all(dir).ok();
    std::fs::write(dir.join(format!("{name}.md")), table.render()).ok();
    std::fs::write(dir.join(format!("{name}.csv")), table.to_csv()).ok();
    println!("[bench] wrote bench_out/{name}.md and .csv\n");
}

/// Write a machine-readable result to bench_out/<name>.json, so perf
/// trajectories can be tracked across PRs.  Top-level objects are
/// stamped with host/build provenance (git sha, rayon threads, CPU
/// model) so `cargo xtask benchdiff` can tell regressions from host
/// changes.
pub fn emit_json(name: &str, value: &spt::util::json::Json) {
    use spt::util::json::Json;
    let stamped = match value.clone() {
        Json::Obj(mut m) => {
            m.insert("provenance".to_string(), spt::util::provenance::provenance());
            Json::Obj(m)
        }
        other => other,
    };
    let dir = Path::new("bench_out");
    std::fs::create_dir_all(dir).ok();
    std::fs::write(dir.join(format!("{name}.json")), format!("{stamped}\n")).ok();
    println!("[bench] wrote bench_out/{name}.json\n");
}

/// Samples/warmup knobs (env-tunable so CI can be quick).
pub fn samples() -> usize {
    std::env::var("SPT_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2)
}

pub fn warmup() -> usize {
    std::env::var("SPT_BENCH_WARMUP")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}
