//! Coordinator integration on the native backend: end-to-end fine-tuning
//! loops with no PJRT toolchain and no artifacts — the default build's
//! `spt train` path.  Includes the checkpoint save → restore → resume
//! round trip, asserting the resumed loss curve is *bit-identical* to an
//! uninterrupted run.

use spt::config::{Mode, RunConfig};
use spt::coordinator::{checkpoint, trial, Backend, NativeBackend, Trainer, TrainerOptions};
use spt::coordinator::trial::TrialManager;

fn rc_for(model: &str, mode: Mode, steps: usize) -> RunConfig {
    RunConfig {
        model: model.into(),
        mode,
        batch: 2,
        seq: 32,
        steps,
        eval_every: 0,
        codebook_refresh_every: 3,
        lr: 5e-3,
        seed: 11,
        ..RunConfig::default()
    }
}

fn rc(mode: Mode, steps: usize) -> RunConfig {
    rc_for("spt-nano", mode, steps)
}

/// 30-step fine-tune per mode on `model`; the tail of the loss curve
/// must sit below the head.
fn assert_training_reduces_loss(model: &str) {
    let backend = NativeBackend::new();
    for mode in Mode::ALL {
        let mut cfg = rc_for(model, mode, 30);
        cfg.eval_every = 15;
        let mut trainer = Trainer::new(&backend, cfg, TrainerOptions::default());
        let report = trainer.train().expect("train");
        assert_eq!(report.steps, 30, "{model}/{mode:?}");
        assert!(
            report.losses.iter().all(|l| l.is_finite()),
            "{model}/{mode:?}: non-finite loss"
        );
        let first: f32 = report.losses[..5].iter().sum::<f32>() / 5.0;
        let last: f32 = report.losses[25..].iter().sum::<f32>() / 5.0;
        assert!(
            last < first,
            "{model}/{mode:?}: loss did not decrease ({first:.4} -> {last:.4})"
        );
        let e = report.evals.last().expect("eval point");
        assert!(
            e.ppl.is_finite() && e.ppl > 1.0,
            "{model}/{mode:?}: ppl {}",
            e.ppl
        );
        if mode == Mode::Spt {
            assert!(report.refreshes > 0, "codebook refresh never ran");
        }
    }
}

#[test]
fn native_training_reduces_loss_in_all_modes() {
    assert_training_reduces_loss("spt-nano");
}

#[test]
fn multi_layer_training_reduces_loss_in_all_modes() {
    // The n_layers=2 stack must train end to end — every layer's leaves
    // receive gradient through the pre-norm residual stream.
    assert_training_reduces_loss("spt-nano-l2");
}

/// The resume contract on `model`: an 8-step run interrupted at step 4,
/// checkpointed, restored, and finished must reproduce the
/// uninterrupted run bit-for-bit (spt: the mode with the most moving
/// parts — sparse attention, routing, codebook refreshes).
fn assert_resume_bit_identical(model: &str, ckpt_name: &str) {
    let backend = NativeBackend::new();
    let mut full =
        Trainer::new(&backend, rc_for(model, Mode::Spt, 8), TrainerOptions::default());
    let full_report = full.train().expect("uninterrupted run");
    assert_eq!(full_report.losses.len(), 8);

    // Interrupted run: halt after 4 optimizer steps, checkpoint to disk.
    let mut first = Trainer::new(
        &backend,
        rc_for(model, Mode::Spt, 8),
        TrainerOptions { stop_after: Some(4), ..Default::default() },
    );
    let r1 = first.train().expect("first half");
    assert_eq!(r1.losses.len(), 4);
    let dir = std::env::temp_dir().join("spt_native_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(ckpt_name);
    checkpoint::save(first.last_state.as_ref().expect("state"), &path).expect("save");

    // Restore and run to completion.
    let restored = checkpoint::load(&path).expect("load");
    assert_eq!(restored.step.scalar().unwrap(), 4.0);
    let mut second =
        Trainer::new(&backend, rc_for(model, Mode::Spt, 8), TrainerOptions::default());
    let r2 = second.train_from(restored).expect("resumed half");
    assert_eq!(r2.losses.len(), 4);

    // The stitched loss curve must equal the uninterrupted one bitwise.
    for (i, (stitched, reference)) in r1
        .losses
        .iter()
        .chain(r2.losses.iter())
        .zip(&full_report.losses)
        .enumerate()
    {
        assert_eq!(
            stitched.to_bits(),
            reference.to_bits(),
            "{model}: loss diverged at step {} ({stitched} vs {reference})",
            i + 1
        );
    }
    // And so must the final parameter/optimizer state.
    let s_full = full.last_state.as_ref().expect("full state");
    let s_res = second.last_state.as_ref().expect("resumed state");
    assert_eq!(s_full.params, s_res.params);
    assert_eq!(s_full.m, s_res.m);
    assert_eq!(s_full.v, s_res.v);
    assert_eq!(s_full.step, s_res.step);
}

#[test]
fn checkpoint_resume_is_bit_identical_to_uninterrupted_run() {
    assert_resume_bit_identical("spt-nano", "resume.ckpt");
}

#[test]
fn multi_layer_checkpoint_resume_is_bit_identical() {
    // Mid-run resume with per-layer leaves (weights, layer norms,
    // adapters, per-layer codebooks) round-tripping through the binary
    // checkpoint format.
    assert_resume_bit_identical("spt-nano-l2", "resume_l2.ckpt");
}

#[test]
fn qa_training_runs_and_scores() {
    let backend = NativeBackend::new();
    let mut trainer = Trainer::new(&backend, rc(Mode::Lora, 6), TrainerOptions::default());
    let report = trainer.train_qa().expect("train-qa");
    assert_eq!(report.steps, 6);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let acc = report.qa_accuracy.expect("accuracy");
    assert!((0.0..=1.0).contains(&acc), "accuracy {acc}");
}

#[test]
fn trial_manager_compares_all_modes_natively() {
    let backend = NativeBackend::new();
    let tm = TrialManager::new(&backend, rc(Mode::Spt, 2), 2);
    let (results, table) = tm.compare_modes().expect("trials");
    assert_eq!(results.len(), Mode::ALL.len());
    let rendered = table.render();
    assert!(rendered.contains("native"), "table should name the backend");
    let best = trial::recommend(&results, 0.10).expect("recommendation");
    assert!(results.iter().any(|r| r.label == best.label));
}

#[test]
fn backend_reports_workload_and_modes() {
    let backend = NativeBackend::new();
    let cfg = rc(Mode::Full, 1);
    assert_eq!(backend.name(), "native");
    assert!(backend.has_mode(&cfg, Mode::Spt));
    let (batch, seq) = backend.workload(&cfg).unwrap();
    assert_eq!((batch, seq), (2, 32));
    // seq clamps to the model's max_seq.
    let mut big = cfg.clone();
    big.seq = 10_000;
    assert_eq!(backend.workload(&big).unwrap().1, 64); // spt-nano max_seq
    assert_eq!(backend.vocab(&cfg).unwrap(), 512);
}
