//! # SPT — Sparse fine-tuning of Transformer language models
//!
//! Rust + JAX + Pallas reproduction of *"SPT: Fine-Tuning Transformer-based
//! Language Models Efficiently with Sparsification"* (Gui et al., 2023).
//!
//! Three-layer architecture (Python never on the training path):
//!
//! * **L1 (Pallas)** — `python/compile/kernels/`: PQ quantization,
//!   bucket-sort top-L, sparse attention (SDDMM/softmax/SpMM), routed FFN
//!   (BSpMV), each with hand-written backward kernels.
//! * **L2 (JAX)** — `python/compile/model.py` + `train.py`: Transformer
//!   blocks in full/LoRA/SPT modes, AdamW fine-tuning step, lowered AOT to
//!   HLO text by `aot.py`.
//! * **L3 (this crate)** — the fine-tuning coordinator: config system,
//!   synthetic data pipeline, microbatch trainer, sparsity-trial manager,
//!   analytic GPU-memory model, the rust-native sparse substrate
//!   (forward *and* backward), and the harness regenerating every table
//!   and figure of the paper's evaluation.
//!
//! Training is **backend-agnostic** ([`coordinator::Backend`]):
//!
//! * [`coordinator::NativeBackend`] (default) fine-tunes the preset's
//!   full `n_layers`-deep pre-norm transformer stack end-to-end on the
//!   sparse substrate — layer norms, dense projections, PQ + top-L
//!   sparse attention, and the routed FFN all have native backward
//!   passes ([`sparse::grad`], parallel twins in [`sparse::mha`]) with
//!   AdamW applied host-side and the readout tied to the token
//!   embedding.  `spt train`,
//!   `train-qa`, and `trial` work out of the box on any machine.
//! * The PJRT engine ([`runtime`]'s `engine`, `coordinator`'s
//!   `PjrtBackend`) executes pre-lowered AOT artifacts and sits behind
//!   the off-by-default `xla` cargo feature (`--backend pjrt` on the
//!   CLI); the bindings crate is stubbed so `--features xla` still
//!   compiles without a PJRT toolchain.
//!
//! Inference is native too ([`infer`]): `spt generate` loads a
//! checkpoint into an [`infer::InferModel`] and decodes with per-layer
//! K/V + PQ-code caches (sparse top-L attention per new token, routed
//! FFN per token batch), and `spt serve-bench` drives the
//! continuous-batching [`infer::ServeDriver`].  Prefill + N decode
//! steps reproduce the training forward over the full sequence bit for
//! bit, at any thread count.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod infer;
pub mod memmodel;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod sparse;
pub mod util;
