//! Configuration system: model/block presets (paper Table 2), tuning
//! modes, sparsity strengths, and run configuration loadable from
//! TOML-subset files or CLI overrides.

pub mod presets;
pub mod toml;

use anyhow::{bail, Result};

/// Tuning mode (paper baselines: Full, LoRA, and SPT).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    Full,
    Lora,
    Spt,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "full" => Mode::Full,
            "lora" => Mode::Lora,
            "spt" | "sparse" => Mode::Spt,
            other => bail!("unknown mode '{other}' (full|lora|spt)"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Mode::Full => "full",
            Mode::Lora => "lora",
            Mode::Spt => "spt",
        }
    }

    pub const ALL: [Mode; 3] = [Mode::Full, Mode::Lora, Mode::Spt];
}

/// Sparsity strengths (paper §3: "users trade off efficiency and quality
/// by setting L and beta").  Expressed as fractions to stay
/// sequence-length independent.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sparsity {
    /// non-zero attention fraction: L = n * mha_num / mha_den
    pub mha_num: u32,
    pub mha_den: u32,
    /// active parameter fraction: G' = G * ffn_num / ffn_den
    pub ffn_num: u32,
    pub ffn_den: u32,
}

impl Default for Sparsity {
    fn default() -> Self {
        // Paper defaults: top-1/8 attention weights, 1/2 FFN parameters.
        Sparsity { mha_num: 1, mha_den: 8, ffn_num: 1, ffn_den: 2 }
    }
}

impl Sparsity {
    pub fn mha_fraction(&self) -> f64 {
        self.mha_num as f64 / self.mha_den as f64
    }

    pub fn ffn_fraction(&self) -> f64 {
        self.ffn_num as f64 / self.ffn_den as f64
    }

    pub fn topl(&self, n: usize) -> usize {
        ((n as u64 * self.mha_num as u64) / self.mha_den as u64).max(1) as usize
    }

    pub fn active_groups(&self, g: usize) -> usize {
        ((g as u64 * self.ffn_num as u64) / self.ffn_den as u64).max(1) as usize
    }
}

/// One Transformer block shape (paper Table 2 row).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockConfig {
    pub name: String,
    pub d_model: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub activation: Activation,
    pub rotary: bool,
    pub lora_rank: usize,
    pub pq_dsub: usize,
    pub pq_codewords: usize,
    pub ffn_groups: usize,
    pub sparsity: Sparsity,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Gelu,
}

impl BlockConfig {
    pub fn n_heads(&self) -> usize {
        debug_assert_eq!(self.d_model % self.d_head, 0);
        self.d_model / self.d_head
    }

    pub fn pq_m(&self) -> usize {
        self.d_head / self.pq_dsub
    }

    /// Base (pre-trained) parameter count of one block.
    pub fn base_params(&self) -> u64 {
        // wq,wk,wv,wo + w_in/w_out (+ biases + 2 LN scale/bias pairs)
        let d = self.d_model as u64;
        let f = self.d_ffn as u64;
        4 * d * d + 2 * d * f + f + d + 4 * d
    }

    /// Trainable LoRA parameter count of one block (modes lora/spt).
    pub fn lora_params(&self) -> u64 {
        let d = self.d_model as u64;
        let f = self.d_ffn as u64;
        let r = self.lora_rank as u64;
        // q, k, v, o: (d r + r d) each; in: d r + r f; out: f r + r d
        4 * 2 * d * r + (d * r + r * f) + (f * r + r * d)
    }

    /// SPT extras: router + PQ codebooks (q & k).
    pub fn spt_params(&self) -> u64 {
        let router = (self.d_model * self.ffn_groups) as u64;
        let cb = 2 * (self.pq_m() * self.pq_codewords * self.pq_dsub) as u64;
        router + cb
    }

    pub fn trainable_params(&self, mode: Mode) -> u64 {
        match mode {
            Mode::Full => self.base_params(),
            Mode::Lora => self.lora_params(),
            Mode::Spt => {
                self.lora_params() + (self.d_model * self.ffn_groups) as u64
            }
        }
    }
}

/// Full-model configuration (end-to-end fine-tuning).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub block: BlockConfig,
    pub n_layers: usize,
    pub vocab_size: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    pub fn param_count(&self) -> u64 {
        self.n_layers as u64 * self.block.base_params()
            + 2 * (self.vocab_size * self.block.d_model) as u64
            + (self.max_seq * self.block.d_model) as u64
    }
}

/// A fine-tuning run (what the CLI / TOML configures).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub mode: Mode,
    pub batch: usize,
    pub seq: usize,
    pub steps: usize,
    pub eval_every: usize,
    pub codebook_refresh_every: usize, // paper §5.1: every ~20 mini-batches
    /// AdamW learning rate (native backend; PJRT artifacts bake their own).
    pub lr: f64,
    pub seed: u64,
    pub artifacts_dir: String,
    pub out_dir: String,
    /// Memory budget (bytes) the OOM search models (paper: 24 GB RTX3090).
    pub memory_budget: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "spt-tiny".into(),
            mode: Mode::Spt,
            batch: 4,
            seq: 128,
            steps: 100,
            eval_every: 25,
            codebook_refresh_every: 20,
            lr: 1e-3,
            seed: 0,
            artifacts_dir: "artifacts".into(),
            out_dir: "runs".into(),
            memory_budget: 24 * (1 << 30),
        }
    }
}

impl RunConfig {
    /// Apply a `key = value` override (from TOML or `--set key=value`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "model" => self.model = value.to_string(),
            "mode" => self.mode = Mode::parse(value)?,
            "batch" => self.batch = value.parse()?,
            "seq" => self.seq = value.parse()?,
            "steps" => self.steps = value.parse()?,
            "eval_every" => self.eval_every = value.parse()?,
            "codebook_refresh_every" => {
                self.codebook_refresh_every = value.parse()?
            }
            "lr" => self.lr = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "out_dir" => self.out_dir = value.to_string(),
            "memory_budget_gb" => {
                let gb: f64 = value.parse()?;
                self.memory_budget = (gb * (1u64 << 30) as f64) as u64;
            }
            other => bail!("unknown run config key '{other}'"),
        }
        Ok(())
    }

    /// Load from a TOML-subset file, then apply overrides.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let pairs = toml::parse(&text)?;
        let mut rc = RunConfig::default();
        for (k, v) in &pairs {
            // accept both bare keys and [run] section keys
            let key = k.strip_prefix("run.").unwrap_or(k);
            rc.set(key, v)?;
        }
        Ok(rc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in Mode::ALL {
            assert_eq!(Mode::parse(m.as_str()).unwrap(), m);
        }
        assert!(Mode::parse("sparse").unwrap() == Mode::Spt);
        assert!(Mode::parse("nope").is_err());
    }

    #[test]
    fn sparsity_defaults_match_paper() {
        let s = Sparsity::default();
        assert_eq!(s.topl(512), 64); // 512/8
        assert_eq!(s.active_groups(8), 4); // 8/2
        assert_eq!(s.mha_fraction(), 0.125);
        assert_eq!(s.ffn_fraction(), 0.5);
    }

    #[test]
    fn param_counts_scale_as_expected() {
        let b = presets::block("opt-2048").unwrap();
        // 4 d^2 + 2 d F dominates
        let want = 4 * 2048u64 * 2048 + 2 * 2048 * 8192;
        assert!(b.base_params() > want && b.base_params() < want + want / 50);
        // LoRA params are orders of magnitude smaller.
        assert!(b.lora_params() < b.base_params() / 20);
        assert_eq!(b.trainable_params(Mode::Full), b.base_params());
    }

    #[test]
    fn runconfig_overrides() {
        let mut rc = RunConfig::default();
        rc.set("mode", "full").unwrap();
        rc.set("batch", "16").unwrap();
        rc.set("memory_budget_gb", "24").unwrap();
        assert_eq!(rc.mode, Mode::Full);
        assert_eq!(rc.batch, 16);
        assert_eq!(rc.memory_budget, 24 * (1 << 30));
        assert!(rc.set("bogus", "1").is_err());
    }
}
