"""L1 Pallas kernels: sparse attention (SDDMM -> sparse softmax -> SpMM).

Paper mapping (SPT §5.1, Fig. 7): attention over only the top-L keys per
query.  The sparse matrix has a *fixed* L nonzeros per row, so the CSR
``Indptr`` is the implicit ``[0, L, 2L, ...]`` the paper notes, and only the
``Indices [n, L]`` / ``Values [n, L]`` arrays are materialized — this is the
memory win: ``O(nL)`` instead of the dense ``O(n^2)`` attention matrix.

The CUDA artifact calls cuSPARSE (``sddmm_ker``/``csrmm_alg2``).  The
TPU/Pallas adaptation exploits the fixed-L regularity instead: each grid
step gathers its L key/value rows into a dense ``[n, L, d]`` VMEM tile and
hits the VPU/MXU with ordinary dense contractions — regularized sparsity is
what makes sparse compute map onto dense tiles (DESIGN.md
§Hardware-Adaptation).

``pallas_call`` under ``interpret=True`` does not support reverse-mode AD,
so — exactly like the paper's custom CUDA backward ops (Fig. 11 checks both
passes) — every op here carries a hand-written backward Pallas kernel wired
up through ``jax.custom_vjp``:

  d_vals = SDDMM(dy, V)            (same kernel shape as forward SDDMM)
  softmax bwd: dv = w * (dw - sum_l w dw)
  d_q = SpMM(d_vals, K),  d_k = scatter-add of d_vals^T outer q
  d_v = scatter-add of w^T outer dy

The scatter-add transpose kernels keep the whole per-head tile in one block
(VMEM) — at n=512, d<=128 that is <= 256 KiB per operand.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

INTERPRET = True

_NEG = -1e30  # large-negative logit for masked slots (finfo.min overflows exp)


# ---------------------------------------------------------------------------
# Forward kernels
# ---------------------------------------------------------------------------


def _sddmm_kernel(q_ref, k_ref, idx_ref, vals_ref):
    """vals[i, l] = q_i . k_{idx[i, l]} for one batch-head instance."""
    q = q_ref[0]  # [n, d]
    k = k_ref[0]  # [n, d]
    idx = idx_ref[0]  # [n, L]
    kg = k[idx]  # [n, L, d] dense gather tile
    vals_ref[0] = jnp.einsum("nd,nld->nl", q, kg)


def _softmax_kernel(vals_ref, valid_ref, w_ref):
    """Masked row softmax over the L sampled entries."""
    vals = vals_ref[0]  # [n, L]
    valid = valid_ref[0] != 0  # [n, L]
    masked = jnp.where(valid, vals, _NEG)
    mx = jnp.max(masked, axis=-1, keepdims=True)
    ex = jnp.where(valid, jnp.exp(masked - mx), 0.0)
    denom = jnp.sum(ex, axis=-1, keepdims=True)
    w_ref[0] = ex / jnp.maximum(denom, 1e-30)


def _spmm_kernel(w_ref, idx_ref, v_ref, y_ref):
    """y_i = sum_l w[i, l] * v[idx[i, l]]."""
    w = w_ref[0]  # [n, L]
    idx = idx_ref[0]  # [n, L]
    v = v_ref[0]  # [n, d]
    vg = v[idx]  # [n, L, d]
    y_ref[0] = jnp.einsum("nl,nld->nd", w, vg)


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------


def _softmax_bwd_kernel(w_ref, dw_ref, dvals_ref):
    """dvals = w * (dw - sum_l w * dw), rowwise."""
    w = w_ref[0]
    dw = dw_ref[0]
    inner = jnp.sum(w * dw, axis=-1, keepdims=True)
    dvals_ref[0] = w * (dw - inner)


def _scatter_outer_kernel(coef_ref, idx_ref, src_ref, out_ref):
    """out[j] += sum over (i,l) with idx[i,l]==j of coef[i,l] * src[i].

    The shared transpose pattern: d_k (coef=d_vals, src=q) and
    d_v (coef=w, src=dy).
    """
    coef = coef_ref[0]  # [n, L]
    idx = idx_ref[0]  # [n, L]
    src = src_ref[0]  # [n, d]
    n, l = coef.shape
    d = src.shape[1]
    contrib = coef[:, :, None] * src[:, None, :]  # [n, L, d]
    out = jnp.zeros((n, d), dtype=src.dtype)
    out_ref[0] = out.at[idx.reshape(-1)].add(contrib.reshape(n * l, d))


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------


def _bh_call(kernel, out_shape, *args):
    """Run `kernel` once per leading (batch*head) index with full blocks."""
    b = args[0].shape[0]
    specs = [
        # nd=a.ndim default-arg pins the per-array rank (late-binding trap).
        pl.BlockSpec(
            (1,) + a.shape[1:], lambda bi, nd=a.ndim: (bi,) + (0,) * (nd - 1)
        )
        for a in args
    ]
    nd_out = len(out_shape)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=specs,
        out_specs=pl.BlockSpec(
            (1,) + out_shape[1:], lambda bi: (bi,) + (0,) * (nd_out - 1)
        ),
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=INTERPRET,
    )(*args)


def sddmm(q: jax.Array, k: jax.Array, indices: jax.Array) -> jax.Array:
    """Sampled dense-dense matmul.  q,k: [b,n,d]; indices: [b,n,L] -> [b,n,L]."""
    b, n, _ = q.shape
    l = indices.shape[-1]
    return _bh_call(_sddmm_kernel, (b, n, l), q, k, indices)


def sparse_softmax_fwd(vals: jax.Array, valid: jax.Array) -> jax.Array:
    """Masked softmax over sampled entries. vals,valid(int32): [b,n,L]."""
    return _bh_call(_softmax_kernel, vals.shape, vals, valid)


def spmm(w: jax.Array, indices: jax.Array, v: jax.Array) -> jax.Array:
    """Sparse-weights @ dense-V. w:[b,n,L] idx:[b,n,L] v:[b,n,d] -> [b,n,d]."""
    b, n, _ = w.shape
    d = v.shape[-1]
    return _bh_call(_spmm_kernel, (b, n, d), w, indices, v)


def _softmax_bwd(w: jax.Array, dw: jax.Array) -> jax.Array:
    return _bh_call(_softmax_bwd_kernel, w.shape, w, dw)


def _scatter_outer(coef: jax.Array, idx: jax.Array, src: jax.Array) -> jax.Array:
    b, n, _ = coef.shape
    d = src.shape[-1]
    return _bh_call(_scatter_outer_kernel, (b, n, d), coef, idx, src)


# ---------------------------------------------------------------------------
# Validity mask (causal + duplicate suppression)
# ---------------------------------------------------------------------------


def _make_valid_mask_kernel(causal: bool, l: int):
    def kernel(idx_ref, valid_ref):
        idx = idx_ref[0]  # [n, L]
        n = idx.shape[0]
        valid = jnp.ones(idx.shape, dtype=jnp.int32)
        if causal:
            rows = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 0)
            valid = valid * (idx <= rows).astype(jnp.int32)
        # Duplicate suppression via a static slot loop (keep the first
        # occurrence).  NOTE: the obvious [n, L, L] tril-broadcast
        # formulation is miscompiled by xla_extension 0.5.1 (wrong slots
        # masked); the unrolled pairwise comparison lowers to simple
        # 2-D ops that the old backend executes exactly — the same
        # pattern the bucket-sort kernel relies on.
        cols = []
        for j in range(l):
            if j == 0:
                cols.append(jnp.ones((n,), dtype=jnp.int32))
                continue
            dup_j = jnp.zeros((n,), dtype=jnp.int32)
            for k in range(j):
                dup_j = jnp.maximum(
                    dup_j, (idx[:, k] == idx[:, j]).astype(jnp.int32)
                )
            cols.append(1 - dup_j)
        nodup = jnp.stack(cols, axis=1)  # [n, L]
        valid_ref[0] = valid * nodup

    return kernel


def make_valid_mask(indices: jax.Array, causal: bool) -> jax.Array:
    """int32 [b, n, L]: 1 where the sampled slot participates in softmax.

    A slot is invalid when (a) causal and key > query, or (b) its key index
    duplicates an earlier slot in the row (top-L padding).  Implemented as
    a Pallas kernel (see note in `_make_valid_mask_kernel`).
    """
    b, n, l = indices.shape
    return pl.pallas_call(
        _make_valid_mask_kernel(causal, l),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n, l), lambda bi: (bi, 0, 0))],
        out_specs=pl.BlockSpec((1, n, l), lambda bi: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, l), jnp.int32),
        interpret=INTERPRET,
    )(indices)


# ---------------------------------------------------------------------------
# Composite op with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def sparse_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    indices: jax.Array,
    causal: bool = False,
    scale: float | None = None,
) -> jax.Array:
    """Sparse MHA core for a batch of heads (paper Alg. 1 lines 4-5).

    Args:
      q, k, v: ``[b, n, d]`` per-head projections (b = batch * heads).
      indices: ``[b, n, L]`` top-L key ids per query (from topl.topl_select);
        treated as non-differentiable.
      causal: apply the decoder look-ahead mask.
      scale: logit scale, default ``1/sqrt(d)``.

    Returns:
      ``[b, n, d]`` attention outputs.
    """
    y, _ = _sparse_attention_fwd(q, k, v, indices, causal, scale)
    return y


def _sparse_attention_fwd(q, k, v, indices, causal, scale):
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    valid = make_valid_mask(indices, causal)
    vals = sddmm(q * scale, k, indices)
    w = sparse_softmax_fwd(vals, valid)
    y = spmm(w, indices, v)
    return y, (q, k, v, indices, w)


def _sparse_attention_bwd(causal, scale, res, dy):
    q, k, v, indices, w = res
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    # dL/dw[i,l] = dy_i . v[idx[i,l]]  — SDDMM-shaped.
    dw = sddmm(dy, v, indices)
    # dL/dv[j] += w[i,l] * dy_i for idx[i,l] == j — scatter-outer.
    dv = _scatter_outer(w, indices, dy)
    # softmax backward.
    dvals = _softmax_bwd(w, dw)
    # dL/dq_i = scale * sum_l dvals[i,l] k[idx[i,l]] — SpMM-shaped.
    dq = spmm(dvals, indices, k) * scale
    # dL/dk[j] += scale * dvals[i,l] * q_i for idx[i,l]==j — scatter-outer.
    dk = _scatter_outer(dvals, indices, q * scale)
    d_idx = np.zeros(indices.shape, dtype=jax.dtypes.float0)
    return dq, dk, dv, d_idx


sparse_attention.defvjp(_sparse_attention_fwd, _sparse_attention_bwd)
