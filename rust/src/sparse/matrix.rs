//! Dense row-major f32 matrix — the substrate's working representation —
//! plus the blocked GEMM layer the training hot paths run on.
//!
//! ## The microkernel
//!
//! [`gemm_into`] computes `C = A @ B` with the B operand packed once per
//! call into column panels of [`NR`] floats, then tiled over (M, N, K)
//! with [`MC`]-row × [`KC`]-deep blocks so one panel tile stays cache
//! resident while a row block streams over it.  Inside a block the work
//! runs through the register-blocked microkernel in [`super::kernel`]:
//! [`kernel::MR`] rows of A at a time against [`kernel::LANES`]-wide
//! column strips of the panel, with the partial sums held in fixed-width
//! accumulator arrays the compiler keeps in vector registers (safe,
//! autovectorizable code — the workspace forbids `unsafe`).
//!
//! Vectorization runs across the column dimension only, so every output
//! element still accumulates in plain ascending-`k` order with separate
//! mul and add (no FMA contraction) — bit-identical to the naive triple
//! loop at any blocking and any thread count (the property all substrate
//! parallelism maintains).  Unlike the pre-register-blocked kernel, the
//! dense path no longer branches on `a == 0.0`: for finite operands,
//! adding `±0.0 * b` is an identity on an accumulator that starts at
//! `+0.0` and can never become `-0.0` under round-to-nearest-even, so
//! dropping the skip is bitwise neutral while removing a per-`k` branch
//! from the inner loop.  (Genuinely sparse consumers — CSR `spmm`, the
//! decode attention rows — keep their skip, where it means skipping
//! whole rows of work, not single scalars.)
//!
//! [`gemm_nt_into`] (`C = A @ B^T`) rides the same microkernel: the B
//! block is transpose-packed by [`pack_bt`] into the identical panel
//! layout, which preserves each output element's single ascending-order
//! dot product.  Tiny row counts (below [`NT_PACK_MIN_ROWS`], e.g. the
//! decode path's one-row readout) skip the packing pass and run the
//! per-row dot kernel directly — both paths are bit-identical, so the
//! threshold is a pure performance knob.
//!
//! Both kernels address B as `row * stride + column offset`, so callers
//! can multiply against a column block or row block of a larger matrix
//! (the routed FFN's `W_I[g]` / `W_O[g]`) without materializing the
//! slice — the packing walks the block in place.
//!
//! ## Workspaces
//!
//! [`Workspace`] owns the pack/transpose scratch; the `*_into` / `*_ws`
//! variants reuse it across calls so steady-state training stops
//! allocating fresh buffers per GEMM.  Workspace contents never affect
//! results: a fresh and a reused workspace produce identical bits.

use rayon::prelude::*;

use super::kernel;
use crate::util::rng::Rng;

/// Below this many multiply-adds the GEMMs stay sequential (forking the
/// rayon pool costs more than the product itself).
const PAR_MATMUL_FLOPS: usize = 1 << 16;

/// Packed-B panel width (columns), the unit of N tiling.
const NR: usize = 64;
/// K (depth) tile: one `KC x NR` panel tile is 32 KiB — comfortably
/// cache-resident while a row block streams over it.
const KC: usize = 128;
/// Rows of C per cache block and per parallel task.
const MC: usize = 32;
/// B rows per block of the small-m NT fallback kernel (reused across a
/// C row block).
const NJ: usize = 32;
/// Below this many A rows, [`gemm_nt_into`] skips the transpose-packing
/// pass and runs the per-row dot kernel directly (packing `k x n` floats
/// to feed one or two rows costs more than it saves).  Both paths are
/// bit-identical, so the threshold cannot affect results.
const NT_PACK_MIN_ROWS: usize = 4;

/// Reusable scratch for the blocked GEMM kernels: the packed-B buffer,
/// a transpose scratch, and two matrix slots for O(n²) attention
/// transients (logits/probabilities and their gradients).  Contents are
/// meaningless between calls — any workspace, including a fresh one,
/// produces identical results.
#[derive(Debug, Default)]
pub struct Workspace {
    pub(crate) packb: Vec<f32>,
    pub(crate) tmp: Vec<f32>,
    pub(crate) attn: Matrix,
    pub(crate) attn2: Matrix,
}

impl Workspace {
    /// Bytes this workspace has grown to (capacity high-water across all
    /// buffers).  A pure read for the observability memory-truth channel:
    /// capacities only ever grow, so the value is the allocation
    /// high-water of every GEMM this workspace has served.
    pub fn bytes(&self) -> u64 {
        ((self.packb.capacity()
            + self.tmp.capacity()
            + self.attn.data.capacity()
            + self.attn2.data.capacity())
            * 4) as u64
    }
}

/// A B operand packed once into the microkernel's column-panel layout
/// (the output of [`pack_b`] over the whole matrix), so repeated
/// `A @ B` products against the same B — every decode step's projection,
/// every train step's per-item forward — skip the per-call packing pass.
///
/// [`gemm_packed_into`] consumes it and is bit-identical to
/// [`gemm_into`] with the same operands: the panel layout and the
/// per-element accumulation order are exactly the per-call path's.
/// A `PackedB` is immutable; invalidation is by construction — callers
/// rebuild it whenever the underlying weight changes (the native
/// backend re-materializes its `Weights` after every optimizer update).
#[derive(Debug, Clone)]
pub struct PackedB {
    /// Rows of the packed B (the GEMM's K dimension).
    pub k: usize,
    /// Columns of the packed B (the GEMM's N dimension).
    pub n: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Pack all of `b` once.  Equivalent to the packing [`gemm_into`]
    /// performs internally on every call.
    pub fn pack(b: &Matrix) -> Self {
        let mut data = Vec::new();
        pack_b(b.rows, b.cols, &b.data, b.cols, 0, &mut data);
        let pb = PackedB { k: b.rows, n: b.cols, data };
        pb.debug_validate();
        pb
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Debug-build contract check: the panel buffer holds exactly the
    /// `k × n` floats [`pack_b`] lays out (full column panels of [`NR`]
    /// floats, each spanning all `k` rows).  Called at construction and
    /// at kernel entry; compiles to nothing in release builds.
    #[inline]
    pub fn debug_validate(&self) {
        if cfg!(debug_assertions) {
            debug_assert_eq!(self.data.len(), self.k * self.n, "PackedB panel geometry");
        }
    }
}

/// Blocked GEMM against a pre-packed B: `out[m x n] = a[m x pb.k] @ B`.
/// Bit-identical to [`gemm_into`] with the same logical operands — the
/// same row-block kernel runs over the same panel layout, with the same
/// parallelization threshold.
pub fn gemm_packed_into(m: usize, a: &[f32], pb: &PackedB, out: &mut [f32]) {
    pb.debug_validate();
    let (k, n) = (pb.k, pb.n);
    assert!(a.len() >= m * k, "gemm_packed: A too small");
    assert_eq!(out.len(), m * n, "gemm_packed: C shape mismatch");
    out.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let pack: &[f32] = &pb.data;
    if m * k * n >= PAR_MATMUL_FLOPS {
        out.par_chunks_mut(MC * n)
            .enumerate()
            .for_each(|(ci, chunk)| {
                gemm_rows(ci * MC, chunk.len() / n, k, n, a, pack, chunk);
            });
    } else {
        gemm_rows(0, m, k, n, a, pack, out);
    }
}

/// Dense row-major matrix.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal() * scale).collect();
        Matrix { rows, cols, data }
    }

    /// Reshape to `rows x cols`, reusing the allocation; contents zeroed.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.reset_any(rows, cols);
        self.data.fill(0.0);
    }

    /// Reshape to `rows x cols`, reusing the allocation; contents
    /// *unspecified* when the element count is unchanged.  For consumers
    /// that overwrite every element anyway (the GEMM kernels zero-fill
    /// their output; gathers copy every row), this skips the redundant
    /// memset the steady-state hot path would otherwise pay per op.
    pub(crate) fn reset_any(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        if self.data.len() != rows * cols {
            self.data.clear();
            self.data.resize(rows * cols, 0.0);
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self @ other` through the blocked microkernel, allocating both
    /// the output and a transient workspace.  Hot paths should prefer
    /// [`Self::matmul_ws`] / [`Self::matmul_into`] with a reused
    /// [`Workspace`]; the result is bit-identical either way.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        self.matmul_ws(other, &mut Workspace::default())
    }

    /// `self @ other`, reusing `ws` for the packed-B panels.
    pub fn matmul_ws(&self, other: &Matrix, ws: &mut Workspace) -> Matrix {
        let mut out = Matrix::default();
        self.matmul_into(other, &mut out, ws);
        out
    }

    /// `out = self @ other`, reusing both the output allocation and the
    /// workspace pack buffer.
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        out.reset_any(self.rows, other.cols);
        gemm_into(
            self.rows,
            self.cols,
            other.cols,
            &self.data,
            &other.data,
            other.cols,
            0,
            &mut out.data,
            &mut ws.packb,
        );
    }

    /// `self @ B` against a B packed once with [`PackedB::pack`]
    /// (weight-stationary hot paths: decode steps, per-item training
    /// forwards).  Bit-identical to [`Self::matmul`] with the unpacked B.
    pub fn matmul_packed(&self, pb: &PackedB) -> Matrix {
        let mut out = Matrix::default();
        self.matmul_packed_into(pb, &mut out);
        out
    }

    /// [`Self::matmul_packed`] into a reusable output allocation.
    pub fn matmul_packed_into(&self, pb: &PackedB, out: &mut Matrix) {
        assert_eq!(self.cols, pb.k, "matmul_packed shape mismatch");
        out.reset_any(self.rows, pb.n);
        gemm_packed_into(self.rows, &self.data, pb, &mut out.data);
    }

    /// Elementwise sum (residual connections in the native model).
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "add shape mismatch");
        assert_eq!(self.cols, other.cols, "add shape mismatch");
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise in-place accumulate.
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.rows, other.rows, "add_assign shape mismatch");
        assert_eq!(self.cols, other.cols, "add_assign shape mismatch");
        for (o, &b) in self.data.iter_mut().zip(&other.data) {
            *o += b;
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::default();
        self.transpose_into(&mut out);
        out
    }

    /// Blocked transpose into a reusable output matrix.
    pub fn transpose_into(&self, out: &mut Matrix) {
        out.rows = self.cols;
        out.cols = self.rows;
        transpose_slice(self.rows, self.cols, &self.data, &mut out.data);
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn relu(&self) -> Matrix {
        self.map(|x| x.max(0.0))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Row-wise softmax (dense attention baseline).
    pub fn softmax_rows(&self) -> Matrix {
        let mut out = self.clone();
        out.softmax_rows_inplace();
        out
    }

    /// Row-wise softmax in place (same per-row operation order as
    /// [`Self::softmax_rows`]).
    pub fn softmax_rows_inplace(&mut self) {
        for r in 0..self.rows {
            let row = self.row_mut(r);
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for x in row.iter_mut() {
                *x = (*x - mx).exp();
                sum += *x;
            }
            for x in row.iter_mut() {
                *x /= sum.max(1e-30);
            }
        }
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// Pack columns `[b_col0, b_col0 + n)` of the row-major `b` (`k` rows,
/// row stride `b_stride`) into column panels of [`NR`] floats: panel `p`
/// holds rows `0..k` of its column range, row-major within the panel, so
/// the microkernel streams each `KC x NR` tile contiguously.
fn pack_b(k: usize, n: usize, b: &[f32], b_stride: usize, b_col0: usize, pack: &mut Vec<f32>) {
    pack.clear();
    pack.reserve(k * n);
    let mut p0 = 0;
    while p0 < n {
        let w = NR.min(n - p0);
        for kk in 0..k {
            let off = kk * b_stride + b_col0 + p0;
            pack.extend_from_slice(&b[off..off + w]);
        }
        p0 += w;
    }
}

/// The per-row-block driver of [`gemm_into`]: accumulate rows
/// `[row0, row0 + rows)` of C against the packed B panels through the
/// register-blocked [`kernel::gemm_block`].  The K-block loop is
/// outermost and ascending, and within a block `kk` ascends, so every
/// output element accumulates in plain ascending-`k` order — identical
/// to the naive loop, independent of tiling.  (Accumulators round-trip
/// through `out` at K-block boundaries; the f32 store/load is exact, so
/// the chain is unbroken.)
fn gemm_rows(
    row0: usize,
    rows: usize,
    k: usize,
    n: usize,
    a: &[f32],
    pack: &[f32],
    out: &mut [f32],
) {
    let a_block = &a[row0 * k..row0 * k + rows * k];
    let mut kb = 0;
    while kb < k {
        let kw = KC.min(k - kb);
        let mut p0 = 0;
        while p0 < n {
            let w = NR.min(n - p0);
            // Panel p0 starts after p0 full columns of k rows each.
            let panel = &pack[p0 * k..p0 * k + k * w];
            kernel::gemm_block(rows, k, kb, kb + kw, n, p0, w, a_block, panel, out);
            p0 += w;
        }
        kb += kw;
    }
}

/// Blocked GEMM: `out[m x n] = a[m x k] @ B`, where B is the column
/// block `[b_col0, b_col0 + n)` of a row-major buffer with row stride
/// `b_stride`.  `out` is fully overwritten.  Row-parallel above
/// [`PAR_MATMUL_FLOPS`]; bit-identical at any thread count (see the
/// module docs).  `pack` is the reusable packed-B scratch.
pub fn gemm_into(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    b_stride: usize,
    b_col0: usize,
    out: &mut [f32],
    pack: &mut Vec<f32>,
) {
    assert!(a.len() >= m * k, "gemm: A too small");
    assert_eq!(out.len(), m * n, "gemm: C shape mismatch");
    if k > 0 && n > 0 {
        assert!(
            (k - 1) * b_stride + b_col0 + n <= b.len(),
            "gemm: B block out of bounds"
        );
    }
    out.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    pack_b(k, n, b, b_stride, b_col0, pack);
    let pack: &[f32] = pack;
    if m * k * n >= PAR_MATMUL_FLOPS {
        out.par_chunks_mut(MC * n)
            .enumerate()
            .for_each(|(ci, chunk)| {
                gemm_rows(ci * MC, chunk.len() / n, k, n, a, pack, chunk);
            });
    } else {
        gemm_rows(0, m, k, n, a, pack, out);
    }
}

/// Transpose-pack rows `[0, n)` of the NT operand (row `j` of B at
/// `b[j * b_stride + b_col0 ..][..kdim]`) into the same column-panel
/// layout [`pack_b`] produces for `B^T`: panel `p` holds packed rows
/// `0..kdim`, each a `w`-wide strip of B-rows `p0..p0 + w`.  After this
/// pass [`gemm_rows`] runs unchanged, and each output element is still
/// the single ascending-order dot `Σ a[i][kk] * b[j][kk]`.
fn pack_bt(
    kdim: usize,
    n: usize,
    b: &[f32],
    b_stride: usize,
    b_col0: usize,
    pack: &mut Vec<f32>,
) {
    // Every element is overwritten below; only grow/shrink zero-fills.
    if pack.len() != kdim * n {
        pack.clear();
        pack.resize(kdim * n, 0.0);
    }
    let mut base = 0;
    let mut p0 = 0;
    while p0 < n {
        let w = NR.min(n - p0);
        for jj in 0..w {
            let off = (p0 + jj) * b_stride + b_col0;
            let b_row = &b[off..off + kdim];
            for (kk, &v) in b_row.iter().enumerate() {
                pack[base + kk * w + jj] = v;
            }
        }
        base += kdim * w;
        p0 += w;
    }
}

/// The small-m kernel of [`gemm_nt_into`]: each output element is one
/// ascending-order dot product, with B processed in [`NJ`]-row blocks so
/// a block is reused across the chunk's rows.
fn gemm_nt_rows(
    row0: usize,
    rows: usize,
    kdim: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    b_stride: usize,
    b_col0: usize,
    out: &mut [f32],
) {
    let mut j0 = 0;
    while j0 < n {
        let jw = NJ.min(n - j0);
        for i in 0..rows {
            let a_row = &a[(row0 + i) * kdim..(row0 + i) * kdim + kdim];
            for j in j0..j0 + jw {
                let off = j * b_stride + b_col0;
                let b_row = &b[off..off + kdim];
                out[i * n + j] = kernel::dot(a_row, b_row);
            }
        }
        j0 += jw;
    }
}

/// Blocked `out[m x n] = a[m x kdim] @ B^T`, where row `j` of B lives at
/// `b[j * b_stride + b_col0 ..][..kdim]` — i.e. B is a row or column
/// block of a larger row-major matrix, multiplied without materializing
/// the transpose.  `out` is fully overwritten; row-parallel above
/// [`PAR_MATMUL_FLOPS`] and bit-identical at any thread count.
///
/// At [`NT_PACK_MIN_ROWS`] rows or more, the B block is transpose-packed
/// by [`pack_bt`] into `pack` and the product runs through the same
/// register-blocked kernel as [`gemm_into`]; below it, the per-row dot
/// kernel runs directly.  Each output element is the ascending-order dot
/// `Σ a[i][kk] * b[j][kk]` on both paths, so the results are
/// bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_into(
    m: usize,
    kdim: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    b_stride: usize,
    b_col0: usize,
    out: &mut [f32],
    pack: &mut Vec<f32>,
) {
    assert!(a.len() >= m * kdim, "gemm_nt: A too small");
    assert_eq!(out.len(), m * n, "gemm_nt: C shape mismatch");
    if n > 0 && kdim > 0 {
        assert!(
            (n - 1) * b_stride + b_col0 + kdim <= b.len(),
            "gemm_nt: B block out of bounds"
        );
    }
    out.fill(0.0);
    if m == 0 || n == 0 || kdim == 0 {
        return;
    }
    if m < NT_PACK_MIN_ROWS {
        // Tiny row counts (decode readouts, single-row probes) never hit
        // the parallel threshold's MC-row chunking anyway: run the dot
        // kernel sequentially and skip the packing pass.
        gemm_nt_rows(0, m, kdim, n, a, b, b_stride, b_col0, out);
        return;
    }
    pack_bt(kdim, n, b, b_stride, b_col0, pack);
    let pack: &[f32] = pack;
    if m * kdim * n >= PAR_MATMUL_FLOPS {
        out.par_chunks_mut(MC * n)
            .enumerate()
            .for_each(|(ci, chunk)| {
                gemm_rows(ci * MC, chunk.len() / n, kdim, n, a, pack, chunk);
            });
    } else {
        gemm_rows(0, m, kdim, n, a, pack, out);
    }
}

/// Blocked transpose of `src` (`rows x cols`, row-major) into `dst`
/// (`cols x rows`), reusing the destination allocation.
pub(crate) fn transpose_slice(rows: usize, cols: usize, src: &[f32], dst: &mut Vec<f32>) {
    assert_eq!(src.len(), rows * cols, "transpose shape mismatch");
    // Every element is overwritten below; only grow/shrink zero-fills.
    if dst.len() != rows * cols {
        dst.clear();
        dst.resize(rows * cols, 0.0);
    }
    const TB: usize = 32;
    let mut r0 = 0;
    while r0 < rows {
        let rl = (r0 + TB).min(rows);
        let mut c0 = 0;
        while c0 < cols {
            let cl = (c0 + TB).min(cols);
            for r in r0..rl {
                for c in c0..cl {
                    dst[c * rows + r] = src[r * cols + c];
                }
            }
            c0 = cl;
        }
        r0 = rl;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The pre-microkernel reference: plain triple loop, ascending k,
    /// zero-`a` terms skipped — the order the blocked kernel must match
    /// bit for bit.  (The register-blocked kernel no longer skips zero
    /// terms, but for finite B that is bitwise inert: the accumulator
    /// starts at `+0.0`, can never turn `-0.0` under round-to-nearest-
    /// even, and `acc + ±0.0` is then the identity — so this reference,
    /// skip and all, still pins the exact bits.)
    fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.rows);
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for (k, &av) in a.row(i).iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let orow = out.row_mut(i);
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut eye = Matrix::zeros(4, 4);
        for i in 0..4 {
            *eye.at_mut(i, i) = 1.0;
        }
        assert!(a.matmul(&eye).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn blocked_matmul_matches_naive_bits_across_tile_boundaries() {
        // Shapes straddling the MC/KC/NR tile edges, plus scattered
        // zeros to exercise the skip path.
        let mut rng = Rng::new(3);
        for (m, k, n) in [
            (1, 1, 1),
            (MC - 1, KC - 1, NR - 1),
            (MC + 3, KC + 5, NR + 7),
            (2 * MC + 1, 2 * KC + 3, 2 * NR + 9),
            (7, 300, 90),
        ] {
            let mut a = Matrix::randn(m, k, 1.0, &mut rng);
            for (i, v) in a.data.iter_mut().enumerate() {
                if i % 7 == 0 {
                    *v = 0.0;
                }
            }
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let got = a.matmul(&b);
            let want = matmul_naive(&a, &b);
            assert_eq!(got, want, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_column_block_matches_materialized_slice() {
        // Multiplying against a column block of B in place must equal
        // multiplying against a copied-out slice.
        let mut rng = Rng::new(4);
        let (m, k, n_full, col0, n) = (9, 37, 50, 12, 20);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n_full, 1.0, &mut rng);
        let mut b_slice = Matrix::zeros(k, n);
        for r in 0..k {
            b_slice.row_mut(r).copy_from_slice(&b.row(r)[col0..col0 + n]);
        }
        let want = a.matmul(&b_slice);
        let mut out = vec![0.0f32; m * n];
        let mut pack = Vec::new();
        gemm_into(m, k, n, &a.data, &b.data, b.cols, col0, &mut out, &mut pack);
        assert_eq!(out, want.data);
    }

    #[test]
    fn gemm_nt_matches_explicit_transpose_bits() {
        // Shapes cover both NT paths: below NT_PACK_MIN_ROWS (per-row
        // dot kernel) and at/above it (transpose-pack + register-blocked
        // kernel), with kd crossing the KC boundary.
        let mut rng = Rng::new(5);
        let mut pack = Vec::new();
        for (m, kd, n) in [(1, 9, 6), (3, 5, 4), (4, 17, 9), (40, 70, 45), (65, 129, 33)] {
            let a = Matrix::randn(m, kd, 1.0, &mut rng);
            let b = Matrix::randn(n, kd, 1.0, &mut rng);
            let want = a.matmul(&b.transpose());
            let mut out = vec![0.0f32; m * n];
            gemm_nt_into(m, kd, n, &a.data, &b.data, b.cols, 0, &mut out, &mut pack);
            // Both sides are the same ascending-k chain per element, so
            // equality is bitwise, not approximate.
            let gb: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u32> = want.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "{m}x{kd}x{n}");
        }
    }

    #[test]
    fn gemm_nt_column_block_matches_materialized_slice() {
        // NT against a column block of a wider B (the routed FFN's
        // per-group W_I slices) must equal NT against a copied-out slice,
        // on both the dot path and the packed path.
        let mut rng = Rng::new(9);
        let (kd, n_full, col0) = (21, 40, 7);
        let b = Matrix::randn(n_full, 33, 1.0, &mut rng);
        let mut b_slice = Matrix::zeros(12, kd);
        for r in 0..12 {
            b_slice
                .row_mut(r)
                .copy_from_slice(&b.row(r)[col0..col0 + kd]);
        }
        let mut pack = Vec::new();
        for m in [2usize, 10] {
            let a = Matrix::randn(m, kd, 1.0, &mut rng);
            let want = a.matmul(&b_slice.transpose());
            let mut out = vec![0.0f32; m * 12];
            gemm_nt_into(m, kd, 12, &a.data, &b.data, b.cols, col0, &mut out, &mut pack);
            assert_eq!(out, want.data, "m={m}");
        }
    }

    #[test]
    fn matmul_into_reuses_buffers_and_matches_matmul() {
        let mut rng = Rng::new(6);
        let mut ws = Workspace::default();
        let mut out = Matrix::default();
        for (m, k, n) in [(20, 30, 40), (5, 8, 3), (33, 65, 70)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            a.matmul_into(&b, &mut out, &mut ws);
            assert_eq!(out, a.matmul(&b), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn packed_matmul_matches_per_call_packing_bits() {
        // Pack-once must reproduce the per-call path exactly, across
        // shapes straddling the panel/tile boundaries and under both the
        // sequential and the row-parallel dispatch.
        let mut rng = Rng::new(8);
        for (m, k, n) in [(1, 1, 1), (5, 8, 3), (MC + 3, KC + 5, NR + 7), (64, 48, 64)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let pb = PackedB::pack(&b);
            assert_eq!((pb.k, pb.n), (k, n));
            let got = a.matmul_packed(&pb);
            assert_eq!(got, a.matmul(&b), "{m}x{k}x{n}");
            // Reusing the same pack for a second A is still exact.
            let a2 = Matrix::randn(m, k, 1.0, &mut rng);
            assert_eq!(a2.matmul_packed(&pb), a2.matmul(&b), "{m}x{k}x{n} reuse");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(3, 5, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn blocked_transpose_matches_elementwise() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(67, 41, 1.0, &mut rng);
        let t = a.transpose();
        assert_eq!((t.rows, t.cols), (41, 67));
        for r in 0..a.rows {
            for c in 0..a.cols {
                assert_eq!(t.at(c, r), a.at(r, c));
            }
        }
    }

    #[test]
    fn reset_reuses_allocation() {
        let mut m = Matrix::zeros(4, 4);
        m.data[0] = 9.0;
        m.reset(2, 3);
        assert_eq!((m.rows, m.cols), (2, 3));
        assert!(m.data.iter().all(|&x| x == 0.0));
        assert_eq!(m.data.len(), 6);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(6, 9, 2.0, &mut rng);
        let s = a.softmax_rows();
        for r in 0..6 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_clamps() {
        let a = Matrix::from_vec(1, 3, vec![-1.0, 0.0, 2.0]);
        assert_eq!(a.relu().data, vec![0.0, 0.0, 2.0]);
    }

    #[test]
    fn add_and_add_assign() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![0.5, -2.0, 1.0, 0.0]);
        let c = a.add(&b);
        assert_eq!(c.data, vec![1.5, 0.0, 4.0, 4.0]);
        let mut d = a.clone();
        d.add_assign(&b);
        assert_eq!(d, c);
    }

    #[test]
    fn parallel_matmul_matches_sequential_bits() {
        // Above the parallel threshold the row-parallel path must produce
        // the same bits as a 1-thread pool run of the same call.
        let mut rng = Rng::new(7);
        let a = Matrix::randn(64, 48, 1.0, &mut rng);
        let b = Matrix::randn(48, 64, 1.0, &mut rng);
        assert!(64 * 48 * 64 >= super::PAR_MATMUL_FLOPS);
        let par = a.matmul(&b);
        let pool = rayon::ThreadPoolBuilder::new()
            .num_threads(1)
            .build()
            .expect("pool");
        let seq = pool.install(|| a.matmul(&b));
        assert_eq!(par, seq);
    }
}
