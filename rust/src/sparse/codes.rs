//! Flat contiguous storage for PQ codes and top-L selections.
//!
//! `pq::quantize`, `topl::select`, and `naive_pq::select` used to return
//! `Vec<Vec<_>>` — one heap allocation per query row, which made the
//! batched multi-head path allocation-bound and hostile to parallel
//! chunking.  [`Codes`] and [`TopL`] hold the same data row-major in a
//! single buffer, so per-(head × query-chunk) workers slice disjoint
//! windows without locks or per-row allocation, and the whole structure
//! moves through caches as one contiguous block.

/// PQ codeword ids for `n` vectors × `m` subspaces, row-major.
/// `u8` suffices: E <= 256 always (the paper uses E = 16).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Codes {
    pub n: usize,
    pub m: usize,
    /// `[n * m]`, row `i` at `i * m .. (i + 1) * m`.
    pub data: Vec<u8>,
}

impl Codes {
    pub fn zeros(n: usize, m: usize) -> Self {
        assert!(m >= 1, "need at least one subspace");
        Codes { n, m, data: vec![0u8; n * m] }
    }

    /// Build from per-row code vectors (tests / interop).
    pub fn from_rows(rows: &[Vec<u8>]) -> Self {
        assert!(!rows.is_empty(), "no rows");
        let m = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * m);
        for r in rows {
            assert_eq!(r.len(), m, "ragged code rows");
            data.extend_from_slice(r);
        }
        Codes { n: rows.len(), m, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[u8] {
        &self.data[i * self.m..(i + 1) * self.m]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [u8] {
        &mut self.data[i * self.m..(i + 1) * self.m]
    }

    /// Iterate rows as slices.
    pub fn rows(&self) -> std::slice::ChunksExact<'_, u8> {
        self.data.chunks_exact(self.m)
    }

    /// Back to nested rows (tests / interop only).
    pub fn to_rows(&self) -> Vec<Vec<u8>> {
        self.rows().map(<[u8]>::to_vec).collect()
    }

    /// Stored bytes (the paper's O(nM) code memory).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Debug-build contract check: the buffer is exactly `n × m` and
    /// every code id addresses one of the `e` codewords.  Called after
    /// quantization fills a code matrix; compiles to nothing in release
    /// builds.
    #[inline]
    pub fn debug_validate(&self, e: usize) {
        if cfg!(debug_assertions) {
            debug_assert_eq!(self.data.len(), self.n * self.m, "Codes buffer shape");
            for (i, row) in self.rows().enumerate() {
                for &c in row {
                    debug_assert!((c as usize) < e, "Codes row {i}: code {c} >= E={e}");
                }
            }
        }
    }
}

/// Top-L key selections for `n` queries, row-major: exactly `l` unique
/// key indices per query, ordered by (-score, key index).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopL {
    pub n: usize,
    pub l: usize,
    /// `[n * l]`, row `i` at `i * l .. (i + 1) * l`.
    pub data: Vec<u32>,
}

impl TopL {
    pub fn zeros(n: usize, l: usize) -> Self {
        assert!(l >= 1, "need at least one selection per query");
        TopL { n, l, data: vec![0u32; n * l] }
    }

    /// Build from per-row index vectors (tests / interop).
    pub fn from_rows(rows: &[Vec<u32>]) -> Self {
        assert!(!rows.is_empty(), "no rows");
        let l = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * l);
        for r in rows {
            assert_eq!(r.len(), l, "ragged selection rows");
            data.extend_from_slice(r);
        }
        TopL { n: rows.len(), l, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.data[i * self.l..(i + 1) * self.l]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [u32] {
        &mut self.data[i * self.l..(i + 1) * self.l]
    }

    /// Iterate rows as slices.
    pub fn rows(&self) -> std::slice::ChunksExact<'_, u32> {
        self.data.chunks_exact(self.l)
    }

    /// Back to nested rows (tests / interop only).
    pub fn to_rows(&self) -> Vec<Vec<u32>> {
        self.rows().map(<[u32]>::to_vec).collect()
    }

    /// Stored bytes (the paper's O(nL) index memory).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Debug-build contract check: the buffer is exactly `n × l` and
    /// every row holds `l` unique key ids below `n_keys`.  Called after
    /// top-L selection fills a matrix; compiles to nothing in release
    /// builds.
    #[inline]
    pub fn debug_validate(&self, n_keys: usize) {
        if cfg!(debug_assertions) {
            debug_assert_eq!(self.data.len(), self.n * self.l, "TopL buffer shape");
            for (i, row) in self.rows().enumerate() {
                for (p, &j) in row.iter().enumerate() {
                    debug_assert!((j as usize) < n_keys, "TopL row {i}: key {j} >= {n_keys}");
                    debug_assert!(!row[..p].contains(&j), "TopL row {i}: duplicate key {j}");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_round_trip_rows() {
        let rows = vec![vec![1u8, 2, 3], vec![4, 5, 6]];
        let c = Codes::from_rows(&rows);
        assert_eq!((c.n, c.m), (2, 3));
        assert_eq!(c.row(1), &[4, 5, 6]);
        assert_eq!(c.to_rows(), rows);
        assert_eq!(c.rows().count(), 2);
        assert_eq!(c.bytes(), 6);
    }

    #[test]
    fn codes_row_mut_writes_in_place() {
        let mut c = Codes::zeros(3, 2);
        c.row_mut(2).copy_from_slice(&[7, 9]);
        assert_eq!(c.data, vec![0, 0, 0, 0, 7, 9]);
    }

    #[test]
    fn topl_round_trip_rows() {
        let rows = vec![vec![3u32, 0], vec![1, 2], vec![2, 1]];
        let t = TopL::from_rows(&rows);
        assert_eq!((t.n, t.l), (3, 2));
        assert_eq!(t.row(0), &[3, 0]);
        assert_eq!(t.to_rows(), rows);
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn topl_rejects_ragged_rows() {
        TopL::from_rows(&[vec![0u32], vec![1, 2]]);
    }

    #[test]
    fn debug_validate_accepts_well_formed() {
        let c = Codes::from_rows(&[vec![0u8, 3], vec![1, 2]]);
        c.debug_validate(4);
        let t = TopL::from_rows(&[vec![3u32, 0], vec![1, 2]]);
        t.debug_validate(4);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "code 3 >= E=3")]
    fn debug_validate_catches_out_of_range_code() {
        Codes::from_rows(&[vec![0u8, 3]]).debug_validate(3);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "duplicate key")]
    fn debug_validate_catches_duplicate_selection() {
        TopL::from_rows(&[vec![2u32, 2]]).debug_validate(4);
    }
}
