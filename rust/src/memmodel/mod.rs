//! Analytic GPU-memory model for Transformer fine-tuning.
//!
//! The paper measures peak GPU memory on RTX 3090s; this testbed is
//! CPU-PJRT, so peak *device* memory is reproduced analytically: every
//! tensor a training step materializes is accounted by name and phase,
//! using the same structural facts the paper's numbers come from —
//!
//! * dense MHA stores the `[B, H, n, n]` attention matrix (and its
//!   gradient) — quadratic in sequence length (paper Fig. 9);
//! * sparse MHA stores `[B, H, n, L]` values + int32 indices instead
//!   (paper §4.1: O(nL) vs O(n^2));
//! * FFN activations are `[B, n, D]`; the routed FFN saves only the
//!   activated fraction beta (paper §4.2);
//! * Full tuning keeps gradients + AdamW moments for every base weight;
//!   LoRA/SPT only for adapters (paper §2.2) — but *activations* dominate
//!   at realistic batch sizes (paper §6.2 Discussions).
//!
//! The model is validated in-tree: monotonicity properties, the paper's
//! qualitative orderings, and ratio checks against Table 1/Table 4/Fig. 8b
//! live in `rust/tests/` and the bench harness prints model outputs next
//! to the paper's columns.
//!
//! The native training path now *realizes* the `n_layers`-deep
//! activation picture [`model_peak`] prices: `coordinator/native.rs`
//! stacks the preset's full depth and its backward holds every layer's
//! saved activations live (per-layer attention CSRs, routed-FFN
//! routings, layer-norm inputs) exactly as the
//! no-activation-checkpointing branch below assumes, while gradient
//! memory is bounded by the fixed-size chunked accumulator fan-out
//! rather than O(batch).

pub mod block;
pub mod report;

pub use block::{block_peak, module_peak, BlockWorkload, MemBreakdown, Module, Phase, TensorAcct};

use crate::config::{BlockConfig, Mode};

/// Peak memory for an `n_layers`-deep model: with activation
/// checkpointing off (paper's setting), backward keeps every layer's saved
/// activations live, while weights/grads/opt scale with depth — the same
/// structure the native backend's stacked train step materializes.
pub fn model_peak(
    cfg: &BlockConfig,
    mode: Mode,
    batch: usize,
    seq: usize,
    n_layers: usize,
    vocab: usize,
) -> u64 {
    let per_block = block_peak(cfg, mode, &BlockWorkload { batch, seq });
    // Per-layer persistent (weights+grad+opt) and saved activations stack;
    // the transient workspace is needed once (layers execute serially).
    let persistent: u64 = per_block.persistent_bytes();
    let saved: u64 = per_block.saved_activation_bytes();
    let transient: u64 = per_block.transient_bytes();
    let embed = (2 * vocab + seq) as u64 * cfg.d_model as u64 * 4;
    let logits = (batch * seq * vocab) as u64 * 4;
    // logits + grad of logits live at the loss boundary.
    n_layers as u64 * (persistent + saved) + transient + embed * multiplier(mode) + 2 * logits
}

fn multiplier(mode: Mode) -> u64 {
    // Full tuning trains the embedding/head too: grad + 2 opt moments.
    match mode {
        Mode::Full => 4,
        Mode::Lora | Mode::Spt => 1,
    }
}

/// Peak *GPU* memory with DeepSpeed-style parameter/optimizer offloading
/// (the paper's Table 3 setting): persistent state lives in host memory
/// and streams through a 2-block working set; activations (and the loss
/// boundary) stay on the GPU.
pub fn model_peak_offloaded(
    cfg: &BlockConfig,
    mode: Mode,
    batch: usize,
    seq: usize,
    n_layers: usize,
    vocab: usize,
) -> u64 {
    let per_block = block_peak(cfg, mode, &BlockWorkload { batch, seq });
    let working_set = 2 * per_block.persistent_bytes(); // current + prefetch
    // Activation offloading streams saved activations to host, but a
    // pipeline window of blocks stays resident (DeepSpeed keeps several
    // in flight to overlap transfers).
    const ACT_WINDOW: u64 = 4;
    let saved = ACT_WINDOW.min(n_layers as u64) * per_block.saved_activation_bytes();
    let transient = per_block.transient_bytes();
    let embed_act = (batch * seq * cfg.d_model) as u64 * 4;
    let logits = (batch * seq * vocab) as u64 * 4;
    saved + working_set + transient + embed_act + 2 * logits
}

/// Per-sequence decode-cache bytes at `seq` cached positions: per layer
/// and per head, cached K and V (f32) plus — spt mode — the PQ codes of
/// every cached key (one `u8` per subspace), which is what lets each
/// decode step select top-L from integer codes without touching floats.
pub fn decode_cache_bytes(cfg: &BlockConfig, mode: Mode, seq: usize, n_layers: usize) -> u64 {
    let d = cfg.d_model as u64;
    let n = seq as u64;
    let kv = 2 * n * d * 4;
    let codes = match mode {
        Mode::Spt => n * cfg.n_heads() as u64 * cfg.pq_m() as u64,
        Mode::Full | Mode::Lora => 0,
    };
    n_layers as u64 * (kv + codes)
}

/// Transient attention state one decode step materializes for one new
/// token (all heads of one layer — layers run serially): the dense path
/// holds an O(n) softmax row per head, the sparse path O(L) values +
/// selected indices — the paper's Fig. 9 memory argument applied to the
/// decode hot loop, where it bounds *per-token serving state* instead of
/// training activations.
pub fn decode_step_state_bytes(cfg: &BlockConfig, mode: Mode, seq: usize) -> u64 {
    let h = cfg.n_heads() as u64;
    match mode {
        Mode::Full | Mode::Lora => h * seq as u64 * 4,
        Mode::Spt => {
            let l = cfg.sparsity.topl(seq).min(seq) as u64;
            h * l * (4 + 4)
        }
    }
}

/// Admission cost of one serving request at its *target* length
/// (prompt + max new tokens): the cache it will have filled by its last
/// decode step plus its per-step attention state at that length.  The
/// dense-slot analytic cost; the serve daemon's live budget is now
/// page-granular ([`decode_page_bytes`] × [`decode_request_pages`]),
/// which upper-bounds this cache term by construction.
pub fn decode_request_bytes(
    cfg: &BlockConfig,
    mode: Mode,
    target_len: usize,
    n_layers: usize,
) -> u64 {
    decode_cache_bytes(cfg, mode, target_len, n_layers)
        + decode_step_state_bytes(cfg, mode, target_len)
}

/// Bytes of one KV page (`page_tokens` cached positions across all
/// layers/heads): the page pool's allocation granule.  Identical math
/// to [`decode_cache_bytes`] at `page_tokens` positions — the analytic
/// twin of `PagePool::bytes_per_page`, used to size a pool from
/// `--mem_budget_mb`.
pub fn decode_page_bytes(
    cfg: &BlockConfig,
    mode: Mode,
    page_tokens: usize,
    n_layers: usize,
) -> u64 {
    decode_cache_bytes(cfg, mode, page_tokens, n_layers)
}

/// Pages one request occupies at its target length (prompt + max new
/// tokens) — what the serve driver charges at admission.
pub fn decode_request_pages(target_len: usize, page_tokens: usize) -> usize {
    target_len.div_ceil(page_tokens.max(1))
}

/// Largest page pool a byte budget affords (0 = budget below one page).
pub fn pool_pages_for_budget(budget: u64, page_bytes: u64) -> usize {
    usize::try_from(budget / page_bytes.max(1)).unwrap_or(usize::MAX)
}

/// Peak decode-time memory for `batch` concurrent sequences at `seq`
/// cached positions: effective weights (plus the pack-once GEMM panels
/// of the forward projections), embeddings, every sequence's cache, the
/// per-step attention state, and the in-flight logits rows.  No
/// gradients, moments, or saved activations — the structural reason
/// serving fits where training OOMs.
pub fn decode_peak(
    cfg: &BlockConfig,
    mode: Mode,
    batch: usize,
    seq: usize,
    n_layers: usize,
    vocab: usize,
) -> u64 {
    let d = cfg.d_model as u64;
    let f = cfg.d_ffn as u64;
    let nl = n_layers as u64;
    let adapters = match mode {
        Mode::Full => 0,
        Mode::Lora => cfg.lora_params(),
        Mode::Spt => cfg.lora_params() + cfg.spt_params(),
    };
    let weights = nl * (cfg.base_params() + adapters) * 4;
    // Pack-once panels: q/k/v/o always; the dense FFN pair outside spt.
    let packed_ffn = if mode == Mode::Spt { 0 } else { 2 * d * f };
    let packed = nl * (4 * d * d + packed_ffn) * 4;
    let embed = (vocab as u64 + seq as u64) * d * 4;
    let caches = batch as u64 * decode_cache_bytes(cfg, mode, seq, n_layers);
    let step_state = batch as u64 * decode_step_state_bytes(cfg, mode, seq);
    let logits = (batch * vocab) as u64 * 4;
    weights + packed + embed + caches + step_state + logits
}

/// Max sequence length under a byte budget, probing in `step` increments —
/// the paper's Table 3 "Max Length" protocol (increments of 128 until OOM,
/// with DeepSpeed offloading enabled).
pub fn max_seq_under_budget(
    cfg: &BlockConfig,
    mode: Mode,
    batch: usize,
    n_layers: usize,
    vocab: usize,
    budget: u64,
    step: usize,
) -> usize {
    let mut best = 0;
    let mut seq = step;
    while seq <= 65536 {
        let peak = model_peak_offloaded(cfg, mode, batch, seq, n_layers, vocab);
        if peak > budget {
            break;
        }
        best = seq;
        seq += step;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn wl() -> BlockWorkload {
        BlockWorkload { batch: 16, seq: 512 }
    }

    #[test]
    fn ordering_matches_paper_block_level() {
        // Fig. 8b: peak(SPT) < peak(LoRA) < peak(Full) for every config.
        for cfg in presets::paper_blocks() {
            let full = block_peak(&cfg, Mode::Full, &wl()).peak_bytes();
            let lora = block_peak(&cfg, Mode::Lora, &wl()).peak_bytes();
            let spt = block_peak(&cfg, Mode::Spt, &wl()).peak_bytes();
            assert!(spt < lora, "{}: spt {} !< lora {}", cfg.name, spt, lora);
            assert!(lora < full, "{}: lora {} !< full {}", cfg.name, lora, full);
        }
    }

    #[test]
    fn quadratic_growth_for_dense_linear_for_sparse() {
        // Fig. 9: dense MHA memory grows ~quadratically in n, sparse ~linearly
        // (L = n/8 keeps nL quadratic too but 8x smaller; the paper's picture
        // is the gap widening with n — assert that).
        let cfg = presets::block("opt-2048").unwrap();
        let gap = |seq: usize| {
            let w = BlockWorkload { batch: 16, seq };
            block_peak(&cfg, Mode::Lora, &w).peak_bytes() as i64
                - block_peak(&cfg, Mode::Spt, &w).peak_bytes() as i64
        };
        assert!(gap(1024) > 2 * gap(512), "{} vs {}", gap(1024), gap(512));
    }

    #[test]
    fn spt_max_length_exceeds_baselines() {
        // Table 3: SPT supports ~2x Full's max length, >1.5x LoRA's.
        let cfg = presets::block("opt-2560").unwrap();
        let budget = 24u64 << 30;
        let full = max_seq_under_budget(&cfg, Mode::Full, 16, 32, 50272, budget, 128);
        let lora = max_seq_under_budget(&cfg, Mode::Lora, 16, 32, 50272, budget, 128);
        let spt = max_seq_under_budget(&cfg, Mode::Spt, 16, 32, 50272, budget, 128);
        assert!(full > 0 && lora >= full && spt > lora, "{full} {lora} {spt}");
    }

    #[test]
    fn decode_model_orders_as_expected() {
        let cfg = presets::block("opt-2048").unwrap();
        // Per-step attention state: sparse O(L) << dense O(n), and the
        // gap widens with sequence length (Fig. 9, decode edition).
        let gap = |seq: usize| {
            decode_step_state_bytes(&cfg, Mode::Lora, seq) as i64
                - decode_step_state_bytes(&cfg, Mode::Spt, seq) as i64
        };
        assert!(gap(512) > 0);
        assert!(gap(2048) > 2 * gap(512), "{} vs {}", gap(2048), gap(512));
        // The spt cache pays a small integer-code premium over dense KV.
        let kv = decode_cache_bytes(&cfg, Mode::Lora, 512, 32);
        let kv_spt = decode_cache_bytes(&cfg, Mode::Spt, 512, 32);
        assert!(kv_spt > kv);
        assert!(kv_spt < kv + kv / 10, "codes should be a small premium");
        // Decode peak is far below the training peak (no grads, moments,
        // or saved activations) and monotone in batch and seq.
        let train = model_peak(&cfg, Mode::Spt, 16, 512, 32, 50272);
        let serve = decode_peak(&cfg, Mode::Spt, 16, 512, 32, 50272);
        assert!(serve < train / 2, "serve {serve} vs train {train}");
        assert!(
            decode_peak(&cfg, Mode::Spt, 32, 512, 32, 50272) > serve
                && decode_peak(&cfg, Mode::Spt, 16, 1024, 32, 50272) > serve
        );
    }

    #[test]
    fn request_cost_bounds_cache_plus_step_state_and_is_monotone() {
        let cfg = presets::block("opt-1024").unwrap();
        for mode in Mode::ALL {
            let cost = decode_request_bytes(&cfg, mode, 256, 8);
            assert_eq!(
                cost,
                decode_cache_bytes(&cfg, mode, 256, 8)
                    + decode_step_state_bytes(&cfg, mode, 256)
            );
            // The charged cost dominates the footprint at every shorter
            // in-flight length (what makes the budget sum an upper bound).
            for len in [1, 64, 255] {
                assert!(
                    decode_cache_bytes(&cfg, mode, len, 8)
                        + decode_step_state_bytes(&cfg, mode, len)
                        <= cost,
                    "{mode:?} at len {len}"
                );
            }
            assert!(decode_request_bytes(&cfg, mode, 512, 8) > cost, "{mode:?}");
        }
    }

    #[test]
    fn page_accounting_covers_the_cache_it_pays_for() {
        let cfg = presets::block("opt-1024").unwrap();
        for mode in Mode::ALL {
            let pb = decode_page_bytes(&cfg, mode, 16, 8);
            assert_eq!(pb, decode_cache_bytes(&cfg, mode, 16, 8));
            for target in [1, 15, 16, 17, 100, 256] {
                let bytes = decode_request_pages(target, 16) as u64 * pb;
                let cache = decode_cache_bytes(&cfg, mode, target, 8);
                // Charged pages cover the cache at the target length,
                // with less than one page of rounding slack.
                assert!(bytes >= cache, "{mode:?} target {target}");
                assert!(bytes < cache + pb, "{mode:?} target {target}");
            }
            assert_eq!(pool_pages_for_budget(10 * pb + pb / 2, pb), 10);
            assert_eq!(pool_pages_for_budget(pb - 1, pb), 0);
        }
        assert_eq!(decode_request_pages(33, 16), 3);
        assert_eq!(decode_request_pages(32, 16), 2);
    }

    #[test]
    fn batch_scaling_is_linear_in_activations() {
        let cfg = presets::block("opt-1024").unwrap();
        let p1 = block_peak(&cfg, Mode::Spt, &BlockWorkload { batch: 1, seq: 512 });
        let p4 = block_peak(&cfg, Mode::Spt, &BlockWorkload { batch: 4, seq: 512 });
        assert_eq!(p1.persistent_bytes(), p4.persistent_bytes());
        assert!(p4.saved_activation_bytes() >= 4 * p1.saved_activation_bytes() - 64);
    }
}
