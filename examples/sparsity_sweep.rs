//! Sparsity-strength sweep (paper Fig. 10 + §3 trial workflow):
//! trains short trials in every available mode, sweeps the substrate's
//! MHA approximation error over L, and prints the trade-off table with a
//! recommendation.
//!
//!     cargo run --release --example sparsity_sweep -- [--model spt-tiny] [--steps 16]

use anyhow::Result;
use spt::config::RunConfig;
use spt::coordinator::trial::TrialManager;
use spt::metrics::Table;
use spt::runtime::Engine;
use spt::sparse::{attention::sparse_vs_dense_error, pq, Matrix};
use spt::util::rng::Rng;

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> Result<()> {
    // Substrate sweep: L fraction -> attention error (Fig. 10a mechanism).
    let (n, d) = (256usize, 64usize);
    let mut rng = Rng::new(21);
    let k = Matrix::randn(n, d, 1.0, &mut rng);
    let noise = Matrix::randn(n, d, 0.5, &mut rng);
    let q = Matrix::from_vec(
        n, d,
        k.data.iter().zip(&noise.data).map(|(a, b)| 2.0 * a + b).collect(),
    );
    let v = Matrix::randn(n, d, 1.0, &mut rng);
    let mut cb = pq::Codebooks::random(8, 16, 8, &mut rng);
    for _ in 0..5 {
        pq::codebook_update(&k.data, &mut cb, 1.0);
    }
    let mut sweep = Table::new(
        "MHA sparsity sweep (substrate): non-zero portion vs output error",
        &["portion", "rel. error"],
    );
    for den in [1usize, 2, 4, 8, 16] {
        let err = sparse_vs_dense_error(&q, &k, &v, &cb, (n / den).max(1));
        sweep.row(&[format!("1/{den}"), format!("{err:.4}")]);
    }
    println!("{}", sweep.render());

    // Trial manager over the AOT artifacts (paper §3).
    let dir = std::env::var("SPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::new(&dir)?;
    let mut rc = RunConfig::default();
    rc.model = arg("--model", "spt-tiny");
    rc.artifacts_dir = dir;
    let steps: usize = arg("--steps", "16").parse()?;
    let tm = TrialManager::new(&engine, rc, steps);
    let (results, table) = tm.compare_modes()?;
    println!("{}", table.render());
    if let Some(best) = TrialManager::recommend(&results, 0.10) {
        println!(
            "recommendation: {} — {:.3} s/step at ppl {:.2} (within 10% of best quality)",
            best.label, best.secs_per_step, best.ppl
        );
    }
    Ok(())
}
