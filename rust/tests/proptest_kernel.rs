//! Bitwise-equality property tests for the register-blocked microkernel:
//! every GEMM entry point (`gemm_into`, `gemm_packed_into`,
//! `gemm_nt_into`) against the naive ascending-`k` triple loop, across
//! ragged shapes (M, K, N deliberately not multiples of MR/NR/KC), plus
//! a pool-1/2/8 determinism check through `train_step`.
//!
//! Equality is asserted on `to_bits()` — the kernels' contract is exact
//! bit reproduction of the naive accumulation order, not approximate
//! agreement.

use spt::config::{Mode, RunConfig};
use spt::coordinator::{Backend, NativeBackend, TrainState};
use spt::data::SyntheticCorpus;
use spt::sparse::{matrix, Matrix, PackedB};
use spt::util::proptest::{check, prop_assert};

/// Naive triple-loop `A @ B`, ascending k, zero-`a` terms skipped (the
/// pre-register-blocking kernel's order; the skip is bitwise inert for
/// finite B — see `sparse::matrix`'s module docs).
fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let mut out = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for (k, &av) in a.row(i).iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            for (o, &bv) in out.row_mut(i).iter_mut().zip(b.row(k)) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Naive `A @ B^T`: one scalar ascending dot per output element.
fn matmul_nt_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols);
    let mut out = Matrix::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        for j in 0..b.rows {
            let mut acc = 0.0f32;
            for (x, y) in a.row(i).iter().zip(b.row(j)) {
                acc += x * y;
            }
            *out.at_mut(i, j) = acc;
        }
    }
    out
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Random matrix with exact zeros sprinkled in (the old kernel branched
/// on them; the new one must not need the branch to stay exact).
fn ragged_operand(g: &mut spt::util::proptest::Gen, rows: usize, cols: usize) -> Matrix {
    let mut m = Matrix::from_vec(rows, cols, g.vec_f32(rows * cols));
    let step = g.usize_in(3, 11);
    for (i, v) in m.data.iter_mut().enumerate() {
        if i % step == 0 {
            *v = 0.0;
        }
    }
    m
}

#[test]
fn gemm_into_matches_naive_bits_on_ragged_shapes() {
    let mut pack = Vec::new();
    check(60, |g| {
        let (m, k, n) = (g.usize_in(1, 70), g.usize_in(1, 300), g.usize_in(1, 150));
        let a = ragged_operand(g, m, k);
        let b = Matrix::from_vec(k, n, g.vec_f32(k * n));
        let mut out = vec![0.0f32; m * n];
        matrix::gemm_into(m, k, n, &a.data, &b.data, n, 0, &mut out, &mut pack);
        let want = matmul_naive(&a, &b);
        prop_assert(
            bits(&out) == bits(&want.data),
            format!("gemm {m}x{k}x{n} diverged from naive"),
        )
    });
}

#[test]
fn gemm_packed_into_matches_naive_bits_on_ragged_shapes() {
    check(40, |g| {
        let (m, k, n) = (g.usize_in(1, 50), g.usize_in(1, 200), g.usize_in(1, 140));
        let a = ragged_operand(g, m, k);
        let b = Matrix::from_vec(k, n, g.vec_f32(k * n));
        let pb = PackedB::pack(&b);
        let mut out = vec![0.0f32; m * n];
        matrix::gemm_packed_into(m, &a.data, &pb, &mut out);
        let want = matmul_naive(&a, &b);
        prop_assert(
            bits(&out) == bits(&want.data),
            format!("gemm_packed {m}x{k}x{n} diverged from naive"),
        )
    });
}

#[test]
fn gemm_nt_into_matches_naive_bits_on_both_paths() {
    // m spans 1..=40: below NT_PACK_MIN_ROWS the per-row dot kernel
    // runs, at or above it the transpose-pack + register-blocked path —
    // both must reproduce the naive dots exactly.
    let mut pack = Vec::new();
    check(60, |g| {
        let (m, kd, n) = (g.usize_in(1, 40), g.usize_in(1, 260), g.usize_in(1, 90));
        let a = ragged_operand(g, m, kd);
        let b = Matrix::from_vec(n, kd, g.vec_f32(n * kd));
        let mut out = vec![0.0f32; m * n];
        matrix::gemm_nt_into(m, kd, n, &a.data, &b.data, b.cols, 0, &mut out, &mut pack);
        let want = matmul_nt_naive(&a, &b);
        prop_assert(
            bits(&out) == bits(&want.data),
            format!("gemm_nt {m}x{kd}x{n} diverged from naive"),
        )
    });
}

#[test]
fn gemm_nt_into_column_block_matches_naive_bits() {
    // The strided/offset B addressing (routed-FFN W_I column blocks).
    let mut pack = Vec::new();
    check(30, |g| {
        let kd = g.usize_in(1, 120);
        let extra = g.usize_in(0, 30);
        let col0 = g.usize_in(0, extra);
        let n = g.usize_in(1, 50);
        let m = g.usize_in(1, 24);
        let b_full = Matrix::from_vec(n, kd + extra, g.vec_f32(n * (kd + extra)));
        let mut b_slice = Matrix::zeros(n, kd);
        for r in 0..n {
            b_slice
                .row_mut(r)
                .copy_from_slice(&b_full.row(r)[col0..col0 + kd]);
        }
        let a = ragged_operand(g, m, kd);
        let mut out = vec![0.0f32; m * n];
        matrix::gemm_nt_into(
            m, kd, n, &a.data, &b_full.data, b_full.cols, col0, &mut out, &mut pack,
        );
        let want = matmul_nt_naive(&a, &b_slice);
        prop_assert(
            bits(&out) == bits(&want.data),
            format!("gemm_nt block {m}x{kd}x{n}+{col0} diverged"),
        )
    });
}

/// Two `train_step`s plus the final state under a dedicated pool.
fn train_under_pool(threads: usize) -> (Vec<u32>, TrainState) {
    let pool = rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("pool");
    pool.install(|| {
        let backend = NativeBackend::new();
        let cfg = RunConfig {
            model: "spt-nano".into(),
            mode: Mode::Spt,
            batch: 8,
            seq: 32,
            seed: 123,
            lr: 5e-3,
            eval_every: 0,
            codebook_refresh_every: 0,
            ..RunConfig::default()
        };
        let (batch, seq) = backend.workload(&cfg).unwrap();
        let vocab = backend.vocab(&cfg).unwrap();
        let mut corpus = SyntheticCorpus::new(vocab, 4, 0.85, cfg.seed);
        let mut tokens = Vec::new();
        let mut targets = Vec::new();
        for _ in 0..batch {
            let (x, y) = corpus.lm_pair(seq);
            tokens.extend(x.iter().map(|&t| t as i32));
            targets.extend(y.iter().map(|&t| t as i32));
        }
        let mut state = backend.init_state(&cfg).unwrap();
        let mut lbits = Vec::new();
        for _ in 0..2 {
            let loss = backend
                .train_step(&cfg, &mut state, &tokens, &targets)
                .unwrap();
            lbits.push(loss.to_bits());
        }
        (lbits, state)
    })
}

#[test]
fn train_step_on_register_blocked_kernel_is_pool_invariant() {
    let (bits1, state1) = train_under_pool(1);
    for threads in [2usize, 8] {
        let (bits_t, state_t) = train_under_pool(threads);
        assert_eq!(bits1, bits_t, "losses diverge at pool size {threads}");
        assert_eq!(state1.params, state_t.params, "params diverge at pool size {threads}");
        assert_eq!(state1.m, state_t.m, "AdamW m diverges at pool size {threads}");
        assert_eq!(state1.v, state_t.v, "AdamW v diverges at pool size {threads}");
    }
}
