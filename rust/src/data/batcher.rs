//! Mini-batch scheduler: deterministic shuffling, epoch boundaries, and
//! the conservation invariant (every sequence scheduled exactly once per
//! epoch) the coordinator's proptests verify.

use crate::util::rng::Rng;

/// One training mini-batch (token ids flattened row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
    pub epoch: usize,
    pub index_in_epoch: usize,
}

/// Batches a fixed pool of (tokens, targets) sequences.
pub struct Batcher {
    pool_tokens: Vec<Vec<u32>>,
    pool_targets: Vec<Vec<u32>>,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(
        pool_tokens: Vec<Vec<u32>>,
        pool_targets: Vec<Vec<u32>>,
        batch: usize,
        seed: u64,
    ) -> Self {
        assert_eq!(pool_tokens.len(), pool_targets.len());
        assert!(pool_tokens.len() >= batch && batch >= 1);
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..pool_tokens.len()).collect();
        rng.shuffle(&mut order);
        Batcher {
            pool_tokens,
            pool_targets,
            batch,
            order,
            cursor: 0,
            epoch: 0,
            rng,
        }
    }

    pub fn pool_size(&self) -> usize {
        self.pool_tokens.len()
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.pool_size() / self.batch
    }

    /// Next mini-batch; reshuffles at epoch boundaries.  The tail that
    /// doesn't fill a batch is dropped (paper-standard drop_last).
    pub fn next(&mut self) -> Batch {
        if self.cursor + self.batch > self.batches_per_epoch() * self.batch {
            self.epoch += 1;
            self.cursor = 0;
            self.rng.shuffle(&mut self.order);
        }
        let seq = self.pool_tokens[0].len();
        let mut tokens = Vec::with_capacity(self.batch * seq);
        let mut targets = Vec::with_capacity(self.batch * seq);
        let index_in_epoch = self.cursor / self.batch;
        for i in 0..self.batch {
            let idx = self.order[self.cursor + i];
            tokens.extend(self.pool_tokens[idx].iter().map(|&t| t as i32));
            targets.extend(self.pool_targets[idx].iter().map(|&t| t as i32));
        }
        self.cursor += self.batch;
        Batch {
            tokens,
            targets,
            batch: self.batch,
            seq,
            epoch: self.epoch,
            index_in_epoch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    fn pool(n: usize, seq: usize) -> (Vec<Vec<u32>>, Vec<Vec<u32>>) {
        // sequence i is constant-i so batches are traceable.
        let toks: Vec<Vec<u32>> = (0..n).map(|i| vec![i as u32; seq]).collect();
        (toks.clone(), toks)
    }

    #[test]
    fn every_sequence_scheduled_once_per_epoch() {
        check(20, |g| {
            let n = g.usize_in(4, 64);
            let bs = g.usize_in(1, n);
            let (t, y) = pool(n, 4);
            let mut b = Batcher::new(t, y, bs, g.seed);
            let per_epoch = b.batches_per_epoch();
            let mut seen = vec![0usize; n];
            for _ in 0..per_epoch {
                let batch = b.next();
                prop_assert(batch.epoch == 0, "epoch advanced early")?;
                for r in 0..bs {
                    seen[batch.tokens[r * 4] as usize] += 1;
                }
            }
            prop_assert(
                seen.iter().all(|&c| c <= 1),
                "sequence repeated within epoch",
            )?;
            let scheduled: usize = seen.iter().sum();
            prop_assert(
                scheduled == per_epoch * bs,
                "conservation violated",
            )
        });
    }

    #[test]
    fn epochs_reshuffle() {
        let (t, y) = pool(16, 4);
        let mut b = Batcher::new(t, y, 4, 9);
        let first_epoch: Vec<i32> =
            (0..4).flat_map(|_| b.next().tokens).collect();
        let second_epoch: Vec<i32> =
            (0..4).flat_map(|_| b.next().tokens).collect();
        assert_ne!(first_epoch, second_epoch); // astronomically unlikely
        assert_eq!(b.next().epoch, 2);
    }

    #[test]
    fn batch_layout_row_major() {
        let (t, y) = pool(4, 3);
        let mut b = Batcher::new(t, y, 2, 0);
        let batch = b.next();
        assert_eq!(batch.tokens.len(), 6);
        assert_eq!(batch.tokens[0], batch.tokens[1]);
        assert_eq!(batch.tokens[0], batch.tokens[2]);
    }
}
