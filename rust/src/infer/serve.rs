//! Continuous-batching serve driver: a step-loop scheduler over the
//! cached-decode path.
//!
//! Each step (1) **admits** queued requests in submission order while a
//! slot is free (prefill runs on admission, and the first token is
//! sampled immediately from the prefill logits), (2) runs **one batched
//! decode** over every in-flight sequence — one GEMM per projection and
//! one routed-FFN call per layer across all their new tokens — and
//! (3) **retires** finished sequences in ascending slot order, freeing
//! capacity for the next admissions.
//!
//! Determinism: per-request token streams depend only on the model, the
//! request (prompt, `max_new_tokens`) and the per-request RNG stream
//! (derived from the driver seed and the request id) — every batched op
//! is row-local and bit-identical to a single-sequence decode, so the
//! batch composition, `max_batch`, and the rayon pool size never change
//! what any request generates (asserted by `serving_is_batch_invariant`
//! below).
//!
//! Degradation contract: a malformed request or slot (prefill failure,
//! out-of-range token) retires *that request* with
//! [`Completion::error`] set — the driver keeps serving everything
//! else.  [`ServeDriver::cancel`] retires an in-flight request at a
//! step boundary the same way (the daemon's deadline enforcement).

use std::collections::VecDeque;
use std::time::Instant; // det: wall-clock (latency metrics only)

use anyhow::{bail, Result};

use super::sampler::Sampler;
use super::session::{decode_batch, prefill_state, DecodeState, InferModel, StepScratch};
use crate::util::rng::Rng;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub tokens: Vec<i32>,
    /// Seconds from the driver's first step to retirement (includes
    /// queueing — the client-visible latency under load).
    pub latency_secs: f64,
    /// Seconds spent queued before a slot admitted this request.
    pub queue_wait_secs: f64,
    /// `Some(reason)` when the request was degraded (prefill failure,
    /// malformed slot, cancellation) instead of completing; `tokens`
    /// then holds whatever was generated before the failure.
    pub error: Option<String>,
}

/// Driver knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// In-flight sequence capacity (1 = the one-at-a-time baseline).
    pub max_batch: usize,
    pub sampler: Sampler,
    /// Base seed; request `id` forks a decorrelated per-request stream.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 8, sampler: Sampler::Greedy, seed: 0 }
    }
}

/// Bookkeeping for one in-flight sequence (parallel to the driver's
/// `states` vector, which `decode_batch` consumes directly).
struct SlotMeta {
    id: usize,
    rng: Rng,
    out: Vec<i32>,
    max_new: usize,
    logits: Vec<f32>,
    queue_wait_secs: f64,
}

/// Aggregate results of a drained driver.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Completions sorted by request id (degraded ones included, with
    /// [`Completion::error`] set).
    pub completions: Vec<Completion>,
    pub wall_secs: f64,
    pub decode_steps: usize,
    pub generated_tokens: usize,
    /// Steady-state decode throughput: generated tokens / wall seconds.
    pub tokens_per_sec: f64,
    /// Peak in-flight sequences observed.
    pub peak_in_flight: usize,
    /// Completions that ended with an error (degraded or cancelled).
    pub failed: usize,
}

/// Percentile over a sample (p in [0, 100]); 0.0 on an empty sample.
fn percentile(mut values: Vec<f64>, p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.sort_by(f64::total_cmp);
    let ix = ((p / 100.0) * (values.len() - 1) as f64).round() as usize;
    values[ix.min(values.len() - 1)]
}

impl ServeReport {
    /// Machine-readable form — the shared schema of
    /// `bench_out/BENCH_decode_native.json`, used by `spt serve-bench`,
    /// the `decode_throughput` bench, and the daemon's final report so
    /// the producers cannot drift.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("tokens_per_sec".into(), Json::Num(self.tokens_per_sec));
        m.insert("wall_secs".into(), Json::Num(self.wall_secs));
        m.insert("decode_steps".into(), Json::Num(self.decode_steps as f64));
        m.insert(
            "generated_tokens".into(),
            Json::Num(self.generated_tokens as f64),
        );
        m.insert(
            "peak_in_flight".into(),
            Json::Num(self.peak_in_flight as f64),
        );
        m.insert("completed".into(), Json::Num(self.completions.len() as f64));
        m.insert("failed".into(), Json::Num(self.failed as f64));
        m.insert("p50_latency_s".into(), Json::Num(self.latency_percentile(50.0)));
        m.insert("p90_latency_s".into(), Json::Num(self.latency_percentile(90.0)));
        m.insert("p99_latency_s".into(), Json::Num(self.latency_percentile(99.0)));
        m.insert(
            "queue_wait_p50_s".into(),
            Json::Num(self.queue_wait_percentile(50.0)),
        );
        m.insert(
            "queue_wait_p99_s".into(),
            Json::Num(self.queue_wait_percentile(99.0)),
        );
        Json::Obj(m)
    }

    /// Latency percentile over completions (p in [0, 100]).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        percentile(self.completions.iter().map(|c| c.latency_secs).collect(), p)
    }

    /// Queue-wait percentile over completions (p in [0, 100]) — how
    /// long requests sat in the driver queue before admission, the
    /// overload signal `serve-bench` records.
    pub fn queue_wait_percentile(&self, p: f64) -> f64 {
        percentile(self.completions.iter().map(|c| c.queue_wait_secs).collect(), p)
    }
}

/// The continuous-batching driver.
pub struct ServeDriver<'m> {
    model: &'m InferModel,
    cfg: ServeConfig,
    /// Queued requests with their submit offset (seconds from epoch).
    queue: VecDeque<(Request, f64)>,
    states: Vec<DecodeState>,
    meta: Vec<SlotMeta>,
    finished: Vec<Completion>,
    /// Cross-step decode scratch (GEMM workspace + routing buffers),
    /// reused for the driver's whole lifetime.
    scratch: StepScratch,
    epoch: Option<Instant>, // det: wall-clock (latency metrics only)
    decode_steps: usize,
    generated_tokens: usize,
    peak_in_flight: usize,
}

impl<'m> ServeDriver<'m> {
    pub fn new(model: &'m InferModel, cfg: ServeConfig) -> Result<Self> {
        if cfg.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        Ok(ServeDriver {
            model,
            cfg,
            queue: VecDeque::new(),
            states: Vec::new(),
            meta: Vec::new(),
            finished: Vec::new(),
            scratch: StepScratch::default(),
            epoch: None,
            decode_steps: 0,
            generated_tokens: 0,
            peak_in_flight: 0,
        })
    }

    /// Seconds since the driver's epoch (0.0 before the first step —
    /// requests submitted before serving starts wait from the start).
    fn now_secs(&self) -> f64 {
        self.epoch
            .map(|e| e.elapsed().as_secs_f64()) // det: wall-clock (metrics)
            .unwrap_or(0.0)
    }

    /// Enqueue a request (admitted in submission order).
    pub fn submit(&mut self, req: Request) -> Result<()> {
        if req.max_new_tokens == 0 {
            bail!("request {}: max_new_tokens must be >= 1", req.id);
        }
        if req.prompt.is_empty() {
            bail!("request {}: empty prompt", req.id);
        }
        if req.prompt.len() + req.max_new_tokens > self.model.max_seq() {
            bail!(
                "request {}: prompt {} + max_new {} exceeds max_seq {}",
                req.id,
                req.prompt.len(),
                req.max_new_tokens,
                self.model.max_seq()
            );
        }
        let submitted = self.now_secs();
        self.queue.push_back((req, submitted));
        Ok(())
    }

    /// Request ids currently in flight, in admission order.
    pub fn in_flight_ids(&self) -> Vec<usize> {
        self.meta.iter().map(|m| m.id).collect()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> usize {
        self.meta.len()
    }

    /// Batched decode steps executed so far (the daemon's deterministic
    /// deadline clock).
    pub fn decode_steps(&self) -> usize {
        self.decode_steps
    }

    /// Retire request `id` at a step boundary with an error completion
    /// carrying whatever it generated so far.  Returns `false` when the
    /// id is not in flight.  This is how the daemon enforces
    /// per-request deadlines without perturbing other streams.
    pub fn cancel(&mut self, id: usize, reason: &str) -> bool {
        let Some(si) = self.meta.iter().position(|m| m.id == id) else {
            return false;
        };
        let now = self.now_secs();
        let m = self.meta.remove(si);
        self.states.remove(si);
        self.finished.push(Completion {
            id: m.id,
            tokens: m.out,
            latency_secs: now,
            queue_wait_secs: m.queue_wait_secs,
            error: Some(reason.to_string()),
        });
        true
    }

    /// Drain completions retired since the last call (the daemon's
    /// streaming seam; [`Self::report`] folds drained completions back
    /// in via its argument).
    pub fn take_finished(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.finished)
    }

    /// One scheduler step: admit → batched decode → sample → retire.
    /// Returns `false` once the queue and all slots are drained.
    pub fn step(&mut self) -> Result<bool> {
        let epoch = *self.epoch.get_or_insert_with(Instant::now); // det: wall-clock (metrics)
        // Admit in submission order while capacity allows.  Prefill runs
        // here; the first token is sampled straight from its logits.  A
        // failed prefill degrades that request, not the driver.
        while self.states.len() < self.cfg.max_batch {
            let Some((req, submitted)) = self.queue.pop_front() else { break };
            let now = epoch.elapsed().as_secs_f64(); // det: wall-clock (metrics)
            let queue_wait = (now - submitted).max(0.0);
            let target = req.prompt.len() + req.max_new_tokens;
            let (state, logits) = match prefill_state(self.model, &req.prompt, target) {
                Ok(pair) => pair,
                Err(e) => {
                    self.finished.push(Completion {
                        id: req.id,
                        tokens: Vec::new(),
                        latency_secs: now,
                        queue_wait_secs: queue_wait,
                        error: Some(format!("prefill failed: {e:#}")),
                    });
                    continue;
                }
            };
            let mut slot = SlotMeta {
                id: req.id,
                rng: Rng::new(
                    self.cfg
                        .seed
                        .wrapping_add((req.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                ),
                out: Vec::with_capacity(req.max_new_tokens),
                max_new: req.max_new_tokens,
                logits,
                queue_wait_secs: queue_wait,
            };
            let first = self.cfg.sampler.sample(&slot.logits, &mut slot.rng);
            let Ok(first) = i32::try_from(first) else {
                self.finished.push(Completion {
                    id: slot.id,
                    tokens: slot.out,
                    latency_secs: now,
                    queue_wait_secs: queue_wait,
                    error: Some(format!("sampled token {first} exceeds i32 range")),
                });
                continue;
            };
            slot.out.push(first);
            self.generated_tokens += 1;
            if slot.out.len() >= slot.max_new {
                self.finished.push(Completion {
                    id: slot.id,
                    tokens: slot.out,
                    latency_secs: epoch.elapsed().as_secs_f64(), // det: wall-clock (metrics)
                    queue_wait_secs: queue_wait,
                    error: None,
                });
                continue;
            }
            self.states.push(state);
            self.meta.push(slot);
        }
        self.peak_in_flight = self.peak_in_flight.max(self.states.len());
        // Defensive: a slot with no sampled token cannot join a batched
        // decode — retire it as degraded instead of poisoning the step.
        if self.meta.iter().any(|m| m.out.is_empty()) {
            let now = epoch.elapsed().as_secs_f64(); // det: wall-clock (metrics)
            for si in (0..self.meta.len()).rev() {
                if self.meta[si].out.is_empty() {
                    let m = self.meta.remove(si);
                    self.states.remove(si);
                    self.finished.push(Completion {
                        id: m.id,
                        tokens: m.out,
                        latency_secs: now,
                        queue_wait_secs: m.queue_wait_secs,
                        error: Some("malformed slot: in flight with no sampled token".into()),
                    });
                }
            }
        }
        if self.states.is_empty() {
            return Ok(!self.queue.is_empty());
        }
        // One batched decode over every in-flight sequence's last token.
        let tokens: Vec<i32> = self
            .meta
            .iter()
            .filter_map(|m| m.out.last().copied())
            .collect();
        let logits = decode_batch(self.model, &mut self.states, &tokens, &mut self.scratch)?;
        self.decode_steps += 1;
        // Sample per slot (ascending slot order; each slot's own RNG).
        // `retire` collects (slot, error) pairs in ascending slot order.
        let mut retire: Vec<(usize, Option<String>)> = Vec::new();
        for (si, m) in self.meta.iter_mut().enumerate() {
            m.logits.clear();
            m.logits.extend_from_slice(logits.row(si));
            let t = self.cfg.sampler.sample(&m.logits, &mut m.rng);
            match i32::try_from(t) {
                Ok(tok) => {
                    m.out.push(tok);
                    self.generated_tokens += 1;
                    if m.out.len() >= m.max_new {
                        retire.push((si, None));
                    }
                }
                Err(_) => {
                    retire.push((si, Some(format!("sampled token {t} exceeds i32 range"))));
                }
            }
        }
        // Retire in ascending slot order (completions keep a stable
        // order); remove descending so indices stay valid.
        let now = epoch.elapsed().as_secs_f64(); // det: wall-clock (metrics)
        for (si, error) in &retire {
            let m = &self.meta[*si];
            self.finished.push(Completion {
                id: m.id,
                tokens: m.out.clone(),
                latency_secs: now,
                queue_wait_secs: m.queue_wait_secs,
                error: error.clone(),
            });
        }
        for (si, _) in retire.iter().rev() {
            self.meta.remove(*si);
            self.states.remove(*si);
        }
        Ok(!(self.queue.is_empty() && self.states.is_empty()))
    }

    /// Aggregate report over `drained` (completions previously taken via
    /// [`Self::take_finished`]) plus anything still in `finished`.  All
    /// counters and the wall clock are anchored to the driver's epoch
    /// (its first `step`), so the numbers stay consistent when manual
    /// `step()` calls preceded this.
    pub fn report(&mut self, drained: Vec<Completion>) -> ServeReport {
        let epoch = *self.epoch.get_or_insert_with(Instant::now); // det: wall-clock (metrics)
        let wall = epoch.elapsed().as_secs_f64();
        let mut completions = drained;
        completions.extend(self.finished.iter().cloned());
        completions.sort_by_key(|c| c.id);
        let failed = completions.iter().filter(|c| c.error.is_some()).count();
        ServeReport {
            wall_secs: wall,
            decode_steps: self.decode_steps,
            generated_tokens: self.generated_tokens,
            tokens_per_sec: self.generated_tokens as f64 / wall.max(1e-9),
            peak_in_flight: self.peak_in_flight,
            failed,
            completions,
        }
    }

    /// Drain queue and slots; returns the aggregate report.
    pub fn run_to_completion(&mut self) -> Result<ServeReport> {
        while self.step()? {}
        Ok(self.report(Vec::new()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode, RunConfig};
    use crate::coordinator::{Backend, NativeBackend};

    fn model(mode: Mode) -> InferModel {
        let rc = RunConfig {
            model: "spt-nano".into(),
            mode,
            seed: 9,
            ..RunConfig::default()
        };
        let backend = NativeBackend::new();
        let state = backend.init_state(&rc).unwrap();
        InferModel::new(&rc, state).unwrap()
    }

    fn requests(n: usize, max_new: usize) -> Vec<Request> {
        (0..n)
            .map(|id| Request {
                id,
                prompt: vec![1 + id as i32, 2, 3, 4 + id as i32],
                max_new_tokens: max_new,
            })
            .collect()
    }

    fn run(model: &InferModel, reqs: &[Request], max_batch: usize) -> ServeReport {
        let cfg = ServeConfig {
            max_batch,
            sampler: Sampler::TopK { k: 8, temperature: 0.9 },
            seed: 77,
        };
        let mut driver = ServeDriver::new(model, cfg).unwrap();
        for r in reqs {
            driver.submit(r.clone()).unwrap();
        }
        driver.run_to_completion().unwrap()
    }

    #[test]
    fn serving_is_batch_invariant() {
        // The continuous-batching contract: every request generates the
        // same tokens whether it shares a batch or runs alone.
        for mode in Mode::ALL {
            let m = model(mode);
            let reqs = requests(5, 7);
            let batched = run(&m, &reqs, 4);
            let serial = run(&m, &reqs, 1);
            assert_eq!(batched.completions.len(), 5, "{mode:?}");
            assert_eq!(serial.completions.len(), 5, "{mode:?}");
            for (b, s) in batched.completions.iter().zip(&serial.completions) {
                assert_eq!(b.id, s.id, "{mode:?}");
                assert_eq!(b.tokens, s.tokens, "{mode:?} request {}", b.id);
                assert_eq!(b.tokens.len(), 7, "{mode:?}");
                assert!(b.error.is_none() && s.error.is_none(), "{mode:?}");
            }
            assert!(batched.peak_in_flight > 1, "{mode:?}: never batched");
            assert_eq!(serial.peak_in_flight, 1, "{mode:?}");
            assert_eq!(batched.failed, 0, "{mode:?}");
            // Queued requests wait longer when slots are scarcer.
            assert!(
                serial.queue_wait_percentile(99.0) >= batched.queue_wait_percentile(50.0),
                "{mode:?}"
            );
        }
    }

    #[test]
    fn admit_and_retire_follow_submission_order() {
        let m = model(Mode::Spt);
        // Request 0 is long, 1 and 2 shorter: with capacity 2, request 2
        // must wait for a retirement, then take the freed slot.
        let reqs = vec![
            Request { id: 0, prompt: vec![1, 2, 3], max_new_tokens: 10 },
            Request { id: 1, prompt: vec![4, 5, 6], max_new_tokens: 3 },
            Request { id: 2, prompt: vec![7, 8, 9], max_new_tokens: 3 },
        ];
        let mut driver =
            ServeDriver::new(&m, ServeConfig { max_batch: 2, ..Default::default() }).unwrap();
        for r in &reqs {
            driver.submit(r.clone()).unwrap();
        }
        // Step 1: 0 and 1 admitted (submission order), 2 queued.
        assert!(driver.step().unwrap());
        assert_eq!(driver.in_flight_ids(), vec![0, 1], "admission order");
        assert_eq!(driver.queued(), 1);
        // Step 2: request 1 reaches 3 tokens (1 at admission + 2 decode
        // steps) and retires.
        assert!(driver.step().unwrap());
        assert_eq!(driver.in_flight_ids(), vec![0], "short request retired");
        assert_eq!(driver.queued(), 1);
        // Step 3: the freed slot goes to request 2.
        assert!(driver.step().unwrap());
        assert_eq!(driver.in_flight_ids(), vec![0, 2], "freed slot refilled");
        let report = driver.run_to_completion().unwrap();
        let ids: Vec<usize> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        let lens: Vec<usize> =
            report.completions.iter().map(|c| c.tokens.len()).collect();
        assert_eq!(lens, vec![10, 3, 3]);
        assert_eq!(report.generated_tokens, 16);
        assert!(report.latency_percentile(50.0) <= report.latency_percentile(99.0));
        assert!(report.queue_wait_percentile(50.0) <= report.queue_wait_percentile(99.0));
    }

    #[test]
    fn submit_validates_requests() {
        let m = model(Mode::Spt);
        let mut driver = ServeDriver::new(&m, ServeConfig::default()).unwrap();
        assert!(driver
            .submit(Request { id: 0, prompt: vec![], max_new_tokens: 1 })
            .is_err());
        assert!(driver
            .submit(Request { id: 1, prompt: vec![1], max_new_tokens: 0 })
            .is_err());
        let too_long = m.max_seq();
        assert!(driver
            .submit(Request { id: 2, prompt: vec![1, 2], max_new_tokens: too_long })
            .is_err());
        assert!(ServeDriver::new(&m, ServeConfig { max_batch: 0, ..Default::default() })
            .is_err());
    }

    #[test]
    fn max_new_one_completes_without_a_decode_step() {
        let m = model(Mode::Lora);
        let mut driver = ServeDriver::new(&m, ServeConfig::default()).unwrap();
        driver
            .submit(Request { id: 0, prompt: vec![1, 2], max_new_tokens: 1 })
            .unwrap();
        let report = driver.run_to_completion().unwrap();
        assert_eq!(report.completions.len(), 1);
        assert_eq!(report.completions[0].tokens.len(), 1);
        assert_eq!(report.decode_steps, 0);
    }

    #[test]
    fn cancel_retires_one_request_without_perturbing_others() {
        let m = model(Mode::Spt);
        let reqs = requests(3, 8);
        let mut driver =
            ServeDriver::new(&m, ServeConfig { max_batch: 4, ..Default::default() }).unwrap();
        for r in &reqs {
            driver.submit(r.clone()).unwrap();
        }
        // Two steps in, cancel request 1 at the boundary.
        driver.step().unwrap();
        driver.step().unwrap();
        assert!(driver.cancel(1, "deadline exceeded"));
        assert!(!driver.cancel(1, "again"), "already retired");
        assert!(!driver.cancel(99, "never existed"));
        let report = driver.run_to_completion().unwrap();
        assert_eq!(report.completions.len(), 3);
        assert_eq!(report.failed, 1);
        let cancelled = &report.completions[1];
        assert_eq!(cancelled.id, 1);
        assert_eq!(cancelled.error.as_deref(), Some("deadline exceeded"));
        assert_eq!(cancelled.tokens.len(), 3, "1 admission + 2 decode tokens");
        // Survivors are bit-identical to an undisturbed run with the
        // same config (per-request RNG streams are independent).
        let mut driver2 =
            ServeDriver::new(&m, ServeConfig { max_batch: 4, ..Default::default() }).unwrap();
        for r in &reqs {
            driver2.submit(r.clone()).unwrap();
        }
        let undisturbed = driver2.run_to_completion().unwrap();
        for (got, want) in report
            .completions
            .iter()
            .zip(&undisturbed.completions)
            .filter(|(g, _)| g.error.is_none())
        {
            assert_eq!(got.tokens, want.tokens, "request {}", got.id);
        }
    }

    #[test]
    fn take_finished_streams_and_report_folds_back() {
        let m = model(Mode::Lora);
        let mut driver = ServeDriver::new(&m, ServeConfig::default()).unwrap();
        for r in requests(3, 2) {
            driver.submit(r).unwrap();
        }
        let mut drained: Vec<Completion> = Vec::new();
        while driver.step().unwrap() {
            drained.extend(driver.take_finished());
        }
        drained.extend(driver.take_finished());
        assert_eq!(drained.len(), 3);
        let report = driver.report(drained);
        assert_eq!(report.completions.len(), 3);
        let ids: Vec<usize> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(report.failed, 0);
    }
}
