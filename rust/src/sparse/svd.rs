//! Singular values via one-sided Jacobi — substrate for Fig. 5.
//!
//! The paper motivates the routed FFN (dynamic pruning) with the CDF of
//! singular values of the FFN projection matrix vs. its output features:
//! W_I is near-full-rank (static pruning would hurt) while H = relu(X W_I)
//! is low-rank (dynamic, input-aware sparsity is cheap).  We need singular
//! values of matrices up to a few thousand columns; one-sided Jacobi is
//! simple, accurate, and fast enough at bench scale.

use super::matrix::Matrix;

/// Singular values of `a` (descending).  One-sided Jacobi on columns;
/// converges quadratically, `sweeps` capped for bench-scale inputs.
pub fn singular_values(a: &Matrix, max_sweeps: usize) -> Vec<f32> {
    // Work on the thinner orientation: svd(A) == svd(A^T).
    let work = if a.rows < a.cols { a.transpose() } else { a.clone() };
    let m = work.rows;
    let n = work.cols;
    // Column-major copy for cache-friendly column ops.
    let mut cols: Vec<Vec<f64>> = (0..n)
        .map(|c| (0..m).map(|r| work.at(r, c) as f64).collect())
        .collect();
    let eps = 1e-10;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    app += cols[p][i] * cols[p][i];
                    aqq += cols[q][i] * cols[q][i];
                    apq += cols[p][i] * cols[q][i];
                }
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) inner product.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let vp = cols[p][i];
                    let vq = cols[q][i];
                    cols[p][i] = c * vp - s * vq;
                    cols[q][i] = s * vp + c * vq;
                }
            }
        }
        if off < eps {
            break;
        }
    }
    let mut sv: Vec<f32> = cols
        .iter()
        .map(|col| {
            (col.iter().map(|x| x * x).sum::<f64>()).sqrt() as f32
        })
        .collect();
    sv.sort_by(|a, b| b.total_cmp(a));
    sv
}

/// Normalized cumulative singular-value CDF at `points` fractions —
/// the exact series Fig. 5 plots.
pub fn singular_value_cdf(a: &Matrix, points: usize) -> Vec<(f32, f32)> {
    let sv = singular_values(a, 30);
    let total: f64 = sv.iter().map(|&x| x as f64).sum();
    let n = sv.len();
    let mut out = Vec::with_capacity(points);
    let mut acc = 0.0f64;
    let mut next = 1;
    for (i, &s) in sv.iter().enumerate() {
        acc += s as f64;
        let frac = (i + 1) as f32 / n as f32;
        if frac >= next as f32 / points as f32 {
            out.push((frac, (acc / total.max(1e-30)) as f32));
            next += 1;
        }
    }
    out
}

/// Effective rank: #singular values needed to reach `energy` of the total.
pub fn effective_rank(a: &Matrix, energy: f32) -> usize {
    let sv = singular_values(a, 30);
    let total: f64 = sv.iter().map(|&x| x as f64).sum();
    let mut acc = 0.0f64;
    for (i, &s) in sv.iter().enumerate() {
        acc += s as f64;
        if acc >= energy as f64 * total {
            return i + 1;
        }
    }
    sv.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_matrix_recovers_diagonal() {
        let mut a = Matrix::zeros(4, 4);
        for (i, v) in [5.0f32, 3.0, 2.0, 1.0].into_iter().enumerate() {
            *a.at_mut(i, i) = v;
        }
        let sv = singular_values(&a, 20);
        for (got, want) in sv.iter().zip([5.0, 3.0, 2.0, 1.0]) {
            assert!((got - want).abs() < 1e-4, "{got} vs {want}");
        }
    }

    #[test]
    fn rank_one_matrix_has_one_singular_value() {
        let mut rng = Rng::new(1);
        let u = rng.normal_vec(16);
        let v = rng.normal_vec(8);
        let mut a = Matrix::zeros(16, 8);
        for r in 0..16 {
            for c in 0..8 {
                *a.at_mut(r, c) = u[r] * v[c];
            }
        }
        let sv = singular_values(&a, 20);
        assert!(sv[0] > 1e-3);
        assert!(sv[1] < 1e-4 * sv[0], "sv1={} sv0={}", sv[1], sv[0]);
        assert_eq!(effective_rank(&a, 0.99), 1);
    }

    #[test]
    fn frobenius_norm_preserved() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(12, 7, 1.0, &mut rng);
        let sv = singular_values(&a, 30);
        let fro2: f32 = sv.iter().map(|x| x * x).sum();
        let want = a.fro_norm().powi(2);
        assert!((fro2 - want).abs() / want < 1e-3);
    }

    #[test]
    fn random_gaussian_is_high_rank_lowrank_product_is_not() {
        // The Fig. 5 contrast in miniature: W ~ N(0,1) has near-linear
        // singular CDF; H = relu(X W) after projection is skewed.
        let mut rng = Rng::new(3);
        let w = Matrix::randn(48, 48, 1.0, &mut rng);
        let rank_w = effective_rank(&w, 0.5);
        // Low-rank-ish: product through a narrow bottleneck.
        let a = Matrix::randn(48, 8, 1.0, &mut rng);
        let b = Matrix::randn(8, 48, 1.0, &mut rng);
        let low = a.matmul(&b);
        let rank_low = effective_rank(&low, 0.5);
        assert!(
            rank_low < rank_w,
            "low-rank {rank_low} !< gaussian {rank_w}"
        );
    }

    #[test]
    fn cdf_monotone_ending_at_one() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let cdf = singular_value_cdf(&a, 10);
        for w in cdf.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-6);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-4);
    }
}
