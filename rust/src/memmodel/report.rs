//! Human-readable memory reports (feeds the bench harness tables).

use super::block::{MemBreakdown, Module, Phase};
use crate::util::fmt_bytes;

impl MemBreakdown {
    /// Multi-line report grouped by phase, largest first.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (phase, label) in [
            (Phase::Weights, "weights"),
            (Phase::Gradients, "gradients"),
            (Phase::Optimizer, "optimizer"),
            (Phase::SavedActivation, "saved activations"),
            (Phase::Transient, "transient (max)"),
        ] {
            let mut rows: Vec<_> = self
                .tensors
                .iter()
                .filter(|t| t.phase == phase)
                .collect();
            if rows.is_empty() {
                continue;
            }
            rows.sort_by(|a, b| b.bytes.cmp(&a.bytes));
            let total: u64 = rows.iter().map(|t| t.bytes).sum();
            out.push_str(&format!("  {label} ({}):\n", fmt_bytes(total)));
            for t in rows {
                out.push_str(&format!(
                    "    {:<24} {:>12}  [{}]\n",
                    t.name,
                    fmt_bytes(t.bytes),
                    match t.module {
                        Module::Mha => "mha",
                        Module::Ffn => "ffn",
                        Module::Shared => "shared",
                    }
                ));
            }
        }
        out.push_str(&format!(
            "  peak = {} (persistent {} + saved {} + transient {})\n",
            fmt_bytes(self.peak_bytes()),
            fmt_bytes(self.persistent_bytes()),
            fmt_bytes(self.saved_activation_bytes()),
            fmt_bytes(self.transient_bytes()),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{presets, Mode};
    use crate::memmodel::block::{block_peak, BlockWorkload};

    #[test]
    fn render_contains_key_tensors() {
        let cfg = presets::block("opt-2048").unwrap();
        let bd = block_peak(&cfg, Mode::Spt, &BlockWorkload { batch: 16, seq: 512 });
        let s = bd.render();
        assert!(s.contains("attn_vals(nxL)"));
        assert!(s.contains("peak = "));
        assert!(s.contains("saved activations"));
    }
}
