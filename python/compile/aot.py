"""AOT compiler: lower every SPT entry point to HLO text + manifest.

This is the single build-time bridge between the Python layers (L1 Pallas
kernels, L2 JAX model) and the rust coordinator (L3).  It lowers each entry
point with ``jax.jit(...).lower(...)`` and serializes **HLO text** — not
``.serialize()`` protos: jax >= 0.5 emits 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under ``artifacts/``):

* ``<name>.hlo.txt``    — one per entry point.
* ``manifest.json``     — for every artifact: input/output names, shapes,
  dtypes, parameter leaf paths (canonical pytree order), and the static
  workload dims (batch, seq, L, G', ...) the rust side needs.
* ``goldens.json``      — sample inputs/outputs for small artifacts, used
  by rust integration tests to validate the PJRT round trip numerically.

Run ``python -m compile.aot --help`` from ``python/``.  ``make artifacts``
invokes this with defaults; it is a no-op when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .kernels import pq, routed_ffn, sparse_attn, topl


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def _leaf_paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in flat]


class Builder:
    """Accumulates artifacts + manifest + goldens."""

    def __init__(self, out_dir: str, golden: bool):
        self.out_dir = out_dir
        self.golden = golden
        self.manifest: dict = {"artifacts": {}, "generated_unix": int(time.time())}
        self.goldens: dict = {}
        os.makedirs(out_dir, exist_ok=True)

    def add(
        self,
        name: str,
        fn,
        example_args: tuple,
        meta: dict | None = None,
        input_names: list[str] | None = None,
        golden: bool = False,
        donate_argnums: tuple = (),
    ):
        """Lower ``fn(*example_args)`` and record it."""
        t0 = time.time()
        flat_args, in_tree = jax.tree_util.tree_flatten(example_args)
        # keep_unused=True: the rust side feeds every leaf in the manifest
        # signature; jax must not prune unused parameters from the
        # executable's argument list.
        jfn = jax.jit(fn, donate_argnums=donate_argnums, keep_unused=True)
        lowered = jfn.lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_example = jax.eval_shape(fn, *example_args)
        flat_out, _ = jax.tree_util.tree_flatten(out_example)
        entry = {
            "file": f"{name}.hlo.txt",
            "inputs": [_spec(a) for a in flat_args],
            "input_paths": _leaf_paths(example_args),
            "outputs": [_spec(o) for o in flat_out],
            "output_paths": _leaf_paths(out_example),
            "meta": meta or {},
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        if input_names:
            entry["input_names"] = input_names
        self.manifest["artifacts"][name] = entry
        if golden and self.golden:
            # Golden inputs must be NON-TRIVIAL (zeros would validate
            # nothing): fill floats with seeded gaussians and ints with
            # values valid for their role (indices < n, codes < E).
            rng = np.random.default_rng(0xC0FFEE + len(self.goldens))
            golden_args = []
            for a in flat_args:
                if jnp.issubdtype(a.dtype, jnp.floating):
                    # 0.1 scale keeps GEMM intermediates O(1): golden
                    # comparisons then sit well inside the cross-backend
                    # fp-reassociation tolerance.
                    golden_args.append(
                        0.1
                        * jnp.asarray(
                            rng.standard_normal(a.shape, dtype=np.float32)
                        )
                    )
                else:
                    hi = max(1, int(min(s for s in a.shape[-1:] or [8])))
                    # safe upper bound: smallest trailing dim of any float
                    # input (n for idx, E for codes) — callers can rely on
                    # index-like ints being < first float input's dim 1.
                    n_like = flat_args[0].shape[1] if flat_args[0].ndim > 1 else 8
                    del hi
                    golden_args.append(
                        jnp.asarray(
                            rng.integers(0, max(2, n_like), a.shape),
                            dtype=a.dtype,
                        )
                    )
            golden_args = jax.tree_util.tree_unflatten(in_tree, golden_args)
            outs = jax.jit(fn)(*golden_args)
            flat_gargs, _ = jax.tree_util.tree_flatten(golden_args)
            flat_outs, _ = jax.tree_util.tree_flatten(outs)
            self.goldens[name] = {
                "inputs": [np.asarray(a).flatten().tolist() for a in flat_gargs],
                "input_specs": [_spec(a) for a in flat_gargs],
                "outputs": [np.asarray(o).flatten().tolist() for o in flat_outs],
                "output_specs": [_spec(o) for o in flat_outs],
            }
        dt = time.time() - t0
        print(f"  [aot] {name}: {len(text)//1024} KiB, {dt:.1f}s")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        if self.golden:
            with open(os.path.join(self.out_dir, "goldens.json"), "w") as f:
                json.dump(self.goldens, f)
        n = len(self.manifest["artifacts"])
        print(f"[aot] wrote {n} artifacts to {self.out_dir}")


# ---------------------------------------------------------------------------
# Entry-point groups
# ---------------------------------------------------------------------------


def add_model_artifacts(b: Builder, model_name: str, batch: int, seq: int):
    """End-to-end fine-tuning artifacts: init / train_step / eval / refresh."""
    mc = M.MODEL_CONFIGS[model_name]
    seq = min(seq, mc.max_seq)
    for mode in M.MODES:
        params = jax.eval_shape(
            lambda: M.init_model_params(jax.random.PRNGKey(0), mc, mode)
        )
        tokens = jnp.zeros((batch, seq), jnp.int32)
        targets = jnp.zeros((batch, seq), jnp.int32)
        meta = {
            "kind": "model",
            "model": model_name,
            "mode": mode,
            "batch": batch,
            "seq": seq,
            "vocab": mc.vocab_size,
            "n_layers": mc.n_layers,
            "d_model": mc.block.d_model,
            "param_count": mc.param_count(),
        }

        def init_fn(seed):
            return M.init_model_params(jax.random.PRNGKey(seed), mc, mode)

        b.add(
            f"model_init_{model_name}_{mode}",
            init_fn,
            (jnp.zeros((), jnp.int32),),
            meta={**meta, "entry": "init"},
        )

        step = T.make_train_step(mc, mode)
        params_c = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), params
        )
        opt_c = T.init_opt_state(params_c)
        b.add(
            f"train_step_{model_name}_{mode}",
            step,
            (params_c, opt_c, tokens, targets),
            meta={**meta, "entry": "train_step"},
        )
        ev = T.make_eval_loss(mc, mode)
        b.add(
            f"eval_loss_{model_name}_{mode}",
            ev,
            (params_c, tokens, targets),
            meta={**meta, "entry": "eval_loss"},
        )
        # MMLU-surrogate scorer: answer slot is at seq-2 (taskgen layout).
        b.add(
            f"qa_logits_{model_name}_{mode}",
            T.make_qa_logits(mc, mode, answer_pos=seq - 2),
            (params_c, tokens),
            meta={**meta, "entry": "qa_logits", "answer_pos": seq - 2},
        )
        # Chunked train step (K microbatches per dispatch) — §Perf fast path.
        k_chunk = 8
        tokens_k = jnp.zeros((k_chunk, batch, seq), jnp.int32)
        b.add(
            f"train_chunk{k_chunk}_{model_name}_{mode}",
            T.make_train_chunk(mc, mode, k_chunk),
            (params_c, opt_c, tokens_k, tokens_k),
            meta={**meta, "entry": "train_chunk", "chunk": k_chunk},
        )
    # DKM codebook refresh (spt only): whole-model, per-layer, one fwd pass.
    spt_params = jax.eval_shape(
        lambda: M.init_model_params(jax.random.PRNGKey(0), mc, "spt")
    )
    spt_params_c = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), spt_params
    )
    tokens = jnp.zeros((batch, seq), jnp.int32)
    b.add(
        f"codebook_refresh_{model_name}",
        T.make_model_codebook_refresh(mc),
        (spt_params_c, tokens),
        meta={
            "kind": "refresh",
            "model": model_name,
            "mode": "spt",
            "entry": "codebook_refresh",
        },
    )


def add_block_artifacts(
    b: Builder, cfg_name: str, batch: int, seq: int, modes=M.MODES
):
    """Per-block fwd+bwd step (paper Fig. 8 workload) for each tuning mode."""
    cfg = M.BLOCK_CONFIGS[cfg_name]
    x = jnp.zeros((batch, seq, cfg.d_model), jnp.float32)
    for mode in modes:
        params = jax.eval_shape(
            lambda: M.init_block_params(jax.random.PRNGKey(0), cfg, mode)
        )
        params_c = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), params
        )
        meta = {
            "kind": "block",
            "config": cfg_name,
            "mode": mode,
            "batch": batch,
            "seq": seq,
            "d_model": cfg.d_model,
            "d_head": cfg.d_head,
            "d_ffn": cfg.d_ffn,
            "entry": "block_step",
        }
        b.add(
            f"block_step_{cfg_name}_{mode}",
            T.make_block_fwdbwd(cfg, mode),
            (params_c, x),
            meta=meta,
        )

        def init_fn(seed, _cfg=cfg, _mode=mode):
            return M.init_block_params(jax.random.PRNGKey(seed), _cfg, _mode)

        b.add(
            f"block_init_{cfg_name}_{mode}",
            init_fn,
            (jnp.zeros((), jnp.int32),),
            meta={**meta, "entry": "block_init"},
        )


def add_module_artifacts(
    b: Builder, cfg_name: str, batch: int, seq: int
):
    """MHA-only / FFN-only fwd+bwd at several sparsity strengths
    (paper Tables 1, 4, 5)."""
    base = M.BLOCK_CONFIGS[cfg_name]
    x = jnp.zeros((batch, seq, base.d_model), jnp.float32)

    variants: list[tuple[str, M.BlockConfig, str]] = [
        ("full", base, "full"),
        ("lora", base, "lora"),
        # sparse MHA at 1/4 and 1/8 nonzeros; routed FFN at 3/4 and 1/2.
        ("spt_l4", base.with_sparsity(mha_num=1, mha_den=4), "spt"),
        ("spt_l8", base.with_sparsity(mha_num=1, mha_den=8), "spt"),
        ("spt_b34", base.with_sparsity(ffn_num=3, ffn_den=4), "spt"),
        ("spt_b12", base.with_sparsity(ffn_num=1, ffn_den=2), "spt"),
    ]
    for tag, cfg, mode in variants:
        params = jax.eval_shape(
            lambda: M.init_block_params(jax.random.PRNGKey(0), cfg, mode)
        )
        params_c = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), params
        )
        meta = {
            "kind": "module",
            "config": cfg_name,
            "mode": mode,
            "variant": tag,
            "batch": batch,
            "seq": seq,
            "mha_frac": f"{cfg.mha_topl_num}/{cfg.mha_topl_den}",
            "ffn_frac": f"{cfg.ffn_active_num}/{cfg.ffn_active_den}",
        }
        if not tag.startswith("spt_b"):  # MHA variants
            b.add(
                f"mha_{cfg_name}_{tag}",
                T.make_mha_fwdbwd(cfg, mode),
                (params_c, x),
                meta={**meta, "entry": "mha_fwdbwd"},
            )
        if not tag.startswith("spt_l"):  # FFN variants
            b.add(
                f"ffn_{cfg_name}_{tag}",
                T.make_ffn_fwdbwd(cfg, mode),
                (params_c, x),
                meta={**meta, "entry": "ffn_fwdbwd"},
            )


def add_kernel_artifacts(b: Builder, bh: int, n: int, dh: int):
    """Kernel-level micro artifacts (paper Tables 5, 6)."""
    m, e, dsub = dh // 8, 16, 8
    l = max(1, n // 8)
    q = jnp.zeros((bh, n, dh), jnp.float32)
    cb = jnp.zeros((m, e, dsub), jnp.float32)
    codes = jnp.zeros((bh, n, m), jnp.int32)
    idx = jnp.zeros((bh, n, l), jnp.int32)
    meta = {"kind": "kernel", "bh": bh, "n": n, "d_head": dh, "L": l, "M": m}

    b.add("kernel_pq_quantize", pq.pq_quantize, (q, cb),
          meta={**meta, "entry": "pq_quantize"}, golden=True)
    b.add(
        "kernel_topl_select",
        lambda cq, ck: topl.topl_select(cq, ck, l, causal=True),
        (codes, codes),
        meta={**meta, "entry": "topl_select"},
        golden=True,
    )
    b.add(
        "kernel_naive_pq_select",
        lambda cq, ck, c: topl.naive_pq_select(cq, ck, c, l, causal=True),
        (codes, codes, cb),
        meta={**meta, "entry": "naive_pq_select"},
    )
    b.add(
        "kernel_sparse_attention",
        lambda qq, kk, vv, ii: sparse_attn.sparse_attention(
            qq, kk, vv, ii, True, None
        ),
        (q, q, q, idx),
        meta={**meta, "entry": "sparse_attention"},
        golden=True,
    )

    def dense_attn(qq, kk, vv):
        s = jnp.einsum("bnd,bmd->bnm", qq, kk) / jnp.sqrt(float(dh))
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        s = jnp.where(mask[None], s, -1e30)
        return jnp.einsum("bnm,bmd->bnd", jax.nn.softmax(s, axis=-1), vv)

    b.add("kernel_dense_attention", dense_attn, (q, q, q),
          meta={**meta, "entry": "dense_attention"})

    # FFN kernels at a representative shape.
    nt, d, dffn, g, ga = bh * n // 4, 512, 2048, 8, 4
    xt = jnp.zeros((nt, d), jnp.float32)
    wi = jnp.zeros((d, dffn), jnp.float32)
    wo = jnp.zeros((dffn, d), jnp.float32)
    wr = jnp.zeros((d, g), jnp.float32)
    fmeta = {"kind": "kernel", "nt": nt, "d": d, "d_ffn": dffn, "G": g, "Ga": ga}
    b.add(
        "kernel_routed_ffn",
        lambda x2, a, o, r: routed_ffn.routed_ffn(x2, a, o, r, ga, 1.25)[0],
        (xt, wi, wo, wr),
        meta={**fmeta, "entry": "routed_ffn"},
        golden=True,
    )
    b.add(
        "kernel_dense_ffn",
        lambda x2, a, o: jax.nn.relu(x2 @ a) @ o,
        (xt, wi, wo),
        meta={**fmeta, "entry": "dense_ffn"},
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models", default="spt-tiny,spt-30m",
        help="comma list from: " + ",".join(M.MODEL_CONFIGS),
    )
    ap.add_argument("--model-batch", type=int, default=4)
    ap.add_argument("--model-seq", type=int, default=128)
    ap.add_argument(
        "--blocks",
        default="opt-1024,opt-2048,opt-2560,llama-2560,llama-4096",
        help="comma list from: " + ",".join(M.BLOCK_CONFIGS),
    )
    ap.add_argument("--block-batch", type=int, default=1)
    ap.add_argument("--block-seq", type=int, default=128)
    ap.add_argument(
        "--module-configs", default="opt-2048,llama-4096",
        help="configs for MHA/FFN module artifacts (Tables 1/4/5)",
    )
    ap.add_argument("--no-goldens", action="store_true")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()

    b = Builder(args.out, golden=not args.no_goldens)
    print("[aot] kernel micro artifacts")
    if not args.skip_kernels:
        add_kernel_artifacts(b, bh=8, n=128, dh=64)
    for name in filter(None, args.blocks.split(",")):
        print(f"[aot] block artifacts: {name}")
        add_block_artifacts(b, name, args.block_batch, args.block_seq)
    for name in filter(None, args.module_configs.split(",")):
        print(f"[aot] module artifacts: {name}")
        add_module_artifacts(b, name, args.block_batch, args.block_seq)
    for name in filter(None, args.models.split(",")):
        print(f"[aot] model artifacts: {name}")
        add_model_artifacts(b, name, args.model_batch, args.model_seq)
    b.finish()


if __name__ == "__main__":
    main()
