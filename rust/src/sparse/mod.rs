//! Rust-native sparse substrate: the paper's algorithms implemented
//! directly in rust.
//!
//! Two purposes:
//!
//! 1. **Baselines & alternatives** — Table 6 compares the bucket-sort
//!    top-L against "Naive-PQ" (float scores + full sort) and BSpMV against
//!    the BSR masking approach; those comparisons are regenerated here at
//!    native speed, independent of the XLA runtime.
//! 2. **Cross-validation** — the same contracts as the L1 Pallas kernels
//!    (`python/compile/kernels/`), checked against each other through the
//!    goldens round trip and through property tests, so a bug in either
//!    implementation surfaces as a disagreement.
//!
//! Modules mirror the paper's §4–§5 structure.  Codes and top-L
//! selections live in flat contiguous buffers ([`codes`]), and [`mha`]
//! layers a rayon-parallel multi-head path (head × query-chunk fan-out,
//! block-parallel routed FFN) over the sequential single-head pipelines,
//! which remain the cross-validation reference.
//!
//! Since the native-backend refactor the substrate is trainable:
//! [`grad`] implements the backward passes (dense projections, sparse
//! attention through the fixed top-L mask, routed FFN along the same
//! routing as the forward), with parallel twins in [`mha`].  Structure
//! decisions — PQ quantization, top-L and top-G' selection — stay
//! non-differentiable, as in the paper's kernels.

pub mod attention;
pub mod bspmv;
pub mod bsr;
pub mod codes;
pub mod csr;
pub mod grad;
pub mod kernel;
pub mod matrix;
pub mod mha;
pub mod naive_pq;
pub mod pq;
pub mod svd;
pub mod topl;

pub use codes::{Codes, TopL};
pub use csr::Csr;
pub use matrix::{Matrix, PackedB, Workspace};
pub use mha::MultiHeadSparseAttention;
