//! Kernel-level bench for the register-blocked microkernel: gemm /
//! gemm_nt / CSR spmm / routed FFN at the `spt-mini-64` preset shapes
//! (d_model=64, d_head=16, d_ffn=256, 8 FFN groups with G'=4,
//! vocab=2048, seq=128, L=seq/4), emitting
//! `bench_out/BENCH_kernels_native.json` — the perf trajectory's first
//! kernel-level datapoints.
//!
//! Each GEMM shape is also timed against a scalar reference that
//! reproduces the pre-register-blocking inner loop (one-row axpy with
//! the `a == 0.0` branch; per-element dots for NT), so the JSON records
//! `speedup_vs_scalar` per shape.  All kernel timings run on a dedicated
//! 1-thread pool: the point is single-core kernel throughput, not rayon
//! scaling (table3/table5 cover that).

mod common;

use std::collections::BTreeMap;

use spt::metrics::{bench, Table};
use spt::sparse::{bspmv, matrix, Csr, Matrix, Workspace};
use spt::util::fmt_duration;
use spt::util::json::Json;
use spt::util::rng::Rng;

/// The pre-PR dense kernel's arithmetic: scalar one-row axpy over
/// ascending k, zero-`a` terms skipped.
fn scalar_gemm_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// The pre-PR NT kernel's arithmetic: one scalar ascending dot per
/// output element.
fn scalar_gemm_nt_ref(m: usize, kd: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * kd..(i + 1) * kd];
        for j in 0..n {
            let brow = &b[j * kd..(j + 1) * kd];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            out[i * n + j] = acc;
        }
    }
}

struct KernelRecord {
    kernel: &'static str,
    m: usize,
    k: usize,
    n: usize,
    median_s: f64,
    flops: f64,
    speedup_vs_scalar: Option<f64>,
}

impl KernelRecord {
    fn gflops(&self) -> f64 {
        self.flops / self.median_s / 1e9
    }
}

fn main() {
    let (w, s) = (common::warmup().max(1), common::samples().max(3));
    let mut rng = Rng::new(0x64);
    // spt-mini-64 preset shapes.
    let (seq, d, d_head, dff) = (128usize, 64usize, 16usize, 256usize);
    let (vocab, g, ga) = (2048usize, 8usize, 4usize);
    let l = seq / 4;
    let pool = common::pool(1);
    let mut records: Vec<KernelRecord> = Vec::new();

    // Dense GEMM shapes: QKV/O projection, FFN up, plus the NT readout
    // and FFN-dX shapes the training backward runs.
    let gemm_shapes: [(&'static str, usize, usize, usize); 2] =
        [("gemm_proj", seq, d, d), ("gemm_ffn", seq, d, dff)];
    let mut ws = Workspace::default();
    for (name, m, k, n) in gemm_shapes {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut out = vec![0.0f32; m * n];
        let r = bench(name, w, s, || {
            pool.install(|| {
                matrix::gemm_into(m, k, n, &a.data, &b.data, n, 0, &mut out, &mut ws.packb);
            });
            std::hint::black_box(&out);
        });
        let rref = bench("scalar_ref", w, s, || {
            scalar_gemm_ref(m, k, n, &a.data, &b.data, &mut out);
            std::hint::black_box(&out);
        });
        records.push(KernelRecord {
            kernel: name,
            m,
            k,
            n,
            median_s: r.median(),
            flops: 2.0 * (m * k * n) as f64,
            speedup_vs_scalar: Some(rref.median() / r.median()),
        });
    }

    // NT shapes: per-head attention logits (Q Kᵀ) and the tied readout
    // (xf tokᵀ — the widest product in the step).
    let nt_shapes: [(&'static str, usize, usize, usize); 2] =
        [("gemm_nt_logits", seq, d_head, seq), ("gemm_nt_readout", seq, d, vocab)];
    for (name, m, kd, n) in nt_shapes {
        let a = Matrix::randn(m, kd, 1.0, &mut rng);
        let b = Matrix::randn(n, kd, 1.0, &mut rng);
        let mut out = vec![0.0f32; m * n];
        let mut pack = Vec::new();
        let r = bench(name, w, s, || {
            pool.install(|| {
                matrix::gemm_nt_into(m, kd, n, &a.data, &b.data, kd, 0, &mut out, &mut pack);
            });
            std::hint::black_box(&out);
        });
        let rref = bench("scalar_nt_ref", w, s, || {
            scalar_gemm_nt_ref(m, kd, n, &a.data, &b.data, &mut out);
            std::hint::black_box(&out);
        });
        records.push(KernelRecord {
            kernel: name,
            m,
            k: kd,
            n,
            median_s: r.median(),
            flops: 2.0 * (m * kd * n) as f64,
            speedup_vs_scalar: Some(rref.median() / r.median()),
        });
    }

    // CSR sparse attention tail (SDDMM + softmax + SpMM) at L = seq/4.
    {
        let q = Matrix::randn(seq, d_head, 1.0, &mut rng);
        let k = Matrix::randn(seq, d_head, 1.0, &mut rng);
        let v = Matrix::randn(seq, d_head, 1.0, &mut rng);
        let sel_rows: Vec<Vec<u32>> = (0..seq)
            .map(|i| {
                let mut row = Vec::with_capacity(l);
                let mut j = u32::try_from(i % 7).unwrap();
                while row.len() < l {
                    if !row.contains(&j) {
                        row.push(j);
                    }
                    j = (j + 5) % u32::try_from(seq).unwrap();
                }
                row
            })
            .collect();
        let proto = Csr::from_rows(&sel_rows, seq);
        let r = bench("spmm_attn", w, s, || {
            pool.install(|| {
                let mut csr = proto.clone();
                csr.sddmm(&q, &k);
                csr.softmax_rows();
                std::hint::black_box(csr.spmm(&v));
            });
        });
        records.push(KernelRecord {
            kernel: "spmm_attn",
            m: seq,
            k: d_head,
            n: l,
            // SDDMM + SpMM multiply-adds over the L kept entries per row.
            flops: 2.0 * (2 * seq * l * d_head) as f64,
            median_s: r.median(),
            speedup_vs_scalar: None,
        });
    }

    // Routed FFN at beta = G'/G = 1/2 (the block GEMMs ride the same
    // microkernel through gemm_into's column-block addressing).
    {
        let x = Matrix::randn(seq, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, dff, 0.2, &mut rng);
        let wo = Matrix::randn(dff, d, 0.2, &mut rng);
        let routing = bspmv::route(&Matrix::randn(seq, g, 1.0, &mut rng), ga);
        let r = bench("routed_ffn", w, s, || {
            pool.install(|| {
                std::hint::black_box(bspmv::routed_ffn(&x, &wi, &wo, &routing));
            });
        });
        records.push(KernelRecord {
            kernel: "routed_ffn",
            m: seq,
            k: d,
            n: dff,
            // Active fraction G'/G of the dense 2*(x@Wi + h@Wo) FLOPs.
            flops: 2.0 * (2 * seq * d * dff) as f64 * (ga as f64 / g as f64),
            median_s: r.median(),
            speedup_vs_scalar: None,
        });
    }

    let mut table = Table::new(
        "Kernel bench — register-blocked microkernel at spt-mini-64 shapes (1 thread)",
        &["Kernel", "m x k x n", "Median", "GFLOP/s", "Speedup vs scalar"],
    );
    for rec in &records {
        table.row(&[
            rec.kernel.to_string(),
            format!("{}x{}x{}", rec.m, rec.k, rec.n),
            fmt_duration(rec.median_s),
            format!("{:.2}", rec.gflops()),
            rec.speedup_vs_scalar
                .map(|x| format!("{x:.2}x"))
                .unwrap_or_default(),
        ]);
    }
    common::emit("kernel_bench", &table);

    let kernels: Vec<Json> = records
        .iter()
        .map(|rec| {
            let mut o = BTreeMap::new();
            o.insert("kernel".to_string(), Json::Str(rec.kernel.to_string()));
            o.insert("m".to_string(), Json::Num(rec.m as f64));
            o.insert("k".to_string(), Json::Num(rec.k as f64));
            o.insert("n".to_string(), Json::Num(rec.n as f64));
            o.insert("ms_median".to_string(), Json::Num(rec.median_s * 1e3));
            o.insert("gflops".to_string(), Json::Num(rec.gflops()));
            if let Some(sp) = rec.speedup_vs_scalar {
                o.insert("speedup_vs_scalar".to_string(), Json::Num(sp));
            }
            Json::Obj(o)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("kernel_bench".to_string()));
    top.insert("model".to_string(), Json::Str("spt-mini-64".to_string()));
    top.insert("threads".to_string(), Json::Num(1.0));
    top.insert("kernels".to_string(), Json::Arr(kernels));
    common::emit_json("BENCH_kernels_native", &Json::Obj(top));
}
