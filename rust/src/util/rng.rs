//! Deterministic PRNG (xoshiro256**) — substrate for data generation and
//! the property-testing harness (no `rand` crate in the offline registry).

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so correlated integer seeds give decorrelated
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).  Rejection-free Lemire reduction.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork an independent stream (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs = r.normal_vec(20_000);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.06, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(8);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
