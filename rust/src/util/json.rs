//! Minimal JSON parser/writer.
//!
//! The crate registry available to this build has no `serde`/`serde_json`,
//! so the runtime's manifest/goldens/reports use this hand-rolled
//! recursive-descent implementation.  It supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, booleans, null) which
//! is all `aot.py` emits.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { pos: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        self.skip_ws();
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| JsonError { pos: start, msg: "bad utf8".into() })?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError { pos: start, msg: format!("bad number: {e}") })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(JsonError {
                                pos: self.pos,
                                msg: "bad \\u escape".into(),
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or(JsonError {
                                    pos: self.pos,
                                    msg: "bad hex digit".into(),
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) => {
                    // Collect the full UTF-8 sequence starting at c.
                    let len = utf8_len(c);
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    match std::str::from_utf8(&self.bytes[start..self.pos]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => out.push('\u{FFFD}'),
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing bytes");
    }
    Ok(v)
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").idx(1).as_i64(), Some(2));
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""A\t\\""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\\"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn roundtrips_display() {
        let src = r#"{"a":[1,2.5,true,null],"b":"x\"y"}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn handles_unicode_passthrough() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn getters_miss_gracefully() {
        let v = parse("{}").unwrap();
        assert_eq!(v.get("missing").get("deeper"), &Json::Null);
        assert_eq!(v.idx(3), &Json::Null);
    }
}
