//! Backward passes for the sparse substrate (the native training path).
//!
//! The forward pipelines in [`super::attention`] / [`super::bspmv`] treat
//! the *structure* decisions — PQ quantization, bucket-sort top-L
//! selection, router top-G' selection — as non-differentiable, exactly as
//! the paper's CUDA kernels do: gradients flow only through the kept
//! attention entries and the activated FFN blocks, while codebooks are
//! maintained by the DKM-style k-means refresh instead of SGD.
//!
//! Everything here is the *sequential cross-validation reference*; the
//! rayon-parallel twins live in [`super::mha`] and must reproduce these
//! results bit-for-bit (same per-row / per-block operation order — only
//! the distribution of rows/blocks across workers differs).

use super::csr::Csr;
use super::matrix::Matrix;

/// `dX` for `Y = X @ W` given `dY`: `dX = dY @ W^T`.
///
/// `dy` is `[n, p]`, `w` is `[m, p]`-transposed-view (i.e. the forward
/// weight `[m, p]`), result is `[n, m]`.
pub fn matmul_dx(dy: &Matrix, w: &Matrix) -> Matrix {
    assert_eq!(dy.cols, w.cols, "matmul_dx: dY/W inner dim mismatch");
    let mut out = Matrix::zeros(dy.rows, w.rows);
    for i in 0..dy.rows {
        let dy_row = dy.row(i);
        let out_row = out.row_mut(i);
        for (k, o) in out_row.iter_mut().enumerate() {
            *o = dy_row.iter().zip(w.row(k)).map(|(a, b)| a * b).sum();
        }
    }
    out
}

/// `dW` for `Y = X @ W` given `dY`: `dW = X^T @ dY`.
///
/// `x` is `[n, m]`, `dy` is `[n, p]`, result is `[m, p]`.  Accumulation
/// over the `n` rows happens in ascending row order for every output
/// element, so the result is deterministic.
pub fn matmul_dw(x: &Matrix, dy: &Matrix) -> Matrix {
    assert_eq!(x.rows, dy.rows, "matmul_dw: X/dY row mismatch");
    let mut out = Matrix::zeros(x.cols, dy.cols);
    for i in 0..x.rows {
        let x_row = x.row(i);
        let dy_row = dy.row(i);
        for (k, &a) in x_row.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let out_row = out.row_mut(k);
            for (o, &b) in out_row.iter_mut().zip(dy_row) {
                *o += a * b;
            }
        }
    }
    out
}

/// Backward of both directions of `Y = X @ W` at once.
pub fn linear_backward(x: &Matrix, w: &Matrix, dy: &Matrix) -> (Matrix, Matrix) {
    (matmul_dx(dy, w), matmul_dw(x, dy))
}

/// ReLU backward given the forward *output* `h = relu(pre)`:
/// `dpre = dy ⊙ [h > 0]` (the subgradient at the kink is 0, matching
/// `relu`'s `max(0, ·)`).
pub fn relu_backward(h: &Matrix, dy: &Matrix) -> Matrix {
    assert_eq!(h.rows, dy.rows, "relu_backward shape mismatch");
    assert_eq!(h.cols, dy.cols, "relu_backward shape mismatch");
    let data = h
        .data
        .iter()
        .zip(&dy.data)
        .map(|(&hv, &g)| if hv > 0.0 { g } else { 0.0 })
        .collect();
    Matrix { rows: h.rows, cols: h.cols, data }
}

/// Backward of [`super::attention::sparse_attention_masked`] through the
/// kept entries only.
///
/// `attn` is the post-softmax CSR the forward returned (probabilities in
/// `values`, the flat top-L structure in `indices`).  Gradients w.r.t.
/// Q/K/V flow exclusively through the kept `(query, key)` pairs; causal
/// padding slots carry probability 0 after the forward re-mask and so
/// contribute nothing here.  Returns `(dq, dk, dv)`.
pub fn sparse_attention_backward(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    attn: &Csr,
    dy: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    assert_eq!(attn.rows, q.rows, "attn/Q row mismatch");
    assert_eq!(attn.cols, k.rows, "attn/K col mismatch");
    assert_eq!(dy.rows, q.rows, "dY/Q row mismatch");
    assert_eq!(dy.cols, v.cols, "dY/V col mismatch");
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut dq = Matrix::zeros(q.rows, q.cols);
    let mut dk = Matrix::zeros(k.rows, k.cols);
    let mut dv = Matrix::zeros(v.rows, v.cols);
    let mut dp = Vec::new();
    for r in 0..attn.rows {
        let range = attn.row_range(r);
        if range.is_empty() {
            continue;
        }
        let dy_row = dy.row(r);
        // dP_rj = dy_r . v_j, plus the softmax-backward row reduction
        // dot = sum_j P_rj dP_rj.
        dp.clear();
        let mut dot = 0.0f32;
        for p in range.clone() {
            let j = attn.indices[p] as usize;
            let g: f32 = dy_row.iter().zip(v.row(j)).map(|(a, b)| a * b).sum();
            dot += attn.values[p] * g;
            dp.push(g);
        }
        for (slot, p) in range.enumerate() {
            let j = attn.indices[p] as usize;
            let prob = attn.values[p];
            if prob != 0.0 {
                // dV_j += P_rj dy_r
                for (o, &g) in dv.row_mut(j).iter_mut().zip(dy_row) {
                    *o += prob * g;
                }
            }
            // Softmax backward: dS_rj = P_rj (dP_rj - dot); the logits
            // were S = scale * q_r . k_j.
            let ds = prob * (dp[slot] - dot);
            if ds == 0.0 {
                continue;
            }
            let c = scale * ds;
            for (o, &x) in dq.row_mut(r).iter_mut().zip(k.row(j)) {
                *o += c * x;
            }
            for (o, &x) in dk.row_mut(j).iter_mut().zip(q.row(r)) {
                *o += c * x;
            }
        }
    }
    (dq, dk, dv)
}

/// Backward of [`super::attention::dense_attention`] (the full/LoRA
/// attention path of the native model).  Recomputes the probability
/// matrix in the forward operation order, then applies the standard
/// softmax-attention gradients.  Returns `(dq, dk, dv)`.
pub fn dense_attention_backward(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    causal: bool,
    dy: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    assert_eq!(q.cols, k.cols, "Q/K dim mismatch");
    assert_eq!(k.rows, v.rows, "K/V row mismatch");
    assert_eq!(dy.rows, q.rows, "dY/Q row mismatch");
    assert_eq!(dy.cols, v.cols, "dY/V col mismatch");
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut logits = q.matmul(&k.transpose()).map(|x| x * scale);
    if causal {
        for i in 0..logits.rows {
            for j in (i + 1)..logits.cols {
                *logits.at_mut(i, j) = -1e30;
            }
        }
    }
    let p = logits.softmax_rows();
    // dV = P^T dY;  dP = dY V^T.
    let dv = matmul_dw(&p, dy);
    let dp = matmul_dx(dy, v);
    // Softmax backward per row: dS = P ⊙ (dP - sum_j P dP).
    let mut ds = Matrix::zeros(p.rows, p.cols);
    for r in 0..p.rows {
        let p_row = p.row(r);
        let dp_row = dp.row(r);
        let dot: f32 = p_row.iter().zip(dp_row).map(|(a, b)| a * b).sum();
        for (o, (&pv, &g)) in ds.row_mut(r).iter_mut().zip(p_row.iter().zip(dp_row)) {
            *o = pv * (g - dot);
        }
    }
    // dQ = scale * dS K;  dK = scale * dS^T Q.
    let dq = ds.matmul(k).map(|x| x * scale);
    let dk = matmul_dw(&ds, q).map(|x| x * scale);
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::attention;
    use crate::sparse::codes::TopL;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_backward_shapes_and_values() {
        // y = x @ w with scalar-friendly sizes; check against hand math.
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = Matrix::from_vec(2, 1, vec![5.0, 6.0]);
        let dy = Matrix::from_vec(2, 1, vec![1.0, 1.0]);
        let (dx, dw) = linear_backward(&x, &w, &dy);
        // dx = dy w^T = [[5,6],[5,6]]
        assert_eq!(dx.data, vec![5.0, 6.0, 5.0, 6.0]);
        // dw = x^T dy = [[4],[6]]
        assert_eq!(dw.data, vec![4.0, 6.0]);
    }

    #[test]
    fn relu_backward_masks_inactive() {
        let h = Matrix::from_vec(1, 4, vec![0.0, 1.5, 0.0, 2.0]);
        let dy = Matrix::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(relu_backward(&h, &dy).data, vec![0.0, 2.0, 0.0, 4.0]);
    }

    #[test]
    fn sparse_backward_with_full_mask_matches_dense_backward() {
        // When every key is kept the sparse backward must agree with the
        // dense-attention backward (same function, different bookkeeping).
        let mut rng = Rng::new(11);
        let (n, d) = (10, 6);
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let dy = Matrix::randn(n, d, 1.0, &mut rng);
        let full: Vec<Vec<u32>> = (0..n).map(|_| (0..n as u32).collect()).collect();
        let idx = TopL::from_rows(&full);
        let (_, attn) = attention::sparse_attention_masked(&q, &k, &v, &idx, false);
        let (dq_s, dk_s, dv_s) = sparse_attention_backward(&q, &k, &v, &attn, &dy);
        let (dq_d, dk_d, dv_d) = dense_attention_backward(&q, &k, &v, false, &dy);
        assert!(dq_s.max_abs_diff(&dq_d) < 1e-4, "{}", dq_s.max_abs_diff(&dq_d));
        assert!(dk_s.max_abs_diff(&dk_d) < 1e-4, "{}", dk_s.max_abs_diff(&dk_d));
        assert!(dv_s.max_abs_diff(&dv_d) < 1e-4, "{}", dv_s.max_abs_diff(&dv_d));
    }

    #[test]
    fn causal_padding_slots_get_no_gradient() {
        // Row 0 of a causal mask keeps only key 0; the padding slots point
        // at future keys whose probability is 0 after the re-mask, so dK
        // and dV rows for those keys must stay 0 (from row 0's view).
        let mut rng = Rng::new(12);
        let (n, d) = (5, 4);
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        // Only query row 0 receives upstream gradient.
        let mut dy = Matrix::zeros(n, d);
        for c in 0..d {
            *dy.at_mut(0, c) = 1.0;
        }
        let idx = TopL::from_rows(&(0..n).map(|_| vec![0u32, 1, 2]).collect::<Vec<_>>());
        let (_, attn) = attention::sparse_attention_masked(&q, &k, &v, &idx, true);
        let (dq, dk, dv) = sparse_attention_backward(&q, &k, &v, &attn, &dy);
        // Future keys 1 and 2 are masked for query 0: no gradient.
        for j in 1..3 {
            assert!(dk.row(j).iter().all(|&x| x == 0.0), "dk row {j}");
            assert!(dv.row(j).iter().all(|&x| x == 0.0), "dv row {j}");
        }
        // Query 0 attends only to key 0 with probability 1: softmax
        // backward collapses to 0 for dq.
        assert!(dq.row(0).iter().all(|&x| x.abs() < 1e-6));
        assert!(dv.row(0).iter().zip(v.row(0)).all(|(&g, _)| g == 1.0));
    }
}
