"""L1 Pallas kernels: routed FFN (BSpMV — blocked sparse matrix-vector).

Paper mapping (SPT §4.2, §5.2, Alg. 4): the FFN's inner projection rows and
outer projection columns are organized into G blocks; a tiny router
(``x @ W_R``) activates the top-G' blocks per token; computation is batched
*per weight block* — for each block, gather the tokens that activated it,
run a dense GEMM against that block, scatter results back.  This converts
dynamic per-token sparsity into G dense GEMMs (the paper's "BSpMV"),
avoiding both per-token masks (the OOM'ing BSR alternative in Table 6) and
irregular sparse kernels.

Hardware adaptation (CUDA -> Pallas/TPU): the paper parallelizes blocks
across GPU streams and uses ``index_put``/``index_get`` to (de)batch tokens.
On TPU, dynamic shapes are unavailable, so we use the standard
capacity-based formulation (as in MoE layers): each block owns a static
token capacity ``C = ceil(n * G'/G * capacity_factor)``; the per-block token
list is built with the same integer bucket-ranking used in topl.py; tokens
over capacity are dropped for that block (the paper's load-balancing loss
exists precisely to keep activation rates even, making drops rare), and
under-capacity slots are padded with gate 0.  Each grid step then runs two
MXU-shaped dense GEMMs: ``[C, d] @ [d, D/G]`` and ``[C, D/G] @ [D/G, d]``.

FLOP count per layer: ``2 * C * G * d * (D/G) * 2  ~  beta * dense-FFN``
with ``beta = G'/G`` — the real compute reduction behind Table 4's 2.0x /
1.3x FFN speedups at beta = 1/2 and 3/4.

AD: ``pallas_call`` (interpret) has no autodiff; the block compute carries a
hand-written backward Pallas kernel via ``jax.custom_vjp`` (gradients for
x, W_I, W_O, and the gate; routing indices are non-differentiable).  Router
params get gradients through the (plain-jnp, differentiable) gate softmax
and the load-balancing loss.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

INTERPRET = True

_NEG = -1e30


# ---------------------------------------------------------------------------
# Routing (token -> block assignment), all-integer ranking.
# ---------------------------------------------------------------------------


def router_scores(x: jax.Array, w_r: jax.Array) -> jax.Array:
    """Router logits ``[nt, G]`` — a single tiny GEMM (negligible cost)."""
    return x @ w_r


def topk_desc_indices(x: jax.Array, k: int) -> jax.Array:
    """Top-k indices along the last axis, descending, ties by lower index.

    Implemented with ``argsort`` (lowers to the long-stable ``sort`` HLO)
    rather than ``jax.lax.top_k``: jax >= 0.5 lowers top_k to a ``topk``
    instruction with a ``largest`` attribute that xla_extension 0.5.1's HLO
    text parser rejects (see DESIGN.md §Substitutions).
    """
    order = jnp.argsort(-x, axis=-1, stable=True)
    return order[..., :k]


def route_topk_mask(scores: jax.Array, g_active: int) -> jax.Array:
    """Boolean ``[nt, G]``: the top-G' blocks per token by |score|."""
    mag = jnp.abs(scores)
    idx = topk_desc_indices(mag, g_active)
    mask = jnp.zeros(scores.shape, dtype=bool)
    return mask.at[jnp.arange(scores.shape[0])[:, None], idx].set(True)


def build_block_assignment(
    mask: jax.Array, capacity: int
) -> tuple[jax.Array, jax.Array]:
    """Per-block token lists with static capacity.

    Args:
      mask: ``[nt, G]`` bool — token t activates block g.
      capacity: static per-block token budget C.

    Returns:
      token_idx: ``[G, C]`` int32 token ids (ascending token order; padded
        with arbitrary ids where invalid).
      valid: ``[G, C]`` float32 1/0 — slot holds a real assignment.

    Tokens beyond a block's capacity are dropped for that block (paper's
    bucket-overflow analog; LB loss keeps this rare).
    """
    nt, g = mask.shape
    m = mask.T.astype(jnp.int32)  # [G, nt]
    # Integer rank = combined (selected, ascending token id): selected tokens
    # first, each in token order — same trick as topl.py, no float sort.
    combined = m * nt + (nt - 1 - jnp.arange(nt))[None, :]
    token_idx = topk_desc_indices(combined, capacity)  # [G, C]
    sel = jnp.take_along_axis(m, token_idx, axis=1)  # [G, C]
    return token_idx.astype(jnp.int32), sel.astype(jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _route_decision(scores, g_active: int, capacity: int):
    """Routing decision (mask + block assignment), hidden from autodiff.

    Selection is discrete (no gradient); isolating it in a custom_vjp also
    works around this jaxlib's broken sort-JVP (GatherDimensionNumbers has
    no operand_batching_dims), which jax.grad would otherwise trip over
    when differentiating through argsort.
    """
    mask = route_topk_mask(scores, g_active)
    token_idx, valid = build_block_assignment(mask, capacity)
    # int32 mask: Pred-typed artifact outputs marshal unreliably through
    # xla_extension 0.5.1 buffers; keep cross-boundary tensors int/float.
    return mask.astype(jnp.int32), token_idx, valid


def _route_fwd(scores, g_active, capacity):
    return _route_decision(scores, g_active, capacity), scores


def _route_bwd(g_active, capacity, scores, _g):
    return (jnp.zeros_like(scores),)


_route_decision.defvjp(_route_fwd, _route_bwd)


# ---------------------------------------------------------------------------
# BSpMV forward / backward Pallas kernels (grid over blocks)
# ---------------------------------------------------------------------------


def _bspmv_fwd_kernel(x_ref, wi_ref, wo_ref, tid_ref, gate_ref, ypart_ref, h_ref):
    """One weight block g: gather tokens, two dense GEMMs, gated output.

    x_ref:    [nt, d]        (full token matrix, shared by all steps)
    wi_ref:   [1, d, Dg]     block g of W_I (column block)
    wo_ref:   [1, Dg, d]     block g of W_O (row block)
    tid_ref:  [1, C]         token ids assigned to block g
    gate_ref: [1, C]         gate (0 for padding slots)
    ypart_ref:[1, C, d]      gated partial outputs
    h_ref:    [1, C, Dg]     pre-gate hidden (saved for backward)
    """
    x = x_ref[...]
    wi = wi_ref[0]
    wo = wo_ref[0]
    tid = tid_ref[0]
    gate = gate_ref[0]
    xg = x[tid]  # [C, d] token gather (paper's index_get)
    h = jax.nn.relu(xg @ wi)  # [C, Dg] dense GEMM #1
    h_ref[0] = h
    ypart_ref[0] = (h * gate[:, None]) @ wo  # dense GEMM #2


def _bspmv_bwd_kernel(
    x_ref, wi_ref, wo_ref, tid_ref, gate_ref, h_ref, dyp_ref,
    dxpart_ref, dwi_ref, dwo_ref, dgate_ref,
):
    """Backward for one block: grads wrt x (per-block partial), W_I, W_O, gate."""
    x = x_ref[...]
    wi = wi_ref[0]
    wo = wo_ref[0]
    tid = tid_ref[0]
    gate = gate_ref[0]
    h = h_ref[0]  # [C, Dg] post-relu
    dyp = dyp_ref[0]  # [C, d]
    xg = x[tid]
    hg = h * gate[:, None]
    dwo_ref[0] = hg.T @ dyp  # [Dg, d]
    dhg = dyp @ wo.T  # [C, Dg]
    dgate_ref[0] = jnp.sum(dhg * h, axis=-1)  # [C]
    dh = dhg * gate[:, None]
    dpre = dh * (h > 0).astype(h.dtype)  # relu'
    dwi_ref[0] = xg.T @ dpre  # [d, Dg]
    dxg = dpre @ wi.T  # [C, d]
    nt, d = x.shape
    dxpart_ref[0] = jnp.zeros((nt, d), dtype=x.dtype).at[tid].add(dxg)


# ---------------------------------------------------------------------------
# custom_vjp composite over the block compute
# ---------------------------------------------------------------------------


def _bspmv_call(x, w_i_blocks, w_o_blocks, token_idx, gate):
    g, _, dg = w_i_blocks.shape
    nt, d = x.shape
    c = token_idx.shape[1]
    return pl.pallas_call(
        _bspmv_fwd_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((nt, d), lambda gi: (0, 0)),
            pl.BlockSpec((1, d, dg), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, dg, d), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, c), lambda gi: (gi, 0)),
            pl.BlockSpec((1, c), lambda gi: (gi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, d), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, c, dg), lambda gi: (gi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, c, d), jnp.float32),
            jax.ShapeDtypeStruct((g, c, dg), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x, w_i_blocks, w_o_blocks, token_idx, gate)


@jax.custom_vjp
def bspmv(x, w_i_blocks, w_o_blocks, token_idx, gate):
    """Blocked sparse matrix-vector multiply (paper Alg. 4).

    Args:
      x: ``[nt, d]`` tokens (nt = batch * seq).
      w_i_blocks: ``[G, d, D/G]`` inner projection, blocked by column.
      w_o_blocks: ``[G, D/G, d]`` outer projection, blocked by row.
      token_idx: ``[G, C]`` int32 per-block token lists.
      gate: ``[G, C]`` per-slot gate (0 for padding; includes router gate).

    Returns:
      ``[nt, d]`` combined FFN output (sum of per-block scattered partials).
    """
    y, _ = _bspmv_fwd(x, w_i_blocks, w_o_blocks, token_idx, gate)
    return y


def _combine(ypart, token_idx, nt, d):
    """Scatter-add per-block partial outputs back to token order."""
    g, c, _ = ypart.shape
    return jnp.zeros((nt, d), dtype=ypart.dtype).at[
        token_idx.reshape(-1)
    ].add(ypart.reshape(g * c, d))


def _bspmv_fwd(x, w_i_blocks, w_o_blocks, token_idx, gate):
    nt, d = x.shape
    ypart, h = _bspmv_call(x, w_i_blocks, w_o_blocks, token_idx, gate)
    y = _combine(ypart, token_idx, nt, d)
    return y, (x, w_i_blocks, w_o_blocks, token_idx, gate, h)


def _bspmv_bwd(res, dy):
    x, w_i_blocks, w_o_blocks, token_idx, gate, h = res
    g, _, dg = w_i_blocks.shape
    nt, d = x.shape
    c = token_idx.shape[1]
    # dy gathered per block (gather is the transpose of the fwd scatter-add).
    dyp = dy[token_idx]  # [G, C, d]
    dxpart, dwi, dwo, dgate = pl.pallas_call(
        _bspmv_bwd_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((nt, d), lambda gi: (0, 0)),
            pl.BlockSpec((1, d, dg), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, dg, d), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, c), lambda gi: (gi, 0)),
            pl.BlockSpec((1, c), lambda gi: (gi, 0)),
            pl.BlockSpec((1, c, dg), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, c, d), lambda gi: (gi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nt, d), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, d, dg), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, dg, d), lambda gi: (gi, 0, 0)),
            pl.BlockSpec((1, c), lambda gi: (gi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, nt, d), jnp.float32),
            jax.ShapeDtypeStruct((g, d, dg), jnp.float32),
            jax.ShapeDtypeStruct((g, dg, d), jnp.float32),
            jax.ShapeDtypeStruct((g, c), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x, w_i_blocks, w_o_blocks, token_idx, gate, h, dyp)
    dx = jnp.sum(dxpart, axis=0)  # [nt, d]
    d_tid = np.zeros(token_idx.shape, dtype=jax.dtypes.float0)
    return dx, dwi, dwo, d_tid, dgate


bspmv.defvjp(_bspmv_fwd, _bspmv_bwd)


# ---------------------------------------------------------------------------
# Full routed FFN (router -> assignment -> BSpMV), differentiable end to end.
# ---------------------------------------------------------------------------


def routed_ffn(
    x: jax.Array,
    w_i: jax.Array,
    w_o: jax.Array,
    w_r: jax.Array,
    g_active: int,
    capacity_factor: float = 1.0,
) -> tuple[jax.Array, jax.Array]:
    """Routed FFN: ``y = BSpMV(relu(x W_I) blocks, gated by router)``.

    Args:
      x: ``[nt, d]`` tokens.
      w_i: ``[d, D]``; w_o: ``[D, d]``; w_r: ``[d, G]`` router.
      g_active: G' — blocks active per token.
      capacity_factor: per-block capacity slack over perfect balance
        (1.0 = exactly balanced; >= G/G' disables drops entirely).

    Returns:
      ``(y [nt, d], router_scores [nt, G])`` — scores feed the LB loss.
    """
    nt, d = x.shape
    dd = w_i.shape[1]
    g = w_r.shape[1]
    assert dd % g == 0 and 1 <= g_active <= g
    scores = router_scores(x, w_r)
    capacity = int(np.ceil(nt * g_active / g * capacity_factor))
    capacity = min(max(capacity, 1), nt)
    mask_i, token_idx, valid = _route_decision(scores, g_active, capacity)
    mask = mask_i != 0
    # Differentiable gate: softmax over the selected block scores.
    gate_tok = jax.nn.softmax(jnp.where(mask, scores, _NEG), axis=-1)
    gate_tok = gate_tok * g_active  # keep output scale ~ dense FFN
    # Per-slot gate = token's gate for this block, zeroed on padding slots.
    gate_slot = jnp.take_along_axis(gate_tok.T, token_idx, axis=1) * valid
    wi_b = w_i.reshape(d, g, dd // g).transpose(1, 0, 2)  # [G, d, Dg]
    wo_b = w_o.reshape(g, dd // g, d)  # [G, Dg, d]
    y = bspmv(x, wi_b, wo_b, token_idx, gate_slot)
    return y, scores


def load_balance_loss(scores: jax.Array, g_active: int) -> jax.Array:
    """Switch-style LB loss (paper §4.2): G * sum_g f_g p_g / G'."""
    g = scores.shape[1]
    # Selection via the grad-isolated routing decision: the activation
    # fraction f is a constant w.r.t. autodiff (Switch-Transformer style);
    # gradient flows only through the mean router probability p.
    mask = _route_decision(scores, g_active, 1)[0].astype(scores.dtype)  # int32 0/1
    f = jnp.mean(mask, axis=0)
    p = jnp.mean(jax.nn.softmax(scores, axis=-1), axis=0)
    return g * jnp.sum(f * p) / g_active
