//! Paper Table 5: per-kernel time breakdown of the sparse MHA and routed
//! FFN vs their dense counterparts (forward pass).
//!
//! The paper breaks CUDA kernels (sgemm / cusparse::sddmm / csrmm /
//! pq_lookup / index ops).  The default build measures the rust-native
//! substrate: each pipeline stage timed standalone (the ratio structure
//! to reproduce: selection overhead small, routed FFN ~= beta x dense
//! FFN), plus a thread-scaling table for the rayon multi-head path
//! against the sequential reference.  With `--features xla` the
//! artifact-based breakdown through PJRT also runs.

mod common;

use spt::metrics::{bench, Table};
use spt::sparse::{attention, bspmv, naive_pq, pq, topl, Matrix};
use spt::util::fmt_duration;
use spt::util::rng::Rng;

fn main() {
    native_kernels();
    thread_scaling();
    #[cfg(feature = "xla")]
    xla_kernels();
}

fn native_kernels() {
    let (w, s) = (common::warmup().max(1), common::samples().max(3));
    let mut rng = Rng::new(17);
    let (n, d, m, e) = (256usize, 64usize, 8usize, 16usize);
    let l = n / 4;
    let mut cb = pq::Codebooks::random(m, e, d / m, &mut rng);
    let q = Matrix::randn(n, d, 1.0, &mut rng);
    let k = Matrix::randn(n, d, 1.0, &mut rng);
    let v = Matrix::randn(n, d, 1.0, &mut rng);
    for _ in 0..3 {
        pq::codebook_update(&k.data, &mut cb, 1.0);
    }
    let cq = pq::quantize(&q.data, &cb);
    let ck = pq::quantize(&k.data, &cb);
    let tables = naive_pq::ScoreTables::build(&cb);
    let (nt, dff, gg, ga) = (256usize, 1024usize, 8usize, 4usize);
    let x = Matrix::randn(nt, d, 1.0, &mut rng);
    let wi = Matrix::randn(d, dff, 0.2, &mut rng);
    let wo = Matrix::randn(dff, d, 0.2, &mut rng);
    let routing = bspmv::route(&Matrix::randn(nt, gg, 1.0, &mut rng), ga);

    // The blocked GEMM microkernel underneath every dense product, timed
    // with and without workspace reuse (the training hot path reuses).
    let mut ws = spt::sparse::Workspace::default();
    let gemm_alloc = bench("gemm_alloc", w, s, || {
        std::hint::black_box(x.matmul(&wi));
    });
    let gemm_reuse = bench("gemm_reuse", w, s, || {
        std::hint::black_box(x.matmul_ws(&wi, &mut ws));
    });

    let results: Vec<(&str, spt::metrics::BenchResult)> = vec![
        ("GEMM microkernel (alloc per call)", gemm_alloc),
        ("GEMM microkernel (reused workspace)", gemm_reuse),
        (
            "pq_lookup (quantize)",
            bench("quantize", w, s, || {
                std::hint::black_box(pq::quantize(&q.data, &cb));
            }),
        ),
        (
            "bucket-sort top-L",
            bench("topl", w, s, || {
                std::hint::black_box(topl::select(&cq, &ck, l, false));
            }),
        ),
        (
            "naive-PQ select",
            bench("naive_pq", w, s, || {
                std::hint::black_box(naive_pq::select(&cq, &ck, &tables, l, false));
            }),
        ),
        (
            "sparse attn (sddmm+softmax+spmm)",
            bench("sparse_attn", w, s, || {
                std::hint::black_box(attention::sparse_attention(
                    &q, &k, &v, &cb, l, false,
                ));
            }),
        ),
        (
            "dense attention",
            bench("dense_attn", w, s, || {
                std::hint::black_box(attention::dense_attention(&q, &k, &v, false));
            }),
        ),
        (
            "routed FFN (BSpMV)",
            bench("routed_ffn", w, s, || {
                std::hint::black_box(bspmv::routed_ffn(&x, &wi, &wo, &routing));
            }),
        ),
        (
            "dense FFN",
            bench("dense_ffn", w, s, || {
                std::hint::black_box(bspmv::dense_gated_ffn(&x, &wi, &wo, &routing));
            }),
        ),
    ];

    let get = |nm: &str| {
        results
            .iter()
            .find(|(lbl, _)| *lbl == nm)
            .map(|(_, r)| r.median())
    };
    let mut table = Table::new(
        &format!(
            "Table 5 — kernel-level forward-time breakdown on the substrate \
             (n={n}, d={d}, L={l}; FFN nt={nt}, D={dff}, beta=1/2)"
        ),
        &["Kernel", "Median", "Calls/s", "Notes"],
    );
    for (label, r) in &results {
        let note = match *label {
            "routed FFN (BSpMV)" => get("dense FFN")
                .map(|dn| format!("{:.2}x vs dense (beta=1/2 => ~2x ideal)", dn / r.median()))
                .unwrap_or_default(),
            "bucket-sort top-L" => get("naive-PQ select")
                .map(|nv| format!("{:.2}x vs naive-PQ", nv / r.median()))
                .unwrap_or_default(),
            "sparse attn (sddmm+softmax+spmm)" => get("dense attention")
                .map(|dn| {
                    format!("{:.2}x vs dense (memory, not speed, is the goal)", dn / r.median())
                })
                .unwrap_or_default(),
            "GEMM microkernel (reused workspace)" => {
                get("GEMM microkernel (alloc per call)")
                    .map(|al| format!("{:.2}x vs alloc per call", al / r.median()))
                    .unwrap_or_default()
            }
            _ => String::new(),
        };
        table.row(&[
            label.to_string(),
            fmt_duration(r.median()),
            format!("{:.1}", 1.0 / r.median()),
            note,
        ]);
    }
    common::emit("table5_kernel_breakdown", &table);
}

/// Multi-head path across thread counts vs the sequential reference.
fn thread_scaling() {
    let wl = common::native_workload(8, 256, 64, 64, 512, 1024, 8, 4);
    common::emit_thread_scaling(
        &wl,
        "Table 5b — multi-head substrate thread scaling \
         (8 heads, n=256, L=64 + routed FFN beta=1/2)",
        "table5_thread_scaling",
    );
}

/// The original artifact-based breakdown through PJRT.
#[cfg(feature = "xla")]
fn xla_kernels() {
    use spt::coordinator::profile::random_inputs;

    let Some(engine) = common::engine_or_skip("table5") else { return };
    let (w, s) = (common::warmup(), common::samples());
    let kernels = [
        ("pq_lookup (quantize)", "kernel_pq_quantize"),
        ("bucket-sort top-L", "kernel_topl_select"),
        ("naive-PQ select", "kernel_naive_pq_select"),
        ("sparse attn (sddmm+softmax+spmm)", "kernel_sparse_attention"),
        ("dense attention", "kernel_dense_attention"),
        ("routed FFN (BSpMV)", "kernel_routed_ffn"),
        ("dense FFN", "kernel_dense_ffn"),
    ];
    let mut table = Table::new(
        "Table 5 (XLA artifacts) — kernel forward-time breakdown",
        &["Kernel", "Median", "Calls/s", "Notes"],
    );
    let mut results = Vec::new();
    for (label, name) in kernels {
        if engine.manifest().get(name).is_err() {
            println!("[table5] missing {name}");
            continue;
        }
        let inputs = random_inputs(&engine, name, 5).expect("inputs");
        engine.load(name).expect("compile");
        let r = bench(name, w, s, || {
            engine.run(name, &inputs).expect("run");
        });
        results.push((label, r));
    }
    // Notes: ratios that correspond to the paper's observations.
    let get = |nm: &str| {
        results
            .iter()
            .find(|(l, _)| *l == nm)
            .map(|(_, r)| r.median())
    };
    for (label, r) in &results {
        let note = match *label {
            "routed FFN (BSpMV)" => get("dense FFN")
                .map(|d| format!("{:.2}x vs dense (beta=1/2 => ~2x ideal)", d / r.median()))
                .unwrap_or_default(),
            "bucket-sort top-L" => get("naive-PQ select")
                .map(|n| format!("{:.2}x vs naive-PQ", n / r.median()))
                .unwrap_or_default(),
            "sparse attn (sddmm+softmax+spmm)" => get("dense attention")
                .map(|d| format!("{:.2}x vs dense (memory, not speed, is the goal)", d / r.median()))
                .unwrap_or_default(),
            _ => String::new(),
        };
        table.row(&[
            label.to_string(),
            fmt_duration(r.median()),
            format!("{:.1}", 1.0 / r.median()),
            note,
        ]);
    }
    common::emit("table5_xla_kernel_breakdown", &table);

    // Engine-level cumulative stats (the "profiler output" analog).
    let mut stats = Table::new(
        "Engine execution stats",
        &["Artifact", "Calls", "Total", "Compile"],
    );
    for (name, st) in engine.stats() {
        stats.row(&[
            name,
            st.calls.to_string(),
            fmt_duration(st.total_secs),
            fmt_duration(st.compile_secs),
        ]);
    }
    common::emit("table5_engine_stats", &stats);
}
