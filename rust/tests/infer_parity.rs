//! The inference subsystem's parity and determinism contracts:
//!
//! * **Prefill/decode parity** — `prefill(prompt)` + N teacher-forced
//!   decode steps produce logits *bit-identical* to one training
//!   forward over the `prompt + N`-token sequence, in every tuning mode
//!   (the proptest randomizes sequence length, prompt split, and seed).
//! * **Pool invariance** — the same holds under dedicated rayon pools
//!   of 1, 2, and 8 threads, and the decoded bits agree across pools.
//! * **Checkpoint round trip** — train → `save_tagged` → load →
//!   generate is deterministic per seed, and identity mismatches fail
//!   with a clear error instead of a shape panic.

use spt::config::{Mode, RunConfig};
use spt::coordinator::checkpoint::{self, CkptMeta};
use spt::coordinator::{Backend, NativeBackend, Trainer, TrainerOptions};
use spt::data::SyntheticCorpus;
use spt::infer::{InferModel, Sampler, Session};
use spt::util::proptest::{check, prop_assert};
use spt::util::rng::Rng;

fn rc(model: &str, mode: Mode, seed: u64) -> RunConfig {
    RunConfig {
        model: model.into(),
        mode,
        seed,
        eval_every: 0,
        codebook_refresh_every: 0,
        ..RunConfig::default()
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Decode logits rows `p-1 .. seq-1` via prefill + teacher-forced decode.
fn decode_bits(model: &InferModel, toks: &[i32], p: usize) -> Vec<Vec<u32>> {
    let mut sess = Session::new(model, &toks[..p], toks.len()).expect("prefill");
    let mut rows = vec![bits(sess.logits())];
    for &t in &toks[p..] {
        rows.push(bits(sess.decode(t).expect("decode")));
    }
    rows
}

/// The parity assertion for one (model, mode, seed, seq, prompt) case.
fn assert_parity(
    model_name: &str,
    mode: Mode,
    seed: u64,
    seq: usize,
    p: usize,
) -> Result<(), String> {
    let cfg = rc(model_name, mode, seed);
    let backend = NativeBackend::new();
    let state = backend.init_state(&cfg).map_err(|e| e.to_string())?;
    let model = InferModel::new(&cfg, state.clone()).map_err(|e| e.to_string())?;
    let mut corpus = SyntheticCorpus::new(backend.vocab(&cfg).unwrap(), 4, 0.85, seed ^ 0xC0);
    let toks: Vec<i32> = corpus.sequence(seq).iter().map(|&t| t as i32).collect();
    let full = backend.forward_logits(&cfg, &state, &toks).map_err(|e| e.to_string())?;
    let got = decode_bits(&model, &toks, p);
    for (step, row) in got.iter().enumerate() {
        let want = bits(full.row(p - 1 + step));
        if row != &want {
            return Err(format!(
                "{model_name}/{mode:?} seed {seed} seq {seq} prompt {p}: \
                 logits row {} diverges from the full forward",
                p - 1 + step
            ));
        }
    }
    Ok(())
}

#[test]
fn prefill_decode_parity_proptest_all_modes() {
    // Randomized over sequence length, prompt split, and seed; every
    // mode must reproduce the training forward bit for bit — including
    // prompts shorter than the session L (the bucket-clamp edge) and
    // 1-token prompts.
    check(8, |g| {
        let seq = g.usize_in(4, 32);
        let p = g.usize_in(1, seq - 1);
        let seed = g.rng().next_u64();
        for mode in Mode::ALL {
            assert_parity("spt-nano", mode, seed, seq, p).map_err(|e| e.to_string())?;
        }
        prop_assert(true, "unreachable")
    });
}

#[test]
fn prefill_decode_parity_multi_layer() {
    // The 2-layer stack: inter-layer residuals flow through the decode
    // caches of both layers.
    for mode in Mode::ALL {
        assert_parity("spt-nano-l2", mode, 11, 28, 9).unwrap();
        // Prompt of 1 token: everything after the first position runs
        // through the incremental path.
        assert_parity("spt-nano-l2", mode, 12, 16, 1).unwrap();
    }
}

#[test]
fn parity_holds_at_pools_1_2_8() {
    // Dedicated pools of 1, 2, and 8 threads: the decoded logits must
    // agree with the single-thread reference bit for bit (and with the
    // full forward, which assert_parity already pins per pool).
    for mode in Mode::ALL {
        let cfg = rc("spt-nano", mode, 21);
        let backend = NativeBackend::new();
        let state = backend.init_state(&cfg).unwrap();
        let model = InferModel::new(&cfg, state).unwrap();
        let mut corpus = SyntheticCorpus::new(512, 4, 0.85, 77);
        let toks: Vec<i32> = corpus.sequence(20).iter().map(|&t| t as i32).collect();
        let run_under = |threads: usize| {
            let pool = rayon::ThreadPoolBuilder::new()
                .num_threads(threads)
                .build()
                .expect("pool");
            pool.install(|| decode_bits(&model, &toks, 7))
        };
        let reference = run_under(1);
        for threads in [2usize, 8] {
            assert_eq!(
                reference,
                run_under(threads),
                "{mode:?}: decode bits diverge between pools of 1 and {threads}"
            );
        }
    }
    // And the parity contract itself under an oversubscribed pool.
    let pool = rayon::ThreadPoolBuilder::new().num_threads(8).build().unwrap();
    pool.install(|| {
        for mode in Mode::ALL {
            assert_parity("spt-nano", mode, 31, 24, 6).unwrap();
        }
    });
}

#[test]
fn train_checkpoint_generate_roundtrip() {
    // Short spt fine-tune -> tagged checkpoint -> load -> generate:
    // deterministic per seed, and the checkpoint's embedded identity
    // guards against loading under the wrong preset.
    let cfg = rc("spt-nano", Mode::Spt, 4);
    let backend = NativeBackend::new();
    let mut train_cfg = cfg.clone();
    train_cfg.steps = 3;
    train_cfg.batch = 2;
    train_cfg.seq = 24;
    let mut trainer = Trainer::new(&backend, train_cfg, TrainerOptions::default());
    trainer.train().expect("train");
    let state = trainer.last_state.as_ref().expect("state");
    let dir = std::env::temp_dir().join("spt_infer_roundtrip_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("gen.ckpt");
    checkpoint::save_tagged(
        state,
        &CkptMeta { model: "spt-nano".into(), mode: Mode::Spt, n_layers: 1 },
        &path,
    )
    .expect("save");

    let gen = |seed: u64| {
        let model = InferModel::from_checkpoint(&cfg, &path).expect("load");
        let mut corpus = SyntheticCorpus::new(model.vocab(), 4, 0.85, 1);
        let prompt: Vec<i32> = corpus.sequence(8).iter().map(|&t| t as i32).collect();
        let mut sess = Session::new(&model, &prompt, prompt.len() + 16).expect("prefill");
        let mut rng = Rng::new(seed);
        sess.generate(&Sampler::TopK { k: 32, temperature: 0.9 }, &mut rng, 16)
            .expect("generate")
    };
    let a = gen(5);
    assert_eq!(a, gen(5), "same seed must reproduce the stream");
    assert_eq!(a.len(), 16);
    assert!(a.iter().all(|&t| (t as usize) < 512), "tokens in vocab");

    // Wrong mode and wrong model fail up front with the identity error.
    let wrong = rc("spt-nano", Mode::Lora, 4);
    let err = InferModel::from_checkpoint(&wrong, &path).unwrap_err();
    assert!(err.to_string().contains("mode"), "unexpected error: {err}");
    let wrong_model = rc("spt-nano-l2", Mode::Spt, 4);
    assert!(InferModel::from_checkpoint(&wrong_model, &path).is_err());
}
