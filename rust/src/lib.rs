//! # SPT — Sparse fine-tuning of Transformer language models
//!
//! Rust + JAX + Pallas reproduction of *"SPT: Fine-Tuning Transformer-based
//! Language Models Efficiently with Sparsification"* (Gui et al., 2023).
//!
//! Three-layer architecture (Python never on the training path):
//!
//! * **L1 (Pallas)** — `python/compile/kernels/`: PQ quantization,
//!   bucket-sort top-L, sparse attention (SDDMM/softmax/SpMM), routed FFN
//!   (BSpMV), each with hand-written backward kernels.
//! * **L2 (JAX)** — `python/compile/model.py` + `train.py`: Transformer
//!   blocks in full/LoRA/SPT modes, AdamW fine-tuning step, lowered AOT to
//!   HLO text by `aot.py`.
//! * **L3 (this crate)** — the fine-tuning coordinator: config system,
//!   synthetic data pipeline, microbatch trainer, sparsity-trial manager,
//!   analytic GPU-memory model, a rust-native sparse substrate used for
//!   baselines/benches, and the harness regenerating every table and
//!   figure of the paper's evaluation.
//!
//! The PJRT execution path ([`runtime`] and the artifact-driven parts of
//! [`coordinator`]) is behind the off-by-default `xla` cargo feature: the
//! default build needs no PJRT toolchain and still provides the full
//! sparse substrate (including the parallel multi-head layer in
//! [`sparse::mha`]), memory model, data pipeline, and benches.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod memmodel;
pub mod metrics;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sparse;
pub mod util;
