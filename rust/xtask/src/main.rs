//! `cargo xtask` — workspace automation, wired up through the alias in
//! `rust/.cargo/config.toml`.
//!
//! Tasks:
//!
//! * `detlint` — the determinism lint pass described in `detlint.rs`
//!   and in README's "Determinism contract" section.  Run it as
//!   `cargo xtask detlint` (defaults to the spt crate's `src/`) or
//!   `cargo xtask detlint path/to/file.rs dir/` to lint specific paths.
//! * `benchdiff` — the perf regression gate described in
//!   `benchdiff.rs`: `cargo xtask benchdiff <baseline.json>
//!   <current.json>` fails on >25% same-host regressions against the
//!   committed baselines in `bench_out/baselines/`.

use std::path::PathBuf;
use std::process::ExitCode;

mod benchdiff;
mod detlint;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let Some(cmd) = args.next() else {
        eprintln!("usage: cargo xtask <detlint|benchdiff> [args...]");
        return ExitCode::FAILURE;
    };
    match cmd.as_str() {
        "detlint" => detlint::run(&args.map(PathBuf::from).collect::<Vec<_>>()),
        "benchdiff" => benchdiff::run(&args.collect::<Vec<_>>()),
        other => {
            eprintln!("unknown xtask '{other}' (available: detlint, benchdiff)");
            ExitCode::FAILURE
        }
    }
}
