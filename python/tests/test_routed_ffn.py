"""Routed FFN / BSpMV kernels vs reference: routing, fwd, bwd, capacity."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, routed_ffn

SETTINGS = dict(max_examples=3, deadline=None)


def _setup(seed, nt, d, dd, g):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (nt, d), dtype=jnp.float32)
    wi = jax.random.normal(ks[1], (d, dd), dtype=jnp.float32) * 0.1
    wo = jax.random.normal(ks[2], (dd, d), dtype=jnp.float32) * 0.1
    wr = jax.random.normal(ks[3], (d, g), dtype=jnp.float32) * 0.1
    return x, wi, wo, wr


# ---------------------------------------------------------------------------
# Routing / assignment invariants
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    g=st.sampled_from([2, 4, 8]),
    ga_frac=st.sampled_from([1, 2]),
)
def test_topk_mask_cardinality(seed, g, ga_frac):
    ga = max(1, g // (2 * ga_frac))
    x, _, _, wr = _setup(seed, 64, 32, 128, g)
    scores = routed_ffn.router_scores(x, wr)
    mask = routed_ffn.route_topk_mask(scores, ga)
    assert bool(jnp.all(jnp.sum(mask, axis=1) == ga))


def test_topk_mask_picks_largest_magnitude():
    scores = jnp.array([[0.1, -5.0, 2.0, 0.0], [3.0, 1.0, -1.0, -4.0]])
    mask = routed_ffn.route_topk_mask(scores, 2)
    assert mask.tolist() == [
        [False, True, True, False],
        [True, False, False, True],
    ]


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), cap=st.sampled_from([4, 8, 16]))
def test_block_assignment_invariants(seed, cap):
    x, _, _, wr = _setup(seed, 32, 16, 64, 4)
    mask = routed_ffn.route_topk_mask(routed_ffn.router_scores(x, wr), 2)
    tid, valid = routed_ffn.build_block_assignment(mask, cap)
    tid, valid, mask = np.asarray(tid), np.asarray(valid), np.asarray(mask)
    for g in range(4):
        sel = tid[g][valid[g] > 0]
        # valid slots reference tokens that actually chose this block
        assert all(mask[t, g] for t in sel)
        # no token appears twice in a block
        assert len(set(sel.tolist())) == len(sel)
        # ascending token order (Alg. 4 iterates tokens in order)
        assert list(sel) == sorted(sel)
        # capacity respected; drops only when oversubscribed
        want = min(int(mask[:, g].sum()), cap)
        assert len(sel) == want


# ---------------------------------------------------------------------------
# Forward / backward vs reference
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    nt=st.sampled_from([16, 64, 96]),
    d=st.sampled_from([16, 32]),
    mult=st.sampled_from([2, 4]),
    g=st.sampled_from([2, 4, 8]),
)
def test_forward_matches_ref(seed, nt, d, mult, g):
    dd = d * mult * g  # divisible by g
    ga = max(1, g // 2)
    x, wi, wo, wr = _setup(seed, nt, d, dd, g)
    # capacity_factor g/ga disables drops -> exact equality with dense ref
    y, s = routed_ffn.routed_ffn(x, wi, wo, wr, ga, capacity_factor=g / ga)
    y_ref, s_ref = ref.routed_ffn(x, wi, wo, wr, ga)
    assert jnp.allclose(s, s_ref, atol=1e-5)
    assert jnp.allclose(y, y_ref, atol=1e-4), float(jnp.max(jnp.abs(y - y_ref)))


def test_grads_match_ref():
    x, wi, wo, wr = _setup(21, 64, 32, 256, 4)
    ga = 2

    def loss_kernel(x, wi, wo, wr):
        y, s = routed_ffn.routed_ffn(x, wi, wo, wr, ga, capacity_factor=2.0)
        return jnp.sum(y**2) + 0.1 * routed_ffn.load_balance_loss(s, ga)

    def loss_ref(x, wi, wo, wr):
        y, s = ref.routed_ffn(x, wi, wo, wr, ga)
        return jnp.sum(y**2) + 0.1 * ref.load_balance_loss(s, ga)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2, 3))(x, wi, wo, wr)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, wi, wo, wr)
    for a, b, nm in zip(g1, g2, ["x", "wi", "wo", "wr"]):
        assert jnp.allclose(a, b, atol=5e-3), (nm, float(jnp.max(jnp.abs(a - b))))


def test_g_active_equals_g_recovers_scaled_dense_ffn():
    """With every block active and uniform gate, output == dense FFN."""
    x, wi, wo, wr = _setup(22, 32, 16, 64, 4)
    y, _ = routed_ffn.routed_ffn(x, wi, wo, wr * 0.0, 4, capacity_factor=1.0)
    want = ref.dense_ffn(x, wi, wo)  # gates = softmax(0)*G = 1 each
    assert jnp.allclose(y, want, atol=1e-4)


def test_capacity_drops_zero_contribution():
    """Tokens over capacity contribute nothing from that block (no NaNs)."""
    x, wi, wo, wr = _setup(23, 64, 16, 64, 4)
    y_full, _ = routed_ffn.routed_ffn(x, wi, wo, wr, 2, capacity_factor=2.0)
    y_tight, _ = routed_ffn.routed_ffn(x, wi, wo, wr, 2, capacity_factor=0.5)
    assert bool(jnp.all(jnp.isfinite(y_tight)))
    assert not jnp.allclose(y_full, y_tight)  # drops actually happened


def test_load_balance_loss_uniform_is_minimal():
    """Uniform routing scores the theoretical minimum (== 1.0)."""
    nt, g, ga = 512, 4, 2
    key = jax.random.PRNGKey(3)
    uniform = jax.random.normal(key, (nt, g)) * 1e-4
    skew = jnp.concatenate(
        [10 + jax.random.normal(key, (nt, 1)), jax.random.normal(key, (nt, g - 1))],
        axis=1,
    )
    lb_u = float(routed_ffn.load_balance_loss(uniform, ga))
    lb_s = float(routed_ffn.load_balance_loss(skew, ga))
    assert lb_u < lb_s
    assert lb_u == pytest.approx(1.0, rel=0.05)


def test_flop_reduction_is_beta():
    """The BSpMV formulation computes beta = G'/G of the dense FFN FLOPs
    (capacity slots, incl. padding) — the source of Table 4's speedup."""
    nt, d, dd, g, ga = 128, 32, 256, 8, 2
    cap = int(np.ceil(nt * ga / g))
    blocked_flops = g * (2 * cap * d * (dd // g) * 2)
    dense_flops = 2 * nt * d * dd * 2
    assert blocked_flops / dense_flops == pytest.approx(ga / g)
