//! L3 coordinator: the fine-tuning system around the AOT artifacts.
//!
//! * [`state`]   — leaf-indexed training state (params / AdamW moments)
//!   mapped onto artifact signatures.
//! * [`trainer`] — the training loop: batching, train-step dispatch,
//!   codebook refresh scheduling (paper §5.1), eval, loss curves.
//! * [`trial`]   — sparsity trial manager (paper §3: "short training
//!   trials on some sample data" to pick L and beta).
//! * [`profile`] — module/block profiler joining measured step time with
//!   the analytic memory model (Tables 1/4, Fig. 8).
//! * [`checkpoint`] — binary save/restore of training state.

//! All submodules execute AOT artifacts through the PJRT engine, so the
//! whole coordinator is gated on the `xla` feature; the engine-free
//! analytics live in `memmodel` and `sparse`.

#[cfg(feature = "xla")]
pub mod checkpoint;
#[cfg(feature = "xla")]
pub mod profile;
#[cfg(feature = "xla")]
pub mod state;
#[cfg(feature = "xla")]
pub mod trainer;
#[cfg(feature = "xla")]
pub mod trial;

#[cfg(feature = "xla")]
pub use state::TrainState;
#[cfg(feature = "xla")]
pub use trainer::{TrainReport, Trainer, TrainerOptions};
