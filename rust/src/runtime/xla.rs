//! Compile-time stub for the `xla_extension` PJRT bindings crate.
//!
//! The offline registry for this build does not ship the real `xla`
//! bindings crate (its dependency line in `Cargo.toml` is commented
//! out), yet the PJRT engine must keep *compiling* under
//! `--features xla` so the backend seam stays honest.  This module
//! mirrors exactly the slice of the bindings API that
//! [`super::engine`] / [`super::tensor`] consume; every entry point
//! fails at **runtime** with a clear error, so `spt train --backend
//! pjrt` degrades into an actionable message instead of a build break.
//!
//! Swapping in the real crate is mechanical: uncomment the `xla`
//! dependency in `Cargo.toml`, delete this module, and drop the
//! `use super::xla;` lines in `engine.rs` / `tensor.rs` so the paths
//! resolve to the external crate again.

// The stub mirrors the full API surface the engine consumes; variants
// and helpers the error paths never construct are expected.
#![allow(dead_code)]

use std::path::Path;

use anyhow::{bail, Result};

fn unavailable<T>(what: &str) -> Result<T> {
    bail!(
        "{what}: the PJRT bindings crate is stubbed out in this build \
         (uncomment the `xla` dependency in rust/Cargo.toml and remove \
         rust/src/runtime/xla.rs to link the real runtime)"
    )
}

/// Stubbed PJRT client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}

/// Stubbed compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Stubbed device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stubbed HLO module proto (text-parsed).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stubbed XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stubbed element type of an array literal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
    U32,
}

/// Stubbed primitive type (conversion targets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    S32,
}

/// Stubbed literal shape.
pub enum Shape {
    Tuple(Vec<Shape>),
    Array(ArrayShape),
}

/// Stubbed array shape (dims + element type).
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Stubbed host literal.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn shape(&self) -> Result<Shape> {
        unavailable("Literal::shape")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        unavailable("Literal::convert")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}
