//! `cargo xtask benchdiff <baseline.json> <current.json>` — the perf
//! regression gate.
//!
//! Both files are BENCH JSON artifacts (`bench_out/BENCH_*.json`); the
//! baseline copies live under `bench_out/baselines/` in the repo.  The
//! differ dispatches on the top-level `"bench"` field to extract the
//! comparable metrics of each artifact shape:
//!
//! * `decode_native` — `tokens_per_sec` of the `batched` / `baseline` /
//!   `overload` sections (higher is better).
//! * `table3_native_step` — `ms_per_step` per `entries[]` element,
//!   keyed `{mode},t{threads}` (lower is better).
//! * `kernel_bench` — `ms_median` per `kernels[]` element, keyed
//!   `{kernel}[{m}x{k}x{n}]` (lower is better).
//! * `obs_native` — the obs-report rollup: `steps_per_sec` (higher is
//!   better) plus `attn_density_mean`, `expert_imbalance`, and
//!   `mem_model_err` (lower is better — sparsity decaying, routing
//!   collapsing, or the memory model drifting are all regressions).
//!
//! A metric that moved more than [`THRESHOLD`] in the bad direction is
//! a regression and the task exits non-zero — unless the two files'
//! `provenance` stamps disagree on CPU model or rayon thread count (or
//! either is `"unknown"`), in which case the numbers are not
//! host-comparable and every regression is downgraded to a warning.
//! Metrics present in the baseline but missing from the current run
//! always fail: a silently vanished benchmark is not a pass.

use std::process::ExitCode;

use spt::util::json::{self, Json};

/// Relative change beyond which a metric counts as regressed (25%).
pub const THRESHOLD: f64 = 0.25;

/// One comparable metric extracted from a BENCH JSON.
#[derive(Debug, PartialEq)]
struct Metric {
    key: String,
    value: f64,
    higher_is_better: bool,
}

/// A baseline/current metric pair with its verdict.
#[derive(Debug)]
pub struct Delta {
    pub key: String,
    pub baseline: f64,
    pub current: f64,
    /// Signed relative change, positive = worse (normalized so the
    /// threshold applies uniformly to both metric directions).
    pub worse_by: f64,
    pub regressed: bool,
}

/// The full comparison of two BENCH JSON artifacts.
#[derive(Debug)]
pub struct Diff {
    pub bench: String,
    pub deltas: Vec<Delta>,
    /// Baseline metrics absent from the current run (always a failure).
    pub missing: Vec<String>,
    /// Why the hosts are not comparable (downgrades regressions to
    /// warnings), if they are not.
    pub host_mismatch: Option<String>,
}

impl Diff {
    pub fn regressions(&self) -> Vec<&Delta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }

    /// Whether this diff should fail the build.
    pub fn failed(&self) -> bool {
        !self.missing.is_empty()
            || (self.host_mismatch.is_none() && !self.regressions().is_empty())
    }
}

fn num(v: &Json, key: &str) -> Result<f64, String> {
    v.get(key)
        .as_f64()
        .ok_or_else(|| format!("missing numeric field '{key}'"))
}

fn extract(v: &Json) -> Result<Vec<Metric>, String> {
    let bench = v
        .get("bench")
        .as_str()
        .ok_or("missing top-level 'bench' field")?;
    let mut metrics = Vec::new();
    match bench {
        "decode_native" => {
            for section in ["batched", "baseline", "overload"] {
                let s = v.get(section);
                if matches!(s, Json::Null) {
                    continue;
                }
                metrics.push(Metric {
                    key: format!("{section}.tokens_per_sec"),
                    value: num(s, "tokens_per_sec")?,
                    higher_is_better: true,
                });
            }
        }
        "table3_native_step" => {
            let entries = v
                .get("entries")
                .as_arr()
                .ok_or("table3_native_step: missing 'entries' array")?;
            for e in entries {
                let mode = e.get("mode").as_str().unwrap_or("?");
                let threads = e.get("threads").as_usize().unwrap_or(0);
                metrics.push(Metric {
                    key: format!("{mode},t{threads}.ms_per_step"),
                    value: num(e, "ms_per_step")?,
                    higher_is_better: false,
                });
            }
        }
        "kernel_bench" => {
            let kernels = v
                .get("kernels")
                .as_arr()
                .ok_or("kernel_bench: missing 'kernels' array")?;
            for k in kernels {
                let name = k.get("kernel").as_str().unwrap_or("?");
                let (m, kk, n) = (
                    k.get("m").as_usize().unwrap_or(0),
                    k.get("k").as_usize().unwrap_or(0),
                    k.get("n").as_usize().unwrap_or(0),
                );
                metrics.push(Metric {
                    key: format!("{name}[{m}x{kk}x{n}].ms_median"),
                    value: num(k, "ms_median")?,
                    higher_is_better: false,
                });
            }
        }
        "obs_native" => {
            metrics.push(Metric {
                key: "steps_per_sec".into(),
                value: num(v, "steps_per_sec")?,
                higher_is_better: true,
            });
            for key in ["attn_density_mean", "expert_imbalance", "mem_model_err"] {
                metrics.push(Metric {
                    key: key.into(),
                    value: num(v, key)?,
                    higher_is_better: false,
                });
            }
        }
        other => return Err(format!("unknown bench kind '{other}'")),
    }
    if metrics.is_empty() {
        return Err(format!("bench '{bench}': no metrics extracted"));
    }
    Ok(metrics)
}

/// Compare the provenance stamps; `Some(reason)` when the numbers are
/// not host-comparable.  Git SHAs are *expected* to differ and are not
/// compared.
fn host_mismatch(baseline: &Json, current: &Json) -> Option<String> {
    let (bp, cp) = (baseline.get("provenance"), current.get("provenance"));
    if matches!(bp, Json::Null) || matches!(cp, Json::Null) {
        return Some("one side has no provenance stamp".into());
    }
    let (bc, cc) = (
        bp.get("cpu_model").as_str().unwrap_or("unknown"),
        cp.get("cpu_model").as_str().unwrap_or("unknown"),
    );
    if bc == "unknown" || cc == "unknown" {
        return Some("cpu_model unknown on at least one side".into());
    }
    if bc != cc {
        return Some(format!("cpu_model differs: '{bc}' vs '{cc}'"));
    }
    let (bt, ct) = (
        bp.get("rayon_threads").as_usize(),
        cp.get("rayon_threads").as_usize(),
    );
    if bt != ct {
        return Some(format!("rayon_threads differs: {bt:?} vs {ct:?}"));
    }
    None
}

/// Pure comparison of two parsed BENCH JSON values.
pub fn diff(baseline: &Json, current: &Json) -> Result<Diff, String> {
    let base_metrics = extract(baseline).map_err(|e| format!("baseline: {e}"))?;
    let cur_metrics = extract(current).map_err(|e| format!("current: {e}"))?;
    let bench = baseline.get("bench").as_str().unwrap_or("?").to_string();
    if current.get("bench").as_str() != Some(bench.as_str()) {
        return Err(format!(
            "bench kind mismatch: baseline '{bench}' vs current '{}'",
            current.get("bench").as_str().unwrap_or("?")
        ));
    }
    let mut deltas = Vec::new();
    let mut missing = Vec::new();
    for b in &base_metrics {
        let Some(c) = cur_metrics.iter().find(|c| c.key == b.key) else {
            missing.push(b.key.clone());
            continue;
        };
        // Normalize: worse_by > 0 means the metric moved the bad way.
        let worse_by = if b.value.abs() < 1e-12 {
            0.0
        } else if b.higher_is_better {
            (b.value - c.value) / b.value
        } else {
            (c.value - b.value) / b.value
        };
        deltas.push(Delta {
            key: b.key.clone(),
            baseline: b.value,
            current: c.value,
            worse_by,
            regressed: worse_by > THRESHOLD,
        });
    }
    Ok(Diff {
        bench,
        deltas,
        missing,
        host_mismatch: host_mismatch(baseline, current),
    })
}

fn load(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

pub fn run(args: &[String]) -> ExitCode {
    let [baseline_path, current_path] = args else {
        eprintln!("usage: cargo xtask benchdiff <baseline.json> <current.json>");
        return ExitCode::FAILURE;
    };
    let result = (|| -> Result<Diff, String> {
        diff(&load(baseline_path)?, &load(current_path)?)
    })();
    let d = match result {
        Ok(d) => d,
        Err(e) => {
            eprintln!("[benchdiff] error: {e}");
            return ExitCode::FAILURE;
        }
    };
    println!(
        "[benchdiff] {}: {} metrics vs {}",
        d.bench,
        d.deltas.len(),
        baseline_path
    );
    for delta in &d.deltas {
        let tag = if delta.regressed { "REGRESSED" } else { "ok" };
        println!(
            "  {:9} {}  baseline {:.3}  current {:.3}  ({:+.1}% worse)",
            tag,
            delta.key,
            delta.baseline,
            delta.current,
            delta.worse_by * 100.0
        );
    }
    for key in &d.missing {
        eprintln!("  MISSING   {key} (present in baseline, absent in current)");
    }
    if let Some(reason) = &d.host_mismatch {
        eprintln!(
            "[benchdiff] warning: hosts not comparable ({reason}); regressions \
             downgraded to warnings"
        );
    }
    if d.failed() {
        eprintln!(
            "[benchdiff] FAIL: {} regression(s) beyond {:.0}%, {} missing metric(s)",
            d.regressions().len(),
            THRESHOLD * 100.0,
            d.missing.len()
        );
        ExitCode::FAILURE
    } else {
        println!("[benchdiff] ok");
        ExitCode::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decode_json(tps: f64, cpu: &str) -> Json {
        json::parse(&format!(
            r#"{{"bench":"decode_native",
                 "batched":{{"tokens_per_sec":{tps}}},
                 "baseline":{{"tokens_per_sec":100.0}},
                 "overload":{{"tokens_per_sec":90.0}},
                 "provenance":{{"git_sha":"abc","rayon_threads":8,"cpu_model":"{cpu}"}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn seeded_regression_beyond_threshold_fails() {
        // Throughput drops 30% on the same host: must fail.
        let base = decode_json(1000.0, "TestCPU");
        let cur = decode_json(700.0, "TestCPU");
        let d = diff(&base, &cur).unwrap();
        assert_eq!(d.host_mismatch, None);
        assert_eq!(d.regressions().len(), 1);
        assert_eq!(d.regressions()[0].key, "batched.tokens_per_sec");
        assert!(d.failed());
        // A 20% drop stays under the 25% threshold.
        let d = diff(&base, &decode_json(800.0, "TestCPU")).unwrap();
        assert!(!d.failed(), "20% drop is within threshold");
        // Improvements never fail.
        let d = diff(&base, &decode_json(2000.0, "TestCPU")).unwrap();
        assert!(!d.failed());
    }

    #[test]
    fn host_mismatch_downgrades_regressions_to_warnings() {
        let base = decode_json(1000.0, "CPU-A");
        let d = diff(&base, &decode_json(700.0, "CPU-B")).unwrap();
        assert!(d.host_mismatch.is_some());
        assert_eq!(d.regressions().len(), 1, "regression still reported");
        assert!(!d.failed(), "but does not fail across hosts");
        // "unknown" on either side is also not comparable.
        let d = diff(&decode_json(1000.0, "unknown"), &decode_json(700.0, "CPU-B")).unwrap();
        assert!(d.host_mismatch.is_some());
        assert!(!d.failed());
    }

    #[test]
    fn lower_is_better_metrics_regress_upward() {
        let base = json::parse(
            r#"{"bench":"table3_native_step",
                "entries":[{"mode":"spt","threads":4,"ms_per_step":10.0},
                           {"mode":"full","threads":4,"ms_per_step":20.0}],
                "provenance":{"git_sha":"a","rayon_threads":8,"cpu_model":"X"}}"#,
        )
        .unwrap();
        let cur = json::parse(
            r#"{"bench":"table3_native_step",
                "entries":[{"mode":"spt","threads":4,"ms_per_step":13.0},
                           {"mode":"full","threads":4,"ms_per_step":19.0}],
                "provenance":{"git_sha":"b","rayon_threads":8,"cpu_model":"X"}}"#,
        )
        .unwrap();
        let d = diff(&base, &cur).unwrap();
        // 10 -> 13 ms is +30% worse; 20 -> 19 is an improvement.
        assert_eq!(d.regressions().len(), 1);
        assert_eq!(d.regressions()[0].key, "spt,t4.ms_per_step");
        assert!(d.failed());
    }

    #[test]
    fn kernel_metrics_key_on_shape_and_missing_entries_fail() {
        let base = json::parse(
            r#"{"bench":"kernel_bench",
                "kernels":[{"kernel":"gemm","m":64,"k":64,"n":64,"ms_median":1.0},
                           {"kernel":"bspmv","m":64,"k":64,"n":256,"ms_median":2.0}],
                "provenance":{"git_sha":"a","rayon_threads":1,"cpu_model":"X"}}"#,
        )
        .unwrap();
        let cur = json::parse(
            r#"{"bench":"kernel_bench",
                "kernels":[{"kernel":"gemm","m":64,"k":64,"n":64,"ms_median":1.1}],
                "provenance":{"git_sha":"b","rayon_threads":1,"cpu_model":"X"}}"#,
        )
        .unwrap();
        let d = diff(&base, &cur).unwrap();
        assert_eq!(d.missing, vec!["bspmv[64x64x256].ms_median".to_string()]);
        assert!(d.failed(), "a vanished kernel metric always fails");
        assert!(d.regressions().is_empty(), "1.0 -> 1.1 ms is within threshold");
    }

    fn obs_json(sps: f64, density: f64, imb: f64, err: f64) -> Json {
        json::parse(&format!(
            r#"{{"bench":"obs_native",
                 "steps_per_sec":{sps},
                 "attn_density_mean":{density},
                 "expert_imbalance":{imb},
                 "mem_model_err":{err},
                 "provenance":{{"git_sha":"abc","rayon_threads":8,"cpu_model":"X"}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn obs_native_gates_throughput_and_telemetry_drift() {
        let base = obs_json(2.0, 0.125, 1.5, 0.1);
        // Unchanged telemetry: clean.
        let d = diff(&base, &obs_json(2.0, 0.125, 1.5, 0.1)).unwrap();
        assert!(!d.failed());
        assert_eq!(d.deltas.len(), 4);
        // Throughput halved: regression on the higher-is-better metric.
        let d = diff(&base, &obs_json(1.0, 0.125, 1.5, 0.1)).unwrap();
        assert_eq!(d.regressions().len(), 1);
        assert_eq!(d.regressions()[0].key, "steps_per_sec");
        assert!(d.failed());
        // Attention density doubling (sparsity decaying) regresses too.
        let d = diff(&base, &obs_json(2.0, 0.25, 1.5, 0.1)).unwrap();
        assert_eq!(d.regressions().len(), 1);
        assert_eq!(d.regressions()[0].key, "attn_density_mean");
        // Memory-model error growing 3x is a regression.
        let d = diff(&base, &obs_json(2.0, 0.125, 1.5, 0.3)).unwrap();
        assert_eq!(d.regressions().len(), 1);
        assert_eq!(d.regressions()[0].key, "mem_model_err");
        // Denser-than-baseline improvements never fail.
        let d = diff(&base, &obs_json(3.0, 0.06, 1.1, 0.01)).unwrap();
        assert!(!d.failed());
    }

    #[test]
    fn mismatched_or_unknown_bench_kinds_error() {
        let a = json::parse(r#"{"bench":"decode_native","batched":{"tokens_per_sec":1}}"#)
            .unwrap();
        let b = json::parse(
            r#"{"bench":"kernel_bench","kernels":[{"kernel":"g","m":1,"k":1,"n":1,"ms_median":1}]}"#,
        )
        .unwrap();
        assert!(diff(&a, &b).is_err());
        let odd = json::parse(r#"{"bench":"nope"}"#).unwrap();
        assert!(diff(&odd, &odd).is_err());
    }
}
