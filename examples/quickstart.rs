//! Quickstart: load the AOT artifacts, validate the goldens, and run a
//! few SPT fine-tuning steps on the tiny model.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end slice of the system: PJRT engine ->
//! manifest -> golden validation -> coordinator train loop.

use anyhow::Result;
use spt::config::{Mode, RunConfig};
use spt::coordinator::{Trainer, TrainerOptions};
use spt::runtime::{goldens, Engine};

fn main() -> Result<()> {
    let dir = std::env::var("SPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = Engine::new(&dir)?;
    println!("platform: {} | artifacts: {}", engine.platform(), engine.manifest().artifacts.len());

    // 1. Validate the python -> rust numeric round trip.
    for g in goldens::load_goldens(&dir)? {
        let diff = goldens::check_artifact(&engine, &g, 1e-3)?;
        println!("  golden {:<26} max|diff| = {diff:.2e}", g.name);
    }

    // 2. Fine-tune the tiny model with SPT sparsification for 16 steps.
    let mut rc = RunConfig::default();
    rc.model = "spt-tiny".into();
    rc.mode = Mode::Spt;
    rc.steps = 16;
    rc.eval_every = 8;
    rc.codebook_refresh_every = 10;
    rc.artifacts_dir = dir;
    let mut trainer = Trainer::new(&engine, rc, TrainerOptions::default());
    let report = trainer.train()?;
    println!(
        "\ntrained {} steps: loss {:.3} -> {:.3} ({:.0} tokens/s, {} codebook refreshes)",
        report.steps,
        report.losses.first().unwrap(),
        report.losses.last().unwrap(),
        report.tokens_per_sec,
        report.refreshes,
    );
    for e in &report.evals {
        println!("  step {:>3}: eval loss {:.3} (ppl {:.1})", e.step, e.eval_loss, e.ppl);
    }
    Ok(())
}
