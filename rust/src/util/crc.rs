//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the checksum
//! stamped per tensor payload in v3 checkpoints so bit-flips on disk are
//! caught at load time instead of materializing as silently-wrong
//! weights.  Table-driven, std-only (the offline registry ships no
//! checksum crates), and byte-order independent: the digest is over the
//! little-endian payload bytes exactly as written.

/// 256-entry lookup table, built at compile time.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// Incremental CRC-32 state.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            let ix = ((crc ^ b as u32) & 0xFF) as usize;
            crc = TABLE[ix] ^ (crc >> 8);
        }
        self.state = crc;
    }

    /// Finish the digest (the state stays usable for further updates).
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot digest.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard CRC-32/IEEE check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data = b"split across several updates";
        let mut c = Crc32::new();
        c.update(&data[..7]);
        c.update(&data[7..20]);
        c.update(&data[20..]);
        assert_eq!(c.finish(), crc32(data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0xA5u8; 64];
        let clean = crc32(&data);
        data[31] ^= 0x10;
        assert_ne!(crc32(&data), clean);
    }
}
