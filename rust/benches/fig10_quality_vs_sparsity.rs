//! Paper Fig. 10: model quality (PPL) vs sparsity strength.
//!
//! Two axes, as in the paper:
//! (a) sparse-MHA non-zero portion (1, 1/2, 1/4, 1/8, 1/16): measured as
//!     the relative output error of sparse vs dense attention on the
//!     substrate (the quantity that drives PPL degradation), plus a
//!     short LM fine-tuning trial per available tuning mode via the
//!     coordinator for the end-to-end PPL readings.
//! (b) routed-FFN active portion (1, 3/4, 1/2, 1/4): FLOP fraction and
//!     capacity-drop rate (balanced routing -> negligible drops at 1/2,
//!     the paper's "stabilizes at 1/2" point).

mod common;

#[cfg(feature = "xla")]
use spt::config::RunConfig;
#[cfg(feature = "xla")]
use spt::coordinator::trial::TrialManager;
#[cfg(feature = "xla")]
use spt::coordinator::PjrtBackend;
use spt::metrics::Table;
use spt::sparse::attention::sparse_vs_dense_error;
use spt::sparse::{bspmv, pq, Matrix};
use spt::util::rng::Rng;

fn main() {
    // ---- (a) MHA sparsity -> attention approximation error ----
    let (n, d, m, e) = (256usize, 64usize, 8usize, 16usize);
    let mut rng = Rng::new(5);
    let k = Matrix::randn(n, d, 1.0, &mut rng);
    let noise = Matrix::randn(n, d, 0.5, &mut rng);
    let q = Matrix::from_vec(
        n,
        d,
        k.data.iter().zip(&noise.data).map(|(a, b)| 2.0 * a + b).collect(),
    );
    let v = Matrix::randn(n, d, 1.0, &mut rng);
    let mut cb = pq::Codebooks::random(m, e, d / m, &mut rng);
    for _ in 0..5 {
        pq::codebook_update(&k.data, &mut cb, 1.0);
    }
    let mut ta = Table::new(
        "Fig. 10a — sparse MHA: non-zero portion vs attention output error",
        &["non-zero portion", "L (of 256)", "relative output error"],
    );
    for (label, den) in [("1", 1usize), ("1/2", 2), ("1/4", 4), ("1/8", 8), ("1/16", 16)] {
        let l = (n / den).max(1);
        let err = sparse_vs_dense_error(&q, &k, &v, &cb, l);
        ta.row(&[label.to_string(), l.to_string(), format!("{err:.4}")]);
    }
    common::emit("fig10a_mha_error", &ta);

    // ---- (b) FFN sparsity -> FLOPs + drop rate under balanced routing ----
    let (nt, g) = (4096usize, 8usize);
    let scores = Matrix::randn(nt, g, 1.0, &mut rng);
    let mut tb = Table::new(
        "Fig. 10b — routed FFN: active portion vs FLOP fraction & capacity drops",
        &["active portion", "G' (of 8)", "FLOP fraction", "drop rate @cap=1.25x"],
    );
    for (label, ga) in [("1", 8usize), ("3/4", 6), ("1/2", 4), ("1/4", 2)] {
        let routing = bspmv::route(&scores, ga);
        let flops = bspmv::routed_flops(nt, 512, 2048, g, ga) as f64
            / bspmv::dense_flops(nt, 512, 2048) as f64;
        // capacity per block = nt*ga/g * 1.25; count over-capacity tokens.
        let cap = (nt * ga / g) as f64 * 1.25;
        let mut dropped = 0usize;
        for gi in 0..g {
            let load = (0..nt).filter(|&t| routing.mask[t][gi]).count();
            dropped += load.saturating_sub(cap as usize);
        }
        let drop_rate = dropped as f64 / (nt * ga) as f64;
        tb.row(&[
            label.to_string(),
            ga.to_string(),
            format!("{flops:.3}"),
            format!("{:.2}%", 100.0 * drop_rate),
        ]);
    }
    common::emit("fig10b_ffn_flops", &tb);

    // ---- end-to-end PPL trials through the coordinator ----
    #[cfg(feature = "xla")]
    e2e_trials();
}

#[cfg(feature = "xla")]
fn e2e_trials() {
    if let Some(engine) = common::engine_or_skip("fig10-e2e") {
        let mut rc = RunConfig::default();
        rc.model = std::env::var("SPT_FIG10_MODEL").unwrap_or_else(|_| "spt-tiny".into());
        rc.artifacts_dir = common::artifacts_dir();
        let steps = std::env::var("SPT_FIG10_STEPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(12);
        let backend = PjrtBackend::new(&engine);
        let tm = TrialManager::new(&backend, rc, steps);
        match tm.compare_modes() {
            Ok((_, table)) => common::emit("fig10_e2e_trials", &table),
            Err(e) => println!("[fig10] e2e trials skipped: {e:#}"),
        }
    }
}
