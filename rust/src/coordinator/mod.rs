//! L3 coordinator: the backend-agnostic fine-tuning system.
//!
//! * [`backend`] — the [`Backend`] trait (init, train step, eval, QA
//!   readout, codebook refresh) plus the `xla`-gated PJRT
//!   implementation.  The trainer, trial manager, and checkpoints are
//!   generic over it.
//! * [`native`]  — [`NativeBackend`]: end-to-end training on the rust
//!   sparse substrate (forward + backward + host-side AdamW), always
//!   available — no PJRT toolchain or AOT artifacts needed.
//! * [`state`]   — leaf-indexed training state (params / AdamW moments)
//!   shared by both backends, plus the AdamW update itself.
//! * [`trainer`] — the training loop: batching, train-step dispatch,
//!   codebook refresh scheduling (paper §5.1), eval, loss curves,
//!   bit-identical checkpoint resume.
//! * [`trial`]   — sparsity trial manager (paper §3: "short training
//!   trials on some sample data" to pick L and beta).
//! * [`profile`] — module/block profiler joining measured step time with
//!   the analytic memory model (Tables 1/4, Fig. 8); artifact-driven, so
//!   still behind the `xla` feature.
//! * [`checkpoint`] — binary save/restore of training state (works with
//!   any backend's state).

pub mod backend;
pub mod checkpoint;
pub mod native;
#[cfg(feature = "xla")]
pub mod profile;
pub mod state;
pub mod trainer;
pub mod trial;

pub use backend::Backend;
#[cfg(feature = "xla")]
pub use backend::PjrtBackend;
pub use native::NativeBackend;
pub use state::{AdamW, TrainState};
pub use trainer::{TrainReport, Trainer, TrainerOptions};
