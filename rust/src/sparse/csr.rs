//! CSR sparse matrix with fixed L nonzeros per row (paper §5.1, Fig. 7).
//!
//! The sparse attention matrix produced by top-L selection always has
//! exactly L entries per row, so `indptr` is the implicit
//! `[0, L, 2L, ...]` the paper points out; we still store it to keep the
//! structure general (tests cover ragged rows as well).

use anyhow::{bail, Result};

use super::codes::TopL;
use super::kernel;
use super::matrix::Matrix;

/// Compressed sparse row matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from the top-L selection output: exactly L entries per query
    /// (paper: "constructed directly from the output of the previous
    /// top-L selection step"), so `indptr` is the implicit
    /// `[0, L, 2L, ...]` and the index buffer is reused as-is.
    pub fn from_topl(sel: &TopL, cols: usize) -> Self {
        let rows = sel.n;
        let l = sel.l;
        let indptr = (0..=rows)
            .map(|r| u32::try_from(r * l).expect("nnz fits u32"))
            .collect();
        let csr = Csr {
            rows,
            cols,
            indptr,
            indices: sel.data.clone(),
            values: vec![0.0; rows * l],
        };
        csr.debug_validate();
        csr
    }

    /// Build from per-row index lists (general, possibly ragged — the
    /// tests exercise ragged rows through this constructor).
    pub fn from_rows(indices: &[Vec<u32>], cols: usize) -> Self {
        let rows = indices.len();
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut flat = Vec::new();
        indptr.push(0u32);
        for row in indices {
            flat.extend_from_slice(row);
            indptr.push(u32::try_from(flat.len()).expect("nnz fits u32"));
        }
        let nnz = flat.len();
        let csr = Csr { rows, cols, indptr, indices: flat, values: vec![0.0; nnz] };
        csr.debug_validate();
        csr
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Validity check: monotone indptr, in-range column ids.
    pub fn validate(&self) -> Result<()> {
        if self.indptr.len() != self.rows + 1 {
            bail!("indptr length {} != rows+1", self.indptr.len());
        }
        if *self.indptr.last().unwrap_or(&0) as usize != self.nnz() {
            bail!("indptr end != nnz");
        }
        for w in self.indptr.windows(2) {
            if w[1] < w[0] {
                bail!("indptr not monotone");
            }
        }
        if self.values.len() != self.nnz() {
            bail!("values length mismatch");
        }
        if let Some(&bad) = self.indices.iter().find(|&&c| c as usize >= self.cols) {
            bail!("column index {bad} out of range {}", self.cols);
        }
        Ok(())
    }

    pub fn row_range(&self, r: usize) -> std::ops::Range<usize> {
        self.indptr[r] as usize..self.indptr[r + 1] as usize
    }

    /// Debug-build contract check: [`Self::validate`] plus per-row
    /// uniqueness of column ids — the invariants every CSR kernel
    /// assumes.  Called at construction and at kernel entry; compiles
    /// to nothing in release builds.  Rows are ordered by selection
    /// rank (score-descending, then index), not by column id, so column
    /// sortedness is deliberately not part of the contract.
    #[inline]
    pub fn debug_validate(&self) {
        if cfg!(debug_assertions) {
            self.validate().expect("Csr contract");
            for r in 0..self.rows {
                let row = &self.indices[self.row_range(r)];
                for (p, &c) in row.iter().enumerate() {
                    debug_assert!(!row[..p].contains(&c), "Csr row {r}: duplicate column {c}");
                }
            }
        }
    }

    /// SDDMM: `values[i,l] = q_i . k_{indices[i,l]}` (paper §5.1).
    pub fn sddmm(&mut self, q: &Matrix, k: &Matrix) {
        self.debug_validate();
        assert_eq!(q.rows, self.rows);
        assert_eq!(k.rows, self.cols);
        assert_eq!(q.cols, k.cols);
        for r in 0..self.rows {
            let qrow = q.row(r);
            for p in self.row_range(r) {
                let krow = k.row(self.indices[p] as usize);
                self.values[p] = kernel::dot(qrow, krow);
            }
        }
    }

    /// Row-wise softmax over the stored entries (the paper's revised
    /// softmax: kept weights renormalize to 1).
    pub fn softmax_rows(&mut self) {
        for r in 0..self.rows {
            let range = self.row_range(r);
            if range.is_empty() {
                continue;
            }
            let vals = &mut self.values[range];
            let mx = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in vals.iter_mut() {
                *v = (*v - mx).exp();
                sum += *v;
            }
            for v in vals.iter_mut() {
                *v /= sum.max(1e-30);
            }
        }
    }

    /// SpMM: `Y = self @ V` (paper §5.1).
    pub fn spmm(&self, v: &Matrix) -> Matrix {
        self.debug_validate();
        assert_eq!(v.rows, self.cols);
        let mut out = Matrix::zeros(self.rows, v.cols);
        for r in 0..self.rows {
            for p in self.row_range(r) {
                let w = self.values[p];
                // Genuinely sparse operand: a zero weight skips a whole
                // V row (unlike the dense GEMM, which dropped its skip).
                if w == 0.0 {
                    continue;
                }
                let vrow = v.row(self.indices[p] as usize);
                kernel::axpy(out.row_mut(r), w, vrow);
            }
        }
        out
    }

    /// Densify (tests / small reports only).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for p in self.row_range(r) {
                *out.at_mut(r, self.indices[p] as usize) += self.values[p];
            }
        }
        out
    }

    /// Bytes to store this matrix (the memory-model input; paper's O(nL)).
    pub fn bytes(&self) -> usize {
        self.indptr.len() * 4 + self.indices.len() * 4 + self.values.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};
    use crate::util::rng::Rng;

    fn random_topl(rng: &mut Rng, n: usize, l: usize) -> Vec<Vec<u32>> {
        (0..n)
            .map(|_| {
                let mut ids: Vec<u32> = (0..n as u32).collect();
                rng.shuffle(&mut ids);
                ids.truncate(l);
                ids
            })
            .collect()
    }

    #[test]
    fn from_topl_builds_regular_indptr() {
        let idx = TopL::from_rows(&[vec![1, 2], vec![0, 3], vec![2, 1]]);
        let m = Csr::from_topl(&idx, 4);
        m.validate().unwrap();
        assert_eq!(m.indptr, vec![0, 2, 4, 6]); // [0, L, 2L, ...] (Fig. 7)
        assert_eq!(m.nnz(), 6);
        // Agrees with the general ragged constructor.
        let m2 = Csr::from_rows(&idx.to_rows(), 4);
        assert_eq!(m, m2);
    }

    #[test]
    fn from_rows_accepts_ragged_rows() {
        let idx = vec![vec![0u32, 2], vec![1], vec![]];
        let m = Csr::from_rows(&idx, 3);
        m.validate().unwrap();
        assert_eq!(m.indptr, vec![0, 2, 3, 3]);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn sddmm_softmax_spmm_matches_dense_pipeline() {
        check(30, |g| {
            let n = g.usize_in(2, 24);
            let d = g.usize_in(1, 16);
            let l = g.usize_in(1, n);
            let mut rng = g.rng().fork();
            let q = Matrix::randn(n, d, 1.0, &mut rng);
            let k = Matrix::randn(n, d, 1.0, &mut rng);
            let v = Matrix::randn(n, d, 1.0, &mut rng);
            let idx = random_topl(&mut rng, n, l);
            let mut a = Csr::from_rows(&idx, n);
            a.sddmm(&q, &k);
            a.softmax_rows();
            let y = a.spmm(&v);

            // Dense reference: mask logits to the selected set.
            let mut logits = q.matmul(&k.transpose());
            let mut mask = vec![vec![false; n]; n];
            for (i, row) in idx.iter().enumerate() {
                for &j in row {
                    mask[i][j as usize] = true;
                }
            }
            for i in 0..n {
                for j in 0..n {
                    if !mask[i][j] {
                        *logits.at_mut(i, j) = -1e30;
                    }
                }
            }
            let y_ref = logits.softmax_rows().matmul(&v);
            prop_assert(
                y.max_abs_diff(&y_ref) < 1e-4,
                format!("diff {}", y.max_abs_diff(&y_ref)),
            )
        });
    }

    #[test]
    fn spmm_identity_weights_gathers_rows() {
        let idx = vec![vec![2u32], vec![0], vec![1]];
        let mut a = Csr::from_rows(&idx, 3);
        a.values = vec![1.0, 1.0, 1.0];
        let v = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let y = a.spmm(&v);
        assert_eq!(y.data, vec![5., 6., 1., 2., 3., 4.]);
    }

    #[test]
    fn validate_catches_corruption() {
        let idx = vec![vec![1u32], vec![0]];
        let mut a = Csr::from_rows(&idx, 2);
        a.indices[0] = 9;
        assert!(a.validate().is_err());
        let mut b = Csr::from_rows(&idx, 2);
        b.indptr[1] = 7;
        assert!(b.validate().is_err());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "duplicate column")]
    fn debug_validate_catches_duplicate_columns() {
        let mut a = Csr::from_rows(&[vec![0u32, 1]], 2);
        a.indices[1] = 0;
        a.debug_validate();
    }

    #[test]
    fn memory_is_o_nl_not_n2() {
        let n = 512;
        let l = 64;
        let idx = TopL::from_rows(
            &(0..n)
                .map(|i| {
                    (0..l as u32).map(|j| (i as u32 + j) % n as u32).collect()
                })
                .collect::<Vec<Vec<u32>>>(),
        );
        let a = Csr::from_topl(&idx, n);
        let dense_bytes = n * n * 4;
        // paper: nL values + nL indices + (n+1) ptr << n^2
        assert!(a.bytes() < dense_bytes / 3, "{} vs {}", a.bytes(), dense_bytes);
    }
}
