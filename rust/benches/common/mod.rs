#![allow(dead_code)] // shared across bench binaries; each uses a subset
//! Shared helpers for the bench binaries (one per paper table/figure).

use std::path::Path;

use spt::metrics::Table;
use spt::runtime::Engine;

/// Artifacts directory: SPT_ARTIFACTS env or ./artifacts.
pub fn artifacts_dir() -> String {
    std::env::var("SPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Open the engine, or explain how to build artifacts and exit 0 (so
/// `cargo bench` degrades gracefully on a fresh checkout).
pub fn engine_or_skip(bench: &str) -> Option<Engine> {
    let dir = artifacts_dir();
    if !Path::new(&dir).join("manifest.json").exists() {
        println!("[{bench}] skipped: no artifacts at '{dir}' (run `make artifacts`)");
        return None;
    }
    match Engine::new(&dir) {
        Ok(e) => Some(e),
        Err(err) => {
            println!("[{bench}] skipped: {err:#}");
            None
        }
    }
}

/// Write the rendered table to stdout and bench_out/<name>.{md,csv}.
pub fn emit(name: &str, table: &Table) {
    println!("{}", table.render());
    let dir = Path::new("bench_out");
    std::fs::create_dir_all(dir).ok();
    std::fs::write(dir.join(format!("{name}.md")), table.render()).ok();
    std::fs::write(dir.join(format!("{name}.csv")), table.to_csv()).ok();
    println!("[bench] wrote bench_out/{name}.md and .csv\n");
}

/// Samples/warmup knobs (env-tunable so CI can be quick).
pub fn samples() -> usize {
    std::env::var("SPT_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2)
}

pub fn warmup() -> usize {
    std::env::var("SPT_BENCH_WARMUP")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}
