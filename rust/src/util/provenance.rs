//! Host/build provenance stamped into every BENCH JSON artifact.
//!
//! Benchmark numbers are only comparable when they come from the same
//! code on the same class of machine.  [`provenance`] captures the three
//! facts `cargo xtask benchdiff` needs to decide whether a regression is
//! real or a host change: the git commit, the rayon pool width, and the
//! CPU model string.  Every probe degrades to `"unknown"` instead of
//! failing — a bench run must never die because `git` is missing or
//! `/proc/cpuinfo` is not Linux-shaped.

use std::collections::BTreeMap;
use std::process::Command;

use crate::util::json::Json;

/// Short git commit hash of the working tree, or `"unknown"`.
pub fn git_sha() -> String {
    Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// CPU model string from `/proc/cpuinfo`, or `"unknown"`.
pub fn cpu_model() -> String {
    std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|text| {
            text.lines()
                .find(|l| l.starts_with("model name"))
                .and_then(|l| l.split_once(':').map(|(_, v)| v.trim().to_string()))
        })
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// The provenance object stamped into BENCH JSONs:
/// `{"git_sha":..,"rayon_threads":..,"cpu_model":..}`.
pub fn provenance() -> Json {
    let mut m = BTreeMap::new();
    m.insert("git_sha".to_string(), Json::Str(git_sha()));
    m.insert(
        "rayon_threads".to_string(),
        Json::Num(rayon::current_num_threads() as f64),
    );
    m.insert("cpu_model".to_string(), Json::Str(cpu_model()));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn provenance_has_all_keys_and_never_fails() {
        let p = provenance();
        assert!(!p.get("git_sha").as_str().unwrap_or("").is_empty());
        assert!(!p.get("cpu_model").as_str().unwrap_or("").is_empty());
        let threads = p.get("rayon_threads").as_usize().unwrap();
        assert!(threads >= 1, "rayon pool is at least one thread");
    }

    #[test]
    fn probes_degrade_to_unknown_not_empty() {
        // Direct probes never return the empty string: any failure path
        // lands on the literal "unknown" the differ treats as warn-only.
        assert!(!git_sha().is_empty());
        assert!(!cpu_model().is_empty());
    }
}
