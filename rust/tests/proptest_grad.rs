//! Finite-difference gradient checks for the native backward passes.
//!
//! Each property builds a scalar loss `f = sum(output ⊙ R)` for a fixed
//! random weighting `R`, computes analytic gradients via the backward
//! kernels in `spt::sparse::grad` / `bspmv`, and compares them against
//! central differences on randomly chosen coordinates.
//!
//! The structure decisions are held *fixed* across perturbations, the
//! same way training treats them: the top-L selection is computed once
//! and `sparse_attention_masked` differentiates through the kept entries
//! only, and the FFN routing is computed once from unperturbed scores.
//! For the routed FFN, coordinates whose perturbation flips a ReLU
//! pre-activation sign are skipped (the loss is piecewise linear; a
//! crossed kink makes the central difference measure the chord, not
//! either one-sided derivative).

use spt::config::{Mode, RunConfig};
use spt::coordinator::{Backend, NativeBackend};
use spt::sparse::attention;
use spt::sparse::bspmv::{self, Routing};
use spt::sparse::codes::{Codes, TopL};
use spt::sparse::grad;
use spt::sparse::topl;
use spt::sparse::Matrix;
use spt::util::proptest::{check, prop_assert, Gen, PropResult};

const EPS: f32 = 1e-2;

/// |fd - an| within `abs + rel * max(|fd|, |an|)`.
fn close(fd: f32, an: f32, abs: f32, rel: f32) -> bool {
    (fd - an).abs() <= abs + rel * fd.abs().max(an.abs())
}

fn weighted_sum(y: &Matrix, r: &Matrix) -> f32 {
    y.data.iter().zip(&r.data).map(|(a, b)| a * b).sum()
}

fn random_codes(g: &mut Gen, n: usize, m: usize, e: usize) -> Codes {
    let mut c = Codes::zeros(n, m);
    for x in c.data.iter_mut() {
        *x = g.usize_in(0, e - 1) as u8;
    }
    c
}

/// Pick `count` distinct-ish coordinates of an `rows x cols` matrix.
fn sample_coords(g: &mut Gen, rows: usize, cols: usize, count: usize) -> Vec<(usize, usize)> {
    (0..count)
        .map(|_| (g.usize_in(0, rows - 1), g.usize_in(0, cols - 1)))
        .collect()
}

// ---------------------------------------------------------------- attention

#[test]
fn sparse_attention_gradients_match_finite_differences() {
    check(10, |g| {
        let n = g.usize_in(3, 9);
        let m = g.usize_in(1, 3);
        let dsub = g.usize_in(1, 3);
        let d = m * dsub;
        let l = g.usize_in(1, n);
        let causal = g.bool();
        let mut rng = g.rng().fork();
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let r = Matrix::randn(n, d, 1.0, &mut rng);
        // Fixed top-L structure from the real selection pipeline.
        let cq = random_codes(g, n, m, 4);
        let ck = random_codes(g, n, m, 4);
        let idx = topl::select(&cq, &ck, l, causal);

        let (_, attn) = attention::sparse_attention_masked(&q, &k, &v, &idx, causal);
        let (dq, dk, dv) = grad::sparse_attention_backward(&q, &k, &v, &attn, &r);

        let loss = |q_: &Matrix, k_: &Matrix, v_: &Matrix| -> f32 {
            let (y, _) = attention::sparse_attention_masked(q_, k_, v_, &idx, causal);
            weighted_sum(&y, &r)
        };
        for (ri, ci) in sample_coords(g, n, d, 5) {
            // dQ
            let mut qp = q.clone();
            *qp.at_mut(ri, ci) = q.at(ri, ci) + EPS;
            let mut qm = q.clone();
            *qm.at_mut(ri, ci) = q.at(ri, ci) - EPS;
            let fd = (loss(&qp, &k, &v) - loss(&qm, &k, &v)) / (2.0 * EPS);
            prop_assert(
                close(fd, dq.at(ri, ci), 5e-3, 5e-2),
                format!("dq[{ri},{ci}]: fd {fd} vs an {}", dq.at(ri, ci)),
            )?;
            // dK
            let mut kp = k.clone();
            *kp.at_mut(ri, ci) = k.at(ri, ci) + EPS;
            let mut km = k.clone();
            *km.at_mut(ri, ci) = k.at(ri, ci) - EPS;
            let fd = (loss(&q, &kp, &v) - loss(&q, &km, &v)) / (2.0 * EPS);
            prop_assert(
                close(fd, dk.at(ri, ci), 5e-3, 5e-2),
                format!("dk[{ri},{ci}]: fd {fd} vs an {}", dk.at(ri, ci)),
            )?;
            // dV
            let mut vp = v.clone();
            *vp.at_mut(ri, ci) = v.at(ri, ci) + EPS;
            let mut vm = v.clone();
            *vm.at_mut(ri, ci) = v.at(ri, ci) - EPS;
            let fd = (loss(&q, &k, &vp) - loss(&q, &k, &vm)) / (2.0 * EPS);
            prop_assert(
                close(fd, dv.at(ri, ci), 5e-3, 5e-2),
                format!("dv[{ri},{ci}]: fd {fd} vs an {}", dv.at(ri, ci)),
            )?;
        }
        Ok(())
    });
}

#[test]
fn dense_attention_gradients_match_finite_differences() {
    check(10, |g| {
        let n = g.usize_in(3, 8);
        let d = g.usize_in(2, 6);
        let causal = g.bool();
        let mut rng = g.rng().fork();
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let r = Matrix::randn(n, d, 1.0, &mut rng);
        let (dq, dk, dv) = grad::dense_attention_backward(&q, &k, &v, causal, &r);
        let loss = |q_: &Matrix, k_: &Matrix, v_: &Matrix| -> f32 {
            weighted_sum(&attention::dense_attention(q_, k_, v_, causal), &r)
        };
        for (ri, ci) in sample_coords(g, n, d, 4) {
            let mut qp = q.clone();
            *qp.at_mut(ri, ci) += EPS;
            let mut qm = q.clone();
            *qm.at_mut(ri, ci) -= EPS;
            let fd = (loss(&qp, &k, &v) - loss(&qm, &k, &v)) / (2.0 * EPS);
            prop_assert(
                close(fd, dq.at(ri, ci), 5e-3, 5e-2),
                format!("dq[{ri},{ci}]: fd {fd} vs an {}", dq.at(ri, ci)),
            )?;
            let mut kp = k.clone();
            *kp.at_mut(ri, ci) += EPS;
            let mut km = k.clone();
            *km.at_mut(ri, ci) -= EPS;
            let fd = (loss(&q, &kp, &v) - loss(&q, &km, &v)) / (2.0 * EPS);
            prop_assert(
                close(fd, dk.at(ri, ci), 5e-3, 5e-2),
                format!("dk[{ri},{ci}]: fd {fd} vs an {}", dk.at(ri, ci)),
            )?;
            let mut vp = v.clone();
            *vp.at_mut(ri, ci) += EPS;
            let mut vm = v.clone();
            *vm.at_mut(ri, ci) -= EPS;
            let fd = (loss(&q, &k, &vp) - loss(&q, &k, &vm)) / (2.0 * EPS);
            prop_assert(
                close(fd, dv.at(ri, ci), 5e-3, 5e-2),
                format!("dv[{ri},{ci}]: fd {fd} vs an {}", dv.at(ri, ci)),
            )?;
        }
        Ok(())
    });
}

// --------------------------------------------------------------- routed FFN

/// ReLU pre-activation signs over all active (block, token, unit) slots,
/// in deterministic order — used to detect kink crossings.
fn relu_signs(x: &Matrix, wi: &Matrix, routing: &Routing) -> Vec<bool> {
    let dg = wi.cols / routing.g;
    let mut signs = Vec::new();
    for gi in 0..routing.g {
        for t in 0..x.rows {
            if !routing.mask[t][gi] {
                continue;
            }
            for u in 0..dg {
                let col = gi * dg + u;
                let pre: f32 = x
                    .row(t)
                    .iter()
                    .enumerate()
                    .map(|(i, &a)| a * wi.at(i, col))
                    .sum();
                signs.push(pre > 0.0);
            }
        }
    }
    signs
}

#[test]
fn routed_ffn_gradients_match_finite_differences() {
    check(12, |g| {
        let nt = g.usize_in(2, 10);
        let d = g.usize_in(2, 6);
        let gg = *g.pick(&[2usize, 4]);
        let dg = g.usize_in(1, 4);
        let dd = gg * dg;
        let ga = g.usize_in(1, gg);
        let mut rng = g.rng().fork();
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, dd, 0.5, &mut rng);
        let wo = Matrix::randn(dd, d, 0.5, &mut rng);
        let r = Matrix::randn(nt, d, 1.0, &mut rng);
        let routing = bspmv::route(&Matrix::randn(nt, gg, 1.0, &mut rng), ga);
        let (dx, dwi, dwo) =
            bspmv::routed_ffn_backward(&x, &wi, &wo, &routing, &r);
        let loss = |x_: &Matrix, wi_: &Matrix, wo_: &Matrix| -> f32 {
            weighted_sum(&bspmv::routed_ffn(x_, wi_, wo_, &routing), &r)
        };
        // The loss is piecewise multilinear, so away from kinks the
        // central difference is exact up to float noise.
        let check_coord = |fd: f32, an: f32, what: &str| -> PropResult {
            prop_assert(close(fd, an, 2e-3, 2e-2), format!("{what}: fd {fd} vs an {an}"))
        };
        for (ri, ci) in sample_coords(g, nt, d, 4) {
            let mut xp = x.clone();
            *xp.at_mut(ri, ci) += EPS;
            let mut xm = x.clone();
            *xm.at_mut(ri, ci) -= EPS;
            if relu_signs(&xp, &wi, &routing) != relu_signs(&xm, &wi, &routing) {
                continue; // kink crossed: skip this coordinate
            }
            let fd = (loss(&xp, &wi, &wo) - loss(&xm, &wi, &wo)) / (2.0 * EPS);
            check_coord(fd, dx.at(ri, ci), &format!("dx[{ri},{ci}]"))?;
        }
        for (ri, ci) in sample_coords(g, d, dd, 4) {
            let mut wp = wi.clone();
            *wp.at_mut(ri, ci) += EPS;
            let mut wm = wi.clone();
            *wm.at_mut(ri, ci) -= EPS;
            if relu_signs(&x, &wp, &routing) != relu_signs(&x, &wm, &routing) {
                continue;
            }
            let fd = (loss(&x, &wp, &wo) - loss(&x, &wm, &wo)) / (2.0 * EPS);
            check_coord(fd, dwi.at(ri, ci), &format!("dwi[{ri},{ci}]"))?;
        }
        for (ri, ci) in sample_coords(g, dd, d, 4) {
            // f is exactly linear in W_O: no kinks possible.
            let mut wp = wo.clone();
            *wp.at_mut(ri, ci) += EPS;
            let mut wm = wo.clone();
            *wm.at_mut(ri, ci) -= EPS;
            let fd = (loss(&x, &wi, &wp) - loss(&x, &wi, &wm)) / (2.0 * EPS);
            check_coord(fd, dwo.at(ri, ci), &format!("dwo[{ri},{ci}]"))?;
        }
        Ok(())
    });
}

// --------------------------------------------------------------- layer norm

#[test]
fn layer_norm_gradients_match_finite_differences() {
    check(10, |g| {
        let n = g.usize_in(2, 8);
        let d = g.usize_in(4, 12);
        let mut rng = g.rng().fork();
        let x = Matrix::randn(n, d, 1.0, &mut rng);
        let scale = Matrix::randn(1, d, 1.0, &mut rng);
        let bias = Matrix::randn(1, d, 0.5, &mut rng);
        let dy = Matrix::randn(n, d, 1.0, &mut rng);
        let (dx, dscale, dbias) = grad::layer_norm_backward(&x, &scale, &dy);
        let loss = |x_: &Matrix, s_: &Matrix, b_: &Matrix| -> f32 {
            weighted_sum(&grad::layer_norm(x_, s_, b_), &dy)
        };
        for (ri, ci) in sample_coords(g, n, d, 4) {
            let mut xp = x.clone();
            *xp.at_mut(ri, ci) += EPS;
            let mut xm = x.clone();
            *xm.at_mut(ri, ci) -= EPS;
            let fd = (loss(&xp, &scale, &bias) - loss(&xm, &scale, &bias)) / (2.0 * EPS);
            prop_assert(
                close(fd, dx.at(ri, ci), 5e-3, 5e-2),
                format!("dx[{ri},{ci}]: fd {fd} vs an {}", dx.at(ri, ci)),
            )?;
        }
        for (_, ci) in sample_coords(g, 1, d, 3) {
            let mut sp = scale.clone();
            *sp.at_mut(0, ci) += EPS;
            let mut sm = scale.clone();
            *sm.at_mut(0, ci) -= EPS;
            let fd = (loss(&x, &sp, &bias) - loss(&x, &sm, &bias)) / (2.0 * EPS);
            prop_assert(
                close(fd, dscale.at(0, ci), 5e-3, 5e-2),
                format!("dscale[{ci}]: fd {fd} vs an {}", dscale.at(0, ci)),
            )?;
            // The loss is exactly linear in the bias.
            let mut bp = bias.clone();
            *bp.at_mut(0, ci) += EPS;
            let mut bm = bias.clone();
            *bm.at_mut(0, ci) -= EPS;
            let fd = (loss(&x, &scale, &bp) - loss(&x, &scale, &bm)) / (2.0 * EPS);
            prop_assert(
                close(fd, dbias.at(0, ci), 5e-3, 5e-2),
                format!("dbias[{ci}]: fd {fd} vs an {}", dbias.at(0, ci)),
            )?;
        }
        Ok(())
    });
}

// -------------------------------------------------- multi-layer native stack

/// Directional-derivative step for the stacked-model check: the whole
/// leaf is perturbed along a random direction, which averages ReLU-kink
/// noise over thousands of coordinates instead of betting on one.
const STACK_EPS: f32 = 1e-2;

#[test]
fn two_layer_stack_gradients_match_finite_differences() {
    // End-to-end gradient check through the native 2-layer pre-norm
    // stack (embedding -> [ln1/MHA/ln2/FFN] x2 -> lnf -> tied readout):
    // per trainable leaf, the analytic directional derivative from
    // `loss_and_grads` must match central differences on `eval_loss`.
    check(4, |g| {
        let mode = *g.pick(&[Mode::Full, Mode::Lora]);
        let mut rng = g.rng().fork();
        let rc = RunConfig {
            model: "spt-nano-l2".into(),
            mode,
            batch: 1,
            seq: 8,
            seed: rng.next_u64(),
            ..RunConfig::default()
        };
        let backend = NativeBackend::new();
        let (batch, seq) = backend.workload(&rc).unwrap();
        let vocab = backend.vocab(&rc).unwrap();
        let tokens: Vec<i32> =
            (0..batch * seq).map(|_| rng.below(vocab) as i32).collect();
        let targets: Vec<i32> =
            (0..batch * seq).map(|_| rng.below(vocab) as i32).collect();
        // Two optimizer steps move LoRA `b` off its zero init so every
        // adapter leaf carries a non-trivial gradient at the test point.
        let mut state = backend.init_state(&rc).unwrap();
        for _ in 0..2 {
            backend
                .train_step(&rc, &mut state, &tokens, &targets)
                .unwrap();
        }
        let (_, grads) = backend
            .loss_and_grads(&rc, &state, &tokens, &targets)
            .unwrap();
        let trainable: Vec<usize> = grads
            .iter()
            .enumerate()
            .filter_map(|(ix, gl)| gl.as_ref().map(|_| ix))
            .collect();
        prop_assert(!trainable.is_empty(), "no trainable leaves")?;
        for _ in 0..4 {
            let ix = *g.pick(&trainable);
            let gl = grads[ix].as_ref().unwrap();
            let dir = rng.normal_vec(gl.len());
            let an: f32 = gl.iter().zip(&dir).map(|(a, b)| a * b).sum();
            let eval_shifted = |delta: f32| -> f32 {
                let mut s = state.clone();
                let buf = s.params[ix].as_f32_mut().unwrap();
                for (p, &dv) in buf.iter_mut().zip(&dir) {
                    *p += delta * dv;
                }
                backend.eval_loss(&rc, &s, &tokens, &targets).unwrap()
            };
            let fd =
                (eval_shifted(STACK_EPS) - eval_shifted(-STACK_EPS)) / (2.0 * STACK_EPS);
            prop_assert(
                close(fd, an, 1e-2, 1e-1),
                format!("{mode:?} leaf {ix}: fd {fd} vs an {an}"),
            )?;
        }
        Ok(())
    });
}

// ------------------------------------------------------------- projections

#[test]
fn linear_backward_matches_finite_differences() {
    check(15, |g| {
        let n = g.usize_in(1, 8);
        let m = g.usize_in(1, 6);
        let p = g.usize_in(1, 6);
        let mut rng = g.rng().fork();
        let x = Matrix::randn(n, m, 1.0, &mut rng);
        let w = Matrix::randn(m, p, 1.0, &mut rng);
        let r = Matrix::randn(n, p, 1.0, &mut rng);
        let (dx, dw) = grad::linear_backward(&x, &w, &r);
        let loss =
            |x_: &Matrix, w_: &Matrix| -> f32 { weighted_sum(&x_.matmul(w_), &r) };
        for (ri, ci) in sample_coords(g, n, m, 3) {
            let mut xp = x.clone();
            *xp.at_mut(ri, ci) += EPS;
            let mut xm = x.clone();
            *xm.at_mut(ri, ci) -= EPS;
            let fd = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * EPS);
            prop_assert(
                close(fd, dx.at(ri, ci), 2e-3, 2e-2),
                format!("dx[{ri},{ci}]: fd {fd} vs an {}", dx.at(ri, ci)),
            )?;
        }
        for (ri, ci) in sample_coords(g, m, p, 3) {
            let mut wp = w.clone();
            *wp.at_mut(ri, ci) += EPS;
            let mut wm = w.clone();
            *wm.at_mut(ri, ci) -= EPS;
            let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * EPS);
            prop_assert(
                close(fd, dw.at(ri, ci), 2e-3, 2e-2),
                format!("dw[{ri},{ci}]: fd {fd} vs an {}", dw.at(ri, ci)),
            )?;
        }
        Ok(())
    });
}

// Keep TopL in the public-API smoke below so the flat-buffer reuse the
// backward relies on stays exercised from outside the crate.
#[test]
fn masked_forward_agrees_with_selection_pipeline() {
    check(10, |g| {
        let n = g.usize_in(2, 12);
        let m = g.usize_in(1, 3);
        let l = g.usize_in(1, n);
        let causal = g.bool();
        let mut rng = g.rng().fork();
        let d = m * 2;
        let q = Matrix::randn(n, d, 1.0, &mut rng);
        let k = Matrix::randn(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, 1.0, &mut rng);
        let cq = random_codes(g, n, m, 4);
        let ck = random_codes(g, n, m, 4);
        let idx: TopL = topl::select(&cq, &ck, l, causal);
        let (y, attn) = attention::sparse_attention_masked(&q, &k, &v, &idx, causal);
        prop_assert(y.rows == n && y.cols == d, "output shape")?;
        prop_assert(attn.nnz() == n * l, "CSR keeps exactly L entries per query")?;
        // Kept-entry probabilities renormalize to 1 per row (or 0 for a
        // fully-masked row, which cannot happen here since l >= 1).
        for r in 0..n {
            let s: f32 = attn.row_range(r).map(|p| attn.values[p]).sum();
            prop_assert((s - 1.0).abs() < 1e-4, format!("row {r} prob sum {s}"))?;
        }
        Ok(())
    });
}
