"""Pure-jnp reference oracles for every SPT kernel.

These are the correctness ground truth the Pallas kernels (pq.py, topl.py,
sparse_attn.py, routed_ffn.py) are tested against.  Everything here is plain
``jax.numpy`` — dense, obvious, and slow; no Pallas, no tricks.

Semantics follow the paper (SPT, Gui et al. 2023):

* PQ quantization (Alg. 2): per-subspace nearest codeword under L2.
* Integer similarity (Eq. 6): ``s(q, k) = sum_m 1[t_q^m == t_k^m]``.
* Bucket-sort top-L (Alg. 3): rank keys by ``(-score, key_index)``
  lexicographically — i.e. higher score first, ties broken by *insertion
  order*, which for Alg. 3's sequential scan is ascending key index.
* Sparse attention (§4.1): softmax over only the selected L entries
  (renormalized so the kept weights sum to 1), optional causal mask.
* Routed FFN (§4.2): router ``x @ W_R``, activate the top-G' blocks by
  |score|, gate each active block by a softmax over the *selected* scores,
  and compute only those blocks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# PQ quantization
# ---------------------------------------------------------------------------


def pq_quantize(x: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Quantize ``x`` with product quantization.

    Args:
      x: ``[n, d]`` vectors to quantize.
      codebooks: ``[M, E, d']`` codebooks, ``d = M * d'``.

    Returns:
      ``[n, M]`` int32 codeword indices.
    """
    n, d = x.shape
    m, e, dsub = codebooks.shape
    assert d == m * dsub, f"d={d} must equal M*d'={m}*{dsub}"
    xs = x.reshape(n, m, dsub)  # [n, M, d']
    # [n, M, E] squared L2 distance per subspace.
    diff = xs[:, :, None, :] - codebooks[None, :, :, :]
    dist = jnp.sum(diff * diff, axis=-1)
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def pq_quantize_error(x: jax.Array, codebooks: jax.Array) -> jax.Array:
    """Mean squared quantization error (scalar) — DKM-style codebook signal."""
    n, d = x.shape
    m, e, dsub = codebooks.shape
    codes = pq_quantize(x, codebooks)  # [n, M]
    xs = x.reshape(n, m, dsub)
    chosen = jnp.take_along_axis(
        codebooks[None], codes[:, :, None, None], axis=2
    )[:, :, 0, :]  # [n, M, d']
    return jnp.mean((xs - chosen) ** 2)


def pq_codebook_update(
    x: jax.Array, codebooks: jax.Array, lr: float = 0.5
) -> jax.Array:
    """One soft k-means (DKM-flavoured) codebook refresh step.

    Moves each codeword toward the mean of the vectors assigned to it.
    Empty codewords are left untouched.
    """
    n, d = x.shape
    m, e, dsub = codebooks.shape
    codes = pq_quantize(x, codebooks)  # [n, M]
    xs = x.reshape(n, m, dsub)
    onehot = jax.nn.one_hot(codes, e, dtype=x.dtype)  # [n, M, E]
    counts = jnp.sum(onehot, axis=0)  # [M, E]
    sums = jnp.einsum("nme,nmd->med", onehot, xs)  # [M, E, d']
    means = sums / jnp.maximum(counts, 1.0)[:, :, None]
    occupied = (counts > 0)[:, :, None]
    target = jnp.where(occupied, means, codebooks)
    return codebooks + lr * (target - codebooks)


# ---------------------------------------------------------------------------
# Integer similarity + bucket-sort top-L
# ---------------------------------------------------------------------------


def pq_scores(codes_q: jax.Array, codes_k: jax.Array) -> jax.Array:
    """Integer similarity matrix ``[nq, nk]``: number of matching codewords."""
    eq = codes_q[:, None, :] == codes_k[None, :, :]  # [nq, nk, M]
    return jnp.sum(eq.astype(jnp.int32), axis=-1)


def topl_select(
    codes_q: jax.Array,
    codes_k: jax.Array,
    l: int,
    causal: bool = False,
) -> jax.Array:
    """Bucket-sort top-L key selection (paper Alg. 3 semantics).

    Keys are ranked by ``(-score, key_index)``; the first L are returned in
    that order.  With ``causal=True``, key j is only eligible for query i if
    ``j <= i`` (ineligible keys get score -1 but, to keep the output shape
    static, may still appear as padding when a query has < L eligible keys —
    exactly like Alg. 3 reading residual bucket slots; the attention mask
    downstream re-masks them).

    Returns ``[nq, L]`` int32 key indices.
    """
    nq = codes_q.shape[0]
    nk = codes_k.shape[0]
    s = pq_scores(codes_q, codes_k)  # [nq, nk]
    if causal:
        i = jnp.arange(nq)[:, None]
        j = jnp.arange(nk)[None, :]
        s = jnp.where(j <= i, s, -1)
    # Lexicographic (-score, j): encode as score * nk + (nk - 1 - j); larger
    # is better.  Scores are small non-negative ints so no overflow.
    combined = s * nk + (nk - 1 - jnp.arange(nk))[None, :]
    _, idx = jax.lax.top_k(combined, l)
    return idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Sparse attention (SDDMM -> masked softmax -> SpMM)
# ---------------------------------------------------------------------------


def sddmm(q: jax.Array, k: jax.Array, indices: jax.Array) -> jax.Array:
    """Sampled dense-dense matmul: ``vals[i, l] = q_i . k_{indices[i, l]}``."""
    kg = k[indices]  # [n, L, d]
    return jnp.einsum("nd,nld->nl", q, kg)


def sparse_softmax(
    vals: jax.Array, indices: jax.Array, causal: bool = False
) -> jax.Array:
    """Row softmax over the L sampled entries; duplicate/causal-invalid
    entries are masked out.

    A row's entries are invalid if (a) causal and index > row, or (b) the
    same key index appeared earlier in the row (top-L padding duplicates).
    """
    n, l = vals.shape
    valid = jnp.ones_like(vals, dtype=bool)
    if causal:
        rows = jnp.arange(n)[:, None]
        valid = valid & (indices <= rows)
    # Mask duplicate indices within a row (keep the first occurrence).
    first = indices[:, :, None] == indices[:, None, :]  # [n, L, L]
    earlier = jnp.tril(jnp.ones((l, l), dtype=bool), k=-1)[None]
    dup = jnp.any(first & earlier, axis=-1)
    valid = valid & ~dup
    neg = jnp.finfo(vals.dtype).min
    masked = jnp.where(valid, vals, neg)
    mx = jnp.max(masked, axis=-1, keepdims=True)
    ex = jnp.where(valid, jnp.exp(masked - mx), 0.0)
    denom = jnp.sum(ex, axis=-1, keepdims=True)
    return ex / jnp.maximum(denom, jnp.finfo(vals.dtype).tiny)


def spmm(weights: jax.Array, indices: jax.Array, v: jax.Array) -> jax.Array:
    """Sparse @ dense: ``y_i = sum_l weights[i, l] * v[indices[i, l]]``."""
    vg = v[indices]  # [n, L, d]
    return jnp.einsum("nl,nld->nd", weights, vg)


def sparse_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    indices: jax.Array,
    causal: bool = False,
    scale: float | None = None,
) -> jax.Array:
    """Full sparse-MHA pipeline for one head given the top-L indices."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    vals = sddmm(q * scale, k, indices)
    w = sparse_softmax(vals, indices, causal=causal)
    return spmm(w, indices, v)


def dense_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = False
) -> jax.Array:
    """Vanilla softmax attention — the baseline SPT approximates."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = (q @ k.T) * scale
    if causal:
        n = q.shape[0]
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    w = jax.nn.softmax(logits, axis=-1)
    return w @ v


# ---------------------------------------------------------------------------
# Routed FFN
# ---------------------------------------------------------------------------


def router_topk(scores: jax.Array, g_active: int) -> jax.Array:
    """Top-G' block selection by |score| -> boolean mask ``[n, G]``."""
    mag = jnp.abs(scores)
    _, idx = jax.lax.top_k(mag, g_active)
    mask = jnp.zeros_like(scores, dtype=bool)
    return mask.at[jnp.arange(scores.shape[0])[:, None], idx].set(True)


def routed_ffn(
    x: jax.Array,
    w_i: jax.Array,
    w_o: jax.Array,
    w_r: jax.Array,
    g_active: int,
    activation: str = "relu",
) -> tuple[jax.Array, jax.Array]:
    """Routed FFN reference (paper §4.2, Fig. 6a).

    Args:
      x: ``[n, d]`` tokens.
      w_i: ``[d, D]`` inner projection.
      w_o: ``[D, d]`` outer projection.
      w_r: ``[d, G]`` router.
      g_active: number of active blocks G' per token.

    Returns:
      ``(y, router_scores)`` with y ``[n, d]`` and router_scores ``[n, G]``
      (pre-activation, used for the load-balancing loss).
    """
    n, d = x.shape
    dd = w_i.shape[1]
    g = w_r.shape[1]
    assert dd % g == 0
    scores = x @ w_r  # [n, G]
    mask = router_topk(scores, g_active)  # [n, G] bool
    # Gate: softmax over the selected scores only (renormalized), so the
    # router receives gradient through the output as well as the LB loss.
    neg = jnp.finfo(scores.dtype).min
    gate = jax.nn.softmax(jnp.where(mask, scores, neg), axis=-1)  # [n, G]
    h = x @ w_i  # [n, D]
    h = jax.nn.relu(h) if activation == "relu" else jax.nn.gelu(h)
    # Expand block gate across each block's D/G hidden units.
    gate_full = jnp.repeat(gate * g_active, dd // g, axis=1)  # [n, D]
    y = (h * gate_full) @ w_o
    return y, scores


def load_balance_loss(scores: jax.Array, g_active: int) -> jax.Array:
    """Switch-style load-balancing loss over router scores.

    ``G * sum_g f_g * p_g`` where f_g is the fraction of tokens whose top-G'
    includes block g and p_g the mean router probability of block g.
    Minimized when routing is uniform across blocks.
    """
    g = scores.shape[1]
    mask = router_topk(scores, g_active).astype(scores.dtype)
    f = jnp.mean(mask, axis=0)  # [G]
    p = jnp.mean(jax.nn.softmax(scores, axis=-1), axis=0)  # [G]
    return g * jnp.sum(f * p) / g_active


def dense_ffn(
    x: jax.Array, w_i: jax.Array, w_o: jax.Array, activation: str = "relu"
) -> jax.Array:
    """Vanilla FFN baseline."""
    h = x @ w_i
    h = jax.nn.relu(h) if activation == "relu" else jax.nn.gelu(h)
    return h @ w_o


# ---------------------------------------------------------------------------
# LoRA
# ---------------------------------------------------------------------------


def lora_linear(
    x: jax.Array, w: jax.Array, b_lo: jax.Array, c_lo: jax.Array,
    alpha: float = 1.0,
) -> jax.Array:
    """LoRA projection ``x @ (W + alpha * B C)`` (Eq. 5)."""
    return x @ w + (x @ b_lo) @ (alpha * c_lo)
