//! Paper Table 6: alternative implementations.
//!
//! (a) Sparse MHA selection: bucket-sort (integer scores) vs Naive-PQ
//!     (float ADC tables + full sort).  Paper: Naive-PQ is 4.6x slower
//!     and slightly more memory.  Measured here on the rust-native
//!     substrate at the paper's per-head shape (n=512), and also via the
//!     XLA kernel artifacts.
//! (b) Routed FFN: BSpMV vs BSR masking.  Paper: BSR OOMs (200 GB masks);
//!     we run BSR at small scale and report the accounted bytes at paper
//!     scale.

mod common;

#[cfg(feature = "xla")]
use spt::coordinator::profile::random_inputs;
use spt::metrics::{bench, Table};
use spt::sparse::{bspmv, bsr, naive_pq, pq, topl, Matrix};
use spt::util::{fmt_bytes, fmt_duration};
use spt::util::rng::Rng;

fn main() {
    let (w, s) = (common::warmup(), common::samples().max(5));

    // ---------------- (a) native selection comparison ----------------
    let mut rng = Rng::new(42);
    let (n, d, m, e) = (512usize, 64usize, 8usize, 16usize);
    let l = n / 8;
    let mut cb = pq::Codebooks::random(m, e, d / m, &mut rng);
    let q = Matrix::randn(n, d, 1.0, &mut rng);
    let k = Matrix::randn(n, d, 1.0, &mut rng);
    for _ in 0..3 {
        pq::codebook_update(&k.data, &mut cb, 1.0);
    }
    let cq = pq::quantize(&q.data, &cb);
    let ck = pq::quantize(&k.data, &cb);
    let tables = naive_pq::ScoreTables::build(&cb);

    let bucket = bench("bucket", w, s, || {
        std::hint::black_box(topl::select(&cq, &ck, l, false));
    });
    let naive = bench("naive", w, s, || {
        std::hint::black_box(naive_pq::select(&cq, &ck, &tables, l, false));
    });

    let mut ta = Table::new(
        "Table 6a — top-L selection: bucket sort vs Naive-PQ (n=512, L=64, M=8, E=16)",
        &["Method", "Median", "Slowdown", "Scratch bytes/query", "Paper"],
    );
    ta.row(&[
        "SPT (bucket sort)".into(),
        fmt_duration(bucket.median()),
        "1.00x".into(),
        fmt_bytes(((m + 2) * l * 4) as u64),
        "54.1 ms, 1123 MB".into(),
    ]);
    ta.row(&[
        "Naive-PQ (float sort)".into(),
        fmt_duration(naive.median()),
        format!("{:.2}x", naive.median() / bucket.median()),
        fmt_bytes(naive_pq::scratch_bytes_per_query(n) as u64),
        "248.9 ms (4.6x), 1253 MB".into(),
    ]);
    common::emit("table6a_selection", &ta);

    // ---------------- (b) BSpMV vs BSR ----------------
    let (nt, dd, df, g, ga) = (128usize, 64usize, 256usize, 8usize, 4usize);
    let x = Matrix::randn(nt, dd, 1.0, &mut rng);
    let wi = Matrix::randn(dd, df, 0.2, &mut rng);
    let wo = Matrix::randn(df, dd, 0.2, &mut rng);
    let scores = Matrix::randn(nt, g, 1.0, &mut rng);
    let routing = bspmv::route(&scores, ga);
    let b_bspmv = bench("bspmv", w, s, || {
        std::hint::black_box(bspmv::routed_ffn(&x, &wi, &wo, &routing));
    });
    let b_bsr = bench("bsr", w, s, || {
        std::hint::black_box(bsr::routed_ffn_bsr(&x, &wi, &wo, &routing));
    });
    let mut tb = Table::new(
        "Table 6b — routed FFN: BSpMV vs BSR masking (small scale + paper-scale accounting)",
        &["Method", "Median (nt=128 toy)", "Mask bytes @paper scale (16x512 tokens, OPT-2048)", "Paper"],
    );
    tb.row(&[
        "BSpMV (token batching)".into(),
        fmt_duration(b_bspmv.median()),
        "0 (no masks)".into(),
        "runs, ~theoretical speedup".into(),
    ]);
    tb.row(&[
        "BSR / per-token masks".into(),
        fmt_duration(b_bsr.median()),
        fmt_bytes(bsr::expanded_mask_bytes(16 * 512, 2048, 8192)),
        "OOM (200 GB masks)".into(),
    ]);
    common::emit("table6b_bsr", &tb);

    // ---------------- XLA-kernel cross-check (if artifacts exist) -------
    #[cfg(feature = "xla")]
    xla_selection(w, s);
}

#[cfg(feature = "xla")]
fn xla_selection(w: usize, s: usize) {
    if let Some(engine) = common::engine_or_skip("table6-xla") {
        let mut tx = Table::new(
            "Table 6 (XLA artifacts) — selection kernels through PJRT",
            &["Artifact", "Median"],
        );
        for name in ["kernel_topl_select", "kernel_naive_pq_select"] {
            if engine.manifest().get(name).is_err() {
                continue;
            }
            let inputs = random_inputs(&engine, name, 3).expect("inputs");
            engine.load(name).expect("compile");
            let r = bench(name, w, s, || {
                engine.run(name, &inputs).expect("run");
            });
            tx.row(&[name.to_string(), fmt_duration(r.median())]);
        }
        if tx.rows() > 0 {
            common::emit("table6_xla_selection", &tx);
        }
    }
}
