//! Thread-count determinism of the native backend's chunk-parallel step.
//!
//! `train_step` fans out over fixed-size item chunks and `eval_loss`
//! over items, on top of the blocked GEMM microkernel; both must stay
//! bit-identical at any rayon pool size.  These tests run the same
//! fine-tune under dedicated pools of 1, 2, and 8 threads (deliberately
//! oversubscribed relative to small CI machines) and assert the losses,
//! eval losses, parameters, and AdamW moments agree to the bit — for the
//! single-block `spt-nano` preset and for the multi-layer `spt-nano-l2`
//! stack (per-layer weights, layer norms, and codebook leaves all
//! compared).  CI additionally runs the `global_pool` tests under two
//! `RAYON_NUM_THREADS` settings to cover the global-pool path.

use spt::config::{Mode, RunConfig};
use spt::coordinator::{Backend, NativeBackend, TrainState};
use spt::data::SyntheticCorpus;

const STEPS: usize = 3;

fn rc(model: &str, mode: Mode) -> RunConfig {
    RunConfig {
        model: model.into(),
        mode,
        batch: 8,
        seq: 32,
        seed: 123,
        lr: 5e-3,
        eval_every: 0,
        codebook_refresh_every: 0,
        ..RunConfig::default()
    }
}

fn lm_batch(rc: &RunConfig, backend: &NativeBackend) -> (Vec<i32>, Vec<i32>) {
    let (batch, seq) = backend.workload(rc).unwrap();
    let vocab = backend.vocab(rc).unwrap();
    let mut corpus = SyntheticCorpus::new(vocab, 4, 0.85, rc.seed);
    let mut tokens = Vec::new();
    let mut targets = Vec::new();
    for _ in 0..batch {
        let (x, y) = corpus.lm_pair(seq);
        tokens.extend(x.iter().map(|&t| t as i32));
        targets.extend(y.iter().map(|&t| t as i32));
    }
    (tokens, targets)
}

/// Run `STEPS` train steps plus one eval under a dedicated pool of
/// `threads` workers; returns the loss bit patterns and the final state.
fn run_under_pool(threads: usize, model: &str, mode: Mode) -> (Vec<u32>, TrainState) {
    let pool = rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("pool");
    pool.install(|| {
        let backend = NativeBackend::new();
        let cfg = rc(model, mode);
        let (tokens, targets) = lm_batch(&cfg, &backend);
        let mut state = backend.init_state(&cfg).unwrap();
        let mut bits = Vec::with_capacity(STEPS + 1);
        for _ in 0..STEPS {
            let loss = backend
                .train_step(&cfg, &mut state, &tokens, &targets)
                .unwrap();
            assert!(loss.is_finite(), "{model}/{mode:?}: non-finite loss");
            bits.push(loss.to_bits());
        }
        let eval = backend.eval_loss(&cfg, &state, &tokens, &targets).unwrap();
        bits.push(eval.to_bits());
        (bits, state)
    })
}

/// The shared assertion: pools of 2 and 8 must reproduce the 1-thread
/// pool bit-for-bit — losses, every parameter leaf, and both AdamW
/// moment sets (which covers per-layer weights, layer norms, adapters,
/// and codebook leaves on multi-layer presets).
fn assert_pool_invariance(model: &str) {
    for mode in Mode::ALL {
        let (bits1, state1) = run_under_pool(1, model, mode);
        for threads in [2usize, 8] {
            let (bits_t, state_t) = run_under_pool(threads, model, mode);
            assert_eq!(
                bits1, bits_t,
                "{model}/{mode:?}: losses diverge between pools of 1 and {threads}"
            );
            assert_eq!(
                state1.params, state_t.params,
                "{model}/{mode:?}: params diverge between pools of 1 and {threads}"
            );
            assert_eq!(
                state1.m, state_t.m,
                "{model}/{mode:?}: AdamW m diverges between pools of 1 and {threads}"
            );
            assert_eq!(
                state1.v, state_t.v,
                "{model}/{mode:?}: AdamW v diverges between pools of 1 and {threads}"
            );
        }
    }
}

#[test]
fn train_step_bit_identical_across_pool_sizes() {
    assert_pool_invariance("spt-nano");
}

#[test]
fn multi_layer_train_step_bit_identical_across_pool_sizes() {
    assert_pool_invariance("spt-nano-l2");
}

/// Whatever `RAYON_NUM_THREADS` CI sets for the global pool, results
/// must equal the dedicated 1-thread pool's.
fn assert_global_pool_matches_reference(model: &str) {
    let backend = NativeBackend::new();
    let cfg = rc(model, Mode::Spt);
    let (tokens, targets) = lm_batch(&cfg, &backend);
    let mut state = backend.init_state(&cfg).unwrap();
    let mut global_bits = Vec::new();
    for _ in 0..STEPS {
        global_bits.push(
            backend
                .train_step(&cfg, &mut state, &tokens, &targets)
                .unwrap()
                .to_bits(),
        );
    }
    let (reference, ref_state) = run_under_pool(1, model, Mode::Spt);
    assert_eq!(&reference[..STEPS], &global_bits[..], "{model}: losses");
    assert_eq!(ref_state.params, state.params, "{model}: params");
    assert_eq!(ref_state.m, state.m, "{model}: AdamW m");
    assert_eq!(ref_state.v, state.v, "{model}: AdamW v");
}

#[test]
fn global_pool_matches_dedicated_single_thread_pool() {
    assert_global_pool_matches_reference("spt-nano");
}

#[test]
fn global_pool_matches_dedicated_single_thread_pool_multi_layer() {
    assert_global_pool_matches_reference("spt-nano-l2");
}
