//! Host tensors — the coordinator's working representation — plus
//! literal marshalling to PJRT when the `xla` feature is on.

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use anyhow::Context;

use super::manifest::{DType, TensorSpec};
#[cfg(feature = "xla")]
use super::xla;
use crate::util::rng::Rng;

/// A host-side tensor: the coordinator's working representation.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn zeros(spec: &TensorSpec) -> Result<Self> {
        let n = spec.elements();
        Ok(match spec.dtype {
            DType::F32 => HostTensor::F32 { shape: spec.shape.clone(), data: vec![0.0; n] },
            DType::I32 => HostTensor::I32 { shape: spec.shape.clone(), data: vec![0; n] },
            other => bail!("zeros: unsupported dtype {other:?}"),
        })
    }

    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![x] }
    }

    /// Standard-normal tensor (scaled) — for synthetic workloads.
    pub fn randn(shape: Vec<usize>, scale: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() * scale).collect();
        HostTensor::F32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn elements(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Mutable f32 view (the native backend's in-place AdamW update).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Scalar extraction (0-d or 1-element tensors).
    pub fn scalar(&self) -> Result<f32> {
        match self {
            HostTensor::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            HostTensor::I32 { data, .. } if data.len() == 1 => Ok(data[0] as f32),
            _ => bail!("not a scalar (shape {:?})", self.shape()),
        }
    }

    /// Check this tensor matches a manifest spec.
    pub fn matches(&self, spec: &TensorSpec) -> bool {
        self.shape() == spec.shape.as_slice() && self.dtype() == spec.dtype
    }

    /// Convert to an XLA literal for execution.
    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        lit.reshape(&dims).context("literal reshape")
    }

    /// Read a literal back into a host tensor.
    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            xla::ElementType::Pred => {
                // Bools: widen to i32 via an XLA-side convert (the crate
                // refuses to read Pred buffers as u8 directly).
                let as_i32 = lit.convert(xla::PrimitiveType::S32)?;
                Ok(HostTensor::I32 { shape: dims, data: as_i32.to_vec::<i32>()? })
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Max of |a-b| - rtol*|b| (0 when within mixed tolerance everywhere).
    /// Different XLA backends reassociate GEMM reductions, so float
    /// comparisons need a relative term; integer tensors compare exactly.
    pub fn max_tol_excess(&self, other: &HostTensor, rtol: f32) -> Result<f32> {
        match (self, other) {
            (HostTensor::F32 { data: a, .. }, HostTensor::F32 { data: b, .. }) => {
                if a.len() != b.len() {
                    anyhow::bail!("length mismatch {} vs {}", a.len(), b.len());
                }
                Ok(a.iter()
                    .zip(b)
                    .map(|(x, y)| (x - y).abs() - rtol * x.abs())
                    .fold(0.0f32, f32::max))
            }
            _ => self.max_abs_diff(other),
        }
    }

    /// Max |a - b| against another tensor (goldens comparison).
    pub fn max_abs_diff(&self, other: &HostTensor) -> Result<f32> {
        match (self, other) {
            (HostTensor::F32 { data: a, .. }, HostTensor::F32 { data: b, .. }) => {
                if a.len() != b.len() {
                    bail!("length mismatch {} vs {}", a.len(), b.len());
                }
                Ok(a.iter()
                    .zip(b)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f32, f32::max))
            }
            (HostTensor::I32 { data: a, .. }, HostTensor::I32 { data: b, .. }) => {
                if a.len() != b.len() {
                    bail!("length mismatch {} vs {}", a.len(), b.len());
                }
                Ok(a.iter()
                    .zip(b)
                    .map(|(x, y)| (x - y).abs() as f32)
                    .fold(0.0f32, f32::max))
            }
            _ => bail!("dtype mismatch"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec { shape: shape.to_vec(), dtype }
    }

    #[test]
    fn zeros_shapes() {
        let t = HostTensor::zeros(&spec(&[2, 3], DType::F32)).unwrap();
        assert_eq!(t.elements(), 6);
        assert_eq!(t.bytes(), 24);
        assert!(t.matches(&spec(&[2, 3], DType::F32)));
        assert!(!t.matches(&spec(&[3, 2], DType::F32)));
        assert!(!t.matches(&spec(&[2, 3], DType::I32)));
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(HostTensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
        assert_eq!(HostTensor::scalar_i32(-3).scalar().unwrap(), -3.0);
        assert!(HostTensor::f32(vec![2], vec![1.0, 2.0]).scalar().is_err());
    }

    #[test]
    fn randn_is_deterministic() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let a = HostTensor::randn(vec![4, 4], 1.0, &mut r1);
        let b = HostTensor::randn(vec![4, 4], 1.0, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn diff_computation() {
        let a = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::f32(vec![3], vec![1.5, 2.0, 2.0]);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
        let c = HostTensor::i32(vec![1], vec![5]);
        assert!(a.max_abs_diff(&c).is_err());
    }

    #[test]
    fn mutable_view_updates_in_place() {
        let mut t = HostTensor::f32(vec![2], vec![1.0, 2.0]);
        t.as_f32_mut().unwrap()[1] = 5.0;
        assert_eq!(t.as_f32().unwrap(), &[1.0, 5.0]);
        let mut i = HostTensor::i32(vec![1], vec![3]);
        assert!(i.as_f32_mut().is_err());
    }

    // The literal round-trip tests ran against the real PJRT bindings;
    // with the stubbed `xla` module the marshalling entry points must
    // fail with an actionable error instead (swap this back to a
    // round-trip check when the real bindings crate is linked).
    #[cfg(feature = "xla")]
    #[test]
    fn literal_marshalling_reports_stubbed_bindings() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let err = t.to_literal().unwrap_err().to_string();
        assert!(err.contains("stub"), "unexpected error: {err}");
    }
}
