//! Batched multi-head execution layer over the sparse substrate —
//! forward *and* backward.
//!
//! The single-head pipelines in [`super::attention`], [`super::bspmv`]
//! and [`super::grad`] stay the *sequential cross-validation reference*;
//! this module runs H heads with rayon parallelism over
//! (head × query-chunk), fans the routed FFN out over its weight blocks,
//! and does the same for the backward passes
//! ([`MultiHeadSparseAttention::backward`],
//! [`routed_ffn_backward_par`]).  All parallel paths reproduce the
//! sequential results bit-for-bit: every per-row floating-point
//! reduction happens in the same operation order as the reference — only
//! *across* rows/blocks/heads is the work distributed — so the property
//! tests can assert exact equality without chasing reassociation noise.

use rayon::prelude::*;

use super::bspmv::{self, Routing};
use super::codes::Codes;
use super::csr::Csr;
use super::grad;
use super::kernel;
use super::matrix::Matrix;
use super::pq::{self, Codebooks};
use super::topl;

/// Default number of query rows per parallel work item.  Small enough to
/// load-balance H × (n / chunk) tasks across the pool, large enough that
/// the per-task scratch allocation amortizes.
pub const DEFAULT_QUERY_CHUNK: usize = 32;

/// Multi-head sparse attention: per-head PQ codebooks and per-head
/// Q/K/V, shared sparsity strength `l` and causality.
#[derive(Debug, Clone)]
pub struct MultiHeadSparseAttention {
    /// One codebook per head (heads are quantized independently).
    pub codebooks: Vec<Codebooks>,
    /// Keys kept per query (paper's L).
    pub l: usize,
    pub causal: bool,
    /// Query rows per parallel task; tune for cache vs scheduling.
    pub query_chunk: usize,
}

impl MultiHeadSparseAttention {
    pub fn new(codebooks: Vec<Codebooks>, l: usize, causal: bool) -> Self {
        assert!(!codebooks.is_empty(), "need at least one head");
        assert!(l >= 1);
        MultiHeadSparseAttention {
            codebooks,
            l,
            causal,
            query_chunk: DEFAULT_QUERY_CHUNK,
        }
    }

    pub fn heads(&self) -> usize {
        self.codebooks.len()
    }

    fn check(&self, q: &[Matrix], k: &[Matrix], v: &[Matrix]) {
        let hh = self.heads();
        assert_eq!(q.len(), hh, "q head count");
        assert_eq!(k.len(), hh, "k head count");
        assert_eq!(v.len(), hh, "v head count");
        for h in 0..hh {
            assert_eq!(q[h].cols, k[h].cols, "head {h}: q/k dims differ");
            assert_eq!(k[h].rows, v[h].rows, "head {h}: k/v rows differ");
            assert_eq!(
                q[h].cols,
                self.codebooks[h].d(),
                "head {h}: codebook dim mismatch"
            );
            assert!(
                self.l <= k[h].rows,
                "head {h}: L={} exceeds {} keys",
                self.l,
                k[h].rows
            );
        }
    }

    /// Sequential reference: the single-head pipeline, head by head.
    /// The parallel [`Self::forward`] must match this bit-for-bit.
    pub fn forward_seq(&self, q: &[Matrix], k: &[Matrix], v: &[Matrix]) -> Vec<Matrix> {
        self.check(q, k, v);
        (0..self.heads())
            .map(|h| {
                super::attention::sparse_attention(
                    &q[h],
                    &k[h],
                    &v[h],
                    &self.codebooks[h],
                    self.l,
                    self.causal,
                )
                .0
            })
            .collect()
    }

    /// Parallel path: rayon over heads and, within each head, over
    /// query-row chunks of the output buffer (disjoint `&mut` windows, no
    /// locks).  Nested rayon scopes compose by work-stealing, so the
    /// effective fan-out is H × ceil(n / query_chunk) tasks.
    pub fn forward(&self, q: &[Matrix], k: &[Matrix], v: &[Matrix]) -> Vec<Matrix> {
        self.check(q, k, v);
        (0..self.heads())
            .into_par_iter()
            .map(|h| self.forward_head(&q[h], &k[h], &v[h], &self.codebooks[h]))
            .collect()
    }

    /// Forward that also returns each head's post-softmax attention CSR
    /// — the cache [`Self::backward`] consumes.  Rayon-parallel over
    /// heads; within a head this is the sequential single-head pipeline,
    /// so outputs are bit-identical to [`Self::forward`] /
    /// [`Self::forward_seq`].
    pub fn forward_cached(
        &self,
        q: &[Matrix],
        k: &[Matrix],
        v: &[Matrix],
    ) -> (Vec<Matrix>, Vec<Csr>) {
        self.check(q, k, v);
        let per_head: Vec<(Matrix, Csr)> = (0..self.heads())
            .into_par_iter()
            .map(|h| {
                let cb = &self.codebooks[h];
                let cq = pq::quantize(&q[h].data, cb);
                let ck = pq::quantize(&k[h].data, cb);
                let idx = topl::select(&cq, &ck, self.l, self.causal);
                super::attention::sparse_attention_masked(
                    &q[h], &k[h], &v[h], &idx, self.causal,
                )
            })
            .collect();
        per_head.into_iter().unzip()
    }

    /// Multi-head backward through the kept entries: rayon over heads,
    /// each head running the sequential reference kernel
    /// [`grad::sparse_attention_backward`] — so the result is
    /// bit-identical to a head-by-head sequential sweep.  Returns
    /// per-head `(dq, dk, dv)`.
    #[allow(clippy::type_complexity)]
    pub fn backward(
        &self,
        q: &[Matrix],
        k: &[Matrix],
        v: &[Matrix],
        attn: &[Csr],
        dy: &[Matrix],
    ) -> (Vec<Matrix>, Vec<Matrix>, Vec<Matrix>) {
        let hh = self.heads();
        assert_eq!(attn.len(), hh, "attn head count");
        assert_eq!(dy.len(), hh, "dy head count");
        let per_head: Vec<(Matrix, Matrix, Matrix)> = (0..hh)
            .into_par_iter()
            .map(|h| grad::sparse_attention_backward(&q[h], &k[h], &v[h], &attn[h], &dy[h]))
            .collect();
        let mut dq = Vec::with_capacity(hh);
        let mut dk = Vec::with_capacity(hh);
        let mut dv = Vec::with_capacity(hh);
        for (a, b, c) in per_head {
            dq.push(a);
            dk.push(b);
            dv.push(c);
        }
        (dq, dk, dv)
    }

    /// One head of the parallel path.  Per chunk, each query row runs the
    /// full pipeline (PQ quantize -> bucket-sort top-L -> SDDMM ->
    /// softmax -> SpMM) in exactly the reference operation order.
    fn forward_head(&self, q: &Matrix, k: &Matrix, v: &Matrix, cb: &Codebooks) -> Matrix {
        let scale = 1.0 / (q.cols as f32).sqrt();
        let l = self.l;
        let causal = self.causal;
        let d_out = v.cols;
        // Key codes are shared by every chunk: quantize once per head.
        let ck = pq::quantize(&k.data, cb);
        let mut out = Matrix::zeros(q.rows, d_out);
        let chunk = self.query_chunk.max(1);
        out.data
            .par_chunks_mut(chunk * d_out)
            .enumerate()
            .for_each(|(ci, out_chunk)| {
                let row0 = ci * chunk;
                let rows = out_chunk.len() / d_out;
                // Per-task scratch, reused across the chunk's rows.
                let mut qcodes = vec![0u8; cb.m];
                let mut sel = vec![0u32; l];
                let mut vals = vec![0.0f32; l];
                let mut qs = vec![0.0f32; q.cols];
                let mut buckets = topl::BucketScratch::default();
                for r in 0..rows {
                    let qi = row0 + r;
                    let qrow = q.row(qi);
                    // PQ quantize the query (integer path — exact).
                    pq::quantize_row(qrow, cb, &mut qcodes);
                    // Bucket-sort top-L against the key codes.
                    topl::select_into(
                        &qcodes,
                        &ck,
                        l,
                        causal.then_some(qi),
                        &mut sel,
                        &mut buckets,
                    );
                    // SDDMM on the scaled query, reference op order.
                    for (s, &x) in qs.iter_mut().zip(qrow) {
                        *s = x * scale;
                    }
                    for (val, &j) in vals.iter_mut().zip(sel.iter()) {
                        let krow = k.row(j as usize);
                        *val = kernel::dot(&qs, krow);
                    }
                    // Causal re-mask: padding slots may reference future
                    // keys (same as the sequential pipeline).
                    if causal {
                        for (val, &j) in vals.iter_mut().zip(sel.iter()) {
                            if j as usize > qi {
                                *val = -1e30;
                            }
                        }
                    }
                    // Row softmax, same order as `Csr::softmax_rows`.
                    let mx = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0.0;
                    for x in vals.iter_mut() {
                        *x = (*x - mx).exp();
                        sum += *x;
                    }
                    for x in vals.iter_mut() {
                        *x /= sum.max(1e-30);
                    }
                    // SpMM row, same order as `Csr::spmm` (zero-weight
                    // skip kept: the sparse operand skips whole V rows).
                    let orow = &mut out_chunk[r * d_out..(r + 1) * d_out];
                    for (p, &j) in sel.iter().enumerate() {
                        let w = vals[p];
                        if w == 0.0 {
                            continue;
                        }
                        kernel::axpy(orow, w, v.row(j as usize));
                    }
                }
            });
        out
    }
}

/// Reusable per-worker scratch for [`decode_attend_row`]: query codes,
/// the top-L selection, the scaled query, the SDDMM values, and the
/// bucket-sort storage.  Contents never affect results — a fresh and a
/// reused scratch produce identical bits.
#[derive(Debug, Default, Clone)]
pub struct DecodeScratch {
    qcodes: Vec<u8>,
    sel: Vec<u32>,
    qs: Vec<f32>,
    vals: Vec<f32>,
    buckets: topl::BucketScratch,
}

/// One (head, new-query-row) unit of cached decode: PQ-quantize the new
/// query against `cb`, bucket-sort top-`min(l, pos+1)` against the
/// cached key codes `ck`, then run the SDDMM→softmax→SpMM row kernel
/// against the cached K/V.  `l` is the *session's* sparsity strength —
/// the L of the full target sequence length, pinned per sequence (so it
/// rides alongside, not on, the shared per-layer codebooks).
///
/// Bit-identical to row `pos` of
/// [`MultiHeadSparseAttention::forward_cached`] over the full sequence
/// with the same `l`: the full forward's row-`pos` selection scans
/// future keys into the sentinel bucket (drained last, probability
/// exactly 0 after the causal re-mask, skipped by the SpMM's zero test),
/// so restricting to the `pos + 1` cached keys — with the bucket
/// capacity clamp `min(l, pos+1)`, which never truncates a bucket the
/// full capacity wouldn't — preserves the kept set, its order, and every
/// output bit.  `out` (length `v.cols`) is fully overwritten.
pub fn decode_attend_row(
    cb: &Codebooks,
    q_row: &[f32],
    k: &Matrix,
    v: &Matrix,
    ck: &Codes,
    pos: usize,
    l: usize,
    out: &mut [f32],
    scratch: &mut DecodeScratch,
) {
    assert_eq!(q_row.len(), cb.d(), "query dim mismatch");
    assert_eq!(k.rows, pos + 1, "key cache out of sync");
    assert_eq!(ck.n, pos + 1, "code cache out of sync");
    assert!(l >= 1, "need l >= 1");
    let l_eff = l.min(pos + 1);
    scratch.qcodes.resize(cb.m, 0);
    pq::quantize_row(q_row, cb, &mut scratch.qcodes);
    scratch.sel.resize(l_eff, 0);
    topl::select_into(
        &scratch.qcodes,
        ck,
        l_eff,
        Some(pos),
        &mut scratch.sel,
        &mut scratch.buckets,
    );
    scratch.qs.resize(q_row.len(), 0.0);
    scratch.vals.resize(l_eff, 0.0);
    super::attention::sparse_attend_row(
        q_row,
        k,
        v,
        &scratch.sel,
        Some(pos),
        &mut scratch.qs,
        &mut scratch.vals,
        out,
    );
}

/// Work threshold below which [`routed_ffn_auto`] stays sequential:
/// decode-sized token batches (a handful of tokens × active blocks)
/// finish faster than the rayon fan-out costs to schedule.
const ROUTED_FFN_PAR_FLOPS: usize = 1 << 16;

/// Routed-FFN entry for decode-sized batches: dispatches to the
/// sequential [`bspmv::routed_ffn`] below [`ROUTED_FFN_PAR_FLOPS`]
/// multiply-adds and to the block-parallel [`routed_ffn_par`] above it.
/// The two paths are bit-identical by construction, so the cutover never
/// changes results — only scheduling overhead.
pub fn routed_ffn_auto(x: &Matrix, w_i: &Matrix, w_o: &Matrix, routing: &Routing) -> Matrix {
    let dg = w_i.cols / routing.g;
    let flops = x.rows * routing.g_active * 4 * x.cols * dg;
    if flops < ROUTED_FFN_PAR_FLOPS {
        bspmv::routed_ffn(x, w_i, w_o, routing)
    } else {
        routed_ffn_par(x, w_i, w_o, routing)
    }
}

/// Parallel routed FFN (paper Alg. 4, block-parallel): fan out over the
/// G weight blocks — each task runs the shared
/// [`bspmv::block_partial`] kernel (gather + two block GEMMs, the
/// per-thread partial output) — then reduce the partials into `Y` in
/// ascending block order.  Same per-block ops and same scatter-add
/// order as the sequential [`bspmv::routed_ffn`], so the result is
/// bit-identical and deterministic regardless of thread schedule.
pub fn routed_ffn_par(x: &Matrix, w_i: &Matrix, w_o: &Matrix, routing: &Routing) -> Matrix {
    routing.debug_validate();
    let nt = x.rows;
    let d = x.cols;
    assert_eq!(w_i.cols % routing.g, 0);
    // Fan out: one task per block, each reusing a per-worker
    // [`bspmv::BlockScratch`] (scratch contents never affect results).
    let partials: Vec<Option<(Vec<usize>, Matrix)>> = (0..routing.g)
        .into_par_iter()
        .map_init(bspmv::BlockScratch::default, |scratch, gi| {
            bspmv::block_partial(gi, x, w_i, w_o, routing, scratch)
        })
        .collect();
    // Reduce: scatter-add partials in block order (cheap: O(active · d)).
    let mut y = Matrix::zeros(nt, d);
    for (tokens, yg) in partials.into_iter().flatten() {
        for (r, &t) in tokens.iter().enumerate() {
            for (o, &v) in y.row_mut(t).iter_mut().zip(yg.row(r)) {
                *o += v;
            }
        }
    }
    y
}

/// Parallel routed-FFN backward: fan out over the G weight blocks — each
/// task runs the shared [`bspmv::block_backward`] kernel — then reduce in
/// ascending block order, exactly mirroring the forward's
/// [`routed_ffn_par`] structure.  Bit-identical to
/// [`bspmv::routed_ffn_backward`] by construction: the per-block math is
/// the same function, the token scatter-add happens in block order, and
/// each block owns disjoint slices of dW_I / dW_O.
pub fn routed_ffn_backward_par(
    x: &Matrix,
    w_i: &Matrix,
    w_o: &Matrix,
    routing: &Routing,
    dy: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    routing.debug_validate();
    let nt = x.rows;
    let d = x.cols;
    assert_eq!(w_i.cols % routing.g, 0);
    assert_eq!(dy.rows, nt, "dY/X row mismatch");
    assert_eq!(dy.cols, d, "dY/X col mismatch");
    let dg = w_i.cols / routing.g;
    let partials: Vec<Option<(Vec<usize>, Matrix, Matrix, Matrix)>> = (0..routing.g)
        .into_par_iter()
        .map_init(bspmv::BlockScratch::default, |scratch, gi| {
            bspmv::block_backward(gi, x, w_i, w_o, routing, dy, scratch)
        })
        .collect();
    let mut dx = Matrix::zeros(nt, d);
    let mut dwi = Matrix::zeros(w_i.rows, w_i.cols);
    let mut dwo = Matrix::zeros(w_o.rows, w_o.cols);
    for (gi, partial) in partials.into_iter().enumerate() {
        if let Some((tokens, dxg, dwi_g, dwo_g)) = partial {
            bspmv::scatter_block_grads(
                &mut dx, &mut dwi, &mut dwo, gi, dg, &tokens, &dxg, &dwi_g, &dwo_g,
            );
        }
    }
    (dx, dwi, dwo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{attention, bspmv};
    use crate::util::rng::Rng;

    fn head_workload(
        hh: usize,
        n: usize,
        m: usize,
        dsub: usize,
        seed: u64,
    ) -> (Vec<Codebooks>, Vec<Matrix>, Vec<Matrix>, Vec<Matrix>) {
        let d = m * dsub;
        let mut rng = Rng::new(seed);
        let mut cbs = Vec::new();
        let (mut qs, mut ks, mut vs) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..hh {
            let mut cb = Codebooks::random(m, 8, dsub, &mut rng);
            let k = Matrix::randn(n, d, 1.0, &mut rng);
            let noise = Matrix::randn(n, d, 0.4, &mut rng);
            let q = Matrix::from_vec(
                n,
                d,
                k.data
                    .iter()
                    .zip(&noise.data)
                    .map(|(a, b)| 2.0 * a + b)
                    .collect(),
            );
            pq::codebook_update(&k.data, &mut cb, 1.0);
            cbs.push(cb);
            qs.push(q);
            ks.push(k);
            vs.push(Matrix::randn(n, d, 1.0, &mut rng));
        }
        (cbs, qs, ks, vs)
    }

    #[test]
    fn parallel_matches_sequential_reference() {
        for (causal, seed) in [(false, 1u64), (true, 2)] {
            let (cbs, q, k, v) = head_workload(3, 29, 4, 4, seed);
            let mha = MultiHeadSparseAttention::new(cbs, 7, causal);
            let par = mha.forward(&q, &k, &v);
            let seq = mha.forward_seq(&q, &k, &v);
            assert_eq!(par.len(), seq.len());
            for h in 0..par.len() {
                let diff = par[h].max_abs_diff(&seq[h]);
                assert!(diff < 1e-7, "causal={causal} head {h}: diff {diff}");
            }
        }
    }

    #[test]
    fn chunk_size_does_not_change_result() {
        let (cbs, q, k, v) = head_workload(2, 17, 2, 4, 3);
        let mut mha = MultiHeadSparseAttention::new(cbs, 5, true);
        mha.query_chunk = 1;
        let a = mha.forward(&q, &k, &v);
        mha.query_chunk = 7;
        let b = mha.forward(&q, &k, &v);
        mha.query_chunk = 10_000; // single chunk per head
        let c = mha.forward(&q, &k, &v);
        for h in 0..a.len() {
            assert_eq!(a[h], b[h], "head {h} chunk 1 vs 7");
            assert_eq!(b[h], c[h], "head {h} chunk 7 vs max");
        }
    }

    #[test]
    fn single_head_matches_attention_module() {
        let (cbs, q, k, v) = head_workload(1, 24, 2, 8, 4);
        let (want, _) =
            attention::sparse_attention(&q[0], &k[0], &v[0], &cbs[0], 6, false);
        let mha = MultiHeadSparseAttention::new(cbs, 6, false);
        let got = mha.forward(&q, &k, &v);
        assert!(got[0].max_abs_diff(&want) < 1e-7);
    }

    #[test]
    fn routed_ffn_par_matches_sequential() {
        let mut rng = Rng::new(5);
        let (nt, d, gg, dg, ga) = (33, 6, 4, 3, 2);
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, gg * dg, 0.3, &mut rng);
        let wo = Matrix::randn(gg * dg, d, 0.3, &mut rng);
        let scores = Matrix::randn(nt, gg, 1.0, &mut rng);
        let routing = bspmv::route(&scores, ga);
        let par = routed_ffn_par(&x, &wi, &wo, &routing);
        let seq = bspmv::routed_ffn(&x, &wi, &wo, &routing);
        assert!(par.max_abs_diff(&seq) < 1e-7, "{}", par.max_abs_diff(&seq));
    }

    #[test]
    fn forward_cached_matches_forward_and_seq_reference() {
        let (cbs, q, k, v) = head_workload(3, 23, 2, 4, 7);
        let mha = MultiHeadSparseAttention::new(cbs, 5, true);
        let plain = mha.forward(&q, &k, &v);
        let seq = mha.forward_seq(&q, &k, &v);
        let (cached, attn) = mha.forward_cached(&q, &k, &v);
        assert_eq!(attn.len(), 3);
        for h in 0..3 {
            // Cached = the sequential CSR pipeline, bit for bit.
            assert_eq!(seq[h], cached[h], "head {h} vs seq");
            assert!(plain[h].max_abs_diff(&cached[h]) < 1e-7, "head {h} vs par");
            assert_eq!(attn[h].rows, 23);
        }
    }

    #[test]
    fn parallel_backward_matches_sequential_reference() {
        let (cbs, q, k, v) = head_workload(3, 19, 2, 4, 8);
        let mut rng = Rng::new(80);
        let mha = MultiHeadSparseAttention::new(cbs, 6, true);
        let (ys, attn) = mha.forward_cached(&q, &k, &v);
        let dy: Vec<Matrix> = ys
            .iter()
            .map(|y| Matrix::randn(y.rows, y.cols, 1.0, &mut rng))
            .collect();
        let (dq, dk, dv) = mha.backward(&q, &k, &v, &attn, &dy);
        for h in 0..3 {
            let (eq, ek, ev) = crate::sparse::grad::sparse_attention_backward(
                &q[h], &k[h], &v[h], &attn[h], &dy[h],
            );
            assert_eq!(dq[h], eq, "head {h} dq");
            assert_eq!(dk[h], ek, "head {h} dk");
            assert_eq!(dv[h], ev, "head {h} dv");
        }
    }

    #[test]
    fn routed_ffn_backward_par_matches_sequential() {
        let mut rng = Rng::new(9);
        let (nt, d, gg, dg, ga) = (27, 5, 8, 3, 3);
        let x = Matrix::randn(nt, d, 1.0, &mut rng);
        let wi = Matrix::randn(d, gg * dg, 0.3, &mut rng);
        let wo = Matrix::randn(gg * dg, d, 0.3, &mut rng);
        let scores = Matrix::randn(nt, gg, 1.0, &mut rng);
        let dy = Matrix::randn(nt, d, 1.0, &mut rng);
        let routing = bspmv::route(&scores, ga);
        let (dx_p, dwi_p, dwo_p) = routed_ffn_backward_par(&x, &wi, &wo, &routing, &dy);
        let (dx_s, dwi_s, dwo_s) =
            bspmv::routed_ffn_backward(&x, &wi, &wo, &routing, &dy);
        assert_eq!(dx_p, dx_s);
        assert_eq!(dwi_p, dwi_s);
        assert_eq!(dwo_p, dwo_s);
    }

    #[test]
    fn decode_row_matches_forward_cached_rows_bitwise() {
        // Grow the cache one key at a time and decode each new row; the
        // outputs must equal the full-sequence forward_cached rows bit
        // for bit (self.l equals the full-sequence L here).
        let n = 21;
        let (cbs, q, k, v) = head_workload(2, n, 2, 4, 11);
        let mha = MultiHeadSparseAttention::new(cbs.clone(), 5, true);
        let (want, _) = mha.forward_cached(&q, &k, &v);
        let d = q[0].cols;
        for h in 0..2 {
            let mut scratch = DecodeScratch::default();
            let mut kc = Matrix::zeros(0, d);
            let mut vc = Matrix::zeros(0, d);
            let mut ck = Codes::zeros(0, cbs[h].m);
            let mut out = vec![0.0f32; d];
            for pos in 0..n {
                kc.rows += 1;
                kc.data.extend_from_slice(k[h].row(pos));
                vc.rows += 1;
                vc.data.extend_from_slice(v[h].row(pos));
                pq::quantize_append(k[h].row(pos), &cbs[h], &mut ck);
                decode_attend_row(
                    &cbs[h], q[h].row(pos), &kc, &vc, &ck, pos, mha.l, &mut out, &mut scratch,
                );
                assert_eq!(out.as_slice(), want[h].row(pos), "head {h} row {pos}");
            }
        }
    }

    #[test]
    fn routed_ffn_auto_matches_both_paths() {
        let mut rng = Rng::new(21);
        let (d, gg, dg) = (6, 4, 3);
        let wi = Matrix::randn(d, gg * dg, 0.3, &mut rng);
        let wo = Matrix::randn(gg * dg, d, 0.3, &mut rng);
        // A 1-token batch (sequential side of the cutover) and a large
        // batch (parallel side) must both equal the sequential reference.
        for nt in [1usize, 700] {
            let x = Matrix::randn(nt, d, 1.0, &mut rng);
            let scores = Matrix::randn(nt, gg, 1.0, &mut rng);
            let routing = bspmv::route(&scores, 2);
            let auto = routed_ffn_auto(&x, &wi, &wo, &routing);
            let seq = bspmv::routed_ffn(&x, &wi, &wo, &routing);
            assert_eq!(auto, seq, "nt={nt}");
        }
    }

    #[test]
    fn dedicated_pool_gives_same_answer() {
        // The parallel path must be schedule-independent: a 1-thread pool
        // and the default pool produce identical bits.
        let (cbs, q, k, v) = head_workload(2, 21, 2, 4, 6);
        let mha = MultiHeadSparseAttention::new(cbs, 4, false);
        let default_pool = mha.forward(&q, &k, &v);
        let pool = rayon::ThreadPoolBuilder::new()
            .num_threads(1)
            .build()
            .expect("pool");
        let single = pool.install(|| mha.forward(&q, &k, &v));
        for h in 0..default_pool.len() {
            assert_eq!(default_pool[h], single[h], "head {h}");
        }
    }
}
