//! Paper Table 3: end-to-end fine-tuning — quality (MMLU surrogate),
//! max sequence length before OOM, and wall time/speedup, for
//! Full / LoRA / SPT.
//!
//! Paper (OPT-2.7B / LLaMA-2.7B on 4x RTX 3090): SPT 1.39-1.47x over
//! Full, 2x max length vs Full, ~1 point MMLU drop.
//!
//! Default build (no artifacts needed): the analytic max-length table at
//! the paper's scale, the substrate end-to-end block forward
//! (multi-head sparse attention + routed FFN) with a thread-scaling
//! column against the sequential reference path, and the native-backend
//! fine-tune step (forward + backward + AdamW) across full/LoRA/SPT
//! modes with the same thread-scaling treatment.  With `--features xla`
//! the original artifact-driven training comparison also runs.

mod common;

use std::collections::BTreeMap;

use spt::config::{presets, Mode, RunConfig};
use spt::coordinator::{Backend, NativeBackend};
use spt::data::SyntheticCorpus;
use spt::memmodel;
use spt::metrics::Table;
use spt::util::fmt_duration;
use spt::util::json::Json;

fn main() {
    max_length_table();
    thread_scaling_table();
    fine_tune_step_table();
    #[cfg(feature = "xla")]
    engine_table();
}

/// Max length at the paper's scale (OPT-2.7B-like block, 32 layers,
/// 24 GB/GPU, DeepSpeed offloading modeled) — engine-free.
fn max_length_table() {
    let paper_cfg = presets::block("opt-2560").expect("cfg");
    let mut table = Table::new(
        "Table 3a — max sequence length before OOM (opt-2560, 32L, 24 GB)",
        &["System", "Max Length (model)", "paper"],
    );
    let paper = [("full", "256"), ("lora", "512"), ("spt", "768")];
    for mode in Mode::ALL {
        let max_len = memmodel::max_seq_under_budget(
            &paper_cfg,
            mode,
            16,
            32,
            50272,
            24u64 << 30,
            128,
        );
        table.row(&[
            mode.as_str().to_string(),
            max_len.to_string(),
            paper
                .iter()
                .find(|(m, _)| *m == mode.as_str())
                .map(|(_, p)| p.to_string())
                .unwrap_or_default(),
        ]);
    }
    common::emit("table3_max_length", &table);
}

/// Substrate end-to-end forward (H-head sparse MHA + routed FFN): the
/// sequential reference vs the rayon path across thread counts.
fn thread_scaling_table() {
    let wl = common::native_workload(8, 384, 64, 96, 1024, 2048, 8, 4);
    common::emit_thread_scaling(
        &wl,
        "Table 3b — substrate e2e forward thread scaling \
         (8 heads, n=384, L=96 + routed FFN beta=1/2)",
        "table3_thread_scaling",
    );
}

/// Native-backend fine-tune step (fwd + bwd + AdamW) per mode, with the
/// thread-scaling treatment: dedicated rayon pools sized per
/// [`common::thread_counts`], one step per sample.  Besides the rendered
/// table, emits machine-readable `bench_out/BENCH_table3_native.json`
/// (mode × threads × ms/step) so the perf trajectory is tracked across
/// PRs.
fn fine_tune_step_table() {
    // spt-nano keeps the default run fast; the perf-tracking targets are
    // SPT_TABLE3_NATIVE_MODEL=spt-mini-64 (GEMM-bound, same block) and
    // spt-mini-64-l4 (the same block stacked 4 layers deep — the
    // multi-layer train-step path), and spt-tiny measures at the
    // paper-surrogate scale.
    let model = std::env::var("SPT_TABLE3_NATIVE_MODEL")
        .unwrap_or_else(|_| "spt-nano".into());
    let backend = NativeBackend::new();
    let (w, s) = (common::warmup().max(1), common::samples().max(2));
    let mut table = Table::new(
        &format!(
            "Table 3c — native fine-tune step, {model} (full vs LoRA vs SPT, s/step)"
        ),
        &["Threads", "full", "lora", "spt", "spt vs full"],
    );
    let mut json_entries: Vec<Json> = Vec::new();
    for t in common::thread_counts() {
        let pool = common::pool(t);
        let mut cells = vec![t.to_string()];
        let mut full_median = None;
        let mut spt_median = None;
        for mode in Mode::ALL {
            let rc = RunConfig {
                model: model.clone(),
                mode,
                eval_every: 0,
                codebook_refresh_every: 0,
                ..RunConfig::default()
            };
            let (batch, seq) = backend.workload(&rc).expect("workload");
            let vocab = backend.vocab(&rc).expect("vocab");
            let mut corpus = SyntheticCorpus::new(vocab, 4, 0.85, 0);
            let mut tokens = Vec::with_capacity(batch * seq);
            let mut targets = Vec::with_capacity(batch * seq);
            for _ in 0..batch {
                let (x, y) = corpus.lm_pair(seq);
                tokens.extend(x.iter().map(|&v| v as i32));
                targets.extend(y.iter().map(|&v| v as i32));
            }
            let mut state = backend.init_state(&rc).expect("init");
            let r = spt::metrics::bench(
                &format!("step_{}_{t}", mode.as_str()),
                w,
                s,
                || {
                    pool.install(|| {
                        std::hint::black_box(
                            backend
                                .train_step(&rc, &mut state, &tokens, &targets)
                                .expect("train step"),
                        );
                    });
                },
            );
            let median = r.median();
            if mode == Mode::Full {
                full_median = Some(median);
            }
            if mode == Mode::Spt {
                spt_median = Some(median);
            }
            cells.push(fmt_duration(median));
            let mut e = BTreeMap::new();
            e.insert("mode".to_string(), Json::Str(mode.as_str().to_string()));
            e.insert("threads".to_string(), Json::Num(t as f64));
            e.insert("ms_per_step".to_string(), Json::Num(median * 1e3));
            json_entries.push(Json::Obj(e));
        }
        cells.push(match (full_median, spt_median) {
            (Some(f), Some(sp)) => format!("{:.2}x", f / sp),
            _ => String::new(),
        });
        table.row(&cells);
    }
    common::emit("table3_native_step", &table);
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("table3_native_step".to_string()));
    top.insert("model".to_string(), Json::Str(model));
    top.insert("warmup".to_string(), Json::Num(w as f64));
    top.insert("samples".to_string(), Json::Num(s as f64));
    top.insert("entries".to_string(), Json::Arr(json_entries));
    common::emit_json("BENCH_table3_native", &Json::Obj(top));
}

/// The original artifact-driven end-to-end comparison (QA surrogate
/// accuracy + measured step time), behind the `xla` feature.
#[cfg(feature = "xla")]
fn engine_table() {
    use spt::coordinator::{PjrtBackend, Trainer, TrainerOptions};

    let Some(engine) = common::engine_or_skip("table3") else { return };
    let backend = PjrtBackend::new(&engine);
    let model = std::env::var("SPT_TABLE3_MODEL").unwrap_or_else(|_| "spt-tiny".into());
    let steps: usize = std::env::var("SPT_TABLE3_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);

    let paper_cfg = presets::block("opt-2560").expect("cfg");
    let mut table = Table::new(
        &format!("Table 3 — end-to-end fine-tuning ({model}, {steps} steps; max-length @opt-2560/32L/24GB)"),
        &["System", "QA acc (MMLU surrogate)", "Max Length (model)", "Time", "speedup", "paper"],
    );
    let paper = [
        ("full", "27.0 MMLU, 256, 6.7 h (1.00x)"),
        ("lora", "27.0 MMLU, 512, 5.8 h (1.15x)"),
        ("spt", "26.1 MMLU, 768, 4.6 h (1.47x)"),
    ];
    let mut full_time = None;
    for mode in Mode::ALL {
        let name = format!("train_step_{model}_{}", mode.as_str());
        if engine.manifest().get(&name).is_err() {
            println!("[table3] missing {name}");
            continue;
        }
        let rc = RunConfig {
            model: model.clone(),
            mode,
            steps,
            eval_every: 0,
            artifacts_dir: common::artifacts_dir(),
            ..RunConfig::default()
        };
        let mut trainer = Trainer::new(&backend, rc, TrainerOptions::default());
        let report = trainer.train_qa().expect("train-qa");
        if mode == Mode::Full {
            full_time = Some(report.total_secs);
        }
        let max_len = memmodel::max_seq_under_budget(
            &paper_cfg, mode, 16, 32, 50272, 24u64 << 30, 128,
        );
        table.row(&[
            mode.as_str().to_string(),
            format!("{:.1}%", report.qa_accuracy.unwrap_or(f32::NAN) * 100.0),
            max_len.to_string(),
            fmt_duration(report.total_secs),
            full_time
                .map(|f| format!("{:.2}x", f / report.total_secs))
                .unwrap_or_default(),
            paper
                .iter()
                .find(|(m, _)| *m == mode.as_str())
                .map(|(_, p)| p.to_string())
                .unwrap_or_default(),
        ]);
    }
    common::emit("table3_end_to_end", &table);
}
