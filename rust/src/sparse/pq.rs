//! Product quantization (paper §4.1, Alg. 2) — rust-native substrate.
//!
//! Mirrors `python/compile/kernels/pq.py`: per-subspace nearest codeword
//! under squared L2, plus a k-means-style (DKM-flavoured) codebook refresh.

use super::codes::Codes;
use crate::util::rng::Rng;

/// PQ codebooks: `m` subspaces × `e` codewords × `dsub` dims.
#[derive(Debug, Clone)]
pub struct Codebooks {
    pub m: usize,
    pub e: usize,
    pub dsub: usize,
    /// `[m * e * dsub]`, codeword (mi, ei) at `((mi * e) + ei) * dsub ..`.
    pub data: Vec<f32>,
}

impl Codebooks {
    pub fn random(m: usize, e: usize, dsub: usize, rng: &mut Rng) -> Self {
        let data = rng.normal_vec(m * e * dsub);
        Codebooks { m, e, dsub, data }
    }

    #[inline]
    pub fn codeword(&self, mi: usize, ei: usize) -> &[f32] {
        let off = (mi * self.e + ei) * self.dsub;
        &self.data[off..off + self.dsub]
    }

    pub fn d(&self) -> usize {
        self.m * self.dsub
    }
}

/// Quantize `n` vectors of dim `m * dsub` into a flat [`Codes`] matrix.
pub fn quantize(x: &[f32], cb: &Codebooks) -> Codes {
    let d = cb.d();
    assert_eq!(x.len() % d, 0, "input not a multiple of d");
    let n = x.len() / d;
    let mut codes = Codes::zeros(n, cb.m);
    for (i, code_row) in codes.data.chunks_exact_mut(cb.m).enumerate() {
        quantize_row(&x[i * d..(i + 1) * d], cb, code_row);
    }
    codes.debug_validate(cb.e);
    codes
}

/// Quantize one vector into a preallocated `m`-wide code row — the unit
/// of work the parallel multi-head path dispatches per query.
pub fn quantize_row(v: &[f32], cb: &Codebooks, out: &mut [u8]) {
    debug_assert_eq!(v.len(), cb.d());
    debug_assert_eq!(out.len(), cb.m);
    for mi in 0..cb.m {
        let sub = &v[mi * cb.dsub..(mi + 1) * cb.dsub];
        let mut best = f32::INFINITY;
        let mut best_e = 0usize;
        for ei in 0..cb.e {
            let cw = cb.codeword(mi, ei);
            let mut dist = 0.0;
            for (a, b) in sub.iter().zip(cw) {
                let diff = a - b;
                dist += diff * diff;
            }
            if dist < best {
                best = dist;
                best_e = ei;
            }
        }
        out[mi] = best_e as u8;
    }
}

/// Append the codes of `x` (one or more `d`-dim vectors) to an existing
/// flat code matrix — the decode cache's incremental path.  Quantization
/// is row-independent, so the grown matrix is bit-identical to a fresh
/// [`quantize`] over the concatenated inputs.
pub fn quantize_append(x: &[f32], cb: &Codebooks, codes: &mut Codes) {
    let d = cb.d();
    assert_eq!(x.len() % d, 0, "input not a multiple of d");
    assert_eq!(codes.m, cb.m, "code width mismatch");
    let n_new = x.len() / d;
    let start = codes.n;
    codes.n += n_new;
    codes.data.resize(codes.n * codes.m, 0);
    for i in 0..n_new {
        quantize_row(&x[i * d..(i + 1) * d], cb, codes.row_mut(start + i));
    }
    codes.debug_validate(cb.e);
}

/// Mean squared quantization error (per dimension) — the DKM signal.
pub fn quantize_error(x: &[f32], cb: &Codebooks) -> f32 {
    let d = cb.d();
    let n = x.len() / d;
    if n == 0 {
        return 0.0;
    }
    let codes = quantize(x, cb);
    let mut total = 0.0f64;
    for i in 0..n {
        let v = &x[i * d..(i + 1) * d];
        for mi in 0..cb.m {
            let sub = &v[mi * cb.dsub..(mi + 1) * cb.dsub];
            let cw = cb.codeword(mi, codes.row(i)[mi] as usize);
            for (a, b) in sub.iter().zip(cw) {
                total += ((a - b) * (a - b)) as f64;
            }
        }
    }
    (total / (n * cb.m * cb.dsub) as f64) as f32
}

/// One k-means refresh step: move each codeword toward the mean of its
/// assigned sub-vectors (paper §5.1: run every ~20 mini-batches).
pub fn codebook_update(x: &[f32], cb: &mut Codebooks, lr: f32) {
    let d = cb.d();
    let n = x.len() / d;
    let codes = quantize(x, cb);
    let mut sums = vec![0.0f32; cb.m * cb.e * cb.dsub];
    let mut counts = vec![0u32; cb.m * cb.e];
    for i in 0..n {
        let v = &x[i * d..(i + 1) * d];
        for mi in 0..cb.m {
            let ei = codes.row(i)[mi] as usize;
            counts[mi * cb.e + ei] += 1;
            let off = (mi * cb.e + ei) * cb.dsub;
            for (k, val) in v[mi * cb.dsub..(mi + 1) * cb.dsub].iter().enumerate() {
                sums[off + k] += val;
            }
        }
    }
    for mi in 0..cb.m {
        for ei in 0..cb.e {
            let cnt = counts[mi * cb.e + ei];
            if cnt == 0 {
                continue; // empty codewords stay put
            }
            let off = (mi * cb.e + ei) * cb.dsub;
            for k in 0..cb.dsub {
                let mean = sums[off + k] / cnt as f32;
                cb.data[off + k] += lr * (mean - cb.data[off + k]);
            }
        }
    }
}

/// Integer similarity (paper Eq. 6): number of matching codewords.
#[inline]
pub fn match_score(a: &[u8], b: &[u8]) -> u32 {
    a.iter().zip(b).map(|(x, y)| u32::from(x == y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn codeword_quantizes_to_itself() {
        let mut rng = Rng::new(1);
        let cb = Codebooks::random(4, 8, 8, &mut rng);
        // Build a vector equal to codeword 3 in every subspace.
        let mut v = Vec::new();
        for mi in 0..4 {
            v.extend_from_slice(cb.codeword(mi, 3));
        }
        let codes = quantize(&v, &cb);
        assert_eq!(codes.row(0), &[3u8; 4]);
        assert!(quantize_error(&v, &cb) < 1e-10);
    }

    #[test]
    fn update_reduces_error() {
        let mut rng = Rng::new(2);
        let mut cb = Codebooks::random(2, 4, 4, &mut rng);
        let x = rng.normal_vec(64 * cb.d());
        let e0 = quantize_error(&x, &cb);
        for _ in 0..5 {
            codebook_update(&x, &mut cb, 1.0);
        }
        let e1 = quantize_error(&x, &cb);
        assert!(e1 < e0, "{e1} !< {e0}");
    }

    #[test]
    fn quantize_append_matches_batch_quantize() {
        check(25, |g| {
            let m = g.usize_in(1, 6);
            let e = g.usize_in(2, 8);
            let dsub = g.usize_in(1, 6);
            let n0 = g.usize_in(0, 12);
            let n1 = g.usize_in(1, 12);
            let mut rng = g.rng().fork();
            let cb = Codebooks::random(m, e, dsub, &mut rng);
            let x0 = rng.normal_vec(n0 * cb.d());
            let x1 = rng.normal_vec(n1 * cb.d());
            let mut grown = quantize(&x0, &cb);
            quantize_append(&x1, &cb, &mut grown);
            let mut all = x0.clone();
            all.extend_from_slice(&x1);
            prop_assert(grown == quantize(&all, &cb), "append != batch")
        });
    }

    #[test]
    fn match_score_counts() {
        assert_eq!(match_score(&[1, 2, 3], &[1, 5, 3]), 2);
        assert_eq!(match_score(&[0; 8], &[0; 8]), 8);
        assert_eq!(match_score(&[1, 2], &[3, 4]), 0);
    }

    #[test]
    fn prop_codes_in_range_and_deterministic() {
        check(30, |g| {
            let m = g.usize_in(1, 8);
            let e = g.usize_in(2, 16);
            let dsub = g.usize_in(1, 8);
            let n = g.usize_in(1, 32);
            let mut rng = g.rng().fork();
            let cb = Codebooks::random(m, e, dsub, &mut rng);
            let x = rng.normal_vec(n * cb.d());
            let c1 = quantize(&x, &cb);
            let c2 = quantize(&x, &cb);
            prop_assert(c1 == c2, "non-deterministic")?;
            prop_assert((c1.n, c1.m) == (n, m), "wrong code shape")?;
            prop_assert(
                c1.data.iter().all(|&c| (c as usize) < e),
                "code out of range",
            )
        });
    }

    #[test]
    fn prop_empty_codewords_stay_fixed() {
        check(20, |g| {
            let mut rng = g.rng().fork();
            let mut cb = Codebooks::random(1, 4, 2, &mut rng);
            // Data glued to codeword 0's location: far codewords never chosen.
            let far: Vec<f32> = cb.codeword(0, 0).to_vec();
            let x: Vec<f32> = (0..16).flat_map(|_| far.clone()).collect();
            let before = cb.data.clone();
            let codes = quantize(&x, &cb);
            let used = codes.row(0)[0] as usize;
            codebook_update(&x, &mut cb, 1.0);
            for ei in 0..4 {
                let off = ei * 2;
                let same = cb.data[off..off + 2] == before[off..off + 2];
                if ei != used {
                    prop_assert(same, format!("unused codeword {ei} moved"))?;
                }
            }
            Ok(())
        });
    }
}
