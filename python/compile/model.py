"""L2: SPT Transformer model in JAX — full / LoRA / SPT tuning modes.

This is the paper's "Model Adapter" (§3) expressed functionally: a
Transformer block (Fig. 1) whose MHA and FFN can be swapped for the sparse
MHA (§4.1) and routed FFN (§4.2), with LoRA adapters (Eq. 5) inserted on
every projection.  All hot-spot compute calls the L1 Pallas kernels in
``compile.kernels``; this module is lowered once by ``aot.py`` to HLO text
and executed from the rust coordinator — Python is never on the training
path.

Three tuning modes (matching the paper's baselines):

* ``full`` — dense MHA + dense FFN, every base parameter trainable.
* ``lora`` — dense MHA + dense FFN, base frozen, LoRA B/C trainable.
* ``spt``  — LoRA + sparse MHA (PQ top-L) + routed FFN; trainables are the
  LoRA matrices and the router; PQ codebooks are updated out-of-band by the
  DKM refresh artifact (paper §5.1: every ~20 mini-batches), not by SGD.

Parameters are nested dicts; ``jax.tree_util`` flattening (sorted keys)
gives the canonical leaf order recorded in the AOT manifest and consumed by
``rust/src/runtime``.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import pq, routed_ffn, sparse_attn, topl

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One Transformer block configuration (paper Table 2)."""

    name: str
    d_model: int
    d_head: int
    d_ffn: int
    activation: str = "relu"  # "relu" (OPT) | "gelu" (LLaMA)
    rotary: bool = False  # rotary position embedding (LLaMA)
    # --- tuning hyper-parameters ---
    lora_rank: int = 16  # paper's d_lora default
    # sparse MHA: keep top (n * mha_topl_num / mha_topl_den) keys per query
    mha_topl_num: int = 1
    mha_topl_den: int = 8  # paper default 1/8
    pq_dsub: int = 8  # codeword dim d' (paper §5.1)
    pq_codewords: int = 16  # E (paper §5.1)
    # routed FFN: activate ffn_active_num/ffn_active_den of G groups
    ffn_groups: int = 8  # G (paper: 4 or 8)
    ffn_active_num: int = 1
    ffn_active_den: int = 2  # paper default 1/2
    ffn_capacity_factor: float = 1.25

    @property
    def n_heads(self) -> int:
        assert self.d_model % self.d_head == 0
        return self.d_model // self.d_head

    @property
    def pq_m(self) -> int:
        assert self.d_head % self.pq_dsub == 0
        return self.d_head // self.pq_dsub

    @property
    def ffn_active(self) -> int:
        g = self.ffn_groups * self.ffn_active_num // self.ffn_active_den
        return max(1, g)

    def topl(self, n: int) -> int:
        return max(1, n * self.mha_topl_num // self.mha_topl_den)

    def with_sparsity(
        self,
        mha_num: int | None = None,
        mha_den: int | None = None,
        ffn_num: int | None = None,
        ffn_den: int | None = None,
    ) -> "BlockConfig":
        """Derive a config with different sparsity strengths (paper §6.3)."""
        return dataclasses.replace(
            self,
            mha_topl_num=mha_num if mha_num is not None else self.mha_topl_num,
            mha_topl_den=mha_den if mha_den is not None else self.mha_topl_den,
            ffn_active_num=ffn_num if ffn_num is not None else self.ffn_active_num,
            ffn_active_den=ffn_den if ffn_den is not None else self.ffn_active_den,
        )


# Paper Table 2: the five evaluated Transformer block shapes, plus
# scaled-down shapes for CPU-budget profiling and the e2e model.
BLOCK_CONFIGS: dict[str, BlockConfig] = {
    c.name: c
    for c in [
        BlockConfig("opt-1024", 1024, 64, 4096, "relu"),
        BlockConfig("opt-2048", 2048, 64, 8192, "relu"),
        BlockConfig("opt-2560", 2560, 80, 10240, "relu"),
        BlockConfig("llama-2560", 2560, 128, 6912, "gelu", rotary=True),
        BlockConfig("llama-4096", 4096, 128, 11008, "gelu", rotary=True),
        BlockConfig("gpt-768", 768, 64, 3072, "relu"),
        BlockConfig("mini-512", 512, 64, 2048, "relu"),
        BlockConfig("mini-256", 256, 32, 1024, "relu"),
    ]
}

MODES = ("full", "lora", "spt")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Full LM: embedding + N blocks + head (for end-to-end fine-tuning)."""

    name: str
    block: BlockConfig
    n_layers: int
    vocab_size: int
    max_seq: int = 512

    def param_count(self) -> int:
        b = self.block
        per_block = 4 * b.d_model * b.d_model + 2 * b.d_model * b.d_ffn
        return self.n_layers * per_block + 2 * self.vocab_size * b.d_model


MODEL_CONFIGS: dict[str, ModelConfig] = {
    m.name: m
    for m in [
        # ~106M parameters: the end-to-end validation model (EXPERIMENTS.md).
        ModelConfig("spt-100m", BLOCK_CONFIGS["gpt-768"], 12, 16384, 512),
        # ~34M: budget-friendly e2e default on CPU-PJRT.
        ModelConfig("spt-30m", BLOCK_CONFIGS["mini-512"], 8, 8192, 256),
        # ~5M: integration tests / smoke runs.
        ModelConfig("spt-tiny", BLOCK_CONFIGS["mini-256"], 4, 4096, 128),
    ]
}


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _dense_init(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale


def init_block_params(key: jax.Array, cfg: BlockConfig, mode: str) -> Params:
    """Initialize one Transformer block for the given tuning mode."""
    assert mode in MODES
    d, dffn, r = cfg.d_model, cfg.d_ffn, cfg.lora_rank
    ks = iter(jax.random.split(key, 32))
    p: Params = {
        "ln1_scale": jnp.ones((d,), jnp.float32),
        "ln1_bias": jnp.zeros((d,), jnp.float32),
        "ln2_scale": jnp.ones((d,), jnp.float32),
        "ln2_bias": jnp.zeros((d,), jnp.float32),
        "wq": _dense_init(next(ks), d, d),
        "wk": _dense_init(next(ks), d, d),
        "wv": _dense_init(next(ks), d, d),
        "wo": _dense_init(next(ks), d, d),
        "w_in": _dense_init(next(ks), d, dffn),
        "b_in": jnp.zeros((dffn,), jnp.float32),
        "w_out": _dense_init(next(ks), dffn, d),
        "b_out": jnp.zeros((d,), jnp.float32),
    }
    if mode in ("lora", "spt"):
        # LoRA: B ~ N(0, 1/d_in), C = 0 (delta starts at zero — Eq. 5).
        for nm, d_in, d_out in [
            ("q", d, d), ("k", d, d), ("v", d, d), ("o", d, d),
            ("in", d, dffn), ("out", dffn, d),
        ]:
            p[f"lora_{nm}_b"] = _dense_init(next(ks), d_in, r)
            p[f"lora_{nm}_c"] = jnp.zeros((r, d_out), jnp.float32)
    if mode == "spt":
        m, e, dsub = cfg.pq_m, cfg.pq_codewords, cfg.pq_dsub
        p["pq_q"] = pq.init_codebooks(next(ks), m, e, dsub)
        p["pq_k"] = pq.init_codebooks(next(ks), m, e, dsub)
        p["w_router"] = _dense_init(next(ks), d, cfg.ffn_groups)
    return p


def init_model_params(key: jax.Array, mc: ModelConfig, mode: str) -> Params:
    """Initialize the full LM. Blocks are stacked along a leading layer axis
    (consumed by ``lax.scan``)."""
    kemb, khead, kpos, kblocks = jax.random.split(key, 4)
    blocks = jax.vmap(
        lambda k: init_block_params(k, mc.block, mode)
    )(jax.random.split(kblocks, mc.n_layers))
    return {
        "embed": _dense_init(kemb, mc.vocab_size, mc.block.d_model, 0.02),
        "pos": _dense_init(kpos, mc.max_seq, mc.block.d_model, 0.02),
        "head": _dense_init(khead, mc.block.d_model, mc.vocab_size),
        "lnf_scale": jnp.ones((mc.block.d_model,), jnp.float32),
        "lnf_bias": jnp.zeros((mc.block.d_model,), jnp.float32),
        "blocks": blocks,
    }


def trainable_mask(params: Params, mode: str) -> Params:
    """Pytree of bools: which leaves the optimizer updates.

    full: everything except PQ codebooks (absent anyway).
    lora: only lora_* leaves.
    spt:  lora_* + router; codebooks move via the DKM artifact instead.
    """

    def mask_entry(path: tuple, _leaf) -> bool:
        keys = [getattr(q, "key", None) for q in path]
        name = next(
            (k for k in keys if isinstance(k, str) and k != "blocks"), ""
        )
        if mode == "full":
            return not name.startswith("pq_") and name != "w_router"
        if name.startswith("lora_"):
            return True
        if mode == "spt" and name == "w_router":
            return True
        return False

    return jax.tree_util.tree_map_with_path(mask_entry, params)


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * scale + bias


def _proj(p: Params, nm: str, x: jax.Array, mode: str) -> jax.Array:
    """Projection with optional LoRA adapter: x @ (W + B C) (Eq. 5)."""
    w = {
        "q": "wq", "k": "wk", "v": "wv", "o": "wo",
        "in": "w_in", "out": "w_out",
    }[nm]
    y = x @ p[w]
    if mode in ("lora", "spt"):
        y = y + (x @ p[f"lora_{nm}_b"]) @ p[f"lora_{nm}_c"]
    return y


def _rotary(x: jax.Array) -> jax.Array:
    """Rotary position embedding over [b, n, d_head] heads-folded input."""
    bh, n, d = x.shape
    half = d // 2
    freqs = 1.0 / (10000 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    t = jnp.arange(n, dtype=jnp.float32)
    ang = jnp.einsum("n,f->nf", t, freqs)  # [n, half]
    cos, sin = jnp.cos(ang)[None], jnp.sin(ang)[None]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _select_topl_indices(q, k, cb_q, cb_k, l, causal):
    """PQ quantization + bucket-sort selection, hidden from autodiff.

    The selection path is pure integer compute (paper: 'both computing and
    ranking the scores involve only integers') and has no gradient; wrapping
    it in a custom_vjp keeps jax.grad from trying to linearize the interpret-
    mode pallas_calls inside.
    """
    cq = pq.pq_quantize(q, cb_q)
    ck = pq.pq_quantize(k, cb_k)
    return topl.topl_select(cq, ck, l, causal=causal)


def _select_fwd(q, k, cb_q, cb_k, l, causal):
    idx = _select_topl_indices(q, k, cb_q, cb_k, l, causal)
    return idx, (q, k, cb_q, cb_k)


def _select_bwd(l, causal, res, _g):
    # Pure integer selection: zero cotangents (residuals are DCE'd by XLA).
    return tuple(jnp.zeros_like(r) for r in res)


_select_topl_indices.defvjp(_select_fwd, _select_bwd)


def mha(
    p: Params,
    x: jax.Array,
    cfg: BlockConfig,
    mode: str,
    causal: bool = True,
) -> jax.Array:
    """Multi-head attention; ``spt`` mode runs the sparse pipeline (Alg. 1).

    x: [batch, n, d_model] -> [batch, n, d_model]
    """
    bsz, n, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    def split(t):  # [b, n, d] -> [b*h, n, dh]
        return (
            t.reshape(bsz, n, h, dh)
            .transpose(0, 2, 1, 3)
            .reshape(bsz * h, n, dh)
        )

    q = split(_proj(p, "q", x, mode))
    k = split(_proj(p, "k", x, mode))
    v = split(_proj(p, "v", x, mode))
    if cfg.rotary:
        q, k = _rotary(q), _rotary(k)

    if mode == "spt":
        # Alg. 1: quantize -> bucket-sort top-L -> SDDMM/softmax/SpMM.
        l = cfg.topl(n)
        idx = _select_topl_indices(q, k, p["pq_q"], p["pq_k"], l, causal)
        y = sparse_attn.sparse_attention(q, k, v, idx, causal, None)
    else:
        scale = 1.0 / math.sqrt(dh)
        logits = jnp.einsum("bnd,bmd->bnm", q, k) * scale
        if causal:
            mask = jnp.tril(jnp.ones((n, n), dtype=bool))
            logits = jnp.where(mask[None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        y = jnp.einsum("bnm,bmd->bnd", w, v)

    y = (
        y.reshape(bsz, h, n, dh).transpose(0, 2, 1, 3).reshape(bsz, n, d)
    )
    return _proj(p, "o", y, mode)


def ffn(
    p: Params, x: jax.Array, cfg: BlockConfig, mode: str
) -> tuple[jax.Array, jax.Array | None]:
    """FFN; ``spt`` mode routes tokens through G' of G blocks (Alg. 4).

    Returns (y, router_scores-or-None); scores feed the LB loss.
    """
    bsz, n, d = x.shape
    if mode == "spt":
        xt = x.reshape(bsz * n, d)
        # The BSpMV kernel consumes the *merged* blocked weight (W + BC) so
        # the routed GEMMs still carry the LoRA adaptation.
        w_in = p["w_in"] + p["lora_in_b"] @ p["lora_in_c"]
        w_out = p["w_out"] + p["lora_out_b"] @ p["lora_out_c"]
        y, scores = routed_ffn.routed_ffn(
            xt,
            w_in,
            w_out,
            p["w_router"],
            cfg.ffn_active,
            capacity_factor=cfg.ffn_capacity_factor,
        )
        y = y + p["b_out"]  # output bias applies outside the routed blocks
        return y.reshape(bsz, n, d), scores
    h = _proj(p, "in", x, mode) + p["b_in"]
    h = jax.nn.relu(h) if cfg.activation == "relu" else jax.nn.gelu(h)
    y = _proj(p, "out", h, mode) + p["b_out"]
    return y, None


def block_forward(
    p: Params,
    x: jax.Array,
    cfg: BlockConfig,
    mode: str,
    causal: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Pre-LN Transformer block (Fig. 1). Returns (y, lb_loss)."""
    a = mha(p, layer_norm(x, p["ln1_scale"], p["ln1_bias"]), cfg, mode, causal)
    x = x + a
    f, scores = ffn(p, layer_norm(x, p["ln2_scale"], p["ln2_bias"]), cfg, mode)
    lb = (
        routed_ffn.load_balance_loss(scores, cfg.ffn_active)
        if scores is not None
        else jnp.zeros((), jnp.float32)
    )
    return x + f, lb


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def model_forward(
    params: Params,
    tokens: jax.Array,
    mc: ModelConfig,
    mode: str,
) -> tuple[jax.Array, jax.Array]:
    """tokens [b, n] int32 -> (logits [b, n, V], mean lb loss)."""
    b, n = tokens.shape
    x = params["embed"][tokens] + params["pos"][:n][None]

    def body(carry, layer_p):
        xc, lb = carry
        xc, lb_i = block_forward(layer_p, xc, mc.block, mode, causal=True)
        return (xc, lb + lb_i), None

    (x, lb), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    x = layer_norm(x, params["lnf_scale"], params["lnf_bias"])
    logits = x @ params["head"]
    return logits, lb / mc.n_layers


def lm_loss(
    params: Params,
    tokens: jax.Array,
    targets: jax.Array,
    mc: ModelConfig,
    mode: str,
    lb_weight: float = 0.01,
) -> jax.Array:
    """Next-token cross entropy + load-balancing auxiliary (paper §4.2)."""
    logits, lb = model_forward(params, tokens, mc, mode)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + lb_weight * lb
