//! Dense row-major f32 matrix — the substrate's working representation.
//!
//! `matmul` parallelizes over output rows once the product is large
//! enough to amortize the fork: every output row is produced by the same
//! per-row operation order as the sequential loop, so results are
//! bit-identical at any thread count (the property all substrate
//! parallelism maintains).

use rayon::prelude::*;

use crate::util::rng::Rng;

/// Below this many multiply-adds `matmul` stays sequential (forking the
/// rayon pool costs more than the product itself).
const PAR_MATMUL_FLOPS: usize = 1 << 16;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal() * scale).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self @ other` — naive GEMM, row-parallel above
    /// [`PAR_MATMUL_FLOPS`].  Per-row operation order is identical on
    /// both paths, so the output is the same bits either way.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        if out.cols == 0 {
            return out;
        }
        if self.rows * self.cols * other.cols >= PAR_MATMUL_FLOPS {
            out.data
                .par_chunks_mut(other.cols)
                .enumerate()
                .for_each(|(i, out_row)| {
                    Self::matmul_row(self.row(i), other, out_row);
                });
        } else {
            for i in 0..self.rows {
                Self::matmul_row(self.row(i), other, out.row_mut(i));
            }
        }
        out
    }

    /// One output row of `matmul`: `out_row += a_row @ other`.
    #[inline]
    fn matmul_row(a_row: &[f32], other: &Matrix, out_row: &mut [f32]) {
        for (k, &a) in a_row.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let b_row = other.row(k);
            for (o, &b) in out_row.iter_mut().zip(b_row) {
                *o += a * b;
            }
        }
    }

    /// Elementwise sum (residual connections in the native model).
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "add shape mismatch");
        assert_eq!(self.cols, other.cols, "add shape mismatch");
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise in-place accumulate.
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.rows, other.rows, "add_assign shape mismatch");
        assert_eq!(self.cols, other.cols, "add_assign shape mismatch");
        for (o, &b) in self.data.iter_mut().zip(&other.data) {
            *o += b;
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn relu(&self) -> Matrix {
        self.map(|x| x.max(0.0))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Row-wise softmax (dense attention baseline).
    pub fn softmax_rows(&self) -> Matrix {
        let mut out = self.clone();
        for r in 0..self.rows {
            let row = out.row_mut(r);
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for x in row.iter_mut() {
                *x = (*x - mx).exp();
                sum += *x;
            }
            for x in row.iter_mut() {
                *x /= sum.max(1e-30);
            }
        }
        out
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut eye = Matrix::zeros(4, 4);
        for i in 0..4 {
            *eye.at_mut(i, i) = 1.0;
        }
        assert!(a.matmul(&eye).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(3, 5, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(6, 9, 2.0, &mut rng);
        let s = a.softmax_rows();
        for r in 0..6 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_clamps() {
        let a = Matrix::from_vec(1, 3, vec![-1.0, 0.0, 2.0]);
        assert_eq!(a.relu().data, vec![0.0, 0.0, 2.0]);
    }

    #[test]
    fn add_and_add_assign() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![0.5, -2.0, 1.0, 0.0]);
        let c = a.add(&b);
        assert_eq!(c.data, vec![1.5, 0.0, 4.0, 4.0]);
        let mut d = a.clone();
        d.add_assign(&b);
        assert_eq!(d, c);
    }

    #[test]
    fn parallel_matmul_matches_sequential_bits() {
        // Above the parallel threshold the row-parallel path must produce
        // the same bits as a 1-thread pool run of the same call.
        let mut rng = Rng::new(7);
        let a = Matrix::randn(64, 48, 1.0, &mut rng);
        let b = Matrix::randn(48, 64, 1.0, &mut rng);
        assert!(64 * 48 * 64 >= super::PAR_MATMUL_FLOPS);
        let par = a.matmul(&b);
        let pool = rayon::ThreadPoolBuilder::new()
            .num_threads(1)
            .build()
            .expect("pool");
        let seq = pool.install(|| a.matmul(&b));
        assert_eq!(par, seq);
    }
}
