//! Zero-perturbation proof for the observability layer.
//!
//! The obs contract is that telemetry only *reads* values the run
//! already computed: turning `--obs-log` on must leave every computed
//! result — losses, parameters, optimizer moments, served token
//! streams — bit-identical, at any rayon pool size.  These tests run
//! the same workloads with observability on and off under dedicated
//! pools of 1, 2, and 8 threads and compare to the bit.  (The obs
//! *logs* themselves are not expected identical across runs — they
//! carry wall-clock timings — only the computation is.)

use spt::config::{Mode, RunConfig};
use spt::coordinator::{Backend, NativeBackend, TrainState, Trainer, TrainerOptions};
use spt::data::SyntheticCorpus;
use spt::infer::{Daemon, DaemonConfig, InferModel};
use spt::metrics::{Counters, Gauge, Histogram};
use spt::obs::{ObsLog, StepObs};
use spt::util::json::Json;

const STEPS: usize = 3;

fn rc(mode: Mode) -> RunConfig {
    RunConfig {
        model: "spt-nano".into(),
        mode,
        batch: 8,
        seq: 32,
        seed: 123,
        lr: 5e-3,
        eval_every: 0,
        codebook_refresh_every: 0,
        ..RunConfig::default()
    }
}

fn lm_batch(rc: &RunConfig, backend: &NativeBackend) -> (Vec<i32>, Vec<i32>) {
    let (batch, seq) = backend.workload(rc).unwrap();
    let vocab = backend.vocab(rc).unwrap();
    let mut corpus = SyntheticCorpus::new(vocab, 4, 0.85, rc.seed);
    let mut tokens = Vec::new();
    let mut targets = Vec::new();
    for _ in 0..batch {
        let (x, y) = corpus.lm_pair(seq);
        tokens.extend(x.iter().map(|&t| t as i32));
        targets.extend(y.iter().map(|&t| t as i32));
    }
    (tokens, targets)
}

/// Run `STEPS` steps under a dedicated pool, with or without the
/// instrumented step; returns loss bits, the final state, and the last
/// step's telemetry when instrumented.
fn run_under_pool(
    threads: usize,
    mode: Mode,
    instrumented: bool,
) -> (Vec<u32>, TrainState, Option<StepObs>) {
    let pool = rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("pool");
    pool.install(|| {
        let backend = NativeBackend::new();
        let cfg = rc(mode);
        let (tokens, targets) = lm_batch(&cfg, &backend);
        let mut state = backend.init_state(&cfg).unwrap();
        let mut bits = Vec::with_capacity(STEPS);
        let mut last_obs = None;
        for _ in 0..STEPS {
            let loss = if instrumented {
                let mut sobs = StepObs::default();
                let loss = backend
                    .train_step_obs(&cfg, &mut state, &tokens, &targets, &mut sobs)
                    .unwrap();
                last_obs = Some(sobs);
                loss
            } else {
                backend.train_step(&cfg, &mut state, &tokens, &targets).unwrap()
            };
            assert!(loss.is_finite(), "{mode:?}: non-finite loss");
            bits.push(loss.to_bits());
        }
        (bits, state, last_obs)
    })
}

/// Instrumented and plain training must agree to the bit — per mode,
/// at every pool size, against the plain 1-thread reference.
#[test]
fn train_bit_identical_with_obs_on_and_off_across_pools() {
    for mode in Mode::ALL {
        let (ref_bits, ref_state, _) = run_under_pool(1, mode, false);
        for threads in [1usize, 2, 8] {
            let (bits, state, sobs) = run_under_pool(threads, mode, true);
            assert_eq!(
                ref_bits, bits,
                "{mode:?}: obs-on losses diverge at {threads} threads"
            );
            assert_eq!(
                ref_state.params, state.params,
                "{mode:?}: obs-on params diverge at {threads} threads"
            );
            assert_eq!(
                ref_state.m, state.m,
                "{mode:?}: obs-on AdamW m diverges at {threads} threads"
            );
            assert_eq!(
                ref_state.v, state.v,
                "{mode:?}: obs-on AdamW v diverges at {threads} threads"
            );
            // The probe actually observed the run it rode along with.
            let sobs = sobs.expect("instrumented run records telemetry");
            assert!(!sobs.phases.is_empty(), "{mode:?}: no phase timings");
            if mode == Mode::Spt {
                assert!(!sobs.attn_density.is_empty(), "spt records attn density");
                assert!(
                    sobs.attn_density.iter().all(|&d| d > 0.0 && d <= 1.0),
                    "densities are ratios: {:?}",
                    sobs.attn_density
                );
                assert!(!sobs.expert_load.is_empty(), "spt records expert load");
            }
        }
    }
}

/// The telemetry values themselves (not timings) are deterministic:
/// the same step observes the same densities and expert loads at any
/// pool size.
#[test]
fn value_telemetry_is_pool_invariant() {
    let (_, _, ref_obs) = run_under_pool(1, Mode::Spt, true);
    let ref_obs = ref_obs.unwrap();
    for threads in [2usize, 8] {
        let (_, _, sobs) = run_under_pool(threads, Mode::Spt, true);
        let sobs = sobs.unwrap();
        assert_eq!(ref_obs.attn_density, sobs.attn_density, "{threads} threads");
        assert_eq!(ref_obs.expert_load, sobs.expert_load, "{threads} threads");
        assert_eq!(ref_obs.trace_bytes, sobs.trace_bytes, "{threads} threads");
    }
}

/// End-to-end through the Trainer: a run writing an `--obs-log` JSONL
/// produces the same losses and final parameters as one that does not,
/// and the log itself is a well-formed obs stream.
#[test]
fn trainer_obs_log_does_not_change_results() {
    let dir = std::env::temp_dir().join("spt_obs_parity_test");
    std::fs::create_dir_all(&dir).unwrap();
    let log_path = dir.join("train.jsonl");
    std::fs::remove_file(&log_path).ok();

    let mk_rc = || RunConfig {
        steps: 2,
        eval_every: 2,
        ..rc(Mode::Spt)
    };
    let backend = NativeBackend::new();
    let mut plain = Trainer::new(&backend, mk_rc(), TrainerOptions::default());
    let plain_report = plain.train().unwrap();

    let mut logged = Trainer::new(&backend, mk_rc(), TrainerOptions::default());
    logged.obs = ObsLog::create(&log_path, "train").unwrap();
    let logged_report = logged.train().unwrap();

    let bits = |ls: &[f32]| ls.iter().map(|l| l.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&plain_report.losses), bits(&logged_report.losses));
    assert_eq!(
        plain.last_state.as_ref().unwrap().params,
        logged.last_state.as_ref().unwrap().params,
        "obs log changed the trained parameters"
    );

    let summary = spt::obs::report::summarize(&log_path).unwrap();
    assert_eq!(summary.cmd, "train");
    assert_eq!(summary.steps, 2);
    assert!(summary.phases.contains_key("fwd_bwd"), "{:?}", summary.phases);
    assert!(summary.phases.contains_key("optimizer"), "{:?}", summary.phases);
    assert!(summary.phases.contains_key("mha"), "{:?}", summary.phases);
    assert!(summary.phases.contains_key("ffn"), "{:?}", summary.phases);
    assert!(summary.phases.contains_key("ln"), "{:?}", summary.phases);
    assert!(summary.attn_density_mean() > 0.0, "spt run records density");
    assert_eq!(summary.evals.len(), 1, "eval event captured");
    assert!(summary.memory.is_some(), "memory-truth join emitted");
    let (observed, predicted, _) = summary.memory.unwrap();
    assert!(observed > 0 && predicted > 0);
    let rendered = spt::obs::report::render(&summary);
    assert!(rendered.contains("Phase breakdown"));
    assert!(rendered.contains("Memory truth"));
    std::fs::remove_file(&log_path).ok();
}

fn infer_fixture() -> InferModel {
    let cfg = RunConfig {
        model: "spt-nano".into(),
        mode: Mode::Spt,
        seed: 5,
        ..RunConfig::default()
    };
    let backend = NativeBackend::new();
    let state = backend.init_state(&cfg).unwrap();
    InferModel::new(&cfg, state).unwrap()
}

fn submit_line(id: usize, prompt: &[i32], max_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!(
        r#"{{"op":"submit","id":{id},"prompt":[{}],"max_new_tokens":{max_new}}}"#,
        toks.join(",")
    )
}

/// Run the daemon over a fixed request set; when `nosy` is set, pepper
/// every scheduler turn with `status` and `metrics` ops.  Returns each
/// request's token stream.
fn serve_tokens(model: &InferModel, nosy: bool) -> Vec<(usize, Vec<i64>)> {
    let mut d = Daemon::new(model, DaemonConfig::default()).unwrap();
    for (id, len) in [(1usize, 3usize), (2, 5), (3, 2)] {
        let prompt: Vec<i32> = (1..=len as i32).collect();
        let ev = d.handle_line(&submit_line(id, &prompt, 4));
        assert_eq!(ev[0].get("event").as_str(), Some("accepted"));
        if nosy {
            d.handle_line(r#"{"op":"status"}"#);
        }
    }
    let mut out = Vec::new();
    while d.has_work() {
        if nosy {
            let ev = d.handle_line(r#"{"op":"metrics"}"#);
            assert_eq!(ev[0].get("event").as_str(), Some("metrics"));
        }
        for e in d.pump().unwrap() {
            if e.get("event").as_str() == Some("done") {
                let id = e.get("id").as_usize().unwrap();
                let toks: Vec<i64> = e
                    .get("tokens")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .filter_map(Json::as_i64)
                    .collect();
                out.push((id, toks));
            }
        }
    }
    out.sort();
    out
}

/// Interleaving `status` and `metrics` reads must not change a single
/// served token.
#[test]
fn served_streams_identical_with_metrics_interleaved() {
    let model = infer_fixture();
    let quiet = serve_tokens(&model, false);
    let nosy = serve_tokens(&model, true);
    assert_eq!(quiet, nosy, "observability ops changed the served tokens");
    assert_eq!(quiet.len(), 3);
    assert!(quiet.iter().all(|(_, t)| t.len() == 4));
}

/// Histogram bucketing is fixed at construction and insensitive to
/// observation order — two permutations of the same values render the
/// same Prometheus text.
#[test]
fn histogram_and_prometheus_rendering_are_deterministic() {
    let values = [0.002, 0.03, 0.03, 0.4, 7.0, 0.0005];
    let bounds = [0.001, 0.01, 0.1, 1.0, 10.0];
    let mut fwd = Histogram::new("spt_request_latency_seconds", &bounds);
    let mut rev = Histogram::new("spt_request_latency_seconds", &bounds);
    for v in values {
        fwd.observe(v);
    }
    for v in values.iter().rev() {
        rev.observe(*v);
    }
    let mut counters = Counters::new();
    counters.add("spt_completions_total", 6);
    let gauges = [Gauge::new("spt_pool_pages", 8.0)];
    let a = spt::obs::prometheus_text(&counters, &gauges, &[fwd]);
    let b = spt::obs::prometheus_text(&counters, &gauges, &[rev]);
    assert_eq!(a, b, "observation order leaked into the rendering");
    assert!(a.contains("# TYPE spt_request_latency_seconds histogram"));
    assert!(a.contains("spt_request_latency_seconds_bucket{le=\"0.001\"} 1\n"));
    assert!(a.contains("spt_request_latency_seconds_bucket{le=\"+Inf\"} 6\n"));
    assert!(a.contains("spt_request_latency_seconds_count 6\n"));
    assert!(a.contains("# TYPE spt_completions_total counter"));
    assert!(a.contains("spt_completions_total 6\n"));
    assert!(a.contains("spt_pool_pages 8\n"));
}
