//! TOML-subset parser for run configs (no `toml` crate offline).
//!
//! Supports: `[section]` headers, `key = value` pairs (strings, numbers,
//! booleans), `#` comments, and blank lines.  Keys are flattened to
//! `section.key`.  That covers every config file this project ships;
//! anything fancier (arrays-of-tables, multiline strings) is rejected
//! loudly rather than mis-parsed.

use anyhow::{bail, Result};

/// Parse into flattened (key, value) pairs, section-prefixed.
pub fn parse(text: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            if name.contains('[') || name.is_empty() {
                bail!("line {}: unsupported section '{name}'", lineno + 1);
            }
            section = name.trim().to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected key = value", lineno + 1);
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.push((full_key, value));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<String> {
    if v.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = v.strip_prefix('"') {
        let Some(s) = inner.strip_suffix('"') else {
            bail!("unterminated string");
        };
        if s.contains('"') {
            bail!("embedded quotes unsupported");
        }
        return Ok(s.to_string());
    }
    if v.starts_with('[') || v.starts_with('{') {
        bail!("arrays/inline tables unsupported in config files");
    }
    // bare scalar: number / bool / datetime — keep the raw token.
    Ok(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let text = r#"
# fine-tuning run
model = "spt-30m"

[run]
mode = "spt"     # sparse tuning
batch = 8
seq = 256
deterministic = true
"#;
        let pairs = parse(text).unwrap();
        assert_eq!(
            pairs,
            vec![
                ("model".to_string(), "spt-30m".to_string()),
                ("run.mode".to_string(), "spt".to_string()),
                ("run.batch".to_string(), "8".to_string()),
                ("run.seq".to_string(), "256".to_string()),
                ("run.deterministic".to_string(), "true".to_string()),
            ]
        );
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let pairs = parse(r##"name = "a#b""##).unwrap();
        assert_eq!(pairs[0].1, "a#b");
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[open").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = [1,2]").is_err());
        assert!(parse("k = \"unterminated").is_err());
        assert!(parse(" = 3").is_err());
    }
}
