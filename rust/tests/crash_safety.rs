//! Crash-safety integration: deterministic fault injection through the
//! periodic-checkpoint path, proving the atomic-save + auto-resume
//! contract end to end.
//!
//! The scenario mirrors a real operational failure: a training run
//! checkpointing every 2 steps is killed mid-save (the fault plan
//! crashes the writer after a fixed byte count), leaving a torn `.tmp`
//! behind.  The previous checkpoint must be untouched, the scan must
//! pick it up, and the resumed run must be bit-identical to a run that
//! never crashed.

use std::path::PathBuf;
use std::sync::Arc;

use spt::config::{Mode, RunConfig};
use spt::coordinator::{checkpoint, NativeBackend, Trainer, TrainerOptions};
use spt::util::fault::{self, FaultPlan};

fn rc(steps: usize) -> RunConfig {
    RunConfig {
        model: "spt-nano".into(),
        mode: Mode::Spt,
        batch: 2,
        seq: 32,
        steps,
        eval_every: 0,
        codebook_refresh_every: 3,
        lr: 5e-3,
        seed: 11,
        ..RunConfig::default()
    }
}

fn opts(dir: &PathBuf, fault: Option<Arc<FaultPlan>>) -> TrainerOptions {
    TrainerOptions {
        ckpt_dir: Some(dir.clone()),
        ckpt_every: 2,
        fault,
        ..Default::default()
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("spt_crash_safety_test").join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn ckpt_names(dir: &PathBuf) -> Vec<String> {
    let mut names: Vec<String> = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    names
}

#[test]
fn fault_killed_save_leaves_prior_checkpoint_and_resume_is_bit_identical() {
    let backend = NativeBackend::new();

    // Reference: 8 steps, checkpoint every 2, no faults.
    let dir_a = tmp_dir("reference");
    let mut full = Trainer::new(&backend, rc(8), opts(&dir_a, None));
    let full_report = full.train().expect("uninterrupted run");
    assert_eq!(full_report.losses.len(), 8);
    assert_eq!(
        ckpt_names(&dir_a),
        vec![
            "step-00000002.ckpt",
            "step-00000004.ckpt",
            "step-00000006.ckpt",
            "step-00000008.ckpt",
        ],
        "periodic checkpoints written every 2 steps"
    );

    // Crashed run: the 2nd periodic save (step 4) dies after 64 bytes.
    let dir_b = tmp_dir("crashed");
    let plan = Arc::new(FaultPlan::new().with("ckpt_crash", 2).with("ckpt_crash_bytes", 64));
    let mut crashed = Trainer::new(&backend, rc(8), opts(&dir_b, Some(plan)));
    let err = crashed.train().expect_err("the injected crash must surface");
    assert!(fault::is_crash(&err), "not a crash marker: {err:#}");

    // The step-2 checkpoint survived intact; step-4 is a torn .tmp only.
    let names = ckpt_names(&dir_b);
    assert!(names.contains(&"step-00000002.ckpt".to_string()), "{names:?}");
    assert!(!names.contains(&"step-00000004.ckpt".to_string()), "{names:?}");
    assert!(names.contains(&"step-00000004.ckpt.tmp".to_string()), "{names:?}");
    let torn = std::fs::metadata(dir_b.join("step-00000004.ckpt.tmp")).unwrap();
    assert_eq!(torn.len(), 64, "writer crashed after exactly the planned bytes");
    let a2 = std::fs::read(dir_a.join("step-00000002.ckpt")).unwrap();
    let b2 = std::fs::read(dir_b.join("step-00000002.ckpt")).unwrap();
    assert_eq!(a2, b2, "prior checkpoint bytes must be untouched by the crash");

    // The scan skips the torn tmp and finds step 2.
    let latest = checkpoint::find_latest_valid(&dir_b)
        .expect("scan")
        .expect("a valid checkpoint survived");
    assert_eq!(latest.step, 2);
    let meta = latest.meta.expect("v3 checkpoints carry identity");
    meta.verify("spt-nano", Mode::Spt).expect("identity matches");

    // Resume from it: the finished run must be bit-identical to the
    // uninterrupted reference from step 3 onward.
    let dir_c = tmp_dir("resumed");
    let mut resumed = Trainer::new(&backend, rc(8), opts(&dir_c, None));
    let r2 = resumed.train_from(latest.state).expect("resumed run");
    assert_eq!(r2.losses.len(), 6);
    for (i, (got, want)) in r2.losses.iter().zip(&full_report.losses[2..]).enumerate() {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "loss diverged at step {} ({got} vs {want})",
            i + 3
        );
    }
    let s_full = full.last_state.as_ref().expect("full state");
    let s_res = resumed.last_state.as_ref().expect("resumed state");
    assert_eq!(s_full.params, s_res.params);
    assert_eq!(s_full.m, s_res.m);
    assert_eq!(s_full.v, s_res.v);
    assert_eq!(s_full.step, s_res.step);
    // And the resumed run's own later checkpoints equal the reference's.
    let a8 = std::fs::read(dir_a.join("step-00000008.ckpt")).unwrap();
    let c8 = std::fs::read(dir_c.join("step-00000008.ckpt")).unwrap();
    assert_eq!(a8, c8, "recovered trajectory re-produces identical checkpoints");
}

#[test]
fn transient_write_fault_is_retried_and_does_not_perturb_training() {
    let backend = NativeBackend::new();

    let dir_clean = tmp_dir("clean");
    let mut clean = Trainer::new(&backend, rc(4), opts(&dir_clean, None));
    let clean_report = clean.train().expect("clean run");

    // One transient write error on the first save; retry must recover
    // and the run must be bit-identical to the clean one.
    let dir_fault = tmp_dir("transient");
    let plan = Arc::new(FaultPlan::new().with("ckpt_write_err", 1));
    let mut faulted = Trainer::new(&backend, rc(4), opts(&dir_fault, Some(plan.clone())));
    let fault_report = faulted.train().expect("transient fault must be absorbed");

    assert!(plan.probes("ckpt_write_err") >= 2, "the save was retried");
    for (got, want) in fault_report.losses.iter().zip(&clean_report.losses) {
        assert_eq!(got.to_bits(), want.to_bits(), "fault plan perturbed training");
    }
    for name in ["step-00000002.ckpt", "step-00000004.ckpt"] {
        let a = std::fs::read(dir_clean.join(name)).unwrap();
        let b = std::fs::read(dir_fault.join(name)).unwrap();
        assert_eq!(a, b, "{name}: checkpoint bytes differ under transient fault");
    }
}

#[test]
fn page_pool_starvation_rejects_structurally_and_never_perturbs_survivors() {
    use spt::config::presets;
    use spt::coordinator::Backend;
    use spt::infer::{Daemon, DaemonConfig, InferModel};
    use spt::memmodel;
    use spt::util::json::Json;

    let backend = NativeBackend::new();
    let run_cfg = RunConfig { model: "spt-nano".into(), mode: Mode::Spt, seed: 11, ..RunConfig::default() };
    let state = backend.init_state(&run_cfg).unwrap();
    let model = InferModel::new(&run_cfg, state).unwrap();

    // A budget of 1.5 pages buys a one-page pool: requests with a
    // <= page_tokens target fit (and serialize); anything larger can
    // never fit and must be rejected with a structured mem_budget
    // event — not a panic, not a silent drop.
    let mc = presets::model("spt-nano").unwrap();
    let page = memmodel::decode_page_bytes(
        &mc.block,
        Mode::Spt,
        spt::infer::ServeConfig::default().page_tokens,
        mc.n_layers.max(1),
    );
    let submit = |id: usize, prompt: &[i32], max_new: usize| {
        let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
        format!(
            r#"{{"op":"submit","id":{id},"prompt":[{}],"max_new_tokens":{max_new}}}"#,
            toks.join(",")
        )
    };
    let run = |fault: Option<Arc<FaultPlan>>| -> (Vec<(usize, Vec<i64>)>, Vec<String>) {
        let cfg = DaemonConfig {
            mem_budget: Some(page + page / 2),
            fault,
            ..DaemonConfig::default()
        };
        let mut d = Daemon::new(&model, cfg).unwrap();
        let mut rejected_codes = Vec::new();
        for line in [
            submit(1, &[1, 2, 3], 5),           // target 8  = 1 page: fits
            submit(2, &[1, 2, 3, 4], 30),       // target 34 = 3 pages: never fits
            submit(3, &[2, 3, 4], 5),           // target 8  = 1 page: fits
        ] {
            for ev in d.handle_line(&line) {
                if ev.get("event").as_str() == Some("rejected") {
                    rejected_codes.push(ev.get("code").as_str().unwrap_or("?").to_string());
                }
            }
        }
        let mut streams = Vec::new();
        let (events, report) = d.finish().unwrap();
        for ev in &events {
            if ev.get("event").as_str() == Some("done") {
                assert_eq!(ev.get("error"), &Json::Null, "survivor degraded: {ev}");
                let toks: Vec<i64> =
                    ev.get("tokens").as_arr().unwrap().iter().filter_map(Json::as_i64).collect();
                streams.push((ev.get("id").as_usize().unwrap(), toks));
            }
        }
        streams.sort();
        assert_eq!(report.completions.len(), 2, "both fitting requests completed");
        assert_eq!(report.failed, 0);
        (streams, rejected_codes)
    };

    let (clean_streams, clean_rejects) = run(None);
    assert_eq!(clean_rejects, vec!["mem_budget".to_string()], "oversized request rejected");
    assert_eq!(clean_streams.len(), 2);
    assert_eq!(clean_streams[0].1.len(), 5);

    // Same trace with the pool-starved fault armed at the driver's
    // first admission probe: the request stays queued one extra step,
    // then admits — streams bit-identical, nothing panics or degrades.
    let plan = Arc::new(FaultPlan::new().with("page_pool_exhausted", 1));
    let (faulted_streams, faulted_rejects) = run(Some(plan.clone()));
    assert!(plan.probes("page_pool_exhausted") >= 1, "the fault site was probed");
    assert_eq!(faulted_rejects, clean_rejects);
    assert_eq!(
        faulted_streams, clean_streams,
        "a transient pool-starvation fault must not perturb any token stream"
    );
}

#[test]
fn zero_step_runs_error_clearly_instead_of_panicking() {
    let backend = NativeBackend::new();
    let mut t = Trainer::new(&backend, rc(0), TrainerOptions::default());
    let err = t.train().expect_err("steps=0 must not panic");
    assert!(err.to_string().contains("--steps"), "{err:#}");
    let mut t = Trainer::new(&backend, rc(0), TrainerOptions::default());
    let err = t.train_qa().expect_err("qa steps=0 must not panic");
    assert!(err.to_string().contains("--steps"), "{err:#}");

    // batch=0 is clamped to a 1-sequence workload by the native backend
    // (the trainer's own empty-workload guard covers backends that
    // don't clamp); either way, no panic and no poisoned loss curve.
    let mut cfg = rc(2);
    cfg.batch = 0;
    let mut t = Trainer::new(&backend, cfg, TrainerOptions::default());
    let report = t.train().expect("clamped workload trains");
    assert_eq!(report.losses.len(), 2);
    assert!(report.losses.iter().all(|l| l.is_finite()));
}
