//! Crash-safe serving daemon: an NDJSON request/stream protocol around
//! [`ServeDriver`] with bounded admission, memory-budget accounting,
//! step-counted deadlines, and graceful drain.
//!
//! ## Protocol (one JSON object per line, both directions)
//!
//! Requests:
//! - `{"op":"submit","id":N,"prompt":[t,...],"max_new_tokens":M}`
//! - `{"op":"status"}`
//! - `{"op":"metrics"}` — Prometheus-text snapshot of pool/queue/latency
//!   telemetry (pure read of already-tracked values).
//! - `{"op":"drain"}` — stop admitting, finish in-flight work, emit the
//!   final `{"event":"report",...}` and exit.
//!
//! Events:
//! - `{"event":"accepted","id":N,"cost_bytes":C,"queued":Q}`
//! - `{"event":"rejected","id":N,"code":"queue_full|mem_budget|invalid|draining","reason":..}`
//! - `{"event":"done","id":N,"tokens":[..],"latency_s":..,"queue_wait_s":..[,"error":..]}`
//! - `{"event":"metrics","content_type":"text/plain; version=0.0.4","text":..}`
//! - `{"event":"status",...}` / `{"event":"report",...}` /
//!   `{"event":"error","reason":..}` (malformed input degrades that
//!   line, never the daemon).  `status` and `report` carry the run's
//!   build/host provenance (git sha, rayon threads, CPU model).
//!
//! ## Admission control
//!
//! Budgeting is page-granular and live: the driver's KV storage is a
//! fixed page pool sized from `--mem_budget` (budget /
//! [`crate::memmodel::decode_page_bytes`] pages), and the driver
//! charges every request its full target-length page demand at
//! admission, crediting pages back on retirement — so committed bytes
//! (pages in use × page bytes) provably never exceed the budget.  The
//! daemon rejects outright (structured `mem_budget` event) only a
//! request whose page demand exceeds the *whole* pool; anything that
//! fits eventually waits in the bounded queue (capacity `queue_cap`,
//! overflow rejected with a structured `queue_full` error, never
//! silently dropped).
//!
//! ## Determinism
//!
//! Deadlines are counted in *decode steps*, not wall time, and faults
//! come from the seeded [`FaultPlan`] — so a daemon fed the same script
//! produces the same admissions, cancellations, and token streams at
//! any rayon pool size.  Wall-clock only ever lands in latency metrics.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::serve::{Completion, Request, ServeConfig, ServeDriver, ServeReport};
use super::session::InferModel;
use crate::config::{presets, Mode};
use crate::memmodel;
use crate::metrics::{Counters, Gauge, Histogram};
use crate::util::fault::{self, FaultPlan};
use crate::util::json::Json;
use crate::util::provenance;
use crate::util::retry::{retry, Backoff};

/// Daemon knobs on top of the driver's [`ServeConfig`].
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    pub serve: ServeConfig,
    /// Capacity of the daemon's admission queue (requests accepted but
    /// not yet fed to the driver).  Overflow is rejected, not dropped.
    pub queue_cap: usize,
    /// Byte budget for the KV page pool: the pool is sized to
    /// `budget / page_bytes` pages (unless `serve.pool_pages` already
    /// overrides it), so committed cache bytes can never exceed it.
    /// `None` keeps the driver's default pool (max_batch full-length
    /// sequences).
    pub mem_budget: Option<u64>,
    /// Cancel a request once it has been in the driver this many decode
    /// steps (a deterministic deadline).  `None` disables deadlines.
    pub deadline_steps: Option<usize>,
    /// Fault-injection plan (sites `queue_full`, `accept_err`; shared
    /// into the driver for `page_pool_exhausted` unless the serve
    /// config already carries its own plan).
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            serve: ServeConfig::default(),
            queue_cap: 64,
            mem_budget: None,
            deadline_steps: None,
            fault: None,
        }
    }
}

fn event(kind: &str, pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    m.insert("event".to_string(), Json::Str(kind.to_string()));
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn error_event(reason: impl Into<String>) -> Json {
    event("error", vec![("reason", Json::Str(reason.into()))])
}

/// The daemon: driver + admission queue + budget/deadline bookkeeping.
pub struct Daemon<'m> {
    driver: ServeDriver<'m>,
    cfg: DaemonConfig,
    max_seq: usize,
    /// Bytes of one pool page — the admission/budget granule
    /// ([`memmodel::decode_page_bytes`]).
    page_bytes: u64,
    /// Live request ids (accepted, not yet done) — duplicate detection.
    live: BTreeSet<usize>,
    /// Accepted requests not yet fed to the driver.
    pending: VecDeque<Request>,
    /// Completions already streamed as `done` events, folded back into
    /// the final report.
    done: Vec<Completion>,
    draining: bool,
    /// Build/host provenance, probed once at construction (the git
    /// subprocess must not run per status line) and stamped into
    /// `status` and `report` events.
    provenance: Json,
}

impl<'m> Daemon<'m> {
    pub fn new(model: &'m InferModel, mut cfg: DaemonConfig) -> Result<Self> {
        let mc = presets::model(model.model_name())?;
        // The driver probes `page_pool_exhausted`; share the daemon's
        // plan down unless the serve config carries its own.
        if cfg.serve.fault.is_none() {
            cfg.serve.fault = cfg.fault.clone();
        }
        let page_bytes = memmodel::decode_page_bytes(
            &mc.block,
            model.mode(),
            cfg.serve.page_tokens,
            mc.n_layers.max(1),
        );
        if let (Some(budget), None) = (cfg.mem_budget, cfg.serve.pool_pages) {
            let pages = memmodel::pool_pages_for_budget(budget, page_bytes);
            if pages == 0 {
                bail!(
                    "mem_budget {budget} bytes cannot hold even one \
                     {page_bytes}-byte KV page"
                );
            }
            cfg.serve.pool_pages = Some(pages);
        }
        Ok(Daemon {
            driver: ServeDriver::new(model, cfg.serve.clone())?,
            max_seq: model.max_seq(),
            page_bytes,
            cfg,
            live: BTreeSet::new(),
            pending: VecDeque::new(),
            done: Vec::new(),
            draining: false,
            provenance: provenance::provenance(),
        })
    }

    /// Stop admitting; already-accepted work still runs to completion.
    pub fn begin_drain(&mut self) {
        self.draining = true;
    }

    pub fn draining(&self) -> bool {
        self.draining
    }

    /// Anything left to do (pending, queued in driver, or in flight)?
    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || self.driver.queued() > 0 || self.driver.in_flight() > 0
    }

    /// Bytes of KV cache currently committed (live pool pages × page
    /// bytes) — bounded by the pool size, hence by `mem_budget`.
    pub fn committed_bytes(&self) -> u64 {
        self.driver.pool_pages_in_use() as u64 * self.page_bytes
    }

    /// Actual bytes one pool page occupies in the driver's KV storage —
    /// the observed side of the obs memory-truth join.
    pub fn observed_page_bytes(&self) -> u64 {
        self.driver.page_bytes() as u64
    }

    /// Analytic page size ([`memmodel::decode_page_bytes`]) the budget
    /// was planned with — the predicted side of that join.
    pub fn planned_page_bytes(&self) -> u64 {
        self.page_bytes
    }

    /// Handle one protocol line; returns the events it produced.
    /// Malformed input yields an `error` event — the daemon never dies
    /// on bad bytes.
    pub fn handle_line(&mut self, line: &str) -> Vec<Json> {
        let line = line.trim();
        if line.is_empty() {
            return Vec::new();
        }
        let v = match crate::util::json::parse(line) {
            Ok(v) => v,
            Err(e) => return vec![error_event(format!("bad json: {e}"))],
        };
        match v.get("op").as_str() {
            Some("submit") => self.op_submit(&v),
            Some("status") => vec![self.status_event()],
            Some("metrics") => vec![self.metrics_event()],
            Some("drain") => {
                self.begin_drain();
                vec![self.status_event()]
            }
            Some(other) => vec![error_event(format!("unknown op '{other}'"))],
            None => vec![error_event("missing 'op' field")],
        }
    }

    fn status_event(&self) -> Json {
        event(
            "status",
            vec![
                ("pending", Json::Num(self.pending.len() as f64)),
                ("in_flight", Json::Num(self.driver.in_flight() as f64)),
                ("driver_queued", Json::Num(self.driver.queued() as f64)),
                ("committed_bytes", Json::Num(self.committed_bytes() as f64)),
                ("pool_pages", Json::Num(self.driver.pool_pages() as f64)),
                (
                    "pool_free_pages",
                    Json::Num(self.driver.pool_free_pages() as f64),
                ),
                ("decode_steps", Json::Num(self.driver.decode_steps() as f64)),
                ("draining", Json::Bool(self.draining)),
                ("provenance", self.provenance.clone()),
            ],
        )
    }

    /// The `metrics` op: a Prometheus text-format snapshot of the
    /// daemon's queue, the driver's page pool, and completion latency.
    /// Every value is already tracked for scheduling or the final
    /// report — the snapshot reads no clocks and mutates nothing, so
    /// interleaving `metrics` lines cannot change any token stream.
    fn metrics_event(&self) -> Json {
        let mut counters = Counters::new();
        counters.add("spt_decode_steps_total", self.driver.decode_steps() as u64);
        counters.add("spt_completions_total", self.done.len() as u64);
        counters.add(
            "spt_failures_total",
            self.done.iter().filter(|c| c.error.is_some()).count() as u64,
        );
        let gauges = [
            Gauge::new("spt_pending_requests", self.pending.len() as f64),
            Gauge::new("spt_driver_queued_requests", self.driver.queued() as f64),
            Gauge::new("spt_in_flight_requests", self.driver.in_flight() as f64),
            Gauge::new("spt_pool_pages", self.driver.pool_pages() as f64),
            Gauge::new("spt_pool_pages_in_use", self.driver.pool_pages_in_use() as f64),
            Gauge::new("spt_pool_free_pages", self.driver.pool_free_pages() as f64),
            Gauge::new("spt_page_bytes", self.driver.page_bytes() as f64),
            Gauge::new("spt_committed_bytes", self.committed_bytes() as f64),
        ];
        let mut latency =
            Histogram::new("spt_request_latency_seconds", &[0.001, 0.01, 0.1, 1.0, 10.0]);
        for c in &self.done {
            latency.observe(c.latency_secs);
        }
        event(
            "metrics",
            vec![
                (
                    "content_type",
                    Json::Str("text/plain; version=0.0.4".to_string()),
                ),
                (
                    "text",
                    Json::Str(crate::obs::prometheus_text(&counters, &gauges, &[latency])),
                ),
            ],
        )
    }

    fn rejected(id: Option<usize>, code: &str, reason: impl Into<String>) -> Json {
        let mut pairs = vec![
            ("code", Json::Str(code.to_string())),
            ("reason", Json::Str(reason.into())),
        ];
        if let Some(id) = id {
            pairs.insert(0, ("id", Json::Num(id as f64)));
        }
        event("rejected", pairs)
    }

    fn op_submit(&mut self, v: &Json) -> Vec<Json> {
        let Some(id) = v.get("id").as_usize() else {
            return vec![Self::rejected(None, "invalid", "missing or non-numeric 'id'")];
        };
        if self.draining {
            return vec![Self::rejected(Some(id), "draining", "daemon is draining")];
        }
        if self.live.contains(&id) {
            return vec![Self::rejected(
                Some(id),
                "invalid",
                format!("request id {id} is already live"),
            )];
        }
        let Some(arr) = v.get("prompt").as_arr() else {
            return vec![Self::rejected(Some(id), "invalid", "'prompt' must be a token array")];
        };
        let mut prompt = Vec::with_capacity(arr.len());
        for t in arr {
            match t.as_i64().and_then(|x| i32::try_from(x).ok()) {
                Some(tok) => prompt.push(tok),
                None => {
                    return vec![Self::rejected(
                        Some(id),
                        "invalid",
                        "prompt tokens must be i32 integers",
                    )]
                }
            }
        }
        let Some(max_new) = v.get("max_new_tokens").as_usize() else {
            return vec![Self::rejected(
                Some(id),
                "invalid",
                "missing or non-numeric 'max_new_tokens'",
            )];
        };
        // Mirror the driver's validation so a fed request cannot fail it.
        if prompt.is_empty() {
            return vec![Self::rejected(Some(id), "invalid", "empty prompt")];
        }
        if max_new == 0 {
            return vec![Self::rejected(Some(id), "invalid", "max_new_tokens must be >= 1")];
        }
        let target = prompt.len() + max_new;
        if target > self.max_seq {
            return vec![Self::rejected(
                Some(id),
                "invalid",
                format!(
                    "prompt {} + max_new {} exceeds max_seq {}",
                    prompt.len(),
                    max_new,
                    self.max_seq
                ),
            )];
        }
        if fault::fire(self.cfg.fault.as_deref(), "queue_full")
            || self.pending.len() >= self.cfg.queue_cap
        {
            return vec![Self::rejected(
                Some(id),
                "queue_full",
                format!("admission queue at capacity {}", self.cfg.queue_cap),
            )];
        }
        let pages = memmodel::decode_request_pages(target, self.cfg.serve.page_tokens);
        let cost = pages as u64 * self.page_bytes;
        if pages > self.driver.pool_pages() {
            return vec![Self::rejected(
                Some(id),
                "mem_budget",
                format!(
                    "request needs {pages} KV pages, pool holds {}",
                    self.driver.pool_pages()
                ),
            )];
        }
        let queued = self.pending.len() + 1;
        self.live.insert(id);
        self.pending.push_back(Request { id, prompt, max_new_tokens: max_new });
        vec![event(
            "accepted",
            vec![
                ("id", Json::Num(id as f64)),
                ("cost_bytes", Json::Num(cost as f64)),
                ("queued", Json::Num(queued as f64)),
            ],
        )]
    }

    /// Feed pending requests to the driver.  Page-granular budgeting
    /// lives in the driver's own admission loop (charge at admit,
    /// credit at retire), so the daemon hands everything over and lets
    /// requests wait in the driver's queue until pages free up.
    fn feed_driver(&mut self, events: &mut Vec<Json>) {
        while let Some(req) = self.pending.pop_front() {
            let id = req.id;
            match self.driver.submit(req) {
                Ok(()) => {}
                Err(e) => {
                    // Validation mirrored at submit should make this
                    // unreachable; degrade the one request regardless.
                    self.live.remove(&id);
                    let c = Completion {
                        id,
                        tokens: Vec::new(),
                        latency_secs: 0.0,
                        queue_wait_secs: 0.0,
                        error: Some(format!("driver rejected request: {e:#}")),
                    };
                    events.push(Self::done_event(&c));
                    self.done.push(c);
                }
            }
        }
    }

    fn done_event(c: &Completion) -> Json {
        let tokens = Json::Arr(c.tokens.iter().map(|&t| Json::Num(f64::from(t))).collect());
        let mut pairs = vec![
            ("id", Json::Num(c.id as f64)),
            ("tokens", tokens),
            ("latency_s", Json::Num(c.latency_secs)),
            ("queue_wait_s", Json::Num(c.queue_wait_secs)),
        ];
        if let Some(err) = &c.error {
            pairs.push(("error", Json::Str(err.clone())));
        }
        event("done", pairs)
    }

    /// One scheduler turn: feed the driver, run one batched step,
    /// enforce deadlines, and emit `done` events for retirements.
    pub fn pump(&mut self) -> Result<Vec<Json>> {
        let mut events = Vec::new();
        self.feed_driver(&mut events);
        if self.driver.queued() > 0 || self.driver.in_flight() > 0 {
            self.driver.step()?;
            if let Some(limit) = self.cfg.deadline_steps {
                let now = self.driver.decode_steps();
                let overdue: Vec<usize> = self
                    .driver
                    .in_flight_ids()
                    .into_iter()
                    .filter(|id| {
                        self.driver
                            .admitted_step(*id)
                            .is_some_and(|at| now.saturating_sub(at) >= limit)
                    })
                    .collect();
                for id in overdue {
                    self.driver
                        .cancel(id, &format!("deadline exceeded: {limit} decode steps"));
                }
            }
        }
        for c in self.driver.take_finished() {
            self.live.remove(&c.id);
            events.push(Self::done_event(&c));
            self.done.push(c);
        }
        Ok(events)
    }

    /// Drain to completion and build the final report (folds streamed
    /// completions back in so the report covers the daemon's lifetime).
    pub fn finish(&mut self) -> Result<(Vec<Json>, ServeReport)> {
        self.begin_drain();
        let mut events = Vec::new();
        while self.has_work() {
            events.extend(self.pump()?);
        }
        let drained = std::mem::take(&mut self.done);
        let report = self.driver.report(drained);
        let report_event = match report.to_json() {
            Json::Obj(mut m) => {
                m.insert("event".to_string(), Json::Str("report".to_string()));
                m.insert("provenance".to_string(), self.provenance.clone());
                Json::Obj(m)
            }
            other => other,
        };
        events.push(report_event);
        Ok((events, report))
    }

    /// Serve one NDJSON stream.  Input lines are read on a helper
    /// thread so in-flight decoding never stalls on a slow client.
    /// Returns `Some(report)` when this stream drained the daemon
    /// (explicit `drain` op, or EOF with `eof_drains`); `None` when the
    /// stream ended but the daemon should keep serving (TCP client
    /// disconnect — accepted work still runs to completion first).
    pub fn serve_stream<R, W>(
        &mut self,
        reader: R,
        mut writer: W,
        eof_drains: bool,
    ) -> Result<Option<ServeReport>>
    where
        R: Read + Send + 'static,
        W: Write,
    {
        let (tx, rx) = mpsc::channel::<String>();
        // Detached on purpose: over TCP the client may hold the socket
        // open past drain, and the thread exits when its next send
        // fails after `rx` drops.
        std::thread::spawn(move || {
            let mut br = BufReader::new(reader);
            let mut line = String::new();
            loop {
                line.clear();
                match br.read_line(&mut line) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => {
                        if tx.send(line.trim_end().to_string()).is_err() {
                            break;
                        }
                    }
                }
            }
        });
        let mut eof = false;
        loop {
            // Drain whatever input is ready without blocking.
            loop {
                match rx.try_recv() {
                    Ok(line) => {
                        let events = self.handle_line(&line);
                        write_events(&mut writer, &events)?;
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        eof = true;
                        break;
                    }
                }
            }
            if eof && eof_drains {
                self.begin_drain();
            }
            if self.draining && !self.has_work() {
                break;
            }
            if self.has_work() {
                let events = self.pump()?;
                write_events(&mut writer, &events)?;
            } else if eof {
                // Stream over, nothing to do, not draining: hand the
                // daemon back to the caller (next connection).
                return Ok(None);
            } else {
                // Idle: block for the next request line.
                match rx.recv() {
                    Ok(line) => {
                        let events = self.handle_line(&line);
                        write_events(&mut writer, &events)?;
                    }
                    Err(_) => eof = true,
                }
            }
        }
        let (events, report) = self.finish()?;
        write_events(&mut writer, &events)?;
        Ok(Some(report))
    }

    /// Serve connections on `addr` until one requests a drain.  Accept
    /// errors are retried with capped backoff (fault site `accept_err`
    /// exercises that path deterministically).
    pub fn serve_tcp(&mut self, addr: &str) -> Result<ServeReport> {
        let listener = std::net::TcpListener::bind(addr)
            .with_context(|| format!("binding daemon listener on {addr}"))?;
        crate::log_info!(
            "daemon listening addr={}",
            listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| addr.to_string())
        );
        loop {
            let plan = self.cfg.fault.clone();
            let stream = retry(&Backoff::default(), "accepting daemon connection", |_| {
                if fault::fire(plan.as_deref(), "accept_err") {
                    return Err(std::io::Error::other("injected accept failure").into());
                }
                let (stream, peer) = listener.accept().context("accept")?;
                crate::log_info!("connection accepted peer={peer}");
                Ok(stream)
            })?;
            let reader = stream.try_clone().context("cloning daemon connection")?;
            if let Some(report) = self.serve_stream(reader, stream, false)? {
                return Ok(report);
            }
        }
    }
}

fn write_events(writer: &mut impl Write, events: &[Json]) -> Result<()> {
    for e in events {
        writeln!(writer, "{e}").context("writing daemon event")?;
    }
    if !events.is_empty() {
        writer.flush().context("flushing daemon events")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::{Backend, NativeBackend};

    fn model() -> InferModel {
        let rc = RunConfig {
            model: "spt-nano".into(),
            mode: Mode::Spt,
            seed: 5,
            ..RunConfig::default()
        };
        let backend = NativeBackend::new();
        let state = backend.init_state(&rc).unwrap();
        InferModel::new(&rc, state).unwrap()
    }

    fn submit_line(id: usize, prompt: &[i32], max_new: usize) -> String {
        let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
        format!(
            r#"{{"op":"submit","id":{id},"prompt":[{}],"max_new_tokens":{max_new}}}"#,
            toks.join(",")
        )
    }

    fn kind(e: &Json) -> &str {
        e.get("event").as_str().unwrap_or("?")
    }

    #[test]
    fn lifecycle_submit_pump_drain() {
        let m = model();
        let mut d = Daemon::new(&m, DaemonConfig::default()).unwrap();
        let ev = d.handle_line(&submit_line(1, &[1, 2, 3], 4));
        assert_eq!(ev.len(), 1);
        assert_eq!(kind(&ev[0]), "accepted");
        assert!(d.has_work());
        let mut done = Vec::new();
        while d.has_work() {
            done.extend(d.pump().unwrap());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(kind(&done[0]), "done");
        assert_eq!(done[0].get("id").as_usize(), Some(1));
        assert_eq!(done[0].get("tokens").as_arr().unwrap().len(), 4);
        assert_eq!(done[0].get("error"), &Json::Null);
        let (events, report) = d.finish().unwrap();
        assert_eq!(kind(events.last().unwrap()), "report");
        assert_eq!(report.completions.len(), 1);
        assert_eq!(report.failed, 0);
        assert_eq!(d.committed_bytes(), 0, "charge released on completion");
    }

    #[test]
    fn malformed_lines_degrade_not_kill() {
        let m = model();
        let mut d = Daemon::new(&m, DaemonConfig::default()).unwrap();
        for bad in [
            "not json at all",
            r#"{"op":"explode"}"#,
            r#"{"no_op":1}"#,
            r#"{"op":"submit"}"#,
            r#"{"op":"submit","id":7,"prompt":"nope","max_new_tokens":2}"#,
            r#"{"op":"submit","id":7,"prompt":[1],"max_new_tokens":0}"#,
            r#"{"op":"submit","id":7,"prompt":[],"max_new_tokens":2}"#,
        ] {
            let ev = d.handle_line(bad);
            assert_eq!(ev.len(), 1, "{bad}");
            assert!(matches!(kind(&ev[0]), "error" | "rejected"), "{bad}");
        }
        // Daemon still serves after all that abuse.
        let ev = d.handle_line(&submit_line(1, &[1, 2], 2));
        assert_eq!(kind(&ev[0]), "accepted");
    }

    #[test]
    fn queue_cap_and_draining_reject_structured() {
        let m = model();
        let cfg = DaemonConfig { queue_cap: 2, ..DaemonConfig::default() };
        let mut d = Daemon::new(&m, cfg).unwrap();
        for id in 0..2 {
            assert_eq!(kind(&d.handle_line(&submit_line(id, &[1, 2], 2))[0]), "accepted");
        }
        let ev = d.handle_line(&submit_line(2, &[1, 2], 2));
        assert_eq!(kind(&ev[0]), "rejected");
        assert_eq!(ev[0].get("code").as_str(), Some("queue_full"));
        d.begin_drain();
        let ev = d.handle_line(&submit_line(3, &[1, 2], 2));
        assert_eq!(ev[0].get("code").as_str(), Some("draining"));
        // Duplicate live id.
        let ev = d.handle_line(&submit_line(0, &[1, 2], 2));
        assert_eq!(ev[0].get("code").as_str(), Some("draining"), "drain wins first");
    }

    #[test]
    fn mem_budget_bounds_committed_bytes() {
        let m = model();
        let mc = presets::model("spt-nano").unwrap();
        let page = memmodel::decode_page_bytes(
            &mc.block,
            Mode::Spt,
            ServeConfig::default().page_tokens,
            mc.n_layers.max(1),
        );
        // Budget fits exactly one KV page: the pool serializes requests.
        let budget = page + page / 2;
        let cfg = DaemonConfig {
            mem_budget: Some(budget),
            queue_cap: 16,
            ..DaemonConfig::default()
        };
        let mut d = Daemon::new(&m, cfg).unwrap();
        assert_eq!(d.driver.pool_pages(), 1, "budget buys exactly one page");
        for id in 0..3 {
            // target 8 tokens = one 16-token page: fits, so it queues.
            let ev = d.handle_line(&submit_line(id, &[1, 2, 3, 4], 4));
            assert_eq!(kind(&ev[0]), "accepted", "budget queues, never rejects fits");
        }
        // Target 34 tokens = 3 pages > the whole 1-page pool: rejected.
        let ev = d.handle_line(&submit_line(9, &[1, 2, 3, 4], 30));
        assert_eq!(ev[0].get("code").as_str(), Some("mem_budget"));
        let mut max_committed = 0;
        while d.has_work() {
            d.pump().unwrap();
            max_committed = max_committed.max(d.committed_bytes());
            assert!(
                d.committed_bytes() <= budget,
                "committed {} exceeds budget {budget}",
                d.committed_bytes()
            );
        }
        assert_eq!(max_committed, page, "exactly one page live at a time");
        let (_, report) = d.finish().unwrap();
        assert_eq!(report.completions.len(), 3);
        assert_eq!(report.failed, 0);
        assert_eq!(report.peak_in_flight, 1, "budget serialized the requests");
    }

    #[test]
    fn deadline_cancels_overdue_requests() {
        let m = model();
        let cfg = DaemonConfig { deadline_steps: Some(3), ..DaemonConfig::default() };
        let mut d = Daemon::new(&m, cfg).unwrap();
        // Wants 10 tokens but the deadline allows ~3 decode steps.
        d.handle_line(&submit_line(1, &[1, 2], 10));
        let mut done = Vec::new();
        while d.has_work() {
            done.extend(d.pump().unwrap());
        }
        assert_eq!(done.len(), 1);
        let err = done[0].get("error").as_str().unwrap_or("");
        assert!(err.contains("deadline"), "{err}");
        let toks = done[0].get("tokens").as_arr().unwrap().len();
        assert!(toks < 10 && toks >= 1, "partial tokens preserved, got {toks}");
    }

    #[test]
    fn queue_full_fault_fires_deterministically() {
        let m = model();
        let plan = Arc::new(FaultPlan::new().with("queue_full", 2));
        let cfg = DaemonConfig { fault: Some(plan.clone()), ..DaemonConfig::default() };
        let mut d = Daemon::new(&m, cfg).unwrap();
        assert_eq!(kind(&d.handle_line(&submit_line(0, &[1, 2], 2))[0]), "accepted");
        let ev = d.handle_line(&submit_line(1, &[1, 2], 2));
        assert_eq!(ev[0].get("code").as_str(), Some("queue_full"), "2nd probe fires");
        assert_eq!(kind(&d.handle_line(&submit_line(2, &[1, 2], 2))[0]), "accepted");
        assert_eq!(plan.probes("queue_full"), 3);
    }

    #[test]
    fn scripted_stream_drains_with_report() {
        let m = model();
        let mut d = Daemon::new(&m, DaemonConfig::default()).unwrap();
        let script = format!(
            "{}\n{}\nnot json\n{{\"op\":\"status\"}}\n{{\"op\":\"drain\"}}\n",
            submit_line(1, &[1, 2, 3], 3),
            submit_line(2, &[4, 5], 2),
        );
        let mut out: Vec<u8> = Vec::new();
        let report = d
            .serve_stream(std::io::Cursor::new(script.into_bytes()), &mut out, true)
            .unwrap()
            .expect("drain op must produce a report");
        assert_eq!(report.completions.len(), 2);
        assert_eq!(report.failed, 0);
        let text = String::from_utf8(out).unwrap();
        let events: Vec<Json> = text
            .lines()
            .map(|l| crate::util::json::parse(l).expect("every output line is JSON"))
            .collect();
        let kinds: Vec<&str> = events.iter().map(kind).collect();
        assert_eq!(kinds.iter().filter(|k| **k == "accepted").count(), 2);
        assert_eq!(kinds.iter().filter(|k| **k == "error").count(), 1);
        assert_eq!(kinds.iter().filter(|k| **k == "done").count(), 2);
        assert_eq!(*kinds.last().unwrap(), "report", "report is the final event");
        let report_ev = events.last().unwrap();
        assert_eq!(report_ev.get("completed").as_usize(), Some(2));
        assert_eq!(report_ev.get("failed").as_usize(), Some(0));
    }

    #[test]
    fn metrics_op_renders_prometheus_snapshot() {
        let m = model();
        let mut d = Daemon::new(&m, DaemonConfig::default()).unwrap();
        d.handle_line(&submit_line(1, &[1, 2, 3], 2));
        while d.has_work() {
            d.pump().unwrap();
        }
        let ev = d.handle_line(r#"{"op":"metrics"}"#);
        assert_eq!(ev.len(), 1);
        assert_eq!(kind(&ev[0]), "metrics");
        assert_eq!(
            ev[0].get("content_type").as_str(),
            Some("text/plain; version=0.0.4")
        );
        let text = ev[0].get("text").as_str().unwrap();
        assert!(text.contains("# TYPE spt_completions_total counter"), "{text}");
        assert!(text.contains("spt_completions_total 1"), "{text}");
        assert!(text.contains("# TYPE spt_pool_pages gauge"), "{text}");
        assert!(text.contains("spt_failures_total 0"), "{text}");
        assert!(
            text.contains("# TYPE spt_request_latency_seconds histogram"),
            "{text}"
        );
        assert!(text.contains("spt_request_latency_seconds_count 1"), "{text}");
        assert!(
            text.contains("spt_request_latency_seconds_bucket{le=\"+Inf\"} 1"),
            "{text}"
        );
        // The snapshot is a pure read: asking again changes nothing but
        // the text it reports, and the daemon still serves.
        let again = d.handle_line(r#"{"op":"metrics"}"#);
        assert_eq!(again[0].get("text"), ev[0].get("text"));
        assert_eq!(kind(&d.handle_line(&submit_line(2, &[1, 2], 2))[0]), "accepted");
    }

    #[test]
    fn status_and_report_carry_provenance() {
        let m = model();
        let mut d = Daemon::new(&m, DaemonConfig::default()).unwrap();
        let status = &d.handle_line(r#"{"op":"status"}"#)[0];
        let prov = status.get("provenance");
        assert!(!prov.get("git_sha").as_str().unwrap_or("").is_empty());
        assert!(!prov.get("cpu_model").as_str().unwrap_or("").is_empty());
        assert!(prov.get("rayon_threads").as_usize().unwrap() >= 1);
        let (events, _) = d.finish().unwrap();
        let report_ev = events.last().unwrap();
        assert_eq!(kind(report_ev), "report");
        assert_eq!(report_ev.get("provenance"), prov, "same probe, stamped once");
    }
}
