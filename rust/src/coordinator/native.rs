//! The native training backend: end-to-end fine-tuning on the rust
//! sparse substrate, no PJRT toolchain or AOT artifacts required.
//!
//! The model is the preset's full `n_layers`-deep pre-norm residual
//! stack, mirroring the L2 JAX definition's block structure
//! (`python/compile/model.py::model_forward`): token + learned position
//! embeddings, then per layer
//!
//! ```text
//! x   = x + MHA(LN(x; ln1))        (attention sub-block)
//! x   = x + FFN(LN(x; ln2))        (feed-forward sub-block)
//! ```
//!
//! followed by a final layer norm and the readout.  One deliberate
//! deviation from the JAX model (which carries a separate `['head']`
//! leaf, rotary embeddings on some blocks, and a router load-balance
//! aux loss): the native readout is **tied to the token embedding**
//! (`logits = LN(x; lnf) · E^T`), so the tied leaf doubles as the task
//! head and trains in every mode.  Per tuning mode:
//!
//! * **full** — embeddings + every layer's dense causal MHA, dense ReLU
//!   FFN, and layer norms, everything trained;
//! * **lora** — the backbone frozen, rank-r adapters on the six
//!   projections (q/k/v/o and both FFN matrices) of *every layer* plus
//!   the tied embedding/readout trained;
//! * **spt**  — LoRA's trainable set, with the *execution* swapped for
//!   the sparse substrate per layer: PQ + bucket-sort top-L sparse
//!   attention ([`MultiHeadSparseAttention`]) and the routed FFN over
//!   BSpMV ([`mha::routed_ffn_par`]).  Gradients flow only through kept
//!   attention entries and activated FFN blocks
//!   ([`crate::sparse::grad`]); each layer owns its per-head PQ
//!   codebooks, maintained by the DKM k-means refresh, and the
//!   router/top-G' selection is treated as non-differentiable, as in
//!   the paper's kernels.
//!
//! ## Parallelism and determinism
//!
//! `train_step` fans out over *fixed-size item chunks* (size
//! [`GRAD_CHUNK`], independent of the thread count): each chunk runs its
//! items' forwards + backwards sequentially into one shared [`GradAcc`]
//! (with a per-worker GEMM [`Workspace`] reused across ops), and the
//! per-chunk gradients and losses are then reduced in ascending chunk
//! order.  Chunking keeps gradient memory at O(batch / GRAD_CHUNK)
//! accumulators instead of O(batch) — which matters now that each
//! accumulator spans every layer's leaves — while the fixed chunk
//! boundaries keep the floating-point reduction tree identical at any
//! rayon pool size.  Together with the substrate's own guarantees
//! (every parallel GEMM/head/block path reduces in a fixed order) this
//! keeps the whole step deterministic: losses, parameters, and AdamW
//! moments are bit-identical whether the pool has 1 or 64 threads,
//! which the checkpoint-resume and thread-determinism tests rely on —
//! including the `n_layers >= 2` presets (`spt-nano-l2`,
//! `spt-mini-64-l4`).

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};
use rayon::prelude::*;

use super::backend::Backend;
use super::state::{adamw_update, AdamW, TrainState};
use crate::config::{presets, Mode, ModelConfig, RunConfig, Sparsity};
use crate::obs::{time_opt, PhaseTimes, StepObs};
use crate::runtime::HostTensor;
use crate::sparse::attention;
use crate::sparse::bspmv::{self, Routing};
use crate::sparse::grad;
use crate::sparse::mha::{self, MultiHeadSparseAttention};
use crate::sparse::pq::{self, Codebooks};
use crate::sparse::{Csr, Matrix, PackedB, Workspace};
use crate::util::rng::Rng;

/// Items per gradient-accumulation chunk in `train_step`.  Fixed (never
/// derived from the pool size) so the gradient reduction tree — and so
/// every result bit — is the same at any thread count.
const GRAD_CHUNK: usize = 4;

/// The always-available backend (see module docs).
#[derive(Debug, Default)]
pub struct NativeBackend {
    /// Memoized preset + leaf layout for the last `(model, mode)` seen,
    /// so repeated steps with an unchanged [`RunConfig`] don't
    /// re-deserialize the preset table and rebuild the layout per call.
    cache: Mutex<Option<LayoutCache>>,
}

#[derive(Debug)]
struct LayoutCache {
    model: String,
    mode: Mode,
    cfg: Arc<ModelConfig>,
    layout: Arc<Layout>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend::default()
    }

    /// The cached `(preset, layout)` pair for `rc`, rebuilding on a
    /// model/mode change.
    fn cached(&self, rc: &RunConfig) -> Result<(Arc<ModelConfig>, Arc<Layout>)> {
        let mut guard = self.cache.lock().expect("layout cache poisoned");
        if let Some(c) = guard.as_ref() {
            if c.model == rc.model && c.mode == rc.mode {
                return Ok((c.cfg.clone(), c.layout.clone()));
            }
        }
        let cfg = Arc::new(presets::model(&rc.model)?);
        let layout = Arc::new(Layout::new(&cfg, rc.mode)?);
        *guard = Some(LayoutCache {
            model: rc.model.clone(),
            mode: rc.mode,
            cfg: cfg.clone(),
            layout: layout.clone(),
        });
        Ok((cfg, layout))
    }
}

/// Leaf indices of one LoRA adapter pair.
#[derive(Debug, Clone, Copy)]
struct LoraIx {
    a: usize,
    b: usize,
}

/// Slots of the six adapted projections, indexing `LayerIx::lora` /
/// `LayerWeights::lora`.
const SLOT_Q: usize = 0;
const SLOT_K: usize = 1;
const SLOT_V: usize = 2;
const SLOT_O: usize = 3;
const SLOT_WI: usize = 4;
const SLOT_WO2: usize = 5;

/// Leaf indices of one transformer layer.
#[derive(Debug, Clone)]
pub(crate) struct LayerIx {
    ln1_scale: usize,
    ln1_bias: usize,
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    ln2_scale: usize,
    ln2_bias: usize,
    wi: usize,
    wo2: usize,
    lora: Option<[LoraIx; 6]>,
    router: Option<usize>,
    pq_cb: Option<usize>,
}

/// Static description of the native model: dimensions plus the index of
/// every leaf in the [`TrainState`] vectors.  Shared leaves (tied
/// embedding/readout, positions, final layer norm) come first, then one
/// [`LayerIx`] group per layer.  `pub(crate)` so the inference subsystem
/// (`crate::infer`) shares the exact model description the trainer uses.
#[derive(Debug, Clone)]
pub(crate) struct Layout {
    pub(crate) mode: Mode,
    pub(crate) vocab: usize,
    pub(crate) d: usize,
    pub(crate) dff: usize,
    pub(crate) max_seq: usize,
    pub(crate) heads: usize,
    pub(crate) d_head: usize,
    pub(crate) pq_m: usize,
    pub(crate) pq_e: usize,
    pub(crate) pq_dsub: usize,
    pub(crate) groups: usize,
    pub(crate) sparsity: Sparsity,
    /// Token embedding, tied to the readout (`logits = xf · tok^T`).
    pub(crate) tok: usize,
    pub(crate) pos: usize,
    pub(crate) lnf_scale: usize,
    pub(crate) lnf_bias: usize,
    pub(crate) layers: Vec<LayerIx>,
    pub(crate) shapes: Vec<(usize, usize)>,
    pub(crate) paths: Vec<String>,
    pub(crate) inits: Vec<LeafInit>,
}

/// How a leaf is initialized (recorded at registration time so
/// `init_state` stays a single deterministic pass over the leaves).
#[derive(Debug, Clone, Copy)]
pub(crate) enum LeafInit {
    /// `N(0, scale^2)` draws from the init RNG stream.
    Normal(f32),
    /// Constant fill, consuming no RNG draws (layer-norm scales start
    /// at 1; biases and LoRA `b` factors at 0).
    Const(f32),
}

/// Leaf registrar backing [`Layout::new`].
#[derive(Default)]
struct LeafBuilder {
    shapes: Vec<(usize, usize)>,
    paths: Vec<String>,
    inits: Vec<LeafInit>,
}

impl LeafBuilder {
    fn add(&mut self, path: impl Into<String>, rows: usize, cols: usize, init: LeafInit) -> usize {
        let ix = self.paths.len();
        self.paths.push(path.into());
        self.shapes.push((rows, cols));
        self.inits.push(init);
        ix
    }

    /// Fan-in scaled normal init for a dense `[rows, cols]` weight.
    fn fan_in(rows: usize) -> LeafInit {
        LeafInit::Normal(1.0 / (rows as f32).sqrt())
    }
}

impl Layout {
    pub(crate) fn new(cfg: &ModelConfig, mode: Mode) -> Result<Self> {
        let b = &cfg.block;
        let (d, dff) = (b.d_model, b.d_ffn);
        let (heads, d_head) = (b.n_heads(), b.d_head);
        let (pq_m, pq_e, pq_dsub) = (b.pq_m(), b.pq_codewords, b.pq_dsub);
        if pq_m * pq_dsub != d_head {
            bail!("PQ subspaces ({pq_m} x {pq_dsub}) do not tile d_head {d_head}");
        }
        let n_layers = cfg.n_layers.max(1);
        let r = b.lora_rank;
        let mut lb = LeafBuilder::default();
        let tok = lb.add("['embed']['tok']", cfg.vocab_size, d, LeafInit::Normal(0.02));
        let pos = lb.add("['embed']['pos']", cfg.max_seq, d, LeafInit::Normal(0.02));
        let mut layers = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            let p = |leaf: &str| format!("['blocks'][{li}]{leaf}");
            let ln1_scale = lb.add(p("['ln1']['scale']"), 1, d, LeafInit::Const(1.0));
            let ln1_bias = lb.add(p("['ln1']['bias']"), 1, d, LeafInit::Const(0.0));
            let wq = lb.add(p("['attn']['wq']"), d, d, LeafBuilder::fan_in(d));
            let wk = lb.add(p("['attn']['wk']"), d, d, LeafBuilder::fan_in(d));
            let wv = lb.add(p("['attn']['wv']"), d, d, LeafBuilder::fan_in(d));
            let wo = lb.add(p("['attn']['wo']"), d, d, LeafBuilder::fan_in(d));
            let ln2_scale = lb.add(p("['ln2']['scale']"), 1, d, LeafInit::Const(1.0));
            let ln2_bias = lb.add(p("['ln2']['bias']"), 1, d, LeafInit::Const(0.0));
            let wi = lb.add(p("['ffn']['wi']"), d, dff, LeafBuilder::fan_in(d));
            let wo2 = lb.add(p("['ffn']['wo']"), dff, d, LeafBuilder::fan_in(dff));
            let lora = if mode == Mode::Lora || mode == Mode::Spt {
                let mut pair = |name: &str, rows: usize, cols: usize| LoraIx {
                    a: lb.add(
                        p(&format!("['lora']['{name}']['a']")),
                        rows,
                        r,
                        LeafBuilder::fan_in(rows),
                    ),
                    b: lb.add(
                        p(&format!("['lora']['{name}']['b']")),
                        r,
                        cols,
                        LeafInit::Const(0.0),
                    ),
                };
                Some([
                    pair("q", d, d),
                    pair("k", d, d),
                    pair("v", d, d),
                    pair("o", d, d),
                    pair("wi", d, dff),
                    pair("wo", dff, d),
                ])
            } else {
                None
            };
            let (router, pq_cb) = if mode == Mode::Spt {
                (
                    Some(lb.add(p("['router']"), d, b.ffn_groups, LeafBuilder::fan_in(d))),
                    Some(lb.add(
                        p("['pq']['codebooks']"),
                        heads,
                        pq_m * pq_e * pq_dsub,
                        LeafInit::Normal(0.05),
                    )),
                )
            } else {
                (None, None)
            };
            layers.push(LayerIx {
                ln1_scale,
                ln1_bias,
                wq,
                wk,
                wv,
                wo,
                ln2_scale,
                ln2_bias,
                wi,
                wo2,
                lora,
                router,
                pq_cb,
            });
        }
        let lnf_scale = lb.add("['lnf']['scale']", 1, d, LeafInit::Const(1.0));
        let lnf_bias = lb.add("['lnf']['bias']", 1, d, LeafInit::Const(0.0));
        Ok(Layout {
            mode,
            vocab: cfg.vocab_size,
            d,
            dff,
            max_seq: cfg.max_seq,
            heads,
            d_head,
            pq_m,
            pq_e,
            pq_dsub,
            groups: b.ffn_groups,
            sparsity: b.sparsity,
            tok,
            pos,
            lnf_scale,
            lnf_bias,
            layers,
            shapes: lb.shapes,
            paths: lb.paths,
            inits: lb.inits,
        })
    }

    pub(crate) fn n_leaves(&self) -> usize {
        self.paths.len()
    }

    /// Which leaves receive AdamW updates in this mode.
    fn trainable(&self) -> Vec<bool> {
        let mut t = vec![false; self.n_leaves()];
        // The tied embedding/readout is the task head: it trains in
        // every mode (in lora/spt it is the only non-adapter leaf that
        // moves, receiving gradient from both the readout and the
        // embedding lookup).
        t[self.tok] = true;
        if self.mode == Mode::Full {
            t[self.pos] = true;
            t[self.lnf_scale] = true;
            t[self.lnf_bias] = true;
        }
        for lx in &self.layers {
            match self.mode {
                Mode::Full => {
                    for ix in [
                        lx.ln1_scale,
                        lx.ln1_bias,
                        lx.wq,
                        lx.wk,
                        lx.wv,
                        lx.wo,
                        lx.ln2_scale,
                        lx.ln2_bias,
                        lx.wi,
                        lx.wo2,
                    ] {
                        t[ix] = true;
                    }
                }
                Mode::Lora | Mode::Spt => {
                    if let Some(pairs) = &lx.lora {
                        for p in pairs {
                            t[p.a] = true;
                            t[p.b] = true;
                        }
                    }
                    // The router and PQ codebooks are not SGD-trained:
                    // the top-G' / top-L selections are
                    // non-differentiable and codebooks refresh via DKM
                    // k-means.
                }
            }
        }
        t
    }
}

/// Materialized effective weights of one layer (base + LoRA deltas),
/// with the GEMM microkernel's packed-B panels cached for the forward
/// projections (pack-once: the weights are constant within a step — and
/// for a whole inference session — so repeated products skip the
/// per-call packing pass; the cache is invalidated by construction
/// because `Weights` is re-materialized after every optimizer update).
pub(crate) struct LayerWeights {
    pub(crate) ln1_scale: Matrix,
    pub(crate) ln1_bias: Matrix,
    pub(crate) wq: Matrix,
    pub(crate) wk: Matrix,
    pub(crate) wv: Matrix,
    pub(crate) wo: Matrix,
    pub(crate) ln2_scale: Matrix,
    pub(crate) ln2_bias: Matrix,
    pub(crate) wi: Matrix,
    pub(crate) wo2: Matrix,
    /// Packed panels of the four attention projections (always used by
    /// the forward, in every mode).
    pub(crate) wq_p: PackedB,
    pub(crate) wk_p: PackedB,
    pub(crate) wv_p: PackedB,
    pub(crate) wo_p: PackedB,
    /// Packed panels of the dense-FFN matrices (full/lora forward; the
    /// spt forward multiplies `W_I`/`W_O` block-wise through BSpMV, whose
    /// sub-NR block widths don't tile the full-matrix panels).
    pub(crate) wi_p: Option<PackedB>,
    pub(crate) wo2_p: Option<PackedB>,
    /// Adapter factors (a, b) per slot, aligned with `LayerIx::lora`.
    pub(crate) lora: Option<Vec<(Matrix, Matrix)>>,
    pub(crate) router: Option<Matrix>,
    pub(crate) codebooks: Option<Vec<Codebooks>>,
}

/// Materialized effective weights for one step: the shared tied
/// embedding/readout and final layer norm plus one [`LayerWeights`] per
/// layer.  `pub(crate)` so `crate::infer` materializes a session's
/// weights through exactly this path.
pub(crate) struct Weights {
    /// `[vocab, d]`; embedding rows on the way in, readout columns
    /// (transposed) on the way out.
    pub(crate) tok: Matrix,
    pub(crate) lnf_scale: Matrix,
    pub(crate) lnf_bias: Matrix,
    pub(crate) layers: Vec<LayerWeights>,
}

fn leaf_matrix(layout: &Layout, state: &TrainState, ix: usize) -> Result<Matrix> {
    let (rows, cols) = layout.shapes[ix];
    let data = state
        .params
        .get(ix)
        .with_context(|| format!("missing leaf {ix}"))?
        .as_f32()?;
    if data.len() != rows * cols {
        bail!(
            "leaf {} ('{}') has {} elements, layout wants {}x{}",
            ix,
            layout.paths[ix],
            data.len(),
            rows,
            cols
        );
    }
    Ok(Matrix::from_vec(rows, cols, data.to_vec()))
}

fn materialize_layer(layout: &Layout, lx: &LayerIx, state: &TrainState) -> Result<LayerWeights> {
    let lora = match &lx.lora {
        Some(pairs) => Some(
            pairs
                .iter()
                .map(|p| {
                    Ok((
                        leaf_matrix(layout, state, p.a)?,
                        leaf_matrix(layout, state, p.b)?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?,
        ),
        None => None,
    };
    let eff = |base_ix: usize, slot: usize| -> Result<Matrix> {
        let mut w = leaf_matrix(layout, state, base_ix)?;
        if let Some(mats) = &lora {
            let (a, b) = &mats[slot];
            w.add_assign(&a.matmul(b));
        }
        Ok(w)
    };
    let wq = eff(lx.wq, SLOT_Q)?;
    let wk = eff(lx.wk, SLOT_K)?;
    let wv = eff(lx.wv, SLOT_V)?;
    let wo = eff(lx.wo, SLOT_O)?;
    let wi = eff(lx.wi, SLOT_WI)?;
    let wo2 = eff(lx.wo2, SLOT_WO2)?;
    // Pack-once: the forward's B operands, packed here so every item (and
    // every decode step) skips the per-call packing pass.
    let (wq_p, wk_p, wv_p, wo_p) = (
        PackedB::pack(&wq),
        PackedB::pack(&wk),
        PackedB::pack(&wv),
        PackedB::pack(&wo),
    );
    let (wi_p, wo2_p) = if layout.mode == Mode::Spt {
        (None, None)
    } else {
        (Some(PackedB::pack(&wi)), Some(PackedB::pack(&wo2)))
    };
    let router = match lx.router {
        Some(ix) => Some(leaf_matrix(layout, state, ix)?),
        None => None,
    };
    let codebooks = match lx.pq_cb {
        Some(ix) => {
            let flat = state.params[ix].as_f32()?;
            let stride = layout.pq_m * layout.pq_e * layout.pq_dsub;
            Some(
                (0..layout.heads)
                    .map(|h| Codebooks {
                        m: layout.pq_m,
                        e: layout.pq_e,
                        dsub: layout.pq_dsub,
                        data: flat[h * stride..(h + 1) * stride].to_vec(),
                    })
                    .collect(),
            )
        }
        None => None,
    };
    Ok(LayerWeights {
        ln1_scale: leaf_matrix(layout, state, lx.ln1_scale)?,
        ln1_bias: leaf_matrix(layout, state, lx.ln1_bias)?,
        wq,
        wk,
        wv,
        wo,
        ln2_scale: leaf_matrix(layout, state, lx.ln2_scale)?,
        ln2_bias: leaf_matrix(layout, state, lx.ln2_bias)?,
        wi,
        wo2,
        wq_p,
        wk_p,
        wv_p,
        wo_p,
        wi_p,
        wo2_p,
        lora,
        router,
        codebooks,
    })
}

impl Weights {
    pub(crate) fn materialize(layout: &Layout, state: &TrainState) -> Result<Self> {
        if state.params.len() != layout.n_leaves() {
            bail!(
                "state has {} leaves, layout wants {} (model/mode mismatch?)",
                state.params.len(),
                layout.n_leaves()
            );
        }
        let layers = layout
            .layers
            .iter()
            .map(|lx| materialize_layer(layout, lx, state))
            .collect::<Result<Vec<_>>>()?;
        Ok(Weights {
            tok: leaf_matrix(layout, state, layout.tok)?,
            lnf_scale: leaf_matrix(layout, state, layout.lnf_scale)?,
            lnf_bias: leaf_matrix(layout, state, layout.lnf_bias)?,
            layers,
        })
    }
}

/// Per-layer forward caches consumed by the backward pass — and, via
/// `crate::infer`, the prefill output that seeds a decode cache (the
/// per-head K/V projections are exactly the cache contents).
pub(crate) struct LayerTrace {
    /// The residual-stream input this layer consumed.
    pub(crate) x_in: Matrix,
    /// `ln1(x_in)` — the attention sub-block's input.
    pub(crate) a_in: Matrix,
    pub(crate) q: Vec<Matrix>,
    pub(crate) k: Vec<Matrix>,
    pub(crate) v: Vec<Matrix>,
    /// spt: per-head post-softmax attention CSRs.
    pub(crate) attn: Option<Vec<Csr>>,
    pub(crate) attn_out: Matrix,
    /// `x_in + attn_out · W_O` — the FFN sub-block's residual input.
    pub(crate) x_mid: Matrix,
    /// `ln2(x_mid)` — the FFN sub-block's input.
    pub(crate) f_in: Matrix,
    /// full/lora: dense FFN hidden activations (post-ReLU).
    pub(crate) h1: Option<Matrix>,
    /// spt: the routing the FFN forward used (backward follows it).
    pub(crate) routing: Option<Routing>,
}

/// Per-item forward caches: one [`LayerTrace`] per layer plus the final
/// residual stream and its layer-normed readout input.
pub(crate) struct ItemTrace {
    pub(crate) layers: Vec<LayerTrace>,
    /// Last layer's output (input to the final layer norm).
    pub(crate) x_out: Matrix,
    /// `lnf(x_out)` — what the tied readout multiplies.
    pub(crate) xf: Matrix,
}

/// Gradient accumulator: one flat buffer per *trainable* leaf.
struct GradAcc {
    g: Vec<Option<Vec<f32>>>,
}

impl GradAcc {
    fn new(layout: &Layout) -> Self {
        let g = layout
            .trainable()
            .iter()
            .enumerate()
            .map(|(ix, &on)| {
                let (r, c) = layout.shapes[ix];
                on.then(|| vec![0.0f32; r * c])
            })
            .collect();
        GradAcc { g }
    }

    /// Accumulate into leaf `ix` (no-op when the leaf is frozen).
    fn add(&mut self, ix: usize, dm: &Matrix) {
        if let Some(buf) = &mut self.g[ix] {
            debug_assert_eq!(buf.len(), dm.data.len());
            for (o, &x) in buf.iter_mut().zip(&dm.data) {
                *o += x;
            }
        }
    }

    /// Route an effective-weight gradient to the base leaf (full mode)
    /// or decompose onto the layer's LoRA factors (`W_eff = W + a b`
    /// gives `da = dW b^T`, `db = a^T dW`; the frozen base absorbs
    /// nothing).
    fn add_weight(
        &mut self,
        lx: &LayerIx,
        lw: &LayerWeights,
        slot: usize,
        base_ix: usize,
        dw: &Matrix,
        ws: &mut Workspace,
    ) {
        match (&lx.lora, &lw.lora) {
            (Some(ixs), Some(mats)) => {
                let (a, b) = &mats[slot];
                self.add(ixs[slot].a, &grad::matmul_dx_ws(dw, b, ws));
                self.add(ixs[slot].b, &grad::matmul_dw_ws(a, dw, ws));
            }
            _ => self.add(base_ix, dw),
        }
    }

    /// Accumulate another accumulator's gradients leaf by leaf.  Calling
    /// this in ascending chunk order reproduces one fixed reduction
    /// order, so the merged gradients are identical at any pool size.
    fn merge(&mut self, other: &GradAcc) {
        for (mine, theirs) in self.g.iter_mut().zip(&other.g) {
            if let (Some(a), Some(b)) = (mine.as_mut(), theirs.as_ref()) {
                debug_assert_eq!(a.len(), b.len());
                for (o, &x) in a.iter_mut().zip(b) {
                    *o += x;
                }
            }
        }
    }

    /// Scatter token/position embedding gradients.  The token leaf is
    /// tied to the readout and trainable in every mode; the position
    /// leaf is frozen outside full mode and `add`-style no-ops.
    fn scatter_embed(&mut self, layout: &Layout, tok: &[i32], dx: &Matrix) {
        let d = layout.d;
        if let Some(buf) = &mut self.g[layout.tok] {
            for (s, &t) in tok.iter().enumerate() {
                let off = t as usize * d;
                for (o, &g) in buf[off..off + d].iter_mut().zip(dx.row(s)) {
                    *o += g;
                }
            }
        }
        if let Some(buf) = &mut self.g[layout.pos] {
            for s in 0..dx.rows {
                let off = s * d;
                for (o, &g) in buf[off..off + d].iter_mut().zip(dx.row(s)) {
                    *o += g;
                }
            }
        }
    }
}

/// Column-slice the H heads out of a `[n, H*dh]` matrix.
pub(crate) fn split_heads(x: &Matrix, heads: usize, dh: usize) -> Vec<Matrix> {
    assert_eq!(x.cols, heads * dh, "head split shape mismatch");
    (0..heads)
        .map(|h| {
            let mut m = Matrix::zeros(x.rows, dh);
            for r in 0..x.rows {
                m.row_mut(r).copy_from_slice(&x.row(r)[h * dh..(h + 1) * dh]);
            }
            m
        })
        .collect()
}

/// Inverse of [`split_heads`].
pub(crate) fn concat_heads(parts: &[Matrix]) -> Matrix {
    let rows = parts[0].rows;
    let dh = parts[0].cols;
    let mut out = Matrix::zeros(rows, parts.len() * dh);
    for (h, p) in parts.iter().enumerate() {
        assert_eq!(p.rows, rows, "head {h} row mismatch");
        for r in 0..rows {
            out.row_mut(r)[h * dh..(h + 1) * dh].copy_from_slice(p.row(r));
        }
    }
    out
}

fn unzip3(v: Vec<(Matrix, Matrix, Matrix)>) -> (Vec<Matrix>, Vec<Matrix>, Vec<Matrix>) {
    let mut a = Vec::with_capacity(v.len());
    let mut b = Vec::with_capacity(v.len());
    let mut c = Vec::with_capacity(v.len());
    for (x, y, z) in v {
        a.push(x);
        b.push(y);
        c.push(z);
    }
    (a, b, c)
}

/// Summed cross-entropy over the rows plus `(softmax - onehot) *
/// inv_count` logit gradients (`inv_count` = 1 / total positions in the
/// mini-batch, so accumulating per-item gradients yields the mean-loss
/// gradient).
fn ce_loss_and_grad(
    logits: &Matrix,
    targets: &[i32],
    inv_count: f32,
    vocab: usize,
) -> Result<(f32, Matrix)> {
    assert_eq!(logits.rows, targets.len(), "logits/targets row mismatch");
    let mut dl = Matrix::zeros(logits.rows, logits.cols);
    let mut loss = 0.0f64;
    for r in 0..logits.rows {
        let t = targets[r] as usize;
        if t >= vocab {
            bail!("target token {t} out of vocabulary {vocab}");
        }
        let row = logits.row(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let drow = dl.row_mut(r);
        let mut sum = 0.0f32;
        for (o, &x) in drow.iter_mut().zip(row) {
            *o = (x - mx).exp();
            sum += *o;
        }
        let inv = 1.0 / sum.max(1e-30);
        let p_t = (drow[t] * inv).max(1e-30);
        loss -= (p_t as f64).ln();
        for o in drow.iter_mut() {
            *o *= inv * inv_count;
        }
        drow[t] -= inv_count;
    }
    Ok((loss as f32, dl))
}

/// Summed cross-entropy only (eval paths — no gradient allocation).
fn ce_loss(logits: &Matrix, targets: &[i32], vocab: usize) -> Result<f32> {
    assert_eq!(logits.rows, targets.len(), "logits/targets row mismatch");
    let mut loss = 0.0f64;
    for r in 0..logits.rows {
        let t = targets[r] as usize;
        if t >= vocab {
            bail!("target token {t} out of vocabulary {vocab}");
        }
        let row = logits.row(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &x in row {
            sum += (x - mx).exp();
        }
        let p_t = ((logits.at(r, t) - mx).exp() / sum.max(1e-30)).max(1e-30);
        loss -= (p_t as f64).ln();
    }
    Ok(loss as f32)
}

/// Per-layer mean attention density (nnz ratio of the post-softmax
/// top-L CSRs, averaged over heads) from a probe trace.  Empty outside
/// spt mode.  Pure read of caches the forward materialized anyway.
fn attn_density(trace: &ItemTrace) -> Vec<f64> {
    trace
        .layers
        .iter()
        .filter_map(|lt| lt.attn.as_ref())
        .map(|csrs| {
            let sum: f64 = csrs
                .iter()
                .map(|c| c.nnz() as f64 / (c.rows * c.cols).max(1) as f64)
                .sum();
            sum / csrs.len().max(1) as f64
        })
        .collect()
}

/// Per-layer routed-FFN expert load (tokens routed to each group) from
/// a probe trace.  Empty outside spt mode.
fn expert_load(trace: &ItemTrace) -> Vec<Vec<u64>> {
    trace
        .layers
        .iter()
        .filter_map(|lt| lt.routing.as_ref())
        .map(|r| {
            let mut loads = vec![0u64; r.g];
            for mrow in &r.mask {
                for (g, &on) in mrow.iter().enumerate() {
                    if on {
                        loads[g] += 1;
                    }
                }
            }
            loads
        })
        .collect()
}

/// Bytes of one item's saved activations (the backward's working set
/// per item) — the f32 matrices, attention CSRs, and routing buffers a
/// probe trace holds.
fn trace_bytes(trace: &ItemTrace) -> u64 {
    let mat = |m: &Matrix| (m.data.len() * 4) as u64;
    let mut total = mat(&trace.x_out) + mat(&trace.xf);
    for lt in &trace.layers {
        total += mat(&lt.x_in)
            + mat(&lt.a_in)
            + mat(&lt.attn_out)
            + mat(&lt.x_mid)
            + mat(&lt.f_in);
        for m in lt.q.iter().chain(&lt.k).chain(&lt.v) {
            total += mat(m);
        }
        if let Some(csrs) = &lt.attn {
            total += csrs.iter().map(|c| c.bytes() as u64).sum::<u64>();
        }
        if let Some(h1) = &lt.h1 {
            total += mat(h1);
        }
        if let Some(r) = &lt.routing {
            total += r.mask.iter().map(|m| m.len() as u64).sum::<u64>()
                + r.gate.iter().map(|g| (g.len() * 4) as u64).sum::<u64>();
        }
    }
    total
}

impl NativeBackend {
    fn model_config(&self, rc: &RunConfig) -> Result<Arc<ModelConfig>> {
        Ok(self.cached(rc)?.0)
    }

    pub(crate) fn layout(&self, rc: &RunConfig) -> Result<Arc<Layout>> {
        Ok(self.cached(rc)?.1)
    }

    /// Token + learned positional embedding for one sequence.
    pub(crate) fn embed(
        &self,
        layout: &Layout,
        state: &TrainState,
        tok: &[i32],
    ) -> Result<Matrix> {
        self.embed_at(layout, state, tok, 0)
    }

    /// [`Self::embed`] with the sequence starting at absolute position
    /// `pos0` — the decode path embeds each new token at its own
    /// position; row `s` here is bit-identical to row `pos0 + s` of a
    /// full-sequence embed (the sum is row-local).
    pub(crate) fn embed_at(
        &self,
        layout: &Layout,
        state: &TrainState,
        tok: &[i32],
        pos0: usize,
    ) -> Result<Matrix> {
        let te = state.params[layout.tok].as_f32()?;
        let pe = state.params[layout.pos].as_f32()?;
        let d = layout.d;
        if pos0 + tok.len() > layout.max_seq {
            bail!(
                "sequence {} exceeds max_seq {}",
                pos0 + tok.len(),
                layout.max_seq
            );
        }
        let mut x = Matrix::zeros(tok.len(), d);
        for (s, &t) in tok.iter().enumerate() {
            let t = t as usize;
            if t >= layout.vocab {
                bail!("token {t} out of vocabulary {}", layout.vocab);
            }
            let trow = &te[t * d..(t + 1) * d];
            let prow = &pe[(pos0 + s) * d..(pos0 + s + 1) * d];
            for ((o, &a), &b) in x.row_mut(s).iter_mut().zip(trow).zip(prow) {
                *o = a + b;
            }
        }
        Ok(x)
    }

    /// Build the per-layer sparse multi-head layers once per call (spt
    /// mode only): each layer's codebooks are constant within a step and
    /// `L` depends only on the sequence length, so per-item construction
    /// would just clone codebooks `batch` times.
    pub(crate) fn sparse_layers(
        &self,
        layout: &Layout,
        w: &Weights,
        seq: usize,
    ) -> Result<Option<Vec<MultiHeadSparseAttention>>> {
        let l = layout.sparsity.topl(seq).min(seq);
        self.sparse_layers_with_l(layout, w, l)
    }

    /// [`Self::sparse_layers`] with an explicit sparsity strength —
    /// the inference prefill pins `l` to the *full* target sequence's L
    /// (clamped to the prompt length) so prefill + decode reproduce a
    /// full-sequence forward bit for bit.
    pub(crate) fn sparse_layers_with_l(
        &self,
        layout: &Layout,
        w: &Weights,
        l: usize,
    ) -> Result<Option<Vec<MultiHeadSparseAttention>>> {
        if layout.mode != Mode::Spt {
            return Ok(None);
        }
        let layers = w
            .layers
            .iter()
            .map(|lw| {
                let cbs = lw.codebooks.clone().context("spt mode without codebooks")?;
                Ok(MultiHeadSparseAttention::new(cbs, l, true))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Some(layers))
    }

    /// One sequence forward through the whole pre-norm stack, up to the
    /// final layer norm (no readout).  `ws` is the item's reusable GEMM
    /// workspace.
    pub(crate) fn forward_model(
        &self,
        layout: &Layout,
        w: &Weights,
        state: &TrainState,
        tok: &[i32],
        sparse: Option<&[MultiHeadSparseAttention]>,
        ws: &mut Workspace,
    ) -> Result<ItemTrace> {
        self.forward_model_inner(layout, w, state, tok, sparse, ws, None)
    }

    /// [`Self::forward_model`] with optional per-phase timing (the obs
    /// probe forward).  With `pt = None` — every pre-existing caller —
    /// each closure runs directly and no clock exists anywhere on the
    /// path; with `Some`, [`time_opt`] reads the clock around each phase
    /// at this sequential boundary.  Either way the closures compute the
    /// exact expressions of the untimed forward, in the same order, so
    /// the trace is bit-identical.
    #[allow(clippy::too_many_arguments)]
    fn forward_model_inner(
        &self,
        layout: &Layout,
        w: &Weights,
        state: &TrainState,
        tok: &[i32],
        sparse: Option<&[MultiHeadSparseAttention]>,
        ws: &mut Workspace,
        mut pt: Option<&mut PhaseTimes>,
    ) -> Result<ItemTrace> {
        let mut x = time_opt(&mut pt, "embed", || self.embed(layout, state, tok))?;
        let mut layers = Vec::with_capacity(w.layers.len());
        for (li, lw) in w.layers.iter().enumerate() {
            let a_in = time_opt(&mut pt, "ln", || {
                grad::layer_norm(&x, &lw.ln1_scale, &lw.ln1_bias)
            });
            let (q, k, v, ys, attn) = time_opt(&mut pt, "mha", || -> Result<_> {
                let q =
                    split_heads(&a_in.matmul_packed(&lw.wq_p), layout.heads, layout.d_head);
                let k =
                    split_heads(&a_in.matmul_packed(&lw.wk_p), layout.heads, layout.d_head);
                let v =
                    split_heads(&a_in.matmul_packed(&lw.wv_p), layout.heads, layout.d_head);
                let (ys, attn) = if layout.mode == Mode::Spt {
                    let layer = &sparse.context("spt mode without sparse layers")?[li];
                    let (ys, csrs) = layer.forward_cached(&q, &k, &v);
                    (ys, Some(csrs))
                } else {
                    let ys: Vec<Matrix> = (0..layout.heads)
                        .into_par_iter()
                        .map_init(Workspace::default, |hws, h| {
                            attention::dense_attention_ws(&q[h], &k[h], &v[h], true, hws)
                        })
                        .collect();
                    (ys, None)
                };
                Ok((q, k, v, ys, attn))
            })?;
            let (attn_out, x_mid) = time_opt(&mut pt, "mha", || {
                let attn_out = concat_heads(&ys);
                let x_mid = x.add(&attn_out.matmul_packed(&lw.wo_p));
                (attn_out, x_mid)
            });
            let f_in = time_opt(&mut pt, "ln", || {
                grad::layer_norm(&x_mid, &lw.ln2_scale, &lw.ln2_bias)
            });
            let (f, h1, routing) = time_opt(&mut pt, "ffn", || -> Result<_> {
                if layout.mode == Mode::Spt {
                    let router = lw.router.as_ref().context("spt mode without router")?;
                    let scores = f_in.matmul_ws(router, ws);
                    let g_active =
                        layout.sparsity.active_groups(layout.groups).min(layout.groups);
                    let routing = bspmv::route(&scores, g_active);
                    let f = mha::routed_ffn_par(&f_in, &lw.wi, &lw.wo2, &routing);
                    Ok((f, None, Some(routing)))
                } else {
                    let wi_p = lw.wi_p.as_ref().context("dense mode without packed W_I")?;
                    let wo2_p =
                        lw.wo2_p.as_ref().context("dense mode without packed W_O")?;
                    let h1 = f_in.matmul_packed(wi_p).relu();
                    let f = h1.matmul_packed(wo2_p);
                    Ok((f, Some(h1), None))
                }
            })?;
            let x_next = x_mid.add(&f);
            layers.push(LayerTrace {
                x_in: x,
                a_in,
                q,
                k,
                v,
                attn,
                attn_out,
                x_mid,
                f_in,
                h1,
                routing,
            });
            x = x_next;
        }
        let xf = time_opt(&mut pt, "ln", || grad::layer_norm(&x, &w.lnf_scale, &w.lnf_bias));
        Ok(ItemTrace { layers, x_out: x, xf })
    }

    /// One sequence forward; returns the backward caches and the logits
    /// (`xf · tok^T` through the tied readout, on the NT kernel).
    pub(crate) fn forward_item(
        &self,
        layout: &Layout,
        w: &Weights,
        state: &TrainState,
        tok: &[i32],
        sparse: Option<&[MultiHeadSparseAttention]>,
        ws: &mut Workspace,
    ) -> Result<(ItemTrace, Matrix)> {
        let trace = self.forward_model(layout, w, state, tok, sparse, ws)?;
        let logits = grad::matmul_dx_ws(&trace.xf, &w.tok, ws);
        Ok((trace, logits))
    }

    /// One sequence backward; accumulates leaf gradients into `acc`.
    /// `ws` is the item's reusable GEMM workspace.
    #[allow(clippy::too_many_arguments)]
    fn backward_item(
        &self,
        layout: &Layout,
        w: &Weights,
        trace: &ItemTrace,
        tok: &[i32],
        dlogits: &Matrix,
        sparse: Option<&[MultiHeadSparseAttention]>,
        acc: &mut GradAcc,
        ws: &mut Workspace,
    ) -> Result<()> {
        // Tied readout: dTok += dlogits^T · xf; dxf = dlogits · tok.
        acc.add(layout.tok, &grad::matmul_dw_ws(dlogits, &trace.xf, ws));
        let dxf = dlogits.matmul_ws(&w.tok, ws);
        // Final layer norm.
        let (mut dx, dlnf_s, dlnf_b) =
            grad::layer_norm_backward(&trace.x_out, &w.lnf_scale, &dxf);
        acc.add(layout.lnf_scale, &dlnf_s);
        acc.add(layout.lnf_bias, &dlnf_b);
        // Layer-by-layer backward, deepest first.
        for li in (0..trace.layers.len()).rev() {
            let lt = &trace.layers[li];
            let lx = &layout.layers[li];
            let lw = &w.layers[li];
            // FFN sub-block: x_next = x_mid + FFN(f_in); dx hits both
            // the residual and the FFN branch.
            let (df_in, dwi_eff, dwo2_eff) = if layout.mode == Mode::Spt {
                let routing = lt.routing.as_ref().context("missing routing trace")?;
                mha::routed_ffn_backward_par(&lt.f_in, &lw.wi, &lw.wo2, routing, &dx)
            } else {
                let h1 = lt.h1.as_ref().context("missing ffn trace")?;
                let dwo2 = grad::matmul_dw_ws(h1, &dx, ws);
                let dpre = grad::relu_backward(h1, &grad::matmul_dx_ws(&dx, &lw.wo2, ws));
                let dwi = grad::matmul_dw_ws(&lt.f_in, &dpre, ws);
                let dff = grad::matmul_dx_ws(&dpre, &lw.wi, ws);
                (dff, dwi, dwo2)
            };
            acc.add_weight(lx, lw, SLOT_WI, lx.wi, &dwi_eff, ws);
            acc.add_weight(lx, lw, SLOT_WO2, lx.wo2, &dwo2_eff, ws);
            let (dx_mid_ln, dln2_s, dln2_b) =
                grad::layer_norm_backward(&lt.x_mid, &lw.ln2_scale, &df_in);
            acc.add(lx.ln2_scale, &dln2_s);
            acc.add(lx.ln2_bias, &dln2_b);
            let dx_mid = dx.add(&dx_mid_ln);
            // Attention output projection: x_mid = x_in + attn_out · W_O.
            let dwo_eff = grad::matmul_dw_ws(&lt.attn_out, &dx_mid, ws);
            acc.add_weight(lx, lw, SLOT_O, lx.wo, &dwo_eff, ws);
            let dy_heads = split_heads(
                &grad::matmul_dx_ws(&dx_mid, &lw.wo, ws),
                layout.heads,
                layout.d_head,
            );
            // Attention core.
            let (dq_h, dk_h, dv_h) = if layout.mode == Mode::Spt {
                let layer = &sparse.context("spt mode without sparse layers")?[li];
                let attn = lt.attn.as_ref().context("missing attn trace")?;
                layer.backward(&lt.q, &lt.k, &lt.v, attn, &dy_heads)
            } else {
                let per: Vec<(Matrix, Matrix, Matrix)> = (0..layout.heads)
                    .into_par_iter()
                    .map_init(Workspace::default, |hws, h| {
                        grad::dense_attention_backward_ws(
                            &lt.q[h], &lt.k[h], &lt.v[h], true, &dy_heads[h], hws,
                        )
                    })
                    .collect();
                unzip3(per)
            };
            let dq = concat_heads(&dq_h);
            let dk = concat_heads(&dk_h);
            let dv = concat_heads(&dv_h);
            let dwq_eff = grad::matmul_dw_ws(&lt.a_in, &dq, ws);
            acc.add_weight(lx, lw, SLOT_Q, lx.wq, &dwq_eff, ws);
            let dwk_eff = grad::matmul_dw_ws(&lt.a_in, &dk, ws);
            acc.add_weight(lx, lw, SLOT_K, lx.wk, &dwk_eff, ws);
            let dwv_eff = grad::matmul_dw_ws(&lt.a_in, &dv, ws);
            acc.add_weight(lx, lw, SLOT_V, lx.wv, &dwv_eff, ws);
            // Back through ln1 into this layer's residual input (the
            // effective weights carry the LoRA path too).
            let mut da_in = grad::matmul_dx_ws(&dq, &lw.wq, ws);
            da_in.add_assign(&grad::matmul_dx_ws(&dk, &lw.wk, ws));
            da_in.add_assign(&grad::matmul_dx_ws(&dv, &lw.wv, ws));
            let (dx_ln1, dln1_s, dln1_b) =
                grad::layer_norm_backward(&lt.x_in, &lw.ln1_scale, &da_in);
            acc.add(lx.ln1_scale, &dln1_s);
            acc.add(lx.ln1_bias, &dln1_b);
            dx = dx_mid.add(&dx_ln1);
        }
        // Embedding gradients: the tied token leaf collects in every
        // mode (it also took the readout gradient above); positions only
        // in full mode.
        acc.scatter_embed(layout, tok, &dx);
        Ok(())
    }

    fn check_batch(
        &self,
        rc: &RunConfig,
        tokens: &[i32],
        targets: Option<&[i32]>,
    ) -> Result<(usize, usize)> {
        let (batch, seq) = self.workload(rc)?;
        if tokens.len() != batch * seq {
            bail!(
                "token buffer has {} entries, workload wants {}x{}",
                tokens.len(),
                batch,
                seq
            );
        }
        if let Some(t) = targets {
            if t.len() != tokens.len() {
                bail!("targets/tokens length mismatch");
            }
        }
        Ok((batch, seq))
    }

    /// Forward + backward over the whole mini-batch with the chunked
    /// item fan-out (no optimizer update).  Returns the mean loss, the
    /// merged gradient accumulator, and the largest per-worker GEMM
    /// workspace high-water observed (bytes) — a pure read of buffer
    /// capacities for the obs memory-truth channel.
    fn grad_step(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f32, GradAcc, u64)> {
        let (batch, seq) = self.check_batch(rc, tokens, Some(targets))?;
        let layout = self.layout(rc)?;
        let w = Weights::materialize(&layout, state)?;
        let sparse = self.sparse_layers(&layout, &w, seq)?;
        let inv_count = 1.0 / (batch * seq) as f32;
        // Fan out over fixed-size item chunks: each chunk accumulates
        // its items sequentially into one GradAcc (per-worker GEMM
        // workspace reused across the chunk's ops), so gradient memory
        // is O(chunks) while the reduction tree stays independent of
        // the pool size.
        let layout_ref: &Layout = &layout;
        let w_ref = &w;
        let sparse_ref = sparse.as_deref();
        let n_chunks = batch.div_ceil(GRAD_CHUNK);
        let per_chunk: Result<Vec<(f64, GradAcc, u64)>> = (0..n_chunks)
            .into_par_iter()
            .map_init(Workspace::default, |ws, ci| {
                let mut acc = GradAcc::new(layout_ref);
                let mut lsum = 0.0f64;
                for bi in ci * GRAD_CHUNK..((ci + 1) * GRAD_CHUNK).min(batch) {
                    let tok = &tokens[bi * seq..(bi + 1) * seq];
                    let tgt = &targets[bi * seq..(bi + 1) * seq];
                    let (trace, logits) =
                        self.forward_item(layout_ref, w_ref, state, tok, sparse_ref, ws)?;
                    let (lsum_i, dlogits) =
                        ce_loss_and_grad(&logits, tgt, inv_count, layout_ref.vocab)?;
                    lsum += lsum_i as f64;
                    self.backward_item(
                        layout_ref, w_ref, &trace, tok, &dlogits, sparse_ref, &mut acc, ws,
                    )?;
                }
                Ok((lsum, acc, ws.bytes()))
            })
            .collect();
        // Reduce in ascending chunk order: the loss sum and every leaf
        // gradient see one fixed operation order at any pool size.  The
        // workspace high-water merges by max — observability only, and
        // never fed back into any computation.
        let mut acc = GradAcc::new(&layout);
        let mut loss_sum = 0.0f64;
        let mut ws_peak = 0u64;
        for (lsum, chunk_acc, wsb) in per_chunk? {
            loss_sum += lsum;
            acc.merge(&chunk_acc);
            ws_peak = ws_peak.max(wsb);
        }
        Ok((loss_sum as f32 * inv_count, acc, ws_peak))
    }

    /// One AdamW update from merged mini-batch gradients (host side),
    /// bumping the step counter.  The sequential tail of `train_step`,
    /// shared with the obs-instrumented variant so both apply the exact
    /// same update.
    fn apply_adamw(&self, rc: &RunConfig, state: &mut TrainState, acc: &GradAcc) -> Result<()> {
        // det: cast-bounded (step count, far below i32::MAX)
        let t = state.step.scalar()? as i32 + 1;
        state.step = HostTensor::scalar_i32(t);
        let hyper = AdamW { lr: rc.lr as f32, ..AdamW::default() };
        let TrainState { params, m, v, .. } = state;
        for (ix, g) in acc.g.iter().enumerate() {
            if let Some(g) = g {
                adamw_update(
                    params[ix].as_f32_mut()?,
                    g,
                    m[ix].as_f32_mut()?,
                    v[ix].as_f32_mut()?,
                    t,
                    &hyper,
                );
            }
        }
        Ok(())
    }

    /// Read-only phase-timed probe forward of the batch's first item
    /// (obs only): its own materialized weights and a fresh workspace,
    /// no RNG draws, no state mutation — so running it cannot move any
    /// bit of the training computation.  Fills the per-layer attention
    /// density, expert loads, and trace-size telemetry from the caches
    /// the forward materialized anyway.
    fn probe_forward(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        obs: &mut StepObs,
    ) -> Result<()> {
        let (_batch, seq) = self.check_batch(rc, tokens, None)?;
        let layout = self.layout(rc)?;
        let w = Weights::materialize(&layout, state)?;
        let sparse = self.sparse_layers(&layout, &w, seq)?;
        let mut ws = Workspace::default();
        let trace = self.forward_model_inner(
            &layout,
            &w,
            state,
            &tokens[..seq],
            sparse.as_deref(),
            &mut ws,
            Some(&mut obs.phases),
        )?;
        obs.attn_density = attn_density(&trace);
        obs.expert_load = expert_load(&trace);
        obs.trace_bytes = trace_bytes(&trace);
        Ok(())
    }

    /// Forward + backward for one batch without touching the optimizer:
    /// the mean loss plus the per-leaf gradient buffers (`None` for
    /// frozen leaves), indexed like `TrainState::params`.  Exposed for
    /// the finite-difference and determinism tests.
    #[doc(hidden)]
    #[allow(clippy::type_complexity)]
    pub fn loss_and_grads(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Vec<Option<Vec<f32>>>)> {
        let (loss, acc, _ws_peak) = self.grad_step(rc, state, tokens, targets)?;
        Ok((loss, acc.g))
    }

    /// Full-sequence forward logits (`[seq, vocab]`) for one sequence —
    /// the reference the inference subsystem's prefill/decode parity
    /// tests compare against (same weights materialization, same
    /// sequence-length-derived L, same kernels as training).
    #[doc(hidden)]
    pub fn forward_logits(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
    ) -> Result<Matrix> {
        let layout = self.layout(rc)?;
        let w = Weights::materialize(&layout, state)?;
        let sparse = self.sparse_layers(&layout, &w, tokens.len())?;
        let mut ws = Workspace::default();
        let (_, logits) =
            self.forward_item(&layout, &w, state, tokens, sparse.as_deref(), &mut ws)?;
        Ok(logits)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        format!("native-cpu x{}", rayon::current_num_threads())
    }

    fn has_mode(&self, rc: &RunConfig, _mode: Mode) -> bool {
        presets::model(&rc.model).is_ok()
    }

    fn workload(&self, rc: &RunConfig) -> Result<(usize, usize)> {
        let cfg = self.model_config(rc)?;
        let batch = rc.batch.max(1);
        let seq = rc.seq.clamp(1, cfg.max_seq);
        Ok((batch, seq))
    }

    fn vocab(&self, rc: &RunConfig) -> Result<usize> {
        Ok(self.model_config(rc)?.vocab_size)
    }

    fn init_state(&self, rc: &RunConfig) -> Result<TrainState> {
        let layout = self.layout(rc)?;
        let mut rng = Rng::new(rc.seed ^ 0x517A_11CE);
        let mut params = Vec::with_capacity(layout.n_leaves());
        for ix in 0..layout.n_leaves() {
            let (rows, cols) = layout.shapes[ix];
            let data = match layout.inits[ix] {
                LeafInit::Const(c) => vec![c; rows * cols],
                LeafInit::Normal(scale) => rng
                    .normal_vec(rows * cols)
                    .into_iter()
                    .map(|x| x * scale)
                    .collect(),
            };
            params.push(HostTensor::f32(vec![rows, cols], data));
        }
        TrainState::from_params(params, layout.paths.clone())
    }

    fn train_step(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f32> {
        let (loss, acc, _ws_peak) = self.grad_step(rc, state, tokens, targets)?;
        self.apply_adamw(rc, state, &acc)?;
        Ok(loss)
    }

    fn train_step_obs(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
        targets: &[i32],
        obs: &mut StepObs,
    ) -> Result<f32> {
        // Probe first (read-only), then the exact train_step sequence —
        // grad_step and apply_adamw — with the clock read around each at
        // this sequential boundary.  Same calls, same order, same bits;
        // `tests/obs_parity.rs` holds this against plain `train_step`.
        self.probe_forward(rc, state, tokens, obs)?;
        let (loss, acc, ws_peak) = obs
            .phases
            .time("fwd_bwd", || self.grad_step(rc, state, tokens, targets))?;
        obs.ws_bytes = ws_peak;
        obs.phases.time("optimizer", || self.apply_adamw(rc, state, &acc))?;
        Ok(loss)
    }

    fn eval_loss(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f32> {
        let (batch, seq) = self.check_batch(rc, tokens, Some(targets))?;
        let layout = self.layout(rc)?;
        let w = Weights::materialize(&layout, state)?;
        let sparse = self.sparse_layers(&layout, &w, seq)?;
        let inv_count = 1.0 / (batch * seq) as f32;
        // Item-parallel (no gradient memory to bound); the f64 per-item
        // losses are summed in ascending item order after the join.
        let layout_ref: &Layout = &layout;
        let w_ref = &w;
        let sparse_ref = sparse.as_deref();
        let per_item: Result<Vec<f64>> = (0..batch)
            .into_par_iter()
            .map_init(Workspace::default, |ws, bi| {
                let tok = &tokens[bi * seq..(bi + 1) * seq];
                let tgt = &targets[bi * seq..(bi + 1) * seq];
                let (_, logits) =
                    self.forward_item(layout_ref, w_ref, state, tok, sparse_ref, ws)?;
                Ok(ce_loss(&logits, tgt, layout_ref.vocab)? as f64)
            })
            .collect();
        let mut loss_sum = 0.0f64;
        for l in per_item? {
            loss_sum += l;
        }
        Ok(loss_sum as f32 * inv_count)
    }

    fn qa_choice_logits(
        &self,
        rc: &RunConfig,
        state: &TrainState,
        tokens: &[i32],
        answer_pos: &[usize],
        answer_tokens: &[u32; 4],
    ) -> Result<Vec<Vec<f32>>> {
        let (batch, seq) = self.check_batch(rc, tokens, None)?;
        if answer_pos.len() != batch {
            bail!("answer_pos has {} entries, batch is {batch}", answer_pos.len());
        }
        let layout = self.layout(rc)?;
        let w = Weights::materialize(&layout, state)?;
        let sparse = self.sparse_layers(&layout, &w, seq)?;
        let mut ws = Workspace::default();
        let mut out = Vec::with_capacity(batch);
        for (bi, &pos) in answer_pos.iter().enumerate() {
            if pos >= seq {
                bail!("answer slot {pos} outside sequence {seq}");
            }
            let tok = &tokens[bi * seq..(bi + 1) * seq];
            let trace =
                self.forward_model(&layout, &w, state, tok, sparse.as_deref(), &mut ws)?;
            // Only the answer slot's choice-token logits are read, so
            // skip the full (seq x vocab) readout: with the tied head
            // each choice logit is one d-length dot product against the
            // token's embedding row.
            let h = trace.xf.row(pos);
            out.push(
                answer_tokens
                    .iter()
                    .map(|&t| {
                        h.iter()
                            .zip(w.tok.row(t as usize))
                            .map(|(&a, &b)| a * b)
                            .sum::<f32>()
                    })
                    .collect::<Vec<f32>>(),
            );
        }
        Ok(out)
    }

    fn refresh_codebooks(
        &self,
        rc: &RunConfig,
        state: &mut TrainState,
        tokens: &[i32],
    ) -> Result<bool> {
        if rc.mode != Mode::Spt {
            return Ok(false);
        }
        let (batch, seq) = self.check_batch(rc, tokens, None)?;
        let layout = self.layout(rc)?;
        if layout.layers.iter().all(|lx| lx.pq_cb.is_none()) {
            return Ok(false);
        }
        let w = Weights::materialize(&layout, state)?;
        let sparse = self.sparse_layers(&layout, &w, seq)?;
        // Collect the current K and Q projections per (layer, head):
        // every layer quantizes its *own* pre-norm stream, so the
        // refresh runs the real stacked forward and reads each layer's
        // head-split projections out of the trace (queries and keys
        // share the codebook space — match counts compare their codes
        // directly).
        let n_layers = layout.layers.len();
        let dh = layout.d_head;
        let mut head_data: Vec<Vec<Vec<f32>>> =
            vec![vec![Vec::with_capacity(2 * batch * seq * dh); layout.heads]; n_layers];
        let mut ws = Workspace::default();
        for bi in 0..batch {
            let tok = &tokens[bi * seq..(bi + 1) * seq];
            let trace =
                self.forward_model(&layout, &w, state, tok, sparse.as_deref(), &mut ws)?;
            for (lt, per_head) in trace.layers.iter().zip(head_data.iter_mut()) {
                for (h, data) in per_head.iter_mut().enumerate() {
                    data.extend_from_slice(&lt.k[h].data);
                    data.extend_from_slice(&lt.q[h].data);
                }
            }
        }
        let stride = layout.pq_m * layout.pq_e * layout.pq_dsub;
        let mut refreshed = false;
        for (li, lx) in layout.layers.iter().enumerate() {
            let Some(cb_ix) = lx.pq_cb else { continue };
            let mut cbs = w.layers[li]
                .codebooks
                .clone()
                .context("spt mode without codebooks")?;
            for (cb, data) in cbs.iter_mut().zip(&head_data[li]) {
                pq::codebook_update(data, cb, 1.0);
            }
            let buf = state.params[cb_ix].as_f32_mut()?;
            for (h, cb) in cbs.iter().enumerate() {
                buf[h * stride..(h + 1) * stride].copy_from_slice(&cb.data);
            }
            refreshed = true;
        }
        Ok(refreshed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rc_model(model: &str, mode: Mode) -> RunConfig {
        RunConfig {
            model: model.into(),
            mode,
            batch: 2,
            seq: 24,
            seed: 7,
            ..RunConfig::default()
        }
    }

    fn rc(mode: Mode) -> RunConfig {
        rc_model("spt-nano", mode)
    }

    fn lm_batch(rc: &RunConfig, backend: &NativeBackend) -> (Vec<i32>, Vec<i32>) {
        let (batch, seq) = backend.workload(rc).unwrap();
        let vocab = backend.vocab(rc).unwrap();
        let mut corpus =
            crate::data::SyntheticCorpus::new(vocab, 4, 0.85, rc.seed);
        let mut tokens = Vec::new();
        let mut targets = Vec::new();
        for _ in 0..batch {
            let (x, y) = corpus.lm_pair(seq);
            tokens.extend(x.iter().map(|&t| t as i32));
            targets.extend(y.iter().map(|&t| t as i32));
        }
        (tokens, targets)
    }

    #[test]
    fn layouts_have_expected_leaf_counts() {
        // Per layer: 2 LN pairs + 6 projections = 10 leaves; shared:
        // tok, pos + final LN pair = 4; lora adds 12 per layer, spt adds
        // router + codebooks = 2 per layer.
        let cfg = presets::model("spt-nano").unwrap();
        assert_eq!(cfg.n_layers, 1);
        let full = Layout::new(&cfg, Mode::Full).unwrap();
        assert_eq!(full.n_leaves(), 4 + 10);
        let lora = Layout::new(&cfg, Mode::Lora).unwrap();
        assert_eq!(lora.n_leaves(), 4 + 10 + 12);
        let spt = Layout::new(&cfg, Mode::Spt).unwrap();
        assert_eq!(spt.n_leaves(), 4 + 10 + 12 + 2);
        assert_eq!(spt.paths.len(), spt.shapes.len());
        assert_eq!(spt.paths.len(), spt.inits.len());
        // Trainable sets: full trains the backbone + layer norms,
        // lora/spt train the adapters and the tied embedding/readout.
        assert!(full.trainable()[full.layers[0].wq]);
        assert!(full.trainable()[full.layers[0].ln1_scale]);
        assert!(full.trainable()[full.lnf_scale]);
        assert!(!spt.trainable()[spt.layers[0].wq]);
        assert!(!spt.trainable()[spt.layers[0].ln1_scale]);
        assert!(spt.trainable()[spt.tok], "tied head must train in spt");
        assert!(spt.trainable()[spt.layers[0].lora.as_ref().unwrap()[SLOT_Q].a]);
        assert!(!spt.trainable()[spt.layers[0].router.unwrap()]);
    }

    #[test]
    fn multi_layer_layout_stacks_leaf_groups() {
        let cfg = presets::model("spt-nano-l2").unwrap();
        assert_eq!(cfg.n_layers, 2);
        let full = Layout::new(&cfg, Mode::Full).unwrap();
        assert_eq!(full.n_leaves(), 4 + 2 * 10);
        assert_eq!(full.layers.len(), 2);
        let spt = Layout::new(&cfg, Mode::Spt).unwrap();
        assert_eq!(spt.n_leaves(), 4 + 2 * (10 + 12 + 2));
        // Each layer owns distinct leaves with layer-tagged paths.
        assert_ne!(spt.layers[0].wq, spt.layers[1].wq);
        assert!(spt.paths[spt.layers[0].wq].starts_with("['blocks'][0]"));
        assert!(spt.paths[spt.layers[1].wq].starts_with("['blocks'][1]"));
        assert!(spt.paths[spt.layers[1].pq_cb.unwrap()].contains("['pq']"));
    }

    #[test]
    fn train_step_runs_and_is_deterministic_per_seed() {
        for mode in Mode::ALL {
            let rc = rc(mode);
            let backend = NativeBackend::new();
            let (tokens, targets) = lm_batch(&rc, &backend);
            let run = || {
                let mut state = backend.init_state(&rc).unwrap();
                let mut out = Vec::new();
                for _ in 0..3 {
                    out.push(
                        backend
                            .train_step(&rc, &mut state, &tokens, &targets)
                            .unwrap(),
                    );
                }
                out
            };
            let a = run();
            let b = run();
            for (x, y) in a.iter().zip(&b) {
                assert!(x.is_finite(), "{mode:?} loss not finite");
                assert_eq!(x.to_bits(), y.to_bits(), "{mode:?} nondeterministic");
            }
        }
    }

    #[test]
    fn multi_layer_train_step_runs_in_all_modes() {
        for mode in Mode::ALL {
            let rc = rc_model("spt-nano-l2", mode);
            let backend = NativeBackend::new();
            let (tokens, targets) = lm_batch(&rc, &backend);
            let mut state = backend.init_state(&rc).unwrap();
            let l1 = backend
                .train_step(&rc, &mut state, &tokens, &targets)
                .unwrap();
            let l2 = backend
                .train_step(&rc, &mut state, &tokens, &targets)
                .unwrap();
            assert!(l1.is_finite() && l2.is_finite(), "{mode:?}");
            // Repeating the same batch must move the loss (all layers
            // receive gradient through the stack).
            assert_ne!(l1.to_bits(), l2.to_bits(), "{mode:?}: params frozen?");
        }
    }

    #[test]
    fn layout_cache_reuses_allocation_until_config_changes() {
        let backend = NativeBackend::new();
        let rc_spt = rc(Mode::Spt);
        let l1 = backend.layout(&rc_spt).unwrap();
        let l2 = backend.layout(&rc_spt).unwrap();
        assert!(Arc::ptr_eq(&l1, &l2), "unchanged config must hit the cache");
        let rc_full = rc(Mode::Full);
        let l3 = backend.layout(&rc_full).unwrap();
        assert!(!Arc::ptr_eq(&l1, &l3), "mode change must rebuild");
        assert_eq!(l3.mode, Mode::Full);
        // Switching back rebuilds (single-entry cache) and stays correct.
        let l4 = backend.layout(&rc_spt).unwrap();
        assert_eq!(l4.mode, Mode::Spt);
        assert_eq!(l4.n_leaves(), l1.n_leaves());
    }

    #[test]
    fn eval_loss_matches_magnitude_and_ignores_state() {
        let rc = rc(Mode::Spt);
        let backend = NativeBackend::new();
        let (tokens, targets) = lm_batch(&rc, &backend);
        let state = backend.init_state(&rc).unwrap();
        let e1 = backend.eval_loss(&rc, &state, &tokens, &targets).unwrap();
        let e2 = backend.eval_loss(&rc, &state, &tokens, &targets).unwrap();
        assert_eq!(e1.to_bits(), e2.to_bits());
        // Untrained loss should sit near ln(vocab).
        let lnv = (backend.vocab(&rc).unwrap() as f32).ln();
        assert!((e1 - lnv).abs() < 1.0, "eval {e1} vs ln(V) {lnv}");
    }

    #[test]
    fn codebook_refresh_updates_every_layer_only_in_spt() {
        let rc = rc_model("spt-nano-l2", Mode::Spt);
        let backend = NativeBackend::new();
        let (tokens, _) = lm_batch(&rc, &backend);
        let mut state = backend.init_state(&rc).unwrap();
        let layout = backend.layout(&rc).unwrap();
        let before: Vec<HostTensor> = layout
            .layers
            .iter()
            .map(|lx| state.params[lx.pq_cb.unwrap()].clone())
            .collect();
        let refreshed = backend.refresh_codebooks(&rc, &mut state, &tokens).unwrap();
        assert!(refreshed);
        for (li, (lx, b)) in layout.layers.iter().zip(&before).enumerate() {
            let after = &state.params[lx.pq_cb.unwrap()];
            assert!(
                b.max_abs_diff(after).unwrap() > 0.0,
                "layer {li} codebooks unchanged"
            );
        }
        // Full mode: refresh is a no-op.
        let rc_full = rc(Mode::Full);
        let mut s2 = backend.init_state(&rc_full).unwrap();
        let (t2, _) = lm_batch(&rc_full, &backend);
        assert!(!backend.refresh_codebooks(&rc_full, &mut s2, &t2).unwrap());
    }

    #[test]
    fn loss_and_grads_matches_train_step_loss_and_masks_frozen_leaves() {
        let rc = rc(Mode::Spt);
        let backend = NativeBackend::new();
        let (tokens, targets) = lm_batch(&rc, &backend);
        let state = backend.init_state(&rc).unwrap();
        let (loss, grads) = backend
            .loss_and_grads(&rc, &state, &tokens, &targets)
            .unwrap();
        let mut state2 = state.clone();
        let step_loss = backend
            .train_step(&rc, &mut state2, &tokens, &targets)
            .unwrap();
        assert_eq!(loss.to_bits(), step_loss.to_bits());
        let layout = backend.layout(&rc).unwrap();
        for (ix, (g, &on)) in grads.iter().zip(layout.trainable().iter()).enumerate() {
            assert_eq!(g.is_some(), on, "leaf {ix} gradient mask mismatch");
        }
        // The tied head gradient is live (readout + embedding paths).
        let gtok = grads[layout.tok].as_ref().unwrap();
        assert!(gtok.iter().any(|&x| x != 0.0), "tied tok grad all-zero");
    }
}
