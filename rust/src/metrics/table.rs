//! Markdown/console table renderer — every bench prints the paper's
//! table/figure rows through this.

/// A simple aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as a GitHub-flavoured markdown table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for width in &w {
            sep.push_str(&format!("{}-|", "-".repeat(width + 2 - 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Render as CSV (for plotting scripts).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            let escaped: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            out.push_str(&escaped.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["System", "Time"]);
        t.row(&["Full".into(), "188.4 ms".into()]);
        t.row(&["SPT".into(), "106.0 ms".into()]);
        let s = t.render();
        assert!(s.contains("### Demo"));
        assert!(s.contains("| Full"));
        assert!(s.lines().count() >= 5);
        // All data lines have equal width.
        let widths: Vec<usize> = s
            .lines()
            .filter(|l| l.starts_with('|'))
            .map(|l| l.len())
            .collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{widths:?}");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["x,y".into(), "plain".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\",plain"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
