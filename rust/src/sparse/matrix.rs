//! Dense row-major f32 matrix — the substrate's working representation.

use crate::util::rng::Rng;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal() * scale).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self @ other` — naive blocked GEMM (sufficient for substrate-scale
    /// baselines; the heavy GEMMs run inside XLA).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = other.row(k);
                for (j, &b) in b_row.iter().enumerate() {
                    out_row[j] += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn relu(&self) -> Matrix {
        self.map(|x| x.max(0.0))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Row-wise softmax (dense attention baseline).
    pub fn softmax_rows(&self) -> Matrix {
        let mut out = self.clone();
        for r in 0..self.rows {
            let row = out.row_mut(r);
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for x in row.iter_mut() {
                *x = (*x - mx).exp();
                sum += *x;
            }
            for x in row.iter_mut() {
                *x /= sum.max(1e-30);
            }
        }
        out
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut eye = Matrix::zeros(4, 4);
        for i in 0..4 {
            *eye.at_mut(i, i) = 1.0;
        }
        assert!(a.matmul(&eye).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(3, 5, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(6, 9, 2.0, &mut rng);
        let s = a.softmax_rows();
        for r in 0..6 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_clamps() {
        let a = Matrix::from_vec(1, 3, vec![-1.0, 0.0, 2.0]);
        assert_eq!(a.relu().data, vec![0.0, 0.0, 2.0]);
    }
}
