//! Paper Fig. 3: CDF of softmax attention weights — the observation
//! motivating sparse MHA (top-15% of weights ~ 90% of the mass on
//! trained models).
//!
//! Series are generated from the substrate at several query/key
//! correlation strengths (trained attention is highly correlated; random
//! init is not), showing how the skew the paper measured emerges.

mod common;

use spt::metrics::Table;
use spt::sparse::attention::attention_weight_cdf;
use spt::sparse::Matrix;
use spt::util::rng::Rng;

fn main() {
    let (n, d) = (512usize, 64usize);
    let mut rng = Rng::new(7);
    let k = Matrix::randn(n, d, 1.0, &mut rng);
    let mut table = Table::new(
        "Fig. 3 — CDF of softmax attention weights (n=512, d_head=64)",
        &["kept fraction", "random init", "corr=1.0", "corr=2.0 (trained-like)"],
    );
    let mut series = Vec::new();
    for corr in [0.0f32, 1.0, 2.0] {
        let noise = Matrix::randn(n, d, 1.0, &mut rng);
        let q = Matrix::from_vec(
            n,
            d,
            k.data
                .iter()
                .zip(&noise.data)
                .map(|(a, b)| corr * a + b)
                .collect(),
        );
        series.push(attention_weight_cdf(&q, &k, 20, false));
    }
    for i in 0..series[0].len() {
        table.row(&[
            format!("{:.2}", series[0][i].0),
            format!("{:.3}", series[0][i].1),
            format!("{:.3}", series[1][i].1),
            format!("{:.3}", series[2][i].1),
        ]);
    }
    common::emit("fig3_attn_cdf", &table);

    // Headline check (paper: top 15% ~ 90% of mass for trained attention).
    let at15 = series[2]
        .iter()
        .find(|(f, _)| *f >= 0.15)
        .map(|(_, m)| *m)
        .unwrap_or(0.0);
    println!(
        "[fig3] trained-like attention: top-15% of weights carry {:.0}% of the mass (paper: ~90%)",
        at15 * 100.0
    );
}
