//! Memory planner: given a GPU budget, which (mode, sequence length,
//! batch) configurations fit?  The deployment-facing use of the memory
//! model behind Table 3's "Max Length" and Fig. 9.
//!
//!     cargo run --release --example memory_planner -- \
//!         [--block opt-2560] [--layers 32] [--budget-gb 24] [--batch 16]

use anyhow::Result;
use spt::config::{presets, Mode};
use spt::memmodel::{block_peak, max_seq_under_budget, BlockWorkload};
use spt::metrics::Table;
use spt::util::fmt_bytes;

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> Result<()> {
    let block = arg("--block", "opt-2560");
    let layers: usize = arg("--layers", "32").parse()?;
    let budget_gb: f64 = arg("--budget-gb", "24").parse()?;
    let batch: usize = arg("--batch", "16").parse()?;
    let vocab: usize = arg("--vocab", "50272").parse()?;
    let cfg = presets::block(&block)?;
    let budget = (budget_gb * (1u64 << 30) as f64) as u64;

    println!(
        "# memory plan: {block} x{layers} layers, batch {batch}, budget {budget_gb} GB\n"
    );
    let mut t = Table::new(
        "Max sequence length before OOM (Table 3 protocol, offloading modeled)",
        &["System", "Max Length", "x Full"],
    );
    let mut full_len = 0usize;
    for mode in Mode::ALL {
        let len = max_seq_under_budget(&cfg, mode, batch, layers, vocab, budget, 128);
        if mode == Mode::Full {
            full_len = len;
        }
        t.row(&[
            mode.as_str().to_string(),
            len.to_string(),
            if full_len > 0 {
                format!("{:.2}x", len as f64 / full_len as f64)
            } else {
                "-".into()
            },
        ]);
    }
    println!("{}", t.render());

    let mut t2 = Table::new(
        "Per-block peak by sequence length",
        &["Seq", "Full", "LoRA", "SPT"],
    );
    for seq in [256usize, 512, 1024, 2048, 4096] {
        let wl = BlockWorkload { batch, seq };
        let row: Vec<String> = Mode::ALL
            .iter()
            .map(|&m| fmt_bytes(block_peak(&cfg, m, &wl).peak_bytes()))
            .collect();
        t2.row(&[seq.to_string(), row[0].clone(), row[1].clone(), row[2].clone()]);
    }
    println!("{}", t2.render());

    // What dominates?  Show the SPT breakdown at the budget edge.
    let spt_len = max_seq_under_budget(&cfg, Mode::Spt, batch, layers, vocab, budget, 128);
    if spt_len > 0 {
        println!("# SPT per-block breakdown at its max length ({spt_len})");
        let bd = block_peak(&cfg, Mode::Spt, &BlockWorkload { batch, seq: spt_len });
        println!("{}", bd.render());
    }
    Ok(())
}
