//! Block/module profiler example — the paper's `script/profile.py` (§A.3)
//! equivalent:
//!
//!     cargo run --release --example block_profile -- \
//!         [--name opt-2048] [--tuning sparse|lora|full] [--module mha|ffn|both]
//!
//! Prints module timings (this testbed) + the analytic memory breakdown
//! at the paper's workload, mirroring the sample output in Fig. 12.

use anyhow::Result;
use spt::config::{presets, Mode};
use spt::coordinator::profile::{profile_block, profile_module};
use spt::memmodel::{block_peak, BlockWorkload};
use spt::runtime::Engine;
use spt::util::{fmt_bytes, fmt_duration};

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> Result<()> {
    let dir = std::env::var("SPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let name = arg("--name", "opt-2048");
    let tuning = arg("--tuning", "sparse");
    let module = arg("--module", "both");
    let mode = Mode::parse(if tuning == "sparse" { "spt" } else { &tuning })?;
    let engine = Engine::new(&dir)?;

    println!("# profile: name={name} tuning={tuning} module={module}");

    // Module-level timings (mha/ffn artifacts exist for opt-2048 and
    // llama-4096 by default).
    let variants: &[(&str, &str)] = match mode {
        Mode::Full => &[("mha", "full"), ("ffn", "full")],
        Mode::Lora => &[("mha", "lora"), ("ffn", "lora")],
        Mode::Spt => &[("mha", "spt_l8"), ("ffn", "spt_b12")],
    };
    for (kind, variant) in variants {
        if *module != *"both" && *module != **kind {
            continue;
        }
        let art = format!("{kind}_{name}_{variant}");
        if engine.manifest().get(&art).is_err() {
            println!("  ({art} not in manifest — module artifacts exist for opt-2048/llama-4096)");
            continue;
        }
        let row = profile_module(&engine, kind, &name, variant, 1, 5)?;
        println!(
            "  {:<4} {:<8} fwd+bwd {:<12} ({:.0} tokens/s on this testbed)",
            kind.to_uppercase(),
            variant,
            fmt_duration(row.time.median()),
            row.tokens_per_sec
        );
    }

    // Whole-block timing if present.
    let block_art = format!("block_step_{name}_{}", mode.as_str());
    if engine.manifest().get(&block_art).is_ok() {
        let row = profile_block(&engine, &name, mode, 1, 3)?;
        println!(
            "  BLOCK fwd+bwd {:<12} ({:.0} tokens/s)",
            fmt_duration(row.time.median()),
            row.tokens_per_sec
        );
    }

    // Memory breakdown at the paper's workload (Fig. 12's memory summary).
    let cfg = presets::block(&name)?;
    let bd = block_peak(&cfg, mode, &BlockWorkload { batch: 16, seq: 512 });
    println!("\n# peak memory statistics (analytic, bs 16 x seq 512)");
    println!("{}", bd.render());
    println!(
        "peak {} | trainable params {}",
        fmt_bytes(bd.peak_bytes()),
        cfg.trainable_params(mode)
    );
    Ok(())
}
