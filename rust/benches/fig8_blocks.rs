//! Paper Fig. 8: fine-tuning throughput (a) and peak memory (b) for the
//! five Table-2 Transformer blocks under Full / LoRA / SPT.
//!
//! Time: measured fwd+bwd of the block artifacts on this testbed
//! (bs 1, seq 128 — scaled from the paper's bs 16, seq 512 for CPU
//! budget; relative speedups are shape-driven).  Memory: analytic model
//! at the paper's workload.  Paper shape: SPT 1.10-2.20x throughput vs
//! Full (max on llama-4096); 50-73% of Full's memory (min on opt-1024).

mod common;

#[cfg(feature = "xla")]
use spt::config::Mode;
#[cfg(feature = "xla")]
use spt::coordinator::profile::profile_block;
#[cfg(feature = "xla")]
use spt::metrics::Table;
#[cfg(feature = "xla")]
use spt::util::fmt_bytes;

#[cfg(not(feature = "xla"))]
fn main() {
    println!("[fig8] skipped: artifact profiling needs `--features xla`");
}

#[cfg(feature = "xla")]
fn main() {
    let Some(engine) = common::engine_or_skip("fig8") else { return };
    let (w, s) = (common::warmup(), common::samples());
    let blocks = ["opt-1024", "opt-2048", "opt-2560", "llama-2560", "llama-4096"];
    let mut table = Table::new(
        "Fig. 8 — throughput (a) and peak memory (b) per block",
        &["Block", "Mode", "tokens/s", "speedup vs full", "mem @bs16,seq512", "% of full"],
    );
    for cfg in blocks {
        let mut base_tps = None;
        let mut base_mem = None;
        for mode in Mode::ALL {
            let name = format!("block_step_{cfg}_{}", mode.as_str());
            if engine.manifest().get(&name).is_err() {
                println!("[fig8] missing {name}");
                continue;
            }
            let row = profile_block(&engine, cfg, mode, w, s).expect("profile");
            if mode == Mode::Full {
                base_tps = Some(row.tokens_per_sec);
                base_mem = Some(row.model_mem_bytes);
            }
            table.row(&[
                cfg.to_string(),
                mode.as_str().to_string(),
                format!("{:.1}", row.tokens_per_sec),
                base_tps
                    .map(|b| format!("{:.2}x", row.tokens_per_sec / b))
                    .unwrap_or_default(),
                fmt_bytes(row.model_mem_bytes),
                base_mem
                    .map(|b| {
                        format!("{:.0}%", 100.0 * row.model_mem_bytes as f64 / b as f64)
                    })
                    .unwrap_or_default(),
            ]);
        }
    }
    common::emit("fig8_blocks", &table);
}
